"""Shared artifact paths and (de)serialization helpers for the build pipeline.

Everything the Rust runtime consumes lives under ``artifacts/``:

    artifacts/
      data/               tokenizer.json, *.bin token streams, tasks/*.jsonl
      models/<name>/      config.json, ckpt.npz, anyprec.npz, fisher.npz
      calib/<name>/<budget>/<tag>/   dpllm.json, estimators.npz, ...
      hlo/<name>/         decode_step.hlo.txt, prefill_<P>.hlo.txt, ...
      manifest.json       index of everything above

npz files are written uncompressed (faster for the Rust zip reader).
"""

from __future__ import annotations

import json
import os

import numpy as np

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))
ART = os.path.join(REPO_ROOT, "artifacts")


def art(*parts: str) -> str:
    p = os.path.join(ART, *parts)
    os.makedirs(os.path.dirname(p), exist_ok=True)
    return p


def save_npz(path: str, arrays: dict) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in arrays.items()})


def load_npz(path: str) -> dict:
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def save_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)


def load_json(path: str):
    with open(path) as f:
        return json.load(f)


def write_jsonl(path: str, rows: list[dict]) -> None:
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")


def stale(out_paths, in_paths) -> bool:
    """True if any output is missing or older than the newest input."""
    outs = [out_paths] if isinstance(out_paths, str) else list(out_paths)
    ins = [in_paths] if isinstance(in_paths, str) else list(in_paths)
    if any(not os.path.exists(o) for o in outs):
        return True
    newest_in = max((os.path.getmtime(i) for i in ins if os.path.exists(i)),
                    default=0.0)
    return min(os.path.getmtime(o) for o in outs) < newest_in
