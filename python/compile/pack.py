"""Pack the any-precision store into the DPAK container (DESIGN.md §Artifact).

Mirrors ``rust/src/anyprec/dpak.rs`` byte-for-byte:

    offset 0   magic  b"DPAK"
           4   u32 LE format version (1)
           8   u64 LE manifest byte length
          16   UTF-8 JSON manifest (compact, keys sorted), space-padded
           ...zero padding to a 64-byte boundary...
               sections, each 64-byte aligned, zero-padded between

Sections are plane-major (every group's bitplane 0, then bitplane 1, …)
followed by the LUTs by ascending bitwidth, so the byte range a
``max_bits`` tier needs is a *prefix* of the data region.  Digests are
``crc32:%08x`` (zlib.crc32 == the Rust ``util::digest`` IEEE CRC-32),
and the container ``version`` is the CRC-32 of all section digest
strings in canonical order — the Rust writer produces the identical
version for identical weights, which is what the serve-time version
gate compares.

Usage: ``python -m compile.pack --model dpl-tiny``
"""

from __future__ import annotations

import argparse
import json
import zlib

import numpy as np

from . import io_utils as io
from .model import GROUPS

MAGIC = b"DPAK"
FORMAT_VERSION = 1
ALIGN = 64
MIN_BITS, MAX_BITS = 3, 6


def _align_up(x: int) -> int:
    return (x + ALIGN - 1) // ALIGN * ALIGN


def _digest(b: bytes) -> str:
    return "crc32:%08x" % (zlib.crc32(b) & 0xFFFFFFFF)


def _sections(planes: dict, luts: dict) -> list[dict]:
    """Canonical section list: plane-major, then LUTs ascending.

    ``planes[g]`` is u8 ``[L, 6, out, in/8]`` (the anyprec.npz layout);
    ``luts[g][b]`` is f32 ``[L, out, 2**b]``.
    """
    secs = []
    for p in range(MAX_BITS):
        for g in GROUPS:
            arr = planes[g][:, p]  # [L, out, in/8], layer-major payload
            payload = np.ascontiguousarray(arr).tobytes()
            lb = arr.shape[1] * arr.shape[2]
            layers = [_digest(payload[l * lb:(l + 1) * lb])
                      for l in range(arr.shape[0])]
            secs.append({"name": f"plane{p}/{g}", "group": g, "plane": p,
                         "payload": payload, "digest": _digest(payload),
                         "layers": layers})
    for b in range(MIN_BITS, MAX_BITS + 1):
        for g in GROUPS:
            payload = np.ascontiguousarray(
                luts[g][b].astype("<f4")).tobytes()
            secs.append({"name": f"lut{b}/{g}", "group": g, "bits": b,
                         "payload": payload, "digest": _digest(payload)})
    return secs


def _manifest(model: str, version: str, planes: dict, secs: list[dict]) -> dict:
    groups = {}
    for g in GROUPS:
        pl = planes[g]
        entries = [None] * MAX_BITS
        lut_entries = {}
        for s in secs:
            if s["group"] != g:
                continue
            e = {"off": s["off"], "len": len(s["payload"]),
                 "digest": s["digest"]}
            if "plane" in s:
                e["layers"] = s["layers"]
                entries[s["plane"]] = e
            else:
                lut_entries[str(s["bits"])] = e
        groups[g] = {"n_layers": int(pl.shape[0]), "out": int(pl.shape[2]),
                     "in": int(pl.shape[3] * 8), "planes": entries,
                     "luts": lut_entries}
    return {"format": "dpak", "format_version": FORMAT_VERSION,
            "model": model, "version": version, "dtype": "f32",
            "min_bits": MIN_BITS, "max_bits": MAX_BITS, "groups": groups}


def _dump(obj) -> str:
    # Byte-identical to the Rust util::json dump: compact separators,
    # keys sorted (BTreeMap ordering == lexicographic for ASCII keys).
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def write_dpak(path: str, model: str, planes: dict, luts: dict) -> str:
    """Write one container; returns its content version string."""
    for g in GROUPS:
        if g not in planes or g not in luts:
            raise ValueError(f"pack: store missing group {g}")
        if planes[g].shape[1] != MAX_BITS:
            raise ValueError(f"pack: {g} has {planes[g].shape[1]} planes, "
                             f"need {MAX_BITS}")
    secs = _sections(planes, luts)
    version = _digest("".join(s["digest"] for s in secs).encode())

    # Offsets are absolute and appear inside the manifest, whose length
    # moves the data region: iterate to a fixed point, space-padding if
    # the final render lands short (the Rust parser skips trailing ws).
    mlen = 0
    while True:
        off = _align_up(16 + mlen)
        for s in secs:
            s["off"] = off
            off = _align_up(off + len(s["payload"]))
        rendered = _dump(_manifest(model, version, planes, secs)).encode()
        if len(rendered) <= mlen:
            manifest = rendered + b" " * (mlen - len(rendered))
            break
        mlen = len(rendered)

    end = secs[-1]["off"] + len(secs[-1]["payload"])
    out = bytearray(end)
    out[0:4] = MAGIC
    out[4:8] = FORMAT_VERSION.to_bytes(4, "little")
    out[8:16] = len(manifest).to_bytes(8, "little")
    out[16:16 + len(manifest)] = manifest
    for s in secs:
        out[s["off"]:s["off"] + len(s["payload"])] = s["payload"]
    with open(path, "wb") as f:
        f.write(out)
    return version


def pack_model(name: str, out_path: str | None = None) -> str:
    """Repack ``models/<name>/anyprec.npz`` into ``anyprec.dpak``."""
    z = io.load_npz(io.art("models", name, "anyprec.npz"))
    planes = {g: np.asarray(z[f"planes_{g}"], dtype=np.uint8) for g in GROUPS}
    luts = {g: {b: np.asarray(z[f"lut{b}_{g}"], dtype=np.float32)
                for b in range(MIN_BITS, MAX_BITS + 1)} for g in GROUPS}
    path = out_path or io.art("models", name, "anyprec.dpak")
    version = write_dpak(path, name, planes, luts)
    print(f"[pack] {path} version {version}")
    return version


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    pack_model(args.model, args.out)


if __name__ == "__main__":
    main()
