"""Byte-level BPE tokenizer: trainer and encoder.

Build-time only; the runtime encoder/decoder lives in ``rust/src/tokenizer``
and consumes the ``tokenizer.json`` this module writes.  The scheme is a
small GPT-2-style byte BPE:

  * base vocabulary = 256 byte tokens (+ <pad>=256, <bos>=257, <eos>=258),
  * pre-tokenization splits on whitespace, keeping a leading space attached
    to the following word (so ``" the"`` is one pre-token),
  * merges are learned on word-type frequencies (fast, corpus-size
    independent after the counting pass),
  * encoding applies merges greedily by rank within each pre-token.

Vocab ids: 0..255 bytes, 256..258 specials, 259.. merge results.
"""

from __future__ import annotations

import json
import re
from collections import Counter

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
N_SPECIAL = 3

_PRETOK = re.compile(rb" ?[^\s]+|\s+")


def pretokenize(data: bytes) -> list[bytes]:
    return _PRETOK.findall(data)


def train_bpe(text: str, vocab_size: int = 1024, max_word_types: int = 60000):
    """Learn merge rules. Returns list of (left_id, right_id) in rank order."""
    data = text.encode("utf-8")
    words = Counter(pretokenize(data))
    if len(words) > max_word_types:
        words = Counter(dict(words.most_common(max_word_types)))

    # Each word is a tuple of token ids, starting as raw bytes.
    seqs: dict[tuple[int, ...], int] = {
        tuple(w): c for w, c in words.items()
    }
    merges: list[tuple[int, int]] = []
    next_id = 256 + N_SPECIAL
    target_merges = vocab_size - next_id
    for _ in range(target_merges):
        pair_counts: Counter = Counter()
        for seq, c in seqs.items():
            for a, b in zip(seq, seq[1:]):
                pair_counts[(a, b)] += c
        if not pair_counts:
            break
        (a, b), cnt = pair_counts.most_common(1)[0]
        if cnt < 2:
            break
        merges.append((a, b))
        new_seqs: dict[tuple[int, ...], int] = {}
        for seq, c in seqs.items():
            out = []
            i = 0
            while i < len(seq):
                if i + 1 < len(seq) and seq[i] == a and seq[i + 1] == b:
                    out.append(next_id)
                    i += 2
                else:
                    out.append(seq[i])
                    i += 1
            t = tuple(out)
            new_seqs[t] = new_seqs.get(t, 0) + c
        seqs = new_seqs
        next_id += 1
    return merges


class Tokenizer:
    def __init__(self, merges: list[tuple[int, int]]):
        self.merges = merges
        self.ranks = {pair: i for i, pair in enumerate(merges)}
        self.vocab_size = 256 + N_SPECIAL + len(merges)
        # id -> byte string, for decoding
        self._pieces: list[bytes] = [bytes([i]) for i in range(256)]
        self._pieces += [b"<pad>", b"<bos>", b"<eos>"]
        for a, b in merges:
            self._pieces.append(self._pieces[a] + self._pieces[b])

    # -- encoding ---------------------------------------------------------
    def _bpe_word(self, word: bytes) -> list[int]:
        seq = list(word)
        if len(seq) < 2:
            return seq
        while True:
            best_rank = None
            best_i = -1
            for i in range(len(seq) - 1):
                r = self.ranks.get((seq[i], seq[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_i = r, i
            if best_rank is None:
                return seq
            seq[best_i:best_i + 2] = [256 + N_SPECIAL + best_rank]

    def encode(self, text: str, bos: bool = False, eos: bool = False) -> list[int]:
        ids: list[int] = [BOS_ID] if bos else []
        for w in pretokenize(text.encode("utf-8")):
            ids.extend(self._bpe_word(w))
        if eos:
            ids.append(EOS_ID)
        return ids

    def decode(self, ids) -> str:
        out = b"".join(
            self._pieces[i] for i in ids
            if 0 <= i < len(self._pieces) and i not in (PAD_ID, BOS_ID, EOS_ID)
        )
        return out.decode("utf-8", errors="replace")

    # -- serialization ----------------------------------------------------
    def save(self, path: str) -> None:
        obj = {
            "type": "byte_bpe",
            "vocab_size": self.vocab_size,
            "specials": {"pad": PAD_ID, "bos": BOS_ID, "eos": EOS_ID},
            "merges": [[a, b] for a, b in self.merges],
        }
        with open(path, "w") as f:
            json.dump(obj, f)

    @classmethod
    def load(cls, path: str) -> "Tokenizer":
        with open(path) as f:
            obj = json.load(f)
        assert obj["type"] == "byte_bpe"
        return cls([tuple(m) for m in obj["merges"]])


def encode_to_bin(tok: Tokenizer, text: str, path: str) -> int:
    """Tokenize `text` and write a little-endian uint16 binary file."""
    ids = tok.encode(text)
    arr = np.asarray(ids, dtype=np.uint16)
    arr.tofile(path)
    return len(ids)
