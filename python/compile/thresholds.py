"""Phase 3: average-precision → threshold translation + estimator fitting.

For each linear with candidate set (l, h) and fine-tuned average precision
p (from Phase 2):

  * run the calibration stream through the soft-mixed model and record,
    per token: the exact relative error  e = ‖ΔW x‖  (ΔW = W_h − W_l),
    the input norm ‖x‖, and the *uncalibrated* JL projection ‖G₀x‖ with
    G₀ = AΔW, A ~ N(0, 1/k), k = 64  (paper §5.1);
  * threshold  T = r-quantile of the e distribution, r = 1 − (p − l)
    (paper Algorithm 1 Phase 3);
  * hybrid estimator selection: fit e ≈ a‖x‖ + b; if R² ≥ R²_th (0.9) the
    layer uses the linear estimator, else the JL estimator with the
    per-layer scale calibration  c = Σ(e·‖G₀x‖)/Σ‖G₀x‖²,  G = c·G₀
    (the paper's "tune G to match the input distribution").

Writes ``dpllm_<tag>.json`` (runtime selector config consumed by
rust/src/selector) and ``estimators_<tag>.npz`` (calibrated G stacks).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from . import io_utils as io
from .assign import linear_index, targets_for_budget
from .finetune_p import load_level_stacks, mixed_forward
from .model import (GROUPS, ModelConfig, PRESETS, apply_rope, rmsnorm,
                    rope_tables)
from .quantize import calib_batches

R2_TH = 0.9
K_PROJ = 64


# ---------------------------------------------------------------------------
# Collector forward: soft-mixed activations + per-linear statistics.
# ---------------------------------------------------------------------------


def collect_stats(nl: dict, levels: dict, p: dict, dw: dict, g0: dict,
                  cfg: ModelConfig, tokens: jnp.ndarray):
    """Returns {g: (e, xn, gn)} with shapes [B, S, L] each.

    e  = ‖ΔW x‖ exact relative error per token,
    xn = ‖x‖ input norm, gn = ‖G₀ x‖ raw JL estimate.
    Activations flow through the soft-mixed weights (the runtime stream is
    the hard-switched version; the soft mix is its expectation).
    """
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x = nl["tok_emb"][tokens]
    pos = jnp.arange(S)
    cos, sin = rope_tables(cfg, pos)
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    mask = jnp.tril(jnp.ones((S, S), bool))

    def mixw(levels_l, p_i):
        l_f = jnp.floor(p_i)
        l_idx = jnp.clip(l_f.astype(jnp.int32) - 3, 0, 3)
        h_idx = jnp.clip(l_idx + 1, 0, 3)
        r = jnp.clip(1.0 - (p_i - l_f), 0.0, 1.0)
        wl = jax.lax.dynamic_index_in_dim(levels_l, l_idx, 0, keepdims=False)
        wh = jax.lax.dynamic_index_in_dim(levels_l, h_idx, 0, keepdims=False)
        return r * wl + (1.0 - r) * wh

    def stats(x_in, dw_l, g0_l):
        """x_in [B,S,n] -> (e, xn, gn) each [B,S]."""
        e = jnp.linalg.norm(x_in @ dw_l.T, axis=-1)
        xn = jnp.linalg.norm(x_in, axis=-1)
        gn = jnp.linalg.norm(x_in @ g0_l.T, axis=-1)
        return jnp.stack([e, xn, gn], -1)  # [B,S,3]

    def block(x, layer):
        ln1, ln2, lv, pv, dwl, g0l = layer
        h = rmsnorm(x, ln1)
        st = {}
        for g in ("wq", "wk", "wv"):
            st[g] = stats(h, dwl[g], g0l[g])
        q = (h @ mixw(lv["wq"], pv["wq"]).T).reshape(B, S, H, hd)
        k = (h @ mixw(lv["wk"], pv["wk"]).T).reshape(B, S, H, hd)
        v = (h @ mixw(lv["wv"], pv["wv"]).T).reshape(B, S, H, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o_in = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, H * hd)
        st["wo"] = stats(o_in, dwl["wo"], g0l["wo"])
        x = x + o_in @ mixw(lv["wo"], pv["wo"]).T
        h2 = rmsnorm(x, ln2)
        st["wg"] = stats(h2, dwl["wg"], g0l["wg"])
        st["wu"] = stats(h2, dwl["wu"], g0l["wu"])
        gate = jax.nn.silu(h2 @ mixw(lv["wg"], pv["wg"]).T)
        up = h2 @ mixw(lv["wu"], pv["wu"]).T
        mid = gate * up
        st["wd"] = stats(mid, dwl["wd"], g0l["wd"])
        x = x + mid @ mixw(lv["wd"], pv["wd"]).T
        out = jnp.stack([st[g] for g in GROUPS], 0)  # [7, B, S, 3]
        return x, out

    xs = (nl["ln1"], nl["ln2"], levels, p, dw, g0)
    _, per_layer = jax.lax.scan(block, x, xs)  # [L, 7, B, S, 3]
    return per_layer


# ---------------------------------------------------------------------------
# Candidate pairs + ΔW / G₀ construction.
# ---------------------------------------------------------------------------


def candidate_pair(p_i: float, fixed_lh=None) -> tuple[int, int]:
    if fixed_lh is not None:
        return int(fixed_lh[0]), int(fixed_lh[1])
    l = int(np.floor(p_i))
    h = int(np.ceil(p_i))
    return l, max(h, l)


def build_dw_g0(levels: dict, p: dict, cfg: ModelConfig, seed: int,
                fixed_lh=None):
    """ΔW = W_h − W_l and G₀ = AΔW stacks per group (numpy)."""
    rng = np.random.default_rng(seed)
    dw, g0, pairs = {}, {}, {}
    for g in GROUPS:
        out_d, in_d = cfg.group_shape(g)
        L = cfg.n_layers
        dw_g = np.zeros((L, out_d, in_d), np.float32)
        g0_g = np.zeros((L, K_PROJ, in_d), np.float32)
        pr = []
        lv = np.asarray(levels[g])
        for layer in range(L):
            l, h = candidate_pair(float(p[g][layer]), fixed_lh)
            pr.append((l, h))
            if h > l:
                d = lv[layer, h - 3] - lv[layer, l - 3]
                dw_g[layer] = d
                A = rng.standard_normal((K_PROJ, out_d)).astype(np.float32)
                A /= np.sqrt(K_PROJ)
                g0_g[layer] = A @ d
        dw[g] = jnp.asarray(dw_g)
        g0[g] = jnp.asarray(g0_g)
        pairs[g] = pr
    return dw, g0, pairs


# ---------------------------------------------------------------------------
# Main calibration.
# ---------------------------------------------------------------------------


def calibrate(name: str, budget: int, tag: str, calib_seqs: int = 24,
              seq: int = 128, calib_set: str = "synthweb",
              fixed_lh=None) -> None:
    cfg = PRESETS[name]
    base = ("calib", name, f"budget{budget}")
    pconf = io.load_json(io.art(*base, f"dpllm_p_{tag}.json"))
    idx = linear_index(cfg)
    p_list = pconf["p"]
    p = {g: jnp.asarray([p_list[i] for i, (layer, gg) in enumerate(idx)
                         if gg == g]) for g in GROUPS}

    nl_all = io.load_npz(io.art("models", name, "ckpt.npz"))
    nl = {k: jnp.asarray(v) for k, v in nl_all.items() if k not in GROUPS}
    levels = load_level_stacks(name, cfg)
    dw, g0, pairs = build_dw_g0(levels, p, cfg, seed=1234, fixed_lh=fixed_lh)

    calib = calib_batches(io.art("data", f"{calib_set}_calib.bin"),
                          calib_seqs, seq, seed=29)
    coll = jax.jit(lambda toks: collect_stats(nl, levels, p, dw, g0, cfg, toks))
    chunks = []
    bsz = 4
    for i in range(0, len(calib), bsz):
        st = coll(jnp.asarray(calib[i:i + bsz]))      # [L, 7, B, S, 3]
        chunks.append(np.asarray(st))
    st = np.concatenate(chunks, axis=2)               # [L, 7, ΣB, S, 3]
    L = cfg.n_layers

    cal_g = {}
    records = []
    n_lin_est, n_jl_est = 0, 0
    for li, (layer, g) in enumerate(idx):
        gi = GROUPS.index(g)
        e = st[layer, gi, :, :, 0].ravel()
        xn = st[layer, gi, :, :, 1].ravel()
        gn = st[layer, gi, :, :, 2].ravel()
        l, h = pairs[g][layer]
        p_i = float(p_list[li])
        r = 1.0 - (p_i - l) if h > l else 1.0
        if h == l or e.max() <= 1e-12:
            rec = {"l": l, "h": h, "p": p_i, "thr": float("1e30"),
                   "use_lin": 1, "lin_a": 0.0, "lin_b": 0.0,
                   "r2": 1.0, "g_scale": 0.0}
            cal_g.setdefault(g, np.zeros((L, K_PROJ, cfg.group_shape(g)[1]),
                                         np.float32))
            records.append(rec)
            continue
        # Threshold = r-quantile of the relative-error distribution.
        if r >= 1.0 - 1e-9:
            thr = float(e.max() * 1.0001)
        elif r <= 1e-9:
            thr = 0.0
        else:
            thr = float(np.quantile(e, r))
        # Linear fit e ≈ a‖x‖+b.
        a, b = np.polyfit(xn, e, 1)
        pred = a * xn + b
        ss_res = float(((e - pred) ** 2).sum())
        ss_tot = float(((e - e.mean()) ** 2).sum()) + 1e-20
        r2 = 1.0 - ss_res / ss_tot
        use_lin = bool(r2 >= R2_TH)
        # JL scale calibration.
        c = float((e * gn).sum() / ((gn * gn).sum() + 1e-20))
        arr = cal_g.setdefault(g, np.zeros((L, K_PROJ, cfg.group_shape(g)[1]),
                                           np.float32))
        arr[layer] = c * np.asarray(g0[g][layer])
        if use_lin:
            n_lin_est += 1
        else:
            n_jl_est += 1
        records.append({"l": l, "h": h, "p": p_i, "thr": thr,
                        "use_lin": int(use_lin), "lin_a": float(a),
                        "lin_b": float(b), "r2": float(r2), "g_scale": c})

    out = {
        "model": name, "budget": budget, "tag": tag,
        "target": pconf["target"], "calib_set": calib_set,
        "r2_threshold": R2_TH, "k_proj": K_PROJ,
        "n_linear_estimators": n_lin_est, "n_jl_estimators": n_jl_est,
        "linears": records,
    }
    io.save_json(io.art(*base, f"dpllm_{tag}.json"), out)
    io.save_npz(io.art(*base, f"estimators_{tag}.npz"),
                {f"G_{g}": cal_g[g] for g in GROUPS})
    print(f"[thresholds:{name}/b{budget}/{tag}] {n_lin_est} linear / "
          f"{n_jl_est} JL estimators", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny", choices=sorted(PRESETS))
    ap.add_argument("--budget", type=int, default=5)
    ap.add_argument("--tag", default="", help="empty = all targets")
    ap.add_argument("--calib-set", default="synthweb")
    args = ap.parse_args()
    tags = ([args.tag] if args.tag
            else [f"{t:.2f}" for t in targets_for_budget(args.budget)])
    for t in tags:
        calibrate(args.model, args.budget, t, calib_set=args.calib_set)


if __name__ == "__main__":
    main()
