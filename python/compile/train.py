"""Build-time training of the synthetic-corpus models.

Trains the LLaMA-style models from ``model.PRESETS`` on the mixed
pre-training stream produced by ``corpus.py`` + ``tokenizer.py``.  Pure
JAX — AdamW and the cosine schedule are implemented here (no optax in the
sandbox).  Checkpoints go to ``artifacts/models/<name>/ckpt.npz``.

Usage:  python -m compile.train --model dpl-tiny --steps 1800
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import io_utils as io
from .model import PRESETS, ModelConfig, init_params, loss_fn


# ---------------------------------------------------------------------------
# AdamW (hand-rolled; matches the paper's fine-tuning optimizer choice).
# ---------------------------------------------------------------------------


def adamw_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m, v):
        step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        return p - step - lr * wd * p

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def clip_grads(grads, max_norm: float):
    flat = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in flat))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def cosine_lr(step, total, peak, warmup):
    warm = peak * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * peak * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, jnp.maximum(cos, 0.1 * peak))


# ---------------------------------------------------------------------------
# Data sampling.
# ---------------------------------------------------------------------------


class TokenStream:
    def __init__(self, path: str):
        self.data = np.fromfile(path, dtype=np.uint16)

    def batch(self, rng: np.random.Generator, bsz: int, seq: int) -> np.ndarray:
        starts = rng.integers(0, len(self.data) - seq - 1, size=bsz)
        return np.stack([self.data[s:s + seq] for s in starts]).astype(np.int32)


# ---------------------------------------------------------------------------
# Training loop.
# ---------------------------------------------------------------------------


def train(cfg: ModelConfig, steps: int, bsz: int, seq: int, peak_lr: float,
          seed: int = 0, log_every: int = 50) -> dict:
    stream = TokenStream(io.art("data", "train.bin"))
    params = init_params(cfg, seed)
    opt = adamw_init(params)
    rng = np.random.default_rng(seed + 1)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens)
        grads, gn = clip_grads(grads, 1.0)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss, gn

    t0 = time.time()
    losses = []
    for s in range(steps):
        tokens = jnp.asarray(stream.batch(rng, bsz, seq))
        lr = cosine_lr(jnp.float32(s), steps, peak_lr, max(20, steps // 20))
        params, opt, loss, gn = step_fn(params, opt, tokens, lr)
        losses.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            dt = time.time() - t0
            print(f"[{cfg.name}] step {s:5d}/{steps} loss {float(loss):.4f} "
                  f"gnorm {float(gn):.3f} lr {float(lr):.2e} ({dt:.1f}s)",
                  flush=True)
    return params, losses


def save_checkpoint(cfg: ModelConfig, params: dict, losses) -> None:
    io.save_npz(io.art("models", cfg.name, "ckpt.npz"),
                {k: np.asarray(v) for k, v in params.items()})
    with open(io.art("models", cfg.name, "config.json"), "w") as f:
        f.write(cfg.to_json())
    io.save_json(io.art("models", cfg.name, "train_log.json"),
                 {"loss_curve": [round(x, 5) for x in losses]})


# Scaled to the sandbox's single CPU core; the templated synthetic corpus
# reaches loss < 0.5 within a few hundred steps.
DEFAULT_STEPS = {"dpl-tiny": 1800, "dpl-small": 450,
                 "dpl-nano": 500, "dpl-base": 400}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    cfg = PRESETS[args.model]
    steps = args.steps or DEFAULT_STEPS[cfg.name]
    params, losses = train(cfg, steps, args.batch, args.seq, args.lr)
    save_checkpoint(cfg, params, losses)
    print(f"[{cfg.name}] saved checkpoint; final loss "
          f"{np.mean(losses[-20:]):.4f}")


if __name__ == "__main__":
    main()
