"""L1 Pallas kernel: any-precision bitplane dequant-GEMV.

This is the paper's compute hot-spot: the batch-1 decode GEMV over
bitplane-packed weights, where the *same* packed store serves every
bitwidth 3..6 (Any-Precision LLM) and DP-LLM picks the bitwidth per layer
per step.

Hardware adaptation (DESIGN.md §3): the CUDA original streams bitplanes
from HBM with one warp per output tile and the centroid LUT in shared
memory.  Here `BlockSpec` expresses the same HBM→VMEM schedule: each grid
step owns a `(TILE_OUT, in/8)` slab of the `bits` MSB planes plus the
`(TILE_OUT, 2**bits)` LUT slice in VMEM, unpacks bits with VPU integer
ops, gathers through the LUT and accumulates the dot product with `x`
(resident in VMEM across the grid).

`interpret=True` is required for CPU-PJRT execution (real TPU lowering
emits Mosaic custom-calls the CPU plugin cannot run); the kernel structure
(tiling, VMEM footprint) is what carries to hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(planes_ref, lut_ref, x_ref, o_ref, *, bits: int):
    """One (TILE_OUT,) slice of y = W_b @ x.

    planes_ref: u8  [bits, TILE_OUT, in/8]   MSB-first planes
    lut_ref:    f32 [TILE_OUT, 2**bits]
    x_ref:      f32 [in]
    o_ref:      f32 [TILE_OUT]
    """
    planes = planes_ref[...]
    t_out, n_bytes = planes.shape[1], planes.shape[2]
    n_in = n_bytes * 8
    # VPU bit unpack: u8 -> 8 bit lanes (little-bit order within a byte).
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits_t = (planes[..., None] >> shifts) & jnp.uint8(1)   # [b, T, in/8, 8]
    bits_t = bits_t.reshape(bits, t_out, n_in).astype(jnp.int32)
    # MSB-first nested code.
    code = jnp.zeros((t_out, n_in), jnp.int32)
    for p in range(bits):
        code = (code << 1) | bits_t[p]
    # Centroid gather (VMEM-local): w[o, i] = lut[o, code[o, i]].
    w = jnp.take_along_axis(lut_ref[...], code, axis=1)
    o_ref[...] = w @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("bits", "tile_out"))
def anyprec_gemv(planes: jnp.ndarray, lut: jnp.ndarray, x: jnp.ndarray,
                 bits: int, tile_out: int = 64) -> jnp.ndarray:
    """y = W_b @ x from the packed any-precision store.

    planes: u8 [6, out, in/8] (all six planes; only the top `bits` are
            read — this mirrors the memory-traffic property the paper's
            kernel gets on GPU: lower precision touches fewer planes).
    lut:    f32 [out, 2**bits] centroids for this bitwidth.
    x:      f32 [in].
    """
    n_planes, out_dim, n_bytes = planes.shape
    assert n_planes == 6, "expect the full 6-plane store"
    assert 3 <= bits <= 6
    assert lut.shape == (out_dim, 2 ** bits)
    tile_out = min(tile_out, out_dim)
    while out_dim % tile_out:
        tile_out //= 2  # e.g. out=96 -> tile 32
    assert tile_out >= 1
    grid = (out_dim // tile_out,)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=grid,
        in_specs=[
            # Only the top `bits` planes of the tile are brought into VMEM.
            pl.BlockSpec((bits, tile_out, n_bytes), lambda i: (0, i, 0)),
            pl.BlockSpec((tile_out, 2 ** bits), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_out,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((out_dim,), jnp.float32),
        interpret=True,
    )(planes[:bits], lut, x)


def vmem_bytes(bits: int, tile_out: int, n_in: int) -> int:
    """Estimated VMEM footprint of one grid step (see DESIGN.md §Perf).

    planes tile + lut tile + x + unpacked-code intermediate + output tile.
    """
    planes_b = bits * tile_out * (n_in // 8)
    lut_b = tile_out * (2 ** bits) * 4
    x_b = n_in * 4
    code_b = tile_out * n_in * 4
    w_b = tile_out * n_in * 4
    out_b = tile_out * 4
    return planes_b + lut_b + x_b + code_b + w_b + out_b
