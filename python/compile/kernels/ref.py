"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest/hypothesis sweeps the Pallas
kernels against these for many shapes, and the quantizer uses
``dequant_ref`` as the semantic definition of the bitplane format.

Bitplane format (shared contract with ``quantize.py``, the kernels and
``rust/src/anyprec``):

  * every weight has a 6-bit *nested* code; the b-bit code is the MSB
    prefix: ``code_b = code_6 >> (6 - b)``;
  * ``planes`` is uint8, shape ``[6, out, in/8]``; plane 0 is the MSB.
    Bit ``j`` of byte ``k`` in a row is weight column ``8*k + j``
    (little-bit order);
  * per-bitwidth centroid tables ``lut_b``: f32 ``[out, 2**b]``;
    dequantized weight = ``lut_b[o, code_b[o, i]]``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def unpack_planes(planes: jnp.ndarray) -> jnp.ndarray:
    """u8 [P, out, in/8] -> bit tensor [P, out, in] (values 0/1, int32)."""
    p, o, w = planes.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (planes[..., None] >> shifts) & jnp.uint8(1)
    return bits.reshape(p, o, w * 8).astype(jnp.int32)


def codes_from_planes(planes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Top-`bits` planes -> integer codes [out, in]."""
    b = unpack_planes(planes)  # [6, out, in]
    code = jnp.zeros(b.shape[1:], jnp.int32)
    for p in range(bits):
        code = (code << 1) | b[p]
    return code


def dequant_ref(planes: jnp.ndarray, lut: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Reference dequantization: [out, in] f32 weights at `bits` precision."""
    code = codes_from_planes(planes, bits)
    return jnp.take_along_axis(lut, code, axis=1)


def anyprec_gemv_ref(planes: jnp.ndarray, lut: jnp.ndarray, x: jnp.ndarray,
                     bits: int) -> jnp.ndarray:
    """y = W_b @ x with W_b dequantized from the bitplane store."""
    return dequant_ref(planes, lut, bits) @ x


def jl_norm_ref(G: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """‖Gx‖₂ — the JL relative-error estimate (scalar)."""
    return jnp.linalg.norm(G @ x)


# -- numpy twins used by the quantizer and its tests ------------------------


def pack_codes_np(code6: np.ndarray) -> np.ndarray:
    """6-bit codes [out, in] -> packed planes u8 [6, out, in/8]."""
    out, n = code6.shape
    assert n % 8 == 0, "in-dim must be a multiple of 8"
    planes = np.zeros((6, out, n // 8), np.uint8)
    for p in range(6):
        bit = (code6 >> (5 - p)) & 1  # plane 0 = MSB
        planes[p] = np.packbits(bit.astype(np.uint8), axis=1, bitorder="little")
    return planes


def dequant_np(planes: np.ndarray, lut: np.ndarray, bits: int) -> np.ndarray:
    bitsarr = np.unpackbits(planes, axis=2, bitorder="little")  # [6, out, in]
    code = np.zeros(bitsarr.shape[1:], np.int64)
    for p in range(bits):
        code = (code << 1) | bitsarr[p]
    return np.take_along_axis(lut, code, axis=1)
