"""L1 Pallas kernel: JL random-projection relative-error estimator.

Computes ``‖Gx‖₂`` for the calibrated projection ``G = c·AΔW`` (k×n).
This is the random-projection branch of DP-LLM's hybrid estimator
(paper §5.1): an O(nk) GEMV instead of the O(n·out) exact ``‖ΔWx‖``.

k = 64 everywhere (paper: bounds the estimation error within 15% at 91%
confidence); with n ≤ 1024 the whole problem fits a single VMEM block, so
the kernel is one grid step — on a real TPU this would fuse into the
surrounding decode step as a tiny MXU matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_PROJ = 64


def _kernel(g_ref, x_ref, o_ref):
    y = g_ref[...] @ x_ref[...]
    o_ref[0] = jnp.sqrt(jnp.sum(y * y))


@jax.jit
def jl_estimate(G: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """‖Gx‖₂ as a [1] vector (scalar outputs need a rank-1 ref in Pallas)."""
    k, n = G.shape
    assert x.shape == (n,)
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(G, x)
