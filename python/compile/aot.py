"""AOT export: lower the L2 serving graphs to HLO *text* for the Rust L3.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exported per model, into ``artifacts/hlo/<model>/``:

  decode_step.hlo.txt     dual-precision DP-LLM decode step (§5, DESIGN §5)
  decode_step_b<B>.hlo.txt   batched decode step for B ∈ {2, 4, 8} slots
                          (continuous batching — DESIGN §Batching); KV
                          caches stay per-slot graph parameters/outputs
                          (``kv0``..``kv<B-1>``) so each request's cache
                          remains an independent device buffer across
                          steps, while tokens/positions/rope/selector
                          flags carry a leading batch dim
  verify_step_g<G>.hlo.txt   speculative-verification step for γ ∈ {2, 4}
                          draft tokens: γ+1 consecutive positions scored
                          causally in one dispatch (per-position logits +
                          the updated KV as output leaves) — the target
                          half of self-speculative decoding (DESIGN
                          §Speculation); async selector flags chain
                          in-graph between positions
  prefill_<P>.hlo.txt     prompt ingestion for buckets P ∈ {64, 128, 256}
  prefill_chunk_<P>.hlo.txt  chunked prompt ingestion for P ∈ {64, 128}:
                          takes the existing KV cache plus a position
                          offset and appends P causal positions (same
                          KV-leaf protocol as decode_step), so a prompt
                          of ANY length ingests as a chain of bounded,
                          schedulable dispatches (DESIGN §Prefill)
  decode_step_s<S>.hlo.txt   tier variants of decode_step whose KV leaf
                          is truncated to S ∈ tier_ladder(max_seq) (128,
                          256, 512, ... — DESIGN §Memory): bitwise the
                          same computation for pos < S, since the
                          ``arange(S) <= pos`` mask never reads the
                          truncated tail, so a short sequence pays KV
                          bytes proportional to its tier, not max_seq
  prefill_chunk_<P>_s<S>.hlo.txt  tier variants of prefill_chunk (only
                          for P <= S), same truncation rule
  anyprec_gemv_<b>.hlo.txt   standalone L1 bitplane-GEMV kernel (b ∈ 3..6)
  jl_estimate.hlo.txt     standalone L1 JL-projection estimator kernel

Argument order is positional and recorded in ``artifacts/manifest.json``;
the Rust runtime trusts that manifest, not guesswork.

Usage: python -m compile.aot --model dpl-tiny
"""

from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import io_utils as io
from .kernels.anyprec_gemv import anyprec_gemv
from .kernels.estimator import K_PROJ, jl_estimate
from .model import (ASYNC_GROUPS, GROUPS, ModelConfig, PRESETS,
                    decode_step_dual, decode_step_dual_batched, kv_shape,
                    prefill, prefill_chunk, verify_step_dual)

PREFILL_BUCKETS = (64, 128, 256)
PREFILL_CHUNK_BUCKETS = (64, 128)
BATCH_BUCKETS = (2, 4, 8)
SPEC_GAMMAS = (2, 4)
# Smallest KV tier of the paged pool (mirror of rust kvpool::BASE_TIER).
KV_TIER_BASE = 128


def tier_ladder(max_seq: int, base: int = KV_TIER_BASE) -> list[int]:
    """Doubling KV-length ladder strictly below ``max_seq`` (the full
    ``max_seq`` graphs are the existing unsuffixed exports).  Mirror of
    rust ``kvpool::tier_ladder`` minus its final ``max_seq`` rung."""
    tiers, s = [], max(base, 1)
    while s < max_seq:
        tiers.append(s)
        s *= 2
    return tiers


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint8)


# ---------------------------------------------------------------------------
# Decode step.
# ---------------------------------------------------------------------------


def shared_weight_specs(cfg: ModelConfig) -> list[tuple[str, object]]:
    """The batch-invariant argument tail shared by the single-step and
    batched decode graphs: non-linear params, wl/wh candidate stacks and
    estimator parameters.  One source of truth — schema drift between
    `decode_arg_specs` and `batched_decode_arg_specs` would otherwise
    only surface at Rust artifact-load time."""
    d, v = cfg.d_model, cfg.vocab
    L = cfg.n_layers
    args: list[tuple[str, object]] = [
        ("tok_emb", f32(v, d)), ("out_head", f32(v, d)),
        ("final_norm", f32(d)), ("ln1", f32(L, d)), ("ln2", f32(L, d)),
    ]
    for pre in ("wl", "wh"):
        for g in GROUPS:
            o, i = cfg.group_shape(g)
            args.append((f"{pre}_{g}", f32(L, o, i)))
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        args.append((f"G_{g}", f32(L, K_PROJ, i)))
        args.append((f"lina_{g}", f32(L)))
        args.append((f"linb_{g}", f32(L)))
        args.append((f"uselin_{g}", f32(L)))
        args.append((f"thr_{g}", f32(L)))
    return args


def decode_arg_specs(cfg: ModelConfig) -> list[tuple[str, object]]:
    """(name, spec) for every positional argument, in order."""
    L = cfg.n_layers
    hd2 = cfg.head_dim // 2
    args: list[tuple[str, object]] = [
        ("token", i32()), ("pos", i32()),
        ("cos", f32(hd2)), ("sin", f32(hd2)),
        ("kv", f32(*kv_shape(cfg))),
    ]
    args += shared_weight_specs(cfg)
    for g in ASYNC_GROUPS:
        args.append((f"useh_{g}", f32(L)))
    args.append(("mode_exact", f32()))
    return args


def decode_output_names() -> list[str]:
    return (["logits", "kv"] + [f"est_{g}" for g in GROUPS]
            + [f"useh_{g}" for g in GROUPS])


def make_decode_fn(cfg: ModelConfig):
    names = [n for n, _ in decode_arg_specs(cfg)]

    def f(*args):
        a = dict(zip(names, args))
        nl = {k: a[k] for k in ("tok_emb", "out_head", "final_norm", "ln1", "ln2")}
        wl = {g: a[f"wl_{g}"] for g in GROUPS}
        wh = {g: a[f"wh_{g}"] for g in GROUPS}
        est = {}
        for g in GROUPS:
            for field in ("G", "lina", "linb", "uselin", "thr"):
                est[f"{field}_{g}"] = a[f"{field}_{g}"]
        use_async = {g: a[f"useh_{g}"] for g in ASYNC_GROUPS}
        logits, kv_new, ests, use_eff = decode_step_dual(
            nl, wl, wh, est, cfg, a["token"], a["pos"], a["cos"], a["sin"],
            a["kv"], use_async, a["mode_exact"])
        return (logits, kv_new, *[ests[g] for g in GROUPS],
                *[use_eff[g] for g in GROUPS])

    return f


# ---------------------------------------------------------------------------
# Speculative-verification step (γ+1 positions, one dispatch).
# ---------------------------------------------------------------------------


def verify_arg_specs(cfg: ModelConfig, G: int) -> list[tuple[str, object]]:
    """(name, spec) per positional argument of the γ-draft verify step.

    Identical to ``decode_arg_specs`` except the per-position inputs grow
    a leading γ+1 dim: ``tokens`` [γ+1] (next committed token + γ
    drafts), ``cos``/``sin`` [γ+1, hd/2].  ``pos`` stays the scalar
    position of ``tokens[0]`` (later positions are ``pos + i`` in-graph)
    and the async flags stay [L] — they seed position 0 only; positions
    1..γ chain in-graph (see ``verify_step_dual``).
    """
    L = cfg.n_layers
    hd2 = cfg.head_dim // 2
    g1 = G + 1
    args: list[tuple[str, object]] = [
        ("tokens", i32(g1)), ("pos", i32()),
        ("cos", f32(g1, hd2)), ("sin", f32(g1, hd2)),
        ("kv", f32(*kv_shape(cfg))),
    ]
    args += shared_weight_specs(cfg)
    for g in ASYNC_GROUPS:
        args.append((f"useh_{g}", f32(L)))
    args.append(("mode_exact", f32()))
    return args


def verify_output_names() -> list[str]:
    """Same leaf names as the single step; logits/est/useh leaves carry a
    leading γ+1 dim, the KV leaf is the final (all-positions-written)
    cache."""
    return decode_output_names()


def make_verify_fn(cfg: ModelConfig, G: int):
    names = [n for n, _ in verify_arg_specs(cfg, G)]

    def f(*args):
        a = dict(zip(names, args))
        nl = {k: a[k] for k in ("tok_emb", "out_head", "final_norm", "ln1", "ln2")}
        wl = {g: a[f"wl_{g}"] for g in GROUPS}
        wh = {g: a[f"wh_{g}"] for g in GROUPS}
        est = {}
        for g in GROUPS:
            for field in ("G", "lina", "linb", "uselin", "thr"):
                est[f"{field}_{g}"] = a[f"{field}_{g}"]
        use_async = {g: a[f"useh_{g}"] for g in ASYNC_GROUPS}
        logits, kv_new, ests, use_eff = verify_step_dual(
            nl, wl, wh, est, cfg, a["tokens"], a["pos"], a["cos"], a["sin"],
            a["kv"], use_async, a["mode_exact"])
        return (logits, kv_new, *[ests[g] for g in GROUPS],
                *[use_eff[g] for g in GROUPS])

    return f


# ---------------------------------------------------------------------------
# Batched decode step (continuous batching across concurrent requests).
# ---------------------------------------------------------------------------


def batched_decode_arg_specs(cfg: ModelConfig, B: int) -> list[tuple[str, object]]:
    """(name, spec) per positional argument of the B-slot batched decode.

    Per-slot inputs carry a leading batch dim (``tokens``/``poss`` [B],
    ``cos``/``sin`` [B, hd/2], ``useh_<g>`` [B, L]) — EXCEPT the KV
    caches, which stay B separate ``kv<i>`` parameters of the single-step
    shape: the Rust runtime keeps one device buffer per request and feeds
    each straight back as ``kv<i>`` of the next batched call, so batch
    membership can change between steps without gathering or scattering
    KV state through a combined buffer.  Weights/estimator params are the
    same shared arguments as ``decode_arg_specs``
    (``shared_weight_specs``).
    """
    L = cfg.n_layers
    hd2 = cfg.head_dim // 2
    args: list[tuple[str, object]] = [
        ("tokens", i32(B)), ("poss", i32(B)),
        ("cos", f32(B, hd2)), ("sin", f32(B, hd2)),
    ]
    for i in range(B):
        args.append((f"kv{i}", f32(*kv_shape(cfg))))
    args += shared_weight_specs(cfg)
    for g in ASYNC_GROUPS:
        args.append((f"useh_{g}", f32(B, L)))
    args.append(("mode_exact", f32()))
    return args


def batched_decode_output_names(B: int) -> list[str]:
    return (["logits"] + [f"kv{i}" for i in range(B)]
            + [f"est_{g}" for g in GROUPS] + [f"useh_{g}" for g in GROUPS])


def make_batched_decode_fn(cfg: ModelConfig, B: int):
    names = [n for n, _ in batched_decode_arg_specs(cfg, B)]

    def f(*args):
        a = dict(zip(names, args))
        nl = {k: a[k] for k in ("tok_emb", "out_head", "final_norm", "ln1", "ln2")}
        wl = {g: a[f"wl_{g}"] for g in GROUPS}
        wh = {g: a[f"wh_{g}"] for g in GROUPS}
        est = {}
        for g in GROUPS:
            for field in ("G", "lina", "linb", "uselin", "thr"):
                est[f"{field}_{g}"] = a[f"{field}_{g}"]
        kv = jnp.stack([a[f"kv{i}"] for i in range(B)])
        use_async = {g: a[f"useh_{g}"] for g in ASYNC_GROUPS}
        logits, kv_new, ests, use_eff = decode_step_dual_batched(
            nl, wl, wh, est, cfg, a["tokens"], a["poss"], a["cos"], a["sin"],
            kv, use_async, a["mode_exact"])
        return (logits, *[kv_new[i] for i in range(B)],
                *[ests[g] for g in GROUPS], *[use_eff[g] for g in GROUPS])

    return f


# ---------------------------------------------------------------------------
# Prefill.
# ---------------------------------------------------------------------------


def prefill_arg_specs(cfg: ModelConfig, P: int) -> list[tuple[str, object]]:
    d, v, L = cfg.d_model, cfg.vocab, cfg.n_layers
    hd2 = cfg.head_dim // 2
    args = [
        ("tokens", i32(P)), ("n_valid", i32()),
        ("cos", f32(P, hd2)), ("sin", f32(P, hd2)),
        ("tok_emb", f32(v, d)), ("out_head", f32(v, d)),
        ("final_norm", f32(d)), ("ln1", f32(L, d)), ("ln2", f32(L, d)),
    ]
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        args.append((f"w_{g}", f32(L, o, i)))
    return args


def make_prefill_fn(cfg: ModelConfig, P: int):
    names = [n for n, _ in prefill_arg_specs(cfg, P)]

    def f(*args):
        a = dict(zip(names, args))
        nl = {k: a[k] for k in ("tok_emb", "out_head", "final_norm", "ln1", "ln2")}
        lin = {g: a[f"w_{g}"] for g in GROUPS}
        return prefill(nl, lin, cfg, a["tokens"], a["n_valid"], a["cos"], a["sin"])

    return f


def prefill_chunk_arg_specs(cfg: ModelConfig, P: int) -> list[tuple[str, object]]:
    """(name, spec) per positional argument of the P-token prefill chunk.

    The ``prefill_<P>`` specs plus the decode-step KV protocol: ``pos``
    (absolute position of ``tokens[0]``) and ``kv`` (the caller's cache,
    an input AND an output leaf) — so the Rust runtime feeds a
    device-resident buffer straight back across chunks, exactly as
    ``decode_step``'s kv leaf.
    """
    d, v, L = cfg.d_model, cfg.vocab, cfg.n_layers
    hd2 = cfg.head_dim // 2
    args = [
        ("tokens", i32(P)), ("pos", i32()), ("n_valid", i32()),
        ("cos", f32(P, hd2)), ("sin", f32(P, hd2)),
        ("kv", f32(*kv_shape(cfg))),
        ("tok_emb", f32(v, d)), ("out_head", f32(v, d)),
        ("final_norm", f32(d)), ("ln1", f32(L, d)), ("ln2", f32(L, d)),
    ]
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        args.append((f"w_{g}", f32(L, o, i)))
    return args


def make_prefill_chunk_fn(cfg: ModelConfig, P: int):
    names = [n for n, _ in prefill_chunk_arg_specs(cfg, P)]

    def f(*args):
        a = dict(zip(names, args))
        nl = {k: a[k] for k in ("tok_emb", "out_head", "final_norm", "ln1", "ln2")}
        lin = {g: a[f"w_{g}"] for g in GROUPS}
        return prefill_chunk(nl, lin, cfg, a["tokens"], a["pos"], a["n_valid"],
                             a["cos"], a["sin"], a["kv"])

    return f


# ---------------------------------------------------------------------------
# Standalone kernel entry points (L1 microbench + faithful-memory path).
# ---------------------------------------------------------------------------


def kernel_specs(cfg: ModelConfig, bits: int):
    # Exported at the model's attention-projection shape.
    o, i = cfg.group_shape("wq")
    return [("planes", u8(6, o, i // 8)), ("lut", f32(o, 2 ** bits)),
            ("x", f32(i))]


# ---------------------------------------------------------------------------
# Golden vectors: the Rust runtime's integration test executes the HLO
# artifact and compares against these jax-computed outputs byte-for-byte
# (within float tolerance) — the cross-language L2→L3 contract.
# ---------------------------------------------------------------------------


def golden_decode_arrays(cfg: ModelConfig, params: dict, token: int = 3,
                         pos: int = 5, seed: int = 7) -> dict:
    """Build one decode-step input set (wl ≠ wh, active estimators and mixed
    thresholds so the selection logic is exercised) + expected outputs."""
    import numpy as np
    from .model import extract_linears, nonlinear_params

    rng = np.random.default_rng(seed)
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    hd = cfg.head_dim
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))
    vals = {
        "token": np.int32(token), "pos": np.int32(pos),
        "cos": np.cos(pos * inv).astype(np.float32),
        "sin": np.sin(pos * inv).astype(np.float32),
        "kv": rng.standard_normal(kv_shape(cfg)).astype(np.float32) * 0.01,
        "tok_emb": np.asarray(nl["tok_emb"]),
        "out_head": np.asarray(nl["out_head"]),
        "final_norm": np.asarray(nl["final_norm"]),
        "ln1": np.asarray(nl["ln1"]), "ln2": np.asarray(nl["ln2"]),
        "mode_exact": np.float32(0.0),
    }
    L = cfg.n_layers
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        w = np.asarray(lin[g])
        vals[f"wl_{g}"] = (w * 0.9).astype(np.float32)
        vals[f"wh_{g}"] = w
        vals[f"G_{g}"] = (rng.standard_normal((L, K_PROJ, i)) * 0.05
                          ).astype(np.float32)
        vals[f"lina_{g}"] = rng.random(L).astype(np.float32)
        vals[f"linb_{g}"] = rng.random(L).astype(np.float32) * 0.1
        vals[f"uselin_{g}"] = (rng.random(L) < 0.5).astype(np.float32)
        vals[f"thr_{g}"] = (rng.random(L) * 0.5).astype(np.float32)
    for g in ASYNC_GROUPS:
        vals[f"useh_{g}"] = (rng.random(L) < 0.5).astype(np.float32)

    names = [n for n, _ in decode_arg_specs(cfg)]
    outs = jax.jit(make_decode_fn(cfg))(*[jnp.asarray(vals[n]) for n in names])
    arrays = {f"in_{n}": vals[n] for n in names}
    import numpy as _np
    for name, o in zip(decode_output_names(), outs):
        arrays[f"out_{name}"] = _np.asarray(o)
    return arrays


def golden_prefill_arrays(cfg: ModelConfig, params: dict, P: int = 64,
                          n_valid: int = 9, seed: int = 11) -> dict:
    import numpy as np
    from .model import extract_linears, nonlinear_params, prefill

    rng = np.random.default_rng(seed)
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    tokens = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    hd = cfg.head_dim
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))
    ang = np.arange(P)[:, None] * inv[None, :]
    vals = {"tokens": tokens, "n_valid": np.int32(n_valid),
            "cos": np.cos(ang).astype(np.float32),
            "sin": np.sin(ang).astype(np.float32),
            "tok_emb": np.asarray(nl["tok_emb"]),
            "out_head": np.asarray(nl["out_head"]),
            "final_norm": np.asarray(nl["final_norm"]),
            "ln1": np.asarray(nl["ln1"]), "ln2": np.asarray(nl["ln2"])}
    for g in GROUPS:
        vals[f"w_{g}"] = np.asarray(lin[g])
    names = [n for n, _ in prefill_arg_specs(cfg, P)]
    logits, kv = jax.jit(make_prefill_fn(cfg, P))(
        *[jnp.asarray(vals[n]) for n in names])
    arrays = {f"in_{n}": vals[n] for n in names}
    arrays["out_logits_last"] = np.asarray(logits)
    arrays["out_kv"] = np.asarray(kv)
    return arrays


def export_golden(name: str) -> None:
    from . import io_utils as _io
    cfg = PRESETS[name]
    ckpt = io.load_npz(io.art("models", name, "ckpt.npz"))
    params = {k: jnp.asarray(v) for k, v in ckpt.items()}
    arrays = golden_decode_arrays(cfg, params)
    _io.save_npz(io.art("hlo", name, "golden_decode.npz"), arrays)
    _io.save_npz(io.art("hlo", name, "golden_prefill.npz"),
                 golden_prefill_arrays(cfg, params))
    print(f"[aot:{name}] golden vectors", flush=True)


# ---------------------------------------------------------------------------
# Export driver.
# ---------------------------------------------------------------------------


def export_model(name: str) -> dict:
    cfg = PRESETS[name]
    outdir = ("hlo", name)
    entry: dict = {"model": name, "config": cfg.to_json(), "entries": {}}

    # decode step
    specs = decode_arg_specs(cfg)
    lowered = jax.jit(make_decode_fn(cfg)).lower(*[s for _, s in specs])
    path = io.art(*outdir, "decode_step.hlo.txt")
    with open(path, "w") as fh:
        fh.write(to_hlo_text(lowered))
    entry["entries"]["decode_step"] = {
        "path": os.path.relpath(path, io.ART),
        "args": [n for n, _ in specs],
        "outputs": decode_output_names(),
        "k_proj": K_PROJ,
    }
    print(f"[aot:{name}] decode_step ({os.path.getsize(path) / 1e3:.0f} kB)",
          flush=True)

    # batched decode steps (continuous batching buckets)
    for B in BATCH_BUCKETS:
        specs = batched_decode_arg_specs(cfg, B)
        lowered = jax.jit(make_batched_decode_fn(cfg, B)).lower(
            *[s for _, s in specs])
        path = io.art(*outdir, f"decode_step_b{B}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entry["entries"][f"decode_step_b{B}"] = {
            "path": os.path.relpath(path, io.ART),
            "args": [n for n, _ in specs],
            "outputs": batched_decode_output_names(B),
            "batch": B,
        }
        print(f"[aot:{name}] decode_step_b{B} "
              f"({os.path.getsize(path) / 1e3:.0f} kB)", flush=True)

    # speculative-verification steps (γ draft tokens + 1 bonus position)
    for G in SPEC_GAMMAS:
        specs = verify_arg_specs(cfg, G)
        lowered = jax.jit(make_verify_fn(cfg, G)).lower(*[s for _, s in specs])
        path = io.art(*outdir, f"verify_step_g{G}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entry["entries"][f"verify_step_g{G}"] = {
            "path": os.path.relpath(path, io.ART),
            "args": [n for n, _ in specs],
            "outputs": verify_output_names(),
            "gamma": G,
        }
        print(f"[aot:{name}] verify_step_g{G} "
              f"({os.path.getsize(path) / 1e3:.0f} kB)", flush=True)

    # prefill buckets
    for P in PREFILL_BUCKETS:
        specs = prefill_arg_specs(cfg, P)
        lowered = jax.jit(make_prefill_fn(cfg, P)).lower(*[s for _, s in specs])
        path = io.art(*outdir, f"prefill_{P}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entry["entries"][f"prefill_{P}"] = {
            "path": os.path.relpath(path, io.ART),
            "args": [n for n, _ in specs],
            "outputs": ["logits_last", "kv"],
        }
        print(f"[aot:{name}] prefill_{P}", flush=True)

    # prefill chunks (incremental prompt ingestion against an existing KV)
    for P in PREFILL_CHUNK_BUCKETS:
        specs = prefill_chunk_arg_specs(cfg, P)
        lowered = jax.jit(make_prefill_chunk_fn(cfg, P)).lower(
            *[s for _, s in specs])
        path = io.art(*outdir, f"prefill_chunk_{P}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entry["entries"][f"prefill_chunk_{P}"] = {
            "path": os.path.relpath(path, io.ART),
            "args": [n for n, _ in specs],
            "outputs": ["logits_last", "kv"],
        }
        print(f"[aot:{name}] prefill_chunk_{P}", flush=True)

    # KV tier variants (paged KV pool — DESIGN §Memory): the same decode
    # step / prefill chunk with the KV leaf truncated to S positions.
    # The ``arange(S) <= pos`` mask makes slots past ``pos`` don't-care,
    # so for pos < S the truncated graphs are bitwise identical to the
    # full-max_seq ones (pinned by test_aot.py::test_tier_graph_parity) —
    # a short sequence just stops paying max_seq KV bytes.  The Rust
    # runtime treats these as optional: absent tiers degrade to the
    # max_seq graphs.
    for S in tier_ladder(cfg.max_seq):
        tcfg = dataclasses.replace(cfg, max_seq=S)
        specs = decode_arg_specs(tcfg)
        lowered = jax.jit(make_decode_fn(tcfg)).lower(*[s for _, s in specs])
        path = io.art(*outdir, f"decode_step_s{S}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entry["entries"][f"decode_step_s{S}"] = {
            "path": os.path.relpath(path, io.ART),
            "args": [n for n, _ in specs],
            "outputs": decode_output_names(),
            "k_proj": K_PROJ,
            "tier": S,
        }
        for P in PREFILL_CHUNK_BUCKETS:
            if P > S:
                continue
            specs = prefill_chunk_arg_specs(tcfg, P)
            lowered = jax.jit(make_prefill_chunk_fn(tcfg, P)).lower(
                *[s for _, s in specs])
            path = io.art(*outdir, f"prefill_chunk_{P}_s{S}.hlo.txt")
            with open(path, "w") as fh:
                fh.write(to_hlo_text(lowered))
            entry["entries"][f"prefill_chunk_{P}_s{S}"] = {
                "path": os.path.relpath(path, io.ART),
                "args": [n for n, _ in specs],
                "outputs": ["logits_last", "kv"],
                "tier": S,
            }
        print(f"[aot:{name}] tier s{S} (decode + chunks)", flush=True)

    # standalone kernels
    for bits in (3, 4, 5, 6):
        specs = kernel_specs(cfg, bits)
        fn = lambda planes, lut, x, _b=bits: (anyprec_gemv(planes, lut, x, _b),)
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        path = io.art(*outdir, f"anyprec_gemv_{bits}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        entry["entries"][f"anyprec_gemv_{bits}"] = {
            "path": os.path.relpath(path, io.ART),
            "args": [n for n, _ in specs],
            "outputs": ["y"],
            "bits": bits,
        }
    o, i = cfg.group_shape("wq")
    fn = lambda G, x: (jl_estimate(G, x),)
    lowered = jax.jit(fn).lower(f32(K_PROJ, i), f32(i))
    path = io.art(*outdir, "jl_estimate.hlo.txt")
    with open(path, "w") as fh:
        fh.write(to_hlo_text(lowered))
    entry["entries"]["jl_estimate"] = {
        "path": os.path.relpath(path, io.ART),
        "args": ["G", "x"],
        "outputs": ["norm"],
    }
    print(f"[aot:{name}] kernels", flush=True)
    if os.path.exists(io.art("models", name, "ckpt.npz")):
        export_golden(name)
        entry["entries"]["golden_decode"] = {
            "path": os.path.join("hlo", name, "golden_decode.npz")}
    return entry


def update_manifest(entries: list[dict]) -> None:
    path = io.art("manifest.json")
    manifest = io.load_json(path) if os.path.exists(path) else {"models": {}}
    for e in entries:
        manifest["models"][e["model"]] = e
    io.save_json(path, manifest)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny")
    ap.add_argument("--out", default="", help="(compat) unused")
    args = ap.parse_args()
    names = sorted(PRESETS) if args.model == "all" else [args.model]
    update_manifest([export_model(n) for n in names])


if __name__ == "__main__":
    main()
