"""Build-pipeline orchestrator: data → train → quantize → calibrate → AOT.

``make artifacts`` runs ``python -m compile.pipeline --scope core``; every
stage is cached by output-vs-input mtimes (``io_utils.stale``), so the
pipeline is a no-op when artifacts exist and inputs are unchanged.

Scopes (single CPU core in this sandbox — see DESIGN.md §2):
  core      dpl-tiny + dpl-small, 5-bit budget, all 7 targets, baselines,
            AOT graphs, Fig-3 analysis.  Powers Tables 1-9 + figures.
  extended  adds: 4-/6-bit budgets (Tables 10/11), dpl-nano + dpl-base
            (Table 12), fixed-(l,h) ablation (Table 13), wikitext-calibrated
            configs (Table 14).
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from . import io_utils as io
from .assign import targets_for_budget

PY = [sys.executable, "-m"]
PYDIR = os.path.join(io.REPO_ROOT, "python")


def run(mod: str, *args: str) -> None:
    cmd = PY + [mod] + list(args)
    t0 = time.time()
    print(f"[pipeline] $ {' '.join(cmd[2:])}", flush=True)
    subprocess.run(cmd, cwd=PYDIR, check=True)
    print(f"[pipeline] done in {time.time() - t0:.0f}s", flush=True)


def m(name: str, f: str) -> str:
    return io.art("models", name, f)


def c(name: str, budget: int, f: str) -> str:
    return io.art("calib", name, f"budget{budget}", f)


def ensure_data() -> None:
    outs = [io.art("data", x) for x in
            ("tokenizer.json", "train.bin", "synthwiki_eval.bin",
             "synthweb_eval.bin", "synthwiki_calib.bin", "synthweb_calib.bin")]
    ins = [os.path.join(PYDIR, "compile", x)
           for x in ("corpus.py", "tokenizer.py", "dataprep.py")]
    if io.stale(outs, ins):
        run("compile.dataprep")


def ensure_model(name: str) -> None:
    if io.stale(m(name, "ckpt.npz"), io.art("data", "train.bin")):
        run("compile.train", "--model", name)
    if io.stale([m(name, "anyprec.npz"), m(name, "fisher.npz")],
                m(name, "ckpt.npz")):
        run("compile.quantize", "--model", name)
    # Packed single-file container (mmap zero-copy serving); the Rust
    # loader prefers it over the npz when present.
    if io.stale(m(name, "anyprec.dpak"), m(name, "anyprec.npz")):
        run("compile.pack", "--model", name)


def ensure_calib(name: str, budget: int, calib_set: str = "synthweb",
                 tag_suffix: str = "", epochs: int = 2) -> None:
    if io.stale(c(name, budget, "maxprec.json"), m(name, "anyprec.npz")):
        run("compile.assign", "--model", name, "--budget", str(budget))
    for t in targets_for_budget(budget):
        tag = f"{t:.2f}{tag_suffix}"
        if io.stale(c(name, budget, f"dpllm_p_{tag}.json"),
                    c(name, budget, "maxprec.json")):
            run("compile.finetune_p", "--model", name, "--budget", str(budget),
                "--target", str(t), "--epochs", str(epochs),
                "--calib-set", calib_set, *(
                    ["--tag", tag] if tag_suffix else []))
        if io.stale(c(name, budget, f"dpllm_{tag}.json"),
                    c(name, budget, f"dpllm_p_{tag}.json")):
            run("compile.thresholds", "--model", name, "--budget", str(budget),
                "--tag", tag, "--calib-set", calib_set)


def ensure_aot(name: str) -> None:
    out = io.art("hlo", name, "decode_step.hlo.txt")
    ins = [os.path.join(PYDIR, "compile", x)
           for x in ("model.py", "aot.py", "kernels/anyprec_gemv.py",
                     "kernels/estimator.py")]
    if io.stale(out, ins):
        run("compile.aot", "--model", name)


def ensure_fig3(name: str) -> None:
    if io.stale(io.art("analysis", f"fig3b_{name}.json"), m(name, "anyprec.npz")):
        run("compile.sensitivity", "--model", name)


def core() -> None:
    ensure_data()
    for name in ("dpl-tiny", "dpl-small"):
        ensure_model(name)
        ensure_calib(name, 5)
        ensure_aot(name)
    ensure_fig3("dpl-tiny")


def extended() -> None:
    # Tables 10/11: other memory budgets (headline model).
    ensure_calib("dpl-tiny", 6)
    ensure_calib("dpl-tiny", 4)
    # Table 12: model scales.
    for name in ("dpl-nano", "dpl-base"):
        ensure_model(name)
        ensure_calib(name, 5)
        ensure_aot(name)
    # Table 14: calibration-set transfer (synthwiki-calibrated configs).
    ensure_calib("dpl-tiny", 5, calib_set="synthwiki", tag_suffix="w")
    # Table 13: fixed (l,h) ablation at 4.5-bit target under 6-bit budget.
    from .finetune_p import finetune_p
    from .thresholds import calibrate
    for (lo, hi) in ((3, 5), (3, 6), (4, 5), (4, 6)):
        tag = f"hl{lo}{hi}"
        if io.stale(c("dpl-tiny", 6, f"dpllm_{tag}.json"),
                    c("dpl-tiny", 6, "maxprec.json")):
            finetune_p("dpl-tiny", 6, 4.5, epochs=2, fixed_lh=(lo, hi), tag=tag)
            calibrate("dpl-tiny", 6, tag, fixed_lh=(lo, hi))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scope", default="core", choices=("core", "extended", "all"))
    args = ap.parse_args()
    t0 = time.time()
    if args.scope in ("core", "all"):
        core()
    if args.scope in ("extended", "all"):
        extended()
    print(f"[pipeline] all stages fresh ({time.time() - t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
