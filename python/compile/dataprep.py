"""Stage 0 of the build pipeline: corpus -> tokenizer -> token streams.

Writes to artifacts/data/:
    corpus_train.txt        (debug reference, also tokenizer training text)
    tokenizer.json          byte-BPE merges (consumed by rust/src/tokenizer)
    train.bin               uint16 token stream for pre-training
    synthwiki_eval.bin      perplexity eval stream (WikiText2 analog)
    synthweb_eval.bin       perplexity eval stream (C4 analog)
    synthwiki_calib.bin     calibration stream (paper: C4-train calibration;
    synthweb_calib.bin       Table 14 swaps the calibration source)
    tasks/<task>_eval.jsonl downstream-task eval sets
    tasks/instruct_eval.jsonl  QoS prompt set (Alpaca analog)

Usage: python -m compile.dataprep [--seed 0]
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from . import corpus as C
from . import io_utils as io
from .tokenizer import Tokenizer, encode_to_bin, train_bpe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=1024)
    args = ap.parse_args()

    print("[dataprep] generating corpus ...", flush=True)
    blobs = C.build_corpus(seed=args.seed)
    train_text = blobs["train_text"]
    with open(io.art("data", "corpus_train.txt"), "w") as f:
        f.write(train_text)

    print(f"[dataprep] corpus: {len(train_text) / 1e6:.1f} MB train text; "
          f"training byte-BPE vocab={args.vocab} ...", flush=True)
    merges = train_bpe(train_text[: 2_000_000], vocab_size=args.vocab)
    tok = Tokenizer(merges)
    tok.save(io.art("data", "tokenizer.json"))

    n = encode_to_bin(tok, train_text, io.art("data", "train.bin"))
    print(f"[dataprep] train stream: {n / 1e6:.2f} M tokens", flush=True)
    encode_to_bin(tok, blobs["synthwiki_eval"], io.art("data", "synthwiki_eval.bin"))
    encode_to_bin(tok, blobs["synthweb_eval"], io.art("data", "synthweb_eval.bin"))

    # Calibration streams: fresh draws, disjoint from train/eval by seed.
    calib_rng = np.random.default_rng(args.seed + 900_001)
    wiki_calib = C.gen_synthwiki(calib_rng, 400)
    web_calib = C.gen_synthweb(calib_rng, 800)
    encode_to_bin(tok, wiki_calib, io.art("data", "synthwiki_calib.bin"))
    encode_to_bin(tok, web_calib, io.art("data", "synthweb_calib.bin"))

    os.makedirs(io.art("data", "tasks", "x").rsplit("/", 1)[0], exist_ok=True)
    for task, (tr, ev) in blobs["tasks"].items():
        rows = [{"task": s.task, "prompt": s.prompt, "answer": s.answer}
                for s in ev]
        io.write_jsonl(io.art("data", "tasks", f"{task}_eval.jsonl"), rows)
        print(f"[dataprep] task {task}: {len(tr)} train / {len(rows)} eval",
              flush=True)
    print("[dataprep] done", flush=True)


if __name__ == "__main__":
    main()
