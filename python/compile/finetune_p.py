"""Phase 2: layer-wise average-precision (p) fine-tuning (paper §4, Eq. 1).

Every linear ``y = W x`` is substituted by the soft mix

    y = r·W_l x + (1 - r)·W_h x,   l = ⌊p⌋, h = ⌈p⌉, r = 1 - (p - l)

with one learnable scalar p per linear (the only trainable parameters,
as in the paper).  The loss adds the regularizer

    L' = L + α (Σ p_i·M_i / Σ M_i  -  b_targ)²

which stops the p's from collapsing to the highest precision.  AdamW, a
few epochs over the small calibration stream (paper Appendix B.1).

Two mix modes:
  * ``adjacent`` (default) — l/h track ⌊p⌋/⌈p⌉ as p moves (the paper's
    scheme, Algorithm 1 Phase 2),
  * ``fixed l h``          — l/h pinned for every layer, r = (h-p)/(h-l)
    (the Table-13 ablation).

Writes ``p`` (plus metadata) into
``artifacts/calib/<model>/budget<b>/dpllm_<tag>.json``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import io_utils as io
from .assign import BITS, dequant_linear, linear_index, targets_for_budget
from .model import (GROUPS, ModelConfig, PRESETS, ce_from_logits, rmsnorm,
                    apply_rope, rope_tables)
from .quantize import calib_batches


# ---------------------------------------------------------------------------
# Quantized level stacks.
# ---------------------------------------------------------------------------


def load_level_stacks(name: str, cfg: ModelConfig) -> dict:
    """{g: f32 [L, 4, out, in]} — dequantized weights at bits 3..6."""
    anyprec = io.load_npz(io.art("models", name, "anyprec.npz"))
    out = {}
    for g in GROUPS:
        L = cfg.n_layers
        levels = np.stack([
            np.stack([dequant_linear(anyprec, g, layer, b) for b in BITS])
            for layer in range(L)
        ])  # [L, 4, out, in]
        out[g] = jnp.asarray(levels)
    return out


# ---------------------------------------------------------------------------
# Soft-mix forward.
# ---------------------------------------------------------------------------


def mixed_forward(nl: dict, levels: dict, p: dict, cfg: ModelConfig,
                  tokens: jnp.ndarray, fixed_lh=None) -> jnp.ndarray:
    """Forward with every linear soft-mixed at its average precision p."""
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x = nl["tok_emb"][tokens]
    pos = jnp.arange(S)
    cos, sin = rope_tables(cfg, pos)
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    mask = jnp.tril(jnp.ones((S, S), bool))

    def mixw(levels_l, p_i):
        """levels_l [4, out, in], p_i scalar -> soft-mixed [out, in]."""
        if fixed_lh is None:
            l_f = jnp.floor(jax.lax.stop_gradient(p_i))
            l_idx = jnp.clip(l_f.astype(jnp.int32) - 3, 0, 3)
            h_idx = jnp.clip(l_idx + 1, 0, 3)
            r = 1.0 - (p_i - l_f)
            r = jnp.clip(r, 0.0, 1.0)
        else:
            lo, hi = fixed_lh
            l_idx, h_idx = lo - 3, hi - 3
            r = jnp.clip((hi - p_i) / (hi - lo), 0.0, 1.0)
        wl = jax.lax.dynamic_index_in_dim(levels_l, l_idx, 0, keepdims=False)
        wh = jax.lax.dynamic_index_in_dim(levels_l, h_idx, 0, keepdims=False)
        return r * wl + (1.0 - r) * wh

    def block(x, layer):
        ln1, ln2, lv, pv = layer
        h = rmsnorm(x, ln1)
        wq = mixw(lv["wq"], pv["wq"])
        wk = mixw(lv["wk"], pv["wk"])
        wv_ = mixw(lv["wv"], pv["wv"])
        q = (h @ wq.T).reshape(B, S, H, hd)
        k = (h @ wk.T).reshape(B, S, H, hd)
        v = (h @ wv_.T).reshape(B, S, H, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, H * hd)
        x = x + o @ mixw(lv["wo"], pv["wo"]).T
        h2 = rmsnorm(x, ln2)
        gate = jax.nn.silu(h2 @ mixw(lv["wg"], pv["wg"]).T)
        up = h2 @ mixw(lv["wu"], pv["wu"]).T
        x = x + (gate * up) @ mixw(lv["wd"], pv["wd"]).T
        return x, None

    xs = (nl["ln1"], nl["ln2"], levels, p)
    x, _ = jax.lax.scan(block, x, xs)
    x = rmsnorm(x, nl["final_norm"])
    return x @ nl["out_head"].T


# ---------------------------------------------------------------------------
# Fine-tuning loop.
# ---------------------------------------------------------------------------


def finetune_p(name: str, budget: int, target: float, alpha: float | None = None,
               epochs: int = 3, lr: float = 0.03, calib_seqs: int = 24,
               seq: int = 128, fixed_lh=None, calib_set: str = "synthweb",
               tag: str | None = None) -> dict:
    cfg = PRESETS[name]
    nl_all = io.load_npz(io.art("models", name, "ckpt.npz"))
    nl = {k: jnp.asarray(v) for k, v in nl_all.items() if k not in GROUPS}
    levels = load_level_stacks(name, cfg)
    maxprec = io.load_json(io.art("calib", name, f"budget{budget}",
                                  "maxprec.json"))["bits"]
    idx = linear_index(cfg)
    M = np.asarray([cfg.group_params(g) for (_, g) in idx], np.float32)
    Msum = float(M.sum())
    M_g = {g: jnp.asarray([cfg.group_params(g)] * cfg.n_layers, jnp.float32)
           for g in GROUPS}

    # Per-linear bounds.
    if fixed_lh is None:
        lo_b = {g: jnp.full(cfg.n_layers, 3.0) for g in GROUPS}
        hi_map = {(layer, g): float(maxprec[i]) for i, (layer, g) in enumerate(idx)}
        hi_b = {g: jnp.asarray([hi_map[(layer, g)] for layer in range(cfg.n_layers)])
                for g in GROUPS}
    else:
        lo, hi = fixed_lh
        lo_b = {g: jnp.full(cfg.n_layers, float(lo)) for g in GROUPS}
        hi_b = {g: jnp.full(cfg.n_layers, float(hi)) for g in GROUPS}

    if alpha is None:
        alpha = 10.0 if target <= 3.3 else 1.0

    calib = calib_batches(io.art("data", f"{calib_set}_calib.bin"),
                          calib_seqs, seq, seed=17)
    p0 = {g: jnp.clip(jnp.full(cfg.n_layers, float(target)), lo_b[g], hi_b[g])
          for g in GROUPS}

    def loss(p, tokens):
        logits = mixed_forward(nl, levels, p, cfg, tokens, fixed_lh=fixed_lh)
        ce = ce_from_logits(logits, tokens)
        avg = sum(jnp.sum(p[g] * M_g[g]) for g in GROUPS) / Msum
        return ce + alpha * (avg - target) ** 2, ce

    grad_fn = jax.jit(jax.value_and_grad(loss, has_aux=True))

    # Adam on p only.
    m = {g: jnp.zeros(cfg.n_layers) for g in GROUPS}
    v = {g: jnp.zeros(cfg.n_layers) for g in GROUPS}
    p = p0
    t0 = time.time()
    step = 0
    batch = 4
    last_ce = float("nan")
    for ep in range(epochs):
        for i in range(0, len(calib), batch):
            tokens = jnp.asarray(calib[i:i + batch])
            (tot, ce), g = grad_fn(p, tokens)
            step += 1
            for k in GROUPS:
                m[k] = 0.9 * m[k] + 0.1 * g[k]
                v[k] = 0.999 * v[k] + 0.001 * g[k] ** 2
                mh = m[k] / (1 - 0.9 ** step)
                vh = v[k] / (1 - 0.999 ** step)
                p[k] = p[k] - lr * mh / (jnp.sqrt(vh) + 1e-8)
                p[k] = jnp.clip(p[k], lo_b[k], hi_b[k])
            last_ce = float(ce)
        avg = float(sum(float(jnp.sum(p[g] * M_g[g])) for g in GROUPS) / Msum)
        print(f"[finetune:{name}/b{budget}/t{target}] epoch {ep} ce {last_ce:.4f} "
              f"avg_p {avg:.4f} ({time.time() - t0:.0f}s)", flush=True)

    # Snap the tiny residual regularization error by uniform shift, then
    # serialize per-linear p in canonical linear order.
    avg = float(sum(float(jnp.sum(p[g] * M_g[g])) for g in GROUPS) / Msum)
    shift = target - avg
    p = {g: jnp.clip(p[g] + shift, lo_b[g], hi_b[g]) for g in GROUPS}
    avg = float(sum(float(jnp.sum(p[g] * M_g[g])) for g in GROUPS) / Msum)

    p_list = [float(p[g][layer]) for (layer, g) in idx]
    out = {
        "model": name, "budget": budget, "target": target, "alpha": alpha,
        "calib_set": calib_set, "avg_p": avg,
        "fixed_lh": list(fixed_lh) if fixed_lh else None,
        "p": p_list,
    }
    tag = tag or f"{target:.2f}"
    io.save_json(io.art("calib", name, f"budget{budget}", f"dpllm_p_{tag}.json"), out)
    print(f"[finetune:{name}/b{budget}/t{target}] done avg_p {avg:.4f}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny", choices=sorted(PRESETS))
    ap.add_argument("--budget", type=int, default=5)
    ap.add_argument("--target", type=float, default=0.0,
                    help="0 = all targets for the budget")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--calib-set", default="synthweb",
                    choices=("synthweb", "synthwiki"))
    ap.add_argument("--tag", default="", help="output tag override")
    args = ap.parse_args()
    targets = [args.target] if args.target else targets_for_budget(args.budget)
    for t in targets:
        finetune_p(args.model, args.budget, t, epochs=args.epochs,
                   calib_set=args.calib_set, tag=args.tag or None)


if __name__ == "__main__":
    main()
