"""Any-Precision quantization substrate (paper [1], built from scratch).

Pipeline per model:

  1. **Diagonal Fisher** — squared gradients of the CE loss over the
     calibration stream, accumulated per weight (SqueezeLLM's sensitivity
     proxy; also reused by Phase 1 and the HAWQ-V2 baseline).
  2. **Seed quantization** — per *output channel*, Fisher-weighted 1-D
     k-means with 2³ centroids (SqueezeLLM-style non-uniform), giving the
     3-bit codes.
  3. **Incremental upscaling** — every cluster is recursively split in two
     (Fisher-weighted 2-means within the parent) up to 6 bits, so the b-bit
     code of every weight is the MSB-prefix of its (b+1)-bit code.  This is
     exactly Any-Precision LLM's nesting property: one 6-bit store serves
     all bitwidths.
  4. **Bitplane packing** — codes are stored MSB-first as packed bitplanes
     (`kernels/ref.py` documents the layout) + per-bitwidth LUTs.

Outputs ``artifacts/models/<name>/fisher.npz`` and ``anyprec.npz``.

Usage: python -m compile.quantize --model dpl-tiny
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import io_utils as io
from .kernels.ref import pack_codes_np
from .model import GROUPS, ModelConfig, PRESETS, loss_fn


# ---------------------------------------------------------------------------
# Fisher information (diagonal).
# ---------------------------------------------------------------------------


def calib_batches(path: str, n_seqs: int, seq: int, seed: int = 0):
    data = np.fromfile(path, dtype=np.uint16)
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(data) - seq - 1, size=n_seqs)
    return np.stack([data[s:s + seq] for s in starts]).astype(np.int32)


def fisher_diag(params: dict, cfg: ModelConfig, calib: np.ndarray,
                batch: int = 4) -> dict:
    """Accumulated squared gradients (diag Fisher) for the 7 linear groups,
    plus the mean signed gradients (``grad_<g>``) the LLM-MQ baseline uses."""
    grad_fn = jax.jit(jax.grad(lambda prm, toks: loss_fn(prm, cfg, toks)))
    acc = {g: jnp.zeros_like(params[g]) for g in GROUPS}
    acc_g = {g: jnp.zeros_like(params[g]) for g in GROUPS}
    n = 0
    for i in range(0, len(calib), batch):
        g = grad_fn(params, jnp.asarray(calib[i:i + batch]))
        for k in GROUPS:
            acc[k] = acc[k] + jnp.square(g[k])
            acc_g[k] = acc_g[k] + g[k]
        n += 1
    out = {k: np.asarray(v / n) for k, v in acc.items()}
    out.update({f"grad_{k}": np.asarray(v / n) for k, v in acc_g.items()})
    return out


# ---------------------------------------------------------------------------
# Fisher-weighted nested k-means (vectorized over rows).
# ---------------------------------------------------------------------------


def _weighted_kmeans_rows(v: np.ndarray, w: np.ndarray, k: int,
                          iters: int = 18) -> tuple[np.ndarray, np.ndarray]:
    """1-D weighted k-means run independently per row.

    v, w: [R, N]; returns (codes [R, N] int, centroids [R, k]).
    Centroids are kept sorted so codes are monotone in value.
    """
    R, N = v.shape
    qs = (np.arange(k) + 0.5) / k
    order = np.argsort(v, axis=1)
    v_sorted = np.take_along_axis(v, order, axis=1)
    w_sorted = np.take_along_axis(w, order, axis=1)
    cw = np.cumsum(w_sorted, axis=1)
    tot = cw[:, -1:] + 1e-12
    cw = cw / tot
    # Initialize at weighted quantiles.
    cent = np.empty((R, k), np.float32)
    for j, q in enumerate(qs):
        idx = np.argmax(cw >= q, axis=1)
        cent[:, j] = np.take_along_axis(v_sorted, idx[:, None], axis=1)[:, 0]
    for _ in range(iters):
        # Assignment by nearest centroid (1-D: threshold at midpoints).
        mids = 0.5 * (cent[:, 1:] + cent[:, :-1])              # [R, k-1]
        codes = np.zeros((R, N), np.int64)
        for j in range(k - 1):
            codes += (v > mids[:, j:j + 1]).astype(np.int64)
        # Update: weighted means per cluster.
        new_cent = cent.copy()
        for j in range(k):
            m = codes == j
            wm = w * m
            sw = wm.sum(axis=1)
            sv = (wm * v).sum(axis=1)
            has = sw > 0
            new_cent[has, j] = (sv[has] / sw[has]).astype(np.float32)
        new_cent = np.sort(new_cent, axis=1)
        if np.allclose(new_cent, cent, atol=1e-7):
            cent = new_cent
            break
        cent = new_cent
    mids = 0.5 * (cent[:, 1:] + cent[:, :-1])
    codes = np.zeros((R, N), np.int64)
    for j in range(k - 1):
        codes += (v > mids[:, j:j + 1]).astype(np.int64)
    return codes, cent


def _split_clusters(v: np.ndarray, w: np.ndarray, codes: np.ndarray,
                    cent: np.ndarray, iters: int = 8):
    """One incremental-upscale level: split every cluster in two.

    v, w: [R, N]; codes: [R, N] in [0, K); cent: [R, K].
    Returns (codes2 [R, N] in [0, 2K), cent2 [R, 2K]).
    child code = parent*2 + side, so the nesting (MSB-prefix) property
    holds by construction.
    """
    R, N = v.shape
    K = cent.shape[1]
    cent2 = np.empty((R, 2 * K), np.float32)
    codes2 = np.zeros((R, N), np.int64)
    for p in range(K):
        m = codes == p
        wm = (w * m).astype(np.float64)
        sw = wm.sum(axis=1) + 1e-20
        mu = (wm * v).sum(axis=1) / sw
        var = (wm * (v - mu[:, None]) ** 2).sum(axis=1) / sw
        sd = np.sqrt(var) + 1e-12
        c0 = (mu - 0.6 * sd).astype(np.float32)
        c1 = (mu + 0.6 * sd).astype(np.float32)
        for _ in range(iters):
            thr = 0.5 * (c0 + c1)
            right = m & (v > thr[:, None])
            left = m & ~right
            wl = (w * left).sum(axis=1)
            wr = (w * right).sum(axis=1)
            vl = (w * left * v).sum(axis=1)
            vr = (w * right * v).sum(axis=1)
            hl = wl > 0
            hr = wr > 0
            nc0 = c0.copy()
            nc1 = c1.copy()
            nc0[hl] = (vl[hl] / wl[hl]).astype(np.float32)
            nc1[hr] = (vr[hr] / wr[hr]).astype(np.float32)
            c0, c1 = np.minimum(nc0, nc1), np.maximum(nc0, nc1)
        thr = 0.5 * (c0 + c1)
        side = (v > thr[:, None]) & m
        codes2[m] = 2 * p
        codes2[side] = 2 * p + 1
        cent2[:, 2 * p] = c0
        cent2[:, 2 * p + 1] = c1
    return codes2, cent2


def quantize_group(w: np.ndarray, fisher: np.ndarray):
    """Nested-quantize one stacked group [L, out, in].

    Returns (planes u8 [L, 6, out, in/8], luts {b: [L, out, 2**b]}).
    """
    L, out, n_in = w.shape
    v = w.reshape(L * out, n_in).astype(np.float32)
    f = fisher.reshape(L * out, n_in).astype(np.float32)
    # Guard degenerate rows (all-zero fisher -> uniform weights).
    f = f + f.mean(axis=1, keepdims=True) * 1e-3 + 1e-12
    codes, cent = _weighted_kmeans_rows(v, f, 8)
    luts = {3: cent.reshape(L, out, 8)}
    for b in (4, 5, 6):
        codes, cent = _split_clusters(v, f, codes, cent)
        luts[b] = cent.reshape(L, out, 2 ** b)
    planes = np.stack([
        pack_codes_np(codes[i * out:(i + 1) * out].astype(np.int64))
        for i in range(L)
    ])  # [L, 6, out, in/8]
    return planes, luts


def quantize_model(name: str, calib_seqs: int = 24, seq: int = 128) -> None:
    cfg = PRESETS[name]
    params = {k: jnp.asarray(v) for k, v in
              io.load_npz(io.art("models", name, "ckpt.npz")).items()}
    calib = calib_batches(io.art("data", "synthweb_calib.bin"), calib_seqs, seq)

    t0 = time.time()
    print(f"[quantize:{name}] fisher over {calib_seqs}x{seq} tokens ...", flush=True)
    fisher = fisher_diag(params, cfg, calib)
    io.save_npz(io.art("models", name, "fisher.npz"), fisher)
    print(f"[quantize:{name}] fisher done ({time.time() - t0:.1f}s)", flush=True)

    out = {}
    for g in GROUPS:
        t1 = time.time()
        planes, luts = quantize_group(np.asarray(params[g]), fisher[g])
        out[f"planes_{g}"] = planes
        for b, lut in luts.items():
            out[f"lut{b}_{g}"] = lut
        print(f"[quantize:{name}] group {g} {tuple(params[g].shape)} "
              f"({time.time() - t1:.1f}s)", flush=True)
    io.save_npz(io.art("models", name, "anyprec.npz"), out)
    print(f"[quantize:{name}] total {time.time() - t0:.1f}s", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny", choices=sorted(PRESETS))
    ap.add_argument("--calib-seqs", type=int, default=24)
    args = ap.parse_args()
    quantize_model(args.model, args.calib_seqs)


if __name__ == "__main__":
    main()
