"""Synthetic corpus and downstream-task generators.

The paper evaluates on WikiText2/C4 (perplexity) and GSM8K/MBPP/BBH/MATH
(decoding-heavy downstream tasks).  None of those are downloadable in this
sandbox, so we substitute deterministic generators that preserve the
properties the experiments actually measure (see DESIGN.md §2):

  * ``synthwiki`` — clean, encyclopedic, templated text with long-range
    entity/attribute consistency (WikiText2 analog).
  * ``synthweb``  — noisier, mixed-register text: reviews, forum posts,
    how-tos, classifieds (C4 analog, distribution-shifted from synthwiki
    so the Table-14 calibration-transfer study is meaningful).
  * ``arith``     — one/two-step arithmetic word problems ending in
    ``#### <n>`` (GSM8K analog, exact-match on the final number).
  * ``listfn``    — tiny list-transformation programs (MBPP analog,
    exact-match on the output list).
  * ``dates``     — weekday/offset multiple-choice questions (BBH analog,
    exact-match on the option letter).
  * ``algebra``   — linear equations ``ax + b = c`` (MATH analog,
    exact-match on the solution).
  * ``instruct``  — instruction-following prompts with length-varied
    responses (Alpaca analog, used only for the per-query QoS study).

All generators are seeded and split train/eval disjointly (eval parameter
tuples never appear in train).  Task data is mixed into pre-training so the
tiny models genuinely acquire the tasks; quantization then degrades them
gracefully, which is the gradient Tables 1/2 measure.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary pools (all synthetic, deterministic).
# ---------------------------------------------------------------------------

_SYL_A = ["ka", "mo", "ri", "ta", "ve", "lu", "sa", "ne", "do", "pi", "ga", "zu"]
_SYL_B = ["ran", "bel", "mir", "dor", "lin", "vas", "ker", "nol", "tis", "mar"]

FIRST_NAMES = [
    "Mara", "Jon", "Tessa", "Rafi", "Lena", "Theo", "Nadia", "Owen", "Priya",
    "Carl", "Ines", "Bram", "Sofia", "Dmitri", "Hana", "Felix", "Ruth", "Omar",
    "Greta", "Ivo", "June", "Kofi", "Lars", "Mina", "Nils", "Okto", "Pema",
    "Quin", "Rosa", "Stig", "Tova", "Ugo", "Vera", "Wim", "Xena", "Yara", "Zeno",
]

OBJECTS = [
    "plums", "coins", "marbles", "books", "apples", "stamps", "shells",
    "pencils", "tokens", "cards", "stones", "beads", "tickets", "acorns",
]

PROFESSIONS = [
    "cartographer", "glassblower", "archivist", "botanist", "ferry pilot",
    "clockmaker", "surveyor", "printer", "weaver", "astronomer", "miller",
    "engraver", "apiarist", "stonemason",
]

EXPORTS = [
    "river salt", "blue ceramics", "pressed olives", "copper wire",
    "dried figs", "woven flax", "cedar planks", "glass lenses",
    "iron tools", "paper reels", "wool cloth", "honey wax",
]

REGIONS = [
    "the northern plateau", "the delta lowlands", "the eastern foothills",
    "the lake district", "the coastal terraces", "the inland basin",
    "the southern ridge", "the high moor",
]

CLIMATES = [
    "mild and wet", "dry and windy", "cold in winter and bright in summer",
    "foggy for much of the year", "warm with short rains", "temperate",
]

LANDMARKS = [
    "stone bridge", "tide mill", "old granary", "signal tower", "salt market",
    "round library", "cliff stair", "river gate", "twin aqueduct", "sun dial",
]

ADJ_REVIEW = [
    "sturdy", "flimsy", "bright", "quiet", "heavy", "compact", "reliable",
    "awkward", "smooth", "rough", "cheap", "well made", "fragile", "fast",
]

PRODUCTS = [
    "kettle", "lamp", "backpack", "keyboard", "bicycle pump", "thermos",
    "notebook", "headset", "tripod", "wall clock", "door hinge", "rain coat",
]

WEEKDAYS = [
    "Monday", "Tuesday", "Wednesday", "Thursday", "Friday", "Saturday", "Sunday",
]

_SPELLED = {
    1: "one", 2: "two", 3: "three", 4: "four", 5: "five", 6: "six",
}


def _entity_name(rng: np.random.Generator) -> str:
    """A synthetic proper noun like 'Kamodor' or 'Velumir'."""
    n = rng.integers(1, 3)
    name = "".join(rng.choice(_SYL_A) for _ in range(n)) + str(rng.choice(_SYL_B))
    return name.capitalize()


# ---------------------------------------------------------------------------
# synthwiki — encyclopedic articles about towns with consistent facts.
# ---------------------------------------------------------------------------


def _town_article(rng: np.random.Generator) -> str:
    name = _entity_name(rng)
    region = rng.choice(REGIONS)
    pop = int(rng.integers(2, 95)) * 1000
    founded = int(rng.integers(1100, 1900))
    export = rng.choice(EXPORTS)
    climate = rng.choice(CLIMATES)
    landmark = rng.choice(LANDMARKS)
    prof = rng.choice(PROFESSIONS)
    person = rng.choice(FIRST_NAMES)

    s = []
    s.append(
        f"{name} is a town in {region} with a population of about {pop}."
    )
    s.append(f"It was founded in {founded} and is known for {export}.")
    s.append(f"The climate of {name} is {climate}.")
    s.append(
        f"The best known landmark of {name} is the {landmark}, which stands "
        f"near the centre of the town."
    )
    s.append(
        f"Trade in {export} made {name} an important stop on the routes of "
        f"{region}."
    )
    if rng.random() < 0.6:
        s.append(
            f"{person} the {prof}, born in {name} in {founded + int(rng.integers(30, 300))}, "
            f"wrote an early account of the {landmark}."
        )
    if rng.random() < 0.5:
        s.append(
            f"Today the population of {name} is close to {pop}, and {export} "
            f"remains the main trade."
        )
    rng.shuffle(s[2:])
    return " ".join(s)


def gen_synthwiki(rng: np.random.Generator, n_articles: int) -> str:
    parts = [_town_article(rng) for _ in range(n_articles)]
    return "\n\n".join(parts) + "\n"


# ---------------------------------------------------------------------------
# synthweb — noisy mixed-register text.
# ---------------------------------------------------------------------------


def _review(rng: np.random.Generator) -> str:
    prod = rng.choice(PRODUCTS)
    adj1, adj2 = rng.choice(ADJ_REVIEW, size=2, replace=False)
    stars = int(rng.integers(1, 6))
    name = rng.choice(FIRST_NAMES)
    t = [
        f"{stars} stars. The {prod} is {adj1} but a bit {adj2}.",
        f"review by {name}: bought this {prod} last month, it is {adj1}.",
        f"would i buy the {prod} again? {'yes' if stars >= 3 else 'no'}, "
        f"it is {adj1} and the price was fair.",
    ]
    return str(rng.choice(t))


def _forum(rng: np.random.Generator) -> str:
    a, b = rng.choice(FIRST_NAMES, size=2, replace=False)
    prod = rng.choice(PRODUCTS)
    k = int(rng.integers(2, 9))
    return (
        f"{a}: has anyone tried fixing a {prod} with tape?\n"
        f"{b}: yes, mine held for {k} weeks. use two layers.\n"
        f"{a}: thanks, will try that."
    )


def _howto(rng: np.random.Generator) -> str:
    prod = rng.choice(PRODUCTS)
    steps = int(rng.integers(3, 6))
    lines = [f"how to clean a {prod} in {steps} steps:"]
    verbs = ["rinse", "wipe", "dry", "check", "oil", "tighten", "dust"]
    chosen = rng.choice(verbs, size=steps, replace=False)
    for i in range(steps):
        lines.append(f"step {i + 1}: {chosen[i]} the {prod} carefully.")
    return "\n".join(lines)


def _classified(rng: np.random.Generator) -> str:
    prod = rng.choice(PRODUCTS)
    price = int(rng.integers(3, 80))
    name = rng.choice(FIRST_NAMES)
    return (
        f"for sale: used {prod}, {rng.choice(ADJ_REVIEW)}, {price} crowns. "
        f"contact {name.lower()} after six."
    )


def gen_synthweb(rng: np.random.Generator, n_docs: int) -> str:
    gens = [_review, _forum, _howto, _classified]
    parts = []
    for _ in range(n_docs):
        g = gens[int(rng.integers(0, len(gens)))]
        parts.append(g(rng))
    return "\n\n".join(parts) + "\n"


# ---------------------------------------------------------------------------
# Tasks.  Each generator returns (prompt, answer, full_text) where
# full_text = prompt + answer is what goes into the training mix and the
# eval harness checks `answer` via task-specific exact matching.
# ---------------------------------------------------------------------------


@dataclass
class TaskSample:
    task: str
    prompt: str
    answer: str

    @property
    def text(self) -> str:
        return self.prompt + self.answer


def _arith_sample(rng: np.random.Generator) -> TaskSample:
    name = rng.choice(FIRST_NAMES)
    other = rng.choice([n for n in FIRST_NAMES if n != name])
    obj = rng.choice(OBJECTS)
    a = int(rng.integers(2, 60))
    b = int(rng.integers(2, 40))
    kind = int(rng.integers(0, 3))
    if kind == 0:
        q = (
            f"Question: {name} has {a} {obj}. {other} gives {name} {b} more. "
            f"How many {obj} does {name} have?"
        )
        work = f"{a} + {b} = {a + b}."
        ans = a + b
    elif kind == 1:
        a = max(a, b + 1)
        q = (
            f"Question: {name} has {a} {obj}. {name} gives {b} {obj} to {other}. "
            f"How many {obj} are left?"
        )
        work = f"{a} - {b} = {a - b}."
        ans = a - b
    else:
        c = int(rng.integers(2, 20))
        q = (
            f"Question: {name} has {a} {obj}. {other} gives {name} {b} more, "
            f"then {name} loses {c}. How many {obj} does {name} have?"
        )
        work = f"{a} + {b} = {a + b}. {a + b} - {c} = {a + b - c}."
        ans = a + b - c
    prompt = q + "\nAnswer: "
    answer = f"{work} #### {ans}"
    return TaskSample("arith", prompt, answer)


_LIST_OPS = ["add", "double", "reverse", "first", "last", "count"]


def _listfn_sample(rng: np.random.Generator) -> TaskSample:
    op = str(rng.choice(_LIST_OPS))
    n = int(rng.integers(2, 5))
    xs = [int(v) for v in rng.integers(1, 20, size=n)]
    xs_s = " ".join(str(v) for v in xs)
    if op == "add":
        k = int(rng.integers(1, 6))
        desc = f"add {k} to each item"
        out = " ".join(str(v + k) for v in xs)
    elif op == "double":
        desc = "double each item"
        out = " ".join(str(2 * v) for v in xs)
    elif op == "reverse":
        desc = "reverse the list"
        out = " ".join(str(v) for v in reversed(xs))
    elif op == "first":
        desc = "take the first item"
        out = str(xs[0])
    elif op == "last":
        desc = "take the last item"
        out = str(xs[-1])
    else:
        desc = "count the items"
        out = str(len(xs))
    prompt = f"Task: {desc}. Input: {xs_s}. Output: "
    return TaskSample("listfn", prompt, out)


def _dates_sample(rng: np.random.Generator) -> TaskSample:
    start = int(rng.integers(0, 7))
    off = int(rng.integers(1, 7))
    fwd = bool(rng.integers(0, 2))
    correct = WEEKDAYS[(start + (off if fwd else -off)) % 7]
    direction = "after" if fwd else "before"
    off_word = _SPELLED[off]
    # Three options, one correct, stable letters.
    wrong = [d for d in WEEKDAYS if d != correct]
    rng.shuffle(wrong)
    opts = [correct, wrong[0], wrong[1]]
    rng.shuffle(opts)
    letters = ["A", "B", "C"]
    right = letters[opts.index(correct)]
    opt_s = " ".join(f"({letter}) {day}" for letter, day in zip(letters, opts))
    prompt = (
        f"Question: which day comes {off_word} days {direction} {WEEKDAYS[start]}? "
        f"Options: {opt_s}. Answer: "
    )
    return TaskSample("dates", prompt, f"({right})")


def _algebra_sample(rng: np.random.Generator) -> TaskSample:
    a = int(rng.integers(1, 6))
    x = int(rng.integers(1, 15))
    b = int(rng.integers(0, 20))
    c = a * x + b
    if a == 1:
        lhs = f"x + {b}" if b else "x"
    else:
        lhs = f"{a}x + {b}" if b else f"{a}x"
    steps = []
    if b:
        steps.append(f"{a}x = {c} - {b} = {a * x}." if a != 1 else f"x = {c} - {b} = {x}.")
    if a != 1:
        steps.append(f"x = {a * x} / {a} = {x}.")
    if not steps:
        steps.append(f"x = {x}.")
    prompt = f"Solve: {lhs} = {c}.\nSolution: "
    answer = " ".join(steps) + f" x = {x}"
    return TaskSample("algebra", prompt, answer)


def _instruct_sample(rng: np.random.Generator) -> TaskSample:
    kind = int(rng.integers(0, 4))
    if kind == 0:
        town = _entity_name(rng)
        prompt = f"Instruction: describe the town of {town}.\nResponse: "
        body = _town_article(rng).replace(town, town, 1)
        answer = body
    elif kind == 1:
        prod = rng.choice(PRODUCTS)
        prompt = f"Instruction: write a short review of a {prod}.\nResponse: "
        answer = _review(rng)
    elif kind == 2:
        prod = rng.choice(PRODUCTS)
        prompt = f"Instruction: explain how to clean a {prod}.\nResponse: "
        answer = _howto(rng)
    else:
        obj = rng.choice(OBJECTS)
        n = int(rng.integers(3, 8))
        prompt = f"Instruction: list {n} uses for {obj}.\nResponse: "
        uses = ["trading", "counting", "decorating", "sorting games",
                "teaching sums", "marking paths", "weighing scales", "gifts"]
        rng.shuffle(uses)
        answer = " ".join(f"{i + 1}. {u}." for i, u in enumerate(uses[:n]))
    return TaskSample("instruct", prompt, answer)


_TASK_GENS = {
    "arith": _arith_sample,
    "listfn": _listfn_sample,
    "dates": _dates_sample,
    "algebra": _algebra_sample,
    "instruct": _instruct_sample,
}

TASKS = tuple(t for t in _TASK_GENS if t != "instruct")


def gen_task_samples(task: str, rng: np.random.Generator, n: int) -> list[TaskSample]:
    g = _TASK_GENS[task]
    return [g(rng) for _ in range(n)]


def _dedup_key(s: TaskSample) -> str:
    return hashlib.sha1(s.prompt.encode()).hexdigest()


def gen_task_split(task: str, seed: int, n_train: int, n_eval: int):
    """Disjoint train/eval task samples (eval prompts never seen in train)."""
    rng = np.random.default_rng(seed)
    train = gen_task_samples(task, rng, n_train)
    seen = {_dedup_key(s) for s in train}
    eval_s: list[TaskSample] = []
    guard = 0
    while len(eval_s) < n_eval and guard < 50 * n_eval:
        s = _TASK_GENS[task](rng)
        guard += 1
        if _dedup_key(s) not in seen:
            seen.add(_dedup_key(s))
            eval_s.append(s)
    return train, eval_s


# ---------------------------------------------------------------------------
# Full corpus assembly.
# ---------------------------------------------------------------------------


def build_corpus(seed: int = 0,
                 wiki_articles: int = 9000,
                 web_docs: int = 16000,
                 task_train: int = 3000,
                 task_eval: int = 200,
                 instruct_train: int = 1500):
    """Returns a dict of named text blobs and task splits.

    Keys: 'train_text' (the pre-training mix), 'synthwiki_eval',
    'synthweb_eval', 'tasks' -> {task: (train, eval)}.
    """
    rng = np.random.default_rng(seed)
    wiki = gen_synthwiki(rng, wiki_articles)
    web = gen_synthweb(rng, web_docs)
    # Held-out eval text from *fresh* entity draws (different articles).
    eval_rng = np.random.default_rng(seed + 104729)
    wiki_eval = gen_synthwiki(eval_rng, max(200, wiki_articles // 12))
    web_eval = gen_synthweb(eval_rng, max(400, web_docs // 12))

    tasks = {}
    task_texts = []
    for i, task in enumerate(sorted(_TASK_GENS)):
        n_tr = instruct_train if task == "instruct" else task_train
        tr, ev = gen_task_split(task, seed + 31 * (i + 1), n_tr, task_eval)
        tasks[task] = (tr, ev)
        task_texts.extend(s.text for s in tr)

    t_rng = np.random.default_rng(seed + 7)
    t_rng.shuffle(task_texts)
    train_text = wiki + "\n" + web + "\n" + "\n\n".join(task_texts) + "\n"
    return {
        "train_text": train_text,
        "synthwiki_eval": wiki_eval,
        "synthweb_eval": web_eval,
        "tasks": tasks,
    }
