"""Fig. 3 analysis: dynamic layer-wise sensitivity across decoding steps.

(a) For one held-out sample, per transformer block j and decoding step t:
    sensitivity(j, t) = NLL_{all-3bit}(t) − NLL_{block-j-at-4bit}(t)
    (the paper's definition: perplexity decrease from applying 4-bit to
    that layer while the rest stay at 3-bit).  L+1 teacher-forced
    forwards.

(b) Perplexity *trend* of three schemes on the same sample, via true
    step-by-step decoding with a per-step per-block bit mask:
      - oracle dynamic: at each step the top-20% blocks by (a)'s
        sensitivity at that step run at 4-bit,
      - static: the top-20% blocks by mean sensitivity run at 4-bit,
      - uniform 3-bit.
    The oracle is impractical at runtime (it peeks at the answer) — it is
    the paper's indicator of the headroom DP-LLM goes after.

Writes ``artifacts/analysis/fig3a.json`` and ``fig3b.json``.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from . import io_utils as io
from .finetune_p import load_level_stacks
from .kernels.estimator import K_PROJ
from .model import (ASYNC_GROUPS, GROUPS, ModelConfig, PRESETS, ce_per_token,
                    decode_step_dual, kv_shape)


def _nl(name: str) -> dict:
    ckpt = io.load_npz(io.art("models", name, "ckpt.npz"))
    return {k: jnp.asarray(v) for k, v in ckpt.items() if k not in GROUPS}


def _lin_at_bits(levels: dict, bits_per_block: np.ndarray) -> dict:
    """Materialize stacked linears with per-block bit choices (3..6)."""
    out = {}
    for g in GROUPS:
        lv = levels[g]  # [L, 4, out, in]
        idx = jnp.asarray(bits_per_block - 3, jnp.int32)
        out[g] = jax.vmap(lambda l, i: l[i])(lv, idx)
    return out


def per_step_sensitivity(name: str, seq_len: int = 96):
    cfg = PRESETS[name]
    nl = _nl(name)
    levels = load_level_stacks(name, cfg)
    data = np.fromfile(io.art("data", "synthwiki_eval.bin"), np.uint16)
    tokens = jnp.asarray(data[:seq_len + 1][None].astype(np.int32))

    nll = jax.jit(lambda lin: ce_per_token(nl, lin, cfg, tokens))
    base_bits = np.full(cfg.n_layers, 3)
    base = np.asarray(nll(_lin_at_bits(levels, base_bits))[0])  # [S]
    sens = np.zeros((cfg.n_layers, seq_len))
    for j in range(cfg.n_layers):
        bits = base_bits.copy()
        bits[j] = 4
        cur = np.asarray(nll(_lin_at_bits(levels, bits))[0])
        sens[j] = base - cur
    return sens, base


def decode_with_mask_series(name: str, masks: np.ndarray, tokens: np.ndarray):
    """Teacher-forced stepwise decode with per-step per-block 4-bit masks.

    masks [S, L] in {0,1}: 1 -> block runs at 4-bit this step, else 3-bit.
    Returns per-step NLL [S].
    Implemented on the same dual-precision graph the runtime uses:
    wl = 3-bit stacks, wh = 4-bit stacks; async groups take the mask via
    use_h_async, sync groups via ±inf thresholds.
    """
    cfg = PRESETS[name]
    nl = _nl(name)
    levels = load_level_stacks(name, cfg)
    wl = _lin_at_bits(levels, np.full(cfg.n_layers, 3))
    wh = _lin_at_bits(levels, np.full(cfg.n_layers, 4))
    est = {}
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        L = cfg.n_layers
        est[f"G_{g}"] = jnp.zeros((L, K_PROJ, i))
        est[f"lina_{g}"] = jnp.zeros(L)
        est[f"linb_{g}"] = jnp.zeros(L)
        est[f"uselin_{g}"] = jnp.ones(L)
        # thr filled per step below

    hd = cfg.head_dim
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))

    @jax.jit
    def step(token, pos, cos, sin, kv, mask):
        e = dict(est)
        for g in GROUPS:
            # est = lin_b = 0; thr<0 -> use high, thr>0 -> use low.
            e[f"thr_{g}"] = jnp.where(mask > 0.5, -1e30, 1e30)
        use_async = {g: mask for g in ASYNC_GROUPS}
        logits, kv, _, _ = decode_step_dual(
            nl, wl, wh, e, cfg, token, pos, cos, sin, kv, use_async,
            jnp.float32(0.0))
        return jax.nn.log_softmax(logits), kv

    S = masks.shape[0]
    kv = jnp.zeros(kv_shape(cfg))
    nlls = []
    for t in range(S):
        cos = jnp.asarray(np.cos(t * inv), jnp.float32)
        sin = jnp.asarray(np.sin(t * inv), jnp.float32)
        lp, kv = step(jnp.int32(tokens[t]), jnp.int32(t), cos, sin, kv,
                      jnp.asarray(masks[t], jnp.float32))
        nlls.append(float(-lp[tokens[t + 1]]))
    return np.asarray(nlls)


def run(name: str, seq_len: int = 96, top_frac: float = 0.2):
    cfg = PRESETS[name]
    sens, base_nll = per_step_sensitivity(name, seq_len)
    k = max(1, int(round(top_frac * cfg.n_layers)))

    # Fig 3a: top-k mask per step.
    order = np.argsort(-sens, axis=0)
    topmask = np.zeros_like(sens, dtype=int)
    for t in range(sens.shape[1]):
        topmask[order[:k, t], t] = 1
    io.save_json(io.art("analysis", f"fig3a_{name}.json"), {
        "model": name, "top_frac": top_frac, "seq_len": seq_len,
        "sensitivity": [[round(float(x), 6) for x in row] for row in sens],
        "top_mask": topmask.tolist(),
    })

    data = np.fromfile(io.art("data", "synthwiki_eval.bin"), np.uint16)
    tokens = data[:seq_len + 1].astype(np.int64)

    # Oracle dynamic vs static vs uniform-3bit.
    masks_dyn = topmask.T.astype(np.float64)                 # [S, L]
    mean_sens = sens.mean(axis=1)
    static_idx = np.argsort(-mean_sens)[:k]
    masks_sta = np.zeros((seq_len, cfg.n_layers))
    masks_sta[:, static_idx] = 1.0
    masks_uni = np.zeros((seq_len, cfg.n_layers))

    out = {"model": name, "steps": seq_len, "k": k}
    for key, masks in (("dynamic_oracle", masks_dyn), ("static", masks_sta),
                       ("uniform3", masks_uni)):
        nll = decode_with_mask_series(name, masks, tokens)
        trend = np.exp(np.cumsum(nll) / (np.arange(seq_len) + 1))
        out[key] = {
            "ppl_trend": [round(float(x), 4) for x in trend],
            "final_ppl": float(trend[-1]),
        }
        print(f"[fig3:{name}] {key}: ppl {trend[-1]:.3f}", flush=True)
    io.save_json(io.art("analysis", f"fig3b_{name}.json"), out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny")
    ap.add_argument("--steps", type=int, default=96)
    args = ap.parse_args()
    run(args.model, args.steps)


if __name__ == "__main__":
    main()
