"""Phase 1 (layer-wise maximum precision) + static mixed-precision baselines.

All three precision-assignment problems in the paper share one structure
(Appendix A, Eq. 6 / Appendix B.2, Eq. 7): pick one bitwidth b in {3..6}
per linear layer i, minimizing a per-(i, b) sensitivity cost subject to an
average-bitwidth (memory) constraint

    sum_i b_i * M_i  <=  b_targ * sum_i M_i         (upper bound)
    sum_i b_i * M_i  >=  b_targmin * sum_i M_i      (LLM-MQ Eq. 8 refinement)

with costs:

  * Phase 1 / HAWQ-V2:  Ω_i,b = Σ_k F_k (W - W_b)_k²   (Fisher ≈ Hessian
    diagonal; HAWQ-V2's trace-weighted form reduces to this under the
    diagonal-Fisher approximation, following SqueezeLLM [13]),
  * LLM-MQ:             Ω_i,b = |g^T (W - W_b)|        (first-order).

The problem is a multiple-choice knapsack.  We solve it with a Lagrangian
bisection over the budget multiplier followed by greedy refinement — exact
up to the budget granularity (DESIGN.md §7.5), and we reproduce the
paper's ±0.005-bit target matching.

Outputs land in ``artifacts/calib/<model>/<budget>/``:
  ``maxprec.json``       Phase-1 list B[i]  (DP-LLM memory-budget fit)
  ``llm_mq_<t>.json``    static per-linear bits for target t
  ``hawq_v2_<t>.json``   static per-linear bits for target t
"""

from __future__ import annotations

import argparse

import numpy as np

from . import io_utils as io
from .kernels.ref import dequant_np
from .model import GROUPS, PRESETS, ModelConfig

BITS = (3, 4, 5, 6)


# ---------------------------------------------------------------------------
# Sensitivity tables.
# ---------------------------------------------------------------------------


def linear_index(cfg: ModelConfig):
    """Canonical enumeration of linears: (layer, group) in group-major-last
    order — index = layer * 7 + group_pos.  Shared with the Rust side."""
    return [(layer, g) for layer in range(cfg.n_layers) for g in GROUPS]


def load_model_arrays(name: str):
    ckpt = io.load_npz(io.art("models", name, "ckpt.npz"))
    anyprec = io.load_npz(io.art("models", name, "anyprec.npz"))
    fisher = io.load_npz(io.art("models", name, "fisher.npz"))
    return ckpt, anyprec, fisher


def dequant_linear(anyprec: dict, g: str, layer: int, bits: int) -> np.ndarray:
    planes = anyprec[f"planes_{g}"][layer]
    lut = anyprec[f"lut{bits}_{g}"][layer]
    return dequant_np(planes, lut, bits)


def sensitivity_tables(name: str, cfg: ModelConfig):
    """Returns (omega_hawq, omega_mq, M) each [n_linear, len(BITS)] / [n_linear].

    omega_hawq uses the diagonal Fisher; omega_mq uses the signed mean
    gradient (recomputed here from fisher.npz's companion ``grad_*`` arrays).
    """
    ckpt, anyprec, fisher = load_model_arrays(name)
    idx = linear_index(cfg)
    n = len(idx)
    omega_h = np.zeros((n, len(BITS)))
    omega_mq = np.zeros((n, len(BITS)))
    M = np.zeros(n)
    for li, (layer, g) in enumerate(idx):
        w = ckpt[g][layer]
        f = fisher[g][layer]
        grad = fisher.get(f"grad_{g}")
        gl = grad[layer] if grad is not None else np.sqrt(f)
        M[li] = w.size
        for bi, b in enumerate(BITS):
            dq = dequant_linear(anyprec, g, layer, b)
            dw = w - dq
            omega_h[li, bi] = float((f * dw * dw).sum())
            omega_mq[li, bi] = float(abs((gl * dw).sum()))
    return omega_h, omega_mq, M


# ---------------------------------------------------------------------------
# Multiple-choice knapsack via Lagrangian bisection + greedy refinement.
# ---------------------------------------------------------------------------


def _choose(omega: np.ndarray, M: np.ndarray, lam: float) -> np.ndarray:
    """argmin_b omega[i,b] + lam * b * M[i] per layer."""
    scores = omega + lam * np.outer(M, BITS)
    return np.argmin(scores, axis=1)


def _avg_bits(choice: np.ndarray, M: np.ndarray) -> float:
    bits = np.asarray(BITS)[choice]
    return float((bits * M).sum() / M.sum())


def solve_assignment(omega: np.ndarray, M: np.ndarray, b_targ: float,
                     max_bits: np.ndarray | None = None,
                     tol: float = 0.005) -> np.ndarray:
    """Pick per-layer bits minimizing total cost at avg precision ≈ b_targ.

    max_bits: optional per-layer cap (Phase-1 B[i] for the baselines).
    Returns per-layer bit values (ints from BITS).
    """
    omega = omega.copy()
    if max_bits is not None:
        for bi, b in enumerate(BITS):
            omega[:, bi] = np.where(b > max_bits, np.inf, omega[:, bi])
    # Lagrangian bisection on lambda >= 0 (higher lambda -> cheaper bits).
    lo, hi = 0.0, 1.0
    while _avg_bits(_choose(omega, M, hi), M) > b_targ and hi < 1e12:
        hi *= 4.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if _avg_bits(_choose(omega, M, mid), M) > b_targ:
            lo = mid
        else:
            hi = mid
    choice = _choose(omega, M, hi)  # feasible side (avg <= target)

    # Greedy refinement toward the target from below: repeatedly apply the
    # upgrade with the best Δcost/Δbits that keeps avg <= b_targ; then, as
    # in the paper's Eq. 8 lower-bound pass, keep upgrading until within
    # tol of the target even if it overshoots slightly.
    def upgrades(choice):
        out = []
        for i in range(len(choice)):
            bi = choice[i]
            if bi + 1 < len(BITS) and np.isfinite(omega[i, bi + 1]):
                dcost = omega[i, bi] - omega[i, bi + 1]  # benefit
                dbits = (BITS[bi + 1] - BITS[bi]) * M[i]
                out.append((dcost / dbits, i))
        out.sort(reverse=True)
        return out

    total_bits = (np.asarray(BITS)[choice] * M).sum()
    budget = b_targ * M.sum()
    while True:
        moved = False
        for _, i in upgrades(choice):
            db = (BITS[choice[i] + 1] - BITS[choice[i]]) * M[i]
            if total_bits + db <= budget + tol * M.sum():
                choice[i] += 1
                total_bits += db
                moved = True
                break
        if not moved:
            break
    return np.asarray(BITS)[choice]


# ---------------------------------------------------------------------------
# Entry point: Phase 1 + baseline adaptation sets for one (model, budget).
# ---------------------------------------------------------------------------


def targets_for_budget(budget: int) -> list[float]:
    """The paper's target grids per memory budget (Tables 1, 10, 11)."""
    if budget >= 6:
        return [3.5, 4.0, 4.5, 5.0, 5.5]
    if budget == 5:
        return [3.25, 3.5, 3.75, 4.0, 4.25, 4.5, 4.75]
    return [3.25, 3.5, 3.75]


def assign_model(name: str, budget: int) -> None:
    cfg = PRESETS[name]
    omega_h, omega_mq, M = sensitivity_tables(name, cfg)
    base = ("calib", name, f"budget{budget}")

    # Phase 1: maximum precision per layer under the memory budget,
    # using the second-order (Fisher) sensitivity.
    maxprec = solve_assignment(omega_h, M, float(budget))
    io.save_json(io.art(*base, "maxprec.json"), {
        "model": name, "budget": budget,
        "bits": [int(b) for b in maxprec],
        "avg_bits": _avg_bits(
            np.asarray([BITS.index(b) for b in maxprec]), M),
    })
    print(f"[assign:{name}/b{budget}] maxprec avg "
          f"{float((maxprec * M).sum() / M.sum()):.3f}", flush=True)

    # Static baselines: one assignment per target, capped by maxprec.
    for t in targets_for_budget(budget):
        for method, omega in (("llm_mq", omega_mq), ("hawq_v2", omega_h)):
            bits = solve_assignment(omega, M, t, max_bits=maxprec)
            avg = float((bits * M).sum() / M.sum())
            io.save_json(io.art(*base, f"{method}_{t:.2f}.json"), {
                "model": name, "budget": budget, "target": t,
                "method": method, "bits": [int(b) for b in bits],
                "avg_bits": avg,
            })
            print(f"[assign:{name}/b{budget}] {method} target {t:.2f} -> "
                  f"avg {avg:.3f}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dpl-tiny", choices=sorted(PRESETS))
    ap.add_argument("--budget", type=int, default=5, choices=(4, 5, 6))
    args = ap.parse_args()
    assign_model(args.model, args.budget)


if __name__ == "__main__":
    main()
