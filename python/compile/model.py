"""L2: LLaMA-style transformer in JAX.

One functional model definition serves four consumers:

  * ``train.py``          — batched forward + loss (fp32 weights),
  * ``quantize.py`` etc.  — the same forward with the seven linear-weight
    groups *overridden* (quantized / soft-mixed weights), via
    ``forward_with_weights``,
  * ``aot.py``            — the serving graphs: ``prefill`` and the
    dual-precision ``decode_step_dual`` with in-graph relative-error
    estimators and precision selection (DP-LLM's runtime mechanism),
  * ``kernels/``          — the Pallas any-precision GEMV is exercised by a
    separate AOT entry point (see aot.py) and validated against ref.py.

Weights are stored **stacked per layer**: e.g. ``wq`` has shape
``[L, D, D]`` — this makes ``jax.lax.scan`` over blocks natural and maps
1:1 onto the grouped weight stacks the Rust coordinator feeds at runtime.

Linear-group naming (7 groups, matching the paper's per-block linears):

    wq wk wv wo  — attention projections  ([L, D, D])
    wg wu        — SwiGLU gate/up         ([L, F, D])
    wd           — SwiGLU down            ([L, D, F])

Async-estimation groups (paper §5.2: layers fed directly by the residual
stream): q, k, v, gate, up.  Sync groups (immediate input required): o,
down.
"""

from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

GROUPS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")
ASYNC_GROUPS = ("wq", "wk", "wv", "wg", "wu")
SYNC_GROUPS = ("wo", "wd")


@dataclasses.dataclass
class ModelConfig:
    name: str = "dpl-tiny"
    vocab: int = 1024
    d_model: int = 256
    n_layers: int = 8
    n_heads: int = 8
    d_ff: int = 704
    max_seq: int = 640
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def group_shape(self, g: str) -> tuple[int, int]:
        d, f = self.d_model, self.d_ff
        return {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
                "wg": (f, d), "wu": (f, d), "wd": (d, f)}[g]

    def n_linear(self) -> int:
        return self.n_layers * len(GROUPS)

    def group_params(self, g: str) -> int:
        o, i = self.group_shape(g)
        return o * i

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, s: str) -> "ModelConfig":
        return cls(**json.loads(s))


# Sizes are scaled to the sandbox (single CPU core — see DESIGN.md §2);
# the *pairs* preserve the paper's role structure: two headline models and
# two extra scale points for Table 12.
PRESETS = {
    # paper analog: Llama-3-8B  -> dpl-tiny   (~3 M params)
    "dpl-tiny": ModelConfig("dpl-tiny", 1024, 192, 6, 6, 512),
    # paper analog: Phi-3-Medium -> dpl-small (~7 M params)
    "dpl-small": ModelConfig("dpl-small", 1024, 256, 8, 8, 704),
    # paper analog (Table 12): Qwen2.5-3B -> dpl-nano, Qwen2.5-32B -> dpl-base
    "dpl-nano": ModelConfig("dpl-nano", 1024, 96, 3, 4, 256),
    "dpl-base": ModelConfig("dpl-base", 1024, 320, 10, 8, 896),
}


# ---------------------------------------------------------------------------
# Parameter init / manipulation.
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab

    def nrm(*shape, scale=0.02):
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    resid_scale = 0.02 / np.sqrt(2 * L)
    p = {
        "tok_emb": nrm(v, d),
        "out_head": nrm(v, d),
        "final_norm": np.ones(d, np.float32),
        "ln1": np.ones((L, d), np.float32),
        "ln2": np.ones((L, d), np.float32),
        "wq": nrm(L, d, d), "wk": nrm(L, d, d), "wv": nrm(L, d, d),
        "wo": nrm(L, d, d, scale=resid_scale),
        "wg": nrm(L, f, d), "wu": nrm(L, f, d),
        "wd": nrm(L, d, f, scale=resid_scale),
    }
    return {k: jnp.asarray(v) for k, v in p.items()}


def extract_linears(params: dict) -> dict:
    return {g: params[g] for g in GROUPS}


def nonlinear_params(params: dict) -> dict:
    return {k: v for k, v in params.items() if k not in GROUPS}


# ---------------------------------------------------------------------------
# Building blocks.
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_tables(cfg: ModelConfig, positions: jnp.ndarray):
    """cos/sin tables [len(positions), head_dim/2]."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x [..., H, hd]; cos/sin broadcastable against [..., H, hd/2]."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape)


# ---------------------------------------------------------------------------
# Training / evaluation forward (full sequence, batched).
# ---------------------------------------------------------------------------


def forward_with_weights(nl: dict, lin: dict, cfg: ModelConfig,
                         tokens: jnp.ndarray) -> jnp.ndarray:
    """Causal forward. tokens [B, S] -> logits [B, S, V].

    ``nl`` holds the non-linear params, ``lin`` the 7 stacked linear groups
    (possibly quantized / soft-mixed — whatever the caller supplies).
    """
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x = nl["tok_emb"][tokens]  # [B, S, D]
    pos = jnp.arange(S)
    cos, sin = rope_tables(cfg, pos)          # [S, hd/2]
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    mask = jnp.tril(jnp.ones((S, S), bool))

    def block(x, layer):
        ln1, ln2, wq, wk, wv, wo, wg, wu, wd = layer
        h = rmsnorm(x, ln1)
        q = (h @ wq.T).reshape(B, S, H, hd)
        k = (h @ wk.T).reshape(B, S, H, hd)
        v = (h @ wv.T).reshape(B, S, H, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, H * hd)
        x = x + o @ wo.T
        h2 = rmsnorm(x, ln2)
        gate = jax.nn.silu(h2 @ wg.T)
        up = h2 @ wu.T
        x = x + (gate * up) @ wd.T
        return x, None

    layers = (nl["ln1"], nl["ln2"], lin["wq"], lin["wk"], lin["wv"],
              lin["wo"], lin["wg"], lin["wu"], lin["wd"])
    x, _ = jax.lax.scan(block, x, layers)
    x = rmsnorm(x, nl["final_norm"])
    return x @ nl["out_head"].T


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    return forward_with_weights(nonlinear_params(params), extract_linears(params),
                                cfg, tokens)


def ce_from_logits(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def loss_fn(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross entropy, tokens [B, S]."""
    return ce_from_logits(forward(params, cfg, tokens), tokens)


def ce_per_token(nl: dict, lin: dict, cfg: ModelConfig,
                 tokens: jnp.ndarray) -> jnp.ndarray:
    """Per-position NLL [B, S-1] — used by the sensitivity analysis (Fig. 3)."""
    logits = forward_with_weights(nl, lin, cfg, tokens)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    return -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]


# ---------------------------------------------------------------------------
# Serving graphs: prefill and the DP-LLM dual-precision decode step.
# ---------------------------------------------------------------------------


def kv_shape(cfg: ModelConfig) -> tuple[int, ...]:
    return (cfg.n_layers, 2, cfg.n_heads, cfg.max_seq, cfg.head_dim)


def prefill(nl: dict, lin: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            n_valid: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """Prompt ingestion at (caller-chosen) fixed weights.

    tokens [P] int32 (padded), n_valid scalar — number of real tokens.
    cos/sin: RoPE tables [P, head_dim/2], passed as inputs for the same
    xla_extension-0.5.1 reason as in ``decode_step_dual``.
    Returns (logits_last [V], kv [L,2,H,Smax,hd]).
    The paper runs prefill at each layer's highest available precision;
    the Rust side passes max-precision-materialized stacks for ``lin``.
    """
    P = tokens.shape[0]
    H, hd, S = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x = nl["tok_emb"][tokens]  # [P, D]
    pos = jnp.arange(P)
    cos_b = cos[:, None, :]
    sin_b = sin[:, None, :]
    valid = pos < n_valid
    mask = (pos[None, :] <= pos[:, None]) & valid[None, :]

    def block(x, layer):
        ln1, ln2, wq, wk, wv, wo, wg, wu, wd = layer
        h = rmsnorm(x, ln1)
        q = (h @ wq.T).reshape(P, H, hd)
        k = (h @ wk.T).reshape(P, H, hd)
        v = (h @ wv.T).reshape(P, H, hd)
        q = apply_rope(q, cos_b, sin_b)
        k = apply_rope(k, cos_b, sin_b)
        att = jnp.einsum("qhd,khd->hqk", q, k) / np.sqrt(hd)
        att = jnp.where(mask[None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("hqk,khd->qhd", att, v).reshape(P, H * hd)
        x = x + o @ wo.T
        h2 = rmsnorm(x, ln2)
        x = x + (jax.nn.silu(h2 @ wg.T) * (h2 @ wu.T)) @ wd.T
        kv = jnp.zeros((2, H, S, hd), jnp.float32)
        kv = kv.at[0, :, :P].set(jnp.transpose(k, (1, 0, 2)))
        kv = kv.at[1, :, :P].set(jnp.transpose(v, (1, 0, 2)))
        return x, kv

    layers = (nl["ln1"], nl["ln2"], lin["wq"], lin["wk"], lin["wv"],
              lin["wo"], lin["wg"], lin["wu"], lin["wd"])
    x, kv = jax.lax.scan(block, x, layers)
    x = rmsnorm(x, nl["final_norm"])
    logits = x @ nl["out_head"].T      # [P, V]
    last = logits[jnp.maximum(n_valid - 1, 0)]
    return last, kv


def prefill_chunk(nl: dict, lin: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                  pos0: jnp.ndarray, n_valid: jnp.ndarray, cos: jnp.ndarray,
                  sin: jnp.ndarray, kv: jnp.ndarray):
    """Chunked prompt ingestion: append P positions to an EXISTING KV cache.

    Unlike ``prefill`` (which builds a KV cache from scratch and caps the
    prompt at its bucket), a chunk takes the caller's per-layer KV buffers
    plus a position offset and writes P new causal positions — the same
    KV-leaf protocol as ``decode_step_dual``, so the Rust side chains
    chunks against one device-resident cache and prompts of any length
    (up to max_seq) ingest as a sequence of bounded dispatches.

    tokens [P] int32 (padded), pos0 scalar — absolute position of
    ``tokens[0]`` (== tokens already in ``kv`` from earlier chunks),
    n_valid scalar — real tokens in THIS chunk.  cos/sin [P, head_dim/2]
    are the RoPE tables for absolute positions pos0..pos0+P (inputs for
    the same xla_extension-0.5.1 reason as everywhere else).
    kv [L, 2, H, Smax, hd].

    Query i (absolute pos0+i) attends keys at absolute positions
    ``s <= pos0 + i``: earlier chunks' entries already in ``kv`` plus the
    causal prefix of this chunk.  Padded tail tokens (i >= n_valid) write
    k/v at positions >= pos0 + n_valid; those slots are stale-but-masked
    under the decode graphs' ``arange(S) <= pos`` rule and are overwritten
    in place by the next chunk or decode step — the identical protocol as
    speculative-decoding rollback (DESIGN.md §Speculation), so a chain of
    full chunks reproduces ``prefill`` bit-for-bit on every valid
    position (pinned by test_prefill_chunk_chain_matches_full_prefill).

    Returns (logits_last [V], kv_new) — logits_last scores the token
    after position ``pos0 + n_valid - 1`` (only meaningful on the final
    chunk).  Runs at the caller-chosen fixed weights; the Rust side
    passes the max-precision prefill stacks, same as ``prefill``.
    """
    P = tokens.shape[0]
    H, hd, S = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x = nl["tok_emb"][tokens]                 # [P, D]
    cos_b = cos[:, None, :]
    sin_b = sin[:, None, :]
    local = jnp.arange(P)
    # Key position s is attended by chunk-local query i iff s <= pos0 + i.
    mask = jnp.arange(S)[None, :] <= (pos0 + local)[:, None]   # [P, S]

    def block(carry, layer):
        (x,) = carry
        ln1, ln2, kv_l, wq, wk, wv, wo, wg, wu, wd = layer
        h = rmsnorm(x, ln1)
        q = (h @ wq.T).reshape(P, H, hd)
        k = (h @ wk.T).reshape(P, H, hd)
        v = (h @ wv.T).reshape(P, H, hd)
        q = apply_rope(q, cos_b, sin_b)
        k = apply_rope(k, cos_b, sin_b)
        kv_l = jax.lax.dynamic_update_slice(
            kv_l,
            jnp.stack([jnp.transpose(k, (1, 0, 2)),
                       jnp.transpose(v, (1, 0, 2))]),   # [2, H, P, hd]
            (0, 0, pos0, 0))
        keys, vals = kv_l[0], kv_l[1]          # [H, Smax, hd]
        att = jnp.einsum("phd,hsd->hps", q, keys) / np.sqrt(hd)
        att = jnp.where(mask[None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("hps,hsd->phd", att, vals).reshape(P, H * hd)
        x = x + o @ wo.T
        h2 = rmsnorm(x, ln2)
        x = x + (jax.nn.silu(h2 @ wg.T) * (h2 @ wu.T)) @ wd.T
        return (x,), kv_l

    layers = (nl["ln1"], nl["ln2"], kv, lin["wq"], lin["wk"], lin["wv"],
              lin["wo"], lin["wg"], lin["wu"], lin["wd"])
    (x,), kv_new = jax.lax.scan(block, (x,), layers)
    x = rmsnorm(x, nl["final_norm"])
    logits = x @ nl["out_head"].T              # [P, V]
    last = logits[jnp.maximum(n_valid - 1, 0)]
    return last, kv_new


def _estimate(x, G, lin_a, lin_b, use_lin):
    """Approximate relative error for one linear: ``a‖x‖+b`` or ``‖Gx‖``."""
    xn = jnp.linalg.norm(x)
    est_lin = lin_a * xn + lin_b
    est_jl = jnp.linalg.norm(G @ x)
    return jnp.where(use_lin > 0.5, est_lin, est_jl)


def decode_step_dual(nl: dict, wl: dict, wh: dict, est: dict, cfg: ModelConfig,
                     token: jnp.ndarray, pos: jnp.ndarray,
                     cos: jnp.ndarray, sin: jnp.ndarray, kv: jnp.ndarray,
                     use_h_async: dict, mode_exact: jnp.ndarray):
    """One decoding step with DP-LLM dynamic per-linear precision.

    Arguments
    ---------
    nl            non-linear params.
    wl / wh       per-group low/high candidate weight stacks ([L, out, in]).
    est           estimator parameters per group ``g``:
                    ``G_<g>``     [L, K, in]  calibrated JL projections,
                    ``lina_<g>``  [L], ``linb_<g>`` [L] linear-fit coefs,
                    ``uselin_<g>`` [L] 0/1 — method select (R² ≥ R²_th),
                    ``thr_<g>``   [L]  thresholds T_i.
    token, pos    current token id / absolute position (scalars, int32).
    cos, sin      RoPE tables for this position, [head_dim/2] each.  These
                  are *inputs* (computed by the Rust coordinator from pos)
                  rather than derived in-graph: xla_extension 0.5.1
                  miscompiles the duplicated iota→pow→cos chain when it
                  re-materializes the KV output (see DESIGN.md §7), and
                  host-side cos/sin of a 16-element vector is free.
    kv            KV cache [L, 2, H, Smax, hd].
    use_h_async   {g: [L] float 0/1} — decisions for the *async* groups
                  (q/k/v/gate/up), made by the Rust selector from the
                  previous step's estimates (paper Fig. 6).
    mode_exact    scalar f32 0/1.  1 → the exact estimator ‖W_h x − W_l x‖
                  drives *all* selections in-graph (Table 3 upper bound);
                  0 → hybrid approximate estimators; async groups honor
                  ``use_h_async``.

    Returns (logits [V], kv_new, ests {g:[L]}, use_h_eff {g:[L]}).
    ``ests`` are this step's estimates (async groups consume them next
    step); ``use_h_eff`` are the decisions actually applied (effective-
    bitwidth accounting in the coordinator).
    """
    H, hd, S = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x0 = nl["tok_emb"][token]                    # [D]
    cos = cos[None, :]                           # [1, hd/2]
    sin = sin[None, :]
    exact = mode_exact.astype(jnp.float32)

    def sel_linear(x_in, wl_g, wh_g, epack, use_h_in, sync):
        """Dual GEMV + selection for one linear. Returns (y, est, use_h)."""
        G, la, lb, ul, thr = epack
        yl = wl_g @ x_in
        yh = wh_g @ x_in
        e_exact = jnp.linalg.norm(yh - yl)
        e_apx = _estimate(x_in, G, la, lb, ul)
        e = exact * e_exact + (1.0 - exact) * e_apx
        in_graph = jnp.maximum(exact, jnp.float32(1.0 if sync else 0.0))
        decided = (e > thr).astype(jnp.float32)
        use_h = in_graph * decided + (1.0 - in_graph) * use_h_in
        y = use_h * yh + (1.0 - use_h) * yl
        return y, e, use_h

    def block(carry, layer):
        (x,) = carry
        (ln1, ln2, kv_l, w_l, w_h, ep, u_in) = layer
        h = rmsnorm(x, ln1)
        q, e_q, f_q = sel_linear(h, w_l["wq"], w_h["wq"], ep["wq"], u_in["wq"], False)
        k, e_k, f_k = sel_linear(h, w_l["wk"], w_h["wk"], ep["wk"], u_in["wk"], False)
        v, e_v, f_v = sel_linear(h, w_l["wv"], w_h["wv"], ep["wv"], u_in["wv"], False)
        q = apply_rope(q.reshape(H, hd), cos, sin)
        k = apply_rope(k.reshape(H, hd), cos, sin)
        v = v.reshape(H, hd)
        kv_l = jax.lax.dynamic_update_slice(
            kv_l, jnp.stack([k, v])[:, :, None, :], (0, 0, pos, 0))
        keys, vals = kv_l[0], kv_l[1]            # [H, Smax, hd]
        att = jnp.einsum("hd,hsd->hs", q, keys) / np.sqrt(hd)
        att = jnp.where(jnp.arange(S)[None, :] <= pos, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o_in = jnp.einsum("hs,hsd->hd", att, vals).reshape(H * hd)
        o, e_o, f_o = sel_linear(o_in, w_l["wo"], w_h["wo"], ep["wo"],
                                 jnp.float32(0.0), True)
        x = x + o
        h2 = rmsnorm(x, ln2)
        g, e_g, f_g = sel_linear(h2, w_l["wg"], w_h["wg"], ep["wg"], u_in["wg"], False)
        u, e_u, f_u = sel_linear(h2, w_l["wu"], w_h["wu"], ep["wu"], u_in["wu"], False)
        mid = jax.nn.silu(g) * u
        dn, e_d, f_d = sel_linear(mid, w_l["wd"], w_h["wd"], ep["wd"],
                                  jnp.float32(0.0), True)
        x = x + dn
        ests_l = jnp.stack([e_q, e_k, e_v, e_o, e_g, e_u, e_d])
        use_l = jnp.stack([f_q, f_k, f_v, f_o, f_g, f_u, f_d])
        return (x,), (kv_l, ests_l, use_l)

    ep = {g: (est[f"G_{g}"], est[f"lina_{g}"], est[f"linb_{g}"],
              est[f"uselin_{g}"], est[f"thr_{g}"]) for g in GROUPS}
    u_async = {g: use_h_async.get(g, jnp.zeros(cfg.n_layers)) for g in GROUPS}
    xs = (nl["ln1"], nl["ln2"], kv, wl, wh, ep, u_async)
    (x,), (kv_new, ests, use_eff) = jax.lax.scan(block, (x0,), xs)
    x = rmsnorm(x, nl["final_norm"])
    logits = x @ nl["out_head"].T
    ests_d = {g: ests[:, i] for i, g in enumerate(GROUPS)}
    use_d = {g: use_eff[:, i] for i, g in enumerate(GROUPS)}
    return logits, kv_new, ests_d, use_d


def decode_step_dual_batched(nl, wl, wh, est, cfg: ModelConfig,
                             tokens: jnp.ndarray, poss: jnp.ndarray,
                             cos: jnp.ndarray, sin: jnp.ndarray,
                             kv: jnp.ndarray, use_h_async: dict,
                             mode_exact: jnp.ndarray):
    """Batched ``decode_step_dual``: one device call decodes one token for
    each of B concurrent requests (continuous batching across requests).

    Leading batch dim on the per-request inputs: ``tokens``/``poss`` [B],
    ``cos``/``sin`` [B, hd/2], ``kv`` [B, L, 2, H, Smax, hd], and each
    ``use_h_async`` leaf [B, L] — every slot carries its own selector
    flags, so one batched graph serves requests sitting at different
    effective bitwidths.  Weight stacks, estimator parameters and
    ``mode_exact`` are shared across the batch (one adaptation-set member
    per batched graph; the Rust scheduler only packs requests whose target
    stacks are the same device buffers).

    Returns ``(logits [B, V], kv_new [B, ...], ests {g: [B, L]},
    use_h_eff {g: [B, L]})``.
    """

    def single(token, pos, cos_1, sin_1, kv_1, use_1):
        return decode_step_dual(nl, wl, wh, est, cfg, token, pos,
                                cos_1, sin_1, kv_1, use_1, mode_exact)

    return jax.vmap(single)(tokens, poss, cos, sin, kv, use_h_async)


def verify_step_dual(nl, wl, wh, est, cfg: ModelConfig, tokens: jnp.ndarray,
                     pos: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
                     kv: jnp.ndarray, use_h_async: dict,
                     mode_exact: jnp.ndarray):
    """Score γ+1 consecutive positions in ONE dispatch — the verification
    step of self-speculative decoding (DESIGN §Speculation).

    ``tokens`` ``[γ+1]`` holds the next committed token followed by γ
    draft tokens; ``pos`` is the absolute position of ``tokens[0]``;
    ``cos``/``sin`` are per-position RoPE tables ``[γ+1, hd/2]``.  The
    positions are processed **causally in sequence** (γ is small and
    static, so the sub-steps unroll): position i writes its KV entry
    before position i+1 attends, exactly as γ+1 sequential
    ``decode_step_dual`` calls would.

    Async selector chaining: position 0 honors the caller-provided
    ``use_h_async`` flags (the same contract as the single step); every
    later position derives its flags **in-graph** from the previous
    position's estimates vs the per-layer thresholds — the identical
    rule the Rust ``SelectorState::observe`` applies between sequential
    steps, so position-wise outputs match the sequential chain bit for
    bit (pinned by ``test_verify_step_matches_sequential_single_steps``).

    Returns ``(logits [γ+1, V], kv_new, ests {g: [γ+1, L]},
    use_h_eff {g: [γ+1, L]})``.  ``logits[i]`` scores the token at
    position ``pos + i + 1``; the Rust side keeps the longest accepted
    draft prefix plus one bonus token and rewinds its position counter
    past any rejected tail (stale KV entries beyond the counter are
    masked by the attention and overwritten when those positions are
    re-decoded).
    """
    n_pos = tokens.shape[0]
    use_cur = dict(use_h_async)
    louts, eouts, uouts = [], [], []
    for i in range(n_pos):
        logits, kv, ests, use_eff = decode_step_dual(
            nl, wl, wh, est, cfg, tokens[i], pos + i, cos[i], sin[i], kv,
            use_cur, mode_exact)
        louts.append(logits)
        eouts.append(ests)
        uouts.append(use_eff)
        use_cur = {g: (ests[g] > est[f"thr_{g}"]).astype(jnp.float32)
                   for g in ASYNC_GROUPS}
    logits_all = jnp.stack(louts)
    ests_d = {g: jnp.stack([e[g] for e in eouts]) for g in GROUPS}
    use_d = {g: jnp.stack([u[g] for u in uouts]) for g in GROUPS}
    return logits_all, kv, ests_d, use_d


# ---------------------------------------------------------------------------
# Reference greedy decoding in pure JAX (used by tests to cross-check the
# Rust decode loop end to end).
# ---------------------------------------------------------------------------


def greedy_decode_ref(params: dict, cfg: ModelConfig, prompt: list[int],
                      n_new: int) -> list[int]:
    toks = list(prompt)
    for _ in range(n_new):
        arr = jnp.asarray([toks], jnp.int32)
        logits = forward(params, cfg, arr)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks
