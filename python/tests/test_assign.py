"""Phase-1 / baseline assignment solver invariants."""

import numpy as np
import pytest

# Property tests need hypothesis; cargo-only / minimal CI
# environments without it skip this module instead of erroring
# out of collection (the ci.sh pytest gate must stay runnable).
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.assign import BITS, solve_assignment


def _rand_problem(rng, n=24):
    # Convex-ish decreasing costs in bits, like real quantization error.
    base = rng.random(n) * 10 + 0.1
    omega = np.stack([base * (0.5 ** bi) for bi in range(len(BITS))], axis=1)
    M = rng.integers(1, 5, size=n).astype(float) * 1000
    return omega, M


def _avg(bits, M):
    return float((bits * M).sum() / M.sum())


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       target=st.sampled_from([3.25, 3.5, 4.0, 4.5, 5.0, 5.5]))
def test_budget_respected_and_tight(seed, target):
    rng = np.random.default_rng(seed)
    omega, M = _rand_problem(rng)
    bits = solve_assignment(omega, M, target)
    avg = _avg(bits, M)
    assert avg <= target + 0.006
    # With convex costs the solver should get close to the target from below
    # (paper matches within 0.005 bits; granularity here is 1 bit / layer).
    assert avg >= target - 1.0 / len(M) * 4 - 0.05


def test_caps_respected():
    rng = np.random.default_rng(0)
    omega, M = _rand_problem(rng)
    caps = np.full(len(M), 4)
    caps[:5] = 6
    bits = solve_assignment(omega, M, 4.0, max_bits=caps)
    assert np.all(bits <= caps)


def test_monotone_in_budget():
    rng = np.random.default_rng(1)
    omega, M = _rand_problem(rng)
    lo = solve_assignment(omega, M, 3.5)
    hi = solve_assignment(omega, M, 5.0)
    assert _avg(lo, M) < _avg(hi, M)


def test_sensitive_layers_get_more_bits():
    """A layer whose error decays much faster with bits should win bits."""
    n = 10
    omega = np.ones((n, 4))
    # layer 0: huge benefit from bits; others: none.
    omega[0] = [100.0, 1.0, 0.01, 0.0001]
    M = np.ones(n) * 1000
    bits = solve_assignment(omega, M, 3.3)
    assert bits[0] == max(bits)


def test_uniform_costs_give_near_uniform_bits():
    n = 8
    omega = np.tile([8.0, 4.0, 2.0, 1.0], (n, 1)).astype(float)
    M = np.ones(n)
    bits = solve_assignment(omega, M, 4.0)
    assert abs(_avg(bits, M) - 4.0) < 0.51
