"""L2 model correctness: causality, serving-graph vs training-forward parity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.estimator import K_PROJ
from compile.model import (ASYNC_GROUPS, GROUPS, ModelConfig, decode_step_dual,
                           extract_linears, forward, init_params, kv_shape,
                           nonlinear_params, prefill)

CFG = ModelConfig("test", vocab=64, d_model=32, n_layers=2, n_heads=2,
                  d_ff=48, max_seq=24)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def _rope(pos):
    hd = CFG.head_dim
    inv = 1.0 / (CFG.rope_theta ** (np.arange(0, hd, 2) / hd))
    return (jnp.asarray(np.cos(pos * inv), jnp.float32),
            jnp.asarray(np.sin(pos * inv), jnp.float32))


def _rope_seq(P):
    hd = CFG.head_dim
    inv = 1.0 / (CFG.rope_theta ** (np.arange(0, hd, 2) / hd))
    ang = np.arange(P)[:, None] * inv[None, :]
    return (jnp.asarray(np.cos(ang), jnp.float32),
            jnp.asarray(np.sin(ang), jnp.float32))


def _zero_est(cfg, thr_val=1e30):
    est = {}
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        L = cfg.n_layers
        est[f"G_{g}"] = jnp.zeros((L, K_PROJ, i))
        est[f"lina_{g}"] = jnp.zeros(L)
        est[f"linb_{g}"] = jnp.zeros(L)
        est[f"uselin_{g}"] = jnp.ones(L)
        est[f"thr_{g}"] = jnp.full(L, thr_val)
    return est


def test_forward_shapes(params):
    toks = jnp.zeros((2, 10), jnp.int32)
    logits = forward(params, CFG, toks)
    assert logits.shape == (2, 10, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params):
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, CFG.vocab, size=(1, 12)).astype(np.int32)
    b = a.copy()
    b[0, 8:] = (b[0, 8:] + 7) % CFG.vocab
    la = np.asarray(forward(params, CFG, jnp.asarray(a)))
    lb = np.asarray(forward(params, CFG, jnp.asarray(b)))
    np.testing.assert_allclose(la[0, :8], lb[0, :8], rtol=2e-4, atol=2e-5)
    assert np.abs(la[0, 8:] - lb[0, 8:]).max() > 1e-4


def test_decode_step_matches_forward(params):
    """Teacher-forced stepwise decode through the dual graph (wl == wh ==
    fp weights) must reproduce the training forward's logits."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, CFG.vocab, size=14).astype(np.int32)
    ref_logits = np.asarray(forward(params, CFG, jnp.asarray(toks[None])))[0]

    nl = nonlinear_params(params)
    lin = extract_linears(params)
    est = _zero_est(CFG)
    use_async = {g: jnp.zeros(CFG.n_layers) for g in ASYNC_GROUPS}
    kv = jnp.zeros(kv_shape(CFG))
    for t, tok in enumerate(toks):
        logits, kv, ests, use_eff = decode_step_dual(
            nl, lin, lin, est, CFG, jnp.int32(tok), jnp.int32(t), *_rope(t), kv,
            use_async, jnp.float32(0.0))
        np.testing.assert_allclose(np.asarray(logits), ref_logits[t],
                                   rtol=2e-3, atol=2e-4)


def test_decode_selection_switches_weights(params):
    """use_h flags must actually switch the multiplied weights."""
    nl = nonlinear_params(params)
    wl = extract_linears(params)
    wh = {g: wl[g] * 2.0 for g in GROUPS}  # distinct high weights
    est = _zero_est(CFG)
    kv = jnp.zeros(kv_shape(CFG))
    zeros = {g: jnp.zeros(CFG.n_layers) for g in ASYNC_GROUPS}
    ones = {g: jnp.ones(CFG.n_layers) for g in ASYNC_GROUPS}
    lo, _, _, use_lo = decode_step_dual(nl, wl, wh, est, CFG, jnp.int32(3),
                                        jnp.int32(0), *_rope(0), kv, zeros, jnp.float32(0.0))
    hi, _, _, use_hi = decode_step_dual(nl, wl, wh, est, CFG, jnp.int32(3),
                                        jnp.int32(0), *_rope(0), kv, ones, jnp.float32(0.0))
    assert float(jnp.abs(lo - hi).max()) > 1e-3
    for g in ASYNC_GROUPS:
        assert float(use_lo[g].sum()) == 0.0
        assert float(use_hi[g].sum()) == CFG.n_layers


def test_decode_exact_mode_thresholds(params):
    """mode_exact=1: sync+async selection in-graph from ‖W_h x − W_l x‖."""
    nl = nonlinear_params(params)
    wl = extract_linears(params)
    wh = {g: wl[g] * 1.5 for g in GROUPS}
    kv = jnp.zeros(kv_shape(CFG))
    zeros = {g: jnp.zeros(CFG.n_layers) for g in ASYNC_GROUPS}
    # thr = 0 -> every exact error > 0 -> everything selects high.
    est = _zero_est(CFG, thr_val=0.0)
    _, _, ests, use_eff = decode_step_dual(nl, wl, wh, est, CFG, jnp.int32(5),
                                           jnp.int32(0), *_rope(0), kv, zeros,
                                           jnp.float32(1.0))
    for g in GROUPS:
        assert float(use_eff[g].min()) == 1.0, g
        assert float(ests[g].min()) > 0.0
    # thr = +inf -> everything selects low.
    est = _zero_est(CFG, thr_val=1e30)
    _, _, _, use_eff = decode_step_dual(nl, wl, wh, est, CFG, jnp.int32(5),
                                        jnp.int32(0), *_rope(0), kv, zeros, jnp.float32(1.0))
    for g in GROUPS:
        assert float(use_eff[g].max()) == 0.0, g


def test_prefill_matches_forward(params):
    rng = np.random.default_rng(2)
    P, n_valid = 16, 11
    toks = rng.integers(0, CFG.vocab, size=P).astype(np.int32)
    ref_logits = np.asarray(
        forward(params, CFG, jnp.asarray(toks[None, :n_valid])))[0]
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    last, kv = prefill(nl, lin, CFG, jnp.asarray(toks), jnp.int32(n_valid), *_rope_seq(P))
    np.testing.assert_allclose(np.asarray(last), ref_logits[-1],
                               rtol=2e-3, atol=2e-4)
    assert kv.shape == kv_shape(CFG)


def test_prefill_then_decode_continues(params):
    """KV from prefill must be usable by the decode step (position P)."""
    rng = np.random.default_rng(3)
    toks = rng.integers(0, CFG.vocab, size=9).astype(np.int32)
    ref = np.asarray(forward(params, CFG, jnp.asarray(toks[None])))[0]

    nl = nonlinear_params(params)
    lin = extract_linears(params)
    pad = np.zeros(16, np.int32)
    pad[:8] = toks[:8]
    _, kv = prefill(nl, lin, CFG, jnp.asarray(pad), jnp.int32(8), *_rope_seq(16))
    est = _zero_est(CFG)
    use_async = {g: jnp.zeros(CFG.n_layers) for g in ASYNC_GROUPS}
    logits, _, _, _ = decode_step_dual(nl, lin, lin, est, CFG,
                                       jnp.int32(toks[8]), jnp.int32(8), *_rope(8), kv,
                                       use_async, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(logits), ref[8], rtol=2e-3, atol=2e-4)
