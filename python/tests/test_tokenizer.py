"""Byte-BPE tokenizer: roundtrips, determinism, serialization."""

import numpy as np
import pytest

# Property tests need hypothesis; cargo-only / minimal CI
# environments without it skip this module instead of erroring
# out of collection (the ci.sh pytest gate must stay runnable).
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.tokenizer import (BOS_ID, EOS_ID, Tokenizer, encode_to_bin,
                               train_bpe)

SAMPLE = (
    "the quick brown fox jumps over the lazy dog. "
    "the town of Kamodor is known for river salt. "
    "Question: Mara has 23 coins. Answer: 23 + 18 = 41. #### 41\n"
) * 30


def _tok():
    return Tokenizer(train_bpe(SAMPLE, vocab_size=320))


def test_train_produces_merges():
    tok = _tok()
    assert tok.vocab_size > 259
    assert tok.vocab_size <= 320


def test_roundtrip_training_text():
    tok = _tok()
    ids = tok.encode(SAMPLE)
    assert tok.decode(ids) == SAMPLE
    # BPE must compress repetitive text.
    assert len(ids) < len(SAMPLE.encode()) * 0.6


@settings(max_examples=30, deadline=None)
@given(st.text(min_size=0, max_size=200))
def test_roundtrip_arbitrary_unicode(s):
    tok = _tok()
    assert tok.decode(tok.encode(s)) == s


def test_specials():
    tok = _tok()
    ids = tok.encode("hi", bos=True, eos=True)
    assert ids[0] == BOS_ID and ids[-1] == EOS_ID
    assert tok.decode(ids) == "hi"


def test_save_load(tmp_path):
    tok = _tok()
    p = str(tmp_path / "tok.json")
    tok.save(p)
    tok2 = Tokenizer.load(p)
    s = "the quick brown fox. #### 41"
    assert tok.encode(s) == tok2.encode(s)


def test_encode_to_bin(tmp_path):
    tok = _tok()
    p = str(tmp_path / "x.bin")
    n = encode_to_bin(tok, SAMPLE, p)
    arr = np.fromfile(p, np.uint16)
    assert len(arr) == n
    assert tok.decode(arr.tolist()) == SAMPLE


def test_determinism():
    a = train_bpe(SAMPLE, vocab_size=300)
    b = train_bpe(SAMPLE, vocab_size=300)
    assert a == b
