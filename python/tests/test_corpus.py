"""Corpus/task generators: determinism, split disjointness, answer formats."""

import re

import numpy as np

from compile import corpus as C


def test_determinism():
    a = C.gen_synthwiki(np.random.default_rng(5), 20)
    b = C.gen_synthwiki(np.random.default_rng(5), 20)
    assert a == b


def test_wiki_web_distinct_registers():
    wiki = C.gen_synthwiki(np.random.default_rng(0), 50)
    web = C.gen_synthweb(np.random.default_rng(0), 100)
    assert "population" in wiki and "population" not in web
    assert "stars" in web


def test_task_split_disjoint():
    for task in C.TASKS:
        tr, ev = C.gen_task_split(task, seed=3, n_train=300, n_eval=60)
        assert len(ev) == 60
        tr_prompts = {s.prompt for s in tr}
        assert not tr_prompts.intersection({s.prompt for s in ev})


def test_arith_answers_consistent():
    rng = np.random.default_rng(1)
    for s in C.gen_task_samples("arith", rng, 200):
        m = re.search(r"#### (-?\d+)$", s.answer)
        assert m, s.answer
        # Answer must equal the last computed value in the work.
        nums = re.findall(r"= (-?\d+)", s.answer)
        assert nums and nums[-1] == m.group(1)


def test_listfn_answers_consistent():
    rng = np.random.default_rng(2)
    for s in C.gen_task_samples("listfn", rng, 200):
        m = re.match(r"Task: (.+)\. Input: (.+)\. Output: ", s.prompt)
        assert m
        desc, xs_s = m.group(1), m.group(2)
        xs = [int(v) for v in xs_s.split()]
        if desc.startswith("add "):
            k = int(desc.split()[1])
            want = " ".join(str(v + k) for v in xs)
        elif desc == "double each item":
            want = " ".join(str(2 * v) for v in xs)
        elif desc == "reverse the list":
            want = " ".join(str(v) for v in reversed(xs))
        elif desc == "take the first item":
            want = str(xs[0])
        elif desc == "take the last item":
            want = str(xs[-1])
        else:
            want = str(len(xs))
        assert s.answer == want


def test_dates_answers_consistent():
    rng = np.random.default_rng(3)
    for s in C.gen_task_samples("dates", rng, 200):
        m = re.match(
            r"Question: which day comes (\w+) days (after|before) (\w+)\? "
            r"Options: (.+)\. Answer: ", s.prompt)
        assert m, s.prompt
        words = {v: k for k, v in C._SPELLED.items()}
        off = words[m.group(1)]
        sign = 1 if m.group(2) == "after" else -1
        start = C.WEEKDAYS.index(m.group(3))
        want_day = C.WEEKDAYS[(start + sign * off) % 7]
        opts = dict(re.findall(r"\((\w)\) (\w+)", m.group(4)))
        letter = s.answer.strip("()")
        assert opts[letter] == want_day


def test_algebra_answers_consistent():
    rng = np.random.default_rng(4)
    for s in C.gen_task_samples("algebra", rng, 200):
        m = re.match(r"Solve: (?:(\d+)x|x)(?: \+ (\d+))? = (\d+)\.", s.prompt)
        assert m, s.prompt
        a = int(m.group(1) or 1)
        b = int(m.group(2) or 0)
        c = int(m.group(3))
        x = (c - b) // a
        assert a * x + b == c
        assert s.answer.endswith(f"x = {x}")


def test_build_corpus_structure():
    blobs = C.build_corpus(seed=1, wiki_articles=30, web_docs=50,
                           task_train=20, task_eval=8, instruct_train=10)
    assert len(blobs["train_text"]) > 10_000
    assert set(blobs["tasks"]) == {"algebra", "arith", "dates", "instruct",
                                   "listfn"}
    for task, (tr, ev) in blobs["tasks"].items():
        assert len(ev) <= 8 and len(ev) > 0
