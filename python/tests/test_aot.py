"""AOT lowering: HLO text round-trips through the XLA text parser, and the
lowered decode graph reproduces the jax-eval semantics.

This is the L2→L3 contract test: if these pass, the Rust loader is
executing the same computation pytest validated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import (batched_decode_arg_specs, batched_decode_output_names,
                         decode_arg_specs, decode_output_names, f32,
                         make_batched_decode_fn, make_decode_fn,
                         make_prefill_chunk_fn, make_prefill_fn,
                         make_verify_fn, prefill_arg_specs,
                         prefill_chunk_arg_specs, to_hlo_text,
                         verify_arg_specs, verify_output_names)
from compile.kernels.estimator import K_PROJ
from compile.model import (ASYNC_GROUPS, GROUPS, ModelConfig, decode_step_dual,
                           extract_linears, init_params, kv_shape,
                           nonlinear_params, prefill, prefill_chunk)

CFG = ModelConfig("aot-test", vocab=32, d_model=16, n_layers=2, n_heads=2,
                  d_ff=24, max_seq=16)


def _decode_args(cfg, params, token=1, pos=0):
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    vals = {
        "token": np.int32(token), "pos": np.int32(pos),
        "kv": np.zeros(kv_shape(cfg), np.float32),
        "tok_emb": nl["tok_emb"], "out_head": nl["out_head"],
        "final_norm": nl["final_norm"], "ln1": nl["ln1"], "ln2": nl["ln2"],
        "mode_exact": np.float32(0.0),
    }
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        L = cfg.n_layers
        vals[f"wl_{g}"] = np.asarray(lin[g])
        vals[f"wh_{g}"] = np.asarray(lin[g])
        vals[f"G_{g}"] = np.zeros((L, K_PROJ, i), np.float32)
        vals[f"lina_{g}"] = np.zeros(L, np.float32)
        vals[f"linb_{g}"] = np.zeros(L, np.float32)
        vals[f"uselin_{g}"] = np.ones(L, np.float32)
        vals[f"thr_{g}"] = np.full(L, 1e30, np.float32)
    for g in ASYNC_GROUPS:
        vals[f"useh_{g}"] = np.zeros(cfg.n_layers, np.float32)
    names = [n for n, _ in decode_arg_specs(cfg)]
    return [np.asarray(vals[n]) for n in names]


@pytest.fixture(scope="module")
def lowered_decode():
    specs = decode_arg_specs(CFG)
    return jax.jit(make_decode_fn(CFG)).lower(*[s for _, s in specs])


def test_decode_lowering_produces_hlo(lowered_decode):
    text = to_hlo_text(lowered_decode)
    assert "ENTRY" in text and "parameter" in text
    n_args = len(decode_arg_specs(CFG))
    assert text.count("parameter(") >= n_args


def test_hlo_text_parses_back(lowered_decode):
    """The text artifact must round-trip through XLA's HLO text parser —
    the same parser the Rust loader (`HloModuleProto::from_text_file`)
    uses.  (The *numeric* round-trip is a Rust integration test against
    the golden npz exported by aot.export_golden.)"""
    text = to_hlo_text(lowered_decode)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
    # Re-serialized proto must be non-trivial.
    assert len(mod.as_serialized_hlo_module_proto()) > 1000


def test_golden_dump_consistent(tmp_path):
    """export_golden writes inputs + outputs that match direct jax eval."""
    from compile.aot import golden_decode_arrays
    params = init_params(CFG, seed=0)
    arrays = golden_decode_arrays(CFG, params, token=3, pos=0)
    names = [n for n, _ in decode_arg_specs(CFG)]
    args = [jnp.asarray(arrays[f"in_{n}"]) for n in names]
    ref = jax.jit(make_decode_fn(CFG))(*args)
    np.testing.assert_allclose(arrays["out_logits"], np.asarray(ref[0]),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(arrays["out_kv"], np.asarray(ref[1]),
                               rtol=2e-4, atol=2e-5)


def test_prefill_lowering(lowered_decode):
    P = 8
    specs = prefill_arg_specs(CFG, P)
    lowered = jax.jit(make_prefill_fn(CFG, P)).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    assert "ENTRY" in text


def test_arg_spec_names_unique():
    names = [n for n, _ in decode_arg_specs(CFG)]
    assert len(names) == len(set(names))
    assert names[0] == "token" and names[-1] == "mode_exact"


# ---------------------------------------------------------------------------
# Chunked prefill (incremental prompt ingestion against an existing KV).
# ---------------------------------------------------------------------------


def _rope_tables(p0, P):
    hd = CFG.head_dim
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))
    ang = np.arange(p0, p0 + P)[:, None] * inv[None, :]
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def test_prefill_chunk_arg_spec_names_unique():
    for P in (4, 8):
        names = [n for n, _ in prefill_chunk_arg_specs(CFG, P)]
        assert len(names) == len(set(names))
        assert names[:3] == ["tokens", "pos", "n_valid"]
        assert "kv" in names, "chunk must take the existing KV as an input"


def test_prefill_chunk_chain_matches_full_prefill():
    """THE chunked-prefill contract: a chain of full chunks against one
    carried KV cache must reproduce a single bucketed ``prefill`` —
    final-position logits AND the complete KV cache — so the Rust side
    can ingest prompts longer than any bucket without changing numerics."""
    P_full, C = 8, 4
    params = init_params(CFG, seed=0)
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(2)
    tokens = rng.integers(0, CFG.vocab, size=P_full).astype(np.int32)
    cos_f, sin_f = _rope_tables(0, P_full)
    logits_full, kv_full = jax.jit(
        lambda *a: prefill(nl, lin, CFG, *a))(
        jnp.asarray(tokens), jnp.int32(P_full),
        jnp.asarray(cos_f), jnp.asarray(sin_f))

    kv = jnp.zeros(kv_shape(CFG), jnp.float32)
    logits_last = None
    for c0 in range(0, P_full, C):
        cos_c, sin_c = _rope_tables(c0, C)
        logits_last, kv = jax.jit(
            lambda *a: prefill_chunk(nl, lin, CFG, *a))(
            jnp.asarray(tokens[c0:c0 + C]), jnp.int32(c0), jnp.int32(C),
            jnp.asarray(cos_c), jnp.asarray(sin_c), kv)
    np.testing.assert_allclose(np.asarray(logits_last),
                               np.asarray(logits_full),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(kv_full),
                               rtol=2e-4, atol=2e-5)


def test_prefill_chunk_partial_tail_matches_full_prefill():
    """A partially filled final chunk (n_valid < P): logits and every
    VALID KV position must match the full prefill; pad-written slots
    beyond n_valid are stale-but-masked by construction (the decode
    graphs' ``arange(S) <= pos`` rule) and are not compared."""
    n_total, C = 7, 4
    params = init_params(CFG, seed=1)
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, CFG.vocab, size=8).astype(np.int32)
    toks[n_total:] = 0  # pad, matching the Rust caller's zero padding
    cos_f, sin_f = _rope_tables(0, 8)
    logits_full, kv_full = prefill(nl, lin, CFG, jnp.asarray(toks),
                                   jnp.int32(n_total), jnp.asarray(cos_f),
                                   jnp.asarray(sin_f))

    kv = jnp.zeros(kv_shape(CFG), jnp.float32)
    # Chunk 1: 4 valid of 4; chunk 2: 3 valid of 4.
    for c0, nv in ((0, 4), (4, 3)):
        cos_c, sin_c = _rope_tables(c0, C)
        logits_last, kv = prefill_chunk(
            nl, lin, CFG, jnp.asarray(toks[c0:c0 + C]), jnp.int32(c0),
            jnp.int32(nv), jnp.asarray(cos_c), jnp.asarray(sin_c), kv)
    np.testing.assert_allclose(np.asarray(logits_last),
                               np.asarray(logits_full),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kv)[:, :, :, :n_total],
                               np.asarray(kv_full)[:, :, :, :n_total],
                               rtol=2e-4, atol=2e-5)


def test_prefill_chunk_then_decode_matches_full_prefill_then_decode():
    """Downstream contract: a decode step on a chunk-assembled KV must
    equal the same step on the full-prefill KV — logits and the KV leaf —
    i.e. chunked ingestion is invisible to the decode path."""
    P_full, C = 8, 4
    params = init_params(CFG, seed=0)
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(5)
    tokens = rng.integers(0, CFG.vocab, size=P_full).astype(np.int32)
    cos_f, sin_f = _rope_tables(0, P_full)
    _, kv_full = prefill(nl, lin, CFG, jnp.asarray(tokens),
                         jnp.int32(P_full), jnp.asarray(cos_f),
                         jnp.asarray(sin_f))
    kv_chunk = jnp.zeros(kv_shape(CFG), jnp.float32)
    for c0 in range(0, P_full, C):
        cos_c, sin_c = _rope_tables(c0, C)
        _, kv_chunk = prefill_chunk(
            nl, lin, CFG, jnp.asarray(tokens[c0:c0 + C]), jnp.int32(c0),
            jnp.int32(C), jnp.asarray(cos_c), jnp.asarray(sin_c), kv_chunk)

    wl = {g: jnp.asarray(lin[g]) for g in GROUPS}
    est = {}
    L = CFG.n_layers
    for g in GROUPS:
        _, i = CFG.group_shape(g)
        est[f"G_{g}"] = jnp.zeros((L, K_PROJ, i), jnp.float32)
        est[f"lina_{g}"] = jnp.zeros(L, jnp.float32)
        est[f"linb_{g}"] = jnp.zeros(L, jnp.float32)
        est[f"uselin_{g}"] = jnp.ones(L, jnp.float32)
        est[f"thr_{g}"] = jnp.full(L, 1e30, jnp.float32)
    use_async = {g: jnp.zeros(L, jnp.float32) for g in ASYNC_GROUPS}
    cos_d, sin_d = _rope_tables(P_full, 1)
    step = lambda kv: decode_step_dual(
        nl, wl, wl, est, CFG, jnp.int32(3), jnp.int32(P_full),
        jnp.asarray(cos_d[0]), jnp.asarray(sin_d[0]), kv, use_async,
        jnp.float32(0.0))
    lo_full, kv_a, _, _ = step(kv_full)
    lo_chunk, kv_b, _, _ = step(kv_chunk)
    np.testing.assert_allclose(np.asarray(lo_chunk), np.asarray(lo_full),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kv_b), np.asarray(kv_a),
                               rtol=2e-4, atol=2e-5)


def test_prefill_chunk_lowering_parses_back():
    P = 4
    specs = prefill_chunk_arg_specs(CFG, P)
    lowered = jax.jit(make_prefill_chunk_fn(CFG, P)).lower(
        *[s for _, s in specs])
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert text.count("parameter(") >= len(specs)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
    assert len(mod.as_serialized_hlo_module_proto()) > 1000


# ---------------------------------------------------------------------------
# Batched decode (continuous batching buckets).
# ---------------------------------------------------------------------------


def _batched_args(cfg, params, B, seed=3):
    """Random-but-deterministic inputs for the B-slot batched decode,
    exercising distinct per-slot tokens/positions/KV/selector flags."""
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(seed)
    L = cfg.n_layers
    poss = rng.integers(0, cfg.max_seq - 2, size=B).astype(np.int32)
    hd = cfg.head_dim
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))
    vals = {
        "tokens": rng.integers(0, cfg.vocab, size=B).astype(np.int32),
        "poss": poss,
        "cos": np.stack([np.cos(p * inv) for p in poss]).astype(np.float32),
        "sin": np.stack([np.sin(p * inv) for p in poss]).astype(np.float32),
        "tok_emb": nl["tok_emb"], "out_head": nl["out_head"],
        "final_norm": nl["final_norm"], "ln1": nl["ln1"], "ln2": nl["ln2"],
        "mode_exact": np.float32(0.0),
    }
    for i in range(B):
        vals[f"kv{i}"] = (rng.standard_normal(kv_shape(cfg)) * 0.01
                          ).astype(np.float32)
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        w = np.asarray(lin[g])
        vals[f"wl_{g}"] = (w * 0.9).astype(np.float32)
        vals[f"wh_{g}"] = w
        vals[f"G_{g}"] = (rng.standard_normal((L, K_PROJ, i)) * 0.05
                          ).astype(np.float32)
        vals[f"lina_{g}"] = rng.random(L).astype(np.float32)
        vals[f"linb_{g}"] = rng.random(L).astype(np.float32) * 0.1
        vals[f"uselin_{g}"] = (rng.random(L) < 0.5).astype(np.float32)
        vals[f"thr_{g}"] = (rng.random(L) * 0.5).astype(np.float32)
    for g in ASYNC_GROUPS:
        vals[f"useh_{g}"] = (rng.random((B, L)) < 0.5).astype(np.float32)
    return vals


def test_batched_arg_spec_names_unique_and_ordered():
    for B in (2, 4):
        names = [n for n, _ in batched_decode_arg_specs(CFG, B)]
        assert len(names) == len(set(names))
        assert names[0] == "tokens" and names[-1] == "mode_exact"
        assert [f"kv{i}" in names for i in range(B)] == [True] * B
        outs = batched_decode_output_names(B)
        assert outs[0] == "logits" and f"kv{B - 1}" in outs


def test_batched_decode_matches_per_slot_single_step():
    """Each slot of the batched graph must reproduce the single-step graph
    on that slot's (token, pos, kv, flags) — the contract the Rust
    `advance_batch` fast path relies on to be a drop-in replacement for
    per-request `advance` calls."""
    B = 2
    params = init_params(CFG, seed=0)
    vals = _batched_args(CFG, params, B)
    bnames = [n for n, _ in batched_decode_arg_specs(CFG, B)]
    bout = jax.jit(make_batched_decode_fn(CFG, B))(
        *[jnp.asarray(vals[n]) for n in bnames])
    bmap = dict(zip(batched_decode_output_names(B), bout))

    snames = [n for n, _ in decode_arg_specs(CFG)]
    single = jax.jit(make_decode_fn(CFG))
    sonames = decode_output_names()
    for slot in range(B):
        sv = dict(vals)
        sv["token"] = vals["tokens"][slot]
        sv["pos"] = vals["poss"][slot]
        sv["cos"] = vals["cos"][slot]
        sv["sin"] = vals["sin"][slot]
        sv["kv"] = vals[f"kv{slot}"]
        for g in ASYNC_GROUPS:
            sv[f"useh_{g}"] = vals[f"useh_{g}"][slot]
        sout = single(*[jnp.asarray(sv[n]) for n in snames])
        smap = dict(zip(sonames, sout))
        np.testing.assert_allclose(np.asarray(bmap["logits"])[slot],
                                   np.asarray(smap["logits"]),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(bmap[f"kv{slot}"]),
                                   np.asarray(smap["kv"]),
                                   rtol=2e-4, atol=2e-5)
        for g in GROUPS:
            np.testing.assert_allclose(np.asarray(bmap[f"est_{g}"])[slot],
                                       np.asarray(smap[f"est_{g}"]),
                                       rtol=2e-4, atol=2e-5)
            # Effective decisions are 0/1 floats — must match exactly.
            np.testing.assert_array_equal(np.asarray(bmap[f"useh_{g}"])[slot],
                                          np.asarray(smap[f"useh_{g}"]))


def test_batched_lowering_parses_back():
    B = 2
    specs = batched_decode_arg_specs(CFG, B)
    lowered = jax.jit(make_batched_decode_fn(CFG, B)).lower(
        *[s for _, s in specs])
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert text.count("parameter(") >= len(specs)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
    assert len(mod.as_serialized_hlo_module_proto()) > 1000


# ---------------------------------------------------------------------------
# Speculative-verification step (γ+1 causal positions, one dispatch).
# ---------------------------------------------------------------------------


def _verify_args(cfg, params, G, pos0=3, seed=5):
    """Inputs for the γ-draft verify step with live selection: wl ≠ wh,
    mid-range thresholds and mixed linear/JL estimators so the in-graph
    async flag chaining actually flips decisions between positions."""
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(seed)
    L = cfg.n_layers
    g1 = G + 1
    hd = cfg.head_dim
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))
    poss = np.arange(pos0, pos0 + g1)
    vals = {
        "tokens": rng.integers(0, cfg.vocab, size=g1).astype(np.int32),
        "pos": np.int32(pos0),
        "cos": np.stack([np.cos(p * inv) for p in poss]).astype(np.float32),
        "sin": np.stack([np.sin(p * inv) for p in poss]).astype(np.float32),
        "kv": (rng.standard_normal(kv_shape(cfg)) * 0.01).astype(np.float32),
        "tok_emb": nl["tok_emb"], "out_head": nl["out_head"],
        "final_norm": nl["final_norm"], "ln1": nl["ln1"], "ln2": nl["ln2"],
        "mode_exact": np.float32(0.0),
    }
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        w = np.asarray(lin[g])
        vals[f"wl_{g}"] = (w * 0.9).astype(np.float32)
        vals[f"wh_{g}"] = w
        vals[f"G_{g}"] = (rng.standard_normal((L, K_PROJ, i)) * 0.05
                          ).astype(np.float32)
        vals[f"lina_{g}"] = rng.random(L).astype(np.float32)
        vals[f"linb_{g}"] = rng.random(L).astype(np.float32) * 0.1
        vals[f"uselin_{g}"] = (rng.random(L) < 0.5).astype(np.float32)
        vals[f"thr_{g}"] = (rng.random(L) * 0.5).astype(np.float32)
    for g in ASYNC_GROUPS:
        vals[f"useh_{g}"] = (rng.random(L) < 0.5).astype(np.float32)
    return vals


def test_verify_arg_spec_names_unique_and_ordered():
    for G in (2, 4):
        names = [n for n, _ in verify_arg_specs(CFG, G)]
        assert len(names) == len(set(names))
        assert names[0] == "tokens" and names[1] == "pos"
        assert names[-1] == "mode_exact"
        assert verify_output_names() == decode_output_names()


@pytest.mark.parametrize("G", [2, 4])
def test_verify_step_matches_sequential_single_steps(G):
    """THE speculation contract: every position of ``verify_step_g{γ}``
    must reproduce what γ+1 sequential ``decode_step`` calls would
    compute — logits, KV evolution, estimates AND the chained async
    flag decisions (position i+1's flags = position i's est > thr,
    exactly the Rust ``SelectorState::observe`` rule).  The Rust
    ``SpecSession`` relies on this to make speculative greedy decode
    token-for-token identical to plain greedy decode."""
    params = init_params(CFG, seed=0)
    vals = _verify_args(CFG, params, G)
    vnames = [n for n, _ in verify_arg_specs(CFG, G)]
    vout = jax.jit(make_verify_fn(CFG, G))(
        *[jnp.asarray(vals[n]) for n in vnames])
    vmap_out = dict(zip(verify_output_names(), vout))

    snames = [n for n, _ in decode_arg_specs(CFG)]
    single = jax.jit(make_decode_fn(CFG))
    sonames = decode_output_names()
    kv = vals["kv"]
    flags = {g: vals[f"useh_{g}"] for g in ASYNC_GROUPS}
    for i in range(G + 1):
        sv = dict(vals)
        sv["token"] = vals["tokens"][i]
        sv["pos"] = np.int32(int(vals["pos"]) + i)
        sv["cos"] = vals["cos"][i]
        sv["sin"] = vals["sin"][i]
        sv["kv"] = kv
        for g in ASYNC_GROUPS:
            sv[f"useh_{g}"] = flags[g]
        sout = single(*[jnp.asarray(sv[n]) for n in snames])
        smap = dict(zip(sonames, sout))
        np.testing.assert_allclose(np.asarray(vmap_out["logits"])[i],
                                   np.asarray(smap["logits"]),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"position {i} logits")
        for g in GROUPS:
            np.testing.assert_allclose(np.asarray(vmap_out[f"est_{g}"])[i],
                                       np.asarray(smap[f"est_{g}"]),
                                       rtol=2e-4, atol=2e-5,
                                       err_msg=f"position {i} est_{g}")
            # 0/1 decisions must match exactly per position.
            np.testing.assert_array_equal(
                np.asarray(vmap_out[f"useh_{g}"])[i],
                np.asarray(smap[f"useh_{g}"]),
                err_msg=f"position {i} useh_{g}")
        # Host-side sequential chaining: next step's async flags from
        # this step's estimates (the SelectorState::observe rule).
        kv = np.asarray(smap["kv"])
        flags = {
            g: (np.asarray(smap[f"est_{g}"]) > vals[f"thr_{g}"]
                ).astype(np.float32)
            for g in ASYNC_GROUPS
        }
    np.testing.assert_allclose(np.asarray(vmap_out["kv"]), kv,
                               rtol=2e-4, atol=2e-5,
                               err_msg="final KV after all positions")


def test_verify_chaining_actually_flips_flags():
    """Guard against a vacuous parity test: with mid-range thresholds the
    chained flags must differ across positions for at least one group
    (otherwise the chaining rule was never exercised)."""
    G = 4
    params = init_params(CFG, seed=0)
    vals = _verify_args(CFG, params, G)
    vnames = [n for n, _ in verify_arg_specs(CFG, G)]
    vout = jax.jit(make_verify_fn(CFG, G))(
        *[jnp.asarray(vals[n]) for n in vnames])
    vmap_out = dict(zip(verify_output_names(), vout))
    flips = 0
    for g in ASYNC_GROUPS:
        u = np.asarray(vmap_out[f"useh_{g}"])  # [G+1, L]
        flips += int((np.abs(np.diff(u, axis=0)).sum() > 0))
    assert flips > 0, "async decisions never changed across positions"


def test_verify_lowering_parses_back():
    G = 2
    specs = verify_arg_specs(CFG, G)
    lowered = jax.jit(make_verify_fn(CFG, G)).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert text.count("parameter(") >= len(specs)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
    assert len(mod.as_serialized_hlo_module_proto()) > 1000


# ---------------------------------------------------------------------------
# KV tier graphs (paged KV pool — DESIGN §Memory).
# ---------------------------------------------------------------------------


def _decode_vals(cfg, params, token, pos, seed=9):
    """Full decode-step input dict (incl. RoPE tables for ``pos`` and a
    random non-zero KV) — `_decode_args` above predates the cos/sin
    arguments and omits them, so the tier tests build their own."""
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(seed)
    hd = cfg.head_dim
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))
    vals = {
        "token": np.int32(token), "pos": np.int32(pos),
        "cos": np.cos(pos * inv).astype(np.float32),
        "sin": np.sin(pos * inv).astype(np.float32),
        "kv": (rng.standard_normal(kv_shape(cfg)) * 0.01).astype(np.float32),
        "tok_emb": nl["tok_emb"], "out_head": nl["out_head"],
        "final_norm": nl["final_norm"], "ln1": nl["ln1"], "ln2": nl["ln2"],
        "mode_exact": np.float32(0.0),
    }
    L = cfg.n_layers
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        w = np.asarray(lin[g])
        vals[f"wl_{g}"] = (w * 0.9).astype(np.float32)
        vals[f"wh_{g}"] = w
        vals[f"G_{g}"] = (rng.standard_normal((L, K_PROJ, i)) * 0.05
                          ).astype(np.float32)
        vals[f"lina_{g}"] = rng.random(L).astype(np.float32)
        vals[f"linb_{g}"] = rng.random(L).astype(np.float32) * 0.1
        vals[f"uselin_{g}"] = (rng.random(L) < 0.5).astype(np.float32)
        vals[f"thr_{g}"] = (rng.random(L) * 0.5).astype(np.float32)
    for g in ASYNC_GROUPS:
        vals[f"useh_{g}"] = (rng.random(L) < 0.5).astype(np.float32)
    return vals


def test_tier_ladder_doubles_below_max_seq():
    from compile.aot import tier_ladder
    assert tier_ladder(640) == [128, 256, 512]
    assert tier_ladder(128) == []
    assert tier_ladder(16, base=4) == [4, 8]


def test_tier_decode_matches_full_graph_bitwise():
    """THE tier-truncation contract: for pos < S, ``decode_step_s{S}``
    must be BITWISE identical to the full-max_seq graph on the same
    prefix — the ``arange(S) <= pos`` mask zeroes every slot past pos
    exactly (−1e30 → softmax weight 0.0), so truncating the tail can
    change nothing.  The Rust KvPool relies on this to run short
    sequences in small tiers and migrate by plain zero-pad."""
    import dataclasses
    S, pos = 8, 5
    tcfg = dataclasses.replace(CFG, max_seq=S)
    params = init_params(CFG, seed=0)
    vals = _decode_vals(CFG, params, token=3, pos=pos)
    tvals = dict(vals)
    tvals["kv"] = vals["kv"][:, :, :, :S]

    names = [n for n, _ in decode_arg_specs(CFG)]
    fout = jax.jit(make_decode_fn(CFG))(
        *[jnp.asarray(vals[n]) for n in names])
    tout = jax.jit(make_decode_fn(tcfg))(
        *[jnp.asarray(tvals[n]) for n in names])
    fmap = dict(zip(decode_output_names(), fout))
    tmap = dict(zip(decode_output_names(), tout))
    np.testing.assert_array_equal(np.asarray(tmap["logits"]),
                                  np.asarray(fmap["logits"]))
    # The written prefix of the KV leaf is identical too (the tail the
    # tier dropped was pass-through in the full graph).
    np.testing.assert_array_equal(np.asarray(tmap["kv"]),
                                  np.asarray(fmap["kv"])[:, :, :, :S])
    for g in GROUPS:
        np.testing.assert_array_equal(np.asarray(tmap[f"useh_{g}"]),
                                      np.asarray(fmap[f"useh_{g}"]))


def test_tier_migration_zero_pad_matches_max_from_birth():
    """THE migration contract: growing a tier-S KV to max_seq by plain
    zero-padding dim 3, then decoding on the full graph, must equal
    having run at max_seq from birth — tail slots are don't-care under
    the mask, so migration is a buffer copy, not a recompute."""
    import dataclasses
    S, C, n_prompt = 8, 4, 6
    tcfg = dataclasses.replace(CFG, max_seq=S)
    params = init_params(CFG, seed=1)
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, CFG.vocab, size=8).astype(np.int32)
    toks[n_prompt:] = 0

    def ingest(cfg):
        kv = jnp.zeros(kv_shape(cfg), jnp.float32)
        for c0, nv in ((0, 4), (4, 2)):
            cos_c, sin_c = _rope_tables(c0, C)
            _, kv = prefill_chunk(
                nl, lin, cfg, jnp.asarray(toks[c0:c0 + C]), jnp.int32(c0),
                jnp.int32(nv), jnp.asarray(cos_c), jnp.asarray(sin_c), kv)
        return np.asarray(kv)

    kv_tier = ingest(tcfg)
    kv_birth = ingest(CFG)
    # Migrate: zero-pad the sequence dim (exactly rust host_grow /
    # kv_cast_hlo_text).
    pad = [(0, 0)] * 5
    pad[3] = (0, CFG.max_seq - S)
    kv_migrated = np.pad(kv_tier, pad)

    names = [n for n, _ in decode_arg_specs(CFG)]
    vals = _decode_vals(CFG, params, token=3, pos=n_prompt)
    step = jax.jit(make_decode_fn(CFG))
    vals["kv"] = kv_birth
    lo_birth = np.asarray(step(*[jnp.asarray(vals[n]) for n in names])[0])
    vals["kv"] = kv_migrated
    lo_migrated = np.asarray(step(*[jnp.asarray(vals[n]) for n in names])[0])
    np.testing.assert_array_equal(lo_migrated, lo_birth)


def test_tier_lowering_parses_back():
    import dataclasses
    tcfg = dataclasses.replace(CFG, max_seq=8)
    specs = decode_arg_specs(tcfg)
    lowered = jax.jit(make_decode_fn(tcfg)).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
