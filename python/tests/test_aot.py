"""AOT lowering: HLO text round-trips through the XLA text parser, and the
lowered decode graph reproduces the jax-eval semantics.

This is the L2→L3 contract test: if these pass, the Rust loader is
executing the same computation pytest validated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import (decode_arg_specs, decode_output_names, f32,
                         make_decode_fn, make_prefill_fn, prefill_arg_specs,
                         to_hlo_text)
from compile.kernels.estimator import K_PROJ
from compile.model import (ASYNC_GROUPS, GROUPS, ModelConfig, extract_linears,
                           init_params, kv_shape, nonlinear_params)

CFG = ModelConfig("aot-test", vocab=32, d_model=16, n_layers=2, n_heads=2,
                  d_ff=24, max_seq=16)


def _decode_args(cfg, params, token=1, pos=0):
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    vals = {
        "token": np.int32(token), "pos": np.int32(pos),
        "kv": np.zeros(kv_shape(cfg), np.float32),
        "tok_emb": nl["tok_emb"], "out_head": nl["out_head"],
        "final_norm": nl["final_norm"], "ln1": nl["ln1"], "ln2": nl["ln2"],
        "mode_exact": np.float32(0.0),
    }
    for g in GROUPS:
        o, i = cfg.group_shape(g)
        L = cfg.n_layers
        vals[f"wl_{g}"] = np.asarray(lin[g])
        vals[f"wh_{g}"] = np.asarray(lin[g])
        vals[f"G_{g}"] = np.zeros((L, K_PROJ, i), np.float32)
        vals[f"lina_{g}"] = np.zeros(L, np.float32)
        vals[f"linb_{g}"] = np.zeros(L, np.float32)
        vals[f"uselin_{g}"] = np.ones(L, np.float32)
        vals[f"thr_{g}"] = np.full(L, 1e30, np.float32)
    for g in ASYNC_GROUPS:
        vals[f"useh_{g}"] = np.zeros(cfg.n_layers, np.float32)
    names = [n for n, _ in decode_arg_specs(cfg)]
    return [np.asarray(vals[n]) for n in names]


@pytest.fixture(scope="module")
def lowered_decode():
    specs = decode_arg_specs(CFG)
    return jax.jit(make_decode_fn(CFG)).lower(*[s for _, s in specs])


def test_decode_lowering_produces_hlo(lowered_decode):
    text = to_hlo_text(lowered_decode)
    assert "ENTRY" in text and "parameter" in text
    n_args = len(decode_arg_specs(CFG))
    assert text.count("parameter(") >= n_args


def test_hlo_text_parses_back(lowered_decode):
    """The text artifact must round-trip through XLA's HLO text parser —
    the same parser the Rust loader (`HloModuleProto::from_text_file`)
    uses.  (The *numeric* round-trip is a Rust integration test against
    the golden npz exported by aot.export_golden.)"""
    text = to_hlo_text(lowered_decode)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
    # Re-serialized proto must be non-trivial.
    assert len(mod.as_serialized_hlo_module_proto()) > 1000


def test_golden_dump_consistent(tmp_path):
    """export_golden writes inputs + outputs that match direct jax eval."""
    from compile.aot import golden_decode_arrays
    params = init_params(CFG, seed=0)
    arrays = golden_decode_arrays(CFG, params, token=3, pos=0)
    names = [n for n, _ in decode_arg_specs(CFG)]
    args = [jnp.asarray(arrays[f"in_{n}"]) for n in names]
    ref = jax.jit(make_decode_fn(CFG))(*args)
    np.testing.assert_allclose(arrays["out_logits"], np.asarray(ref[0]),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(arrays["out_kv"], np.asarray(ref[1]),
                               rtol=2e-4, atol=2e-5)


def test_prefill_lowering(lowered_decode):
    P = 8
    specs = prefill_arg_specs(CFG, P)
    lowered = jax.jit(make_prefill_fn(CFG, P)).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    assert "ENTRY" in text


def test_arg_spec_names_unique():
    names = [n for n, _ in decode_arg_specs(CFG)]
    assert len(names) == len(set(names))
    assert names[0] == "token" and names[-1] == "mode_exact"
