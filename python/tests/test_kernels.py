"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/bitwidths; assert_allclose against ref.py is the
core correctness signal of the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need hypothesis; cargo-only / minimal CI
# environments without it skip this module instead of erroring
# out of collection (the ci.sh pytest gate must stay runnable).
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.anyprec_gemv import anyprec_gemv, vmem_bytes
from compile.kernels.estimator import jl_estimate


def random_store(rng, out_dim: int, n_in: int):
    """A random-but-valid bitplane store + nested LUT family."""
    code6 = rng.integers(0, 64, size=(out_dim, n_in), dtype=np.int64)
    planes = ref.pack_codes_np(code6)
    luts = {}
    for b in range(3, 7):
        luts[b] = rng.standard_normal((out_dim, 2 ** b)).astype(np.float32)
    return planes, luts


@settings(max_examples=12, deadline=None)
@given(
    out_tiles=st.integers(1, 3),
    in_bytes=st.sampled_from([2, 4, 8, 24]),
    bits=st.integers(3, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_anyprec_gemv_matches_ref(out_tiles, in_bytes, bits, seed):
    rng = np.random.default_rng(seed)
    tile = 16
    out_dim, n_in = out_tiles * tile, in_bytes * 8
    planes, luts = random_store(rng, out_dim, n_in)
    x = rng.standard_normal(n_in).astype(np.float32)
    got = anyprec_gemv(jnp.asarray(planes), jnp.asarray(luts[bits]),
                       jnp.asarray(x), bits, tile_out=tile)
    want = ref.anyprec_gemv_ref(jnp.asarray(planes), jnp.asarray(luts[bits]),
                                jnp.asarray(x), bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_anyprec_gemv_model_shapes():
    """The exact shapes the dpl models use."""
    rng = np.random.default_rng(0)
    for out_dim, n_in in [(192, 192), (512, 192), (192, 512), (256, 256)]:
        planes, luts = random_store(rng, out_dim, n_in)
        x = rng.standard_normal(n_in).astype(np.float32)
        for bits in (3, 6):
            got = anyprec_gemv(jnp.asarray(planes), jnp.asarray(luts[bits]),
                               jnp.asarray(x), bits)
            want = ref.anyprec_gemv_ref(jnp.asarray(planes),
                                        jnp.asarray(luts[bits]),
                                        jnp.asarray(x), bits)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)


def test_prefix_nesting_of_codes():
    """code_b must be the MSB prefix of code_{b+1} by format definition."""
    rng = np.random.default_rng(1)
    planes, _ = random_store(rng, 32, 64)
    p = jnp.asarray(planes)
    for b in range(3, 6):
        cb = ref.codes_from_planes(p, b)
        cb1 = ref.codes_from_planes(p, b + 1)
        np.testing.assert_array_equal(np.asarray(cb), np.asarray(cb1) >> 1)


@settings(max_examples=10, deadline=None)
@given(
    k=st.sampled_from([8, 64]),
    n=st.sampled_from([64, 192, 704]),
    seed=st.integers(0, 2**31 - 1),
)
def test_jl_estimator_matches_ref(k, n, seed):
    rng = np.random.default_rng(seed)
    G = rng.standard_normal((k, n)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    got = float(jl_estimate(jnp.asarray(G), jnp.asarray(x))[0])
    want = float(ref.jl_norm_ref(jnp.asarray(G), jnp.asarray(x)))
    assert got == pytest.approx(want, rel=1e-5)


def test_jl_concentration():
    """JL property: ‖Ax‖ concentrates around ‖x‖ for A ~ N(0,1/k)."""
    rng = np.random.default_rng(7)
    k, n = 64, 512
    hits = 0
    trials = 50
    for _ in range(trials):
        A = (rng.standard_normal((k, n)) / np.sqrt(k)).astype(np.float32)
        x = rng.standard_normal(n).astype(np.float32)
        est = float(jl_estimate(jnp.asarray(A), jnp.asarray(x))[0])
        if abs(est - np.linalg.norm(x)) / np.linalg.norm(x) < 0.25:
            hits += 1
    assert hits >= trials * 0.85, f"only {hits}/{trials} within 25%"


def test_vmem_budget():
    """Default tiling keeps one grid step well under a 16 MB VMEM budget."""
    for bits in (3, 6):
        assert vmem_bytes(bits, 64, 1024) < 16 * 2**20


def test_unpack_bit_order():
    """Byte k bit j maps to weight column 8k + j (little-bit order)."""
    planes = np.zeros((6, 1, 2), np.uint8)
    planes[0, 0, 0] = 0b00000010  # MSB plane, column 1
    planes[5, 0, 1] = 0b00000001  # LSB plane, column 8
    bits = np.asarray(ref.unpack_planes(jnp.asarray(planes)))
    assert bits[0, 0, 1] == 1 and bits[0, 0, 0] == 0
    assert bits[5, 0, 8] == 1
