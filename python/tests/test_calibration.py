"""Phase-2 soft-mix and Phase-3 threshold-translation math on a toy model."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.finetune_p import mixed_forward
from compile.model import (GROUPS, ModelConfig, extract_linears, forward,
                           init_params, nonlinear_params)
from compile.thresholds import candidate_pair

CFG = ModelConfig("test", vocab=64, d_model=32, n_layers=2, n_heads=2,
                  d_ff=48, max_seq=24)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, seed=1)
    nl = nonlinear_params(params)
    lin = extract_linears(params)
    rng = np.random.default_rng(2)
    # Synthetic "quantized" levels: level b = weights + noise / 2^(b-3).
    levels = {}
    for g in GROUPS:
        w = np.asarray(lin[g])
        noise = rng.standard_normal(w.shape).astype(np.float32) * 0.01
        levels[g] = jnp.asarray(np.stack(
            [w + noise / (2.0 ** k) for k in range(4)], axis=1))
    return params, nl, levels


def test_mixed_forward_at_integer_p_equals_level(setup):
    params, nl, levels = setup
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, CFG.vocab, size=(2, 10)).astype(np.int32))
    for b in (3, 6):
        p = {g: jnp.full(CFG.n_layers, float(b)) for g in GROUPS}
        got = mixed_forward(nl, levels, p, CFG, toks)
        lin_b = {g: levels[g][:, b - 3] for g in GROUPS}
        want = forward({**nl, **lin_b}, CFG, toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-4)


def test_mixed_forward_interpolates(setup):
    """p = 3.5 output must sit strictly between the 3-bit and 4-bit outputs
    (in the sense of being closer to both than they are to each other)."""
    params, nl, levels = setup
    toks = jnp.asarray(np.random.default_rng(4).integers(
        0, CFG.vocab, size=(1, 8)).astype(np.int32))
    outs = {}
    for val in (3.0, 3.5, 4.0):
        p = {g: jnp.full(CFG.n_layers, val) for g in GROUPS}
        outs[val] = np.asarray(mixed_forward(nl, levels, p, CFG, toks))
    d34 = np.abs(outs[3.0] - outs[4.0]).mean()
    d3m = np.abs(outs[3.0] - outs[3.5]).mean()
    d4m = np.abs(outs[4.0] - outs[3.5]).mean()
    assert d3m < d34 and d4m < d34


def test_mixed_forward_gradient_direction(setup):
    """Loss should (generically) decrease as p rises: grad wrt p exists and
    the regularizer-free CE at p=6 is <= CE at p=3 (more precision)."""
    import jax
    from compile.model import ce_from_logits
    params, nl, levels = setup
    toks = jnp.asarray(np.random.default_rng(5).integers(
        0, CFG.vocab, size=(2, 10)).astype(np.int32))

    def ce_at(val):
        p = {g: jnp.full(CFG.n_layers, val) for g in GROUPS}
        return float(ce_from_logits(mixed_forward(nl, levels, p, CFG, toks), toks))

    def loss(p):
        return ce_from_logits(mixed_forward(nl, levels, p, CFG, toks), toks)

    p0 = {g: jnp.full(CFG.n_layers, 3.5) for g in GROUPS}
    g = jax.grad(loss)(p0)
    total = sum(float(jnp.abs(g[k]).sum()) for k in GROUPS)
    assert np.isfinite(total) and total > 0.0


def test_candidate_pair():
    assert candidate_pair(3.2) == (3, 4)
    assert candidate_pair(4.0) == (4, 4)
    assert candidate_pair(5.999) == (5, 6)
    assert candidate_pair(4.3, fixed_lh=(3, 6)) == (3, 6)


def test_threshold_quantile_semantics():
    """r-quantile threshold ⇒ fraction ~(1-r) of calibration errors exceed
    T ⇒ expected use-high fraction = 1-r = p - l (Algorithm 1 Phase 3)."""
    rng = np.random.default_rng(6)
    errs = rng.gamma(2.0, 1.0, size=5000)
    for p_i in (3.2, 3.5, 3.8):
        r = 1.0 - (p_i - 3)
        thr = np.quantile(errs, r)
        frac_high = (errs > thr).mean()
        assert abs(frac_high - (p_i - 3)) < 0.02
