"""Quantizer invariants: nesting, monotone error, Fisher weighting."""

import numpy as np
import pytest

from compile.kernels import ref
from compile.quantize import (_split_clusters, _weighted_kmeans_rows,
                              quantize_group)


def _rand_group(rng, L=2, out=16, n_in=32):
    w = rng.standard_normal((L, out, n_in)).astype(np.float32) * 0.05
    f = rng.random((L, out, n_in)).astype(np.float32) + 0.1
    return w, f


def test_kmeans_rows_basic():
    rng = np.random.default_rng(0)
    v = rng.standard_normal((4, 200)).astype(np.float32)
    w = np.ones_like(v)
    codes, cent = _weighted_kmeans_rows(v, w, 8)
    assert codes.shape == v.shape and cent.shape == (4, 8)
    assert codes.min() >= 0 and codes.max() < 8
    # Centroids sorted; codes monotone in value.
    assert np.all(np.diff(cent, axis=1) >= -1e-6)
    for r in range(4):
        order = np.argsort(v[r])
        assert np.all(np.diff(codes[r][order]) >= 0)


def test_kmeans_respects_weights():
    """Columns with huge Fisher weight should land nearer their centroid."""
    rng = np.random.default_rng(1)
    v = rng.standard_normal((1, 400)).astype(np.float32)
    w_uni = np.ones_like(v)
    w_spiky = np.ones_like(v)
    heavy = slice(0, 20)
    w_spiky[0, heavy] = 1000.0
    _, cent_u = _weighted_kmeans_rows(v, w_uni, 8)
    codes_s, cent_s = _weighted_kmeans_rows(v, w_spiky, 8)
    err_heavy_s = np.abs(v[0, heavy] - cent_s[0, codes_s[0, heavy]]).mean()
    codes_u, _ = _weighted_kmeans_rows(v, w_uni, 8)
    err_heavy_u = np.abs(v[0, heavy] - cent_u[0, codes_u[0, heavy]]).mean()
    assert err_heavy_s <= err_heavy_u + 1e-6


def test_split_preserves_parent_prefix():
    rng = np.random.default_rng(2)
    v = rng.standard_normal((3, 100)).astype(np.float32)
    w = np.ones_like(v)
    codes, cent = _weighted_kmeans_rows(v, w, 8)
    codes2, cent2 = _split_clusters(v, w, codes, cent)
    assert cent2.shape == (3, 16)
    np.testing.assert_array_equal(codes2 >> 1, codes)


def test_quantize_group_contract():
    rng = np.random.default_rng(3)
    w, f = _rand_group(rng)
    planes, luts = quantize_group(w, f)
    L, out, n_in = w.shape
    assert planes.shape == (L, 6, out, n_in // 8)
    for b in range(3, 7):
        assert luts[b].shape == (L, out, 2 ** b)


def test_quantize_error_monotone_in_bits():
    """More bits -> lower weighted reconstruction error (the property the
    whole adaptation-set idea rests on)."""
    rng = np.random.default_rng(4)
    w, f = _rand_group(rng, L=1, out=24, n_in=64)
    planes, luts = quantize_group(w, f)
    errs = []
    for b in range(3, 7):
        deq = ref.dequant_np(planes[0], luts[b][0], b)
        errs.append(float((f[0] * (deq - w[0]) ** 2).sum()))
    assert errs[0] > errs[1] > errs[2] > errs[3], errs


def test_quantize_6bit_is_accurate():
    rng = np.random.default_rng(5)
    w, f = _rand_group(rng, L=1, out=16, n_in=64)
    planes, luts = quantize_group(w, f)
    deq = ref.dequant_np(planes[0], luts[6][0], 6)
    rel = np.abs(deq - w[0]).mean() / np.abs(w[0]).mean()
    assert rel < 0.08, rel


def test_dequant_np_matches_jnp_ref():
    import jax.numpy as jnp
    rng = np.random.default_rng(6)
    code6 = rng.integers(0, 64, size=(8, 32), dtype=np.int64)
    planes = ref.pack_codes_np(code6)
    lut = rng.standard_normal((8, 16)).astype(np.float32)
    a = ref.dequant_np(planes, lut, 4)
    b = np.asarray(ref.dequant_ref(jnp.asarray(planes), jnp.asarray(lut), 4))
    np.testing.assert_allclose(a, b, rtol=1e-6)
