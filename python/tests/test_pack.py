"""DPAK packer invariants: header, canonical layout, digests, determinism."""

import json
import zlib

import numpy as np
import pytest

from compile.model import GROUPS
from compile.pack import (ALIGN, MAX_BITS, MIN_BITS, _dump, write_dpak)


def _synth_store(rng, L=2, out=8, n_in=16):
    planes = {g: rng.integers(0, 256, size=(L, MAX_BITS, out, n_in // 8),
                              dtype=np.uint8)
              for g in GROUPS}
    luts = {g: {b: rng.standard_normal((L, out, 2 ** b)).astype(np.float32)
                for b in range(MIN_BITS, MAX_BITS + 1)}
            for g in GROUPS}
    return planes, luts


def _parse(path):
    raw = open(path, "rb").read()
    assert raw[0:4] == b"DPAK"
    assert int.from_bytes(raw[4:8], "little") == 1
    mlen = int.from_bytes(raw[8:16], "little")
    manifest = json.loads(raw[16:16 + mlen].decode())
    return raw, manifest


def test_container_layout_and_digests(tmp_path):
    rng = np.random.default_rng(7)
    planes, luts = _synth_store(rng)
    path = str(tmp_path / "t.dpak")
    version = write_dpak(path, "toy", planes, luts)
    raw, man = _parse(path)
    assert man["format"] == "dpak" and man["model"] == "toy"
    assert man["version"] == version and version.startswith("crc32:")
    assert man["min_bits"] == MIN_BITS and man["max_bits"] == MAX_BITS
    assert set(man["groups"]) == set(GROUPS)
    # Every recorded section: aligned, in bounds, digest-true, and its
    # payload byte-equal to the source arrays.
    for g in GROUPS:
        gj = man["groups"][g]
        assert (gj["n_layers"], gj["out"], gj["in"]) == (2, 8, 16)
        for p, e in enumerate(gj["planes"]):
            assert e["off"] % ALIGN == 0
            payload = raw[e["off"]:e["off"] + e["len"]]
            assert payload == np.ascontiguousarray(planes[g][:, p]).tobytes()
            assert e["digest"] == "crc32:%08x" % zlib.crc32(payload)
            lb = e["len"] // gj["n_layers"]
            for l, ld in enumerate(e["layers"]):
                chunk = payload[l * lb:(l + 1) * lb]
                assert ld == "crc32:%08x" % zlib.crc32(chunk)
        for b in range(MIN_BITS, MAX_BITS + 1):
            e = gj["luts"][str(b)]
            payload = raw[e["off"]:e["off"] + e["len"]]
            assert payload == luts[g][b].astype("<f4").tobytes()
            assert e["digest"] == "crc32:%08x" % zlib.crc32(payload)


def test_tier_slice_is_a_prefix(tmp_path):
    """Plane-major layout: the planes a 4-bit tier needs (0..3, the
    dominant bytes) all end before any 5/6-bit plane begins, and the
    LUT region is likewise ordered by ascending bitwidth — higher
    precision is pure appended delta in each region."""
    rng = np.random.default_rng(8)
    planes, luts = _synth_store(rng)
    path = str(tmp_path / "t.dpak")
    write_dpak(path, "toy", planes, luts)
    _, man = _parse(path)
    lo_end, hi_start = 0, 1 << 60
    lut_ends = {b: 0 for b in range(MIN_BITS, MAX_BITS + 1)}
    lut_starts = {b: 1 << 60 for b in range(MIN_BITS, MAX_BITS + 1)}
    for g in GROUPS:
        gj = man["groups"][g]
        for p, e in enumerate(gj["planes"]):
            if p < 4:
                lo_end = max(lo_end, e["off"] + e["len"])
            else:
                hi_start = min(hi_start, e["off"])
        for b in range(MIN_BITS, MAX_BITS + 1):
            e = gj["luts"][str(b)]
            lut_starts[b] = min(lut_starts[b], e["off"])
            lut_ends[b] = max(lut_ends[b], e["off"] + e["len"])
    assert lo_end <= hi_start
    # LUTs live after every plane, ascending by bitwidth.
    assert hi_start <= min(lut_starts.values())
    for b in range(MIN_BITS, MAX_BITS):
        assert lut_ends[b] <= lut_starts[b + 1]


def test_version_is_content_identity(tmp_path):
    """Same weights -> same version; one flipped bit -> different."""
    rng = np.random.default_rng(9)
    planes, luts = _synth_store(rng)
    v1 = write_dpak(str(tmp_path / "a.dpak"), "toy", planes, luts)
    v2 = write_dpak(str(tmp_path / "b.dpak"), "renamed", planes, luts)
    assert v1 == v2  # model name is not part of the content identity
    planes["wq"][0, 0, 0, 0] ^= 1
    v3 = write_dpak(str(tmp_path / "c.dpak"), "toy", planes, luts)
    assert v3 != v1


def test_manifest_dump_is_compact_sorted():
    s = _dump({"b": 1, "a": {"z": True, "y": [1, 2]}})
    assert s == '{"a":{"y":[1,2],"z":true},"b":1}'


def test_missing_group_refused(tmp_path):
    rng = np.random.default_rng(10)
    planes, luts = _synth_store(rng)
    del planes["wd"]
    with pytest.raises(ValueError, match="missing group"):
        write_dpak(str(tmp_path / "t.dpak"), "toy", planes, luts)
