//! §Perf probe: decode-step wall time under the device-resident GenState
//! path, vs the isolated KV host-upload cost the old per-step round trip
//! paid (DESIGN.md §Perf).  Also prints the measured per-step host→device
//! traffic, which must be O(1) in KV size (a few scalar/flag buffers),
//! not O(kv_bytes).
use std::sync::Arc;
use std::time::Instant;
use dp_llm::evalharness::{build_session, Method};
use dp_llm::model::{Manifest, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::runtime::Runtime;

fn main() {
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load("dpl-tiny").unwrap();
    let manifest = Manifest::load().unwrap();
    let session = build_session(&rt, &assets, &manifest, 5,
                                &Method::Dpllm { tag: "4.00".into() }).unwrap();
    let mut gen = session.begin_empty().unwrap();
    // warm (compile caches, rope/scalar device caches)
    for _ in 0..3 {
        session.advance(&mut gen, 1, EstMode::Approx).unwrap();
    }
    let n = 20;
    let before = rt.transfers().snapshot();
    let t0 = Instant::now();
    for _ in 0..n {
        session.advance(&mut gen, 1, EstMode::Approx).unwrap();
    }
    let step_ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
    let after = rt.transfers().snapshot();
    let per_step_bytes = after.upload_bytes_since(&before) as f64 / n as f64;
    // isolate what one kv upload would have cost (the old per-step tax)
    let kv = session.zero_kv();
    let t1 = Instant::now();
    for _ in 0..n {
        let _ = rt.upload_f32(&session.cfg.kv_shape(), &kv).unwrap();
    }
    let up_ms = t1.elapsed().as_secs_f64() * 1e3 / n as f64;
    println!(
        "decode step: {step_ms:.2} ms | kv resident on device: {} | \
         host->device per step: {per_step_bytes:.0} B (kv would be {} B) | \
         avoided kv upload: {up_ms:.2} ms/step ({:.0}% of step, x2 with the \
         old download side)",
        gen.kv_on_device(),
        session.kv_bytes(),
        up_ms / step_ms * 100.0
    );
}
