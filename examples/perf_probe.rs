//! §Perf probe: decode-step wall time vs the isolated KV host-upload cost
//! (EXPERIMENTS.md §Perf item 4).
use std::sync::Arc;
use std::time::Instant;
use dp_llm::evalharness::{build_session, Method};
use dp_llm::model::{Manifest, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::runtime::Runtime;

fn main() {
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load("dpl-tiny").unwrap();
    let manifest = Manifest::load().unwrap();
    let session = build_session(&rt, &assets, &manifest, 5,
                                &Method::Dpllm { tag: "4.00".into() }).unwrap();
    let mut kv = session.zero_kv();
    let sel = session.selector_state();
    // warm
    for t in 0..3 {
        kv = session.step(1, t, &kv, &sel.use_h_async, EstMode::Approx).unwrap().kv;
    }
    let n = 20;
    let t0 = Instant::now();
    for t in 0..n {
        kv = session.step(1, t + 3, &kv, &sel.use_h_async, EstMode::Approx).unwrap().kv;
    }
    let step_ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
    // isolate kv upload cost
    let t1 = Instant::now();
    for _ in 0..n {
        let _ = rt.upload_f32(&session.cfg.kv_shape(), &kv).unwrap();
    }
    let up_ms = t1.elapsed().as_secs_f64() * 1e3 / n as f64;
    println!("decode step: {step_ms:.2} ms | kv upload alone: {up_ms:.2} ms \
              ({:.0}% of step, x2 for download side)", up_ms / step_ms * 100.0);
}
