//! Precision sweep: the latency↔quality trade-off curve DP-LLM exposes —
//! perplexity + measured TPOT + modeled Jetson TPOT at each target
//! precision in the adaptation set, against the static HAWQ-V2 baseline.
//!
//!     cargo run --release --example precision_sweep

use std::sync::Arc;

use dp_llm::costmodel::{weight_bytes_at, JETSON_ORIN};
use dp_llm::evalharness::{build_session, load_stream, perplexity, Method};
use dp_llm::model::{artifacts_available, Manifest, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let rt = Arc::new(Runtime::new()?);
    let assets = ModelAssets::load("dpl-tiny")?;
    let manifest = Manifest::load()?;
    let stream = load_stream("synthwiki")?;
    let tokens: usize = std::env::var("DPLLM_EVAL_TOKENS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(192);

    println!("{:>7} {:>12} {:>12} {:>10} {:>12}",
             "target", "dpllm ppl", "hawq ppl", "eff bits", "jetson tpot");
    for t in [3.25f64, 3.5, 3.75, 4.0, 4.25, 4.5, 4.75] {
        let dyn_m = Method::Dpllm { tag: format!("{t:.2}") };
        let sta_m = Method::Static { method: "hawq_v2".into(), target: t };
        let d = build_session(&rt, &assets, &manifest, 5, &dyn_m)
            .and_then(|s| perplexity(&s, &stream, 96, tokens, EstMode::Approx));
        let s = build_session(&rt, &assets, &manifest, 5, &sta_m)
            .and_then(|s| perplexity(&s, &stream, 96, tokens, EstMode::Approx));
        let jet = JETSON_ORIN.tpot_ms(weight_bytes_at(&assets.store, t));
        match (d, s) {
            (Ok(d), Ok(s)) => println!(
                "{t:>7.2} {:>12.4} {:>12.4} {:>10.3} {:>10.2}ms",
                d.ppl, s.ppl, d.effective_bits, jet
            ),
            _ => println!("{t:>7.2} (config missing)"),
        }
    }
    println!("\n(dpllm ppl ≤ hawq ppl at each row is the paper's headline claim;");
    println!(" 'jetson tpot' is the Table-5-fit device model applied to this model)");
    Ok(())
}
