//! Quickstart: load a DP-LLM configuration and generate text with dynamic
//! per-layer precision.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use dp_llm::evalharness::{build_session, tasks, Method};
use dp_llm::model::{art, artifacts_available, Manifest, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::runtime::Runtime;
use dp_llm::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    // 1. One PJRT CPU runtime per process.
    let rt = Arc::new(Runtime::new()?);
    // 2. Model assets: checkpoint, any-precision store, manifest.
    let assets = ModelAssets::load("dpl-tiny")?;
    let manifest = Manifest::load()?;
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"]))?;

    // 3. Pick a configuration from the adaptation set: DP-LLM at an
    //    average 4.0 bits under the 5-bit memory budget.
    let method = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &method)?;
    println!("loaded {} [{}] — candidate pairs are chosen per layer,",
             assets.cfg.name, session.ec.tag);
    println!("precision is re-selected every decoding step from the");
    println!("relative-error estimate vs the calibrated threshold.\n");

    // 4. Generate.
    for prompt in [
        "The town of Kamodor is",
        "Question: Mara has 23 coins. Jon gives Mara 18 more. How many coins does Mara have?\nAnswer: ",
        "Task: add 3 to each item. Input: 4 7 2. Output: ",
    ] {
        let (text, eff_bits) = tasks::generate(&session, &tok, prompt, 40,
                                               EstMode::Approx)?;
        println!("prompt: {prompt:?}");
        println!("output: {:?}", text.trim_end());
        println!("effective bits this query: {eff_bits:.3}\n");
    }
    Ok(())
}
