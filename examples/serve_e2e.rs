//! End-to-end serving driver (the DESIGN.md mandated validation run):
//!
//! Phase 1 drives the token-interleaved [`ServingCore`] directly: several
//! mixed-QoS requests are admitted mid-flight and stream their tokens
//! through the callback while the core round-robins / EDF-orders decode
//! steps across them — the interleaving is visible in the event log.
//!
//! Phase 2 boots the HTTP server on a real socket with the full adaptation
//! set, fires a batch of concurrent client requests with mixed QoS budgets
//! and pinned-target requests, and reports latency / throughput /
//! effective bitwidth — proving L1 (Pallas kernels in the decode graph),
//! L2 (AOT HLO), and L3 (coordinator/server) compose on the request path
//! with no Python anywhere.
//!
//!     make artifacts && cargo run --release --example serve_e2e

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dp_llm::coordinator::qos::{QosBudget, UtilizationSim};
use dp_llm::coordinator::router::{Router, RouterConfig};
use dp_llm::coordinator::sched::{Request, RequestQueue, SchedPolicy};
use dp_llm::coordinator::service::{CoreEvent, ServingCore, ServingEngine};
use dp_llm::costmodel::{weight_bytes_at, JETSON_ORIN};
use dp_llm::evalharness::tasks;
use dp_llm::model::{artifacts_available, ModelAssets};
use dp_llm::runtime::replica::{engine_link, ReplicaSpec};
use dp_llm::runtime::Runtime;
use dp_llm::server::{http_get, http_post, RouterServer, Server};
use dp_llm::util::json::Json;
use dp_llm::util::stats::{mean, percentile};

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let addr = "127.0.0.1:8077";
    let n_requests: usize = std::env::var("DPLLM_E2E_REQUESTS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(12);

    let rt = Arc::new(Runtime::new()?);
    let engine = ServingEngine::load(&rt, "dpl-tiny", 5,
                                     &["3.25", "3.50", "4.00", "4.50", "4.75"])?;
    println!("[e2e] adaptation set: {:?}", engine.targets());
    let prompts: Vec<String> = tasks::load_task("instruct")?
        .into_iter().map(|s| s.prompt).collect();

    // --- phase 1: token-interleaved streaming through ServingCore -------
    println!("[e2e] phase 1: interleaved streaming (EDF, 3 concurrent)");
    let mut queue = RequestQueue::new(SchedPolicy::Edf);
    for i in 0..3usize {
        let r = Request::new(100 + i as u64, prompts[i % prompts.len()].clone(),
                             12, if i == 2 { QosBudget::tight(120.0) }
                                 else { QosBudget::best_effort() });
        queue.push(if i == 2 { r.with_deadline(500.0) } else { r });
    }
    let mut util = UtilizationSim::constant(0.3);
    let mut stream_log: Vec<(u64, usize)> = Vec::new();
    let outcomes = ServingCore::new(&engine, SchedPolicy::Edf)
        .run(&mut queue, &mut util, &mut |ev| {
            if let CoreEvent::Token { id, index, piece, .. } = ev {
                stream_log.push((*id, *index));
                if *index < 4 {
                    println!("[e2e]   stream req {id} tok#{index}: {piece:?}");
                }
            }
        })?;
    let interleaved = stream_log
        .windows(2)
        .filter(|w| w[0].0 != w[1].0)
        .count();
    println!(
        "[e2e] phase 1 done: {} requests, {} stream events, {} request \
         switches at token granularity",
        outcomes.len(), stream_log.len(), interleaved
    );
    // One serialized counter snapshot — the same serializer behind
    // GET /metrics' `counters` field (transfers + weight cache +
    // batching + speculation).
    println!("[e2e] phase 1 {}", engine.counters_report());
    // The combined device-memory report (weight cache + paged KV pool —
    // DESIGN.md §Memory), as served in GET /metrics' `memory` field.
    println!("[e2e] phase 1 memory {}", engine.memory_json().dump());
    for o in &outcomes {
        println!(
            "[e2e]   req {} target {:.2} eff {:.3} ttft {:.0}ms retargets {}",
            o.id, o.target_precision, o.effective_bits, o.ttft_ms, o.retargets
        );
    }

    // --- phase 2: the HTTP front-end over the same engine ----------------
    let server = Server::new(engine, UtilizationSim::new(5, 0.5));
    let stop = server.stop_handle();

    // Client load runs on worker threads; the server loop runs here.
    let client = std::thread::spawn(move || -> anyhow::Result<Vec<Json>> {
        // wait for the listener
        for _ in 0..100 {
            if http_get(addr, "/health").is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        let health = http_get(addr, "/health")?;
        println!("[e2e] /health -> {}", health.dump());
        let mut handles = Vec::new();
        for i in 0..n_requests {
            let prompt = prompts[i % prompts.len()].clone();
            let h = std::thread::spawn(move || {
                let mut body = Json::obj();
                body.set("prompt", prompt.as_str()).set("max_new", 24usize);
                match i % 3 {
                    0 => {}                                    // best effort
                    1 => { body.set("qos_ms_per_token", 120.0)
                               .set("deadline_ms", 2_000.0); } // EDF-admitted
                    _ => { body.set("target", 3.5); }          // pinned target
                }
                let t0 = std::time::Instant::now();
                let resp = http_post(addr, "/generate", &body.dump());
                resp.map(|mut j| {
                    j.set("client_ms", t0.elapsed().as_secs_f64() * 1e3);
                    j
                })
            });
            handles.push(h);
        }
        let mut out = Vec::new();
        for h in handles {
            out.push(h.join().unwrap()?);
        }
        let metrics = http_get(addr, "/metrics")?;
        println!("[e2e] /metrics -> {}", metrics.dump());
        stop.store(true, Ordering::Relaxed);
        Ok(out)
    });

    server.serve(addr)?;
    let responses = client.join().unwrap()?;

    // --- report ----------------------------------------------------------
    let lat: Vec<f64> = responses.iter()
        .filter_map(|j| j.f64_of("client_ms").ok()).collect();
    let tpot: Vec<f64> = responses.iter()
        .filter_map(|j| j.f64_of("tpot_ms").ok()).collect();
    let bits: Vec<f64> = responses.iter()
        .filter_map(|j| j.f64_of("effective_bits").ok()).collect();
    let toks: f64 = responses.iter()
        .filter_map(|j| j.f64_of("output_tokens").ok()).sum();
    println!("\n[e2e] {} requests completed over HTTP", responses.len());
    println!("[e2e] client latency p50/p90: {:.0}/{:.0} ms",
             percentile(&lat, 50.0), percentile(&lat, 90.0));
    println!("[e2e] mean TPOT {:.1} ms | mean effective bits {:.3}",
             mean(&tpot), mean(&bits));
    println!("[e2e] generated {toks} tokens total");
    for j in responses.iter().take(3) {
        println!("[e2e] sample: target {:.2} -> {:?}",
                 j.f64_of("target").unwrap_or(0.0),
                 j.str_of("text").unwrap_or_default().chars().take(48).collect::<String>());
    }

    // --- phase 3: precision-tiered fleet behind the router ---------------
    // Two engine replicas over ONE shared Arc<ModelAssets> (each thread
    // builds its own Runtime + ServingCore and materializes only its
    // slice of the ladder), with class routing: best-effort traffic to
    // the low-bit economy replica, tight-SLO to the high-bit premium
    // one.  DESIGN.md §Scale-out.
    println!("\n[e2e] phase 3: 2-replica fleet (economy 3.25/3.50 | \
              premium 4.50/4.75)");
    let fleet_addr = "127.0.0.1:8078";
    let assets = Arc::new(ModelAssets::load("dpl-tiny")?);
    let tiers: [&[&str]; 2] = [&["3.25", "3.50"], &["4.50", "4.75"]];
    let specs: Vec<ReplicaSpec> = tiers.iter().enumerate().map(|(i, tags)| {
        let targets: Vec<f64> =
            tags.iter().filter_map(|t| t.parse().ok()).collect();
        let cheapest = targets.iter().copied().fold(f64::INFINITY, f64::min);
        ReplicaSpec {
            id: i,
            model: "dpl-tiny".to_string(),
            budget: 5,
            tags: tags.iter().map(|t| t.to_string()).collect(),
            targets,
            premium: i == 1,
            tpot_ms: JETSON_ORIN.stream_ms(
                weight_bytes_at(&assets.store, cheapest)),
            core: dp_llm::coordinator::service::CoreConfig::default(),
            heartbeat_ms: 200,
        }
    }).collect();
    let spawn_assets = assets.clone();
    let router = Router::new(
        specs,
        Box::new(move |spec| engine_link(spec, spawn_assets.clone())),
        RouterConfig::default(),
    );
    let fleet = RouterServer::new(router);
    let fleet_stop = fleet.stop_handle();
    let fleet_prompts: Vec<String> =
        (0..4).map(|i| format!("A fleet request, number {i}.")).collect();
    let fleet_client = std::thread::spawn(move || -> anyhow::Result<()> {
        for _ in 0..200 {
            if http_get(fleet_addr, "/health").is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        for (i, p) in fleet_prompts.iter().enumerate() {
            let mut body = Json::obj();
            body.set("prompt", p.as_str()).set("max_new", 8usize);
            if i % 2 == 1 {
                // tight per-token budget + deadline -> premium tier
                body.set("qos_ms_per_token", 120.0)
                    .set("deadline_ms", 5_000.0);
            }
            let j = http_post(fleet_addr, "/generate", &body.dump())?;
            println!("[e2e]   fleet req {i}: replica {} target {:.2} \
                      ({} toks)",
                     j.f64_of("replica").unwrap_or(-1.0),
                     j.f64_of("target").unwrap_or(0.0),
                     j.f64_of("output_tokens").unwrap_or(0.0));
        }
        // The fleet /metrics adds the per-replica `replicas` array:
        // tier, queue depth, tokens/s EWMA, steals, respawns.
        let m = http_get(fleet_addr, "/metrics")?;
        println!("[e2e] fleet /metrics -> {}", m.dump());
        fleet_stop.store(true, Ordering::Relaxed);
        Ok(())
    });
    fleet.serve(fleet_addr)?;
    fleet_client.join().unwrap()?;

    println!("[e2e] OK — all three layers composed on the request path");
    Ok(())
}
