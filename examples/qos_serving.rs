//! QoS-adaptive serving (paper Fig. 1): a stream of queries with mixed
//! latency budgets meets fluctuating background utilization; the
//! coordinator picks the adaptation-set member whose predicted TPOT fits
//! the remaining slack, and DP-LLM keeps per-layer precision dynamic
//! inside each configuration.
//!
//!     cargo run --release --example qos_serving

use std::sync::Arc;

use dp_llm::coordinator::qos::{QosBudget, UtilizationSim};
use dp_llm::coordinator::router::{Router, RouterConfig, RouterEvent};
use dp_llm::coordinator::sched::{Request, SchedPolicy};
use dp_llm::coordinator::service::{make_queue, ServingEngine};
use dp_llm::evalharness::tasks;
use dp_llm::model::artifacts_available;
use dp_llm::runtime::replica::sim::{sim_link, SimProfile};
use dp_llm::runtime::replica::ReplicaSpec;
use dp_llm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let rt = Arc::new(Runtime::new()?);
    let mut engine = ServingEngine::load(&rt, "dpl-tiny", 5,
                                         &["3.25", "3.50", "4.00", "4.50", "4.75"])?;
    println!("adaptation set (target precision -> measured TPOT):");
    for (t, ms) in &engine.policy.options {
        println!("  {t:.2} bits -> {ms:.1} ms/token");
    }

    let prompts = tasks::load_task("instruct")?;
    let n = std::env::var("DPLLM_QOS_QUERIES")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(10usize);
    // Mixed QoS classes: a third best-effort, the rest with tightening
    // per-token budgets (EDF admission order).
    let reqs = (0..n).map(|i| {
        let p = &prompts[i % prompts.len()];
        let qos = match i % 3 {
            0 => QosBudget::best_effort(),
            1 => QosBudget::tight(250.0),
            _ => QosBudget::tight(60.0),
        };
        let r = Request::new(i as u64, p.prompt.clone(), 24, qos);
        if i % 3 == 2 { r.with_deadline(2_000.0) } else { r }
    });
    let mut queue = make_queue(SchedPolicy::Edf, reqs);
    let mut util = UtilizationSim::new(23, 0.6);

    // Token-interleaved EDF: requests are admitted mid-flight, decode steps
    // are deadline-ordered per token, and each generation's target
    // precision is re-selected mid-stream as utilization fluctuates.
    let outcomes = engine.run_queue(&mut queue, &mut util)?;
    println!("\nper-query outcomes (interleaved decode):");
    for o in &outcomes {
        println!(
            "  req {:>2}  target {:.2}  eff-bits {:.3}  tpot {:>6.1} ms  \
             ttft {:>6.0} ms  retargets {}  {} toks",
            o.id, o.target_precision, o.effective_bits,
            o.decode_ms / o.output_tokens.max(1) as f64,
            o.ttft_ms, o.retargets, o.output_tokens
        );
    }
    println!("\n{}", engine.metrics.summary().report());
    // One serialized counter snapshot (transfers + weight cache +
    // batching + speculation) — the same serializer behind GET /metrics.
    println!("{}", engine.counters_report());
    // Where device memory went: weight cache + paged KV pool budgets
    // and residency (DESIGN.md §Memory), same object as GET /metrics'
    // `memory` field.
    println!("memory: {}", engine.memory_json().dump());

    // The memory envelope tightens (another app claimed RAM): swap the
    // adaptation set for a leaner one.  Retired sessions are rebound in
    // place via the delta-materialization path — only layers whose bits
    // differ re-dequantize and re-upload (DESIGN.md §Perf).
    let rep = engine.reconfigure(&["3.25", "3.50", "3.75"])?;
    println!(
        "\nreconfigured adaptation set -> [3.25, 3.50, 3.75]: \
         {} stacks rebuilt, {} layers re-materialized",
        rep.stacks_rebuilt, rep.layers_changed
    );
    println!("{}", engine.counters_report());
    let mut tail = make_queue(
        SchedPolicy::Edf,
        (0..3usize).map(|i| {
            Request::new(1000 + i as u64, prompts[i % prompts.len()].prompt.clone(),
                         16, QosBudget::tight(120.0))
        }),
    );
    let mut util2 = UtilizationSim::new(29, 0.4);
    let outcomes = engine.run_queue(&mut tail, &mut util2)?;
    println!("post-reconfigure outcomes:");
    for o in &outcomes {
        println!("  req {:>4}  target {:.2}  eff-bits {:.3}  {} toks",
                 o.id, o.target_precision, o.effective_bits, o.output_tokens);
    }

    // --- fleet view: the same QoS classes over a 2-replica router --------
    // Scale-out happens one level above the engine: a precision-tiered
    // fleet routes best-effort traffic to a low-bit economy replica and
    // tight-SLO traffic to a high-bit premium one, stealing backlog when
    // one side idles (DESIGN.md §Scale-out).  Simulated workers keep
    // this section device-free; per-token time comes from the measured
    // adaptation-set TPOTs above.
    println!("\nfleet view (2 sim replicas, tiers 3.25/3.50 | 4.50/4.75):");
    let tpot_lo = engine.policy.options.first().map(|&(_, ms)| ms)
                        .unwrap_or(1.0);
    let tpot_hi = engine.policy.options.last().map(|&(_, ms)| ms)
                        .unwrap_or(2.0);
    let token_us = ((tpot_lo * 1000.0) as u64).clamp(50, 5_000);
    let specs = vec![
        ReplicaSpec::sim(0, &["3.25", "3.50"], false, tpot_lo),
        ReplicaSpec::sim(1, &["4.50", "4.75"], true, tpot_hi),
    ];
    let mut router = Router::new(
        specs,
        Box::new(move |spec| sim_link(spec, SimProfile {
            token_us, slots: 4, ..SimProfile::default()
        })),
        RouterConfig::default(),
    );
    let mut pending = 0usize;
    for i in 0..12u64 {
        let qos = if i % 3 == 0 { QosBudget::best_effort() }
                  else { QosBudget::tight(60.0) };
        let r = Request::new(2000 + i, format!("fleet query {i}"), 12, qos);
        let r = if i % 3 != 0 { r.with_deadline(5_000.0) } else { r };
        if router.submit(r, None).is_none() {
            pending += 1;
        }
    }
    while pending > 0 {
        for ev in router.poll() {
            match ev {
                RouterEvent::Done { replica, outcome } => {
                    pending -= 1;
                    println!(
                        "  req {:>4} -> replica {replica}  target {:.2}  \
                         {} toks",
                        outcome.id, outcome.target_precision,
                        outcome.output_tokens
                    );
                }
                RouterEvent::Failed { .. }
                | RouterEvent::Rejected { .. } => pending -= 1,
                RouterEvent::Respawned { .. } => {}
            }
        }
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    println!("fleet replicas: {}", router.replicas_json().dump());
    println!("fleet counters: {}", router.counters().json().dump());
    router.shutdown();
    Ok(())
}
