#!/usr/bin/env sh
# Tier-1 verification, mirroring .github/workflows/ci.yml:
#   sh ci.sh
# Artifact-backed integration tests run only when DPLLM_ARTIFACTS points at
# a `make artifacts` output tree; unset they skip, keeping this hermetic.
set -eu
ROOT="$(cd "$(dirname "$0")" && pwd)"
cd "$ROOT/rust"
cargo fmt --check
cargo clippy --all-targets -- -D warnings
cargo build --release
cargo test -q
# Rustdoc gate: the public API docs (crate + module + item docs, incl.
# intra-doc links) must keep compiling warning-free.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
# Prefill scheduling microbench: the artifact-free chunk-schedule sim
# always runs (and gates that the bench binary builds + executes); the
# TTFT/ITL serving comparison engages only when DPLLM_ARTIFACTS is set.
cargo bench --bench prefill_micro
# Paged-KV-pool microbench: fully artifact-free (drives the real pool
# accounting with a unit buffer type) — byte vs slot admission and
# shared-prefix savings; emits results/BENCH_kvpool.json.
cargo bench --bench kvpool_micro
# Router scale-out microbench: sim replica workers behind the REAL
# Router (class routing, work stealing, respawn) — throughput scaling
# at 1/2/4 replicas + a chaos run; emits results/BENCH_router.json.
cargo bench --bench router_micro
# Trace-driven serving bench: seed-pinned Poisson/bursty/diurnal traces
# (long-tail lengths, mixed SLO classes) replayed through sim fleets of
# 1/2/4 replicas behind the real Router — goodput, per-class SLO
# attainment, TTFT/ITL tails, Jain fairness; every cell schema-checked;
# emits results/BENCH_serving_trace.json.  The real-engine cell engages
# only when DPLLM_ARTIFACTS is set.
cargo bench --bench serving_trace
# Observability microbench: flight-recorder record cost (disabled path
# bar ~25 ns/event, exact drop accounting), histogram record/merge cost,
# and the Chrome trace emit path validated by parsing back through
# util::json; emits results/BENCH_obs.json (schema-checked pre-write).
cargo bench --bench obs_micro
# Cold-start microbench: packed DPAK container (verify+mmap, zero
# plane-byte copies — asserted) vs legacy npz (parse+copy) on a
# synthetic store, plus tier-sliced residency at 3/4/6 bits; emits
# results/BENCH_coldstart.json (schema-checked pre-write).
cargo bench --bench coldstart_micro
# Python L2 gate: the jax-level parity tests (incl. the speculative
# verify_step_g* vs sequential-decode contract) run whenever a python
# with jax + pytest is available; a cargo-only environment skips them so
# tier-1 stays hermetic.
if command -v python3 >/dev/null 2>&1 \
    && python3 -c "import jax, pytest" >/dev/null 2>&1; then
  (cd "$ROOT/python" && python3 -m pytest tests -q)
else
  echo "[ci] python/jax unavailable — skipping the L2 pytest gate"
fi
