#!/usr/bin/env sh
# Tier-1 verification, mirroring .github/workflows/ci.yml:
#   sh ci.sh
# Artifact-backed integration tests run only when DPLLM_ARTIFACTS points at
# a `make artifacts` output tree; unset they skip, keeping this hermetic.
set -eu
cd "$(dirname "$0")/rust"
cargo fmt --check
cargo clippy --all-targets -- -D warnings
cargo build --release
cargo test -q
# Rustdoc gate: the public API docs (crate + module + item docs, incl.
# intra-doc links) must keep compiling warning-free.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
