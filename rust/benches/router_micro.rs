//! Multi-replica router microbench (DESIGN.md §Scale-out).
//!
//! Entirely artifact-free: simulated replica workers (fixed per-token
//! service time, bounded active slots) behind the REAL [`Router`] —
//! class routing, queue-depth dispatch, work stealing, capacity retries
//! and respawn all run the production code paths; only the decode loop
//! is simulated.  No device, no model, so it runs in every CI.
//!
//! Part 1 — saturation throughput scaling: 120 requests (16 tokens
//! each) submitted upfront over fleets of {1, 2, 4} replicas × class
//! mixes {balanced, premium-heavy, economy-heavy}.  Reported per cell:
//! aggregate tokens/s, speedup vs the 1-replica fleet of the same mix,
//! p99 queue delay (time-to-first-token minus the simulated service
//! time) and steal count.  The acceptance bar is ≥ 1.5× tokens/s for
//! 2 replicas vs 1 at saturation.
//!
//! Part 2 — chaos: one replica panics mid-run; the fleet must finish
//! every healthy request, report the respawn, and keep both classes
//! flowing.
//!
//! Results land in `results/BENCH_router.json`.

use std::time::{Duration, Instant};

use dp_llm::bench_support as bs;
use dp_llm::coordinator::router::{Router, RouterConfig, RouterEvent};
use dp_llm::coordinator::sched::Request;
use dp_llm::coordinator::QosBudget;
use dp_llm::runtime::replica::sim::{sim_link, SimProfile};
use dp_llm::runtime::replica::ReplicaSpec;
use dp_llm::util::json::Json;
use dp_llm::util::stats;

/// Simulated per-token service time of one replica round.
const TOKEN_US: u64 = 200;
/// Active-generation slots per replica (the sim's `max_active`).
const SLOTS: usize = 4;
const N_REQUESTS: usize = 120;
const MAX_NEW: usize = 16;

/// A fleet of `n` sim replicas: lower half economy (low-bit slice),
/// upper half premium (high-bit slice) — the same tiering the CLI
/// builds for `--replicas n`.
fn fleet(n: usize, profile: SimProfile) -> Router {
    let specs: Vec<ReplicaSpec> = (0..n)
        .map(|i| {
            let premium = i >= n / 2 && n > 1;
            let tags: &[&str] = if premium {
                &["4.50", "4.75"]
            } else {
                &["3.25", "3.50"]
            };
            ReplicaSpec::sim(i, tags, premium, TOKEN_US as f64 / 1000.0)
        })
        .collect();
    Router::new(
        specs,
        Box::new(move |spec| sim_link(spec, profile.clone())),
        RouterConfig::default(),
    )
}

/// Deterministic request mix: request i is premium (tight per-token
/// budget + deadline) when `(i * 7919) % 100` falls under the premium
/// percentage.
fn requests(premium_pct: usize) -> Vec<Request> {
    (0..N_REQUESTS as u64)
        .map(|i| {
            let premium = (i * 7919) % 100 < premium_pct as u64;
            let qos = if premium {
                QosBudget::tight(5.0)
            } else {
                QosBudget::best_effort()
            };
            let r = Request::new(i, format!("bench prompt {i}"), MAX_NEW, qos);
            if premium { r.with_deadline(10_000.0) } else { r }
        })
        .collect()
}

struct Cell {
    replicas: usize,
    mix: &'static str,
    premium_pct: usize,
    tokens_per_s: f64,
    p99_queue_ms: f64,
    steals: u64,
    retries: u64,
    completed: usize,
}

/// Submit every request upfront (saturation), then poll the router to
/// completion.  Returns the measured cell.
fn run_cell(n: usize, mix: &'static str, premium_pct: usize) -> Cell {
    let mut router = fleet(n, SimProfile { token_us: TOKEN_US, slots: SLOTS,
                                           ..SimProfile::default() });
    let reqs = requests(premium_pct);
    let start = Instant::now();
    let mut terminal = 0usize;
    let mut queue_ms: Vec<f64> = Vec::with_capacity(N_REQUESTS);
    let mut tokens = 0usize;
    for r in reqs {
        if router.submit(r, None).is_some() {
            terminal += 1; // immediate reject (should not happen here)
        }
    }
    let deadline = start + Duration::from_secs(30);
    while terminal < N_REQUESTS {
        assert!(Instant::now() < deadline, "router bench wedged");
        for ev in router.poll() {
            match ev {
                RouterEvent::Done { outcome, .. } => {
                    terminal += 1;
                    tokens += outcome.output_tokens;
                    // Queue delay = TTFT minus one simulated service
                    // round (the token the replica actually computed).
                    queue_ms
                        .push((outcome.ttft_ms - TOKEN_US as f64 / 1000.0)
                              .max(0.0));
                }
                RouterEvent::Failed { .. } | RouterEvent::Rejected { .. } => {
                    terminal += 1;
                }
                RouterEvent::Respawned { .. } => {}
            }
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    let elapsed = start.elapsed().as_secs_f64();
    router.shutdown();
    let p99 = stats::percentile_nearest_rank(&queue_ms, 99.0).unwrap_or(0.0);
    let c = router.counters();
    Cell {
        replicas: n,
        mix,
        premium_pct,
        tokens_per_s: tokens as f64 / elapsed.max(1e-9),
        p99_queue_ms: p99,
        steals: c.steals,
        retries: c.retries,
        completed: queue_ms.len(),
    }
}

/// Chaos run: replica 0 panics mid-run; the fleet must still finish
/// every request (completed or capacity-rejected died-inflight work)
/// and respawn the dead worker.
fn run_chaos() -> (usize, usize, u64) {
    let n = 2usize;
    let mut router = fleet(
        n,
        SimProfile {
            token_us: TOKEN_US,
            slots: SLOTS,
            panic_after_tokens: Some((N_REQUESTS * MAX_NEW / 8) as u64),
            ..SimProfile::default()
        },
    );
    let start = Instant::now();
    let (mut done, mut rejected, mut respawns) = (0usize, 0usize, 0u64);
    for r in requests(50) {
        if router.submit(r, None).is_some() {
            rejected += 1;
        }
    }
    let deadline = start + Duration::from_secs(30);
    while done + rejected < N_REQUESTS {
        assert!(Instant::now() < deadline, "chaos bench wedged");
        for ev in router.poll() {
            match ev {
                RouterEvent::Done { .. } => done += 1,
                RouterEvent::Failed { .. } | RouterEvent::Rejected { .. } => {
                    rejected += 1;
                }
                RouterEvent::Respawned { .. } => respawns += 1,
            }
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    router.shutdown();
    (done, rejected, respawns)
}

fn main() {
    let mixes: [(&str, usize); 3] =
        [("balanced", 50), ("premium-heavy", 80), ("economy-heavy", 20)];
    let counts = [1usize, 2, 4];

    let mut rows = Vec::new();
    let mut cells: Vec<Cell> = Vec::new();
    for &(mix, pct) in &mixes {
        for &n in &counts {
            cells.push(run_cell(n, mix, pct));
        }
    }

    println!(
        "router saturation: {N_REQUESTS} reqs x {MAX_NEW} toks, sim \
         {TOKEN_US} us/token x {SLOTS} slots per replica:"
    );
    let mut json_rows = Vec::new();
    let mut speedup_2x_balanced = 0.0f64;
    for c in &cells {
        let base = cells
            .iter()
            .find(|b| b.replicas == 1 && b.mix == c.mix)
            .map(|b| b.tokens_per_s)
            .unwrap_or(c.tokens_per_s);
        let speedup = c.tokens_per_s / base.max(1e-9);
        if c.mix == "balanced" && c.replicas == 2 {
            speedup_2x_balanced = speedup;
        }
        println!(
            "  {:>13} x{}: {:8.0} tok/s ({speedup:4.2}x), p99 queue \
             {:7.2} ms, steals {:>3}, retries {}",
            c.mix, c.replicas, c.tokens_per_s, c.p99_queue_ms, c.steals,
            c.retries
        );
        let mut o = Json::obj();
        o.set("replicas", c.replicas)
            .set("mix", c.mix)
            .set("premium_pct", c.premium_pct)
            .set("tokens_per_s", c.tokens_per_s)
            .set("speedup_vs_1", speedup)
            .set("p99_queue_ms", c.p99_queue_ms)
            .set("steals", c.steals as i64)
            .set("retries", c.retries as i64)
            .set("completed", c.completed);
        json_rows.push(o);
        rows.push(vec![
            format!("{} x{}", c.mix, c.replicas),
            format!("{:.0} tok/s ({speedup:.2}x), p99 {:.2} ms",
                    c.tokens_per_s, c.p99_queue_ms),
        ]);
    }
    println!(
        "  acceptance: 2-replica balanced speedup {speedup_2x_balanced:.2}x \
         (bar: >= 1.50x)"
    );

    let (done, rejected, respawns) = run_chaos();
    println!(
        "chaos: replica 0 panics mid-run -> {done} done, {rejected} \
         rejected, {respawns} respawn(s); every request terminal"
    );
    rows.push(vec![
        "chaos: panic mid-run".into(),
        format!("{done} done / {rejected} rejected, {respawns} respawn(s)"),
    ]);

    let mut chaos = Json::obj();
    chaos
        .set("done", done)
        .set("rejected", rejected)
        .set("respawns", respawns as i64);

    let mut j = Json::obj();
    j.set("bench", "router");
    j.set("requests", N_REQUESTS);
    j.set("max_new", MAX_NEW);
    j.set("token_us", TOKEN_US as i64);
    j.set("slots", SLOTS);
    j.set("speedup_2x_balanced", speedup_2x_balanced);
    j.set("cells", Json::Arr(json_rows));
    j.set("chaos", chaos);
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_router.json", j.dump());
    println!("wrote results/BENCH_router.json");

    bs::emit("router_micro",
             "Precision-tiered router over N sim replicas",
             &["case", "value"], &rows);
}
