//! Observability microbench (DESIGN.md §Observability).
//!
//! Entirely artifact-free: measures the cost of the flight recorder and
//! the log2 latency histograms — the two hot-path primitives every
//! request crosses — plus the Chrome trace-event emit path.
//!
//! Part 1 — record cost: ns/event with the tracer disabled (the price
//! every production dispatch pays when tracing is off — the bar is
//! ~25 ns, one relaxed atomic load + branch) and enabled (thread-local
//! ring push).  The overwrite-oldest ring's drop counter is asserted
//! EXACT: a 256-slot ring fed 1000 events must report 744 drops.
//!
//! Part 2 — histogram cost: ns per `record_ms` and per 2-class
//! 3-family merge (allocation-free fixed arrays).
//!
//! Part 3 — trace export: a 10k-event synthetic run emitted as Chrome
//! trace JSON and parsed back through `util::json::Json` (the same
//! validation `GET /trace` consumers rely on).
//!
//! Results land in `results/BENCH_obs.json` (schema-checked before the
//! write, like `serving_trace`).

use std::hint::black_box;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use dp_llm::bench_support as bs;
use dp_llm::obs::{EventKind, HistogramSet, SloClass, Tracer};
use dp_llm::util::json::Json;

/// Events per timing loop — large enough to amortize the Instant reads.
const N: u64 = 1_000_000;

fn event_for(i: u64) -> EventKind {
    match i % 4 {
        0 => EventKind::Admit { id: i, target_mb: 4000, queue_us: i % 977 },
        1 => EventKind::FirstToken { id: i, ttft_us: 100 + i % 4096 },
        2 => EventKind::Reselect {
            id: i,
            from_mb: 4000,
            to_mb: 3500,
            layers_changed: (i % 7) as u32,
            eff_delta_mb: -((i % 300) as i32),
        },
        _ => EventKind::Done { id: i, tokens: 16, eff_mb: 3600 },
    }
}

/// ns/event over `n` records against `t` (enabled or disabled).
fn record_ns(t: &Tracer, n: u64) -> f64 {
    let start = Instant::now();
    for i in 0..n {
        t.record(black_box(event_for(i)));
    }
    start.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    // Part 1 — record cost, disabled vs enabled.
    let off = Tracer::new(4096);
    let disabled_ns = record_ns(&off, N);
    let snap = off.snapshot();
    assert_eq!(snap.events.len(), 0, "disabled tracer recorded");
    assert_eq!(snap.dropped, 0);

    let on = Tracer::new(4096);
    on.set_enabled(true);
    let enabled_ns = record_ns(&on, N);

    // Exact drop accounting: 1000 events through a 256-slot ring.
    let small = Tracer::new(256);
    small.set_enabled(true);
    for i in 0..1000u64 {
        small.record(event_for(i));
    }
    let snap = small.drain();
    assert_eq!(snap.events.len(), 256, "ring kept exactly its capacity");
    assert_eq!(snap.dropped, 744, "drop counter must be exact");

    println!(
        "trace record: disabled {disabled_ns:.1} ns/event (bar: ~25 ns), \
         enabled {enabled_ns:.1} ns/event; drops exact (744/1000 @ cap 256)"
    );

    // Part 2 — histogram record + merge.
    let mut h = HistogramSet::new();
    let start = Instant::now();
    for i in 0..N {
        let class = SloClass::from_premium(i % 3 == 0);
        let ms = (i % 2048) as f64 / 7.0;
        h.record(class, black_box(ms), ms / 16.0, ms / 4.0);
    }
    let hist_record_ns = start.elapsed().as_nanos() as f64 / N as f64;

    let mut acc = HistogramSet::new();
    const MERGES: u64 = 100_000;
    let start = Instant::now();
    for _ in 0..MERGES {
        acc.merge(black_box(&h));
    }
    let hist_merge_ns = start.elapsed().as_nanos() as f64 / MERGES as f64;
    let p99 = {
        let j = h.json();
        j.get("economy").unwrap().f64_of("ttft_ms_p99").unwrap()
    };
    println!(
        "histogram: record {hist_record_ns:.1} ns (3 families), merge \
         {hist_merge_ns:.1} ns (2 classes x 3 families), economy ttft \
         p99 {p99:.1} ms"
    );

    // Part 3 — Chrome trace emit for a 10k-event synthetic run,
    // validated by parsing back through util::json.
    const EVENTS: u64 = 10_000;
    let t = Tracer::new(EVENTS as usize + 16);
    t.set_enabled(true);
    for i in 0..EVENTS {
        t.record(event_for(i));
    }
    let start = Instant::now();
    let dump = t.snapshot().chrome_json().dump();
    let emit_ms = start.elapsed().as_secs_f64() * 1e3;
    let parsed = Json::parse(&dump).expect("chrome trace JSON parses");
    let rows = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // Every recorded event plus the process-name metadata records.
    assert!(rows.len() >= EVENTS as usize, "trace rows lost in emit");
    assert_eq!(parsed.f64_of("dropped").unwrap(), 0.0);
    println!(
        "chrome emit: {} events -> {:.0} KiB JSON in {emit_ms:.1} ms, \
         parses back",
        rows.len(),
        dump.len() as f64 / 1024.0
    );

    let mut j = Json::obj();
    j.set("bench", "obs")
        .set("events_per_loop", N as i64)
        .set("record_disabled_ns", disabled_ns)
        .set("record_enabled_ns", enabled_ns)
        .set("disabled_bar_ns", 25.0)
        .set("hist_record_ns", hist_record_ns)
        .set("hist_merge_ns", hist_merge_ns)
        .set("chrome_events", rows.len())
        .set("chrome_emit_ms", emit_ms);
    schema_check(&j).expect("obs bench schema");
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_obs.json", j.dump());
    println!("wrote results/BENCH_obs.json");

    bs::emit(
        "obs_micro",
        "Flight recorder + histogram hot-path cost",
        &["case", "value"],
        &[
            vec!["record (disabled)".into(),
                 format!("{disabled_ns:.1} ns/event (bar ~25 ns)")],
            vec!["record (enabled)".into(),
                 format!("{enabled_ns:.1} ns/event")],
            vec!["ring drops".into(), "exact (744/1000 @ cap 256)".into()],
            vec!["histogram record".into(),
                 format!("{hist_record_ns:.1} ns (3 families)")],
            vec!["histogram merge".into(),
                 format!("{hist_merge_ns:.1} ns (full set)")],
            vec!["chrome emit (10k)".into(), format!("{emit_ms:.1} ms")],
        ],
    );
}

/// Pre-write schema gate (the `serving_trace` idiom): every required
/// key present and finite, so a broken emitter fails the bench instead
/// of writing garbage into `results/BENCH_obs.json`.
fn schema_check(j: &Json) -> Result<()> {
    j.req("bench")?.as_str().context("bench")?;
    for key in [
        "events_per_loop",
        "record_disabled_ns",
        "record_enabled_ns",
        "disabled_bar_ns",
        "hist_record_ns",
        "hist_merge_ns",
        "chrome_events",
        "chrome_emit_ms",
    ] {
        let v = j.req(key)?.as_f64().with_context(|| key.to_string())?;
        if !v.is_finite() {
            bail!("obs schema: {key} = {v} not finite");
        }
        if v < 0.0 {
            bail!("obs schema: {key} = {v} negative");
        }
    }
    Ok(())
}
