//! Trace-driven serving bench (DESIGN.md §Evaluation): the end-to-end
//! measurement every earlier bench deferred — goodput, per-class SLO
//! attainment, p50/p99/p999 TTFT + ITL, and Jain fairness under
//! realistic query streams.
//!
//! Artifact-free grid: {Poisson, bursty MMPP, diurnal} arrival models ×
//! {1, 2, 4}-replica fleets of simulated workers behind the REAL
//! [`Router`] (same sim idiom as `router_micro`), replaying a
//! seed-pinned long-tail trace of `N_REQUESTS` per cell at `TIME_SCALE`
//! compression — tens of thousands of requests total, runs in every CI.
//! Artifact-gated cell: the same trace machinery through a real
//! single-engine `ServingCore` when `DPLLM_ARTIFACTS` is set.
//!
//! Every cell is schema-checked (`loadgen::schema_check`) before
//! anything is written; results land in
//! `results/BENCH_serving_trace.json`.

use std::time::Duration;

use dp_llm::bench_support as bs;
use dp_llm::coordinator::loadgen::{
    self, replay_fleet, ArrivalProcess, ReplayOpts, TraceReport, TraceSpec,
};
use dp_llm::coordinator::router::{Router, RouterConfig};
use dp_llm::runtime::replica::sim::{sim_link, SimProfile};
use dp_llm::runtime::replica::ReplicaSpec;
use dp_llm::util::json::Json;

/// Simulated per-token service time of one replica round.
const TOKEN_US: u64 = 50;
/// Active-generation slots per sim replica.
const SLOTS: usize = 8;
/// Requests per grid cell (9 cells → 22.5k replayed requests).
const N_REQUESTS: usize = 2500;
const MAX_SEQ: usize = 512;
const MAX_NEW: usize = 16;
/// Trace-time compression: 0.005 turns a ~100 req/s trace into ~20k
/// req/s offered load — one sim replica saturates, four do not, so the
/// grid shows both regimes.
const TIME_SCALE: f64 = 0.005;
const SEED: u64 = 20250808;

fn arrival_models() -> Vec<ArrivalProcess> {
    vec![
        ArrivalProcess::Poisson { rate_per_s: 100.0 },
        ArrivalProcess::Bursty {
            rate_on: 250.0,
            rate_off: 20.0,
            mean_on_s: 2.0,
            mean_off_s: 2.0,
        },
        ArrivalProcess::Diurnal {
            base_per_s: 100.0,
            amplitude: 0.8,
            period_s: 20.0,
        },
    ]
}

/// Same tiering as `router_micro` / `--replicas n`: lower half economy,
/// upper half premium.
fn fleet(n: usize) -> Router {
    let specs: Vec<ReplicaSpec> = (0..n)
        .map(|i| {
            let premium = i >= n / 2 && n > 1;
            let tags: &[&str] = if premium {
                &["4.50", "4.75"]
            } else {
                &["3.25", "3.50"]
            };
            ReplicaSpec::sim(i, tags, premium, TOKEN_US as f64 / 1e3)
        })
        .collect();
    Router::new(
        specs,
        Box::new(|spec| {
            sim_link(
                spec,
                SimProfile {
                    token_us: TOKEN_US,
                    slots: SLOTS,
                    ..SimProfile::default()
                },
            )
        }),
        RouterConfig::default(),
    )
}

/// The mixed-SLO spec with metering thresholds rescaled to sim service
/// times (sim ITL is 0.05 ms, so the production 250/60 ms ITL budgets
/// would never discriminate — TTFT under queueing is where sim cells
/// differ).
fn spec_for(arrival: ArrivalProcess) -> TraceSpec {
    let mut spec = TraceSpec::mixed(arrival, MAX_SEQ, MAX_NEW);
    spec.classes[1].slo_ttft_ms = 25.0;
    spec.classes[2].slo_ttft_ms = 10.0;
    spec
}

fn run_cell(arrival: ArrivalProcess, replicas: usize) -> TraceReport {
    let trace = spec_for(arrival)
        .generate(N_REQUESTS, SEED)
        .expect("trace generation");
    let mut router = fleet(replicas);
    let report = replay_fleet(
        &trace,
        &mut router,
        &ReplayOpts {
            time_scale: TIME_SCALE,
            deadline: Duration::from_secs(30),
        },
    );
    router.shutdown();
    assert_eq!(
        report.lost, 0,
        "{} x{replicas}: requests without terminal outcome",
        arrival.name()
    );
    report
}

/// Artifact-gated: the identical trace machinery through one real
/// engine-backed `ServingCore`.  `None` when artifacts are missing.
fn run_engine_cell() -> Option<TraceReport> {
    use dp_llm::coordinator::loadgen::replay_core;
    use dp_llm::coordinator::sched::SchedPolicy;
    use dp_llm::coordinator::service::{ServingCore, ServingEngine};
    use dp_llm::coordinator::UtilizationSim;
    use dp_llm::runtime::Runtime;
    use std::sync::Arc;

    if !bs::require_artifacts("serving_trace") {
        return None;
    }
    let rt = Arc::new(Runtime::new().ok()?);
    let engine = match ServingEngine::load(&rt, "dpl-tiny", 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            println!("[serving_trace] engine load failed ({e:#}); skipping");
            return None;
        }
    };
    let mut core = ServingCore::new(&engine, SchedPolicy::Edf);
    let mut util = UtilizationSim::constant(0.3);
    let trace = spec_for(ArrivalProcess::Poisson { rate_per_s: 20.0 })
        .generate(40, SEED)
        .expect("engine trace");
    let report = replay_core(
        &trace,
        &mut core,
        &mut util,
        &ReplayOpts {
            time_scale: 0.05,
            deadline: Duration::from_secs(120),
        },
    );
    Some(report)
}

fn main() {
    let fleets = [1usize, 2, 4];
    let models = arrival_models();

    println!(
        "serving_trace: {N_REQUESTS} reqs/cell, sim {TOKEN_US} us/token x \
         {SLOTS} slots, time_scale {TIME_SCALE} (offered load ~1/scale):"
    );
    let mut rows = Vec::new();
    let mut cells = Vec::new();
    for arrival in &models {
        for &n in &fleets {
            let r = run_cell(*arrival, n);
            println!(
                "  {:>7} x{}: goodput {:8.0} tok/s (of {:8.0}), attain \
                 {:5.3}, ttft p99 {:7.2} ms (premium p999 {:7.2}), jain \
                 {:5.3}",
                r.arrival,
                n,
                r.goodput_tok_s,
                r.throughput_tok_s,
                r.slo_attainment,
                r.classes
                    .iter()
                    .filter_map(|c| c.ttft.map(|t| t.p99))
                    .fold(0.0f64, f64::max),
                r.classes
                    .last()
                    .and_then(|c| c.ttft.map(|t| t.p999))
                    .unwrap_or(0.0),
                r.jain_fairness,
            );
            rows.push(vec![
                format!("{} x{}", r.arrival, n),
                format!(
                    "goodput {:.0}/{:.0} tok/s, attain {:.3}, jain {:.3}",
                    r.goodput_tok_s,
                    r.throughput_tok_s,
                    r.slo_attainment,
                    r.jain_fairness
                ),
            ]);
            cells.push(r);
        }
    }

    // Emitter self-gate: every cell must pass the schema check BEFORE
    // anything lands in results/ — a broken emitter fails CI here.
    let mut json_cells = Vec::with_capacity(cells.len());
    for r in &cells {
        let j = r.to_json();
        loadgen::schema_check(&j).expect("serving_trace cell schema");
        json_cells.push(j);
    }

    let engine_cell = run_engine_cell();

    let mut j = Json::obj();
    j.set("bench", "serving_trace")
        .set("requests_per_cell", N_REQUESTS)
        .set("token_us", TOKEN_US as i64)
        .set("slots", SLOTS)
        .set("time_scale", TIME_SCALE)
        .set("max_seq", MAX_SEQ)
        .set("max_new", MAX_NEW)
        .set("seed", SEED as i64)
        .set("cells", Json::Arr(json_cells));
    if let Some(r) = &engine_cell {
        let cell = r.to_json();
        loadgen::schema_check(&cell).expect("engine cell schema");
        println!(
            "  engine x1: goodput {:.1} tok/s, attain {:.3} (real \
             ServingCore, 40 reqs)",
            r.goodput_tok_s, r.slo_attainment
        );
        rows.push(vec![
            "engine x1 (artifact-gated)".into(),
            format!(
                "goodput {:.1} tok/s, attain {:.3}",
                r.goodput_tok_s, r.slo_attainment
            ),
        ]);
        j.set("engine_cell", cell);
    }

    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_serving_trace.json", j.dump());
    println!("wrote results/BENCH_serving_trace.json");

    bs::emit(
        "serving_trace",
        "Trace-driven serving: goodput / SLO attainment / tails / fairness",
        &["cell", "value"],
        &rows,
    );
}
