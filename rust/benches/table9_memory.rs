//! Table 9: memory overhead of DP-LLM's estimators — total estimator
//! capacity (JL G matrices + linear-fit scalars) across all supported
//! target precisions, relative to the quantized model capacity at the
//! 5-bit budget.  Expected: low single-digit percent.

use dp_llm::bench_support as bs;
use dp_llm::model::calib::DpllmConfig;
use dp_llm::model::ModelAssets;

fn main() {
    if !bs::require_artifacts("table9") {
        return;
    }
    let mut rows = Vec::new();
    for model in bs::headline_models() {
        if !bs::model_available(model) {
            continue;
        }
        let assets = ModelAssets::load(model).unwrap();
        let model_bytes = assets.store.capacity_bytes(5) as f64;
        let mut total = 0usize;
        let mut per_target = Vec::new();
        for t in bs::targets_for_budget(5) {
            if let Ok(dp) = DpllmConfig::load(model, 5, &format!("{t:.2}")) {
                let b = dp.estimator_bytes(&assets.cfg);
                per_target.push(b);
                total += b;
            }
        }
        if per_target.is_empty() {
            continue;
        }
        let avg = per_target.iter().sum::<usize>() as f64 / per_target.len() as f64;
        rows.push(vec![
            model.to_string(),
            format!("{:.2} MB", model_bytes / 1e6),
            format!("{:.3} MB", avg / 1e6),
            format!("{:.3} MB", total as f64 / 1e6),
            format!("{:.2}%", total as f64 / model_bytes * 100.0),
        ]);
    }
    bs::emit("table9", "Table 9 — estimator memory overhead (5-bit budget)",
             &["model", "quantized capacity", "avg estimator/target",
               "total estimators", "overhead"],
             &rows);
}
