//! Paged-KV-pool admission microbench (DESIGN.md §Memory).
//!
//! Entirely artifact-free: both parts drive the REAL [`KvPool`]
//! accounting (charge / migrate_charge / release / prefix cache) with a
//! unit buffer type — no device, no model, so it runs in every CI.
//!
//! Part 1 — byte-based admission vs worst-case slot admission at the
//! SAME byte budget (4 × max_seq worth of KV), on a short-request-
//! dominated workload (90% of requests finish under the 128-token base
//! tier, 10% run to ~max_seq).  Slot admission reserves max_seq bytes
//! per request from birth; tier admission charges the smallest covering
//! tier and grows by ladder migration, so short requests stop paying for
//! KV they never touch.  Reported: mean admitted concurrency and
//! makespan for each policy — the acceptance bar is ≥ 2× concurrency
//! for the tiered pool.
//!
//! Part 2 — shared-prefix prefill savings at shared ratios {0, 0.5,
//! 0.9}: N requests with a 300-token prompt, chunked at 128, so each
//! cold prefill costs 3 chunk dispatches and a prefix hit (quantized to
//! 256 tokens) saves 2 of them.  Uses the pool's real prefix cache
//! (first-writer-wins insert, quantized lookup).  Reported: chunk
//! dispatches with/without the cache and the saved fraction of
//! *prefix* chunks for the sharing group — (N−1)/N when every sharer
//! hits.
//!
//! Results land in `results/BENCH_kvpool.json`.

use dp_llm::bench_support as bs;
use dp_llm::runtime::kvpool::{self, KvPool, BASE_TIER};
use dp_llm::util::json::Json;

/// dpl-tiny KV byte cost of one sequence position:
/// n_layers(8) · 2 · n_heads(8) · head_dim(32) · 4 B.
const BYTES_PER_TOKEN: usize = 8 * 2 * 8 * 32 * 4;
const MAX_SEQ: usize = 640;
/// Budget = worst-case KV of this many concurrent requests.
const BUDGET_SLOTS: usize = 4;
const N_REQUESTS: usize = 200;

/// Deterministic short-dominated workload: request i's total sequence
/// length (prompt + output).  Every 10th request is long (~max_seq);
/// the rest finish inside the base tier.
fn req_len(i: usize) -> usize {
    if i % 10 == 4 { 600 } else { 48 + (i * 13) % 80 }
}

struct Active {
    len: usize,
    pos: usize,
    tier: usize,
}

/// Discrete-time serving sim against the real pool accounting: one
/// token per active request per step, admission refills from the queue
/// each step, tier requests migrate up the ladder on overflow (stalling
/// one step when the pool is too full to grow — backpressure, not
/// failure).  Returns (mean concurrency, makespan steps, peak in_use).
fn run_sim(tiered: bool) -> (f64, usize, usize) {
    let budget = BUDGET_SLOTS * MAX_SEQ * BYTES_PER_TOKEN;
    let ladder = kvpool::tier_ladder(MAX_SEQ, BASE_TIER);
    let mut pool: KvPool<()> = KvPool::new(budget, BYTES_PER_TOKEN);
    let mut next = 0usize;
    let mut active: Vec<Active> = Vec::new();
    let mut steps = 0usize;
    let mut occupancy_sum = 0usize;
    let mut peak = 0usize;
    while next < N_REQUESTS || !active.is_empty() {
        // Admission: smallest covering tier (tiered) or max_seq (slots).
        while next < N_REQUESTS {
            let len = req_len(next);
            let birth = if tiered {
                kvpool::tier_for(&ladder, len.min(BASE_TIER)).unwrap_or(MAX_SEQ)
            } else {
                MAX_SEQ
            };
            if pool.charge(birth).is_err() {
                break;
            }
            active.push(Active { len, pos: 0, tier: birth });
            next += 1;
        }
        steps += 1;
        occupancy_sum += active.len();
        peak = peak.max(pool.in_use_bytes());
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            if a.pos >= a.tier && a.tier < MAX_SEQ {
                // Ladder migration; a full pool stalls the request one
                // step instead of failing it.
                let to = kvpool::tier_for(&ladder, a.pos + 1).unwrap_or(MAX_SEQ);
                if pool.migrate_charge(a.tier, to).is_err() {
                    i += 1;
                    continue;
                }
                a.tier = to;
            }
            a.pos += 1;
            if a.pos >= a.len {
                pool.release(a.tier, Some(()));
                active.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }
    (occupancy_sum as f64 / steps.max(1) as f64, steps, peak)
}

/// Shared-prefix prefill sim at one sharing ratio: returns (dispatches
/// with cache, dispatches without, prefix hits, saved prefix-chunk
/// fraction within the sharing group).
fn run_prefix(n: usize, ratio: f64) -> (usize, usize, usize, f64) {
    let budget = BUDGET_SLOTS * MAX_SEQ * BYTES_PER_TOKEN;
    let ladder = kvpool::tier_ladder(MAX_SEQ, BASE_TIER);
    let mut pool: KvPool<()> = KvPool::new(budget, BYTES_PER_TOKEN);
    let chunk = 128usize;
    let prompt_len = 300usize;
    let total_chunks = (prompt_len + chunk - 1) / chunk;
    let n_shared = (n as f64 * ratio).round() as usize;
    let shared_ids = vec![7u32; prompt_len];
    let tier = kvpool::tier_for(&ladder, prompt_len).unwrap_or(MAX_SEQ);

    let (mut with_cache, mut hits) = (0usize, 0usize);
    for i in 0..n {
        let ids = if i < n_shared {
            shared_ids.clone()
        } else {
            let mut u = shared_ids.clone();
            u[0] = 1000 + i as u32; // unique head -> distinct prefix key
            u
        };
        if let Some(hit) = pool.prefix_lookup("m:4.00", &ids, chunk) {
            hits += 1;
            with_cache += total_chunks - hit.len / chunk;
            continue;
        }
        with_cache += total_chunks;
        if let Some(q) = kvpool::prefix_quantize(prompt_len, chunk) {
            pool.prefix_insert("m:4.00", &ids, q, tier,
                               std::rc::Rc::new(()));
        }
    }
    let without = n * total_chunks;
    let q_chunks = kvpool::prefix_quantize(prompt_len, chunk).unwrap() / chunk;
    let saved_shared = if n_shared > 1 {
        ((n_shared - 1) * q_chunks) as f64 / (n_shared * q_chunks) as f64
    } else {
        0.0
    };
    (with_cache, without, hits, saved_shared)
}

fn main() {
    let mut rows = Vec::new();

    // ---- Part 1: byte-based vs slot-based admission -----------------------
    let (slot_conc, slot_steps, slot_peak) = run_sim(false);
    let (tier_conc, tier_steps, tier_peak) = run_sim(true);
    let speedup = tier_conc / slot_conc.max(1e-9);
    println!(
        "admission @ {} B budget ({BUDGET_SLOTS} max-seq slots), \
         {N_REQUESTS} reqs (90% short):",
        BUDGET_SLOTS * MAX_SEQ * BYTES_PER_TOKEN
    );
    println!(
        "  slot-based: mean concurrency {slot_conc:6.2}, makespan \
         {slot_steps:>5} steps, peak {slot_peak} B"
    );
    println!(
        "  tier-based: mean concurrency {tier_conc:6.2}, makespan \
         {tier_steps:>5} steps, peak {tier_peak} B   ({speedup:.2}x \
         concurrency)"
    );
    rows.push(vec![
        "admission: slot → tier mean concurrency".into(),
        format!("{slot_conc:.2} → {tier_conc:.2} ({speedup:.2}x)"),
    ]);

    let mut adm = Json::obj();
    adm.set("budget_bytes", (BUDGET_SLOTS * MAX_SEQ * BYTES_PER_TOKEN) as i64)
        .set("requests", N_REQUESTS)
        .set("slot_mean_concurrency", slot_conc)
        .set("slot_makespan_steps", slot_steps)
        .set("tier_mean_concurrency", tier_conc)
        .set("tier_makespan_steps", tier_steps)
        .set("concurrency_speedup", speedup);

    // ---- Part 2: shared-prefix prefill savings ----------------------------
    let mut prefix_rows = Vec::new();
    for ratio in [0.0, 0.5, 0.9] {
        let n = 60;
        let (with_cache, without, hits, saved_shared) = run_prefix(n, ratio);
        let saved = 1.0 - with_cache as f64 / without.max(1) as f64;
        println!(
            "prefix ratio {ratio:.1}: {without} chunks cold -> {with_cache} \
             with cache ({hits} hits, {:.0}% total saved, shared-group \
             prefix chunks {:.0}% saved)",
            saved * 100.0,
            saved_shared * 100.0
        );
        let mut o = Json::obj();
        o.set("shared_ratio", ratio)
            .set("requests", n)
            .set("chunks_without_cache", without)
            .set("chunks_with_cache", with_cache)
            .set("prefix_hits", hits)
            .set("total_chunk_fraction_saved", saved)
            .set("shared_prefix_chunk_fraction_saved", saved_shared);
        prefix_rows.push(o);
        rows.push(vec![
            format!("prefix ratio {ratio:.1}: chunk dispatches"),
            format!("{without} → {with_cache} ({hits} hits)"),
        ]);
    }

    let mut j = Json::obj();
    j.set("bench", "kvpool");
    j.set("bytes_per_token", BYTES_PER_TOKEN as i64);
    j.set("admission", adm);
    j.set("prefix", Json::Arr(prefix_rows));
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_kvpool.json", j.dump());
    println!("wrote results/BENCH_kvpool.json");

    bs::emit("kvpool_micro",
             "Paged KV pool (byte admission + shared-prefix cache)",
             &["case", "value"], &rows);
}
