//! L1 kernel microbenchmarks: the any-precision bitplane GEMV (per
//! bitwidth) and the JL estimator, both as standalone AOT executables,
//! plus the Rust-native dequant for reference.  Feeds the §Perf log.

use dp_llm::bench_support as bs;
use dp_llm::model::ModelAssets;
use dp_llm::runtime::Runtime;
use dp_llm::util::stats::bench;

fn main() {
    if !bs::require_artifacts("kernel_micro") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let model = "dpl-tiny";
    let assets = ModelAssets::load(model).unwrap();
    let store = assets.store.group("wq").unwrap();
    let (out_d, in_d) = (store.out_dim, store.in_dim);
    let x: Vec<f32> = (0..in_d).map(|i| (i as f32).sin()).collect();

    let mut rows = Vec::new();
    for bits in [3u8, 4, 5, 6] {
        let entry = manifest.entry(model, &format!("anyprec_gemv_{bits}")).unwrap();
        let exe = rt.load(&entry).unwrap();
        let planes = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8, &[6, out_d, in_d / 8],
            &store.planes[..6 * out_d * in_d / 8]).unwrap();
        let lut = xla::Literal::vec1(&store.luts[&bits][..out_d * (1 << bits)])
            .reshape(&[out_d as i64, 1i64 << bits]).unwrap();
        let xl = xla::Literal::vec1(&x);
        let r = bench(&format!("anyprec_gemv_{bits} (pallas/hlo)"), 8, 20.0, || {
            let _ = exe.run_literals(&[&planes, &lut, &xl]).unwrap();
        });
        println!("{}", r.report());
        rows.push(vec![format!("anyprec_gemv b={bits}"),
                       format!("{:.0}", r.median_ns / 1e3)]);
    }

    // JL estimator executable.
    let entry = manifest.entry(model, "jl_estimate").unwrap();
    let exe = rt.load(&entry).unwrap();
    let g: Vec<f32> = (0..64 * in_d).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
    let gl = xla::Literal::vec1(&g).reshape(&[64, in_d as i64]).unwrap();
    let xl = xla::Literal::vec1(&x);
    let r = bench("jl_estimate k=64 (pallas/hlo)", 8, 20.0, || {
        let _ = exe.run_literals(&[&gl, &xl]).unwrap();
    });
    println!("{}", r.report());
    rows.push(vec!["jl_estimate k=64".into(), format!("{:.0}", r.median_ns / 1e3)]);

    // Rust-native dequant (config-time path), for context.
    let r = bench("rust dequant layer (b=4)", 8, 20.0, || {
        let _ = store.dequant(0, 4).unwrap();
    });
    println!("{}", r.report());
    rows.push(vec!["rust dequant (config-time)".into(),
                   format!("{:.0}", r.median_ns / 1e3)]);

    bs::emit("kernel_micro", "L1 kernel microbench (µs/op, PJRT CPU interpret path)",
             &["kernel", "µs/op"], &rows);
}
