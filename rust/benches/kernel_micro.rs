//! L1 kernel microbenchmarks.
//!
//! Part 1 (artifact-free): the Rust dequantizers on a deterministic
//! synthetic store — naive reference vs the word-level kernel (serial and
//! row-parallel) vs the incremental b-1→b refine path, at every bitwidth.
//! Results land in `results/BENCH_dequant.json` (ns/layer, ops/s, bytes/s)
//! so the perf trajectory of the config-switch hot path is recorded; the
//! acceptance bar is ≥ 4x single-thread word-vs-naive at b=4.
//!
//! Part 2 (artifact-gated): the any-precision bitplane GEMV and JL
//! estimator AOT executables, as before.

use std::collections::BTreeMap;

use dp_llm::anyprec::{Codes, GroupStore, MAX_BITS, MIN_BITS};
use dp_llm::bench_support as bs;
use dp_llm::model::ModelAssets;
use dp_llm::util::json::Json;
use dp_llm::util::rng::Rng;
use dp_llm::util::stats::bench;

fn synthetic_store(l: usize, out: usize, n_in: usize) -> GroupStore {
    let mut rng = Rng::new(0xDE06);
    let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
    for b in planes.iter_mut() {
        *b = rng.next_u64() as u8;
    }
    let mut luts = BTreeMap::new();
    for b in MIN_BITS..=MAX_BITS {
        let w = 1usize << b;
        luts.insert(b, (0..l * out * w).map(|_| rng.f32() * 2.0 - 1.0).collect());
    }
    GroupStore::from_layer_major(&planes, l, out, n_in, luts).unwrap()
}

fn kernel_json(kernel: &str, bits: u8, median_ns: f64, bytes_out: usize) -> Json {
    let mut o = Json::obj();
    o.set("kernel", kernel);
    o.set("bits", bits as usize);
    o.set("ns_per_layer", median_ns);
    o.set("ops_per_s", 1e9 / median_ns);
    o.set("bytes_per_s", bytes_out as f64 * 1e9 / median_ns);
    o
}

fn main() {
    let mut rows = Vec::new();

    // ---- Rust dequant sweep (no artifacts needed) -------------------------
    let (l, out, n_in) = (1usize, 128usize, 1024usize);
    let store = synthetic_store(l, out, n_in);
    let n = out * n_in;
    let bytes_out = n * 4;
    let mut buf = vec![0f32; n];
    let mut kernels = Vec::new();
    let mut speedup_b4 = 0.0;
    for bits in MIN_BITS..=MAX_BITS {
        let naive = bench(&format!("dequant naive b={bits}"), 8, 20.0, || {
            let _ = store.dequant_reference(0, bits).unwrap();
        });
        println!("{}", naive.report());
        let word = bench(&format!("dequant word b={bits}"), 8, 20.0, || {
            store.dequant_into_serial(0, bits, &mut buf).unwrap();
        });
        println!("{}", word.report());
        let par = bench(&format!("dequant word-par b={bits}"), 8, 20.0, || {
            store.dequant_into(0, bits, &mut buf).unwrap();
        });
        println!("{}", par.report());
        kernels.push(kernel_json("naive", bits, naive.median_ns, bytes_out));
        kernels.push(kernel_json("word", bits, word.median_ns, bytes_out));
        kernels.push(kernel_json("word_par", bits, par.median_ns, bytes_out));
        rows.push(vec![
            format!("dequant b={bits} naive/word/word-par"),
            format!("{:.0}/{:.0}/{:.0}", naive.median_ns / 1e3,
                    word.median_ns / 1e3, par.median_ns / 1e3),
        ]);
        if bits == 4 {
            speedup_b4 = naive.median_ns / word.median_ns;
        }
        if bits > MIN_BITS {
            let mut base = Codes::new();
            store.dequant_codes_into(0, bits - 1, &mut base).unwrap();
            let mut codes = Codes::new();
            // The reset memcpy is measurement scaffolding (real refines
            // mutate in place, once); time it separately and subtract so
            // the recorded number is the refine+lut cost alone.
            let reset = bench(&format!("codes reset memcpy b={bits}"), 8, 20.0, || {
                codes.copy_from(&base);
            });
            let refine = bench(
                &format!("dequant refine {}->{bits}", bits - 1), 8, 20.0, || {
                    codes.copy_from(&base);
                    store.refine_codes_into(0, &mut codes).unwrap();
                    store.lut_map_into(0, bits, &codes, &mut buf).unwrap();
                });
            let refine_ns = (refine.median_ns - reset.median_ns).max(0.0);
            println!("{}  (minus {:.0} ns reset memcpy -> {refine_ns:.0} ns)",
                     refine.report(), reset.median_ns);
            kernels.push(kernel_json("refine", bits, refine_ns, bytes_out));
            rows.push(vec![
                format!("dequant refine {}->{bits}", bits - 1),
                format!("{:.0}", refine_ns / 1e3),
            ]);
        }
    }
    println!(
        "word-level vs naive at b=4, single thread: {speedup_b4:.1}x (target >= 4x)"
    );
    let mut dims = Json::obj();
    dims.set("layers", l);
    dims.set("out", out);
    dims.set("in", n_in);
    dims.set("synthetic", true);
    let mut j = Json::obj();
    j.set("bench", "dequant");
    j.set("store", dims);
    j.set("kernels", Json::Arr(kernels));
    j.set("speedup_word_vs_naive_b4", speedup_b4);
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_dequant.json", j.dump());
    println!("wrote results/BENCH_dequant.json");

    // ---- AOT kernel executables (artifact-gated) --------------------------
    if bs::require_artifacts("kernel_micro") {
        let (rt, manifest) = bs::setup().unwrap();
        let model = "dpl-tiny";
        let assets = ModelAssets::load(model).unwrap();
        let store = assets.store.group("wq").unwrap();
        let (out_d, in_d) = (store.out_dim, store.in_dim);
        let x: Vec<f32> = (0..in_d).map(|i| (i as f32).sin()).collect();

        for bits in [3u8, 4, 5, 6] {
            let entry = manifest.entry(model, &format!("anyprec_gemv_{bits}")).unwrap();
            let exe = rt.load(&entry).unwrap();
            let mut layer_planes = Vec::with_capacity(6 * out_d * in_d / 8);
            for p in 0..6 {
                layer_planes.extend_from_slice(store.plane_layer(p, 0).unwrap());
            }
            let planes = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8, &[6, out_d, in_d / 8],
                &layer_planes).unwrap();
            let lut = xla::Literal::vec1(
                    &store.lut(bits).unwrap()[..out_d * (1 << bits)])
                .reshape(&[out_d as i64, 1i64 << bits]).unwrap();
            let xl = xla::Literal::vec1(&x);
            let r = bench(&format!("anyprec_gemv_{bits} (pallas/hlo)"), 8, 20.0, || {
                let _ = exe.run_literals(&[&planes, &lut, &xl]).unwrap();
            });
            println!("{}", r.report());
            rows.push(vec![format!("anyprec_gemv b={bits}"),
                           format!("{:.0}", r.median_ns / 1e3)]);
        }

        // JL estimator executable.
        let entry = manifest.entry(model, "jl_estimate").unwrap();
        let exe = rt.load(&entry).unwrap();
        let g: Vec<f32> = (0..64 * in_d).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
        let gl = xla::Literal::vec1(&g).reshape(&[64, in_d as i64]).unwrap();
        let xl = xla::Literal::vec1(&x);
        let r = bench("jl_estimate k=64 (pallas/hlo)", 8, 20.0, || {
            let _ = exe.run_literals(&[&gl, &xl]).unwrap();
        });
        println!("{}", r.report());
        rows.push(vec!["jl_estimate k=64".into(), format!("{:.0}", r.median_ns / 1e3)]);
    }

    bs::emit("kernel_micro",
             "L1 kernel microbench (µs/op; dequant on synthetic 128x1024 store)",
             &["kernel", "µs/op"], &rows);
}
