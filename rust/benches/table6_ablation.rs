//! Table 6: latency-overhead ablation of the estimation techniques —
//! random-projection-only vs hybrid vs hybrid+async (paper §6.2).
//! Expected shape: RP-only > Hybrid > Hybrid+Async on every cell.

use dp_llm::bench_support as bs;
use dp_llm::costmodel::{overhead_frac, EstScheme, JETSON_ORIN, RTX_4060TI};
use dp_llm::model::calib::DpllmConfig;
use dp_llm::model::ModelAssets;

fn main() {
    if !bs::require_artifacts("table6") {
        return;
    }
    let model = "dpl-tiny"; // paper uses Llama-3-8B here
    if !bs::model_available(model) {
        return;
    }
    let assets = ModelAssets::load(model).unwrap();
    let targets = [3.5, 4.0, 4.5];
    let schemes = [
        ("Random Projection Based", EstScheme::RandomProjOnly),
        ("Hybrid", EstScheme::Hybrid),
        ("Hybrid+Async", EstScheme::HybridAsync),
    ];

    let mut rows = Vec::new();
    for (label, scheme) in schemes {
        let mut row = vec![label.to_string()];
        for profile in [&JETSON_ORIN, &RTX_4060TI] {
            for &t in &targets {
                match DpllmConfig::load(model, 5, &format!("{t:.2}")) {
                    Ok(dp) => {
                        let f = overhead_frac(profile, &assets.cfg, &assets.store,
                                              &dp, t, scheme);
                        row.push(format!("{:.2}%", f * 100.0));
                    }
                    Err(_) => row.push("-".into()),
                }
            }
        }
        rows.push(row);
    }
    bs::emit("table6",
             "Table 6 — estimator-technique overhead (jetson 3.5/4.0/4.5 | 4060ti 3.5/4.0/4.5)",
             &["technique", "j3.5", "j4.0", "j4.5", "r3.5", "r4.0", "r4.5"],
             &rows);
}
