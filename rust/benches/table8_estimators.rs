//! Table 8: number of layers assigned to each relative-error estimation
//! method (linear regression vs JL projection) per (l, h) candidate pair.

use std::collections::BTreeMap;

use dp_llm::bench_support as bs;
use dp_llm::model::calib::DpllmConfig;

fn main() {
    if !bs::require_artifacts("table8") {
        return;
    }
    let mut rows = Vec::new();
    for model in bs::headline_models() {
        // Count across all 5-bit-budget targets, bucketed by (l, h).
        let mut counts: BTreeMap<(u8, u8), (usize, usize)> = BTreeMap::new();
        for t in bs::targets_for_budget(5) {
            let dp = match DpllmConfig::load(model, 5, &format!("{t:.2}")) {
                Ok(d) => d,
                Err(_) => continue,
            };
            for r in &dp.linears {
                if r.h == r.l {
                    continue;
                }
                let e = counts.entry((r.l, r.h)).or_insert((0, 0));
                if r.use_lin {
                    e.0 += 1;
                } else {
                    e.1 += 1;
                }
            }
        }
        for ((l, h), (lin, jl)) in counts {
            rows.push(vec![model.to_string(), format!("({l},{h})"),
                           lin.to_string(), jl.to_string()]);
        }
    }
    bs::emit("table8",
             "Table 8 — #linears per estimation method (summed over 5-bit-budget targets)",
             &["model", "(l,h)", "linear", "JL"], &rows);
}
