//! Table 14: calibration-set sensitivity — DP-LLM configs calibrated on
//! synthwiki (tag suffix "w") vs synthweb (default, the C4-train analog),
//! evaluated on both datasets (requires `make artifacts-extended` for the
//! synthwiki-calibrated configs).

use dp_llm::bench_support as bs;
use dp_llm::evalharness::{load_stream, Method};
use dp_llm::model::ModelAssets;
use dp_llm::runtime::decode::EstMode;

fn main() {
    if !bs::require_artifacts("table14") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let model = "dpl-tiny";
    let assets = ModelAssets::load(model).unwrap();
    let targets = bs::targets_for_budget(5);

    for dataset in ["synthwiki", "synthweb"] {
        let stream = load_stream(dataset).unwrap();
        let mut rows = Vec::new();
        for (label, suffix) in [("synthwiki-calib", "w"), ("synthweb-calib", "")] {
            let mut row = vec![label.to_string()];
            let mut any = false;
            for &t in &targets {
                let m = Method::Dpllm { tag: format!("{t:.2}{suffix}") };
                let cell = bs::ppl_cell(&rt, &assets, &manifest, 5, &m, &stream,
                                        EstMode::Approx);
                any |= cell.is_some();
                row.push(bs::fmt_ppl(cell.as_ref()));
            }
            if !any && !suffix.is_empty() {
                continue;
            }
            if !any {
                bs::note_missing("table14", label);
            }
            rows.push(row);
        }
        let tstr: Vec<String> = targets.iter().map(|t| format!("{t:.2}")).collect();
        let mut header = vec!["calibration set"];
        header.extend(tstr.iter().map(String::as_str));
        bs::emit(&format!("table14_{dataset}"),
                 &format!("Table 14 — calibration-set transfer, eval on {dataset}"),
                 &header, &rows);
    }
}
