//! Table 7: per-query effective-bitwidth distribution on the instruction
//! workload (Alpaca analog).  DP-LLM matches the target on average; this
//! measures how far individual queries stray (p90/p99 vs mean).
//! Expected: ≤ a few percent even at p99.

use std::sync::Arc;

use dp_llm::bench_support as bs;
use dp_llm::evalharness::{build_session, tasks, Method};
use dp_llm::model::{art, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::tokenizer::Tokenizer;
use dp_llm::util::stats::{mean, percentile};

fn main() {
    if !bs::require_artifacts("table7") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let model = "dpl-tiny";
    let assets = ModelAssets::load(model).unwrap();
    let tok = Arc::new(Tokenizer::load(&art(&["data", "tokenizer.json"])).unwrap());
    let prompts = tasks::load_task("instruct").unwrap();
    let n: usize = std::env::var("DPLLM_QOS_QUERIES")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(10);

    let mut rows = Vec::new();
    for t in [3.5f64, 4.0, 4.5] {
        let m = Method::Dpllm { tag: format!("{t:.2}") };
        let session = match build_session(&rt, &assets, &manifest, 5, &m) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let mut bits = Vec::new();
        for s in prompts.iter().take(n) {
            if let Ok((_, eff)) = tasks::generate(&session, &tok, &s.prompt, 24,
                                                  EstMode::Approx) {
                bits.push(eff);
            }
        }
        if bits.is_empty() {
            continue;
        }
        let mu = mean(&bits);
        let p90 = percentile(&bits, 90.0);
        let p99 = percentile(&bits, 99.0);
        rows.push(vec![
            format!("{t:.1}"),
            format!("{mu:.3}"),
            format!("{:+.2}%", (p90 / mu - 1.0) * 100.0),
            format!("{:+.2}%", (p99 / mu - 1.0) * 100.0),
            format!("{}", bits.len()),
        ]);
    }
    bs::emit("table7",
             "Table 7 — per-query effective bitwidth increase over mean (instruct workload)",
             &["target", "mean eff bits", "p90", "p99", "queries"], &rows);
}
