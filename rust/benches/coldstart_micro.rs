//! Cold-start microbench (DESIGN.md §Artifact) — entirely artifact-free.
//!
//! Builds a synthetic full-precision store, writes it both ways (legacy
//! `anyprec.npz` and packed `anyprec.dpak`), and measures what a replica
//! pays to go from file to servable store:
//!
//!   * wall ms: `AnyPrecStore::load` (npz parse + copy every byte) vs
//!     `AnyPrecStore::load_dpak` (manifest + digest verify, then mmap —
//!     zero plane-byte copies);
//!   * bytes mapped vs bytes copied, from [`LoadStats`] — the zero-copy
//!     contract is *asserted*, not just reported;
//!   * tier-slice residency: `load_slice(max_bits)` for 3/4/6 bits maps
//!     strictly fewer bytes the lower the tier.
//!
//! Results land in `results/BENCH_coldstart.json`, schema-checked before
//! the write (the `serving_trace` idiom).

use anyhow::{bail, Context, Result};
use dp_llm::anyprec::{dpak, AnyPrecStore, GROUPS, MAX_BITS, MIN_BITS};
use dp_llm::bench_support as bs;
use dp_llm::util::json::Json;
use dp_llm::util::npz::{write_npz, NpyData};
use dp_llm::util::rng::Rng;
use dp_llm::util::stats::bench;

/// Synthetic store geometry: big enough that parse/copy cost dominates
/// timer noise, small enough for CI (~5.5 MB of planes + ~3.4 MB LUTs).
const L: usize = 4;
const OUT: usize = 256;
const IN: usize = 1024;

fn write_synthetic_npz(path: &str) -> Result<()> {
    let mut rng = Rng::new(0xC01D);
    let mut members: Vec<(String, Vec<usize>, NpyData)> = Vec::new();
    for g in GROUPS {
        let n = L * 6 * OUT * (IN / 8);
        let planes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        members.push((format!("planes_{g}"), vec![L, 6, OUT, IN / 8],
                      NpyData::U8(planes)));
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            let lut: Vec<f32> =
                (0..L * OUT * w).map(|_| rng.f32() * 2.0 - 1.0).collect();
            members.push((format!("lut{b}_{g}"), vec![L, OUT, w],
                          NpyData::F32(lut)));
        }
    }
    let refs: Vec<(&str, &[usize], NpyData)> = members
        .iter()
        .map(|(n, s, d)| (n.as_str(), s.as_slice(), d.clone()))
        .collect();
    write_npz(path, &refs)
}

fn main() {
    let dir = std::env::temp_dir().join("dpllm_coldstart_micro");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let npz = dir.join("anyprec.npz").to_string_lossy().into_owned();
    let dpk = dir.join("anyprec.dpak").to_string_lossy().into_owned();

    write_synthetic_npz(&npz).expect("write synthetic npz");
    let store = AnyPrecStore::load(&npz).expect("npz load");
    let meta = dpak::write(&store, "bench", &dpk).expect("pack");
    println!("packed synthetic store: version {} ({} groups, {}x{}x{})",
             meta.version, GROUPS.len(), L, OUT, IN);

    // ---- cold load wall time: npz parse+copy vs dpak verify+mmap ----------
    let npz_load = bench("coldstart npz load", 3, 200.0, || {
        let s = AnyPrecStore::load(&npz).unwrap();
        assert!(s.stats().plane_bytes_copied > 0);
    });
    println!("{}", npz_load.report());
    let dpak_load = bench("coldstart dpak load", 3, 200.0, || {
        let s = AnyPrecStore::load_dpak(&dpk).unwrap();
        assert_eq!(s.stats().plane_bytes_copied, 0,
                   "dpak load must copy zero plane bytes");
    });
    println!("{}", dpak_load.report());
    let speedup = npz_load.median_ns / dpak_load.median_ns;

    let npz_stats = AnyPrecStore::load(&npz).unwrap().stats();
    let dpak_stats = AnyPrecStore::load_dpak(&dpk).unwrap().stats();
    println!(
        "npz: copied {:.2} MB planes + {:.2} MB luts; dpak: mapped {:.2} MB \
         planes, copied 0 B ({speedup:.1}x faster cold start)",
        npz_stats.plane_bytes_copied as f64 / 1e6,
        npz_stats.lut_bytes_copied as f64 / 1e6,
        dpak_stats.plane_bytes_mapped as f64 / 1e6,
    );

    // ---- tier-sliced residency: bytes a max_bits tier touches -------------
    let mut slice_rows = Vec::new();
    let mut slices = Json::obj();
    let full_bytes = dpak_stats.plane_bytes_mapped + dpak_stats.lut_bytes_mapped
        + dpak_stats.lut_bytes_copied;
    let mut prev = 0u64;
    for b in [3u8, 4, 6] {
        let s = AnyPrecStore::load_slice(&dpk, b).unwrap();
        let st = s.stats();
        assert_eq!(st.plane_bytes_copied, 0, "slice load copied plane bytes");
        let total = st.plane_bytes_mapped + st.lut_bytes_mapped
            + st.lut_bytes_copied;
        assert!(total > prev, "slice {b} does not grow residency");
        prev = total;
        let mut e = Json::obj();
        e.set("plane_bytes_mapped", st.plane_bytes_mapped as f64)
            .set("lut_bytes", (st.lut_bytes_mapped + st.lut_bytes_copied) as f64)
            .set("total_bytes", total as f64)
            .set("fraction_of_full", total as f64 / full_bytes as f64);
        slices.set(&format!("max_bits_{b}"), e);
        slice_rows.push(vec![
            format!("tier slice max_bits={b}"),
            format!("{:.2} MB resident ({:.0}% of full)",
                    total as f64 / 1e6, 100.0 * total as f64 / full_bytes as f64),
        ]);
    }

    let mut j = Json::obj();
    j.set("bench", "coldstart")
        .set("layers", L).set("out", OUT).set("in", IN)
        .set("npz_load_ms", npz_load.median_ns / 1e6)
        .set("dpak_load_ms", dpak_load.median_ns / 1e6)
        .set("speedup_dpak_vs_npz", speedup)
        .set("npz_bytes_copied",
             (npz_stats.plane_bytes_copied + npz_stats.lut_bytes_copied) as f64)
        .set("dpak_plane_bytes_mapped", dpak_stats.plane_bytes_mapped as f64)
        .set("dpak_plane_bytes_copied", dpak_stats.plane_bytes_copied as f64)
        .set("slices", slices);
    schema_check(&j).expect("coldstart bench schema");
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_coldstart.json", j.dump());
    println!("wrote results/BENCH_coldstart.json");

    let mut rows = vec![
        vec!["npz load (parse+copy)".into(),
             format!("{:.2} ms", npz_load.median_ns / 1e6)],
        vec!["dpak load (verify+mmap)".into(),
             format!("{:.2} ms ({speedup:.1}x)", dpak_load.median_ns / 1e6)],
        vec!["dpak plane bytes copied".into(), "0 (asserted)".into()],
    ];
    rows.extend(slice_rows);
    bs::emit("coldstart_micro",
             "Cold start: packed container vs legacy npz (synthetic store)",
             &["case", "value"], &rows);
}

/// Pre-write schema gate: every required key present, finite, sane.
fn schema_check(j: &Json) -> Result<()> {
    j.req("bench")?.as_str().context("bench")?;
    for key in ["layers", "out", "in", "npz_load_ms", "dpak_load_ms",
                "speedup_dpak_vs_npz", "npz_bytes_copied",
                "dpak_plane_bytes_mapped", "dpak_plane_bytes_copied"] {
        let v = j.req(key)?.as_f64().with_context(|| key.to_string())?;
        if !v.is_finite() || v < 0.0 {
            bail!("coldstart schema: {key} = {v} invalid");
        }
    }
    if j.req("dpak_plane_bytes_copied")?.as_f64()? != 0.0 {
        bail!("coldstart schema: dpak load copied plane bytes");
    }
    let s = j.req("slices")?;
    for b in [3u8, 4, 6] {
        let frac = s.req(&format!("max_bits_{b}"))?.f64_of("fraction_of_full")?;
        if !(0.0..=1.0 + 1e-9).contains(&frac) {
            bail!("coldstart schema: slice {b} fraction {frac} out of range");
        }
    }
    Ok(())
}
