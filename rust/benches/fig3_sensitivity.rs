//! Fig. 3: dynamic layer-wise sensitivity (a) and the perplexity trend of
//! dynamic-oracle vs static vs uniform-3-bit (b).  The analysis data is
//! produced at build time by `python -m compile.sensitivity` (it needs the
//! teacher-forced oracle); this harness renders it and asserts the
//! headline shape: dynamic oracle < static < uniform-3.

use dp_llm::bench_support as bs;
use dp_llm::model::art;
use dp_llm::util::json::Json;

fn main() {
    if !bs::require_artifacts("fig3") {
        return;
    }
    let model = "dpl-tiny";
    let a = Json::parse_file(&art(&["analysis", &format!("fig3a_{model}.json")]));
    let b = Json::parse_file(&art(&["analysis", &format!("fig3b_{model}.json")]));
    let (a, b) = match (a, b) {
        (Ok(a), Ok(b)) => (a, b),
        _ => {
            bs::note_missing("fig3", "analysis json (make artifacts)");
            return;
        }
    };

    // Fig 3a: render the top-20% sensitivity mask (layers × steps).
    let mask = a.req("top_mask").unwrap();
    let rows_m = mask.as_arr().unwrap();
    println!("== Fig 3a — top-20% most-sensitive layers per decoding step ==");
    let mut flips_total = 0usize;
    for (layer, row) in rows_m.iter().enumerate() {
        let bits: Vec<usize> = row.as_usize_vec().unwrap();
        let line: String = bits.iter().take(96)
            .map(|&v| if v == 1 { '#' } else { '.' })
            .collect();
        let flips = bits.windows(2).filter(|w| w[0] != w[1]).count();
        flips_total += flips;
        println!("layer {layer:>2} [{line}] ({flips} flips)");
    }
    println!("(total membership flips: {flips_total} — nonzero means the \
              sensitive set is dynamic, the paper's key observation)\n");

    // Fig 3b: perplexity trend.
    let mut rows = Vec::new();
    let mut finals = std::collections::BTreeMap::new();
    for key in ["dynamic_oracle", "static", "uniform3"] {
        let e = b.req(key).unwrap();
        let trend = e.req("ppl_trend").unwrap().as_f64_vec().unwrap();
        let f = e.f64_of("final_ppl").unwrap();
        finals.insert(key.to_string(), f);
        let probe: Vec<String> = trend.iter().step_by(16).map(|v| format!("{v:.3}")).collect();
        rows.push(vec![key.to_string(), format!("{f:.4}"), probe.join(" → ")]);
    }
    bs::emit("fig3b", "Fig 3b — ppl trend across decoding steps (3/4-bit mix)",
             &["scheme", "final ppl", "trend (every 16 steps)"], &rows);

    let d = finals["dynamic_oracle"];
    let s = finals["static"];
    let u = finals["uniform3"];
    println!("shape check: dynamic {d:.4} < static {s:.4} < uniform3 {u:.4}: {}",
             if d < s && s <= u { "HOLDS" } else { "VIOLATED" });
}
