//! Chunked-prefill scheduling microbench (DESIGN.md §Prefill).
//!
//! Part 1 (artifact-free): a deterministic chunk-schedule simulation of
//! per-step decode stall vs. the old synchronous admission-time prefill,
//! at prompt lengths {64, 512, 2048} and chunk sizes {64, 128}.  The
//! cost model is deliberately simple: one decode round costs 1
//! token-time unit for the whole batched active set, and prefill
//! processes `Q` prompt tokens per token-time unit (prefill is
//! batch-parallel over positions, so Q ≫ 1; the exact value only scales
//! both columns).  Synchronous prefill stalls EVERY active decode for
//! `L/Q` units at admission; chunked prefill bounds the per-round stall
//! at `C/Q` and pays `ceil(L/C)` interleaved rounds of TTFT instead —
//! exactly the bounded-stall / TTFT trade the serving core schedules.
//!
//! Part 2 (artifact-gated): serves a >256-token prompt through a real
//! [`ServingCore`] while a short request decodes, and reports the decode
//! request's maximum inter-token latency, the long request's
//! queue/prefill/TTFT split, the `prefill_chunks`/`prefill_stall_ms`
//! counters, and the synchronous-ingestion baseline (one timed
//! `begin_prompt` of the same prompt — the stall the pre-chunking
//! admission would have imposed on every active decode).
//!
//! Results land in `results/BENCH_prefill.json`; the interleave bound
//! itself is enforced by the `prefill_interleaves_*` integration test.

use std::sync::Arc;
use std::time::Instant;

use dp_llm::bench_support as bs;
use dp_llm::coordinator::qos::QosBudget;
use dp_llm::coordinator::sched::{Request, SchedPolicy};
use dp_llm::coordinator::service::{CoreConfig, CoreEvent, ServingCore,
                                   ServingEngine};
use dp_llm::runtime::Runtime;
use dp_llm::tokenizer::Tokenizer;
use dp_llm::util::json::Json;

/// Prompt tokens processed per decode-token-time unit (prefill is
/// batch-parallel over positions; the value scales both schedules).
const Q: f64 = 16.0;
const PROMPTS: [usize; 3] = [64, 512, 2048];
const CHUNKS: [usize; 2] = [64, 128];

fn long_prompt(tok: &Tokenizer, min_tokens: usize) -> String {
    let mut s = String::new();
    let mut i = 0usize;
    while tok.encode(&s).len() < min_tokens {
        s.push_str(&format!("item {} of the ledger; ", i * 37 % 911));
        i += 1;
    }
    s
}

fn main() {
    let mut rows = Vec::new();
    let mut sim_rows = Vec::new();

    // ---- Part 1: chunk-schedule simulation --------------------------------
    for &l in &PROMPTS {
        let sync_stall = l as f64 / Q;
        for &c in &CHUNKS {
            let rounds = (l + c - 1) / c;
            let chunk_stall = c.min(l) as f64 / Q;
            // Chunked TTFT: each round pays one interleaved decode round
            // (1 unit) plus the chunk dispatch.
            let ttft_chunked = rounds as f64 * (1.0 + chunk_stall);
            println!(
                "L={l:<5} C={c:<4}: sync stall {sync_stall:7.1} u | chunked \
                 per-round stall {chunk_stall:5.1} u over {rounds:>2} rounds \
                 (ttft {ttft_chunked:7.1} u vs sync {sync_stall:7.1} u)"
            );
            let mut o = Json::obj();
            o.set("prompt_tokens", l)
                .set("chunk", c)
                .set("rounds", rounds)
                .set("sync_stall_units", sync_stall)
                .set("chunked_per_round_stall_units", chunk_stall)
                .set("stall_reduction", sync_stall / chunk_stall.max(1e-9))
                .set("ttft_chunked_units", ttft_chunked)
                .set("ttft_sync_units", sync_stall);
            sim_rows.push(o);
            if c == 128 {
                rows.push(vec![
                    format!("sim L={l}: per-step stall sync → chunked"),
                    format!("{sync_stall:.1} u → {chunk_stall:.1} u"),
                ]);
            }
        }
    }

    // ---- Part 2: real serving core, decode ITL under a long prefill -------
    let mut serving = Json::obj();
    if bs::require_artifacts("prefill_micro") {
        let rt = Arc::new(Runtime::new().unwrap());
        match ServingEngine::load(&rt, "dpl-tiny", 5, &["4.00"]) {
            Ok(engine) => {
                let session = engine.session_for_target(4.0);
                if session.prefill_chunk_buckets().is_empty() {
                    println!("[prefill_micro] artifacts predate prefill_chunk \
                              entries; serving part skipped");
                } else {
                    // Synchronous-ingestion baseline: the stall one
                    // admission-time prefill of this prompt would impose.
                    let prompt = long_prompt(&engine.tokenizer, 280);
                    let ids = engine.tokenizer.encode(&prompt);
                    let t0 = Instant::now();
                    let _ = session.begin_prompt(&ids).unwrap();
                    let sync_ms = t0.elapsed().as_secs_f64() * 1e3;

                    let config = CoreConfig { spec: false, ..CoreConfig::default() };
                    let mut core = ServingCore::new(&engine, SchedPolicy::Fifo)
                        .with_config(config);
                    core.admit_pinned(
                        Request::new(1, "The town of", 32,
                                     QosBudget::best_effort()), 4.0)
                        .unwrap();
                    // Step until the short request decodes, then admit the
                    // long prompt mid-flight.
                    let mut started = false;
                    while !started {
                        for ev in core.step().unwrap() {
                            if matches!(ev, CoreEvent::Token { id: 1, .. }) {
                                started = true;
                            }
                        }
                    }
                    core.admit_pinned(
                        Request::new(2, prompt, 4, QosBudget::best_effort()),
                        4.0)
                        .unwrap();
                    let mut last_a: Option<Instant> = None;
                    let mut max_itl_ms = 0f64;
                    core.drain(&mut |ev| {
                        if let CoreEvent::Token { id: 1, .. } = ev {
                            let now = Instant::now();
                            if let Some(prev) = last_a {
                                let gap = (now - prev).as_secs_f64() * 1e3;
                                max_itl_ms = max_itl_ms.max(gap);
                            }
                            last_a = Some(now);
                        }
                    })
                    .unwrap();
                    let rec = engine
                        .metrics
                        .records()
                        .into_iter()
                        .find(|r| r.id == 2)
                        .expect("long request recorded");
                    println!(
                        "[prefill_micro] long prompt ({} tok): {} chunks, \
                         prefill {:.1} ms, ttft {:.1} ms | decode max ITL \
                         {max_itl_ms:.1} ms vs sync stall {sync_ms:.1} ms",
                        ids.len(), core.prefill_chunks(), rec.prefill_ms,
                        rec.ttft_ms
                    );
                    serving
                        .set("prompt_tokens", ids.len())
                        .set("prefill_chunks", core.prefill_chunks() as i64)
                        .set("prefill_stall_ms", core.prefill_stall_ms())
                        .set("long_prefill_ms", rec.prefill_ms)
                        .set("long_queue_ms", rec.queue_ms)
                        .set("long_ttft_ms", rec.ttft_ms)
                        .set("decode_max_itl_ms", max_itl_ms)
                        .set("sync_ingest_ms", sync_ms);
                    rows.push(vec![
                        "serving: decode max ITL | sync ingest".into(),
                        format!("{max_itl_ms:.1} ms | {sync_ms:.1} ms"),
                    ]);
                }
            }
            Err(e) => println!("[prefill_micro] engine load failed ({e:#}); \
                                serving part skipped"),
        }
    }

    let mut j = Json::obj();
    j.set("bench", "prefill");
    j.set("prefill_tokens_per_unit", Q);
    j.set("sim", Json::Arr(sim_rows));
    j.set("serving", serving);
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_prefill.json", j.dump());
    println!("wrote results/BENCH_prefill.json");

    bs::emit("prefill_micro",
             "Chunked prefill scheduling (stall sim + serving ITL)",
             &["case", "value"], &rows);
}
