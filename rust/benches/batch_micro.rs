//! Continuous-batching microbench (DESIGN.md §Batching).
//!
//! Part 1 (artifact-free): drives the real [`pick_batch`] scheduler over a
//! synthetic request trace (16 requests × 32 tokens, one shape bucket) at
//! B ∈ {1, 2, 4, 8} and reports dispatch-calls-per-token plus the pure
//! scheduling overhead — the dispatch-amortization curve the batched AOT
//! graphs exist to exploit, measurable on a fresh checkout.
//!
//! Part 2 (artifact-gated): serves B concurrent pinned-target requests
//! through a real [`ServingCore`] at each batch cap and reports measured
//! tokens/s and dispatch-calls/token from the
//! `batched_steps`/`batch_occupancy` counters.
//!
//! Results land in `results/BENCH_batch.json` (see the README bench
//! table); the acceptance bar — ≤ 0.35 dispatches/token at 4 concurrent
//! same-target requests — is enforced by the
//! `dispatch_calls_per_token_bounded_with_four_concurrent` integration
//! test.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use dp_llm::bench_support as bs;
use dp_llm::coordinator::qos::QosBudget;
use dp_llm::coordinator::sched::{Request, SchedPolicy};
use dp_llm::coordinator::service::{pick_batch, BatchItem, CoreEvent,
                                   ServingCore, ServingEngine};
use dp_llm::evalharness::{build_session, perplexity, perplexity_batched,
                          Method};
use dp_llm::model::{art, Manifest, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::runtime::Runtime;
use dp_llm::util::json::Json;
use dp_llm::util::npz::load_u16_bin;

const SIM_REQUESTS: usize = 16;
const SIM_TOKENS: usize = 32;

/// Run the scheduling loop (admission → pick_batch → decrement) without a
/// device; returns (dispatches, tokens decoded).
fn simulate(max_batch: usize) -> (u64, u64) {
    let mut queue: VecDeque<u64> = (0..SIM_REQUESTS as u64).collect();
    // (admission seq, tokens remaining)
    let mut active: Vec<(u64, usize)> = Vec::new();
    let mut cursor = 0usize;
    let mut dispatches = 0u64;
    let mut tokens = 0u64;
    while !active.is_empty() || !queue.is_empty() {
        while active.len() < max_batch {
            match queue.pop_front() {
                Some(seq) => active.push((seq, SIM_TOKENS)),
                None => break,
            }
        }
        let items: Vec<BatchItem> = active
            .iter()
            .map(|&(seq, _)| BatchItem { seq, deadline: None, key: 0 })
            .collect();
        let picked = pick_batch(SchedPolicy::Fifo, cursor, &items, max_batch);
        cursor += 1;
        if picked.is_empty() {
            break;
        }
        dispatches += 1;
        tokens += picked.len() as u64;
        for &i in &picked {
            active[i].1 -= 1;
        }
        active.retain(|&(_, remaining)| remaining > 0);
    }
    (dispatches, tokens)
}

fn main() {
    let mut rows = Vec::new();
    let mut sim_rows = Vec::new();

    // ---- Part 1: scheduling simulation (no artifacts needed) --------------
    for b in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let (dispatches, tokens) = simulate(b);
        let sched_ns = t0.elapsed().as_nanos() as f64 / tokens.max(1) as f64;
        let per_token = dispatches as f64 / tokens.max(1) as f64;
        println!(
            "sim B={b}: {dispatches} dispatches / {tokens} tokens \
             = {per_token:.3} dispatch/token ({sched_ns:.0} ns/token scheduling)"
        );
        let mut o = Json::obj();
        o.set("batch", b);
        o.set("dispatch_calls_per_token", per_token);
        o.set("tokens", tokens as f64);
        o.set("scheduling_ns_per_token", sched_ns);
        sim_rows.push(o);
        rows.push(vec![
            format!("sim B={b} dispatch/token"),
            format!("{per_token:.3}"),
        ]);
    }

    // ---- Part 2: real serving core (artifact-gated) -----------------------
    let mut serving_rows = Vec::new();
    if bs::require_artifacts("batch_micro") {
        let rt = Arc::new(Runtime::new().unwrap());
        match ServingEngine::load(&rt, "dpl-tiny", 5, &["4.00"]) {
            Ok(engine) => {
                let max = engine.session_for_target(4.0).max_batch();
                for b in [1usize, 2, 4, 8] {
                    if b > 1 && b > max {
                        println!("serving B={b}: no batched artifact; skipping");
                        continue;
                    }
                    let mut core = ServingCore::new(&engine, SchedPolicy::Fifo)
                        .with_max_active(b)
                        .with_max_batch(b);
                    for id in 0..b as u64 {
                        core.admit_pinned(
                            Request::new(id, "The town of", 17,
                                         QosBudget::best_effort()),
                            4.0,
                        )
                        .unwrap();
                    }
                    let before = rt.transfers().snapshot();
                    let t0 = Instant::now();
                    let mut decoded = 0u64;
                    core.drain(&mut |ev| {
                        if let CoreEvent::Token { index, .. } = ev {
                            if *index > 0 {
                                decoded += 1;
                            }
                        }
                    })
                    .unwrap();
                    let secs = t0.elapsed().as_secs_f64();
                    let after = rt.transfers().snapshot();
                    let batched = after.batched_steps - before.batched_steps;
                    let occupancy =
                        after.batch_occupancy - before.batch_occupancy;
                    let singles = decoded.saturating_sub(occupancy);
                    let per_token =
                        (batched + singles) as f64 / decoded.max(1) as f64;
                    let tok_s = decoded as f64 / secs.max(1e-9);
                    println!(
                        "serving B={b}: {tok_s:.1} tok/s, \
                         {per_token:.3} dispatch/token \
                         ({batched} batched, occupancy {occupancy})"
                    );
                    let mut o = Json::obj();
                    o.set("batch", b);
                    o.set("tokens_per_s", tok_s);
                    o.set("dispatch_calls_per_token", per_token);
                    o.set("mean_occupancy",
                          occupancy as f64 / batched.max(1) as f64);
                    serving_rows.push(o);
                    rows.push(vec![
                        format!("serving B={b} tok/s | dispatch/token"),
                        format!("{tok_s:.1} | {per_token:.3}"),
                    ]);
                }
            }
            Err(e) => println!("[batch_micro] engine load failed ({e:#}); \
                                serving part skipped"),
        }

        // Teacher-forced eval through the batched fast path: perplexity
        // must match the single-step path while ms/token drops.
        if let (Ok(assets), Ok(manifest), Ok(stream)) = (
            ModelAssets::load("dpl-tiny"),
            Manifest::load(),
            load_u16_bin(&art(&["data", "synthwiki_eval.bin"])),
        ) {
            let m = Method::Dpllm { tag: "4.00".into() };
            match build_session(&rt, &assets, &manifest, 5, &m) {
                Ok(session) => {
                    let single = perplexity(&session, &stream, 32, 128,
                                            EstMode::Approx)
                        .unwrap();
                    let batched = perplexity_batched(&session, &stream, 32,
                                                     128, EstMode::Approx, 4)
                        .unwrap();
                    println!(
                        "eval ppl single {:.4} ({:.2} ms/tok) vs batched \
                         {:.4} ({:.2} ms/tok)",
                        single.ppl, single.ms_per_token,
                        batched.ppl, batched.ms_per_token
                    );
                    rows.push(vec![
                        "eval ms/token single | batched(B=4)".into(),
                        format!("{:.2} | {:.2}", single.ms_per_token,
                                batched.ms_per_token),
                    ]);
                }
                Err(e) => println!("[batch_micro] eval session failed ({e:#})"),
            }
        }
    }

    let mut j = Json::obj();
    j.set("bench", "batch");
    j.set("sim_requests", SIM_REQUESTS);
    j.set("sim_tokens_per_request", SIM_TOKENS);
    j.set("sim", Json::Arr(sim_rows));
    j.set("serving", Json::Arr(serving_rows));
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_batch.json", j.dump());
    println!("wrote results/BENCH_batch.json");

    bs::emit("batch_micro",
             "Continuous batching (dispatch amortization at B ∈ {1,2,4,8})",
             &["case", "value"], &rows);
}
