//! Table 5: TPOT per effective bitwidth — device cost models applied to
//! our models' real packed-store byte counts, plus measured PJRT-CPU
//! decode latency, plus the FP16 row.
//!
//! Expected shape: affine in effective bits; FP16 ≫ quantized.

use dp_llm::bench_support as bs;
use dp_llm::coordinator::service::measure_tpot;
use dp_llm::costmodel::{weight_bytes_at, JETSON_ORIN, RTX_4060TI};
use dp_llm::evalharness::{build_session, Method};
use dp_llm::model::ModelAssets;

fn main() {
    if !bs::require_artifacts("table5") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let budget = 5;
    let targets = bs::targets_for_budget(budget);

    for model in bs::headline_models() {
        if !bs::model_available(model) {
            continue;
        }
        let assets = ModelAssets::load(model).unwrap();
        let n_params: f64 = assets.cfg.total_linear_params() as f64;
        // Role-model parameter counts: the paper's Table 5 rows are for
        // Llama-3-8B / Phi-3-Medium; applying the fitted profiles at that
        // scale reproduces the paper's own cells (the unit-tested fit).
        // At sandbox scale (3-7 MB of weights) device TPOT is overhead-
        // dominated, so the per-bit slope only shows at paper scale.
        let paper_params: f64 = if model == "dpl-small" { 14.0e9 } else { 8.03e9 };
        let mut rows = Vec::new();
        for profile in [&JETSON_ORIN, &RTX_4060TI] {
            let mut row = vec![format!("{} @paper-scale", profile.name)];
            for &t in &targets {
                row.push(format!("{:.2}ms", profile.tpot_ms(paper_params * t / 8.0)));
            }
            row.push(format!("{:.2}ms", profile.tpot_fp16_ms(paper_params)));
            rows.push(row);
        }
        for profile in [&JETSON_ORIN, &RTX_4060TI] {
            let mut row = vec![format!("{} @this-model", profile.name)];
            for &t in &targets {
                let b = weight_bytes_at(&assets.store, t);
                row.push(format!("{:.3}ms", profile.tpot_ms(b)));
            }
            row.push(format!("{:.3}ms", profile.tpot_fp16_ms(n_params)));
            rows.push(row);
        }
        // Measured CPU decode TPOT per target (dynamic configuration).
        let mut row = vec!["pjrt-cpu (measured)".to_string()];
        for &t in &targets {
            let m = Method::Dpllm { tag: format!("{t:.2}") };
            let cell = build_session(&rt, &assets, &manifest, budget, &m)
                .ok()
                .and_then(|s| measure_tpot(&s, 6).ok());
            row.push(match cell {
                Some(ms) => format!("{ms:.1}ms"),
                None => "-".into(),
            });
        }
        row.push("n/a".into());
        rows.push(row);

        let tstr: Vec<String> = targets.iter().map(|t| format!("{t:.2}")).collect();
        let mut header = vec!["device"];
        header.extend(tstr.iter().map(String::as_str));
        header.push("FP16");
        bs::emit(&format!("table5_{model}"),
                 &format!("Table 5 — TPOT ({model})"), &header, &rows);
    }
}
