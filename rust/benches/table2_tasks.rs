//! Table 2: downstream-task exact-match — arith (GSM8K analog), listfn
//! (MBPP), dates (BBH), algebra (MATH) across targets and methods.

use dp_llm::bench_support as bs;
use dp_llm::evalharness::{build_session, tasks};
use dp_llm::model::{art, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::tokenizer::Tokenizer;

fn main() {
    if !bs::require_artifacts("table2") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"])).unwrap();
    let budget = 5;
    // Downstream decode is ~50 steps/sample; keep the grid affordable on
    // one core (overridable: DPLLM_TASK_SAMPLES / DPLLM_TASK_TARGETS).
    let targets: Vec<f64> = std::env::var("DPLLM_TASK_TARGETS")
        .map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_else(|_| vec![3.5, 4.5]);
    let limit = tasks::task_eval_limit();

    for task in ["arith", "listfn", "dates", "algebra"] {
        let mut rows = Vec::new();
        for model in bs::headline_models() {
            if !bs::model_available(model) {
                continue;
            }
            let assets = ModelAssets::load(model).unwrap();
            for method_i in 0..3 {
                let mut row = vec![model.to_string(), String::new()];
                for &t in &targets {
                    let m = &bs::methods_for_target(t)[method_i];
                    row[1] = m.label().split('@').next().unwrap().to_string();
                    let cell = build_session(&rt, &assets, &manifest, budget, m)
                        .ok()
                        .and_then(|s| {
                            tasks::eval_task(&s, &tok, task, limit, EstMode::Approx).ok()
                        });
                    row.push(match cell {
                        Some(r) => format!("{:.1}", r.accuracy),
                        None => "-".into(),
                    });
                }
                rows.push(row);
            }
        }
        let tstr: Vec<String> = targets.iter().map(|t| format!("{t:.2}")).collect();
        let mut header = vec!["model", "method"];
        header.extend(tstr.iter().map(String::as_str));
        bs::emit(&format!("table2_{task}"),
                 &format!("Table 2 — {task} exact-match %, 5-bit budget"),
                 &header, &rows);
    }
}
