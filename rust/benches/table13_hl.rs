//! Table 13: (l, h) candidate-pair ablation at target 4.5 under the 6-bit
//! budget — (3,5), (3,6), (4,5), (4,6) forced for every layer (requires
//! `make artifacts-extended`).  Expected: pairs adjacent to the target win.

use dp_llm::bench_support as bs;
use dp_llm::evalharness::{load_stream, Method};
use dp_llm::model::ModelAssets;
use dp_llm::runtime::decode::EstMode;

fn main() {
    if !bs::require_artifacts("table13") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let model = "dpl-tiny";
    let assets = ModelAssets::load(model).unwrap();
    let pairs = [(3, 5), (3, 6), (4, 5), (4, 6)];

    let mut rows = Vec::new();
    for (l, h) in pairs {
        let m = Method::Dpllm { tag: format!("hl{l}{h}") };
        let mut row = vec![format!("{l} & {h}")];
        let mut any = false;
        for dataset in ["synthwiki", "synthweb"] {
            let stream = load_stream(dataset).unwrap();
            let cell = bs::ppl_cell(&rt, &assets, &manifest, 6, &m, &stream,
                                    EstMode::Approx);
            any |= cell.is_some();
            row.push(bs::fmt_ppl(cell.as_ref()));
        }
        if !any {
            bs::note_missing("table13", &format!("hl{l}{h} config"));
        }
        rows.push(row);
    }
    bs::emit("table13",
             "Table 13 — (l,h) ablation at 4.5-bit target, 6-bit budget (dpl-tiny)",
             &["l & h", "synthwiki", "synthweb"], &rows);
}
