//! Table 1: perplexity under the 5-bit memory budget — 2 models × 2
//! datasets × targets 3.25..4.75 × {LLM-MQ, HAWQ-V2, DP-LLM}.
//!
//! Expected shape (paper): DP-LLM ≤ HAWQ-V2 ≤ LLM-MQ at every target, gaps
//! shrinking as the target approaches the budget.

use dp_llm::bench_support as bs;
use dp_llm::evalharness::load_stream;
use dp_llm::model::ModelAssets;
use dp_llm::runtime::decode::EstMode;

fn main() {
    if !bs::require_artifacts("table1") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let budget = 5;
    let targets = bs::targets_for_budget(budget);

    for dataset in ["synthwiki", "synthweb"] {
        let stream = load_stream(dataset).unwrap();
        let mut rows = Vec::new();
        for model in bs::headline_models() {
            if !bs::model_available(model) {
                bs::note_missing("table1", model);
                continue;
            }
            let assets = ModelAssets::load(model).unwrap();
            for method_i in 0..3 {
                let mut row = vec![model.to_string(), String::new()];
                for &t in &targets {
                    let m = &bs::methods_for_target(t)[method_i];
                    row[1] = m.label().split('@').next().unwrap().to_string();
                    let cell = bs::ppl_cell(&rt, &assets, &manifest, budget, m,
                                            &stream, EstMode::Approx);
                    row.push(bs::fmt_ppl(cell.as_ref()));
                }
                rows.push(row);
            }
        }
        let tstr: Vec<String> = targets.iter().map(|t| format!("{t:.2}")).collect();
        let mut header = vec!["model", "method"];
        header.extend(tstr.iter().map(String::as_str));
        bs::emit(&format!("table1_{dataset}"),
                 &format!("Table 1 — perplexity on {dataset} (5-bit budget)"),
                 &header, &rows);
    }
}
