//! Figs. 8-11: distribution of fine-tuned average precisions p across
//! linears, for targets 3.5 and 4.0 under the 5-bit budget.  Expected
//! shape (paper Appendix B.3): p spreads over the available range rather
//! than collapsing to the extremes.

use dp_llm::bench_support as bs;
use dp_llm::model::calib::DpllmConfig;

fn main() {
    if !bs::require_artifacts("fig8_11") {
        return;
    }
    for model in bs::headline_models() {
        for t in [3.5f64, 4.0] {
            let dp = match DpllmConfig::load(model, 5, &format!("{t:.2}")) {
                Ok(d) => d,
                Err(_) => continue,
            };
            let ps: Vec<f64> = dp.linears.iter().map(|r| r.p).collect();
            // Histogram over [3, 6] in 0.25 bins.
            let mut hist = vec![0usize; 13];
            for &p in &ps {
                let b = (((p - 3.0) / 0.25).floor() as usize).min(12);
                hist[b] += 1;
            }
            let mut rows = Vec::new();
            for (i, &c) in hist.iter().enumerate() {
                let lo = 3.0 + 0.25 * i as f64;
                rows.push(vec![format!("[{lo:.2},{:.2})", lo + 0.25),
                               "#".repeat(c), c.to_string()]);
            }
            let spread = ps.iter().cloned().fold(f64::INFINITY, f64::min)
                ..ps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            bs::emit(&format!("fig_p_{model}_{t:.2}"),
                     &format!("Figs 8-11 — p distribution, {model} target {t} \
                               (range {:.2}..{:.2})", spread.start, spread.end),
                     &["p bin", "hist", "count"], &rows);
        }
    }
}
