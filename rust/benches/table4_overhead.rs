//! Table 4: latency overhead of the runtime estimator, normalized to the
//! static baseline at the same effective bitwidth.
//!
//! Two views: (a) the Jetson-Orin / RTX-4060Ti device cost models fit to
//! the paper's own Table 5 (DESIGN.md §2), applied to our models' real
//! byte counts; (b) measured PJRT-CPU wall clock of the DP-LLM decode step
//! vs the static decode step.

use dp_llm::bench_support as bs;
use dp_llm::costmodel::{overhead_frac, EstScheme, JETSON_ORIN, RTX_4060TI};
use dp_llm::coordinator::service::measure_tpot;
use dp_llm::evalharness::{build_session, Method};
use dp_llm::model::calib::DpllmConfig;
use dp_llm::model::ModelAssets;
use dp_llm::runtime::decode::EstMode;
use dp_llm::util::stats::geomean;

fn main() {
    if !bs::require_artifacts("table4") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let budget = 5;
    let targets = bs::targets_for_budget(budget);

    for model in bs::headline_models() {
        if !bs::model_available(model) {
            continue;
        }
        let assets = ModelAssets::load(model).unwrap();
        let mut rows = Vec::new();
        for profile in [&JETSON_ORIN, &RTX_4060TI] {
            let mut row = vec![profile.name.to_string()];
            let mut fracs = Vec::new();
            for &t in &targets {
                let dp = match DpllmConfig::load(model, budget, &format!("{t:.2}")) {
                    Ok(d) => d,
                    Err(_) => {
                        row.push("-".into());
                        continue;
                    }
                };
                let f = overhead_frac(profile, &assets.cfg, &assets.store, &dp, t,
                                      EstScheme::HybridAsync);
                fracs.push(f);
                row.push(format!("{:.2}%", f * 100.0));
            }
            row.push(format!("{:.2}%", geomean(&fracs) * 100.0));
            rows.push(row);
        }

        // Measured on this machine: DP-LLM step vs static step wall clock,
        // both on the device-resident GenState path (KV never crosses the
        // host boundary — DESIGN.md §Perf), plus the per-step host→device
        // traffic that path actually pays.
        let mut row = vec!["pjrt-cpu (measured)".to_string()];
        let mut traffic_row = vec!["host→device B/step".to_string()];
        let mut fracs = Vec::new();
        let steps = 8;
        for &t in &targets {
            let dyn_m = Method::Dpllm { tag: format!("{t:.2}") };
            let sta_m = Method::Static { method: "hawq_v2".into(), target: t };
            let cell = (|| -> anyhow::Result<(f64, f64)> {
                let sd = build_session(&rt, &assets, &manifest, budget, &dyn_m)?;
                let ss = build_session(&rt, &assets, &manifest, budget, &sta_m)?;
                let td = measure_tpot(&sd, steps)?;
                let ts = measure_tpot(&ss, steps)?;
                // Steady-state traffic: meter warmed steps only, so the
                // one-time zero-KV upload of begin_empty stays out of the
                // per-step figure.
                let mut gen = sd.begin_empty()?;
                sd.advance(&mut gen, 1, EstMode::Approx)?;
                sd.advance(&mut gen, 2, EstMode::Approx)?;
                let before = rt.transfers().snapshot();
                for t in 0..steps as u32 {
                    sd.advance(&mut gen, t % 7 + 1, EstMode::Approx)?;
                }
                let after = rt.transfers().snapshot();
                let per_step =
                    after.upload_bytes_since(&before) as f64 / steps as f64;
                Ok((td / ts - 1.0, per_step))
            })();
            match cell {
                Ok((f, traffic)) => {
                    fracs.push(f.max(0.0));
                    row.push(format!("{:+.2}%", f * 100.0));
                    traffic_row.push(format!("{traffic:.0}B"));
                }
                Err(_) => {
                    row.push("-".into());
                    traffic_row.push("-".into());
                }
            }
        }
        if !fracs.is_empty() {
            row.push(format!("{:.2}%", geomean(&fracs) * 100.0));
        }
        traffic_row.push("(kv stays on device)".into());
        rows.push(row);
        rows.push(traffic_row);

        let tstr: Vec<String> = targets.iter().map(|t| format!("{t:.2}")).collect();
        let mut header = vec!["device"];
        header.extend(tstr.iter().map(String::as_str));
        header.push("geomean");
        bs::emit(&format!("table4_{model}"),
                 &format!("Table 4 — estimator overhead vs static ({model})"),
                 &header, &rows);
    }
}
