//! Table 3: exact vs approximate relative-error estimator (DP-LLM upper
//! bound study).  The exact estimator computes ‖W_h x − W_l x‖ in-graph
//! with fully synchronous selection; the approximate path is the
//! production hybrid + async scheme.  Expected: near-identical perplexity.

use dp_llm::bench_support as bs;
use dp_llm::evalharness::{load_stream, Method};
use dp_llm::model::ModelAssets;
use dp_llm::runtime::decode::EstMode;

fn main() {
    if !bs::require_artifacts("table3") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let assets = ModelAssets::load("dpl-tiny").unwrap();
    let targets = [3.5, 4.0, 4.5];

    for dataset in ["synthwiki", "synthweb"] {
        let stream = load_stream(dataset).unwrap();
        let mut rows = Vec::new();
        for (label, mode) in [("Exact", EstMode::Exact), ("Approx.", EstMode::Approx)] {
            let mut row = vec![label.to_string()];
            for &t in &targets {
                let m = Method::Dpllm { tag: format!("{t:.2}") };
                let cell = bs::ppl_cell(&rt, &assets, &manifest, 5, &m, &stream, mode);
                row.push(bs::fmt_ppl(cell.as_ref()));
            }
            rows.push(row);
        }
        bs::emit(&format!("table3_{dataset}"),
                 &format!("Table 3 — exact vs approx estimator, {dataset} (dpl-tiny)"),
                 &["estimator", "3.50", "4.00", "4.50"], &rows);
    }
}
