//! Table 12: model-scale study — dpl-nano / dpl-base (Qwen2.5-3B/32B
//! analogs) under the 5-bit budget (requires `make artifacts-extended`).

use dp_llm::bench_support as bs;
use dp_llm::evalharness::load_stream;
use dp_llm::model::ModelAssets;
use dp_llm::runtime::decode::EstMode;

fn main() {
    if !bs::require_artifacts("table12") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let targets = bs::targets_for_budget(5);

    for dataset in ["synthwiki", "synthweb"] {
        let stream = load_stream(dataset).unwrap();
        let mut rows = Vec::new();
        for model in ["dpl-nano", "dpl-base"] {
            if !bs::model_available(model) {
                bs::note_missing("table12", model);
                continue;
            }
            let assets = ModelAssets::load(model).unwrap();
            for method_i in 0..3 {
                let mut row = vec![model.to_string(), String::new()];
                for &t in &targets {
                    let m = &bs::methods_for_target(t)[method_i];
                    row[1] = m.label().split('@').next().unwrap().to_string();
                    let cell = bs::ppl_cell(&rt, &assets, &manifest, 5, m,
                                            &stream, EstMode::Approx);
                    row.push(bs::fmt_ppl(cell.as_ref()));
                }
                rows.push(row);
            }
        }
        let tstr: Vec<String> = targets.iter().map(|t| format!("{t:.2}")).collect();
        let mut header = vec!["model", "method"];
        header.extend(tstr.iter().map(String::as_str));
        bs::emit(&format!("table12_{dataset}"),
                 &format!("Table 12 — model-scale study on {dataset}"),
                 &header, &rows);
    }
}
