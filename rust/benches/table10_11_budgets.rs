//! Tables 10/11: perplexity under the 6-bit and 4-bit memory budgets
//! (dpl-tiny; requires `make artifacts-extended`).

use dp_llm::bench_support as bs;
use dp_llm::evalharness::load_stream;
use dp_llm::model::calib::load_maxprec;
use dp_llm::model::ModelAssets;
use dp_llm::runtime::decode::EstMode;

fn main() {
    if !bs::require_artifacts("table10_11") {
        return;
    }
    let (rt, manifest) = bs::setup().unwrap();
    let model = "dpl-tiny";
    let assets = ModelAssets::load(model).unwrap();

    for budget in [6u32, 4] {
        if load_maxprec(model, budget).is_err() {
            bs::note_missing("table10_11", &format!("budget-{budget} calibration"));
            continue;
        }
        let targets = bs::targets_for_budget(budget);
        for dataset in ["synthwiki", "synthweb"] {
            let stream = load_stream(dataset).unwrap();
            let mut rows = Vec::new();
            for method_i in 0..3 {
                let mut row = vec![String::new()];
                for &t in &targets {
                    let m = &bs::methods_for_target(t)[method_i];
                    row[0] = m.label().split('@').next().unwrap().to_string();
                    let cell = bs::ppl_cell(&rt, &assets, &manifest, budget, m,
                                            &stream, EstMode::Approx);
                    row.push(bs::fmt_ppl(cell.as_ref()));
                }
                rows.push(row);
            }
            let tstr: Vec<String> = targets.iter().map(|t| format!("{t:.2}")).collect();
            let mut header = vec!["method"];
            header.extend(tstr.iter().map(String::as_str));
            let tno = if budget == 6 { 10 } else { 11 };
            bs::emit(&format!("table{tno}_{dataset}"),
                     &format!("Table {tno} — ppl on {dataset}, {budget}-bit budget ({model})"),
                     &header, &rows);
        }
    }
}
