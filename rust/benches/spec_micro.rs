//! Self-speculative decoding microbench (DESIGN.md §Speculation).
//!
//! Part 1 (artifact-free): sweeps the costmodel's speculation math —
//! acceptance a ∈ {0.3 … 0.9} × γ ∈ {0, 2, 4} → expected tokens per
//! verify dispatch, predicted ms/token and speedup over plain decode on
//! the paper-fit Jetson profile (3-bit draft vs 6-bit target at
//! Llama-3-8B scale) — plus a γ-controller simulation: Bernoulli
//! acceptance streams at each true rate drive the EWMA and record which
//! γ the controller settles on.
//!
//! Part 2 (artifact-gated): serves one best-effort request through a real
//! [`ServingCore`] with speculation on vs off and reports measured
//! tokens/s, verify dispatches per token and the realized acceptance
//! rate from the `spec_*` counters.
//!
//! Results land in `results/BENCH_spec.json` (see the README bench
//! table); the ≤ 0.6 verify-dispatches/token acceptance bar is enforced
//! by the `spec_*` integration tests.

use std::sync::Arc;
use std::time::Instant;

use dp_llm::bench_support as bs;
use dp_llm::coordinator::qos::QosBudget;
use dp_llm::coordinator::sched::{Request, SchedPolicy};
use dp_llm::coordinator::service::{CoreConfig, CoreEvent, ServingCore,
                                   ServingEngine};
use dp_llm::costmodel::{pick_gamma, spec_cost_per_token,
                        spec_tokens_per_round, JETSON_ORIN};
use dp_llm::runtime::spec::GammaController;
use dp_llm::runtime::Runtime;
use dp_llm::util::json::Json;
use dp_llm::util::rng::Rng;

const GAMMAS: [usize; 3] = [0, 2, 4];
const ACCEPTS: [f64; 7] = [0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];
const SIM_ROUNDS: usize = 200;

fn main() {
    let mut rows = Vec::new();
    let mut sweep_rows = Vec::new();
    let mut ctrl_rows = Vec::new();

    // ---- Part 1a: predicted tokens/dispatch + speedup sweep ---------------
    // Paper-scale pricing: 3-bit draft vs 6-bit target, Llama-3-8B bytes.
    let n_params = 8.03e9f64;
    let tpot_draft = JETSON_ORIN.tpot_ms(n_params * 3.0 / 8.0);
    let tpot_target = JETSON_ORIN.tpot_ms(n_params * 6.0 / 8.0);
    println!("modeled TPOT: draft(3b) {tpot_draft:.2} ms, \
              target(6b) {tpot_target:.2} ms (Jetson fit, L3-8B scale)");
    for &a in &ACCEPTS {
        for &g in &GAMMAS {
            let tokens = spec_tokens_per_round(a, g);
            let cost = spec_cost_per_token(tpot_draft, tpot_target, a, g);
            let speedup = tpot_target / cost;
            println!(
                "a={a:.1} γ={g}: {tokens:.3} tokens/dispatch, \
                 {cost:.2} ms/token, speedup {speedup:.2}x"
            );
            let mut o = Json::obj();
            o.set("accept", a)
                .set("gamma", g)
                .set("tokens_per_dispatch", tokens)
                .set("ms_per_token", cost)
                .set("speedup_vs_plain", speedup);
            sweep_rows.push(o);
        }
    }
    // Two headline cells for the summary table.
    for &a in &[0.5, 0.9] {
        let g = pick_gamma(tpot_draft, tpot_target, a, &[2, 4]);
        let cost = spec_cost_per_token(tpot_draft, tpot_target, a, g);
        rows.push(vec![
            format!("model a={a:.1}: γ*, speedup"),
            format!("γ={g}, {:.2}x", tpot_target / cost),
        ]);
    }

    // ---- Part 1b: controller simulation over Bernoulli acceptance ---------
    // Drives the real GammaController with synthetic rounds at a known
    // true acceptance rate and records where the EWMA + cost model land.
    for (i, &a_true) in ACCEPTS.iter().enumerate() {
        let mut rng = Rng::new(41 + i as u64);
        let mut ctrl = GammaController::new(tpot_draft, tpot_target);
        let mut verify = 0u64;
        let mut spec_tokens = 0u64;
        let mut plain_rounds = 0u64;
        let mut last_gamma = 0usize;
        for _ in 0..SIM_ROUNDS {
            let g = ctrl.pick(&[2, 4]);
            last_gamma = g;
            if g == 0 {
                // Plain decode: one dispatch, one token, no observation
                // — tracked separately so the spec-round yield below is
                // not diluted once the controller parks at γ = 0.
                plain_rounds += 1;
                continue;
            }
            // Longest-prefix acceptance with i.i.d. per-draft prob.
            let mut accepted = 0usize;
            while accepted < g && rng.f64() < a_true {
                accepted += 1;
            }
            ctrl.observe_round(accepted, g);
            verify += 1;
            spec_tokens += accepted as u64 + 1;
        }
        // Yield of the speculative rounds alone (0 when the controller
        // never engaged); plain rounds are always 1 token/dispatch.
        let per_dispatch = spec_tokens as f64 / verify.max(1) as f64;
        println!(
            "ctrl a={a_true:.1}: settles at γ={last_gamma}, ewma {:.2}, \
             {verify} spec rounds at {per_dispatch:.2} tokens/verify-dispatch \
             + {plain_rounds} plain rounds",
            ctrl.accept_ewma
        );
        let mut o = Json::obj();
        o.set("accept_true", a_true)
            .set("gamma_final", last_gamma)
            .set("accept_ewma", ctrl.accept_ewma)
            .set("spec_rounds", verify as f64)
            .set("plain_rounds", plain_rounds as f64)
            .set("tokens_per_verify_dispatch", per_dispatch)
            .set("rounds", SIM_ROUNDS);
        ctrl_rows.push(o);
    }

    // ---- Part 2: real serving core, speculation on vs off -----------------
    let mut serving_rows = Vec::new();
    if bs::require_artifacts("spec_micro") {
        let rt = Arc::new(Runtime::new().unwrap());
        match ServingEngine::load(&rt, "dpl-tiny", 5, &["3.25", "4.00"]) {
            Ok(engine) => {
                for spec_on in [false, true] {
                    let config = CoreConfig {
                        spec: spec_on,
                        ..CoreConfig::default()
                    };
                    let mut core = ServingCore::new(&engine, SchedPolicy::Fifo)
                        .with_config(config);
                    core.admit_pinned(
                        Request::new(u64::from(spec_on), "The town of", 33,
                                     QosBudget::best_effort()),
                        4.0,
                    )
                    .unwrap();
                    let before = rt.transfers().snapshot();
                    let t0 = Instant::now();
                    let mut decoded = 0u64;
                    core.drain(&mut |ev| {
                        if let CoreEvent::Token { index, .. } = ev {
                            if *index > 0 {
                                decoded += 1;
                            }
                        }
                    })
                    .unwrap();
                    let secs = t0.elapsed().as_secs_f64();
                    let after = rt.transfers().snapshot();
                    let verify =
                        after.spec_verify_dispatches - before.spec_verify_dispatches;
                    let drafted = after.spec_drafted - before.spec_drafted;
                    let accepted = after.spec_accepted - before.spec_accepted;
                    let tok_s = decoded as f64 / secs.max(1e-9);
                    let label = if spec_on { "spec" } else { "plain" };
                    println!(
                        "serving {label}: {tok_s:.1} tok/s, {verify} verify \
                         dispatches / {decoded} tokens, acceptance {}/{}",
                        accepted, drafted
                    );
                    let mut o = Json::obj();
                    o.set("mode", label)
                        .set("tokens_per_s", tok_s)
                        .set("tokens", decoded as f64)
                        .set("verify_dispatches", verify as f64)
                        .set(
                            "verify_dispatches_per_token",
                            verify as f64 / decoded.max(1) as f64,
                        )
                        .set(
                            "acceptance_rate",
                            accepted as f64 / drafted.max(1) as f64,
                        );
                    serving_rows.push(o);
                    rows.push(vec![
                        format!("serving {label} tok/s | verify/token"),
                        format!("{tok_s:.1} | {:.3}",
                                verify as f64 / decoded.max(1) as f64),
                    ]);
                }
            }
            Err(e) => println!("[spec_micro] engine load failed ({e:#}); \
                                serving part skipped"),
        }
    }

    let mut j = Json::obj();
    j.set("bench", "spec");
    j.set("tpot_draft_ms", tpot_draft);
    j.set("tpot_target_ms", tpot_target);
    j.set("sweep", Json::Arr(sweep_rows));
    j.set("controller", Json::Arr(ctrl_rows));
    j.set("serving", Json::Arr(serving_rows));
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/BENCH_spec.json", j.dump());
    println!("wrote results/BENCH_spec.json");

    bs::emit("spec_micro",
             "Self-speculative decoding (γ sweep, controller, serving)",
             &["case", "value"], &rows);
}
