//! Downstream-task evaluation (Table 2): prefill the prompt at max
//! precision, greedy-decode with dynamic per-layer precision, extract the
//! answer with task-specific exact matching (the GSM8K `#### n` /
//! MBPP-list / BBH-option / MATH-solution analogs).

use anyhow::{bail, Context, Result};

use crate::model::art;
use crate::runtime::decode::{DecodeSession, EstMode};
use crate::tokenizer::Tokenizer;
use crate::util::json::parse_jsonl;

#[derive(Debug, Clone)]
pub struct TaskSample {
    pub task: String,
    pub prompt: String,
    pub answer: String,
}

pub fn load_task(task: &str) -> Result<Vec<TaskSample>> {
    let path = art(&["data", "tasks", &format!("{task}_eval.jsonl")]);
    parse_jsonl(&path)?
        .iter()
        .map(|j| {
            Ok(TaskSample {
                task: j.str_of("task")?,
                prompt: j.str_of("prompt")?,
                answer: j.str_of("answer")?,
            })
        })
        .collect()
}

/// Greedy generation through the serving path: prefill into a
/// device-resident [`GenState`](crate::runtime::decode::GenState) —
/// chunked ingestion when the prompt exceeds the bucketed prefill
/// ([`DecodeSession::begin_prompt`]), so long prompts evaluate for real
/// instead of being skipped — then advance token by token.
pub fn generate(session: &DecodeSession, tok: &Tokenizer, prompt: &str,
                max_new: usize, mode: EstMode) -> Result<(String, f64)> {
    let prompt_ids = tok.encode(prompt);
    if prompt_ids.is_empty() {
        bail!("empty prompt");
    }
    let (mut gen, logits) =
        session.begin_prompt(&prompt_ids).context("prompt ingestion")?;
    let mut next = DecodeSession::argmax(&logits)?;
    let mut out_ids = vec![next];
    for _ in 1..max_new {
        if gen.pos + 1 >= session.cfg.max_seq {
            break;
        }
        let step = session.advance(&mut gen, next, mode)?;
        next = DecodeSession::argmax(&step.logits)?;
        out_ids.push(next);
        let text = tok.decode(&out_ids);
        if stop_condition(&text) {
            break;
        }
    }
    Ok((tok.decode(&out_ids), gen.sel.effective_bits()))
}

fn stop_condition(text: &str) -> bool {
    // All task formats terminate at a newline or a final answer marker.
    text.contains('\n')
        || text.contains("####")
            && text.rfind("####").map(|i| text.len() > i + 6).unwrap_or(false)
}

/// Extract the comparable answer string from a generation, per task.
pub fn extract_answer(task: &str, text: &str) -> Option<String> {
    let text = text.trim_end();
    match task {
        "arith" => {
            let at = text.find("####")?;
            let rest = text[at + 4..].trim_start();
            let num: String = rest
                .chars()
                .take_while(|c| c.is_ascii_digit() || *c == '-')
                .collect();
            (!num.is_empty()).then_some(num)
        }
        "listfn" => {
            let line = text.lines().next()?.trim();
            (!line.is_empty()).then(|| line.to_string())
        }
        "dates" => {
            let open = text.find('(')?;
            let close = text[open..].find(')')? + open;
            Some(text[open..=close].to_string())
        }
        "algebra" => {
            let at = text.rfind("x = ")?;
            let rest = &text[at + 4..];
            let num: String = rest
                .chars()
                .take_while(|c| c.is_ascii_digit() || *c == '-')
                .collect();
            (!num.is_empty()).then_some(num)
        }
        _ => None,
    }
}

/// Gold answers go through the same extractor so the match is symmetric.
pub fn gold_answer(task: &str, answer: &str) -> Option<String> {
    match task {
        "listfn" => Some(answer.trim().to_string()),
        _ => extract_answer(task, answer),
    }
}

#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: String,
    pub accuracy: f64,
    pub n: usize,
    pub effective_bits: f64,
    /// Samples that did NOT evaluate (generation error or unparseable
    /// gold answer).  The old code silently `continue`d past long
    /// prompts, biasing downstream-task numbers toward short ones; with
    /// chunked prefill those evaluate for real, and any residual skip is
    /// visible here instead of silent (the artifact-gated eval test
    /// asserts zero).
    pub skipped: usize,
}

/// Exact-match accuracy of `session` on a task eval set.  Every skipped
/// sample is COUNTED ([`TaskResult::skipped`]) — a skip changes the
/// denominator, so hiding it silently biases the reported accuracy.
pub fn eval_task(session: &DecodeSession, tok: &Tokenizer, task: &str,
                 limit: usize, mode: EstMode) -> Result<TaskResult> {
    let samples = load_task(task)?;
    let n = samples.len().min(limit);
    let mut correct = 0usize;
    let mut eff = 0.0;
    let mut evaluated = 0usize;
    let mut skipped = 0usize;
    for s in samples.iter().take(n) {
        let gold = match gold_answer(&s.task, &s.answer) {
            Some(g) => g,
            None => {
                skipped += 1; // unparseable gold answer — data fault
                continue;
            }
        };
        let max_new = match task {
            "arith" | "algebra" => 48,
            _ => 24,
        };
        let (text, bits) = match generate(session, tok, &s.prompt, max_new, mode) {
            Ok(r) => r,
            Err(_) => {
                // Post-chunked-prefill this is a real fault (device error,
                // prompt beyond max_seq), not the routine long-prompt case
                // the bucketed path used to hit — keep it visible.
                skipped += 1;
                continue;
            }
        };
        evaluated += 1;
        eff += bits;
        if extract_answer(&s.task, &text).as_deref() == Some(gold.as_str()) {
            correct += 1;
        }
    }
    if evaluated == 0 {
        bail!("no samples evaluated for {task} ({skipped} skipped)");
    }
    Ok(TaskResult {
        task: task.to_string(),
        accuracy: correct as f64 / evaluated as f64 * 100.0,
        n: evaluated,
        effective_bits: eff / evaluated as f64,
        skipped,
    })
}

pub fn task_eval_limit() -> usize {
    std::env::var("DPLLM_TASK_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_arith() {
        assert_eq!(extract_answer("arith", "23 + 18 = 41. #### 41"),
                   Some("41".into()));
        assert_eq!(extract_answer("arith", "#### -7 junk"), Some("-7".into()));
        assert_eq!(extract_answer("arith", "no marker"), None);
    }

    #[test]
    fn extract_listfn_first_line() {
        assert_eq!(extract_answer("listfn", "7 10 5\nTask: junk"),
                   Some("7 10 5".into()));
    }

    #[test]
    fn extract_dates_option() {
        assert_eq!(extract_answer("dates", "(B) maybe more"), Some("(B)".into()));
        assert_eq!(extract_answer("dates", "none"), None);
    }

    #[test]
    fn extract_algebra() {
        assert_eq!(extract_answer("algebra", "3x = 9. x = 3"), Some("3".into()));
        assert_eq!(
            extract_answer("algebra", "x = 12 / 4 = 3. x = 3"),
            Some("3".into())
        );
    }

    #[test]
    fn gold_matches_generation_format() {
        let gold = gold_answer("arith", "23 + 18 = 41. #### 41").unwrap();
        let gen = extract_answer("arith", "23 + 18 = 41. #### 41").unwrap();
        assert_eq!(gold, gen);
    }

    #[test]
    fn stop_conditions() {
        assert!(stop_condition("answer\nmore"));
        assert!(stop_condition("x #### 12345"));
        assert!(!stop_condition("still going"));
    }
}
