//! Evaluation harnesses: teacher-forced perplexity and downstream-task
//! exact-match, both running through the full L3→PJRT request path (the
//! same decode graph that serves traffic — not a Python shortcut).

pub mod tasks;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::calib::{load_maxprec, DpllmConfig, StaticConfig};
use crate::model::{art, Manifest, ModelAssets};
use crate::runtime::decode::{DecodeSession, EstMode, GenState, WeightCache};
use crate::runtime::Runtime;
use crate::selector::EngineConfig;
use crate::util::npz::load_u16_bin;

/// Which precision-assignment method to evaluate.
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    Dpllm { tag: String },
    Static { method: String, target: f64 },
    Uniform { bits: u8 },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Dpllm { tag } => format!("DP-LLM@{tag}"),
            Method::Static { method, target } => format!("{method}@{target:.2}"),
            Method::Uniform { bits } => format!("uniform@{bits}"),
        }
    }
}

/// Resolve (model, budget, method) to an [`EngineConfig`] without building
/// a session — also the input to [`DecodeSession::swap_bits`] rebinds.
pub fn engine_config_for(assets: &ModelAssets, budget: u32,
                         method: &Method) -> Result<EngineConfig> {
    let maxprec = load_maxprec(&assets.cfg.name, budget)?;
    match method {
        Method::Dpllm { tag } => {
            let dp = DpllmConfig::load(&assets.cfg.name, budget, tag)
                .with_context(|| format!("dpllm config {tag}"))?;
            EngineConfig::from_dpllm(&assets.cfg, &dp, &maxprec)
        }
        Method::Static { method, target } => {
            let st = StaticConfig::load(&assets.cfg.name, budget, method, *target)?;
            EngineConfig::from_static(&assets.cfg, &st, &maxprec)
        }
        Method::Uniform { bits } => {
            let st = StaticConfig::uniform(&assets.cfg, *bits);
            EngineConfig::from_static(&assets.cfg, &st, &maxprec)
        }
    }
}

/// Build a servable session for (model, budget, method).
pub fn build_session(rt: &Arc<Runtime>, assets: &ModelAssets,
                     manifest: &Manifest, budget: u32, method: &Method)
                     -> Result<DecodeSession> {
    let ec = engine_config_for(assets, budget, method)?;
    DecodeSession::new(rt.clone(), assets, manifest, ec)
}

/// [`build_session`] materializing through a shared weight cache, so
/// sibling configurations of one model dedupe their (group, layer, bits)
/// dequantizations and uploads (delta materialization across a whole
/// adaptation set).
pub fn build_session_with_cache(rt: &Arc<Runtime>, assets: &ModelAssets,
                                manifest: &Manifest, budget: u32,
                                method: &Method, weights: WeightCache)
                                -> Result<DecodeSession> {
    let ec = engine_config_for(assets, budget, method)?;
    DecodeSession::new_shared(rt.clone(), assets, manifest, ec, weights)
}

/// Result of one perplexity run.
#[derive(Debug, Clone)]
pub struct PplResult {
    pub ppl: f64,
    pub tokens: usize,
    pub effective_bits: f64,
    pub ms_per_token: f64,
}

/// Teacher-forced perplexity over a tokenized stream, decoding step by
/// step through the serving graph with live dynamic precision selection.
/// Each chunk runs through a fresh [`GenState`] whose KV cache stays on
/// the device for the whole chunk (the serving hot path, not a shortcut).
pub fn perplexity(session: &DecodeSession, stream: &[u16], chunk: usize,
                  max_tokens: usize, mode: EstMode) -> Result<PplResult> {
    if stream.len() < chunk + 1 {
        bail!("stream too short");
    }
    let n_chunks = (max_tokens / chunk).max(1);
    let mut nll_sum = 0.0;
    let mut count = 0usize;
    let mut eff_sum = 0.0;
    let t0 = std::time::Instant::now();
    for c in 0..n_chunks {
        let base = c * (chunk + 1);
        if base + chunk + 1 > stream.len() {
            break;
        }
        let toks = &stream[base..base + chunk + 1];
        let mut gen = session.begin_empty()?;
        for t in 0..chunk {
            let out = session.advance(&mut gen, toks[t] as u32, mode)?;
            nll_sum += nll_of(&out.logits, toks[t + 1] as usize);
            count += 1;
        }
        eff_sum += gen.sel.effective_bits();
    }
    let chunks_done = (count / chunk).max(1);
    Ok(PplResult {
        ppl: (nll_sum / count as f64).exp(),
        tokens: count,
        effective_bits: eff_sum / chunks_done as f64,
        ms_per_token: t0.elapsed().as_secs_f64() * 1e3 / count as f64,
    })
}

/// [`perplexity`] through the batched decode fast path: up to `batch`
/// independent chunks advance in lockstep via
/// [`DecodeSession::advance_batch`], cutting device dispatches per token
/// by ~the batch factor while preserving per-chunk numerics (each chunk
/// still owns its [`GenState`] + selector state; `batch` is clamped to
/// the session's largest batched bucket, and `batch == 1` — or artifacts
/// without batched entries — reproduces [`perplexity`]'s per-step path).
pub fn perplexity_batched(session: &DecodeSession, stream: &[u16],
                          chunk: usize, max_tokens: usize, mode: EstMode,
                          batch: usize) -> Result<PplResult> {
    if stream.len() < chunk + 1 {
        bail!("stream too short");
    }
    let batch = batch.clamp(1, session.max_batch());
    let n_chunks = (max_tokens / chunk).max(1);
    let bases: Vec<usize> = (0..n_chunks)
        .map(|c| c * (chunk + 1))
        .filter(|b| b + chunk + 1 <= stream.len())
        .collect();
    if bases.is_empty() {
        bail!("stream too short for chunk size {chunk}");
    }
    let mut nll_sum = 0.0;
    let mut count = 0usize;
    let mut eff_sum = 0.0;
    let mut chunks_done = 0usize;
    let t0 = std::time::Instant::now();
    for group in bases.chunks(batch) {
        let mut gens: Vec<GenState<'_>> = group
            .iter()
            .map(|_| session.begin_empty())
            .collect::<Result<_>>()?;
        for t in 0..chunk {
            let mut slots: Vec<(&mut GenState<'_>, u32)> = gens
                .iter_mut()
                .zip(group.iter())
                .map(|(g, &base)| (g, stream[base + t] as u32))
                .collect();
            let outs = session.advance_batch(&mut slots, mode)?;
            for (out, &base) in outs.iter().zip(group.iter()) {
                nll_sum += nll_of(&out.logits, stream[base + t + 1] as usize);
                count += 1;
            }
        }
        for g in &gens {
            eff_sum += g.sel.effective_bits();
            chunks_done += 1;
        }
    }
    Ok(PplResult {
        ppl: (nll_sum / count as f64).exp(),
        tokens: count,
        effective_bits: eff_sum / chunks_done.max(1) as f64,
        ms_per_token: t0.elapsed().as_secs_f64() * 1e3 / count.max(1) as f64,
    })
}

/// -log softmax(logits)`[target]`
pub fn nll_of(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln() + max;
    lse - logits[target] as f64
}

/// Load one of the eval token streams by short name.
pub fn load_stream(name: &str) -> Result<Vec<u16>> {
    let file = match name {
        "synthwiki" => "synthwiki_eval.bin",
        "synthweb" => "synthweb_eval.bin",
        other => bail!("unknown stream {other}"),
    };
    load_u16_bin(&art(&["data", file]))
}

/// Eval-size knobs (env-overridable so benches scale to the machine).
pub fn eval_tokens_default() -> usize {
    std::env::var("DPLLM_EVAL_TOKENS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

pub fn eval_chunk_default() -> usize {
    std::env::var("DPLLM_EVAL_CHUNK")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_matches_manual_softmax() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let p: Vec<f64> = {
            let z: f64 = logits.iter().map(|&v| (v as f64).exp()).sum();
            logits.iter().map(|&v| (v as f64).exp() / z).collect()
        };
        for (i, pi) in p.iter().enumerate() {
            assert!((nll_of(&logits, i) - (-pi.ln())).abs() < 1e-9);
        }
    }

    #[test]
    fn nll_stable_for_large_logits() {
        let logits = vec![1000.0f32, 999.0];
        let v = nll_of(&logits, 0);
        assert!(v.is_finite() && v > 0.0 && v < 1.0);
    }

    #[test]
    fn method_labels() {
        assert_eq!(Method::Uniform { bits: 4 }.label(), "uniform@4");
        assert_eq!(
            Method::Static { method: "hawq_v2".into(), target: 4.0 }.label(),
            "hawq_v2@4.00"
        );
    }
}
