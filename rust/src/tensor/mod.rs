//! Minimal dense f32 tensor — just enough shape-checked storage for weight
//! materialization, estimator math and eval bookkeeping on the host side.
//! (The heavy math runs inside the AOT-compiled XLA executables; this type
//! mostly ferries data into [`crate::runtime`].)

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let s = self.strides();
        let off: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        self.data[off]
    }

    /// Borrow a contiguous sub-tensor along the leading axis.
    pub fn slice0(&self, i: usize) -> &[f32] {
        let step: usize = self.shape[1..].iter().product();
        &self.data[i * step..(i + 1) * step]
    }

    /// y = W @ x for W `[out, in]` (row-major GEMV, host-side reference).
    pub fn gemv(&self, x: &[f32]) -> Result<Vec<f32>> {
        if self.rank() != 2 || self.shape[1] != x.len() {
            bail!("gemv shape mismatch {:?} vs {}", self.shape, x.len());
        }
        let (out, n) = (self.shape[0], self.shape[1]);
        let mut y = vec![0f32; out];
        for o in 0..out {
            let row = &self.data[o * n..(o + 1) * n];
            let mut acc = 0f32;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[o] = acc;
        }
        Ok(y)
    }

    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

pub fn l2(xs: &[f32]) -> f32 {
    xs.iter().map(|v| v * v).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn strides_and_at() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.strides(), vec![3, 1]);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.slice0(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn gemv_identity() {
        let eye = Tensor::new(vec![3, 3],
                              vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]).unwrap();
        let y = eye.gemv(&[2.0, 3.0, 4.0]).unwrap();
        assert_eq!(y, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn norm_l2() {
        assert!((l2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
