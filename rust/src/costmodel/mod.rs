//! Device cost models for the latency studies (Tables 4/5/6).
//!
//! The paper measures TPOT on an NVIDIA Jetson Orin AGX and an RTX 4060 Ti.
//! Neither exists in this sandbox, so we model what those tables actually
//! demonstrate: weight-only-quantized batch-1 decode is **memory-bandwidth
//! bound**, hence TPOT is affine in the effective bitwidth
//! (paper Table 5 rows are affine with R² > 0.999), and the selector adds
//! a small, scheme-dependent overhead (Tables 4/6).
//!
//! ```text
//! TPOT(b) ≈ overhead_ms + weight_bytes(b) / (BW · eff)
//! ```
//!
//! Profiles are fit to the paper's own Table 5 numbers and then *scaled to
//! our models' real byte counts* from the any-precision store; the CPU
//! profile is fit at runtime from measured decode steps, so the relative
//! overhead claims are additionally validated on real hardware (see
//! `benches/table4_overhead.rs`).

use crate::anyprec::AnyPrecStore;
use crate::model::calib::DpllmConfig;
use crate::model::ModelConfig;

/// Estimator scheme for the ablation in Table 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstScheme {
    /// Every layer uses the JL random projection, synchronously.
    RandomProjOnly,
    /// Hybrid linear/JL selection (paper §5.1), synchronous.
    Hybrid,
    /// Hybrid + asynchronous estimation for q/k/v/gate/up (paper §5.2).
    HybridAsync,
}

/// A modeled device.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Effective memory bandwidth in GB/s.
    pub mem_bw_gbps: f64,
    /// Fraction of peak bandwidth the *quantized dequant-GEMV* kernels
    /// achieve (> 1.0 means L2-cache reuse beyond DRAM bandwidth, as the
    /// paper's 4060 Ti numbers imply — 32 MB L2 holding the LUTs).
    pub efficiency: f64,
    /// Fraction of peak bandwidth dense fp16 GEMV achieves.
    pub fp16_efficiency: f64,
    /// Fixed per-token overhead (attention, activations, launches), ms.
    pub overhead_ms: f64,
    /// Per-selector-invocation overhead on the critical path, µs
    /// (kernel-launch-ish cost of the tiny estimator GEMV + compare).
    pub launch_us: f64,
}

/// Fit to paper Table 5 row "L3-8B Jetson": slope 6.03 ms/bit and
/// intercept 9.18 ms imply 166.5 GB/s effective (81% of the 204.8 GB/s
/// spec); the fp16 row (86.36 ms) implies ~full spec bandwidth for dense
/// GEMV.  The unit tests below pin the fit against the paper's own cells.
pub const JETSON_ORIN: DeviceProfile = DeviceProfile {
    name: "jetson-orin-agx",
    mem_bw_gbps: 204.8,
    efficiency: 0.813,
    fp16_efficiency: 1.0,
    overhead_ms: 9.18,
    launch_us: 28.0,
};

/// Fit to "L3-8B 4060Ti": slope 3.29 ms/bit implies 305 GB/s effective —
/// above the 288 GB/s DRAM spec, consistent with the 32 MB L2 serving the
/// centroid tables; intercept 4.86 ms.
pub const RTX_4060TI: DeviceProfile = DeviceProfile {
    name: "rtx-4060ti",
    mem_bw_gbps: 288.0,
    efficiency: 1.06,
    fp16_efficiency: 1.0,
    overhead_ms: 4.86,
    launch_us: 7.0,
};

/// Fit at runtime from measured PJRT-CPU decode steps.
pub fn cpu_profile(measured_ms_per_bit: f64, measured_overhead_ms: f64) -> DeviceProfile {
    DeviceProfile {
        name: "pjrt-cpu",
        mem_bw_gbps: 1.0 / measured_ms_per_bit.max(1e-9) * 1e-6,
        efficiency: 1.0,
        fp16_efficiency: 1.0,
        overhead_ms: measured_overhead_ms,
        launch_us: 0.0,
    }
}

impl DeviceProfile {
    /// ms to stream `bytes` at effective bandwidth.
    pub fn stream_ms(&self, bytes: f64) -> f64 {
        bytes / (self.mem_bw_gbps * self.efficiency * 1e9) * 1e3
    }

    /// TPOT for a model whose quantized weights occupy `weight_bytes` at
    /// the chosen effective bitwidth.
    pub fn tpot_ms(&self, weight_bytes: f64) -> f64 {
        self.overhead_ms + self.stream_ms(weight_bytes)
    }

    /// TPOT for an fp16 (unquantized) variant of the same model.
    pub fn tpot_fp16_ms(&self, n_params: f64) -> f64 {
        let bytes = n_params * 2.0;
        self.overhead_ms + bytes / (self.mem_bw_gbps * self.fp16_efficiency * 1e9) * 1e3
    }
}

/// Weight bytes actually streamed per token at effective bitwidth `b_eff`
/// for our models (packed planes + LUT rows, from the real store layout).
pub fn weight_bytes_at(store: &AnyPrecStore, b_eff: f64) -> f64 {
    let lo = (b_eff.floor() as u8).clamp(3, 6);
    let hi = (b_eff.ceil() as u8).clamp(3, 6);
    let frac = b_eff - lo as f64;
    let lo_b = store.capacity_bytes(lo) as f64;
    let hi_b = store.capacity_bytes(hi) as f64;
    lo_b + (hi_b - lo_b) * frac
}

/// Per-token estimator cost (bytes on the critical path + launches) for a
/// DP-LLM config under the given scheme — drives Tables 4 and 6.
pub fn estimator_critical_bytes(cfg: &ModelConfig, dp: &DpllmConfig,
                                scheme: EstScheme) -> (f64, usize) {
    let idx = cfg.linear_index();
    let async_groups = ["wq", "wk", "wv", "wg", "wu"];
    let mut bytes = 0.0;
    let mut invocations = 0usize;
    for (li, (_, g)) in idx.iter().enumerate() {
        let r = &dp.linears[li];
        if r.h == r.l {
            continue; // single-precision candidate set: no selector
        }
        let is_async = async_groups.contains(g);
        let (_, in_d) = cfg.group_shape(g);
        let jl_bytes = (dp.k_proj * in_d * 4) as f64;
        let (layer_bytes, on_path) = match scheme {
            EstScheme::RandomProjOnly => (jl_bytes, true),
            EstScheme::Hybrid => {
                if r.use_lin {
                    (0.0, true) // norm reduction ~ free
                } else {
                    (jl_bytes, true)
                }
            }
            EstScheme::HybridAsync => {
                if r.use_lin {
                    (0.0, !is_async)
                } else {
                    (jl_bytes, !is_async)
                }
            }
        };
        if on_path {
            bytes += layer_bytes;
            invocations += 1;
        }
    }
    (bytes, invocations)
}

// ---------------------------------------------------------------------------
// Self-speculative decoding cost model (DESIGN.md §Speculation).
//
// A speculative round costs γ draft decode steps at the low bitwidth plus
// ONE verify dispatch at the target bitwidth (scoring γ+1 positions reads
// the weights once — batch-1 decode is memory-bandwidth bound, §2 above,
// so the verify step costs ≈ one target-precision token).  With per-draft
// acceptance probability `a` the round commits 1 + a + a² + … + a^γ
// tokens in expectation (greedy longest-prefix acceptance, ≥ 1 always).
// The dynamic-γ controller picks the γ minimizing expected ms/token and
// falls back to plain decode (γ = 0) whenever speculation would not be
// strictly cheaper.
// ---------------------------------------------------------------------------

/// Expected committed tokens per verify round: Σ_{i=0}^{γ} aⁱ.
/// γ = 0 → 1.0 (plain decode); a = 1 → γ + 1 (every draft accepted).
pub fn spec_tokens_per_round(accept: f64, gamma: usize) -> f64 {
    let a = accept.clamp(0.0, 1.0);
    let mut e = 1.0;
    let mut p = 1.0;
    for _ in 0..gamma {
        p *= a;
        e += p;
    }
    e
}

/// Expected cost per committed token of a speculative round:
/// `(γ·TPOT_draft + TPOT_target) / E[tokens]`.  γ = 0 degenerates to
/// plain decode's `TPOT_target` exactly.
pub fn spec_cost_per_token(tpot_draft_ms: f64, tpot_target_ms: f64,
                           accept: f64, gamma: usize) -> f64 {
    if gamma == 0 {
        return tpot_target_ms;
    }
    (gamma as f64 * tpot_draft_ms + tpot_target_ms)
        / spec_tokens_per_round(accept, gamma)
}

/// Pick the draft length from `candidates` (γ values with a compiled
/// `verify_step_g*` graph) minimizing expected ms/token at acceptance
/// rate `accept`.  Returns 0 — plain decode — unless some candidate is
/// *strictly* cheaper: a draft as slow as the target (tpot_draft ≥
/// tpot_target · E/(γ+… )) or a poor acceptance rate can never engage
/// speculation, the fall-back the DP-LLM QoS story requires.
pub fn pick_gamma(tpot_draft_ms: f64, tpot_target_ms: f64, accept: f64,
                  candidates: &[usize]) -> usize {
    let mut best = 0usize;
    let mut best_cost = tpot_target_ms;
    for &g in candidates {
        if g == 0 {
            continue;
        }
        let c = spec_cost_per_token(tpot_draft_ms, tpot_target_ms, accept, g);
        if c < best_cost {
            best = g;
            best_cost = c;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// KV-pool admission backpressure (DESIGN.md §Memory).
//
// The serving core admits against a byte budget of KV tiers.  When the
// pool runs hot, DP-LLM's precision knob doubles as an admission-control
// lever (FlexQuant's dynamic-precision-switching scenario, PAPERS.md):
// admit new traffic at a LOWER effective bitwidth instead of rejecting.
// A lower-bit generation streams fewer weight bytes per token (TPOT is
// affine in bits, §top of file), so it finishes — and releases its KV
// tier — sooner, draining pressure fastest exactly when the pool needs
// relief.  The rule is deliberately a pure function of (available
// targets, wanted target, pressure) so it is unit-testable and the
// serving core carries no policy of its own.
// ---------------------------------------------------------------------------

/// Pool pressure (`in_use / budget`) at which admission starts
/// downshifting new requests one precision rung.
pub const DOWNSHIFT_PRESSURE: f64 = 0.85;

/// Pool pressure at which admission drops straight to the lowest
/// resident target precision.
pub const FLOOR_PRESSURE: f64 = 0.95;

/// The target precision a new request should be admitted at, given the
/// adaptation set's resident `targets`, the QoS policy's choice `want`,
/// and the KV pool `pressure`: untouched below [`DOWNSHIFT_PRESSURE`],
/// one available rung down in the band up to [`FLOOR_PRESSURE`], the
/// lowest resident target at or above it.  Unknown/empty target sets and
/// already-lowest choices pass through unchanged.
pub fn downshift_for_pressure(targets: &[f64], want: f64, pressure: f64) -> f64 {
    if targets.is_empty() || pressure < DOWNSHIFT_PRESSURE {
        return want;
    }
    let floor = targets.iter().copied().fold(f64::INFINITY, f64::min);
    if pressure >= FLOOR_PRESSURE {
        return floor.min(want);
    }
    // One rung down: the largest resident target strictly below `want`.
    targets
        .iter()
        .copied()
        .filter(|&t| t < want)
        .fold(f64::NEG_INFINITY, f64::max)
        .max(floor)
        .min(want)
}

/// Relative selector overhead vs. the static baseline (Table 4/6 cells).
pub fn overhead_frac(profile: &DeviceProfile, cfg: &ModelConfig,
                     store: &AnyPrecStore, dp: &DpllmConfig, b_eff: f64,
                     scheme: EstScheme) -> f64 {
    let base = profile.tpot_ms(weight_bytes_at(store, b_eff));
    let (est_bytes, invocations) = estimator_critical_bytes(cfg, dp, scheme);
    let extra = profile.stream_ms(est_bytes)
        + invocations as f64 * profile.launch_us / 1e3;
    extra / base
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Jetson profile applied to the paper's Llama-3-8B reproduces the
    /// paper's own Table 5 slope/intercept within tolerance — the fit the
    /// whole cost model rests on.
    #[test]
    fn jetson_profile_matches_paper_llama() {
        let n_params = 8.03e9f64; // Llama-3-8B
        let tp = |b: f64| JETSON_ORIN.tpot_ms(n_params * b / 8.0);
        // paper: 28.77 ms @ 3.25 eff bits, 37.81 ms @ 4.75 eff bits
        assert!((tp(3.25) - 28.77).abs() / 28.77 < 0.08, "{}", tp(3.25));
        assert!((tp(4.75) - 37.81).abs() / 37.81 < 0.08, "{}", tp(4.75));
        // fp16 row: 86.36 ms
        let fp = JETSON_ORIN.tpot_fp16_ms(n_params);
        assert!((fp - 86.36).abs() / 86.36 < 0.15, "{fp}");
    }

    #[test]
    fn rtx_profile_matches_paper_llama() {
        let n_params = 8.03e9f64;
        let tp = |b: f64| RTX_4060TI.tpot_ms(n_params * b / 8.0);
        assert!((tp(3.25) - 15.54).abs() / 15.54 < 0.08, "{}", tp(3.25));
        assert!((tp(4.75) - 20.47).abs() / 20.47 < 0.08, "{}", tp(4.75));
    }

    #[test]
    fn tpot_affine_in_bits() {
        let n = 8e9f64;
        let t35 = JETSON_ORIN.tpot_ms(n * 3.5 / 8.0);
        let t40 = JETSON_ORIN.tpot_ms(n * 4.0 / 8.0);
        let t45 = JETSON_ORIN.tpot_ms(n * 4.5 / 8.0);
        assert!(((t45 - t40) - (t40 - t35)).abs() < 1e-9);
        assert!(t35 < t40 && t40 < t45);
    }

    #[test]
    fn spec_tokens_per_round_bounds() {
        // γ = 0 and a = 0 both degenerate to one token per round.
        assert_eq!(spec_tokens_per_round(0.7, 0), 1.0);
        assert_eq!(spec_tokens_per_round(0.0, 4), 1.0);
        // Perfect acceptance commits γ + 1 tokens.
        assert!((spec_tokens_per_round(1.0, 4) - 5.0).abs() < 1e-12);
        // a = 0.5, γ = 2: 1 + 0.5 + 0.25.
        assert!((spec_tokens_per_round(0.5, 2) - 1.75).abs() < 1e-12);
        // Monotone in both a and γ.
        assert!(spec_tokens_per_round(0.6, 4) > spec_tokens_per_round(0.4, 4));
        assert!(spec_tokens_per_round(0.6, 4) > spec_tokens_per_round(0.6, 2));
    }

    #[test]
    fn spec_cost_gamma0_is_plain_decode() {
        assert_eq!(spec_cost_per_token(1.0, 3.0, 0.9, 0), 3.0);
    }

    #[test]
    fn pick_gamma_prefers_speculation_only_when_strictly_cheaper() {
        // Very cheap draft + high acceptance → the largest γ wins.
        assert_eq!(pick_gamma(1.0, 10.0, 0.95, &[2, 4]), 4);
        // Fast draft (3-bit vs 6-bit on the affine Jetson profile) + high
        // acceptance → speculation engages (the fixed per-token overhead
        // makes γ = 2 the sweet spot there, but any γ > 0 is the point).
        let n = 8.03e9f64;
        let t3 = JETSON_ORIN.tpot_ms(n * 3.0 / 8.0);
        let t6 = JETSON_ORIN.tpot_ms(n * 6.0 / 8.0);
        assert!(t3 < t6);
        assert!(pick_gamma(t3, t6, 0.9, &[2, 4]) > 0);
        // Low acceptance: each verify mostly commits one token while the
        // round still paid γ drafts — plain decode wins.
        assert_eq!(pick_gamma(t3, t6, 0.05, &[2, 4]), 0);
        // Draft as expensive as the target can never be strictly cheaper
        // (a < 1 ⇒ E[tokens] < γ+1 ⇒ cost/token > TPOT_target).
        assert_eq!(pick_gamma(t6, t6, 0.95, &[2, 4]), 0);
        // No compiled verify graphs → plain decode.
        assert_eq!(pick_gamma(t3, t6, 0.9, &[]), 0);
    }

    #[test]
    fn downshift_engages_only_under_pressure() {
        let targets = [3.5, 4.5, 5.5];
        // Cold pool: the policy's choice passes through.
        assert_eq!(downshift_for_pressure(&targets, 5.5, 0.0), 5.5);
        assert_eq!(downshift_for_pressure(&targets, 5.5, 0.84), 5.5);
        // Pressure band: one available rung down.
        assert_eq!(downshift_for_pressure(&targets, 5.5, 0.90), 4.5);
        assert_eq!(downshift_for_pressure(&targets, 4.5, 0.90), 3.5);
        // At/above the floor threshold: straight to the lowest target.
        assert_eq!(downshift_for_pressure(&targets, 5.5, 0.95), 3.5);
        assert_eq!(downshift_for_pressure(&targets, 5.5, 1.0), 3.5);
        // Already at the lowest rung: nothing below to shift to.
        assert_eq!(downshift_for_pressure(&targets, 3.5, 0.99), 3.5);
        // Degenerate inputs pass through.
        assert_eq!(downshift_for_pressure(&[], 4.5, 0.99), 4.5);
        // A want below every resident target is never shifted UP.
        assert_eq!(downshift_for_pressure(&targets, 3.0, 0.99), 3.0);
    }

    #[test]
    fn scheme_overheads_ordered() {
        // With any mix of linear/JL estimators, the critical-path cost must
        // satisfy RP-only >= Hybrid >= Hybrid+Async (the Table 6 shape).
        use crate::model::calib::LinearCalib;
        let cfg = ModelConfig {
            name: "t".into(), vocab: 8, d_model: 16, n_layers: 2,
            n_heads: 2, d_ff: 24, max_seq: 8, rope_theta: 10000.0,
        };
        let linears: Vec<LinearCalib> = (0..cfg.n_linear())
            .map(|i| LinearCalib {
                l: 3, h: 4, p: 3.5, thr: 1.0,
                use_lin: i % 2 == 0, lin_a: 0.1, lin_b: 0.0, r2: 0.95,
            })
            .collect();
        let dp = DpllmConfig {
            model: "t".into(), budget: 5, tag: "3.50".into(), target: 3.5,
            k_proj: 64, linears, n_linear_estimators: 7, n_jl_estimators: 7,
        };
        let (rp, _) = estimator_critical_bytes(&cfg, &dp, EstScheme::RandomProjOnly);
        let (hy, _) = estimator_critical_bytes(&cfg, &dp, EstScheme::Hybrid);
        let (ha, _) = estimator_critical_bytes(&cfg, &dp, EstScheme::HybridAsync);
        assert!(rp >= hy && hy >= ha);
        assert!(rp > 0.0);
    }
}
