//! `dpllm` CLI subcommands.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::qos::{QosBudget, UtilizationSim};
use crate::coordinator::sched::{Request, SchedPolicy};
use crate::coordinator::service::{make_queue, CoreConfig, ServingEngine};
use crate::evalharness::{self, tasks, Method};
use crate::model::{art, Manifest, ModelAssets};
use crate::runtime::decode::EstMode;
use crate::runtime::Runtime;
use crate::server::Server;
use crate::tokenizer::Tokenizer;
use crate::util::cli::Args;

const HELP: &str = "\
dpllm — DP-LLM coordinator (NeurIPS 2025 reproduction)

USAGE: dpllm <subcommand> [--flags]

  generate   --model M --target T --prompt P [--max-new N] [--budget B]
  serve      --model M [--addr HOST:PORT] [--targets 3.50,4.00,4.50] [--budget B]
             [--replicas N] [--replica-tiers \"3.25,3.50|4.50,4.75\"]
             [--reselect-every N] [--gamma-cap N] [--no-spec] [--no-batch]
             [--eos-token ID] [--kv-budget BYTES] [--trace-out PATH]
             (--trace-out enables the flight recorder and writes the
             Chrome trace-event JSON — Perfetto-loadable — to PATH on
             shutdown; DPLLM_TRACE=1 enables recording without a dump
             file (scrape GET /trace instead); DPLLM_LOG filters
             structured logs, e.g. DPLLM_LOG=warn,router=debug;
             speculative decoding + re-selection cadence knobs; env
             equivalents DPLLM_RESELECT_EVERY / DPLLM_GAMMA_CAP /
             DPLLM_NO_SPEC / DPLLM_NO_BATCH; --eos-token 258 stops
             generations at the byte tokenizer's <eos> on every path;
             --kv-budget caps the paged KV pool in bytes — accepts k/m/g
             suffixes, e.g. --kv-budget 64m; env DPLLM_KV_BUDGET_BYTES.
             DPLLM_NO_PREFIX_CACHE=1 disables the shared-prefix cache.
             --replicas N > 1 serves a precision-tiered fleet behind one
             router: each replica materializes a slice of the ladder —
             --replica-tiers pins the slices, pipe-separated — the upper
             half of the fleet takes tight-SLO traffic, and idle replicas
             steal backlog; see DESIGN.md §Scale-out)
  eval-ppl   --model M --method dpllm|hawq_v2|llm_mq|uniform --target T
             [--dataset synthwiki|synthweb] [--budget B] [--tokens N] [--exact]
  eval-task  --model M --task arith|listfn|dates|algebra --target T [--budget B]
  qos-sim    --model M [--requests N] [--budget B] [--util-max F]
  reassign   --model M --target T [--cap B]   (re-solve a static assignment
             from the Fisher sensitivities, Rust-side — no Python round trip)
  pack       --model M [--out PATH]   (repack the legacy anyprec.npz into the
             versioned anyprec.dpak container: 64-byte-aligned sections,
             per-section + per-layer CRC digests, mmap zero-copy loads,
             tier-sliced residency; serving prefers it automatically)
  inspect    --file PATH | --model M   (verify every DPAK section + layer
             digest and print the manifest summary as JSON)
  info       (artifact inventory)
";

pub fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = Args::parse(&args[1.min(args.len())..]);
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "generate" => generate(&rest),
        "serve" => serve(&rest),
        "eval-ppl" => eval_ppl(&rest),
        "eval-task" => eval_task(&rest),
        "qos-sim" => qos_sim(&rest),
        "reassign" => reassign(&rest),
        "pack" => pack(&rest),
        "inspect" => inspect(&rest),
        "info" => info(),
        other => bail!("unknown subcommand '{other}' (try 'help')"),
    }
}

fn method_from(args: &Args) -> Result<Method> {
    let target = args.f64_or("target", 4.0);
    Ok(match args.get_or("method", "dpllm").as_str() {
        "dpllm" => Method::Dpllm { tag: format!("{target:.2}") },
        "uniform" => Method::Uniform { bits: target as u8 },
        m @ ("hawq_v2" | "llm_mq") => {
            Method::Static { method: m.to_string(), target }
        }
        other => bail!("unknown method {other}"),
    })
}

fn generate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "dpl-tiny");
    let budget = args.usize_or("budget", 5) as u32;
    let target = args.f64_or("target", 4.0);
    let prompt = args.req("prompt")?.to_string();
    let rt = Arc::new(Runtime::new()?);
    let assets = ModelAssets::load(&model)?;
    let manifest = Manifest::load()?;
    let m = Method::Dpllm { tag: format!("{target:.2}") };
    let session = evalharness::build_session(&rt, &assets, &manifest, budget, &m)?;
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"]))?;
    let (text, bits) = tasks::generate(&session, &tok, &prompt,
                                       args.usize_or("max-new", 48),
                                       EstMode::Approx)?;
    println!("{text}");
    eprintln!("[target {target} | effective bits {bits:.3}]");
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "dpl-tiny");
    let budget = args.usize_or("budget", 5) as u32;
    let addr = args.get_or("addr", "127.0.0.1:8077");
    let targets_s = args.get_or("targets", "3.25,3.50,4.00,4.50,4.75");
    let tags: Vec<String> = targets_s
        .split(',')
        .map(|t| format!("{:.2}", t.trim().parse::<f64>().unwrap_or(4.0)))
        .collect();
    let tag_refs: Vec<&str> = tags.iter().map(String::as_str).collect();
    // KV pool byte budget: the flag wins over DPLLM_KV_BUDGET_BYTES by
    // setting it before the engine (and its pool) loads.
    if let Some(b) = args.get("kv-budget") {
        let bytes = crate::runtime::kvpool::parse_bytes(b)?;
        std::env::set_var("DPLLM_KV_BUDGET_BYTES", bytes.to_string());
    }
    // Scheduling knobs: env defaults (CoreConfig::from_env) with CLI
    // flags layered on top.
    let mut cc = CoreConfig::from_env();
    cc.reselect_every = args.usize_or("reselect-every",
                                      cc.reselect_every as usize).max(1) as u64;
    cc.gamma_cap = args.usize_or("gamma-cap", cc.gamma_cap);
    if args.has("no-spec") {
        cc.spec = false;
    }
    if args.has("no-batch") {
        cc.max_batch = 1;
    }
    // Opt-in EOS termination, applied uniformly to every decode path
    // (plain / batched / speculative) — e.g. --eos-token 258, the byte
    // tokenizer's <eos> id.
    if let Some(t) = args.get("eos-token").and_then(|s| s.parse::<u32>().ok()) {
        cc.eos_token = Some(t);
    }
    eprintln!(
        "[serve] core config: reselect_every={} gamma_cap={} spec={} \
         max_batch={}",
        cc.reselect_every, cc.gamma_cap, cc.spec,
        if cc.max_batch == usize::MAX { "∞".to_string() }
        else { cc.max_batch.to_string() }
    );
    let replicas = args.usize_or("replicas", 1).max(1);
    if replicas > 1 {
        // Fleet path: every replica thread builds its own Runtime +
        // engine over the shared assets, so no engine loads here.
        return serve_fleet(args, &model, budget, &addr, &tags, replicas, cc);
    }
    let rt = Arc::new(Runtime::new()?);
    let engine = ServingEngine::load(&rt, &model, budget, &tag_refs)?;
    eprintln!("[serve] adaptation set: {:?}", engine.targets());
    let mut server = Server::new(engine, UtilizationSim::new(7, 0.5))
        .with_core_config(cc);
    if let Some(path) = args.get("trace-out") {
        server = server.with_trace_out(path.into());
    }
    server.serve(&addr)
}

/// `serve --replicas N`: one front-of-house [`Router`] over N replica
/// workers, each with its own `Runtime` + `ServingCore` over a slice of
/// the precision ladder, all sharing one `Arc<ModelAssets>` (weights are
/// mmap-backed — replicas materialize only their own slice).  The upper
/// half of the fleet is the premium (tight-SLO, high-bit) tier.
fn serve_fleet(args: &Args, model: &str, budget: u32, addr: &str,
               tags: &[String], replicas: usize, cc: CoreConfig)
               -> Result<()> {
    use crate::coordinator::router::{
        parse_replica_tiers, split_tiers, Router, RouterConfig,
    };
    use crate::costmodel::{weight_bytes_at, JETSON_ORIN};
    use crate::runtime::replica::{engine_link, ReplicaSpec};
    use crate::server::RouterServer;

    let slices = match args.get("replica-tiers") {
        Some(spec) => {
            let s = parse_replica_tiers(spec)?;
            if s.len() != replicas {
                bail!("--replica-tiers has {} slices but --replicas is {}",
                      s.len(), replicas);
            }
            s
        }
        None => split_tiers(tags, replicas),
    };
    if slices.len() != replicas {
        // split_tiers clamps to one tag per replica minimum.
        eprintln!("[serve] only {} ladder members — fleet clamped to {} \
                   replicas", tags.len(), slices.len());
    }
    let assets = Arc::new(ModelAssets::load(model)?);
    let specs: Vec<ReplicaSpec> = slices
        .iter()
        .enumerate()
        .map(|(i, slice)| {
            let targets: Vec<f64> = slice
                .iter()
                .map(|t| t.parse::<f64>().unwrap_or(4.0))
                .collect();
            // Expected-delay unit: modeled stream time of this
            // replica's cheapest member (no engine needed).
            let cheapest = targets.iter().copied().fold(f64::INFINITY, f64::min);
            let tpot_ms =
                JETSON_ORIN.stream_ms(weight_bytes_at(&assets.store, cheapest));
            ReplicaSpec {
                id: i,
                model: model.to_string(),
                budget,
                tags: slice.clone(),
                targets,
                // From the clamped fleet size, not the requested
                // --replicas: split_tiers may shrink the fleet to the
                // ladder length, and `i >= replicas / 2` would then
                // leave the whole fleet economy.
                premium: i >= slices.len() / 2,
                tpot_ms,
                core: cc.clone(),
                heartbeat_ms: 200,
            }
        })
        .collect();
    for s in &specs {
        eprintln!(
            "[serve] replica {}: tier {:?} ({}) modeled tpot {:.2} ms",
            s.id, s.tags, if s.premium { "premium" } else { "economy" },
            s.tpot_ms
        );
    }
    let spawn_assets = assets.clone();
    let router = Router::new(
        specs,
        Box::new(move |spec| engine_link(spec, spawn_assets.clone())),
        RouterConfig::default(),
    );
    let mut server = RouterServer::new(router);
    if let Some(path) = args.get("trace-out") {
        server = server.with_trace_out(path.into());
    }
    server.serve(addr)
}

fn eval_ppl(args: &Args) -> Result<()> {
    let model = args.get_or("model", "dpl-tiny");
    let budget = args.usize_or("budget", 5) as u32;
    let dataset = args.get_or("dataset", "synthwiki");
    let method = method_from(args)?;
    let rt = Arc::new(Runtime::new()?);
    let assets = ModelAssets::load(&model)?;
    let manifest = Manifest::load()?;
    let session = evalharness::build_session(&rt, &assets, &manifest, budget, &method)?;
    let stream = evalharness::load_stream(&dataset)?;
    let mode = if args.has("exact") { EstMode::Exact } else { EstMode::Approx };
    let res = evalharness::perplexity(
        &session, &stream, evalharness::eval_chunk_default(),
        args.usize_or("tokens", evalharness::eval_tokens_default()), mode)?;
    println!(
        "{} {} {}: ppl {:.4} (eff bits {:.3}, {:.1} ms/tok, {} tokens)",
        model, dataset, method.label(), res.ppl, res.effective_bits,
        res.ms_per_token, res.tokens
    );
    Ok(())
}

fn eval_task(args: &Args) -> Result<()> {
    let model = args.get_or("model", "dpl-tiny");
    let budget = args.usize_or("budget", 5) as u32;
    let task = args.get_or("task", "arith");
    let method = method_from(args)?;
    let rt = Arc::new(Runtime::new()?);
    let assets = ModelAssets::load(&model)?;
    let manifest = Manifest::load()?;
    let session = evalharness::build_session(&rt, &assets, &manifest, budget, &method)?;
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"]))?;
    let res = tasks::eval_task(&session, &tok, &task,
                               args.usize_or("samples", tasks::task_eval_limit()),
                               EstMode::Approx)?;
    println!(
        "{} {} {}: {:.1}% ({} samples, {} skipped, eff bits {:.3})",
        model, task, method.label(), res.accuracy, res.n, res.skipped,
        res.effective_bits
    );
    Ok(())
}

fn qos_sim(args: &Args) -> Result<()> {
    let model = args.get_or("model", "dpl-tiny");
    let budget = args.usize_or("budget", 5) as u32;
    let n = args.usize_or("requests", 12);
    let rt = Arc::new(Runtime::new()?);
    let engine = ServingEngine::load(&rt, &model, budget,
                                     &["3.25", "3.50", "4.00", "4.50", "4.75"])?;
    let mut util = UtilizationSim::new(11, args.f64_or("util-max", 0.6));
    let prompts = tasks::load_task("instruct")?;
    let mut rngi = 0usize;
    let reqs = (0..n).map(|i| {
        let p = &prompts[i % prompts.len()];
        rngi += 1;
        let qos = if i % 3 == 0 {
            QosBudget::best_effort()
        } else {
            QosBudget::tight(30.0 + (i % 5) as f64 * 40.0)
        };
        Request::new(i as u64, p.prompt.clone(), 32, qos)
    });
    let mut queue = make_queue(SchedPolicy::Edf, reqs);
    let outcomes = engine.run_queue(&mut queue, &mut util)?;
    for o in &outcomes {
        println!(
            "req {:>3}: target {:.2} eff {:.3} tpot {:.1} ms  {} toks",
            o.id, o.target_precision, o.effective_bits,
            o.decode_ms / o.output_tokens.max(1) as f64, o.output_tokens
        );
    }
    println!("{}", engine.metrics.summary().report());
    Ok(())
}

/// Runtime adaptation-set reconfiguration: re-solve the static
/// mixed-precision assignment in Rust from the exported sensitivities
/// (used when the device's memory budget changes while serving).
fn reassign(args: &Args) -> Result<()> {
    use crate::selector::assign::problem_from_artifacts;
    let model = args.get_or("model", "dpl-tiny");
    let target = args.f64_or("target", 4.0);
    let cap = args.get("cap").and_then(|s| s.parse::<u8>().ok());
    let problem = problem_from_artifacts(&model)?;
    let caps = cap.map(|c| vec![c; problem.m.len()]);
    let bits = problem.solve(target, caps.as_deref())?;
    let avg: f64 = bits.iter().zip(&problem.m)
        .map(|(&b, &m)| b as f64 * m).sum::<f64>()
        / problem.m.iter().sum::<f64>();
    println!("reassigned {model} to avg {avg:.3} bits (target {target}):");
    for (i, chunk) in bits.chunks(7).enumerate() {
        println!("  block {i:>2}: {chunk:?}");
    }
    Ok(())
}

/// `dpllm pack`: repack a model's legacy `anyprec.npz` into the DPAK
/// container.  Loads the npz directly (NOT `ModelAssets::load`, which
/// would prefer an existing `.dpak`) so repacking is idempotent.
fn pack(args: &Args) -> Result<()> {
    use crate::anyprec::{dpak, AnyPrecStore};
    let model = args.get_or("model", "dpl-tiny");
    let npz = art(&["models", &model, "anyprec.npz"]);
    let out = args.get("out").map(String::from)
        .unwrap_or_else(|| ModelAssets::dpak_path(&model));
    let store = AnyPrecStore::load(&npz)?;
    let meta = dpak::write(&store, &model, &out)?;
    let bytes = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!("packed {npz} -> {out}");
    println!("  model {} version {} max_bits {} ({:.1} MB)",
             meta.model, meta.version, meta.max_bits, bytes as f64 / 1e6);
    Ok(())
}

/// `dpllm inspect`: deep-verify a DPAK container (every section and
/// per-layer digest) and print its manifest summary as JSON.
fn inspect(args: &Args) -> Result<()> {
    use crate::anyprec::dpak;
    let path = match args.get("file") {
        Some(p) => p.to_string(),
        None => ModelAssets::dpak_path(&args.get_or("model", "dpl-tiny")),
    };
    let j = dpak::inspect(&path)?;
    println!("{}", j.dump());
    Ok(())
}

fn info() -> Result<()> {
    let manifest = Manifest::load()?;
    println!("artifacts root: {}", crate::model::artifacts_root().display());
    for m in manifest.models() {
        let assets = ModelAssets::load(&m)?;
        println!(
            "  {m}: d={} L={} vocab={} | anyprec capacity 3b={:.1}MB 6b={:.1}MB",
            assets.cfg.d_model, assets.cfg.n_layers, assets.cfg.vocab,
            assets.store.capacity_bytes(3) as f64 / 1e6,
            assets.store.capacity_bytes(6) as f64 / 1e6,
        );
    }
    Ok(())
}
