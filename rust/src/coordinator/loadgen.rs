//! Trace-driven load generation + end-to-end SLO metering
//! (DESIGN.md §Evaluation).
//!
//! Every bench before this one was micro or steady-state; this module is
//! the missing piece the ROADMAP calls "honest evaluation": a
//! deterministic, seed-replayable query stream shaped like production
//! traffic — Poisson / bursty (MMPP on/off) / diurnal arrivals, long-tail
//! prompt/output lengths (lognormal body + Pareto tail, capped at
//! `max_seq`), and mixed SLO classes layered on the existing
//! [`WorkloadSpec`]/[`QosClass`] machinery — plus the replay drivers that
//! push it through a single [`ServingCore`] or the [`Router`] fleet and
//! meter what the paper's §6.3 experiments meter: goodput (tokens/s from
//! requests that met their SLO), per-class SLO attainment, nearest-rank
//! p50/p99/p999 TTFT and ITL, and a Jain fairness index.
//!
//! Everything here is plain host-side data: the same [`Trace`] replays
//! against simulated replica workers (hermetic tests, the artifact-free
//! `serving_trace` bench cells) and against real engines (the
//! artifact-gated cell) without changing a single metric definition.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::qos::UtilizationSim;
use super::router::{Router, RouterEvent};
use super::sched::Request;
use super::service::{is_capacity_reject, CoreEvent, ServingCore};
use super::workload::{QosClass, WorkloadSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{tail_percentiles, TailPercentiles};

// ---------------------------------------------------------------------------
// Arrival processes
// ---------------------------------------------------------------------------

/// The arrival-time model of a trace.  All three are sampled with the
/// deterministic [`Rng`], so a `(process, seed)` pair always produces the
/// identical arrival sequence.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant rate — the baseline open-loop
    /// load every queueing result assumes.
    Poisson { rate_per_s: f64 },
    /// Markov-modulated Poisson process with ON/OFF phases: arrivals at
    /// `rate_on` during ON dwells, `rate_off` during OFF dwells, with
    /// exponentially distributed dwell times.  Its window-count variance
    /// exceeds Poisson's (index of dispersion > 1) — the bursty traffic
    /// that actually breaks tail latency.
    Bursty {
        rate_on: f64,
        rate_off: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
    /// Non-homogeneous Poisson with a sinusoidal rate
    /// `λ(t) = base·(1 + amplitude·sin(2πt/period))`, sampled by Lewis
    /// thinning — the slow day/night swell under which reconfiguration
    /// policies earn their keep.  `amplitude` is clamped to `[0, 1]`.
    Diurnal {
        base_per_s: f64,
        amplitude: f64,
        period_s: f64,
    },
}

impl ArrivalProcess {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }

    /// Long-run mean arrival rate (requests/s) — capacity planning and
    /// the share-validation hand-off to [`WorkloadSpec`].
    pub fn mean_rate_per_s(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => rate_per_s,
            ArrivalProcess::Bursty {
                rate_on,
                rate_off,
                mean_on_s,
                mean_off_s,
            } => {
                let span = (mean_on_s + mean_off_s).max(1e-12);
                (rate_on * mean_on_s + rate_off * mean_off_s) / span
            }
            ArrivalProcess::Diurnal { base_per_s, .. } => base_per_s,
        }
    }

    fn validate(&self) -> Result<()> {
        let ok = |x: f64| x.is_finite() && x >= 0.0;
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => {
                if !ok(rate_per_s) || rate_per_s == 0.0 {
                    bail!("poisson rate must be finite and positive");
                }
            }
            ArrivalProcess::Bursty {
                rate_on,
                rate_off,
                mean_on_s,
                mean_off_s,
            } => {
                if !ok(rate_on) || !ok(rate_off) || rate_on.max(rate_off) == 0.0 {
                    bail!("bursty rates must be finite, >= 0, not both 0");
                }
                let pos = |x: f64| x.is_finite() && x > 0.0;
                if !pos(mean_on_s) || !pos(mean_off_s) {
                    bail!("bursty dwell means must be positive");
                }
            }
            ArrivalProcess::Diurnal { base_per_s, period_s, .. } => {
                let pos = |x: f64| x.is_finite() && x > 0.0;
                if !pos(base_per_s) || !pos(period_s) {
                    bail!("diurnal base rate and period must be positive");
                }
            }
        }
        Ok(())
    }

    /// Sample `n` arrival offsets (ms from trace start, nondecreasing).
    pub fn arrivals_ms(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => {
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exp(rate_per_s) * 1e3;
                    out.push(t);
                }
            }
            ArrivalProcess::Bursty {
                rate_on,
                rate_off,
                mean_on_s,
                mean_off_s,
            } => {
                let mut t_s = 0.0;
                let mut on = true;
                let mut phase_end = rng.exp(1.0 / mean_on_s);
                while out.len() < n {
                    let rate = if on { rate_on } else { rate_off };
                    // rng.exp(0) is +inf, so an idle OFF phase simply
                    // fast-forwards to its dwell boundary.
                    let dt = rng.exp(rate);
                    if t_s + dt >= phase_end {
                        // Phase flip: the exponential is memoryless, so
                        // redrawing at the new rate is exact.
                        t_s = phase_end;
                        on = !on;
                        let dwell = if on { mean_on_s } else { mean_off_s };
                        phase_end = t_s + rng.exp(1.0 / dwell);
                        continue;
                    }
                    t_s += dt;
                    out.push(t_s * 1e3);
                }
            }
            ArrivalProcess::Diurnal {
                base_per_s,
                amplitude,
                period_s,
            } => {
                let amp = amplitude.clamp(0.0, 1.0);
                let rate_max = base_per_s * (1.0 + amp);
                let mut t_s = 0.0;
                while out.len() < n {
                    // Lewis thinning: homogeneous candidates at the peak
                    // rate, accepted with probability λ(t)/λ_max.
                    t_s += rng.exp(rate_max);
                    let phase = 2.0 * std::f64::consts::PI * t_s / period_s;
                    let rate_t = base_per_s * (1.0 + amp * phase.sin());
                    if rng.f64() * rate_max <= rate_t.max(0.0) {
                        out.push(t_s * 1e3);
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Length distributions
// ---------------------------------------------------------------------------

/// Long-tail length model: a lognormal body with a Pareto tail, clamped
/// to `[min, cap]` — production prompt/output length histograms in two
/// moments plus a tail index.  `cap` is `max_seq` for prompts and the
/// per-request `max_new` for outputs, so a tail draw can never exceed
/// what the serving stack admits.
#[derive(Debug, Clone, Copy)]
pub struct LengthDist {
    /// Mean of `ln(len)` for the body (body median = `e^ln_mean`).
    pub ln_mean: f64,
    /// Stddev of `ln(len)` for the body.
    pub ln_sigma: f64,
    /// Probability a draw comes from the Pareto tail instead.
    pub tail_prob: f64,
    /// Pareto shape α (smaller = heavier tail); the scale is the body
    /// median, so the tail extends the body rather than replacing it.
    pub pareto_alpha: f64,
    pub min: usize,
    /// Inclusive upper clamp.
    pub cap: usize,
}

impl LengthDist {
    /// Prompt lengths: median ~48 tokens, σ=0.8, 5% Pareto(1.2) tail —
    /// the "mostly short, occasionally huge" shape of chat traffic.
    pub fn prompts(cap: usize) -> LengthDist {
        LengthDist {
            ln_mean: 48.0f64.ln(),
            ln_sigma: 0.8,
            tail_prob: 0.05,
            pareto_alpha: 1.2,
            min: 1,
            cap,
        }
    }

    /// Output lengths: median ~12 tokens, σ=0.6, 5% Pareto(1.5) tail.
    pub fn outputs(cap: usize) -> LengthDist {
        LengthDist {
            ln_mean: 12.0f64.ln(),
            ln_sigma: 0.6,
            tail_prob: 0.05,
            pareto_alpha: 1.5,
            min: 1,
            cap,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = if rng.bool(self.tail_prob) {
            let xm = self.ln_mean.exp();
            let u = rng.f64().max(1e-12);
            xm * u.powf(-1.0 / self.pareto_alpha.max(1e-6))
        } else {
            (self.ln_mean + self.ln_sigma * rng.normal()).exp()
        };
        let lo = self.min.max(1);
        let hi = self.cap.max(lo);
        (x.round() as usize).clamp(lo, hi)
    }
}

// ---------------------------------------------------------------------------
// SLO classes + trace spec
// ---------------------------------------------------------------------------

/// A [`QosClass`] plus the metering thresholds that decide whether a
/// completed request *counts*: goodput and attainment are computed
/// against these, while the embedded QoS budget/deadline keep steering
/// admission and scheduling exactly as before.
#[derive(Debug, Clone)]
pub struct TraceClass {
    pub name: String,
    pub qos: QosClass,
    /// TTFT SLO (ms); `INFINITY` = no first-token SLO.
    pub slo_ttft_ms: f64,
    /// Mean inter-token-latency SLO (ms/token); `INFINITY` = none.
    pub slo_itl_ms: f64,
}

/// Everything needed to synthesize a [`Trace`]: arrival model, length
/// models, and the SLO class table.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub arrival: ArrivalProcess,
    pub prompt_len: LengthDist,
    pub output_len: LengthDist,
    pub classes: Vec<TraceClass>,
}

impl TraceSpec {
    /// The standard mixed-SLO trace, layered on [`WorkloadSpec::mixed`]:
    /// the same three QoS classes (best-effort / tight-250 / tight-60 +
    /// 2 s deadline), with metering thresholds derived from each class's
    /// own budget (ITL SLO = `ms_per_token`, TTFT SLO = the EDF
    /// deadline).  Benches override the thresholds for sim-scale runs.
    pub fn mixed(arrival: ArrivalProcess, max_seq: usize, max_new: usize)
                 -> TraceSpec {
        let ws = WorkloadSpec::mixed(arrival.mean_rate_per_s(), max_new);
        let names = ["best_effort", "standard", "premium"];
        let classes = ws
            .classes
            .iter()
            .zip(names)
            .map(|(c, name)| TraceClass {
                name: name.to_string(),
                qos: *c,
                slo_ttft_ms: c.deadline_ms.unwrap_or(f64::INFINITY),
                slo_itl_ms: c.budget.ms_per_token,
            })
            .collect();
        TraceSpec {
            arrival,
            prompt_len: LengthDist::prompts(max_seq),
            output_len: LengthDist::outputs(max_new),
            classes,
        }
    }

    /// View the class table through [`WorkloadSpec::validated`] — one
    /// validation/normalization path for both the steady-state workload
    /// generator and the trace driver.
    fn normalized_shares(&self) -> Result<Vec<f64>> {
        let ws = WorkloadSpec {
            rate_per_s: self.arrival.mean_rate_per_s(),
            max_new: self.output_len.cap.max(1),
            classes: self.classes.iter().map(|c| c.qos).collect(),
        }
        .validated()
        .context("TraceSpec class table")?;
        Ok(ws.classes.iter().map(|c| c.share).collect())
    }

    /// Synthesize `n` requests.  Deterministic: the same `(spec, n,
    /// seed)` always yields the identical trace.
    pub fn generate(&self, n: usize, seed: u64) -> Result<Trace> {
        self.arrival.validate()?;
        let shares = self.normalized_shares()?;
        let mut rng = Rng::new(seed);
        let arrivals = self.arrival.arrivals_ms(n, &mut rng);
        let mut events = Vec::with_capacity(n);
        for at_ms in arrivals {
            let mut draw = rng.f64();
            let mut class = shares.len() - 1;
            for (i, s) in shares.iter().enumerate() {
                draw -= s;
                if draw <= 0.0 {
                    class = i;
                    break;
                }
            }
            events.push(TraceEvent {
                at_ms,
                class,
                prompt_tokens: self.prompt_len.sample(&mut rng),
                max_new: self.output_len.sample(&mut rng),
            });
        }
        Ok(Trace {
            arrival: self.arrival.name(),
            seed,
            classes: self.classes.clone(),
            events,
        })
    }
}

/// One synthetic request: plain data, materialized into a [`Request`]
/// only at its release instant so queue/TTFT metering stays honest.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Release offset from trace start (ms).
    pub at_ms: f64,
    /// Index into [`Trace::classes`].
    pub class: usize,
    pub prompt_tokens: usize,
    pub max_new: usize,
}

/// A fully synthesized, replayable trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub arrival: &'static str,
    pub seed: u64,
    pub classes: Vec<TraceClass>,
    pub events: Vec<TraceEvent>,
}

/// A synthetic prompt of roughly `tokens` tokens: single-character words
/// so sim replicas stay cheap while real tokenizers still see ~one token
/// per word.
pub fn synth_prompt(tokens: usize) -> String {
    let n = tokens.max(1);
    let mut s = String::with_capacity(2 * n);
    for i in 0..n {
        if i > 0 {
            s.push(' ');
        }
        s.push('t');
    }
    s
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total span of the arrival sequence (ms).
    pub fn duration_ms(&self) -> f64 {
        self.events.last().map(|e| e.at_ms).unwrap_or(0.0)
    }

    /// Materialize event `i` as a live [`Request`] — call at release
    /// time: the request's `arrival` stamp is `Instant::now()`.
    pub fn request(&self, i: usize) -> Request {
        let e = self.events[i];
        let c = &self.classes[e.class];
        let mut r = Request::new(
            i as u64,
            synth_prompt(e.prompt_tokens),
            e.max_new,
            c.qos.budget,
        );
        if let Some(d) = c.qos.deadline_ms {
            r = r.with_deadline(d);
        }
        r
    }
}

// ---------------------------------------------------------------------------
// Replay drivers
// ---------------------------------------------------------------------------

/// Replay pacing + safety rails.
#[derive(Debug, Clone, Copy)]
pub struct ReplayOpts {
    /// Wall-clock multiplier on trace timestamps: `0.01` replays a
    /// 100 s trace in ~1 s.  Service times are NOT scaled — compression
    /// raises the offered load, which is exactly what saturation cells
    /// want; report it alongside the results.
    pub time_scale: f64,
    /// Hard wall deadline: requests still pending when it passes are
    /// recorded as [`Terminal::Lost`] instead of hanging the harness.
    pub deadline: Duration,
}

impl Default for ReplayOpts {
    fn default() -> ReplayOpts {
        ReplayOpts { time_scale: 1.0, deadline: Duration::from_secs(60) }
    }
}

/// Terminal state of one replayed request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Terminal {
    /// Completed with an outcome.
    Done,
    /// Admission reject; `capacity: true` is the retryable 503 shape,
    /// `false` the malformed-request 400 shape.
    Rejected { capacity: bool },
    /// Aborted mid-flight.
    Failed,
    /// Never reached a terminal event before the replay deadline — a
    /// wedge; chaos gates assert this stays zero.
    Lost,
}

/// Per-request metering record.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub class: usize,
    pub terminal: Terminal,
    /// First-token latency as reported by the serving outcome (ms);
    /// NaN unless [`Terminal::Done`].
    pub ttft_ms: f64,
    /// Mean inter-token latency: `decode_ms / output_tokens` (ms); NaN
    /// unless [`Terminal::Done`].
    pub itl_ms: f64,
    pub tokens: usize,
    /// Submit → terminal wall latency (ms).
    pub latency_ms: f64,
}

struct ReplayState {
    recs: Vec<RequestRecord>,
    submitted: Vec<Option<Instant>>,
    terminal: usize,
}

impl ReplayState {
    fn new(trace: &Trace) -> ReplayState {
        ReplayState {
            recs: trace
                .events
                .iter()
                .map(|e| RequestRecord {
                    class: e.class,
                    terminal: Terminal::Lost,
                    ttft_ms: f64::NAN,
                    itl_ms: f64::NAN,
                    tokens: 0,
                    latency_ms: f64::NAN,
                })
                .collect(),
            submitted: vec![None; trace.events.len()],
            terminal: 0,
        }
    }

    fn latency_ms(&self, id: usize) -> f64 {
        self.submitted[id]
            .map(|t| t.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(f64::NAN)
    }

    /// Record a terminal state once; later duplicates are ignored (a
    /// request must reach exactly one terminal outcome).
    fn settle(&mut self, id: u64, make: impl FnOnce(&mut RequestRecord)) {
        let i = id as usize;
        if i >= self.recs.len() || self.recs[i].terminal != Terminal::Lost {
            return;
        }
        let lat = self.latency_ms(i);
        let r = &mut self.recs[i];
        r.latency_ms = lat;
        make(r);
        self.terminal += 1;
    }

    fn on_router_event(&mut self, ev: RouterEvent) {
        match ev {
            RouterEvent::Done { outcome, .. } => {
                self.settle(outcome.id, |r| {
                    r.terminal = Terminal::Done;
                    r.ttft_ms = outcome.ttft_ms;
                    r.tokens = outcome.output_tokens;
                    r.itl_ms =
                        outcome.decode_ms / outcome.output_tokens.max(1) as f64;
                });
            }
            RouterEvent::Failed { id, .. } => {
                self.settle(id, |r| r.terminal = Terminal::Failed);
            }
            RouterEvent::Rejected { id, capacity, .. } => {
                self.settle(id, |r| r.terminal = Terminal::Rejected { capacity });
            }
            RouterEvent::Respawned { .. } => {}
        }
    }

    fn on_core_event(&mut self, ev: CoreEvent) {
        match ev {
            CoreEvent::Done(outcome) => {
                self.settle(outcome.id, |r| {
                    r.terminal = Terminal::Done;
                    r.ttft_ms = outcome.ttft_ms;
                    r.tokens = outcome.output_tokens;
                    r.itl_ms =
                        outcome.decode_ms / outcome.output_tokens.max(1) as f64;
                });
            }
            CoreEvent::Failed { id, .. } => {
                self.settle(id, |r| r.terminal = Terminal::Failed);
            }
            CoreEvent::Error { id, capacity, .. } => {
                self.settle(id, |r| {
                    r.terminal = Terminal::Rejected { capacity };
                });
            }
            CoreEvent::Token { .. } => {}
        }
    }
}

/// Replay `trace` through the [`Router`] fleet: release each request at
/// `at_ms · time_scale`, poll terminal events, meter everything.  The
/// router is left running (callers shut it down) so counters can be read
/// after the report.
pub fn replay_fleet(trace: &Trace, router: &mut Router, opts: &ReplayOpts)
                    -> TraceReport {
    let n = trace.events.len();
    let replicas = router.alive_count();
    let mut st = ReplayState::new(trace);
    let start = Instant::now();
    let hard = start + opts.deadline;
    let mut next = 0usize;
    while st.terminal < n {
        if Instant::now() > hard {
            break; // unfinished requests stay Lost
        }
        let now_ms = start.elapsed().as_secs_f64() * 1e3;
        while next < n && trace.events[next].at_ms * opts.time_scale <= now_ms {
            let req = trace.request(next);
            st.submitted[next] = Some(Instant::now());
            if let Some(ev) = router.submit(req, None) {
                st.on_router_event(ev);
            }
            next += 1;
        }
        for ev in router.poll() {
            st.on_router_event(ev);
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    let wall_s = start.elapsed().as_secs_f64();
    build_report(trace, &st.recs, wall_s, replicas)
}

/// Replay `trace` through a single [`ServingCore`] (the artifact-gated
/// path): release due requests into a FIFO, admit while the core has
/// slot capacity, step the core, meter.  Admission errors are terminal
/// for that request only (the PR 5 contract).  `util` feeds the
/// QoS → precision policy exactly as the serving loop does.
pub fn replay_core(trace: &Trace, core: &mut ServingCore,
                   util: &mut UtilizationSim, opts: &ReplayOpts)
                   -> TraceReport {
    let n = trace.events.len();
    let mut st = ReplayState::new(trace);
    let start = Instant::now();
    let hard = start + opts.deadline;
    let mut next = 0usize;
    let mut pending: VecDeque<Request> = VecDeque::new();
    while st.terminal < n {
        if Instant::now() > hard {
            break;
        }
        let now_ms = start.elapsed().as_secs_f64() * 1e3;
        while next < n && trace.events[next].at_ms * opts.time_scale <= now_ms {
            st.submitted[next] = Some(Instant::now());
            pending.push_back(trace.request(next));
            next += 1;
        }
        while core.has_capacity() && !pending.is_empty() {
            let req = pending.pop_front().expect("nonempty pending");
            let id = req.id;
            if let Err(e) = core.admit(req, util.tick()) {
                let capacity = is_capacity_reject(&e);
                st.settle(id, |r| {
                    r.terminal = Terminal::Rejected { capacity };
                });
            }
        }
        if core.has_active() {
            match core.step() {
                Ok(events) => {
                    for ev in events {
                        st.on_core_event(ev);
                    }
                }
                Err(e) => {
                    // PR 5 contract: loop-level errors keep serving;
                    // per-request failures already surfaced as events.
                    eprintln!("[replay_core] step error: {e:#}");
                }
            }
        } else {
            // Nothing active: wait briefly for the next release (or for
            // the wall deadline to flag whatever never settled).
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    build_report(trace, &st.recs, wall_s, 1)
}

// ---------------------------------------------------------------------------
// Metering
// ---------------------------------------------------------------------------

/// Per-class slice of the report.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub name: String,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub failed: usize,
    pub lost: usize,
    /// Completed requests that met both SLO thresholds.
    pub slo_met: usize,
    /// `slo_met / submitted` (1.0 for an empty class).
    pub attainment: f64,
    /// Tokens/s from SLO-meeting requests of this class.
    pub goodput_tok_s: f64,
    /// Nearest-rank TTFT tails over completed requests.
    pub ttft: Option<TailPercentiles>,
    /// Nearest-rank mean-ITL tails over completed requests.
    pub itl: Option<TailPercentiles>,
}

/// The full replay report — everything `BENCH_serving_trace.json` emits.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub arrival: String,
    pub replicas: usize,
    pub requests: usize,
    pub wall_s: f64,
    /// Tokens produced by completed requests.
    pub tokens: usize,
    /// All completed tokens / wall time.
    pub throughput_tok_s: f64,
    /// Tokens from SLO-meeting requests / wall time — the headline.
    pub goodput_tok_s: f64,
    /// Overall `slo_met / submitted`.
    pub slo_attainment: f64,
    /// Jain index `(Σx)²/(n·Σx²)` over per-request service rates
    /// (tokens per second of wall latency) of completed requests;
    /// 1.0 = perfectly even service, →1/n = one request starves the
    /// rest.  1.0 when fewer than two requests completed.
    pub jain_fairness: f64,
    pub lost: usize,
    pub classes: Vec<ClassReport>,
}

/// Jain fairness index over nonnegative rates.
pub fn jain_index(rates: &[f64]) -> f64 {
    let xs: Vec<f64> = rates
        .iter()
        .copied()
        .filter(|x| x.is_finite() && *x >= 0.0)
        .collect();
    if xs.len() < 2 {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    if sumsq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sumsq)
}

fn build_report(trace: &Trace, recs: &[RequestRecord], wall_s: f64,
                replicas: usize) -> TraceReport {
    let wall = wall_s.max(1e-9);
    let mut classes = Vec::with_capacity(trace.classes.len());
    let (mut tokens_all, mut good_tokens, mut met_all, mut lost_all) =
        (0usize, 0usize, 0usize, 0usize);
    for (ci, tc) in trace.classes.iter().enumerate() {
        let mine: Vec<&RequestRecord> =
            recs.iter().filter(|r| r.class == ci).collect();
        let mut ttft = Vec::new();
        let mut itl = Vec::new();
        let (mut completed, mut rejected, mut failed, mut lost) =
            (0usize, 0usize, 0usize, 0usize);
        let (mut slo_met, mut class_good) = (0usize, 0usize);
        for r in &mine {
            match r.terminal {
                Terminal::Done => {
                    completed += 1;
                    tokens_all += r.tokens;
                    ttft.push(r.ttft_ms);
                    itl.push(r.itl_ms);
                    if r.ttft_ms <= tc.slo_ttft_ms && r.itl_ms <= tc.slo_itl_ms
                    {
                        slo_met += 1;
                        class_good += r.tokens;
                    }
                }
                Terminal::Rejected { .. } => rejected += 1,
                Terminal::Failed => failed += 1,
                Terminal::Lost => lost += 1,
            }
        }
        met_all += slo_met;
        good_tokens += class_good;
        lost_all += lost;
        classes.push(ClassReport {
            name: tc.name.clone(),
            submitted: mine.len(),
            completed,
            rejected,
            failed,
            lost,
            slo_met,
            attainment: if mine.is_empty() {
                1.0
            } else {
                slo_met as f64 / mine.len() as f64
            },
            goodput_tok_s: class_good as f64 / wall,
            ttft: tail_percentiles(&ttft),
            itl: tail_percentiles(&itl),
        });
    }
    let rates: Vec<f64> = recs
        .iter()
        .filter(|r| r.terminal == Terminal::Done && r.latency_ms > 0.0)
        .map(|r| r.tokens as f64 / (r.latency_ms / 1e3))
        .collect();
    TraceReport {
        arrival: trace.arrival.to_string(),
        replicas,
        requests: recs.len(),
        wall_s,
        tokens: tokens_all,
        throughput_tok_s: tokens_all as f64 / wall,
        goodput_tok_s: good_tokens as f64 / wall,
        slo_attainment: if recs.is_empty() {
            1.0
        } else {
            met_all as f64 / recs.len() as f64
        },
        jain_fairness: jain_index(&rates),
        lost: lost_all,
        classes,
    }
}

impl TraceReport {
    /// The JSON cell `serving_trace` emits.  Tail percentiles of a class
    /// with zero completions are emitted as 0.0 (check `completed`).
    pub fn to_json(&self) -> Json {
        let tails = |o: &mut Json, prefix: &str, t: Option<TailPercentiles>| {
            let t = t.unwrap_or(TailPercentiles {
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                p999: 0.0,
            });
            o.set(&format!("{prefix}_p50_ms"), t.p50)
                .set(&format!("{prefix}_p90_ms"), t.p90)
                .set(&format!("{prefix}_p99_ms"), t.p99)
                .set(&format!("{prefix}_p999_ms"), t.p999);
        };
        let mut cls = Vec::with_capacity(self.classes.len());
        for c in &self.classes {
            let mut o = Json::obj();
            o.set("name", c.name.as_str())
                .set("submitted", c.submitted)
                .set("completed", c.completed)
                .set("rejected", c.rejected)
                .set("failed", c.failed)
                .set("lost", c.lost)
                .set("slo_met", c.slo_met)
                .set("slo_attainment", c.attainment)
                .set("goodput_tok_s", c.goodput_tok_s);
            tails(&mut o, "ttft", c.ttft);
            tails(&mut o, "itl", c.itl);
            cls.push(o);
        }
        let mut j = Json::obj();
        j.set("arrival", self.arrival.as_str())
            .set("replicas", self.replicas)
            .set("requests", self.requests)
            .set("wall_s", self.wall_s)
            .set("tokens", self.tokens)
            .set("throughput_tok_s", self.throughput_tok_s)
            .set("goodput_tok_s", self.goodput_tok_s)
            .set("slo_attainment", self.slo_attainment)
            .set("jain_fairness", self.jain_fairness)
            .set("lost", self.lost)
            .set("classes", Json::Arr(cls));
        j
    }
}

/// Schema sanity check for one report cell: every required key present,
/// every number finite.  Runs as a unit test AND as the emitter's own
/// pre-write gate, so a broken emitter fails CI instead of writing
/// garbage into `results/BENCH_serving_trace.json`.
pub fn schema_check(j: &Json) -> Result<()> {
    j.req("arrival")?.as_str().context("arrival")?;
    for key in [
        "replicas",
        "requests",
        "wall_s",
        "tokens",
        "throughput_tok_s",
        "goodput_tok_s",
        "slo_attainment",
        "jain_fairness",
        "lost",
    ] {
        let v = j.req(key)?.as_f64().with_context(|| key.to_string())?;
        if !v.is_finite() {
            bail!("serving_trace schema: {key} = {v} not finite");
        }
    }
    let classes = j.req("classes")?.as_arr().context("classes")?;
    if classes.is_empty() {
        bail!("serving_trace schema: empty classes array");
    }
    for (i, c) in classes.iter().enumerate() {
        c.req("name")?.as_str().with_context(|| format!("class {i} name"))?;
        for key in [
            "submitted",
            "completed",
            "rejected",
            "failed",
            "lost",
            "slo_met",
            "slo_attainment",
            "goodput_tok_s",
            "ttft_p50_ms",
            "ttft_p90_ms",
            "ttft_p99_ms",
            "ttft_p999_ms",
            "itl_p50_ms",
            "itl_p90_ms",
            "itl_p99_ms",
            "itl_p999_ms",
        ] {
            let v = c
                .req(key)?
                .as_f64()
                .with_context(|| format!("class {i} {key}"))?;
            if !v.is_finite() {
                bail!("serving_trace schema: class {i} {key} = {v} not finite");
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::RouterConfig;
    use crate::runtime::replica::sim::{sim_link, SimProfile};
    use crate::runtime::replica::ReplicaSpec;

    fn poisson(rate: f64) -> ArrivalProcess {
        ArrivalProcess::Poisson { rate_per_s: rate }
    }

    fn bursty() -> ArrivalProcess {
        ArrivalProcess::Bursty {
            rate_on: 200.0,
            rate_off: 5.0,
            mean_on_s: 0.5,
            mean_off_s: 0.5,
        }
    }

    fn diurnal() -> ArrivalProcess {
        ArrivalProcess::Diurnal {
            base_per_s: 50.0,
            amplitude: 0.9,
            period_s: 10.0,
        }
    }

    /// Index of dispersion (variance/mean of window counts) — ≈1 for
    /// Poisson, >1 for bursty traffic.
    fn dispersion(arrivals_ms: &[f64], window_ms: f64) -> f64 {
        let span = arrivals_ms.last().copied().unwrap_or(0.0);
        let nwin = (span / window_ms).ceil().max(1.0) as usize;
        let mut counts = vec![0.0f64; nwin];
        for &t in arrivals_ms {
            let w = ((t / window_ms) as usize).min(nwin - 1);
            counts[w] += 1.0;
        }
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
            / counts.len() as f64;
        var / mean.max(1e-12)
    }

    #[test]
    fn arrivals_deterministic_per_seed() {
        for proc in [poisson(30.0), bursty(), diurnal()] {
            let a = proc.arrivals_ms(300, &mut Rng::new(7));
            let b = proc.arrivals_ms(300, &mut Rng::new(7));
            assert_eq!(a, b, "{} not seed-deterministic", proc.name());
            let c = proc.arrivals_ms(300, &mut Rng::new(8));
            assert_ne!(a, c, "{} ignores the seed", proc.name());
            for win in a.windows(2) {
                assert!(win[1] >= win[0], "{} non-monotonic", proc.name());
            }
        }
    }

    #[test]
    fn poisson_interarrival_mean_within_tolerance() {
        let rate = 50.0;
        let a = poisson(rate).arrivals_ms(4000, &mut Rng::new(3));
        let mean_gap_ms = a.last().unwrap() / a.len() as f64;
        let expect = 1e3 / rate;
        assert!(
            (mean_gap_ms - expect).abs() < 0.1 * expect,
            "mean gap {mean_gap_ms} ms, expected ~{expect} ms"
        );
    }

    #[test]
    fn bursty_dispersion_exceeds_poisson() {
        // Equal mean rates, same window: the MMPP must be visibly
        // burstier than Poisson.
        let rate = bursty().mean_rate_per_s();
        let pois = poisson(rate).arrivals_ms(3000, &mut Rng::new(5));
        let brst = bursty().arrivals_ms(3000, &mut Rng::new(5));
        let d_pois = dispersion(&pois, 100.0);
        let d_brst = dispersion(&brst, 100.0);
        assert!(d_pois < 2.0, "poisson dispersion {d_pois} implausibly high");
        assert!(
            d_brst > d_pois && d_brst > 1.5,
            "bursty dispersion {d_brst} not above poisson {d_pois}"
        );
    }

    #[test]
    fn diurnal_peak_half_outweighs_trough_half() {
        let a = diurnal().arrivals_ms(4000, &mut Rng::new(9));
        // sin > 0 on the first half of each period, < 0 on the second.
        let period_ms = 10_000.0;
        let (mut peak, mut trough) = (0usize, 0usize);
        for &t in &a {
            if (t % period_ms) < period_ms / 2.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} vs trough {trough}: no diurnal shape"
        );
    }

    #[test]
    fn lengths_clamped_and_long_tailed() {
        let d = LengthDist::prompts(4096);
        let mut rng = Rng::new(4);
        let mut xs = Vec::new();
        for _ in 0..4000 {
            let x = d.sample(&mut rng);
            assert!((1..=4096).contains(&x));
            xs.push(x as f64);
        }
        let med = crate::util::stats::percentile_nearest_rank(&xs, 50.0)
            .unwrap();
        let p99 = crate::util::stats::percentile_nearest_rank(&xs, 99.0)
            .unwrap();
        assert!(
            p99 > 3.0 * med,
            "p99 {p99} vs median {med}: tail not heavy"
        );
    }

    #[test]
    fn trace_generation_deterministic() {
        let spec = TraceSpec::mixed(bursty(), 512, 32);
        let a = spec.generate(500, 42).unwrap();
        let b = spec.generate(500, 42).unwrap();
        assert_eq!(a.len(), 500);
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.at_ms, y.at_ms);
            assert_eq!(x.class, y.class);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.max_new, y.max_new);
        }
        for e in &a.events {
            assert!(e.class < spec.classes.len());
            assert!((1..=512).contains(&e.prompt_tokens));
            assert!((1..=32).contains(&e.max_new));
        }
    }

    #[test]
    fn trace_spec_rejects_malformed_classes() {
        let mut spec = TraceSpec::mixed(poisson(10.0), 128, 16);
        spec.classes[0].qos.share = f64::NAN;
        assert!(spec.generate(10, 1).is_err());
        let mut spec = TraceSpec::mixed(poisson(10.0), 128, 16);
        spec.classes.clear();
        assert!(spec.generate(10, 1).is_err());
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_index(&[2.0, 2.0, 2.0]), 1.0);
        let skew = jain_index(&[10.0, 0.1, 0.1, 0.1]);
        assert!(skew < 0.5, "skewed rates should score low: {skew}");
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[5.0]), 1.0);
    }

    #[test]
    fn synth_prompt_token_count() {
        assert_eq!(synth_prompt(1), "t");
        assert_eq!(synth_prompt(3), "t t t");
        assert_eq!(synth_prompt(0), "t"); // never empty
    }

    /// Hermetic end-to-end: a small bursty trace through a 2-replica sim
    /// fleet — every request terminal, none lost, and the emitted JSON
    /// passes the schema gate (the satellite's emitter regression).
    #[test]
    fn replay_fleet_all_terminal_and_schema_valid() {
        let spec = TraceSpec::mixed(bursty(), 64, 8);
        let trace = spec.generate(80, 17).unwrap();
        let specs: Vec<ReplicaSpec> = (0..2)
            .map(|i| {
                ReplicaSpec::sim(i, &["3.50", "4.50"], i == 1, 0.05)
            })
            .collect();
        let mut router = Router::new(
            specs,
            Box::new(|spec| {
                sim_link(spec, SimProfile { token_us: 50, ..SimProfile::default() })
            }),
            RouterConfig::default(),
        );
        let report = replay_fleet(
            &trace,
            &mut router,
            &ReplayOpts {
                time_scale: 0.002,
                deadline: Duration::from_secs(20),
            },
        );
        router.shutdown();
        assert_eq!(report.requests, 80);
        assert_eq!(report.lost, 0, "requests lost in a healthy fleet");
        let done: usize = report.classes.iter().map(|c| c.completed).sum();
        let rejected: usize = report.classes.iter().map(|c| c.rejected).sum();
        let failed: usize = report.classes.iter().map(|c| c.failed).sum();
        assert_eq!(done + rejected + failed, 80);
        assert!(report.tokens > 0);
        assert!(report.throughput_tok_s > 0.0);
        assert!(report.jain_fairness > 0.0 && report.jain_fairness <= 1.0);
        let j = report.to_json();
        schema_check(&j).expect("schema");
        // And a broken cell must fail the gate.
        assert!(schema_check(&Json::obj()).is_err());
        let mut bad = report.clone();
        bad.jain_fairness = f64::NAN;
        assert!(schema_check(&bad.to_json()).is_err());
    }
}
