//! Token samplers for the decode loop: greedy, temperature, top-k.
//! (The eval harnesses use greedy for determinism; the serving path can
//! request sampled generation per query.)

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// Softmax sampling at `temperature` over the top `k` logits
    /// (k = 0 means no top-k truncation).
    TopK { k: usize, temperature: f64 },
}

impl Sampling {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match *self {
            Sampling::Greedy => argmax(logits),
            Sampling::TopK { k, temperature } => top_k(logits, k, temperature, rng),
        }
    }
}

/// NaN-safe greedy argmax — single implementation lives in
/// [`DecodeSession::argmax`]; this infallible wrapper keeps the sampler
/// signature (empty/all-NaN logits cannot occur on the sampling path,
/// where the decode step has already validated them).
pub fn argmax(logits: &[f32]) -> u32 {
    crate::runtime::decode::DecodeSession::argmax(logits).unwrap_or(0)
}

fn top_k(logits: &[f32], k: usize, temperature: f64, rng: &mut Rng) -> u32 {
    let temperature = temperature.max(1e-4);
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    let k = if k == 0 { logits.len() } else { k.min(logits.len()) };
    let cand = &idx[..k];
    let max = logits[cand[0]] as f64;
    let weights: Vec<f64> = cand
        .iter()
        .map(|&i| ((logits[i] as f64 - max) / temperature).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut draw = rng.f64() * total;
    for (w, &i) in weights.iter().zip(cand) {
        draw -= w;
        if draw <= 0.0 {
            return i as u32;
        }
    }
    cand[k - 1] as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::for_each_seed;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        let mut rng = Rng::new(0);
        assert_eq!(Sampling::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn low_temperature_converges_to_greedy() {
        let logits = vec![0.0, 5.0, 1.0, 4.9];
        for_each_seed(20, |rng| {
            let s = Sampling::TopK { k: 4, temperature: 1e-3 };
            assert_eq!(s.sample(&logits, rng), 1);
        });
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0, 9.0, -50.0, -60.0];
        for_each_seed(30, |rng| {
            let s = Sampling::TopK { k: 2, temperature: 2.0 };
            let t = s.sample(&logits, rng);
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        });
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = vec![1.0, 0.9, 0.8, 0.7];
        let mut rng = Rng::new(42);
        let s = Sampling::TopK { k: 0, temperature: 10.0 };
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&logits, &mut rng));
        }
        assert!(seen.len() >= 3, "only saw {seen:?}");
    }
}
