//! Token samplers for the decode loop: greedy, temperature, top-k.
//! (The eval harnesses use greedy for determinism; the serving path can
//! request sampled generation per query.)
//!
//! NaN safety is real here, not a note: a NaN logit (overflowed
//! activation, broken artifact) is *excluded from the candidate set* on
//! every path — greedy delegates to the NaN-skipping
//! [`DecodeSession::argmax`], and top-k filters NaNs before a
//! `f32::total_cmp` sort (no `partial_cmp(..).unwrap()` to panic the
//! comparator).  Empty or all-NaN logits are an `Err` the caller must
//! handle — silently emitting token 0 corrupted generations downstream.

use anyhow::{bail, Result};

use crate::runtime::decode::DecodeSession;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// Softmax sampling at `temperature` over the top `k` logits
    /// (k = 0 means no top-k truncation).
    TopK { k: usize, temperature: f64 },
}

impl Sampling {
    /// Sample one token.  `Err` on empty or all-NaN logits (both
    /// variants), propagated instead of silently emitting token 0.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> Result<u32> {
        match *self {
            Sampling::Greedy => argmax(logits),
            Sampling::TopK { k, temperature } => top_k(logits, k, temperature, rng),
        }
    }
}

/// NaN-safe greedy argmax — the single implementation lives in
/// [`DecodeSession::argmax`]; this wrapper keeps the sampler module's
/// name and now PROPAGATES the empty/all-NaN error instead of mapping it
/// to token 0 (the old `unwrap_or(0)` silently corrupted generations).
pub fn argmax(logits: &[f32]) -> Result<u32> {
    DecodeSession::argmax(logits)
}

fn top_k(logits: &[f32], k: usize, temperature: f64, rng: &mut Rng) -> Result<u32> {
    let temperature = temperature.max(1e-4);
    // NaN logits leave the candidate set entirely (the argmax rule);
    // total_cmp keys the sort so even a raced-in NaN cannot panic.
    let mut idx: Vec<usize> = (0..logits.len())
        .filter(|&i| !logits[i].is_nan())
        .collect();
    if idx.is_empty() {
        bail!("top-k over empty or all-NaN logits");
    }
    idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
    let k = if k == 0 { idx.len() } else { k.min(idx.len()) };
    let cand = &idx[..k];
    let max = logits[cand[0]] as f64;
    let weights: Vec<f64> = cand
        .iter()
        .map(|&i| ((logits[i] as f64 - max) / temperature).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut draw = rng.f64() * total;
    for (w, &i) in weights.iter().zip(cand) {
        draw -= w;
        if draw <= 0.0 {
            return Ok(i as u32);
        }
    }
    Ok(cand[k - 1] as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::for_each_seed;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        let mut rng = Rng::new(0);
        assert_eq!(Sampling::Greedy.sample(&logits, &mut rng).unwrap(), 1);
    }

    #[test]
    fn low_temperature_converges_to_greedy() {
        let logits = vec![0.0, 5.0, 1.0, 4.9];
        for_each_seed(20, |rng| {
            let s = Sampling::TopK { k: 4, temperature: 1e-3 };
            assert_eq!(s.sample(&logits, rng).unwrap(), 1);
        });
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0, 9.0, -50.0, -60.0];
        for_each_seed(30, |rng| {
            let s = Sampling::TopK { k: 2, temperature: 2.0 };
            let t = s.sample(&logits, rng).unwrap();
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        });
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = vec![1.0, 0.9, 0.8, 0.7];
        let mut rng = Rng::new(42);
        let s = Sampling::TopK { k: 0, temperature: 10.0 };
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&logits, &mut rng).unwrap());
        }
        assert!(seen.len() >= 3, "only saw {seen:?}");
    }

    /// Regression: NaN-laced logits used to panic the top-k sort's
    /// `partial_cmp(..).unwrap()`.  Now NaN entries simply leave the
    /// candidate set and sampling stays within the finite support.
    #[test]
    fn top_k_survives_nan_logits_and_excludes_them() {
        let logits = vec![f32::NAN, 10.0, f32::NAN, 9.0, f32::NAN];
        for_each_seed(40, |rng| {
            let s = Sampling::TopK { k: 2, temperature: 1.0 };
            let t = s.sample(&logits, rng).unwrap();
            assert!(t == 1 || t == 3, "sampled a NaN slot: {t}");
        });
        // k = 0 (no truncation) with NaNs present: same exclusion rule.
        let mut rng = Rng::new(7);
        let s = Sampling::TopK { k: 0, temperature: 5.0 };
        for _ in 0..50 {
            let t = s.sample(&logits, &mut rng).unwrap();
            assert!(t == 1 || t == 3, "sampled a NaN slot: {t}");
        }
    }

    /// Empty / all-NaN logits propagate as errors on BOTH variants — no
    /// silent token 0.
    #[test]
    fn degenerate_logits_error_instead_of_token_zero() {
        let mut rng = Rng::new(0);
        let all_nan = vec![f32::NAN; 4];
        assert!(Sampling::Greedy.sample(&all_nan, &mut rng).is_err());
        assert!(Sampling::TopK { k: 2, temperature: 1.0 }
            .sample(&all_nan, &mut rng)
            .is_err());
        assert!(Sampling::Greedy.sample(&[], &mut rng).is_err());
        assert!(Sampling::TopK { k: 0, temperature: 1.0 }
            .sample(&[], &mut rng)
            .is_err());
        assert!(argmax(&all_nan).is_err());
        // NaN-laced but not degenerate: argmax skips the NaNs.
        assert_eq!(argmax(&[f32::NAN, 2.0, 1.0]).unwrap(), 1);
    }
}
