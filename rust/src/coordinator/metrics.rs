//! Serving metrics: per-request records + percentile summaries
//! (powers the §6.3 per-query QoS study and the e2e example's report).

use std::sync::Mutex;

use crate::util::stats::{mean, percentile};

#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub target_precision: f64,
    pub effective_bits: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
}

impl RequestRecord {
    pub fn tpot_ms(&self) -> f64 {
        self.decode_ms / self.output_tokens.max(1) as f64
    }

    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.prefill_ms + self.decode_ms
    }
}

#[derive(Default)]
pub struct MetricsRegistry {
    records: Mutex<Vec<RequestRecord>>,
}

#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_tpot_ms: f64,
    pub p50_total_ms: f64,
    pub p90_total_ms: f64,
    pub p99_total_ms: f64,
    pub mean_eff_bits: f64,
    pub p90_eff_bits: f64,
    pub p99_eff_bits: f64,
    pub throughput_tok_s: f64,
    pub total_output_tokens: usize,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, r: RequestRecord) {
        self.records.lock().unwrap().push(r);
    }

    pub fn records(&self) -> Vec<RequestRecord> {
        self.records.lock().unwrap().clone()
    }

    pub fn summary(&self) -> Summary {
        let rs = self.records.lock().unwrap();
        let tpot: Vec<f64> = rs.iter().map(|r| r.tpot_ms()).collect();
        let total: Vec<f64> = rs.iter().map(|r| r.total_ms()).collect();
        let bits: Vec<f64> = rs.iter().map(|r| r.effective_bits).collect();
        let out_tokens: usize = rs.iter().map(|r| r.output_tokens).sum();
        let busy_s: f64 = rs.iter().map(|r| (r.prefill_ms + r.decode_ms) / 1e3).sum();
        Summary {
            n: rs.len(),
            mean_tpot_ms: mean(&tpot),
            p50_total_ms: percentile(&total, 50.0),
            p90_total_ms: percentile(&total, 90.0),
            p99_total_ms: percentile(&total, 99.0),
            mean_eff_bits: mean(&bits),
            p90_eff_bits: percentile(&bits, 90.0),
            p99_eff_bits: percentile(&bits, 99.0),
            throughput_tok_s: if busy_s > 0.0 { out_tokens as f64 / busy_s } else { 0.0 },
            total_output_tokens: out_tokens,
        }
    }
}

impl Summary {
    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} tpot={:.2}ms p50/p90/p99 latency={:.0}/{:.0}/{:.0}ms \
             eff-bits mean/p90/p99={:.3}/{:.3}/{:.3} throughput={:.1} tok/s",
            self.n, self.total_output_tokens, self.mean_tpot_ms,
            self.p50_total_ms, self.p90_total_ms, self.p99_total_ms,
            self.mean_eff_bits, self.p90_eff_bits, self.p99_eff_bits,
            self.throughput_tok_s,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, decode_ms: f64, out: usize, bits: f64) -> RequestRecord {
        RequestRecord {
            id, target_precision: 4.0, effective_bits: bits,
            prompt_tokens: 8, output_tokens: out,
            queue_ms: 1.0, prefill_ms: 2.0, decode_ms,
        }
    }

    #[test]
    fn summary_math() {
        let m = MetricsRegistry::new();
        m.record(rec(0, 100.0, 10, 4.0));
        m.record(rec(1, 200.0, 10, 4.2));
        let s = m.summary();
        assert_eq!(s.n, 2);
        assert!((s.mean_tpot_ms - 15.0).abs() < 1e-9);
        assert!((s.mean_eff_bits - 4.1).abs() < 1e-9);
        assert_eq!(s.total_output_tokens, 20);
        assert!(s.throughput_tok_s > 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let m = MetricsRegistry::new();
        for i in 0..100 {
            m.record(rec(i, i as f64, 10, 3.0 + i as f64 * 0.01));
        }
        let s = m.summary();
        assert!(s.p50_total_ms <= s.p90_total_ms);
        assert!(s.p90_total_ms <= s.p99_total_ms);
        assert!(s.p90_eff_bits <= s.p99_eff_bits);
    }
}
