//! Serving metrics: per-request records + percentile summaries
//! (powers the §6.3 per-query QoS study and the e2e example's report),
//! plus the ONE serializer for the runtime counter families — transfers,
//! weight cache, batching, speculation — shared by `GET /metrics`, the
//! examples and the benches so no caller hand-rolls its own snapshot
//! formatting.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::anyprec::materialize::MatSnapshot;
use crate::obs::hist::{HistogramSet, SloClass};
use crate::runtime::kvpool::MemoryStats;
use crate::runtime::TransferSnapshot;
use crate::util::json::Json;
use crate::util::stats::percentile;

/// Serialize every runtime counter family into one JSON object:
/// host↔device transfers + device-side assemblies, the weight
/// materialization cache, continuous-batching occupancy, and the
/// speculative-decoding drafted/accepted/verify counters with their
/// derived rates.  The single source of truth behind `GET /metrics`'
/// `counters` field and the examples' end-of-run reports.
pub fn counters_json(ts: &TransferSnapshot, ws: &MatSnapshot) -> Json {
    let mut j = Json::obj();
    j.set("uploads", ts.uploads as i64)
        .set("upload_bytes", ts.upload_bytes as i64)
        .set("downloads", ts.downloads as i64)
        .set("stack_assemblies", ts.assemblies as i64)
        .set("batched_steps", ts.batched_steps as i64)
        .set("batch_occupancy", ts.batch_occupancy as i64)
        .set(
            "mean_batch_occupancy",
            ts.batch_occupancy as f64 / ts.batched_steps.max(1) as f64,
        )
        .set("spec_drafted", ts.spec_drafted as i64)
        .set("spec_accepted", ts.spec_accepted as i64)
        .set("spec_verify_dispatches", ts.spec_verify_dispatches as i64)
        .set(
            "spec_acceptance_rate",
            ts.spec_accepted as f64 / ts.spec_drafted.max(1) as f64,
        )
        .set("prefill_chunks", ts.prefill_chunks as i64)
        .set("kv_bytes_resident", ts.kv_bytes_resident as i64)
        .set("kv_migrations", ts.kv_migrations as i64)
        .set("prefix_hits", ts.prefix_hits as i64)
        .set("prefix_prefills_saved", ts.prefix_prefills_saved as i64)
        .set("weight_cache_hits", ws.hits as i64)
        .set("weight_cache_misses", ws.misses as i64)
        .set("weight_cache_evictions", ws.evictions as i64)
        .set("weight_cache_bytes_dequantized", ws.bytes_dequantized as i64)
        .set("weight_cache_resident_bytes", ws.resident_bytes as i64);
    j
}

/// Human-readable one-liner over the same snapshot (examples / CLI).
pub fn counters_report(ts: &TransferSnapshot, ws: &MatSnapshot) -> String {
    format!(
        "counters: {} uploads ({:.1} MB) / {} downloads / {} assemblies | \
         batching {} dispatches, occupancy {:.2} | speculation {} verify \
         dispatches, {}/{} drafts accepted ({:.0}%) | {} prefill chunks | \
         weight cache {} hits / {} misses / {:.1} MB dequantized",
        ts.uploads,
        ts.upload_bytes as f64 / 1e6,
        ts.downloads,
        ts.assemblies,
        ts.batched_steps,
        ts.batch_occupancy as f64 / ts.batched_steps.max(1) as f64,
        ts.spec_verify_dispatches,
        ts.spec_accepted,
        ts.spec_drafted,
        100.0 * ts.spec_accepted as f64 / ts.spec_drafted.max(1) as f64,
        ts.prefill_chunks,
        ws.hits,
        ws.misses,
        ws.bytes_dequantized as f64 / 1e6,
    )
}

/// The combined device-memory report: where every resident byte lives
/// (weight cache vs KV tiers vs cached prefixes) next to its budget.
/// One object shared by `GET /metrics`' `memory` field, the engine's
/// `counters_json` and the serve examples — `-1` budgets mean
/// "unbounded" (no `DPLLM_KV_BUDGET_BYTES` / cache cap set).
pub fn memory_json(ws: &MatSnapshot, kv: &MemoryStats) -> Json {
    let budget = |b: usize| if b == usize::MAX { -1i64 } else { b as i64 };
    let mut j = Json::obj();
    j.set("weight_cache_resident_bytes", ws.resident_bytes as i64)
        .set("weight_cache_entries", ws.entries as i64)
        .set("kv_budget_bytes", budget(kv.budget))
        .set("kv_in_use_bytes", kv.in_use as i64)
        .set("kv_free_bytes", kv.free as i64)
        .set("kv_prefix_bytes", kv.prefix as i64)
        .set("kv_prefix_budget_bytes", budget(kv.prefix_budget))
        .set("kv_prefix_entries", kv.prefix_entries as i64)
        .set("kv_tier_reuses", kv.reuses as i64)
        .set("kv_prefix_evictions", kv.prefix_evictions as i64)
        .set(
            "total_resident_bytes",
            (ws.resident_bytes + kv.in_use + kv.free + kv.prefix) as i64,
        );
    j
}

/// One row of the per-replica fleet report: what the router knows about
/// a replica (backlog, respawns, steals) merged with the replica's own
/// heartbeat (active slots, decode-rate EWMA).  Serialized by
/// [`replicas_json`] into the `replicas` array of `GET /metrics`.
#[derive(Debug, Clone)]
pub struct ReplicaStatus {
    pub id: usize,
    /// Comma-joined tier slice, e.g. `"3.25,3.50"`.
    pub tier: String,
    pub premium: bool,
    pub alive: bool,
    /// Router-side backlog (routed, not yet forwarded).
    pub queue_depth: usize,
    /// Forwarded to the replica, not yet terminal.
    pub inflight: usize,
    /// Replica-reported active generation slots (last heartbeat).
    pub active: usize,
    /// Replica-reported decode throughput EWMA (last heartbeat).
    pub tokens_per_s: f64,
    /// Spawn→ready wall time from the replica's `Ready` event (0.0
    /// until it has reported ready; refreshed after every respawn).
    pub cold_start_ms: f64,
    pub steals_in: u64,
    pub steals_out: u64,
    pub respawns: u64,
    /// Requests completed on this replica (router-observed `Done`s).
    pub done: u64,
}

/// The `replicas` array of `GET /metrics` (per-replica observability,
/// DESIGN.md §Scale-out).
pub fn replicas_json(rs: &[ReplicaStatus]) -> Json {
    Json::Arr(
        rs.iter()
            .map(|r| {
                let mut j = Json::obj();
                j.set("id", r.id as i64)
                    .set("tier", r.tier.as_str())
                    .set("premium", r.premium)
                    .set("alive", r.alive)
                    .set("queue_depth", r.queue_depth as i64)
                    .set("inflight", r.inflight as i64)
                    .set("active", r.active as i64)
                    .set("tokens_per_s", r.tokens_per_s)
                    .set("cold_start_ms", r.cold_start_ms)
                    .set("steals_in", r.steals_in as i64)
                    .set("steals_out", r.steals_out as i64)
                    .set("respawns", r.respawns as i64)
                    .set("done", r.done as i64);
                j
            })
            .collect(),
    )
}

#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub target_precision: f64,
    pub effective_bits: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Arrival → admission (slot allocation; no prefill runs inside it).
    pub queue_ms: f64,
    /// Wall time of the request's prompt-ingestion dispatches, summed
    /// across the scheduling rounds they were spread over — NOT a
    /// synchronous admission-time stamp (DESIGN.md §Prefill).
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Arrival → first streamed token.  Under chunked prefill this is
    /// queue wait + the *scheduled* prefill span (chunk dispatches plus
    /// the decode rounds interleaved between them), so
    /// `ttft_ms >= queue_ms + prefill_ms` — the true queue/prefill/TTFT
    /// split the admission-time stamp used to conflate.
    pub ttft_ms: f64,
    /// SLO class: `true` when the request carried a deadline or a
    /// finite per-token budget (keys the per-class histograms).
    pub premium: bool,
    /// Wall-clock arrival stamp (throughput is measured over the span
    /// first arrival → last completion, not summed busy time).
    pub arrival: Instant,
    /// Wall-clock terminal-completion stamp.
    pub completed: Instant,
}

impl RequestRecord {
    pub fn tpot_ms(&self) -> f64 {
        self.decode_ms / self.output_tokens.max(1) as f64
    }

    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.prefill_ms + self.decode_ms
    }
}

/// Default retention window of [`MetricsRegistry`] (records kept for
/// windowed percentiles; cumulative state is exact forever).
pub const DEFAULT_RETAINED_RECORDS: usize = 65_536;

/// Cumulative, never-trimmed aggregate state: long-running summaries
/// stay exact while the record window stays bounded.
#[derive(Default)]
struct Cumulative {
    n: u64,
    out_tokens: u64,
    sum_tpot_ms: f64,
    sum_ttft_ms: f64,
    sum_eff_bits: f64,
    span_start: Option<Instant>,
    span_end: Option<Instant>,
    hist: HistogramSet,
}

struct RegInner {
    /// Bounded window of the most recent records (percentile queries,
    /// example reports).  Oldest records are dropped past `cap`.
    ring: VecDeque<RequestRecord>,
    cap: usize,
    cum: Cumulative,
}

/// Per-request serving metrics with **flat memory**: a bounded ring of
/// the last [`DEFAULT_RETAINED_RECORDS`] records (windowed percentiles)
/// plus cumulative counters/sums and per-SLO-class log2 latency
/// histograms (exact means, throughput and histogram percentiles over
/// the whole lifetime) — a long-running `serve` no longer grows an
/// unbounded `Vec`.
pub struct MetricsRegistry {
    inner: Mutex<RegInner>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::with_capacity(DEFAULT_RETAINED_RECORDS)
    }
}

/// Percentile/mean digest of a [`MetricsRegistry`].  `n`, the means,
/// `throughput_tok_s` and `total_output_tokens` are lifetime-exact
/// (cumulative state); the `p*` percentile fields cover only the
/// retained record window — `window` says how many records that is, so
/// a wrapped ring is visible rather than silently passing window
/// percentiles off as all-of-`n` statistics.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    /// Records behind the `p*` fields: equals `n` until the bounded
    /// ring wraps, the ring capacity afterwards.
    pub window: usize,
    pub mean_tpot_ms: f64,
    pub p50_total_ms: f64,
    pub p90_total_ms: f64,
    pub p99_total_ms: f64,
    /// Arrival → first streamed token (scheduled prefill inside it).
    pub mean_ttft_ms: f64,
    pub p90_ttft_ms: f64,
    pub mean_eff_bits: f64,
    pub p90_eff_bits: f64,
    pub p99_eff_bits: f64,
    /// Lifetime rate: tokens over the wall-clock span first arrival →
    /// last completion since startup.  Idle gaps between bursts dilute
    /// it — that is the long-run average, by design.
    pub throughput_tok_s: f64,
    /// Live rate: same wall-clock-span formula restricted to the
    /// retained record window, so on a long-running server it tracks
    /// recent load instead of being permanently diluted by old idle
    /// stretches (which age out of the ring).
    pub window_throughput_tok_s: f64,
    pub total_output_tokens: usize,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry retaining at most `cap` records (cumulative state is
    /// unaffected by the cap).
    pub fn with_capacity(cap: usize) -> Self {
        MetricsRegistry {
            inner: Mutex::new(RegInner {
                ring: VecDeque::new(),
                cap: cap.max(1),
                cum: Cumulative::default(),
            }),
        }
    }

    pub fn record(&self, r: RequestRecord) {
        let mut g = self.inner.lock().unwrap();
        let cum = &mut g.cum;
        cum.n += 1;
        cum.out_tokens += r.output_tokens as u64;
        cum.sum_tpot_ms += r.tpot_ms();
        cum.sum_ttft_ms += r.ttft_ms;
        cum.sum_eff_bits += r.effective_bits;
        cum.span_start = Some(match cum.span_start {
            Some(s) => s.min(r.arrival),
            None => r.arrival,
        });
        cum.span_end = Some(match cum.span_end {
            Some(e) => e.max(r.completed),
            None => r.completed,
        });
        cum.hist.record(
            SloClass::from_premium(r.premium),
            r.ttft_ms,
            r.tpot_ms(),
            r.queue_ms,
        );
        if g.ring.len() == g.cap {
            g.ring.pop_front();
        }
        g.ring.push_back(r);
    }

    /// The retained record window, oldest first (at most the configured
    /// capacity — NOT the full request history once it wraps).
    pub fn records(&self) -> Vec<RequestRecord> {
        self.inner.lock().unwrap().ring.iter().cloned().collect()
    }

    /// Lifetime request count (exact, unaffected by window trimming).
    pub fn total_recorded(&self) -> u64 {
        self.inner.lock().unwrap().cum.n
    }

    /// Snapshot of the cumulative per-SLO-class latency histograms
    /// (TTFT / ITL / queue delay) — feeds `/metrics` percentiles and
    /// the Prometheus exposition.
    pub fn histograms(&self) -> HistogramSet {
        self.inner.lock().unwrap().cum.hist.clone()
    }

    pub fn summary(&self) -> Summary {
        let g = self.inner.lock().unwrap();
        let rs = &g.ring;
        let tpot: Vec<f64> = rs.iter().map(|r| r.tpot_ms()).collect();
        let total: Vec<f64> = rs.iter().map(|r| r.total_ms()).collect();
        let ttft: Vec<f64> = rs.iter().map(|r| r.ttft_ms).collect();
        let bits: Vec<f64> = rs.iter().map(|r| r.effective_bits).collect();
        let cum = &g.cum;
        let n = cum.n.max(1) as f64;
        // Throughput over the wall-clock span first arrival → last
        // completion: N overlapping requests each contributing T busy
        // seconds over a T-second wall span report N× the old
        // summed-busy-time number, which understated real concurrency.
        let span_s = match (cum.span_start, cum.span_end) {
            (Some(s), Some(e)) => e.saturating_duration_since(s).as_secs_f64(),
            _ => 0.0,
        };
        // Windowed rate: same formula over just the retained records.
        let win_tokens: u64 = rs.iter().map(|r| r.output_tokens as u64).sum();
        let win_span_s = match (
            rs.iter().map(|r| r.arrival).min(),
            rs.iter().map(|r| r.completed).max(),
        ) {
            (Some(s), Some(e)) => e.saturating_duration_since(s).as_secs_f64(),
            _ => 0.0,
        };
        Summary {
            n: cum.n as usize,
            window: rs.len(),
            mean_tpot_ms: cum.sum_tpot_ms / n,
            p50_total_ms: percentile(&total, 50.0),
            p90_total_ms: percentile(&total, 90.0),
            p99_total_ms: percentile(&total, 99.0),
            mean_ttft_ms: cum.sum_ttft_ms / n,
            p90_ttft_ms: percentile(&ttft, 90.0),
            mean_eff_bits: cum.sum_eff_bits / n,
            p90_eff_bits: percentile(&bits, 90.0),
            p99_eff_bits: percentile(&bits, 99.0),
            throughput_tok_s: if span_s > 0.0 {
                cum.out_tokens as f64 / span_s
            } else {
                0.0
            },
            window_throughput_tok_s: if win_span_s > 0.0 {
                win_tokens as f64 / win_span_s
            } else {
                0.0
            },
            total_output_tokens: cum.out_tokens as usize,
        }
    }
}

impl Summary {
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} tokens={} tpot={:.2}ms p50/p90/p99 latency={:.0}/{:.0}/{:.0}ms \
             ttft mean/p90={:.0}/{:.0}ms \
             eff-bits mean/p90/p99={:.3}/{:.3}/{:.3} throughput={:.1} tok/s",
            self.n, self.total_output_tokens, self.mean_tpot_ms,
            self.p50_total_ms, self.p90_total_ms, self.p99_total_ms,
            self.mean_ttft_ms, self.p90_ttft_ms,
            self.mean_eff_bits, self.p90_eff_bits, self.p99_eff_bits,
            self.throughput_tok_s,
        );
        if self.window < self.n {
            s.push_str(&format!(
                " (percentiles over last {} requests, window rate {:.1} tok/s)",
                self.window, self.window_throughput_tok_s,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::time::Duration;

    fn rec(id: u64, decode_ms: f64, out: usize, bits: f64) -> RequestRecord {
        let completed = Instant::now();
        RequestRecord {
            id, target_precision: 4.0, effective_bits: bits,
            prompt_tokens: 8, output_tokens: out,
            queue_ms: 1.0, prefill_ms: 2.0, decode_ms,
            // Scheduled-prefill invariant: ttft >= queue + prefill (the
            // spread includes interleaved decode rounds).
            ttft_ms: 5.0,
            premium: false,
            arrival: completed - Duration::from_secs_f64((3.0 + decode_ms) / 1e3),
            completed,
        }
    }

    #[test]
    fn summary_math() {
        let m = MetricsRegistry::new();
        m.record(rec(0, 100.0, 10, 4.0));
        m.record(rec(1, 200.0, 10, 4.2));
        let s = m.summary();
        assert_eq!(s.n, 2);
        assert!((s.mean_tpot_ms - 15.0).abs() < 1e-9);
        assert!((s.mean_eff_bits - 4.1).abs() < 1e-9);
        assert!((s.mean_ttft_ms - 5.0).abs() < 1e-9);
        assert!(s.p90_ttft_ms >= s.mean_ttft_ms - 1e-9);
        assert_eq!(s.total_output_tokens, 20);
        assert!(s.throughput_tok_s > 0.0);
        // The TTFT split is part of the report line.
        assert!(s.report().contains("ttft mean/p90=5/5ms"), "{}", s.report());
    }

    #[test]
    fn throughput_uses_wall_clock_span_not_summed_busy_time() {
        // Two fully-overlapping requests: each produces 100 tokens over
        // the same 1 s wall-clock span.  Real throughput is 200 tok/s;
        // the old summed-busy-time formula (tokens / Σ per-request busy
        // seconds) reported ~100 tok/s — understating N× with N
        // overlapping requests.
        let m = MetricsRegistry::new();
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_secs(1);
        for id in 0..2u64 {
            m.record(RequestRecord {
                id, target_precision: 4.0, effective_bits: 4.0,
                prompt_tokens: 8, output_tokens: 100,
                queue_ms: 0.0, prefill_ms: 0.0, decode_ms: 1000.0,
                ttft_ms: 10.0, premium: false,
                arrival: t0, completed: t1,
            });
        }
        let s = m.summary();
        assert_eq!(s.total_output_tokens, 200);
        assert!(
            (s.throughput_tok_s - 200.0).abs() < 1.0,
            "wall-clock-span throughput expected ~200 tok/s, got {}",
            s.throughput_tok_s
        );
    }

    #[test]
    fn retention_window_is_bounded_but_cumulative_state_is_exact() {
        let m = MetricsRegistry::with_capacity(4);
        for i in 0..10 {
            m.record(rec(i, 100.0, 10, 4.0));
        }
        // Window trimmed to the newest 4 records…
        let w = m.records();
        assert_eq!(w.len(), 4);
        assert_eq!(w.first().unwrap().id, 6);
        assert_eq!(w.last().unwrap().id, 9);
        // …while lifetime aggregates stay exact.
        assert_eq!(m.total_recorded(), 10);
        let s = m.summary();
        assert_eq!(s.n, 10);
        assert_eq!(s.total_output_tokens, 100);
        assert!((s.mean_tpot_ms - 10.0).abs() < 1e-9);
        assert!((s.mean_eff_bits - 4.0).abs() < 1e-9);
        // The wrapped window is surfaced, not silently passed off as n.
        assert_eq!(s.window, 4);
        assert!(s.report().contains("percentiles over last 4 requests"),
                "{}", s.report());
    }

    #[test]
    fn window_throughput_sheds_evicted_idle_gaps() {
        // An old burst, a 100 s idle gap, then a fresh burst that
        // evicts the old records from the 2-slot ring.  The lifetime
        // rate is diluted by the gap (by design); the window rate
        // covers only the retained burst.
        let m = MetricsRegistry::with_capacity(2);
        let t0 = Instant::now();
        let mk = |arrival: Instant, completed: Instant, id: u64| RequestRecord {
            id, target_precision: 4.0, effective_bits: 4.0,
            prompt_tokens: 8, output_tokens: 100,
            queue_ms: 0.0, prefill_ms: 0.0, decode_ms: 1000.0,
            ttft_ms: 10.0, premium: false, arrival, completed,
        };
        m.record(mk(t0, t0 + Duration::from_secs(1), 0));
        m.record(mk(t0, t0 + Duration::from_secs(1), 1));
        let late = t0 + Duration::from_secs(101);
        m.record(mk(late, late + Duration::from_secs(1), 2));
        m.record(mk(late, late + Duration::from_secs(1), 3));
        let s = m.summary();
        // Lifetime: 400 tokens over the 102 s span ≈ 3.9 tok/s.
        assert!(s.throughput_tok_s < 5.0, "{}", s.throughput_tok_s);
        // Window: the fresh burst's 200 tokens over its 1 s span.
        assert_eq!(s.window, 2);
        assert!(
            (s.window_throughput_tok_s - 200.0).abs() < 1.0,
            "window rate expected ~200 tok/s, got {}",
            s.window_throughput_tok_s
        );
    }

    #[test]
    fn histograms_key_by_slo_class() {
        let m = MetricsRegistry::new();
        let mut premium = rec(0, 100.0, 10, 4.5);
        premium.premium = true;
        m.record(premium);
        m.record(rec(1, 200.0, 10, 3.5));
        m.record(rec(2, 300.0, 10, 3.5));
        let hs = m.histograms();
        let j = hs.json();
        assert_eq!(j.get("premium").unwrap().f64_of("n").unwrap(), 1.0);
        assert_eq!(j.get("economy").unwrap().f64_of("n").unwrap(), 2.0);
    }

    #[test]
    fn counters_json_has_every_family_and_derived_rates() {
        let ts = TransferSnapshot {
            uploads: 10, upload_bytes: 4096, downloads: 7, assemblies: 2,
            batched_steps: 4, batch_occupancy: 10,
            spec_drafted: 8, spec_accepted: 6, spec_verify_dispatches: 2,
            prefill_chunks: 3,
            kv_bytes_resident: 1024, kv_migrations: 2,
            prefix_hits: 3, prefix_prefills_saved: 6,
        };
        let ws = MatSnapshot {
            hits: 5, misses: 3, evictions: 1, bytes_dequantized: 1 << 20,
            resident_bytes: 2048, entries: 3,
        };
        let j = counters_json(&ts, &ws);
        assert_eq!(j.f64_of("batched_steps").unwrap(), 4.0);
        assert!((j.f64_of("mean_batch_occupancy").unwrap() - 2.5).abs() < 1e-12);
        assert!((j.f64_of("spec_acceptance_rate").unwrap() - 0.75).abs() < 1e-12);
        assert_eq!(j.f64_of("spec_verify_dispatches").unwrap(), 2.0);
        assert_eq!(j.f64_of("prefill_chunks").unwrap(), 3.0);
        assert_eq!(j.f64_of("weight_cache_hits").unwrap(), 5.0);
        // The report string carries the same families.
        let r = counters_report(&ts, &ws);
        assert!(r.contains("2 verify dispatches"));
        assert!(r.contains("6/8 drafts accepted (75%)"));
        assert!(r.contains("3 prefill chunks"));
        // Zero denominators must not divide by zero.
        let zero = TransferSnapshot {
            uploads: 0, upload_bytes: 0, downloads: 0, assemblies: 0,
            batched_steps: 0, batch_occupancy: 0,
            spec_drafted: 0, spec_accepted: 0, spec_verify_dispatches: 0,
            prefill_chunks: 0,
            kv_bytes_resident: 0, kv_migrations: 0,
            prefix_hits: 0, prefix_prefills_saved: 0,
        };
        let j = counters_json(&zero, &ws);
        assert_eq!(j.f64_of("spec_acceptance_rate").unwrap(), 0.0);
        assert_eq!(j.f64_of("mean_batch_occupancy").unwrap(), 0.0);
    }

    #[test]
    fn counters_json_carries_kv_pool_family() {
        let ts = TransferSnapshot {
            uploads: 0, upload_bytes: 0, downloads: 0, assemblies: 0,
            batched_steps: 0, batch_occupancy: 0,
            spec_drafted: 0, spec_accepted: 0, spec_verify_dispatches: 0,
            prefill_chunks: 0,
            kv_bytes_resident: 4096, kv_migrations: 3,
            prefix_hits: 2, prefix_prefills_saved: 5,
        };
        let ws = MatSnapshot::default();
        let j = counters_json(&ts, &ws);
        assert_eq!(j.f64_of("kv_bytes_resident").unwrap(), 4096.0);
        assert_eq!(j.f64_of("kv_migrations").unwrap(), 3.0);
        assert_eq!(j.f64_of("prefix_hits").unwrap(), 2.0);
        assert_eq!(j.f64_of("prefix_prefills_saved").unwrap(), 5.0);
    }

    #[test]
    fn memory_json_totals_and_unbounded_budgets() {
        let ws = MatSnapshot {
            hits: 0, misses: 0, evictions: 0, bytes_dequantized: 0,
            resident_bytes: 1000, entries: 2,
        };
        let kv = MemoryStats {
            budget: 8000, in_use: 300, free: 200, prefix: 100,
            prefix_budget: 2000, prefix_entries: 1,
            reuses: 4, prefix_evictions: 1,
        };
        let j = memory_json(&ws, &kv);
        assert_eq!(j.f64_of("kv_budget_bytes").unwrap(), 8000.0);
        assert_eq!(j.f64_of("kv_in_use_bytes").unwrap(), 300.0);
        assert_eq!(j.f64_of("total_resident_bytes").unwrap(), 1600.0);
        assert_eq!(j.f64_of("kv_tier_reuses").unwrap(), 4.0);
        // An unbounded pool serializes its budgets as -1, not usize::MAX.
        let unbounded = MemoryStats {
            budget: usize::MAX, prefix_budget: usize::MAX,
            ..MemoryStats::default()
        };
        let j = memory_json(&ws, &unbounded);
        assert_eq!(j.f64_of("kv_budget_bytes").unwrap(), -1.0);
        assert_eq!(j.f64_of("kv_prefix_budget_bytes").unwrap(), -1.0);
    }

    #[test]
    fn percentiles_ordered() {
        let m = MetricsRegistry::new();
        for i in 0..100 {
            m.record(rec(i, i as f64, 10, 3.0 + i as f64 * 0.01));
        }
        let s = m.summary();
        assert!(s.p50_total_ms <= s.p90_total_ms);
        assert!(s.p90_total_ms <= s.p99_total_ms);
        assert!(s.p90_eff_bits <= s.p99_eff_bits);
    }

    #[test]
    fn replicas_json_serializes_fleet_rows() {
        let rows = vec![
            ReplicaStatus {
                id: 0, tier: "3.25,3.50".to_string(), premium: false,
                alive: true, queue_depth: 3, inflight: 2, active: 2,
                tokens_per_s: 120.5, cold_start_ms: 850.0, steals_in: 0,
                steals_out: 4, respawns: 0, done: 7,
            },
            ReplicaStatus {
                id: 1, tier: "4.50,4.75".to_string(), premium: true,
                alive: false, queue_depth: 0, inflight: 0, active: 0,
                tokens_per_s: 0.0, cold_start_ms: 0.0, steals_in: 4,
                steals_out: 0, respawns: 1, done: 2,
            },
        ];
        let j = replicas_json(&rows);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].str_of("tier").unwrap(), "3.25,3.50");
        assert_eq!(arr[0].f64_of("queue_depth").unwrap(), 3.0);
        assert_eq!(arr[0].f64_of("cold_start_ms").unwrap(), 850.0);
        assert_eq!(arr[0].f64_of("steals_out").unwrap(), 4.0);
        assert_eq!(arr[1].f64_of("respawns").unwrap(), 1.0);
        assert_eq!(arr[1].f64_of("id").unwrap(), 1.0);
    }
}
