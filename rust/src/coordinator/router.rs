//! Front-of-house router for multi-replica serving (DESIGN.md
//! §Scale-out).
//!
//! The [`Router`] owns fleet-level admission: it classifies each
//! request (tight-SLO traffic is *premium*, best-effort is *economy*),
//! picks the replica with the shortest expected delay for that class
//! (`modeled tpot_ms × (backlog + 1)` — the costmodel stream time is
//! the delay unit, so a premium replica with a deep queue loses to an
//! idle sibling), forwards over the [`ReplicaCommand`] channel, and
//! reconciles [`ReplicaEvent`]s back into terminal [`RouterEvent`]s.
//!
//! Three fleet behaviors ride on top of plain routing:
//!
//! - **Work stealing** — an idle replica (no backlog, no active slots)
//!   pulls from the *back* of the deepest sibling queue once it exceeds
//!   a threshold, with pinned targets clamped to the thief's tier
//!   slice.  Class affinity is a preference, not a partition: a drained
//!   premium replica serves economy overflow rather than idling.
//! - **Capacity retry** — a per-replica capacity reject (slot cap / KV
//!   pool exhausted) is retried once on the best sibling before
//!   surfacing as fleet-level 503 (`router_retries` vs
//!   `router_rejects_capacity`).
//! - **Drain + respawn** — a replica that dies (panic → `Died`, channel
//!   drop) or wedges (heartbeat timeout) is drained: its in-flight
//!   requests terminate with a retryable error, its backlog re-routes
//!   to live siblings, and a fresh worker is spawned from the same
//!   [`ReplicaSpec`] — the PR 5 single-loop fault isolation story made
//!   fleet-wide.
//!
//! The spawn function is injected ([`ReplicaSpawn`]), so unit tests and
//! the artifact-free `router_micro` bench drive the REAL routing /
//! steal / drain / respawn code over simulated workers
//! ([`crate::runtime::replica::sim`]).

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::TryRecvError;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::metrics::{replicas_json, ReplicaStatus};
use super::sched::Request;
use super::service::ServeOutcome;
use crate::obs::{global_tracer, EventKind, HistogramSet, SloClass};
use crate::runtime::replica::{ReplicaCommand, ReplicaEvent, ReplicaHealth,
                              ReplicaLink, ReplicaSpec};
use crate::util::json::Json;

/// Builds (or rebuilds, on respawn) the worker for a spec.  Injected so
/// the same router logic runs over engine-backed and simulated workers.
pub type ReplicaSpawn = Box<dyn FnMut(&ReplicaSpec) -> ReplicaLink>;

/// Is this request premium (tight-SLO) traffic?  A finite per-token
/// budget or an explicit deadline means the client asked for latency;
/// everything else is best-effort economy traffic.
pub fn is_premium(req: &Request) -> bool {
    req.deadline_ms.is_some() || req.qos.ms_per_token.is_finite()
}

/// What [`pick_replica`] / [`pick_steal`] see of one replica: plain
/// data, so the routing core is a pure function over snapshots
/// (property-testable without threads).
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSnapshot {
    pub id: usize,
    pub alive: bool,
    pub premium: bool,
    /// Modeled per-token ms of the replica's cheapest target.
    pub tpot_ms: f64,
    /// Work ahead of a new arrival: router backlog + in-flight, where
    /// in-flight is the larger of the router's forwarded count and the
    /// replica-reported active slots — the heartbeat `active` is a
    /// lagged view of the same forwarded requests, so summing both
    /// would bill a busy replica roughly twice.
    pub queued: usize,
    /// Replica-reported active slots (last heartbeat).
    pub active: usize,
}

fn expected_delay(s: &ReplicaSnapshot) -> f64 {
    s.tpot_ms.max(1e-9) * (s.queued + 1) as f64
}

/// Shortest-expected-delay routing with class affinity: prefer alive
/// replicas of the request's class, minimizing
/// `tpot_ms × (queued + 1)` (ties broken by lowest id); when
/// no replica of the class is alive, fall back to any alive replica —
/// a degraded fleet still serves everything.
pub fn pick_replica(snaps: &[ReplicaSnapshot], premium: bool)
                    -> Option<usize> {
    let best = |class_only: bool| {
        snaps
            .iter()
            .filter(|s| s.alive && (!class_only || s.premium == premium))
            .min_by(|a, b| {
                expected_delay(a)
                    .partial_cmp(&expected_delay(b))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.id.cmp(&b.id))
            })
    };
    best(true).or_else(|| best(false)).map(|s| s.id)
}

/// Work stealing: `(victim, thief)` when an alive replica is fully idle
/// (no queue, no active slots) and some sibling's queue is at least
/// `threshold` deep.  The thief takes from the back of the victim's
/// queue (the request that would otherwise wait longest).
pub fn pick_steal(snaps: &[ReplicaSnapshot], threshold: usize)
                  -> Option<(usize, usize)> {
    let thief = snaps
        .iter()
        .filter(|s| s.alive && s.queued == 0 && s.active == 0)
        .min_by_key(|s| s.id)?;
    let victim = snaps
        .iter()
        .filter(|s| s.alive && s.id != thief.id)
        .max_by(|a, b| a.queued.cmp(&b.queued).then(b.id.cmp(&a.id)))?;
    (victim.queued >= threshold.max(1)).then_some((victim.id, thief.id))
}

/// Nearest member of a replica's tier slice to a requested pin — a
/// stolen or re-routed pinned request runs at the closest precision the
/// new replica actually materializes.
pub fn clamp_target(targets: &[f64], t: f64) -> f64 {
    targets
        .iter()
        .copied()
        .min_by(|a, b| {
            (a - t).abs()
                .partial_cmp(&(b - t).abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap_or(t)
}

/// Parse `--replica-tiers "3.25,3.50|4.00,4.50,4.75"`: one
/// pipe-separated tag slice per replica.
pub fn parse_replica_tiers(spec: &str) -> Result<Vec<Vec<String>>> {
    let mut out = Vec::new();
    for slice in spec.split('|') {
        let tags: Vec<String> = slice
            .split(',')
            .map(|t| t.trim().to_string())
            .filter(|t| !t.is_empty())
            .collect();
        if tags.is_empty() {
            return Err(anyhow!("empty tier slice in --replica-tiers {spec:?}"));
        }
        out.push(tags);
    }
    Ok(out)
}

/// Default tier assignment for `--replicas n` without an explicit
/// `--replica-tiers`: contiguous near-even chunks of the ascending
/// ladder, so low replicas materialize the cheap low-bit slice
/// (economy) and high replicas the expensive high-bit slice (premium).
/// `n` is clamped to the ladder length — a replica with no tags cannot
/// serve.
pub fn split_tiers(tags: &[String], n: usize) -> Vec<Vec<String>> {
    let n = n.clamp(1, tags.len().max(1));
    let per = tags.len() / n;
    let extra = tags.len() % n;
    let mut it = tags.iter();
    let mut out = vec![Vec::new(); n];
    for (i, slice) in out.iter_mut().enumerate() {
        let take = per + usize::from(i < extra);
        for _ in 0..take {
            if let Some(t) = it.next() {
                slice.push(t.clone());
            }
        }
    }
    out
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Requests forwarded to one replica concurrently; the rest wait in
    /// the router backlog where they stay stealable/re-routable.
    pub max_inflight: usize,
    /// Minimum victim queue depth before an idle replica steals.
    pub steal_threshold: usize,
    /// Silence longer than this declares a replica wedged — armed only
    /// once the replica has spoken (its `Ready` arrived).
    pub heartbeat_timeout: Duration,
    /// Startup grace: an engine-backed replica sends nothing until
    /// `Runtime::new` + `ServingEngine::load_shared` finish, and
    /// load/compile routinely outlasts a heartbeat period.  Until the
    /// first event arrives the slot is judged against this much longer
    /// deadline instead, so a slow load is not declared wedged and
    /// respawned into a load loop that exhausts the respawn budget.
    pub startup_timeout: Duration,
    /// Respawn budget per replica; a spec that keeps dying stops being
    /// revived (load failures would otherwise respawn forever).
    pub max_respawns: u64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            max_inflight: 4,
            steal_threshold: 2,
            heartbeat_timeout: Duration::from_millis(2000),
            startup_timeout: Duration::from_secs(120),
            max_respawns: 3,
        }
    }
}

/// Fleet-level counters (`router_*` in `GET /metrics`).
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterCounters {
    pub routed_premium: u64,
    pub routed_economy: u64,
    pub steals: u64,
    pub respawns: u64,
    /// Capacity rejects absorbed by retrying on a sibling.
    pub retries: u64,
    /// Capacity rejects surfaced to the client (503 + `Retry-After`).
    pub rejects_capacity: u64,
    /// Malformed-request rejects surfaced to the client (400) — the
    /// fleet-level aggregate of replica-side `admit_rejects_invalid`.
    pub rejects_invalid: u64,
    /// Backlogged requests re-routed off a dead replica.
    pub rerouted: u64,
    /// In-flight requests terminated by a replica death.
    pub died_inflight: u64,
}

impl RouterCounters {
    pub fn json(&self) -> Json {
        let mut j = Json::obj();
        j.set("router_routed_premium", self.routed_premium as i64)
            .set("router_routed_economy", self.routed_economy as i64)
            .set("router_steals", self.steals as i64)
            .set("router_respawns", self.respawns as i64)
            .set("router_retries", self.retries as i64)
            .set("router_rejects_capacity", self.rejects_capacity as i64)
            .set("router_rejects_invalid", self.rejects_invalid as i64)
            .set("router_rerouted", self.rerouted as i64)
            .set("router_died_inflight", self.died_inflight as i64);
        j
    }
}

/// A request inside the router: its class, its (clamped-per-replica)
/// pin, and whether its one sibling retry is spent.
#[derive(Debug, Clone)]
struct RoutedRequest {
    req: Request,
    pinned: Option<f64>,
    premium: bool,
    retried: bool,
    /// When the router accepted the request — the fleet-level
    /// queue-delay clock (backlog wait + every steal/re-route hop).
    enqueued: Instant,
    /// Router queue delay, stamped at the (final) forward.
    queued_ms: f64,
}

/// Terminal (or fleet-level) events [`Router::poll`] hands the
/// transport.
pub enum RouterEvent {
    /// Request finished on `replica`.
    Done { replica: usize, outcome: ServeOutcome },
    /// Request aborted mid-flight (HTTP 500).
    Failed { id: u64, error: String },
    /// Request rejected; `capacity: true` is retryable (HTTP 503 +
    /// `Retry-After`), `false` malformed (HTTP 400).
    Rejected { id: u64, error: String, capacity: bool },
    /// Fleet event: replica `replica` was drained and respawned.
    Respawned { replica: usize },
}

struct ReplicaSlot {
    spec: ReplicaSpec,
    link: ReplicaLink,
    alive: bool,
    /// Exited cleanly via `Shutdown` — never respawned.
    stopped: bool,
    /// Has sent at least one event since (re)spawn — load finished, so
    /// the wedge timer runs at `heartbeat_timeout` instead of
    /// `startup_timeout`.
    ready: bool,
    /// Spawn→ready wall time reported by the worker's `Ready` event
    /// (0.0 until it arrives; refreshed on every respawn).
    cold_start_ms: f64,
    last_seen: Instant,
    health: ReplicaHealth,
    backlog: VecDeque<RoutedRequest>,
    inflight: HashMap<u64, RoutedRequest>,
    steals_in: u64,
    steals_out: u64,
    respawns: u64,
    done: u64,
}

/// The front-of-house router: owns the replica fleet and every routing
/// decision.  Single-threaded like the rest of the executor path — the
/// transport calls [`Router::submit`] / [`Router::poll`] from one loop.
pub struct Router {
    replicas: Vec<ReplicaSlot>,
    spawn: ReplicaSpawn,
    cfg: RouterConfig,
    counters: RouterCounters,
    /// Fleet-level latency histograms, recorded once per terminal
    /// [`RouterEvent::Done`].  The engine-side `MetricsRegistry` set
    /// lives inside each replica and is not scraped in fleet mode, so
    /// no request is double-counted.
    hist: HistogramSet,
}

impl Router {
    /// Spawns every replica.  `specs[i].id` must equal `i`: replica ids
    /// double as fleet indices everywhere (snapshots, steal pairs,
    /// status rows).
    pub fn new(specs: Vec<ReplicaSpec>, mut spawn: ReplicaSpawn,
               cfg: RouterConfig) -> Router {
        let now = Instant::now();
        let replicas = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| {
                assert_eq!(spec.id, i, "replica specs must be indexed 0..n");
                let link = spawn(&spec);
                ReplicaSlot {
                    spec,
                    link,
                    alive: true,
                    stopped: false,
                    ready: false,
                    cold_start_ms: 0.0,
                    last_seen: now,
                    health: ReplicaHealth::default(),
                    backlog: VecDeque::new(),
                    inflight: HashMap::new(),
                    steals_in: 0,
                    steals_out: 0,
                    respawns: 0,
                    done: 0,
                }
            })
            .collect();
        Router {
            replicas,
            spawn,
            cfg,
            counters: RouterCounters::default(),
            hist: HistogramSet::new(),
        }
    }

    pub fn counters(&self) -> RouterCounters {
        self.counters
    }

    /// All distinct target precisions served by live replicas
    /// (ascending) — the fleet-level `/health` payload.
    pub fn targets(&self) -> Vec<f64> {
        let mut all: Vec<f64> = self
            .replicas
            .iter()
            .filter(|r| r.alive)
            .flat_map(|r| r.spec.targets.iter().copied())
            .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        all.dedup();
        all
    }

    pub fn alive_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive).count()
    }

    /// Live replicas whose `Ready` has been observed — i.e. slots whose
    /// wedge timer runs at `heartbeat_timeout` rather than the startup
    /// deadline (diagnostics / deterministic tests).
    pub fn ready_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive && r.ready).count()
    }

    /// True when no routed request is waiting or in flight anywhere.
    pub fn idle(&self) -> bool {
        self.replicas
            .iter()
            .all(|r| r.backlog.is_empty() && r.inflight.is_empty())
    }

    fn snapshot_of(r: &ReplicaSlot) -> ReplicaSnapshot {
        ReplicaSnapshot {
            id: r.spec.id,
            alive: r.alive,
            premium: r.spec.premium,
            tpot_ms: r.spec.tpot_ms,
            queued: r.backlog.len() + r.inflight.len().max(r.health.active),
            active: r.health.active,
        }
    }

    pub fn snapshots(&self) -> Vec<ReplicaSnapshot> {
        self.replicas.iter().map(Self::snapshot_of).collect()
    }

    /// Route one request.  `pinned` fixes the target precision (clamped
    /// to whatever slice serves it).  `None` means accepted; `Some` is
    /// an immediate terminal event (no live replica).
    pub fn submit(&mut self, req: Request, pinned: Option<f64>)
                  -> Option<RouterEvent> {
        let premium = is_premium(&req);
        let snaps = self.snapshots();
        let Some(i) = pick_replica(&snaps, premium) else {
            self.counters.rejects_capacity += 1;
            global_tracer().record(EventKind::Reject { id: req.id, capacity: true });
            return Some(RouterEvent::Rejected {
                id: req.id,
                error: "no live replica".to_string(),
                capacity: true,
            });
        };
        if premium {
            self.counters.routed_premium += 1;
        } else {
            self.counters.routed_economy += 1;
        }
        global_tracer().record(EventKind::Route {
            id: req.id,
            replica: i as u32,
            premium,
        });
        self.replicas[i].backlog.push_back(RoutedRequest {
            req,
            pinned,
            premium,
            retried: false,
            enqueued: Instant::now(),
            queued_ms: 0.0,
        });
        self.pump(i);
        None
    }

    /// Drain replica events, detect wedged/dead replicas, drain +
    /// respawn them, steal work for idle replicas, forward backlogs.
    pub fn poll(&mut self) -> Vec<RouterEvent> {
        self.poll_at(Instant::now())
    }

    /// [`Router::poll`] with an injected clock, so wedge detection is
    /// deterministic under test.
    pub fn poll_at(&mut self, now: Instant) -> Vec<RouterEvent> {
        let mut out = Vec::new();
        // One entry per replica at most: a panic delivers Died AND a
        // closed channel in the same poll, and draining twice would
        // abandon the freshly respawned worker and burn a second
        // respawn from the budget.
        let mut dead: Vec<(usize, String)> = Vec::new();
        fn mark_dead(dead: &mut Vec<(usize, String)>, i: usize, why: String) {
            if !dead.iter().any(|(j, _)| *j == i) {
                dead.push((i, why));
            }
        }
        for i in 0..self.replicas.len() {
            loop {
                let ev = match self.replicas[i].link.rx.try_recv() {
                    Ok(ev) => ev,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if self.replicas[i].alive {
                            mark_dead(&mut dead, i,
                                      "event channel closed".to_string());
                        }
                        break;
                    }
                };
                self.replicas[i].last_seen = now;
                self.replicas[i].ready = true;
                match ev {
                    ReplicaEvent::Ready { cold_start_ms } => {
                        self.replicas[i].cold_start_ms = cold_start_ms;
                        global_tracer().record(EventKind::ColdStart {
                            replica: i as u32,
                            us: (cold_start_ms * 1e3) as u64,
                        });
                    }
                    ReplicaEvent::Heartbeat(h) => self.replicas[i].health = h,
                    ReplicaEvent::Done(o) => {
                        let rr = self.replicas[i].inflight.remove(&o.id);
                        self.replicas[i].done += 1;
                        let premium =
                            rr.as_ref().map(|r| r.premium).unwrap_or(false);
                        let queue_ms =
                            rr.as_ref().map(|r| r.queued_ms).unwrap_or(0.0);
                        let itl_ms =
                            o.decode_ms / o.output_tokens.max(1) as f64;
                        self.hist.record(SloClass::from_premium(premium),
                                         o.ttft_ms, itl_ms, queue_ms);
                        out.push(RouterEvent::Done { replica: i, outcome: o });
                    }
                    ReplicaEvent::Failed { id, error } => {
                        self.replicas[i].inflight.remove(&id);
                        out.push(RouterEvent::Failed { id, error });
                    }
                    ReplicaEvent::Error { id, error, capacity } => {
                        let rr = self.replicas[i].inflight.remove(&id);
                        self.on_reject(i, id, error, capacity, rr, &mut out);
                    }
                    ReplicaEvent::Stopped => {
                        self.replicas[i].alive = false;
                        self.replicas[i].stopped = true;
                    }
                    ReplicaEvent::Died { error } => {
                        mark_dead(&mut dead, i, error);
                    }
                }
            }
            let r = &self.replicas[i];
            // Until the replica has spoken it is still loading: judge it
            // against the (long) startup deadline, not the heartbeat one.
            let (deadline, why) = if r.ready {
                (self.cfg.heartbeat_timeout, "heartbeat timeout (replica wedged)")
            } else {
                (self.cfg.startup_timeout, "startup timeout (replica never became ready)")
            };
            if r.alive && now.duration_since(r.last_seen) > deadline {
                mark_dead(&mut dead, i, why.to_string());
            }
        }
        for (i, reason) in dead {
            self.drain_and_respawn(i, &reason, now, &mut out);
        }
        // Work stealing: keep moving tail items to idle replicas until
        // no (victim, thief) pair qualifies.  Terminates: every move
        // makes the thief non-idle.
        loop {
            let snaps = self.snapshots();
            let Some((victim, thief)) =
                pick_steal(&snaps, self.cfg.steal_threshold)
            else {
                break;
            };
            let Some(mut rr) = self.replicas[victim].backlog.pop_back() else {
                break;
            };
            rr.pinned = rr
                .pinned
                .map(|t| clamp_target(&self.replicas[thief].spec.targets, t));
            global_tracer().record(EventKind::Steal {
                id: rr.req.id,
                from: victim as u32,
                to: thief as u32,
            });
            self.replicas[thief].backlog.push_back(rr);
            self.replicas[victim].steals_out += 1;
            self.replicas[thief].steals_in += 1;
            self.counters.steals += 1;
        }
        for i in 0..self.replicas.len() {
            self.pump(i);
        }
        out
    }

    /// A replica-side admission reject.  Capacity rejects get ONE retry
    /// on the best live sibling (a full replica must not 503 the fleet);
    /// everything else — malformed requests, spent retries, no sibling —
    /// surfaces as a terminal event.
    fn on_reject(&mut self, replica: usize, id: u64, error: String,
                 capacity: bool, rr: Option<RoutedRequest>,
                 out: &mut Vec<RouterEvent>) {
        if let Some(mut rr) = rr {
            if capacity && !rr.retried {
                let snaps: Vec<ReplicaSnapshot> = self
                    .snapshots()
                    .into_iter()
                    .filter(|s| s.id != replica)
                    .collect();
                if let Some(j) = pick_replica(&snaps, rr.premium) {
                    rr.retried = true;
                    self.counters.retries += 1;
                    global_tracer().record(EventKind::Route {
                        id: rr.req.id,
                        replica: j as u32,
                        premium: rr.premium,
                    });
                    self.replicas[j].backlog.push_back(rr);
                    return;
                }
            }
        }
        if capacity {
            self.counters.rejects_capacity += 1;
        } else {
            self.counters.rejects_invalid += 1;
        }
        out.push(RouterEvent::Rejected { id, error, capacity });
    }

    /// Fleet-wide fault isolation: terminate the dead replica's
    /// in-flight requests (retryable — the client re-submits), re-route
    /// its backlog to live siblings, and respawn from the original spec
    /// while the respawn budget lasts.
    fn drain_and_respawn(&mut self, i: usize, reason: &str, now: Instant,
                         out: &mut Vec<RouterEvent>) {
        if !self.replicas[i].alive {
            return;
        }
        self.replicas[i].alive = false;
        self.replicas[i].health = ReplicaHealth::default();
        global_tracer().record(EventKind::Drain {
            replica: i as u32,
            inflight: self.replicas[i].inflight.len() as u32,
            backlog: self.replicas[i].backlog.len() as u32,
        });
        crate::dpllm_log!(Warn, "router", "draining replica {i}: {reason}");
        let mut inflight: Vec<u64> =
            self.replicas[i].inflight.drain().map(|(id, _)| id).collect();
        inflight.sort_unstable();
        for id in inflight {
            self.counters.died_inflight += 1;
            out.push(RouterEvent::Rejected {
                id,
                error: format!("replica {i} died mid-flight: {reason}"),
                capacity: true,
            });
        }
        let backlog: Vec<RoutedRequest> =
            self.replicas[i].backlog.drain(..).collect();
        for mut rr in backlog {
            let snaps = self.snapshots();
            match pick_replica(&snaps, rr.premium) {
                Some(j) => {
                    rr.pinned = rr.pinned.map(|t| {
                        clamp_target(&self.replicas[j].spec.targets, t)
                    });
                    self.counters.rerouted += 1;
                    global_tracer().record(EventKind::Route {
                        id: rr.req.id,
                        replica: j as u32,
                        premium: rr.premium,
                    });
                    self.replicas[j].backlog.push_back(rr);
                }
                None => {
                    self.counters.rejects_capacity += 1;
                    out.push(RouterEvent::Rejected {
                        id: rr.req.id,
                        error: format!(
                            "no live replica (replica {i} died: {reason})"
                        ),
                        capacity: true,
                    });
                }
            }
        }
        if !self.replicas[i].stopped
            && self.replicas[i].respawns < self.cfg.max_respawns
        {
            // The old link is replaced; a wedged thread is abandoned
            // (threads cannot be killed), a panicked one already exited.
            let link = (self.spawn)(&self.replicas[i].spec);
            self.replicas[i].link = link;
            self.replicas[i].alive = true;
            self.replicas[i].ready = false;
            self.replicas[i].last_seen = now;
            self.replicas[i].respawns += 1;
            self.counters.respawns += 1;
            global_tracer().record(EventKind::Respawn { replica: i as u32 });
            crate::dpllm_log!(Info, "router", "respawned replica {i}");
            out.push(RouterEvent::Respawned { replica: i });
        }
    }

    /// Forward backlog to the replica while its in-flight window has
    /// room.  Requests left in the backlog stay stealable/re-routable.
    fn pump(&mut self, i: usize) {
        while self.replicas[i].alive
            && self.replicas[i].inflight.len() < self.cfg.max_inflight.max(1)
        {
            let Some(mut rr) = self.replicas[i].backlog.pop_front() else {
                break;
            };
            rr.pinned = rr
                .pinned
                .map(|t| clamp_target(&self.replicas[i].spec.targets, t));
            let cmd = ReplicaCommand::Submit {
                req: rr.req.clone(),
                pinned: rr.pinned,
            };
            if self.replicas[i].link.tx.send(cmd).is_err() {
                // Channel gone: keep the request; the death is detected
                // and drained on the next poll.
                self.replicas[i].backlog.push_front(rr);
                break;
            }
            rr.queued_ms = rr.enqueued.elapsed().as_secs_f64() * 1e3;
            global_tracer().record(EventKind::Forward {
                id: rr.req.id,
                replica: i as u32,
            });
            self.replicas[i].inflight.insert(rr.req.id, rr);
        }
    }

    /// Per-replica rows for the `replicas` array of `GET /metrics`.
    pub fn status(&self) -> Vec<ReplicaStatus> {
        self.replicas
            .iter()
            .map(|r| ReplicaStatus {
                id: r.spec.id,
                tier: r.spec.tags.join(","),
                premium: r.spec.premium,
                alive: r.alive,
                queue_depth: r.backlog.len(),
                inflight: r.inflight.len(),
                active: r.health.active,
                tokens_per_s: r.health.tokens_per_s,
                cold_start_ms: r.cold_start_ms,
                steals_in: r.steals_in,
                steals_out: r.steals_out,
                respawns: r.respawns,
                done: r.done,
            })
            .collect()
    }

    pub fn replicas_json(&self) -> Json {
        replicas_json(&self.status())
    }

    /// Fleet-level latency histograms (TTFT / ITL / router queue delay
    /// per SLO class).
    pub fn histograms(&self) -> HistogramSet {
        self.hist.clone()
    }

    /// The fleet half of `GET /metrics`: `router_*` counters, the
    /// per-replica `replicas` array, and per-class latency percentiles.
    pub fn metrics_json(&self) -> Json {
        let mut j = self.counters.json();
        j.set("replicas", self.replicas_json());
        j.set("latency", self.hist.json());
        j
    }

    /// Clean shutdown: ask every live replica to finish its active set
    /// and join the workers that can exit (wedged threads are
    /// abandoned).
    pub fn shutdown(&mut self) {
        for r in &mut self.replicas {
            if r.alive {
                let _ = r.link.tx.send(ReplicaCommand::Shutdown);
            }
        }
        for r in &mut self.replicas {
            if r.alive || r.stopped {
                if let Some(j) = r.link.join.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::qos::QosBudget;
    use crate::runtime::replica::sim::{sim_link, SimProfile};
    use crate::util::rng::Rng;

    fn snap(id: usize, alive: bool, premium: bool, tpot_ms: f64,
            queued: usize, active: usize) -> ReplicaSnapshot {
        ReplicaSnapshot { id, alive, premium, tpot_ms, queued, active }
    }

    #[test]
    fn pick_replica_prefers_class_then_shortest_delay() {
        let snaps = vec![
            snap(0, true, false, 1.0, 0, 0), // idle economy
            snap(1, true, true, 2.0, 0, 0),  // idle premium, slower tpot
            snap(2, true, true, 2.0, 3, 1),  // busy premium
        ];
        // Premium traffic prefers the idle premium replica even though
        // the economy one has a lower absolute delay.
        assert_eq!(pick_replica(&snaps, true), Some(1));
        assert_eq!(pick_replica(&snaps, false), Some(0));
        // With every premium replica dead, premium traffic degrades to
        // the economy replica instead of rejecting.
        let degraded = vec![
            snap(0, true, false, 1.0, 0, 0),
            snap(1, false, true, 2.0, 0, 0),
        ];
        assert_eq!(pick_replica(&degraded, true), Some(0));
        assert_eq!(pick_replica(&[], true), None);
        let all_dead = vec![snap(0, false, false, 1.0, 0, 0)];
        assert_eq!(pick_replica(&all_dead, false), None);
    }

    /// Property test over pseudo-random fleets: the pick is always
    /// alive; it matches the class whenever any alive replica of the
    /// class exists; and among alive class-matching replicas none has a
    /// strictly smaller expected delay (ties break to the lowest id).
    #[test]
    fn pick_replica_property_class_affinity_and_min_delay() {
        let mut rng = Rng::new(0xD0_07);
        for _ in 0..500 {
            let n = 1 + (rng.next_u64() % 6) as usize;
            let snaps: Vec<ReplicaSnapshot> = (0..n)
                .map(|id| ReplicaSnapshot {
                    id,
                    alive: rng.bool(0.8),
                    premium: rng.bool(0.5),
                    tpot_ms: rng.range(0.5, 8.0),
                    queued: (rng.next_u64() % 5) as usize,
                    active: (rng.next_u64() % 4) as usize,
                })
                .collect();
            for premium in [false, true] {
                let pick = pick_replica(&snaps, premium);
                let any_alive = snaps.iter().any(|s| s.alive);
                assert_eq!(pick.is_some(), any_alive);
                let Some(id) = pick else { continue };
                let chosen = snaps[id];
                assert!(chosen.alive, "picked a dead replica");
                let class_alive =
                    snaps.iter().any(|s| s.alive && s.premium == premium);
                if class_alive {
                    assert_eq!(chosen.premium, premium,
                               "class ignored while class replicas alive");
                    for s in snaps.iter().filter(|s| {
                        s.alive && s.premium == premium
                    }) {
                        let (d, dc) =
                            (expected_delay(s), expected_delay(&chosen));
                        assert!(dc < d + 1e-12,
                                "replica {} had smaller delay", s.id);
                        if (d - dc).abs() < 1e-12 {
                            assert!(chosen.id <= s.id, "tie not to lowest id");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pick_steal_idle_thief_deepest_victim_threshold() {
        let snaps = vec![
            snap(0, true, false, 1.0, 5, 2),
            snap(1, true, true, 2.0, 0, 0), // idle
            snap(2, true, false, 1.0, 3, 1),
        ];
        assert_eq!(pick_steal(&snaps, 2), Some((0, 1)));
        // Below threshold: no steal.
        let shallow = vec![
            snap(0, true, false, 1.0, 1, 2),
            snap(1, true, true, 2.0, 0, 0),
        ];
        assert_eq!(pick_steal(&shallow, 2), None);
        // No idle replica: no steal.
        let busy = vec![
            snap(0, true, false, 1.0, 5, 2),
            snap(1, true, true, 2.0, 0, 1),
        ];
        assert_eq!(pick_steal(&busy, 2), None);
        // A dead idle replica never steals.
        let dead_thief = vec![
            snap(0, true, false, 1.0, 5, 2),
            snap(1, false, true, 2.0, 0, 0),
        ];
        assert_eq!(pick_steal(&dead_thief, 2), None);
    }

    #[test]
    fn clamp_split_and_parse_tiers() {
        assert_eq!(clamp_target(&[3.25, 3.5], 4.75), 3.5);
        assert_eq!(clamp_target(&[4.5, 4.75], 3.25), 4.5);
        assert_eq!(clamp_target(&[], 4.0), 4.0);
        let tags: Vec<String> = ["3.25", "3.50", "4.00", "4.50", "4.75"]
            .iter()
            .map(|t| t.to_string())
            .collect();
        assert_eq!(split_tiers(&tags, 2), vec![
            vec!["3.25".to_string(), "3.50".to_string(), "4.00".to_string()],
            vec!["4.50".to_string(), "4.75".to_string()],
        ]);
        assert_eq!(split_tiers(&tags, 1).len(), 1);
        assert_eq!(split_tiers(&tags, 9).len(), 5, "clamped to ladder");
        let parsed = parse_replica_tiers("3.25,3.50|4.00, 4.50").unwrap();
        assert_eq!(parsed, vec![
            vec!["3.25".to_string(), "3.50".to_string()],
            vec!["4.00".to_string(), "4.50".to_string()],
        ]);
        assert!(parse_replica_tiers("3.25||4.00").is_err());
    }

    // ---- fleet tests over simulated workers (REAL router logic) ----

    fn fast(core_slots: usize) -> SimProfile {
        SimProfile { token_us: 50, slots: core_slots, ..SimProfile::default() }
    }

    fn two_tier_specs() -> Vec<ReplicaSpec> {
        vec![
            ReplicaSpec::sim(0, &["3.25", "3.50"], false, 1.0),
            ReplicaSpec::sim(1, &["4.50", "4.75"], true, 2.0),
        ]
    }

    fn eco_req(id: u64, max_new: usize) -> Request {
        Request::new(id, "p", max_new, QosBudget::best_effort())
    }

    fn prem_req(id: u64, max_new: usize) -> Request {
        Request::new(id, "p", max_new, QosBudget::tight(5.0))
            .with_deadline(1000.0)
    }

    /// Drive the router until `want` terminal events (Done / Failed /
    /// Rejected) or the deadline passes; returns every event seen.
    fn drive(router: &mut Router, want: usize, ms: u64) -> Vec<RouterEvent> {
        let mut events = Vec::new();
        let deadline = Instant::now() + Duration::from_millis(ms);
        while Instant::now() < deadline {
            events.extend(router.poll());
            let terminal = events
                .iter()
                .filter(|e| !matches!(e, RouterEvent::Respawned { .. }))
                .count();
            if terminal >= want {
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        events
    }

    fn done_ids(events: &[RouterEvent]) -> Vec<u64> {
        let mut ids: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                RouterEvent::Done { outcome, .. } => Some(outcome.id),
                _ => None,
            })
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Class routing over live workers: premium requests complete at
    /// premium-tier precisions, economy at economy-tier, and both
    /// counters advance.
    #[test]
    fn class_routing_maps_qos_to_tier() {
        let mut router = Router::new(
            two_tier_specs(),
            Box::new(|spec| sim_link(spec, fast(4))),
            RouterConfig::default(),
        );
        for id in 0..4u64 {
            let ev = if id % 2 == 0 {
                router.submit(eco_req(id, 4), None)
            } else {
                router.submit(prem_req(id, 4), None)
            };
            assert!(ev.is_none());
        }
        let events = drive(&mut router, 4, 2000);
        assert_eq!(done_ids(&events), vec![0, 1, 2, 3]);
        for ev in &events {
            if let RouterEvent::Done { outcome, .. } = ev {
                if outcome.id % 2 == 1 {
                    assert!(outcome.target_precision >= 4.5,
                            "premium request served at economy precision");
                } else {
                    assert!(outcome.target_precision <= 3.5,
                            "economy request served at premium precision");
                }
            }
        }
        let c = router.counters();
        assert_eq!(c.routed_premium, 2);
        assert_eq!(c.routed_economy, 2);
        // Fleet histograms: one record per terminal Done, keyed by the
        // request's SLO class, surfaced under `latency` in /metrics.
        let lat = router.metrics_json();
        let lat = lat.get("latency").unwrap();
        assert_eq!(lat.get("premium").unwrap().f64_of("n").unwrap(), 2.0);
        assert_eq!(lat.get("economy").unwrap().f64_of("n").unwrap(), 2.0);
        router.shutdown();
    }

    #[test]
    fn work_steal_moves_backlog_between_replicas() {
        let cfg = RouterConfig {
            max_inflight: 1,
            steal_threshold: 2,
            ..RouterConfig::default()
        };
        let mut router = Router::new(
            two_tier_specs(),
            // Slow economy worker, one slot — a deep backlog forms.
            Box::new(|spec| {
                let p = if spec.id == 0 {
                    SimProfile { token_us: 2000, slots: 1,
                                 ..SimProfile::default() }
                } else {
                    fast(4)
                };
                sim_link(spec, p)
            }),
            cfg,
        );
        // All-economy burst: everything routes to replica 0; replica 1
        // idles and must steal.
        for id in 0..10u64 {
            assert!(router.submit(eco_req(id, 4), None).is_none());
        }
        let events = drive(&mut router, 10, 5000);
        assert_eq!(done_ids(&events), (0..10).collect::<Vec<u64>>(),
                   "every request completed despite the skewed burst");
        let c = router.counters();
        assert!(c.steals >= 1, "idle premium replica never stole");
        let status = router.status();
        assert!(status[1].steals_in >= 1);
        assert_eq!(status[0].steals_out, status[1].steals_in);
        router.shutdown();
    }

    /// Chaos regression: replica 0 panics mid-run.  Healthy requests on
    /// the sibling complete, the dead replica's backlog re-routes and
    /// completes, its in-flight requests terminate retryably, and the
    /// counters prove exactly one respawn.
    #[test]
    fn replica_panic_drains_and_respawns() {
        let cfg = RouterConfig {
            max_inflight: 2,
            steal_threshold: usize::MAX, // isolate respawn from stealing
            ..RouterConfig::default()
        };
        let mut router = Router::new(
            two_tier_specs(),
            Box::new(|spec| {
                let p = if spec.id == 0 {
                    // The fault is token-count-keyed, so the respawned
                    // worker (which starts from zero and inherits no
                    // re-routed work — that went to the sibling) never
                    // re-trips it.
                    SimProfile { token_us: 500, slots: 2,
                                 panic_after_tokens: Some(6),
                                 ..SimProfile::default() }
                } else {
                    fast(4)
                };
                sim_link(spec, p)
            }),
            cfg,
        );
        for id in 0..6u64 {
            assert!(router.submit(eco_req(id, 2), None).is_none());
        }
        for id in 6..8u64 {
            assert!(router.submit(prem_req(id, 2), None).is_none());
        }
        let events = drive(&mut router, 8, 5000);
        let c = router.counters();
        assert_eq!(c.respawns, 1, "exactly one respawn");
        assert!(events.iter().any(|e| matches!(
            e, RouterEvent::Respawned { replica: 0 })));
        // Premium (healthy sibling) requests all completed.
        let done = done_ids(&events);
        assert!(done.contains(&6) && done.contains(&7),
                "healthy sibling requests lost");
        // Every request is accounted for: Done or retryable Rejected.
        let mut seen: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                RouterEvent::Done { outcome, .. } => Some(outcome.id),
                RouterEvent::Rejected { id, capacity: true, .. } => Some(*id),
                _ => None,
            })
            .collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, (0..8).collect::<Vec<u64>>(),
                   "a request vanished during the drain");
        assert!(c.died_inflight > 0 || c.rerouted > 0,
                "the dead replica's work was never drained");
        // /metrics reports the respawn.
        let arr = router.replicas_json();
        let rows = arr.as_arr().unwrap();
        assert_eq!(rows[0].f64_of("respawns").unwrap(), 1.0);
        router.shutdown();
    }

    /// Satellite: a per-replica capacity reject retries on a sibling
    /// before surfacing — `router_retries` advances, the request still
    /// completes, and nothing 503s.
    #[test]
    fn capacity_reject_retries_on_sibling() {
        let mut router = Router::new(
            two_tier_specs(),
            Box::new(|spec| {
                let p = if spec.id == 0 {
                    SimProfile { reject_first: true, token_us: 50,
                                 ..SimProfile::default() }
                } else {
                    fast(4)
                };
                sim_link(spec, p)
            }),
            RouterConfig::default(),
        );
        assert!(router.submit(eco_req(0, 3), None).is_none());
        let events = drive(&mut router, 1, 2000);
        assert_eq!(done_ids(&events), vec![0],
                   "request did not complete on the sibling");
        let c = router.counters();
        assert_eq!(c.retries, 1);
        assert_eq!(c.rejects_capacity, 0, "retry leaked into a 503");
        router.shutdown();
    }

    /// With no sibling to retry on, the capacity reject surfaces as a
    /// retryable (503-shaped) event and `router_rejects_capacity`
    /// advances.
    #[test]
    fn capacity_reject_surfaces_without_sibling() {
        let mut router = Router::new(
            vec![ReplicaSpec::sim(0, &["4.00"], true, 1.0)],
            Box::new(|spec| {
                sim_link(spec, SimProfile { reject_first: true, token_us: 50,
                                            ..SimProfile::default() })
            }),
            RouterConfig::default(),
        );
        assert!(router.submit(eco_req(0, 3), None).is_none());
        let events = drive(&mut router, 1, 2000);
        assert!(events.iter().any(|e| matches!(
            e, RouterEvent::Rejected { id: 0, capacity: true, .. })));
        assert_eq!(router.counters().rejects_capacity, 1);
        assert_eq!(router.counters().retries, 0);
        router.shutdown();
    }

    /// Specs whose workers effectively never heartbeat, so wedge tests
    /// are deterministic: no beat can race the fabricated clock.
    fn silent_specs() -> Vec<ReplicaSpec> {
        two_tier_specs()
            .into_iter()
            .map(|mut s| {
                s.heartbeat_ms = 1_000_000;
                s
            })
            .collect()
    }

    /// Wedge detection is pure clock arithmetic: a fabricated `poll_at`
    /// far in the future declares every silent replica wedged, drains
    /// it, and respawns it.
    #[test]
    fn heartbeat_timeout_drains_and_respawns_wedged_replica() {
        let mut router = Router::new(
            silent_specs(),
            Box::new(|spec| sim_link(spec, fast(4))),
            RouterConfig {
                heartbeat_timeout: Duration::from_millis(100),
                ..RouterConfig::default()
            },
        );
        // Drain both workers' Ready (arming the heartbeat timer), then
        // jump the clock past the timeout: every silent replica looks
        // wedged.
        let arm = Instant::now() + Duration::from_secs(2);
        while router.ready_count() < 2 && Instant::now() < arm {
            router.poll();
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(router.ready_count(), 2, "workers never became ready");
        let future = Instant::now() + Duration::from_secs(10);
        let events = router.poll_at(future);
        let respawned = events
            .iter()
            .filter(|e| matches!(e, RouterEvent::Respawned { .. }))
            .count();
        assert_eq!(respawned, 2, "both silent replicas respawned");
        assert_eq!(router.counters().respawns, 2);
        assert_eq!(router.alive_count(), 2, "fleet recovered");
        router.shutdown();
    }

    /// A replica that is still loading (no event sent yet) must be
    /// judged against the long startup deadline, not the heartbeat
    /// one — a real engine's load/compile easily outlasts the 2s
    /// heartbeat timeout, and misdeclaring it wedged respawns it into
    /// a load loop that exhausts the budget and kills the fleet.
    #[test]
    fn slow_startup_is_not_wedged_before_ready() {
        use std::sync::mpsc;
        let spawn = |_spec: &ReplicaSpec| {
            let (cmd_tx, cmd_rx) = mpsc::channel::<ReplicaCommand>();
            let (ev_tx, ev_rx) = mpsc::channel();
            // "Loads" for 200 ms before Ready, then idles silently.
            let join = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(200));
                let _ = ev_tx.send(ReplicaEvent::Ready {
                    cold_start_ms: 200.0,
                });
                loop {
                    match cmd_rx.recv() {
                        Ok(ReplicaCommand::Shutdown) | Err(_) => return,
                        Ok(_) => {}
                    }
                }
            });
            ReplicaLink { tx: cmd_tx, rx: ev_rx, join: Some(join) }
        };
        let mut router = Router::new(
            vec![ReplicaSpec::sim(0, &["4.00"], true, 1.0)],
            Box::new(spawn),
            RouterConfig {
                heartbeat_timeout: Duration::from_millis(50),
                ..RouterConfig::default()
            },
        );
        // Far past the heartbeat timeout but well inside the startup
        // deadline: the loading replica must NOT be declared wedged.
        let events = router.poll_at(Instant::now() + Duration::from_secs(10));
        assert!(events.is_empty(), "loading replica was drained");
        assert_eq!(router.counters().respawns, 0,
                   "slow load respawned mid-load");
        assert_eq!(router.alive_count(), 1);
        // Once Ready arrives the heartbeat timer arms: the same clock
        // jump now declares the (silent) replica wedged.
        let arm = Instant::now() + Duration::from_secs(2);
        while router.ready_count() < 1 && Instant::now() < arm {
            router.poll();
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(router.ready_count(), 1, "worker never became ready");
        let events = router.poll_at(Instant::now() + Duration::from_secs(10));
        assert!(events.iter().any(|e| matches!(
            e, RouterEvent::Respawned { replica: 0 })));
        assert_eq!(router.counters().respawns, 1);
        router.shutdown();
    }

    /// A panic delivers Died AND a closed channel in the same poll;
    /// the drain must run once — a double drain would abandon the
    /// freshly respawned worker and burn a second respawn.
    #[test]
    fn died_then_disconnect_respawns_once() {
        let mut router = Router::new(
            vec![ReplicaSpec::sim(0, &["4.00"], false, 1.0)],
            Box::new(|spec| {
                sim_link(spec, SimProfile {
                    token_us: 50,
                    slots: 1,
                    panic_after_tokens: Some(1),
                    ..SimProfile::default()
                })
            }),
            RouterConfig::default(),
        );
        assert!(router.submit(eco_req(0, 4), None).is_none());
        // Let the worker panic AND fully unwind (its event channel
        // drops), so a single poll sees Died followed by Disconnected.
        std::thread::sleep(Duration::from_millis(300));
        let events = router.poll();
        assert_eq!(router.counters().respawns, 1,
                   "double drain burned two respawns");
        let respawned = events
            .iter()
            .filter(|e| matches!(e, RouterEvent::Respawned { .. }))
            .count();
        assert_eq!(respawned, 1);
        assert_eq!(router.alive_count(), 1, "fresh worker was abandoned");
        router.shutdown();
    }

    /// The respawn budget caps revival: a spec that keeps dying stops
    /// being respawned and the fleet routes around it.
    #[test]
    fn respawn_budget_caps_revival() {
        let mut router = Router::new(
            silent_specs(),
            Box::new(|spec| sim_link(spec, fast(4))),
            RouterConfig {
                heartbeat_timeout: Duration::from_millis(50),
                max_respawns: 2,
                ..RouterConfig::default()
            },
        );
        // Each wedge→respawn cycle takes two fabricated polls (one
        // drains the fresh worker's Ready, arming the heartbeat timer;
        // the next declares it wedged again).  Poll until the budget of
        // 2 per replica is spent and the final drain leaves the fleet
        // dead — the terminal state is absorbing, so the loop is exact.
        let wall = Instant::now() + Duration::from_secs(5);
        let mut future = Instant::now();
        while router.counters().respawns < 4 || router.alive_count() > 0 {
            if Instant::now() >= wall {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
            future += Duration::from_secs(10);
            router.poll_at(future);
        }
        assert_eq!(router.counters().respawns, 4, "2 per replica, capped");
        assert_eq!(router.alive_count(), 0);
        // With the whole fleet down, submission rejects retryably.
        let ev = router.submit(eco_req(99, 2), None);
        assert!(matches!(ev, Some(RouterEvent::Rejected { capacity: true, .. })));
        router.shutdown();
    }
}
