//! The serving engine + token-interleaved serving core.
//!
//! [`ServingEngine`] binds one model's adaptation set (DP-LLM configurations
//! at several target precisions) to the PJRT runtime.  [`ServingCore`] is
//! the decode loop around it: it admits requests mid-flight from the
//! [`RequestQueue`], keeps every active generation's KV cache device-resident
//! ([`GenState`]), round-robins (FIFO) or deadline-orders (EDF) **per
//! token** across the active set, re-selects each request's target
//! precision mid-stream when utilization moves, and streams token events to
//! the caller.  One decode step serves one token of one request — a tight
//! deadline admitted mid-generation preempts best-effort traffic at the
//! next token boundary instead of waiting a whole generation.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::{MetricsRegistry, RequestRecord};
use super::qos::{AdaptationPolicy, UtilizationSim};
use super::sched::{Request, RequestQueue, SchedPolicy};
use crate::anyprec::materialize::MatSnapshot;
use crate::evalharness::{build_session_with_cache, engine_config_for, Method};
use crate::model::{art, Manifest, ModelAssets};
use crate::runtime::decode::{DecodeSession, EstMode, GenState, SwapReport, WeightCache};
use crate::runtime::Runtime;
use crate::selector::EngineConfig;
use crate::tokenizer::Tokenizer;

/// Tokens between utilization ticks / mid-stream target re-selection in the
/// interleaved loop.
pub const RESELECT_EVERY: u64 = 8;

/// Default cap on concurrently-interleaved generations (KV caches resident
/// on the device at once).
pub const DEFAULT_MAX_ACTIVE: usize = 4;

pub struct ServeOutcome {
    pub id: u64,
    pub text: String,
    pub target_precision: f64,
    pub effective_bits: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Request arrival → first streamed token (includes queue wait,
    /// prefill, and any interleaving delay before the first step).
    pub ttft_ms: f64,
    pub output_tokens: usize,
    /// Mid-stream target re-selections applied to this request.
    pub retargets: usize,
}

/// One event from a [`ServingCore::step`] call.
pub enum CoreEvent {
    /// A token was produced for request `id` (streaming callback payload).
    Token {
        id: u64,
        /// 0-based index within the request's output.
        index: usize,
        token: u32,
        /// Detokenized piece (may be empty for byte-partial tokens).
        piece: String,
        /// Target precision the token was decoded at.
        target: f64,
    },
    /// Request finished; terminal stats.
    Done(ServeOutcome),
    /// Request aborted on a decode error; the generation was evicted so
    /// the rest of the active set keeps serving.
    Failed { id: u64, error: String },
}

/// One model + its adaptation set, ready to serve.
pub struct ServingEngine {
    pub tokenizer: Tokenizer,
    /// target precision -> session (dynamic DP-LLM configs).
    sessions: BTreeMap<String, DecodeSession>,
    targets: Vec<(f64, String)>,
    pub policy: AdaptationPolicy,
    pub metrics: MetricsRegistry,
    pub est_mode: EstMode,
    /// Weight materialization cache shared by every session of the
    /// adaptation set: each (group, layer, bits) slab dequantizes and
    /// uploads once no matter how many targets use it, and
    /// [`ServingEngine::reconfigure`] rebinds are delta-materialized.
    weights: WeightCache,
    rt: Arc<Runtime>,
    /// Retained so [`ServingEngine::reconfigure`] rebinds without
    /// re-reading the packed store from disk (the store itself is an
    /// `Arc` already shared with every session).
    assets: ModelAssets,
    manifest: Manifest,
    budget: u32,
}

impl ServingEngine {
    /// Load DP-LLM configurations for every `tags` entry (e.g. "3.50").
    pub fn load(rt: &Arc<Runtime>, model: &str, budget: u32,
                tags: &[&str]) -> Result<ServingEngine> {
        let assets = ModelAssets::load(model)?;
        let manifest = Manifest::load()?;
        let tokenizer = Tokenizer::load(&art(&["data", "tokenizer.json"]))?;
        let weights = DecodeSession::fresh_weight_cache();
        let mut sessions = BTreeMap::new();
        let mut targets = Vec::new();
        for tag in tags {
            let m = Method::Dpllm { tag: tag.to_string() };
            let s = build_session_with_cache(rt, &assets, &manifest, budget, &m,
                                             weights.clone())?;
            targets.push((s.ec.target, tag.to_string()));
            sessions.insert(tag.to_string(), s);
        }
        if sessions.is_empty() {
            return Err(anyhow!("no configurations loaded"));
        }
        // Calibrate the adaptation policy with measured TPOTs.
        let mut options = Vec::new();
        for (target, tag) in &targets {
            let s = &sessions[tag];
            let tpot = measure_tpot(s, 3)?;
            options.push((*target, tpot));
        }
        Ok(ServingEngine {
            tokenizer,
            sessions,
            targets,
            policy: AdaptationPolicy::new(options),
            metrics: MetricsRegistry::new(),
            est_mode: EstMode::Approx,
            weights,
            rt: rt.clone(),
            assets,
            manifest,
            budget,
        })
    }

    /// Counters of the shared weight materialization cache (companion to
    /// `Runtime::transfers()` for the §Perf config-switch contract).
    pub fn weight_cache_stats(&self) -> MatSnapshot {
        self.weights.borrow().snapshot()
    }

    /// Swap the adaptation set at runtime (FlexQuant's scenario: the
    /// memory/latency envelope moved, so the coordinator re-selects which
    /// target precisions to keep resident).  Sessions for retained tags
    /// are untouched; a retired session is **rebound in place** to the
    /// first missing tag via [`DecodeSession::swap_bits`] (re-uploading
    /// only layers whose bits differ), and only when no retired session
    /// is available does a tag build fresh — through the shared cache, so
    /// even that re-uploads only slabs never materialized before.
    /// Requires exclusive access: call between [`ServingCore`] runs.
    ///
    /// Error semantics: config resolution failures (unknown tag, missing
    /// calib) happen before any state changes — the old set stays fully
    /// active.  A device-level failure mid-swap returns `Err` with the
    /// engine still **consistent and serviceable**, but the resident set
    /// may mix new and old tags; inspect [`ServingEngine::targets`] to
    /// see what is actually loaded before retrying.
    pub fn reconfigure(&mut self, tags: &[&str]) -> Result<SwapReport> {
        if tags.is_empty() {
            return Err(anyhow!("reconfigure to an empty adaptation set"));
        }
        let keep: Vec<String> = tags.iter().map(|t| t.to_string()).collect();
        // Resolve every missing tag's config BEFORE touching engine state,
        // so the common failure (unknown tag / missing calib) leaves the
        // current adaptation set fully intact.
        let mut pending: Vec<(String, EngineConfig)> = Vec::new();
        for tag in &keep {
            if self.sessions.contains_key(tag)
                || pending.iter().any(|(t, _)| t == tag)
            {
                continue;
            }
            let m = Method::Dpllm { tag: tag.clone() };
            pending.push((tag.clone(), engine_config_for(&self.assets, self.budget, &m)?));
        }
        let mut retired: Vec<(String, DecodeSession)> = Vec::new();
        let current: Vec<String> = self.sessions.keys().cloned().collect();
        for tag in current {
            if !keep.contains(&tag) {
                let s = self.sessions.remove(&tag).expect("listed key");
                retired.push((tag, s));
            }
        }
        let mut rep = SwapReport::default();
        let mut failure = None;
        for (tag, ec) in pending {
            let s = match retired.pop() {
                // swap_bits is atomic: on error the session is still fully
                // on its old configuration, so it goes back under its old
                // tag below.
                Some((old_tag, mut s)) => match s.swap_bits(ec) {
                    Ok(r) => {
                        rep.absorb(r);
                        s
                    }
                    Err(e) => {
                        failure = Some(e);
                        retired.push((old_tag, s));
                        break;
                    }
                },
                None => match DecodeSession::new_shared(
                    self.rt.clone(), &self.assets, &self.manifest, ec,
                    self.weights.clone())
                {
                    Ok(s) => s,
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                },
            };
            self.sessions.insert(tag, s);
        }
        if failure.is_some() {
            // Device-level failure mid-swap: restore the unconsumed retired
            // sessions so the engine never serves from an empty set.
            for (tag, s) in retired {
                self.sessions.insert(tag, s);
            }
        }
        // Targets always derive from the sessions actually resident.
        self.targets = self
            .sessions
            .iter()
            .map(|(tag, s)| (s.ec.target, tag.clone()))
            .collect();
        // Re-calibrate the adaptation policy for the new set.  A probe
        // failure falls back to the previous calibration's nearest
        // estimate so policy and targets never diverge — and never masks
        // an earlier swap failure.
        let mut options = Vec::new();
        for (target, tag) in &self.targets {
            let tpot = match measure_tpot(&self.sessions[tag], 3) {
                Ok(ms) => ms,
                Err(e) => {
                    let fallback = self
                        .policy
                        .options
                        .iter()
                        .min_by(|a, b| {
                            (a.0 - *target)
                                .abs()
                                .partial_cmp(&(b.0 - *target).abs())
                                .unwrap()
                        })
                        .map(|(_, ms)| *ms)
                        .unwrap_or(1.0);
                    if failure.is_none() {
                        failure = Some(e);
                    }
                    fallback
                }
            };
            options.push((*target, tpot));
        }
        self.policy = AdaptationPolicy::new(options);
        match failure {
            Some(e) => Err(e),
            None => Ok(rep),
        }
    }

    pub fn session_for_target(&self, target: f64) -> &DecodeSession {
        let tag = self
            .targets
            .iter()
            .min_by(|a, b| {
                (a.0 - target).abs().partial_cmp(&(b.0 - target).abs()).unwrap()
            })
            .map(|(_, tag)| tag.clone())
            .expect("nonempty");
        &self.sessions[&tag]
    }

    pub fn targets(&self) -> Vec<f64> {
        self.targets.iter().map(|(t, _)| *t).collect()
    }

    /// Serve one request at the target chosen by the QoS policy.
    pub fn handle(&self, req: &Request, utilization: f64) -> Result<ServeOutcome> {
        let mut core = ServingCore::new(self, SchedPolicy::Fifo);
        core.admit(req.clone(), utilization)?;
        drain_single(core)
    }

    /// Serve one request pinned to a specific target precision (no
    /// mid-stream re-selection).
    pub fn handle_at(&self, req: &Request, target: f64) -> Result<ServeOutcome> {
        let mut core = ServingCore::new(self, SchedPolicy::Fifo);
        core.admit_pinned(req.clone(), target)?;
        drain_single(core)
    }

    /// Drain a queue through the token-interleaved core: admission happens
    /// mid-flight as slots free up, decode steps round-robin / EDF across
    /// the active set, and the utilization simulator advances on the
    /// re-selection cadence.
    pub fn run_queue(&self, queue: &mut RequestQueue, util: &mut UtilizationSim)
                     -> Result<Vec<ServeOutcome>> {
        self.run_queue_streaming(queue, util, &mut |_| {})
    }

    /// [`ServingEngine::run_queue`] with a streaming event callback.
    pub fn run_queue_streaming(&self, queue: &mut RequestQueue,
                               util: &mut UtilizationSim,
                               on_event: &mut dyn FnMut(&CoreEvent))
                               -> Result<Vec<ServeOutcome>> {
        ServingCore::new(self, queue.policy()).run(queue, util, on_event)
    }
}

fn drain_single(mut core: ServingCore<'_>) -> Result<ServeOutcome> {
    let mut failure: Option<String> = None;
    let mut outcomes = core.drain(&mut |ev| {
        if let CoreEvent::Failed { error, .. } = ev {
            failure = Some(error.clone());
        }
    })?;
    match outcomes.pop() {
        Some(o) => Ok(o),
        None => Err(anyhow!(
            failure.unwrap_or_else(|| "request produced no outcome".into())
        )),
    }
}

/// Pure next-step selection over the active set, factored out so the
/// fairness / preemption properties are unit-testable without a device.
///
/// `items` carries, per active generation, its admission sequence number
/// and its absolute deadline (None = best effort).  FIFO round-robins via
/// `rr_cursor`; EDF picks the earliest deadline (best-effort last), with
/// the admission sequence as the FIFO tie-break.
pub fn pick_next(policy: SchedPolicy, rr_cursor: usize,
                 items: &[(u64, Option<Instant>)]) -> Option<usize> {
    if items.is_empty() {
        return None;
    }
    match policy {
        SchedPolicy::Fifo => Some(rr_cursor % items.len()),
        SchedPolicy::Edf => items
            .iter()
            .enumerate()
            .min_by_key(|(_, (seq, dl))| (dl.is_none(), *dl, *seq))
            .map(|(i, _)| i),
    }
}

/// One in-flight generation inside the core.
struct Generation<'e> {
    req: Request,
    session: &'e DecodeSession,
    gen: GenState<'e>,
    target: f64,
    pinned: bool,
    seq: u64,
    next_token: u32,
    out_ids: Vec<u32>,
    queue_ms: f64,
    prefill_ms: f64,
    decode_ms: f64,
    ttft_ms: f64,
}

impl Generation<'_> {
    fn finished(&self) -> bool {
        self.out_ids.len() >= self.req.max_new
            || self.gen.pos + 1 >= self.session.cfg.max_seq
    }
}

/// Token-interleaved decode loop over one [`ServingEngine`].
pub struct ServingCore<'e> {
    engine: &'e ServingEngine,
    policy: SchedPolicy,
    active: Vec<Generation<'e>>,
    rr_cursor: usize,
    next_seq: u64,
    max_active: usize,
    token_clock: u64,
}

impl<'e> ServingCore<'e> {
    pub fn new(engine: &'e ServingEngine, policy: SchedPolicy) -> ServingCore<'e> {
        ServingCore {
            engine,
            policy,
            active: Vec::new(),
            rr_cursor: 0,
            next_seq: 0,
            max_active: DEFAULT_MAX_ACTIVE,
            token_clock: 0,
        }
    }

    pub fn with_max_active(mut self, n: usize) -> ServingCore<'e> {
        self.max_active = n.max(1);
        self
    }

    pub fn has_active(&self) -> bool {
        !self.active.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.max_active
    }

    /// Decode steps taken since construction (drives the re-selection
    /// cadence).
    pub fn token_clock(&self) -> u64 {
        self.token_clock
    }

    /// Admit one request at the QoS-policy target for `utilization`.
    /// Runs prefill immediately (max precision), so the request's first
    /// token is ready before the next [`ServingCore::step`].
    pub fn admit(&mut self, req: Request, utilization: f64) -> Result<u64> {
        let target = self.engine.policy.select(req.qos, utilization);
        self.admit_inner(req, target, false)
    }

    /// Admit pinned to a target precision; never re-selected mid-stream.
    pub fn admit_pinned(&mut self, req: Request, target: f64) -> Result<u64> {
        self.admit_inner(req, target, true)
    }

    /// Pull requests from the queue while there is capacity.
    pub fn admit_from(&mut self, queue: &mut RequestQueue, utilization: f64)
                      -> Result<usize> {
        let mut admitted = 0;
        while self.has_capacity() {
            match queue.pop() {
                Some(r) => {
                    self.admit(r, utilization)?;
                    admitted += 1;
                }
                None => break,
            }
        }
        Ok(admitted)
    }

    fn admit_inner(&mut self, req: Request, target: f64, pinned: bool)
                   -> Result<u64> {
        if !self.has_capacity() {
            return Err(anyhow!("core at capacity ({})", self.max_active));
        }
        let session = self.engine.session_for_target(target);
        let queue_ms = req.arrival.elapsed().as_secs_f64() * 1e3;
        let prompt_ids = self.engine.tokenizer.encode(&req.prompt);
        if prompt_ids.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        let t0 = Instant::now();
        let (gen, logits) = session.begin(&prompt_ids)?;
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        let first = DecodeSession::argmax(&logits)?;
        let id = req.id;
        self.active.push(Generation {
            req,
            session,
            gen,
            target: session.ec.target,
            pinned,
            seq: self.next_seq,
            next_token: first,
            out_ids: vec![first],
            queue_ms,
            prefill_ms,
            decode_ms: 0.0,
            // Finalized when the first token actually streams; under load
            // that is later than admission+prefill (the generation may wait
            // behind deadlined traffic before its first step).
            ttft_ms: queue_ms + prefill_ms,
        });
        self.next_seq += 1;
        Ok(id)
    }

    /// Re-select the target precision of every non-pinned active
    /// generation for the current utilization.  A retargeted generation
    /// keeps its device-resident KV cache and effective-bit statistics;
    /// the new session adopts the state ([`DecodeSession::adopt`]).
    pub fn reselect(&mut self, utilization: f64) -> usize {
        let mut switched = 0;
        for g in &mut self.active {
            if g.pinned || g.finished() {
                continue;
            }
            let want = self.engine.policy.select(g.req.qos, utilization);
            let session = self.engine.session_for_target(want);
            if !std::ptr::eq(session, g.session) {
                g.session = session;
                session.adopt(&mut g.gen);
                g.target = session.ec.target;
                switched += 1;
            }
        }
        switched
    }

    /// Advance ONE generation by ONE token (policy-chosen), emitting the
    /// streamed token event and, on completion, the terminal outcome.
    /// The first call for a request emits its prefill-produced token 0.
    pub fn step(&mut self) -> Result<Vec<CoreEvent>> {
        let items: Vec<(u64, Option<Instant>)> = self
            .active
            .iter()
            .map(|g| (g.seq, g.req.deadline_instant()))
            .collect();
        let Some(idx) = pick_next(self.policy, self.rr_cursor, &items) else {
            return Ok(Vec::new());
        };
        self.rr_cursor = self.rr_cursor.wrapping_add(1);
        self.token_clock += 1;
        let mut events = Vec::new();

        let g = &mut self.active[idx];
        // Token 0 (from prefill) streams on the generation's first step;
        // TTFT is measured to *here*, not to admission.
        if g.gen.steps == 0 {
            g.ttft_ms = g.req.arrival.elapsed().as_secs_f64() * 1e3;
            events.push(CoreEvent::Token {
                id: g.req.id,
                index: 0,
                token: g.next_token,
                piece: self.engine.tokenizer.decode_one(g.next_token),
                target: g.target,
            });
        }
        if !g.finished() {
            let t0 = Instant::now();
            let stepped = g
                .session
                .advance(&mut g.gen, g.next_token, self.engine.est_mode)
                .and_then(|out| DecodeSession::argmax(&out.logits));
            g.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
            let next = match stepped {
                Ok(n) => n,
                Err(e) => {
                    // Evict the broken generation; the rest of the active
                    // set keeps interleaving.
                    let g = self.active.remove(idx);
                    events.push(CoreEvent::Failed {
                        id: g.req.id,
                        error: format!("{e:#}"),
                    });
                    return Ok(events);
                }
            };
            g.next_token = next;
            g.out_ids.push(next);
            events.push(CoreEvent::Token {
                id: g.req.id,
                index: g.out_ids.len() - 1,
                token: next,
                piece: self.engine.tokenizer.decode_one(next),
                target: g.target,
            });
        }
        if g.finished() {
            let g = self.active.remove(idx);
            events.push(CoreEvent::Done(self.complete(g)));
        }
        Ok(events)
    }

    /// Run everything to completion: admit from `queue` as capacity frees
    /// up, tick `util` on the re-selection cadence, stream events.
    pub fn run(mut self, queue: &mut RequestQueue, util: &mut UtilizationSim,
               on_event: &mut dyn FnMut(&CoreEvent)) -> Result<Vec<ServeOutcome>> {
        let mut done = Vec::new();
        while self.has_active() || !queue.is_empty() {
            self.admit_from(queue, util.current())?;
            if self.token_clock % RESELECT_EVERY == 0 {
                let u = util.tick();
                self.reselect(u);
            }
            for ev in self.step()? {
                on_event(&ev);
                if let CoreEvent::Done(o) = ev {
                    done.push(o);
                }
            }
        }
        Ok(done)
    }

    /// Finish all currently-active generations (no further admission).
    pub fn drain(&mut self, on_event: &mut dyn FnMut(&CoreEvent))
                 -> Result<Vec<ServeOutcome>> {
        let mut done = Vec::new();
        while self.has_active() {
            for ev in self.step()? {
                on_event(&ev);
                if let CoreEvent::Done(o) = ev {
                    done.push(o);
                }
            }
        }
        Ok(done)
    }

    fn complete(&self, g: Generation<'e>) -> ServeOutcome {
        let eff = g.gen.sel.effective_bits();
        self.engine.metrics.record(RequestRecord {
            id: g.req.id,
            target_precision: g.target,
            effective_bits: eff,
            prompt_tokens: g.gen.pos - g.out_ids.len() + 1,
            output_tokens: g.out_ids.len(),
            queue_ms: g.queue_ms,
            prefill_ms: g.prefill_ms,
            decode_ms: g.decode_ms,
        });
        ServeOutcome {
            id: g.req.id,
            text: self.engine.tokenizer.decode(&g.out_ids),
            target_precision: g.target,
            effective_bits: eff,
            prefill_ms: g.prefill_ms,
            decode_ms: g.decode_ms,
            ttft_ms: g.ttft_ms,
            output_tokens: g.out_ids.len(),
            retargets: g.gen.retargets,
        }
    }
}

/// Measure mean decode-step latency over `n` steps (policy calibration).
pub fn measure_tpot(session: &DecodeSession, n: usize) -> Result<f64> {
    let mut gen = session.begin_empty()?;
    // Warm-up step (compile caches, allocator, rope/scalar buffers).
    session.advance(&mut gen, 1, EstMode::Approx)?;
    let t0 = Instant::now();
    for _ in 0..n {
        session.advance(&mut gen, 1, EstMode::Approx)?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3 / n as f64)
}

/// Build a FIFO/EDF queue from (prompt, qos) pairs — workload-gen helper.
pub fn make_queue(policy: SchedPolicy,
                  reqs: impl IntoIterator<Item = Request>) -> RequestQueue {
    let mut q = RequestQueue::new(policy);
    for r in reqs {
        q.push(r);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn now_plus(ms: u64) -> Option<Instant> {
        Some(Instant::now() + Duration::from_millis(ms))
    }

    /// FIFO interleaving fairness: with two active generations, each must
    /// advance within any 2-token window.
    #[test]
    fn fifo_round_robin_two_way_fairness() {
        let items = vec![(0u64, None), (1u64, None)];
        let mut picks = Vec::new();
        for cursor in 0..10 {
            picks.push(pick_next(SchedPolicy::Fifo, cursor, &items).unwrap());
        }
        for w in picks.windows(2) {
            assert_ne!(w[0], w[1], "a generation starved in a 2-token window");
        }
        assert!(picks.contains(&0) && picks.contains(&1));
    }

    /// FIFO cursor sweeps all active generations before repeating.
    #[test]
    fn fifo_round_robin_covers_all() {
        let items: Vec<(u64, Option<Instant>)> =
            (0..5u64).map(|s| (s, None)).collect();
        let picked: Vec<usize> = (0..5)
            .map(|c| pick_next(SchedPolicy::Fifo, c, &items).unwrap())
            .collect();
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    /// EDF at token granularity: the tightest deadline is stepped first,
    /// regardless of admission order; best-effort runs last; admission
    /// sequence breaks ties.
    #[test]
    fn edf_token_granularity_preemption() {
        let items = vec![
            (0u64, None),            // admitted first, best effort
            (1u64, now_plus(5000)),  // loose deadline
            (2u64, now_plus(50)),    // tight deadline, admitted last
        ];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &items), Some(2));

        // Tie on deadline -> FIFO by admission seq.
        let t = now_plus(300);
        let tied = vec![(7u64, t), (3u64, t)];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &tied), Some(1));

        // All best-effort -> earliest admission.
        let be = vec![(9u64, None), (4u64, None), (6u64, None)];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &be), Some(1));
    }

    #[test]
    fn pick_next_empty_is_none() {
        assert_eq!(pick_next(SchedPolicy::Fifo, 3, &[]), None);
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &[]), None);
    }
}
