//! The serving engine + token-interleaved serving core.
//!
//! [`ServingEngine`] binds one model's adaptation set (DP-LLM configurations
//! at several target precisions) to the PJRT runtime.  [`ServingCore`] is
//! the decode loop around it: it admits requests mid-flight from the
//! [`RequestQueue`], keeps every active generation's KV cache device-resident
//! ([`GenState`]), round-robins (FIFO) or deadline-orders (EDF) **per
//! token** across the active set, re-selects each request's target
//! precision mid-stream when utilization moves, and streams token events to
//! the caller.  A tight deadline admitted mid-generation preempts
//! best-effort traffic at the next token boundary instead of waiting a
//! whole generation.
//!
//! Each scheduling step serves one token of the policy-chosen request —
//! and, when the batched decode artifacts are available, one token of
//! every *batch-compatible* runnable request alongside it in the SAME
//! device dispatch: [`pick_batch`] groups the active set by target
//! session (same weight-stack device buffers, same KV shape bucket) and
//! [`DecodeSession::advance_batch`] packs the group into one
//! `decode_step_b{2,4,8}` call, preserving FIFO/EDF semantics (the lead
//! is always exactly [`pick_next`]'s choice) while cutting device
//! dispatches per generated token from 1.0 toward 1/B — DESIGN.md
//! §Batching.  When no batch forms (mixed targets, B = 1 artifacts,
//! `DPLLM_NO_BATCH`) every step degenerates to the per-request path.
//!
//! A spec-eligible generation running **alone** instead rides
//! self-speculative decoding (DESIGN.md §Speculation): the adaptation
//! set's lowest-precision session drafts γ tokens for free off the
//! any-precision overlay, one `verify_step_g{γ}` dispatch at the target
//! precision scores them all, and the accepted run streams in order —
//! up to γ+1 tokens per dispatch where batching has no partner to
//! amortize with.  Best-effort and loose-deadline requests are eligible;
//! tight-EDF requests keep token-granular preemption.  The degradation
//! ladder is spec → batched → single, every rung preserving greedy
//! numerics exactly.  All knobs live in [`CoreConfig`].
//!
//! Prompt ingestion is a scheduled work unit, not an admission-time
//! stall (DESIGN.md §Prefill): [`ServingCore::admit`] only tokenizes,
//! validates and allocates the slot (phase `Prefilling`), and every
//! `step()` interleaves **at most one** `prefill_chunk_<P>` dispatch
//! with the decode paths — so active decodes never wait on more than one
//! bounded chunk between tokens, prompts are no longer capped at the
//! largest prefill bucket, and the first token streams (TTFT stamps)
//! the round the last chunk lands.  Admission is fault-isolated:
//! [`ServingCore::admit_from`] turns a rejected request (empty
//! tokenization, over-long prompt, capacity race) into a terminal
//! [`CoreEvent::Error`] for that id plus an `admit_rejects` count and
//! keeps draining — one bad prompt can no longer abort the serving loop
//! with every in-flight request.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::{counters_json, counters_report, memory_json, MetricsRegistry,
                     RequestRecord};
use super::qos::{AdaptationPolicy, UtilizationSim};
use super::sched::{Request, RequestQueue, SchedPolicy};
use crate::anyprec::materialize::MatSnapshot;
use crate::evalharness::{build_session_with_cache, engine_config_for, Method};
use crate::model::{art, Manifest, ModelAssets};
use crate::obs::{global_tracer, EventKind};
use crate::runtime::decode::{DecodeSession, EstMode, GenState, SwapReport, WeightCache};
use crate::runtime::kvpool::{self, KvPool, SharedKvPool};
use crate::runtime::spec::{spec_eligible, spec_round, truncate_at_eos,
                           GammaController, SpecState, MAX_SPEC_CATCHUP};
use crate::runtime::Runtime;
use crate::selector::EngineConfig;
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;

/// Default tokens between utilization ticks / mid-stream target
/// re-selection in the interleaved loop ([`CoreConfig::reselect_every`]).
pub const RESELECT_EVERY: u64 = 8;

/// Precision in integer milli-bits for `Copy` flight-recorder events
/// (4.5 bits → 4500); non-finite values (no decode steps yet) map to 0.
fn milli_bits(bits: f64) -> u32 {
    if bits.is_finite() && bits > 0.0 {
        (bits * 1000.0).round() as u32
    } else {
        0
    }
}

/// Default cap on concurrently-interleaved generations (KV caches resident
/// on the device at once).
pub const DEFAULT_MAX_ACTIVE: usize = 4;

/// Default cap on the speculative draft length γ
/// ([`CoreConfig::gamma_cap`]); 0 disables speculation outright.
pub const DEFAULT_GAMMA_CAP: usize = 4;

/// Default boundary between "tight" and "loose" deadlines for the spec
/// path ([`CoreConfig::loose_deadline_ms`]): requests whose deadline is at
/// least this far out may commit multi-token speculative runs; tighter
/// deadlines keep token-granular EDF preemption.
pub const DEFAULT_LOOSE_DEADLINE_MS: f64 = 1_000.0;

/// Runtime-tunable knobs of the [`ServingCore`] scheduling loop.  The
/// `Default` instance reproduces the historical hard-coded behavior;
/// [`CoreConfig::from_env`] layers the environment escape hatches on top
/// and is what [`ServingCore::new`] uses, so deployments tune the loop
/// without recompiling (the `serve` CLI additionally plumbs flags).
#[derive(Debug, Clone)]
pub struct CoreConfig {
    /// Concurrently-interleaved generations (device KV residency cap).
    pub max_active: usize,
    /// Generations per shared device dispatch (1 = per-request dispatch;
    /// further capped by the lead session's largest `decode_step_b*`).
    pub max_batch: usize,
    /// Tokens between utilization ticks / mid-stream re-selection.
    pub reselect_every: u64,
    /// Largest speculative draft length γ the controller may pick
    /// (candidates are further limited to the compiled `verify_step_g*`
    /// graphs); 0 disables speculation.
    pub gamma_cap: usize,
    /// Master switch for the speculative path (`DPLLM_NO_SPEC` clears it).
    pub spec: bool,
    /// Deadlines at least this many ms out still ride the spec path.
    pub loose_deadline_ms: f64,
    /// Token that terminates a generation when it is emitted, on EVERY
    /// decode path — plain, batched, and speculative (where it truncates
    /// the accepted run, EOS kept) — so speculation and plain decode
    /// stay token-for-token identical.  `None` (the default) preserves
    /// the historical behavior: generations run to `max_new`.
    pub eos_token: Option<u32>,
}

impl Default for CoreConfig {
    fn default() -> CoreConfig {
        CoreConfig {
            max_active: DEFAULT_MAX_ACTIVE,
            max_batch: usize::MAX,
            reselect_every: RESELECT_EVERY,
            gamma_cap: DEFAULT_GAMMA_CAP,
            spec: true,
            loose_deadline_ms: DEFAULT_LOOSE_DEADLINE_MS,
            eos_token: None,
        }
    }
}

impl CoreConfig {
    /// Defaults + environment overrides: `DPLLM_NO_BATCH=1` forces
    /// per-request dispatch, `DPLLM_NO_SPEC=1` disables speculation,
    /// `DPLLM_RESELECT_EVERY=<n>` retunes the re-selection cadence and
    /// `DPLLM_GAMMA_CAP=<n>` caps the speculative draft length.
    pub fn from_env() -> CoreConfig {
        let mut c = CoreConfig::default();
        if std::env::var_os("DPLLM_NO_BATCH").is_some() {
            c.max_batch = 1;
        }
        if std::env::var_os("DPLLM_NO_SPEC").is_some() {
            c.spec = false;
        }
        if let Some(n) = std::env::var("DPLLM_RESELECT_EVERY")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
        {
            c.reselect_every = n.max(1);
        }
        if let Some(n) = std::env::var("DPLLM_GAMMA_CAP")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            c.gamma_cap = n;
        }
        c
    }
}

pub struct ServeOutcome {
    pub id: u64,
    pub text: String,
    pub target_precision: f64,
    pub effective_bits: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Request arrival → first streamed token.  The prompt's chunk
    /// dispatches are *scheduled* across token rounds, so this includes
    /// queue wait, every chunk, and the decode rounds interleaved
    /// between them (≥ queue + prefill, never their conflation).
    pub ttft_ms: f64,
    pub output_tokens: usize,
    /// Scheduled prompt-ingestion dispatches this request took
    /// (1 for a bucket-sized prompt; ceil(len / chunk) beyond it).
    pub prefill_chunks: u64,
    /// Mid-stream target re-selections applied to this request.
    pub retargets: usize,
}

/// One event from a [`ServingCore::step`] call.
pub enum CoreEvent {
    /// A token was produced for request `id` (streaming callback payload).
    Token {
        id: u64,
        /// 0-based index within the request's output.
        index: usize,
        token: u32,
        /// Detokenized piece (may be empty for byte-partial tokens).
        piece: String,
        /// Target precision the token was decoded at.
        target: f64,
    },
    /// Request finished; terminal stats.
    Done(ServeOutcome),
    /// Request aborted on a decode/prefill error mid-flight; the
    /// generation was evicted so the rest of the active set keeps
    /// serving.
    Failed { id: u64, error: String },
    /// Admission rejected: terminal for `id`, which never held a slot.
    /// The serving loop keeps draining — see [`ServingCore::admit_from`]
    /// and [`ServingCore::admit_rejects`].  `capacity` distinguishes the
    /// two reject families for transport-level status mapping: `true`
    /// means the request was fine but the core was full (slot cap or KV
    /// pool exhausted — retryable, HTTP 503), `false` means the request
    /// itself was malformed (empty tokenization, over-long prompt —
    /// HTTP 400).
    Error { id: u64, error: String, capacity: bool },
}

/// Typed admission error for the slot-cap reject, so transports can
/// classify it (alongside [`kvpool::PoolExhausted`]) as retryable
/// capacity pressure rather than a malformed request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoreAtCapacity(pub usize);

impl std::fmt::Display for CoreAtCapacity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "core at capacity ({} slots)", self.0)
    }
}

impl std::error::Error for CoreAtCapacity {}

/// Is this admission error a capacity reject (full core or exhausted KV
/// pool) rather than a malformed request?  Capacity rejects are
/// transient: the same request can succeed once load drains, so
/// transports map them to 503 + `Retry-After` instead of 400.
pub fn is_capacity_reject(e: &anyhow::Error) -> bool {
    e.is::<CoreAtCapacity>() || e.is::<kvpool::PoolExhausted>()
}

/// One model + its adaptation set, ready to serve.
pub struct ServingEngine {
    pub tokenizer: Tokenizer,
    /// target precision -> session (dynamic DP-LLM configs).
    sessions: BTreeMap<String, DecodeSession>,
    targets: Vec<(f64, String)>,
    pub policy: AdaptationPolicy,
    pub metrics: MetricsRegistry,
    pub est_mode: EstMode,
    /// Weight materialization cache shared by every session of the
    /// adaptation set: each (group, layer, bits) slab dequantizes and
    /// uploads once no matter how many targets use it, and
    /// [`ServingEngine::reconfigure`] rebinds are delta-materialized.
    weights: WeightCache,
    /// Byte-budgeted KV pool shared by every session of the adaptation
    /// set (tier free lists + shared-prefix cache — DESIGN.md §Memory).
    /// Budget from `DPLLM_KV_BUDGET_BYTES` (CLI `--kv-budget`), else
    /// unbounded: accounting runs but admission never rejects on bytes.
    kv_pool: SharedKvPool,
    rt: Arc<Runtime>,
    /// Retained so [`ServingEngine::reconfigure`] rebinds without
    /// re-reading the packed store from disk.  Behind an `Arc` so the
    /// multi-replica path ([`crate::runtime::replica`]) parses the packed
    /// store once and shares it across every replica engine.
    assets: Arc<ModelAssets>,
    manifest: Manifest,
    budget: u32,
    /// Wall time [`ServingEngine::load_shared`] took to go from shared
    /// assets to a servable adaptation set (session builds + TPOT
    /// calibration) — the per-replica cold-start cost the fleet metrics
    /// row and flight recorder surface.
    pub cold_start_ms: f64,
}

impl ServingEngine {
    /// Load DP-LLM configurations for every `tags` entry (e.g. "3.50").
    pub fn load(rt: &Arc<Runtime>, model: &str, budget: u32,
                tags: &[&str]) -> Result<ServingEngine> {
        let assets = Arc::new(ModelAssets::load(model)?);
        ServingEngine::load_shared(rt, assets, budget, tags)
    }

    /// Like [`ServingEngine::load`], but over already-loaded assets — the
    /// multi-replica path parses the packed store once and every replica
    /// engine shares the same `Arc<ModelAssets>` (and so the same
    /// `Arc<AnyPrecStore>`), materializing only its slice of the
    /// precision ladder.  Device-side caches (weights, KV) stay
    /// per-engine: PJRT buffers are per-client and `!Send`.
    pub fn load_shared(rt: &Arc<Runtime>, assets: Arc<ModelAssets>,
                       budget: u32, tags: &[&str]) -> Result<ServingEngine> {
        let t0 = Instant::now();
        let manifest = Manifest::load()?;
        let tokenizer = Tokenizer::load(&art(&["data", "tokenizer.json"]))?;
        // Resolve every tag's config first (cheap — no sessions, no
        // device) to learn the highest bitwidth this adaptation set ever
        // dequantizes, then serve from a tier-sliced store view: an
        // economy-tier engine keeps only the planes it needs reachable.
        // The container mapping stays shared across replicas either way
        // (slicing clones Arcs; no weight bytes move).  A later
        // `reconfigure` to a tag above the slice fails with the typed
        // residency error — boot the replica with that tag in scope
        // instead.
        let mut needed = crate::anyprec::MIN_BITS;
        for tag in tags {
            let m = Method::Dpllm { tag: tag.to_string() };
            needed = needed.max(engine_config_for(&assets, budget, &m)?.max_bits());
        }
        let assets = if needed < assets.store.max_bits() {
            Arc::new(assets.sliced(needed)?)
        } else {
            assets
        };
        let weights = DecodeSession::fresh_weight_cache();
        let mut sessions = BTreeMap::new();
        let mut targets = Vec::new();
        for tag in tags {
            let m = Method::Dpllm { tag: tag.to_string() };
            let s = build_session_with_cache(rt, &assets, &manifest, budget, &m,
                                             weights.clone())?;
            targets.push((s.ec.target, tag.to_string()));
            sessions.insert(tag.to_string(), s);
        }
        if sessions.is_empty() {
            return Err(anyhow!("no configurations loaded"));
        }
        // One byte-budgeted KV pool for the whole adaptation set (every
        // session shares the model's KV geometry, so bytes-per-token is
        // uniform).  The prefix-cache tag is model:target — different
        // precision targets prefill through different weight stacks and
        // must never share prefix KV.
        let first = sessions.values().next().expect("nonempty");
        let kv_len: usize = first.cfg.kv_shape().iter().product();
        let bytes_per_token = kv_len / first.cfg.max_seq.max(1) * 4;
        let kv_budget = kvpool::budget_from_env().unwrap_or(usize::MAX);
        let kv_pool: SharedKvPool =
            Rc::new(RefCell::new(KvPool::new(kv_budget, bytes_per_token)));
        for (tag, s) in sessions.iter_mut() {
            let t = format!("{}:{tag}", s.cfg.name);
            s.set_kv_pool(kv_pool.clone(), &t);
        }
        // Calibrate the adaptation policy with measured TPOTs.
        let mut options = Vec::new();
        for (target, tag) in &targets {
            let s = &sessions[tag];
            let tpot = measure_tpot(s, 3)?;
            options.push((*target, tpot));
        }
        Ok(ServingEngine {
            tokenizer,
            sessions,
            targets,
            policy: AdaptationPolicy::new(options),
            metrics: MetricsRegistry::new(),
            est_mode: EstMode::Approx,
            weights,
            kv_pool,
            rt: rt.clone(),
            assets,
            manifest,
            budget,
            cold_start_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Counters of the shared weight materialization cache (companion to
    /// `Runtime::transfers()` for the §Perf config-switch contract).
    pub fn weight_cache_stats(&self) -> MatSnapshot {
        self.weights.borrow().snapshot()
    }

    /// The shared KV pool (tier free lists + prefix cache).
    pub fn kv_pool(&self) -> &SharedKvPool {
        &self.kv_pool
    }

    /// KV pool pressure (`in_use / budget`; 0.0 when unbounded) — the
    /// signal `costmodel::downshift_for_pressure` turns into admission
    /// backpressure.
    pub fn kv_pressure(&self) -> f64 {
        self.kv_pool.borrow().pressure()
    }

    /// Cheap byte-admission pre-gate: could the pool hold one more
    /// generation at its smallest birth tier?  (The authoritative check
    /// is the charge inside admission itself; this keeps queue-driven
    /// admission from popping requests it must immediately reject.)
    pub fn kv_would_admit(&self) -> bool {
        let s = self.sessions.values().next().expect("nonempty");
        let tier = s.kv_tiers().first().copied().unwrap_or(s.cfg.max_seq);
        self.kv_pool.borrow().would_admit(tier)
    }

    /// The combined "where is device memory going" report: weight-cache
    /// bytes + KV pool bytes and budgets, one object (surfaced in
    /// `counters_json`, `GET /metrics` and the serve examples).
    pub fn memory_json(&self) -> Json {
        let mut j = memory_json(&self.weights.borrow().snapshot(),
                                &self.kv_pool.borrow().stats());
        // Host-side packed-store residency: how the weight container got
        // into memory (mmap vs copy) and how much of the precision ladder
        // this engine keeps reachable.
        let st = self.assets.store.stats();
        let mut store = Json::obj();
        store.set("mapped", st.mapped);
        store.set("plane_bytes_mapped", st.plane_bytes_mapped as f64);
        store.set("plane_bytes_copied", st.plane_bytes_copied as f64);
        store.set("lut_bytes_mapped", st.lut_bytes_mapped as f64);
        store.set("lut_bytes_copied", st.lut_bytes_copied as f64);
        store.set("load_ms", st.load_ms);
        store.set("resident_max_bits", self.assets.store.max_bits() as usize);
        if let Some(meta) = self.assets.store.meta() {
            store.set("model", meta.model.as_str());
            store.set("version", meta.version.as_str());
        }
        j.set("store", store);
        j
    }

    /// One serialized snapshot of every runtime counter family —
    /// transfers, weight cache, batching, speculation, KV pool — via the
    /// shared serializer (`coordinator::metrics::counters_json`).  Backs
    /// the `counters` field of `GET /metrics` and the examples' reports.
    pub fn counters_json(&self) -> Json {
        let mut j = counters_json(&self.rt.transfers().snapshot(),
                                  &self.weights.borrow().snapshot());
        j.set("memory", self.memory_json());
        j
    }

    /// Human-readable one-liner over [`ServingEngine::counters_json`]'s
    /// snapshot (examples / CLI end-of-run reports).
    pub fn counters_report(&self) -> String {
        counters_report(&self.rt.transfers().snapshot(),
                        &self.weights.borrow().snapshot())
    }

    /// Costmodel-priced TPOT of a target precision over THIS model's
    /// real packed-store byte counts, at the memory-bandwidth-bound
    /// asymptote (stream time only, no fixed per-token overhead).  The γ
    /// controller prices speculative rounds with this rather than the
    /// measured TPOTs: sandbox-scale measurements are overhead-dominated
    /// (DESIGN.md §2), which would hide exactly the low-bit draft
    /// advantage that reappears at paper scale — the affine slope is the
    /// quantity speculation arbitrages.
    pub fn modeled_tpot_ms(&self, target: f64) -> f64 {
        let bytes = crate::costmodel::weight_bytes_at(&self.assets.store, target);
        crate::costmodel::JETSON_ORIN.stream_ms(bytes)
    }

    /// The draft half of a self-speculative pair for `target`: the
    /// adaptation set's lowest-precision session — resident for free via
    /// the any-precision overlay.  `None` when speculation cannot engage:
    /// the target has no compiled `verify_step_g*` graphs (old
    /// artifacts), or it *is* the lowest-precision member (a draft as
    /// slow as its target can never win; the γ controller would sit at 0
    /// anyway, so the draft prefill is not worth paying).
    pub fn spec_draft_for(&self, target: &DecodeSession) -> Option<&DecodeSession> {
        if target.spec_gammas().is_empty() {
            return None;
        }
        let (_, tag) = self
            .targets
            .iter()
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())?;
        let draft = &self.sessions[tag];
        if std::ptr::eq(draft, target) {
            None
        } else {
            Some(draft)
        }
    }

    /// Swap the adaptation set at runtime (FlexQuant's scenario: the
    /// memory/latency envelope moved, so the coordinator re-selects which
    /// target precisions to keep resident).  Sessions for retained tags
    /// are untouched; a retired session is **rebound in place** to the
    /// first missing tag via [`DecodeSession::swap_bits`] (re-uploading
    /// only layers whose bits differ), and only when no retired session
    /// is available does a tag build fresh — through the shared cache, so
    /// even that re-uploads only slabs never materialized before.
    /// Requires exclusive access: call between [`ServingCore`] runs.
    ///
    /// Error semantics: config resolution failures (unknown tag, missing
    /// calib) happen before any state changes — the old set stays fully
    /// active.  A device-level failure mid-swap returns `Err` with the
    /// engine still **consistent and serviceable**, but the resident set
    /// may mix new and old tags; inspect [`ServingEngine::targets`] to
    /// see what is actually loaded before retrying.
    pub fn reconfigure(&mut self, tags: &[&str]) -> Result<SwapReport> {
        if tags.is_empty() {
            return Err(anyhow!("reconfigure to an empty adaptation set"));
        }
        let keep: Vec<String> = tags.iter().map(|t| t.to_string()).collect();
        // Resolve every missing tag's config BEFORE touching engine state,
        // so the common failure (unknown tag / missing calib) leaves the
        // current adaptation set fully intact.
        let mut pending: Vec<(String, EngineConfig)> = Vec::new();
        for tag in &keep {
            if self.sessions.contains_key(tag)
                || pending.iter().any(|(t, _)| t == tag)
            {
                continue;
            }
            let m = Method::Dpllm { tag: tag.clone() };
            pending.push((tag.clone(), engine_config_for(&self.assets, self.budget, &m)?));
        }
        let mut retired: Vec<(String, DecodeSession)> = Vec::new();
        let current: Vec<String> = self.sessions.keys().cloned().collect();
        for tag in current {
            if !keep.contains(&tag) {
                let s = self.sessions.remove(&tag).expect("listed key");
                retired.push((tag, s));
            }
        }
        let retired_tags: Vec<String> =
            retired.iter().map(|(t, _)| t.clone()).collect();
        let mut rep = SwapReport::default();
        let mut failure = None;
        for (tag, ec) in pending {
            let mut s = match retired.pop() {
                // swap_bits is atomic: on error the session is still fully
                // on its old configuration, so it goes back under its old
                // tag below.
                Some((old_tag, mut s)) => match s.swap_bits(ec) {
                    Ok(r) => {
                        rep.absorb(r);
                        s
                    }
                    Err(e) => {
                        failure = Some(e);
                        retired.push((old_tag, s));
                        break;
                    }
                },
                None => match DecodeSession::new_shared(
                    self.rt.clone(), &self.assets, &self.manifest, ec,
                    self.weights.clone())
                {
                    Ok(s) => s,
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                },
            };
            // (Re)bind the shared KV pool under the *new* target identity:
            // prefix-cache keys are per `(model, target)` so a rebound
            // session never resurrects KV prefilled through other weights.
            let prefix_tag = format!("{}:{tag}", s.cfg.name);
            s.set_kv_pool(self.kv_pool.clone(), &prefix_tag);
            self.sessions.insert(tag, s);
        }
        if failure.is_some() {
            // Device-level failure mid-swap: restore the unconsumed retired
            // sessions so the engine never serves from an empty set.
            for (tag, s) in retired {
                self.sessions.insert(tag, s);
            }
        }
        // Shared-prefix entries are keyed `model:target`, so a retired
        // target's entries can never be *hit* again — but they WOULD
        // strand pool bytes (and device KV buffers) until LRU pressure
        // ages them out, shrinking the budget available to live targets.
        // Invalidate eagerly for every tag that actually left the set
        // (tags restored by the failure path above are still live).
        {
            let model_name = self.assets.cfg.name.clone();
            let mut pool = self.kv_pool.borrow_mut();
            for tag in &retired_tags {
                if !self.sessions.contains_key(tag) {
                    pool.invalidate_tag(&format!("{model_name}:{tag}"));
                }
            }
        }
        // Targets always derive from the sessions actually resident.
        self.targets = self
            .sessions
            .iter()
            .map(|(tag, s)| (s.ec.target, tag.clone()))
            .collect();
        // Re-calibrate the adaptation policy for the new set.  A probe
        // failure falls back to the previous calibration's nearest
        // estimate so policy and targets never diverge — and never masks
        // an earlier swap failure.
        let mut options = Vec::new();
        for (target, tag) in &self.targets {
            let tpot = match measure_tpot(&self.sessions[tag], 3) {
                Ok(ms) => ms,
                Err(e) => {
                    let fallback = self
                        .policy
                        .options
                        .iter()
                        .min_by(|a, b| {
                            (a.0 - *target)
                                .abs()
                                .partial_cmp(&(b.0 - *target).abs())
                                .unwrap()
                        })
                        .map(|(_, ms)| *ms)
                        .unwrap_or(1.0);
                    if failure.is_none() {
                        failure = Some(e);
                    }
                    fallback
                }
            };
            options.push((*target, tpot));
        }
        self.policy = AdaptationPolicy::new(options);
        if failure.is_none() {
            global_tracer().record(EventKind::SwapBits {
                stacks: rep.stacks_rebuilt as u32,
                layers: rep.layers_changed as u32,
                uploads: rep.selector_uploads as u32,
            });
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(rep),
        }
    }

    pub fn session_for_target(&self, target: f64) -> &DecodeSession {
        let tag = self
            .targets
            .iter()
            .min_by(|a, b| {
                (a.0 - target).abs().partial_cmp(&(b.0 - target).abs()).unwrap()
            })
            .map(|(_, tag)| tag.clone())
            .expect("nonempty");
        &self.sessions[&tag]
    }

    pub fn targets(&self) -> Vec<f64> {
        self.targets.iter().map(|(t, _)| *t).collect()
    }

    /// Serve one request at the target chosen by the QoS policy.
    pub fn handle(&self, req: &Request, utilization: f64) -> Result<ServeOutcome> {
        let mut core = ServingCore::new(self, SchedPolicy::Fifo);
        core.admit(req.clone(), utilization)?;
        drain_single(core)
    }

    /// Serve one request pinned to a specific target precision (no
    /// mid-stream re-selection).
    pub fn handle_at(&self, req: &Request, target: f64) -> Result<ServeOutcome> {
        let mut core = ServingCore::new(self, SchedPolicy::Fifo);
        core.admit_pinned(req.clone(), target)?;
        drain_single(core)
    }

    /// Drain a queue through the token-interleaved core: admission happens
    /// mid-flight as slots free up, decode steps round-robin / EDF across
    /// the active set, and the utilization simulator advances on the
    /// re-selection cadence.
    pub fn run_queue(&self, queue: &mut RequestQueue, util: &mut UtilizationSim)
                     -> Result<Vec<ServeOutcome>> {
        self.run_queue_streaming(queue, util, &mut |_| {})
    }

    /// [`ServingEngine::run_queue`] with a streaming event callback.
    pub fn run_queue_streaming(&self, queue: &mut RequestQueue,
                               util: &mut UtilizationSim,
                               on_event: &mut dyn FnMut(&CoreEvent))
                               -> Result<Vec<ServeOutcome>> {
        ServingCore::new(self, queue.policy()).run(queue, util, on_event)
    }
}

fn drain_single(mut core: ServingCore<'_>) -> Result<ServeOutcome> {
    let mut failure: Option<String> = None;
    let mut outcomes = core.drain(&mut |ev| {
        if let CoreEvent::Failed { error, .. } = ev {
            failure = Some(error.clone());
        }
    })?;
    match outcomes.pop() {
        Some(o) => Ok(o),
        None => Err(anyhow!(
            failure.unwrap_or_else(|| "request produced no outcome".into())
        )),
    }
}

/// Pure next-step selection over the active set, factored out so the
/// fairness / preemption properties are unit-testable without a device.
///
/// `items` carries, per active generation, its admission sequence number
/// and its absolute deadline (None = best effort).  FIFO round-robins via
/// `rr_cursor`; EDF picks the earliest deadline (best-effort last), with
/// the admission sequence as the FIFO tie-break.
pub fn pick_next(policy: SchedPolicy, rr_cursor: usize,
                 items: &[(u64, Option<Instant>)]) -> Option<usize> {
    if items.is_empty() {
        return None;
    }
    match policy {
        SchedPolicy::Fifo => Some(rr_cursor % items.len()),
        SchedPolicy::Edf => edf_pick(items),
    }
}

/// The one EDF ordering rule, shared by [`pick_next`] and
/// [`pick_prefill`] so the decode and prefill schedulers can never
/// silently diverge: earliest absolute deadline first, best-effort
/// (None) last, admission sequence as the FIFO tie-break.
fn edf_pick(items: &[(u64, Option<Instant>)]) -> Option<usize> {
    items
        .iter()
        .enumerate()
        .min_by_key(|(_, (seq, dl))| (dl.is_none(), *dl, *seq))
        .map(|(i, _)| i)
}

/// One active generation as seen by [`pick_batch`]: admission sequence,
/// absolute deadline (None = best effort), and an opaque
/// batch-compatibility key.  Two generations may share a device dispatch
/// only when their keys are equal; the serving core keys on the target
/// [`DecodeSession`] pointer, which subsumes "same weight-stack `Arc`"
/// and "compatible KV shape bucket" (one session = one model config =
/// one `[L, 2, H, Smax, hd]` KV bucket).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchItem {
    pub seq: u64,
    pub deadline: Option<Instant>,
    pub key: usize,
}

/// Select up to `max_batch` active generations to advance in ONE device
/// dispatch.  Pure, so the grouping/fairness properties are unit-testable
/// without a device.  Contract:
///
/// * the scheduling *lead* is exactly [`pick_next`]'s choice — batching
///   never changes who is served next, only who rides along for free;
/// * only items sharing the lead's `key` join the batch;
/// * FIFO: membership is a circular window starting at the lead (so the
///   `rr_cursor` rotation stays fair when more than `max_batch`
///   compatible generations are runnable); the returned order is
///   admission order, i.e. stable slot/event order across steps;
/// * EDF: membership and order are earliest-deadline-first with the
///   admission sequence as tie-break and best-effort last — deadline
///   priority is preserved *within* the batch;
/// * `max_batch <= 1` degenerates to `vec![pick_next(..)]`.
pub fn pick_batch(policy: SchedPolicy, rr_cursor: usize, items: &[BatchItem],
                  max_batch: usize) -> Vec<usize> {
    let pairs: Vec<(u64, Option<Instant>)> =
        items.iter().map(|it| (it.seq, it.deadline)).collect();
    let Some(lead) = pick_next(policy, rr_cursor, &pairs) else {
        return Vec::new();
    };
    pick_batch_with_lead(policy, lead, items, max_batch)
}

/// [`pick_batch`] with the scheduling lead already computed: the serving
/// core calls [`pick_next`] once to derive the batch cap from the lead's
/// session, then reuses that pick here — one policy scan per step and a
/// single source of truth for the "lead == pick_next's choice" contract.
fn pick_batch_with_lead(policy: SchedPolicy, lead: usize, items: &[BatchItem],
                        max_batch: usize) -> Vec<usize> {
    let key = items[lead].key;
    let cap = max_batch.max(1);
    let mut sel = vec![lead];
    match policy {
        SchedPolicy::Fifo => {
            for off in 1..items.len() {
                if sel.len() >= cap {
                    break;
                }
                let i = (lead + off) % items.len();
                if items[i].key == key {
                    sel.push(i);
                }
            }
            sel.sort_by_key(|&i| items[i].seq);
        }
        SchedPolicy::Edf => {
            let mut rest: Vec<usize> = (0..items.len())
                .filter(|&i| i != lead && items[i].key == key)
                .collect();
            rest.sort_by_key(|&i| {
                (items[i].deadline.is_none(), items[i].deadline, items[i].seq)
            });
            rest.truncate(cap - 1);
            sel.extend(rest);
        }
    }
    sel
}

/// Pure choice of which prompt-ingesting (`Prefilling`-phase)
/// generation runs its next chunk this round, factored out like
/// [`pick_next`] so the ordering properties are unit-testable without a
/// device.  EDF: earliest deadline first (best-effort last), admission
/// sequence as the tie-break — a deadlined long prompt reaches its first
/// token before best-effort ones; FIFO: admission order.
pub fn pick_prefill(policy: SchedPolicy,
                    items: &[(u64, Option<Instant>)]) -> Option<usize> {
    match policy {
        SchedPolicy::Fifo => items
            .iter()
            .enumerate()
            .min_by_key(|(_, (seq, _))| *seq)
            .map(|(i, _)| i),
        SchedPolicy::Edf => edf_pick(items),
    }
}

/// Where one in-flight generation is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Prompt ingestion in progress: `ingested` of `prompt_ids` tokens
    /// are in the device-resident KV cache; [`ServingCore::step`] runs at
    /// most one more chunk per round ([`DecodeSession::prefill_advance`]).
    Prefilling { ingested: usize },
    /// Prompt fully ingested; `next_token`/`out_ids` are live and the
    /// generation competes for decode dispatches.
    Decoding,
}

/// One in-flight generation inside the core.
struct Generation<'e> {
    req: Request,
    session: &'e DecodeSession,
    gen: GenState<'e>,
    target: f64,
    pinned: bool,
    seq: u64,
    /// The tokenized prompt; `out_ids[j]` was fed (or will be fed) at
    /// absolute position `prompt_ids.len() + j`.
    prompt_ids: Vec<u32>,
    phase: Phase,
    next_token: u32,
    out_ids: Vec<u32>,
    /// Speculation pair state: the low-bit draft generation + γ
    /// controller.  `None` when the request is ineligible (tight
    /// deadline), speculation is disabled, the artifacts lack verify
    /// graphs, the prompt exceeds the draft's bucketed prefill, or a
    /// speculative round failed (permanent per-request fallback to
    /// plain decode).
    spec: Option<SpecState<'e>>,
    /// Set when the prompt finishes ingesting and speculation looks
    /// viable (`spec_pairing_plan`): the draft prefill is DEFERRED to a
    /// later round's single ingestion slot (`spec_pairing_step`), so the
    /// completion round never runs two ingestion dispatches and the
    /// one-dispatch-per-round interleave bound holds with speculation
    /// enabled.  Cleared when the pairing attempt runs (either way).
    spec_pending: bool,
    /// Terminated by emitting [`CoreConfig::eos_token`] (on any decode
    /// path — plain, batched, or inside an accepted speculative run).
    done: bool,
    /// Last speculative draft length the γ controller picked for this
    /// request (flight-recorder `gamma_change` events fire on change).
    gamma_last: u8,
    queue_ms: f64,
    /// Wall time of this request's scheduled prefill dispatches (spread
    /// across rounds — no longer a synchronous admission stamp).
    prefill_ms: f64,
    /// Chunk dispatches this request's prompt took to ingest.
    prefill_chunks: u64,
    decode_ms: f64,
    ttft_ms: f64,
}

impl Generation<'_> {
    fn finished(&self) -> bool {
        matches!(self.phase, Phase::Decoding)
            && (self.done
                || self.out_ids.len() >= self.req.max_new
                || self.gen.pos + 1 >= self.session.cfg.max_seq)
    }
}

/// The cheap half of speculation pairing: every gate EXCEPT the draft
/// prefill dispatch itself — eligibility (config + deadline), a distinct
/// draft session with verify graphs, a prompt within the draft's
/// bucketed prefill (a second chunked ingestion would double the
/// scheduled prefill work; batching still serves long prompts), and a γ
/// controller that could ever pick γ > 0 for this cost pair.  Returns
/// the draft session + seeded controller when pairing is worth a
/// draft-prefill dispatch, so the pairing round consumes the plan
/// instead of re-deriving it (one code path, no gate drift) — the
/// prompt-completion round calls this just to decide `spec_pending`.
fn spec_pairing_plan<'e>(engine: &'e ServingEngine, config: &CoreConfig,
                         session: &DecodeSession, prompt_len: usize,
                         deadline_ms: Option<f64>)
                         -> Option<(&'e DecodeSession, GammaController)> {
    if !(config.spec
        && config.gamma_cap > 0
        && spec_eligible(deadline_ms, config.loose_deadline_ms))
    {
        return None;
    }
    let draft = engine.spec_draft_for(session)?;
    if draft.prefill_bucket(prompt_len).is_err() {
        return None;
    }
    let ctrl = GammaController::new(
        engine.modeled_tpot_ms(draft.ec.target),
        engine.modeled_tpot_ms(session.ec.target),
    );
    // If even the optimistic-start controller can never pick γ > 0 for
    // this draft/target cost pair (e.g. adjacent targets), skip the
    // pairing entirely — no draft prefill dispatch, no second
    // device-resident KV cache.
    let candidates: Vec<usize> = session
        .spec_gammas()
        .into_iter()
        .filter(|&g| g <= config.gamma_cap)
        .collect();
    if ctrl.pick(&candidates) == 0 {
        return None;
    }
    Some((draft, ctrl))
}

/// Token-interleaved decode loop over one [`ServingEngine`], with a
/// batched fast path: every scheduling step advances the policy-chosen
/// generation AND any batch-compatible runnable generations in a single
/// device dispatch (see [`pick_batch`] / DESIGN.md §Batching).
pub struct ServingCore<'e> {
    engine: &'e ServingEngine,
    policy: SchedPolicy,
    active: Vec<Generation<'e>>,
    rr_cursor: usize,
    next_seq: u64,
    /// Scheduling knobs ([`CoreConfig`]); seeded from the environment by
    /// [`ServingCore::new`].
    config: CoreConfig,
    /// Batched dispatches that failed and fell back to per-request
    /// advances (see [`ServingCore::batch_errors`]).
    batch_errors: u64,
    /// Speculative rounds that failed; each failure permanently drops
    /// that request's speculation state (see [`ServingCore::spec_errors`]).
    spec_errors: u64,
    /// Malformed-request admissions rejected by
    /// [`ServingCore::admit_from`] (empty tokenization, over-long
    /// prompt); each became a terminal [`CoreEvent::Error`] and the
    /// drain continued.
    admit_rejects_invalid: u64,
    /// Capacity admissions rejected by [`ServingCore::admit_from`] (core
    /// full, KV pool exhausted) — transient pressure, mapped to 503 at
    /// the transport.
    admit_rejects_capacity: u64,
    /// Admissions whose target precision was downshifted by KV-pool
    /// pressure before the request entered the core (the DP-LLM
    /// precision knob as admission backpressure).
    admit_downshifts: u64,
    /// Rejection events recorded by [`ServingCore::admit_from`], drained
    /// at the head of the next [`ServingCore::step`].
    rejects: Vec<CoreEvent>,
    /// Ingestion dispatches run by [`ServingCore::step`]: prompt chunks,
    /// whole bucketed prefills on chunk-less artifacts, and deferred
    /// speculation pairings (see [`ServingCore::prefill_chunks`]).
    prefill_chunks: u64,
    /// Total wall time decode rounds were extended by an interleaved
    /// prefill dispatch (see [`ServingCore::prefill_stall_ms`]).
    prefill_stall_ms: f64,
    token_clock: u64,
    /// Last `token_clock / reselect_every` epoch a re-selection ran for
    /// (see [`ServingCore::reselect_due`]).
    reselect_epoch: Option<u64>,
}

impl<'e> ServingCore<'e> {
    pub fn new(engine: &'e ServingEngine, policy: SchedPolicy) -> ServingCore<'e> {
        ServingCore {
            engine,
            policy,
            active: Vec::new(),
            rr_cursor: 0,
            next_seq: 0,
            config: CoreConfig::from_env(),
            batch_errors: 0,
            spec_errors: 0,
            admit_rejects_invalid: 0,
            admit_rejects_capacity: 0,
            admit_downshifts: 0,
            rejects: Vec::new(),
            prefill_chunks: 0,
            prefill_stall_ms: 0.0,
            token_clock: 0,
            reselect_epoch: None,
        }
    }

    /// Replace the scheduling knobs wholesale (tests, CLI plumbing).
    pub fn with_config(mut self, config: CoreConfig) -> ServingCore<'e> {
        self.config = config;
        self.config.max_active = self.config.max_active.max(1);
        self.config.max_batch = self.config.max_batch.max(1);
        self.config.reselect_every = self.config.reselect_every.max(1);
        self
    }

    pub fn with_max_active(mut self, n: usize) -> ServingCore<'e> {
        self.config.max_active = n.max(1);
        self
    }

    /// Cap the number of generations packed into one device dispatch
    /// (1 = per-request dispatch, the pre-batching behavior).
    pub fn with_max_batch(mut self, n: usize) -> ServingCore<'e> {
        self.config.max_batch = n.max(1);
        self
    }

    pub fn has_active(&self) -> bool {
        !self.active.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn has_capacity(&self) -> bool {
        // Slot cap AND a cheap KV-pool pre-gate: when the pool cannot
        // hold even one birth-tier generation, queue-driven admission
        // stops popping requests it would immediately 503.  The
        // authoritative byte check is the charge inside admission.
        self.active.len() < self.config.max_active
            && self.engine.kv_would_admit()
    }

    /// Tokens decoded since construction (drives the re-selection
    /// cadence).  A batched step advances this by its occupancy, so it
    /// counts tokens, not device dispatches.
    pub fn token_clock(&self) -> u64 {
        self.token_clock
    }

    /// Batched dispatches that failed and fell back to per-request
    /// advances.  Non-zero with a growing trend means the
    /// `decode_step_b*` artifacts are broken and every step is paying a
    /// doomed dispatch — regenerate them or set `DPLLM_NO_BATCH=1`.
    pub fn batch_errors(&self) -> u64 {
        self.batch_errors
    }

    /// Speculative rounds that failed.  Each failure drops that
    /// request's speculation state permanently (plain decode from then
    /// on), so this stays small; a non-zero value usually means broken
    /// `verify_step_g*` artifacts — regenerate them or set
    /// `DPLLM_NO_SPEC=1`.
    pub fn spec_errors(&self) -> u64 {
        self.spec_errors
    }

    /// Admission rejections recorded by [`ServingCore::admit_from`]:
    /// each produced a terminal [`CoreEvent::Error`] for its id and the
    /// drain continued — the fault-isolation contract (one bad prompt
    /// cannot take down the serving loop).  Sum of the two families.
    pub fn admit_rejects(&self) -> u64 {
        self.admit_rejects_invalid + self.admit_rejects_capacity
    }

    /// Malformed-request rejections (empty tokenization, over-long
    /// prompt) — the non-retryable family (HTTP 400 at the transport).
    pub fn admit_rejects_invalid(&self) -> u64 {
        self.admit_rejects_invalid
    }

    /// Capacity rejections (core slots full, KV pool exhausted) — the
    /// retryable family (HTTP 503 + `Retry-After` at the transport).
    pub fn admit_rejects_capacity(&self) -> u64 {
        self.admit_rejects_capacity
    }

    /// Admissions whose target precision was lowered by
    /// [`crate::costmodel::downshift_for_pressure`] because the KV pool
    /// was under pressure at admit time: lower bits finish sooner, so
    /// their KV bytes drain sooner — backpressure before rejection.
    pub fn admit_downshifts(&self) -> u64 {
        self.admit_downshifts
    }

    /// Ingestion dispatches this core has scheduled: one per
    /// `prefill_chunk_<P>` call, per whole bucketed prefill on
    /// chunk-less artifacts, and per deferred speculation pairing (the
    /// draft's seed prefill runs through the same per-round ingestion
    /// slot).  Companion to the runtime-level
    /// `TransferSnapshot::prefill_chunks`, which counts only chunk
    /// dispatches but includes harness-driven ones outside any core.
    pub fn prefill_chunks(&self) -> u64 {
        self.prefill_chunks
    }

    /// Total wall time decode rounds were extended by an interleaved
    /// prefill dispatch: a chunk's duration is added whenever the same
    /// scheduling round also served decode traffic.  Because `step()`
    /// runs at most one chunk per round, `prefill_stall_ms` divided by
    /// the number of stalling chunks bounds the extra latency any active
    /// decode saw between its tokens from prompt ingestion — the
    /// interleave contract the artifact-gated tests assert.
    pub fn prefill_stall_ms(&self) -> f64 {
        self.prefill_stall_ms
    }

    /// True when a utilization tick + mid-stream re-selection is due:
    /// once per [`CoreConfig::reselect_every`]-token epoch, and on the
    /// first call.  Epoch-based rather than `token_clock % n == 0`
    /// because a batched step or an accepted speculative run can move
    /// the clock across a multiple without ever landing on it.
    pub fn reselect_due(&mut self) -> bool {
        let epoch = self.token_clock / self.config.reselect_every.max(1);
        if self.reselect_epoch == Some(epoch) {
            false
        } else {
            self.reselect_epoch = Some(epoch);
            true
        }
    }

    /// Admit one request at the QoS-policy target for `utilization`.
    ///
    /// **Non-blocking**: tokenizes, validates, allocates the slot and
    /// enqueues a `Prefilling` phase — no prefill dispatch runs here.
    /// The prompt ingests chunk by chunk through [`ServingCore::step`]
    /// (at most one chunk per round, interleaved with the decode paths),
    /// which streams the first token when the last chunk lands.  `Err`
    /// means the request was REJECTED (empty tokenization, prompt beyond
    /// [`DecodeSession::max_prompt_len`], capacity) with core state
    /// untouched; queue-driven callers should prefer
    /// [`ServingCore::admit_from`], which converts rejections into
    /// terminal [`CoreEvent::Error`]s instead of propagating them.
    pub fn admit(&mut self, req: Request, utilization: f64) -> Result<u64> {
        let mut target = self.engine.policy.select(req.qos, utilization);
        // KV pressure is a precision signal before it is a reject: a
        // downshifted request decodes faster, so its KV bytes drain
        // sooner (the DP-LLM knob as admission backpressure).
        let pressure = self.engine.kv_pressure();
        if pressure >= crate::costmodel::DOWNSHIFT_PRESSURE {
            let shifted = crate::costmodel::downshift_for_pressure(
                &self.engine.targets(), target, pressure);
            if shifted != target {
                self.admit_downshifts += 1;
                global_tracer().record(EventKind::PressureDownshift {
                    id: req.id,
                    want_mb: milli_bits(target),
                    got_mb: milli_bits(shifted),
                    pressure_pct: (pressure * 100.0).clamp(0.0, 255.0) as u8,
                });
                target = shifted;
            }
        }
        self.admit_inner(req, target, false)
    }

    /// Admit pinned to a target precision; never re-selected mid-stream.
    pub fn admit_pinned(&mut self, req: Request, target: f64) -> Result<u64> {
        self.admit_inner(req, target, true)
    }

    /// Pull requests from the queue while there is capacity.  Fault
    /// isolation (the headline bugfix of ISSUE 5): a rejected request is
    /// terminal for THAT id only — it becomes a pending
    /// [`CoreEvent::Error`] (drained by the next [`ServingCore::step`]),
    /// bumps [`ServingCore::admit_rejects`], and the loop keeps admitting
    /// and serving.  Returns how many requests were actually admitted.
    pub fn admit_from(&mut self, queue: &mut RequestQueue, utilization: f64)
                      -> usize {
        let mut admitted = 0;
        while self.has_capacity() {
            let Some(r) = queue.pop() else { break };
            let id = r.id;
            match self.admit(r, utilization) {
                Ok(_) => admitted += 1,
                Err(e) => {
                    let capacity = is_capacity_reject(&e);
                    if capacity {
                        self.admit_rejects_capacity += 1;
                    } else {
                        self.admit_rejects_invalid += 1;
                    }
                    global_tracer().record(EventKind::Reject { id, capacity });
                    self.rejects.push(CoreEvent::Error {
                        id,
                        error: format!("{e:#}"),
                        capacity,
                    });
                }
            }
        }
        admitted
    }

    fn admit_inner(&mut self, req: Request, target: f64, pinned: bool)
                   -> Result<u64> {
        if !self.has_capacity() {
            return Err(anyhow::Error::new(CoreAtCapacity(self.config.max_active)));
        }
        let session = self.engine.session_for_target(target);
        let prompt_ids = self.engine.tokenizer.encode(&req.prompt);
        if prompt_ids.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        if prompt_ids.len() > session.max_prompt_len() {
            return Err(anyhow!(
                "prompt of {} tokens exceeds the maximum ingestible length \
                 {} (max_seq {})",
                prompt_ids.len(),
                session.max_prompt_len(),
                session.cfg.max_seq
            ));
        }
        let queue_ms = req.arrival.elapsed().as_secs_f64() * 1e3;
        // Non-blocking admission: at most the zero-KV state the chunked
        // ingestion extends is allocated here (one bounded upload, no
        // dispatch) — a long prompt can never stall the active decodes
        // from inside admission.  Chunk-less artifacts get a no-upload
        // placeholder instead: their first scheduled ingestion round
        // replaces the whole GenState via `begin`, so an uploaded zero
        // KV would be discarded unused.  Speculation pairing is deferred
        // to its own ingestion round (`spec_pairing_step`).
        let (gen, ingested) = if session.max_prefill_chunk() > 0 {
            // Shared-prefix fast path: a cached prefix of this prompt
            // (same model + target) clones its KV zero-copy and the
            // request starts with those chunks already ingested — N
            // requests sharing a system prompt pay one chunked prefill.
            match session.begin_from_prefix(&prompt_ids) {
                Some((gen, len)) => {
                    global_tracer().record(EventKind::PrefixHit {
                        id: req.id,
                        saved_tokens: len as u32,
                    });
                    (gen, len)
                }
                None => (session.begin_empty()?, 0),
            }
        } else {
            (session.begin_deferred(), 0)
        };
        let id = req.id;
        global_tracer().record(EventKind::Admit {
            id,
            target_mb: milli_bits(session.ec.target),
            queue_us: (queue_ms * 1e3).max(0.0) as u64,
        });
        self.active.push(Generation {
            req,
            session,
            gen,
            target: session.ec.target,
            pinned,
            seq: self.next_seq,
            prompt_ids,
            phase: Phase::Prefilling { ingested },
            next_token: 0,
            out_ids: Vec::new(),
            spec: None,
            spec_pending: false,
            done: false,
            gamma_last: 0,
            queue_ms,
            prefill_ms: 0.0,
            prefill_chunks: 0,
            decode_ms: 0.0,
            // Stamped when the first token actually streams (the round
            // the last prefill chunk lands).
            ttft_ms: 0.0,
        });
        self.next_seq += 1;
        Ok(id)
    }

    /// Re-select the target precision of every non-pinned active
    /// generation for the current utilization.  A retargeted generation
    /// keeps its device-resident KV cache and effective-bit statistics;
    /// the new session adopts the state ([`DecodeSession::adopt`]).
    pub fn reselect(&mut self, utilization: f64) -> usize {
        let mut switched = 0;
        for g in &mut self.active {
            // A mid-prefill retarget would switch prefill weight stacks
            // halfway through the prompt; ingestion finishes on the
            // admission-time session and the first post-completion
            // reselect moves the generation if utilization asks for it.
            if g.pinned || g.finished()
                || matches!(g.phase, Phase::Prefilling { .. })
            {
                continue;
            }
            let want = self.engine.policy.select(g.req.qos, utilization);
            let session = self.engine.session_for_target(want);
            let from_mb = milli_bits(g.target);
            let mut layers_changed = 0u32;
            if !std::ptr::eq(session, g.session) {
                // Per-linear (low, high) candidate flips the retarget
                // applies — the per-layer payload of the Reselect event.
                layers_changed = session
                    .ec
                    .wl_bits
                    .iter()
                    .zip(&g.session.ec.wl_bits)
                    .zip(session.ec.wh_bits.iter().zip(&g.session.ec.wh_bits))
                    .filter(|((nl, ol), (nh, oh))| nl != ol || nh != oh)
                    .count() as u32;
                g.session = session;
                session.adopt(&mut g.gen);
                g.target = session.ec.target;
                // The γ controller's cost comparison tracks the new
                // target (the draft half stays pinned to the lowest
                // member; if the target moved onto it, the controller's
                // strict-improvement rule parks γ at 0 by itself).
                if let Some(spec) = &mut g.spec {
                    spec.ctrl.tpot_target_ms =
                        self.engine.modeled_tpot_ms(g.target);
                }
                switched += 1;
            }
            // One precision-decision event per active request per
            // epoch — `from_mb == to_mb` records "epoch kept the
            // assignment", so the trace shows every decision, not only
            // the switches.
            let eff = g.gen.sel.effective_bits();
            let eff_delta_mb = if eff.is_finite() {
                ((g.target - eff) * 1000.0).round() as i32
            } else {
                0
            };
            global_tracer().record(EventKind::Reselect {
                id: g.req.id,
                from_mb,
                to_mb: milli_bits(g.target),
                layers_changed,
                eff_delta_mb,
            });
        }
        switched
    }

    /// Speculative draft length for one active generation this step, 0
    /// when the plain/batched path should run instead: no speculation
    /// state, γ controller says plain decode, or the remaining token /
    /// sequence budget cannot fit a γ+1 run.
    fn spec_gamma_for(&self, g: &Generation<'e>) -> usize {
        let Some(spec) = &g.spec else { return 0 };
        let remaining = g.req.max_new.saturating_sub(g.out_ids.len());
        let candidates: Vec<usize> = g
            .session
            .spec_gammas()
            .into_iter()
            .filter(|&gm| {
                gm <= self.config.gamma_cap
                    && gm + 1 <= remaining
                    && g.gen.pos + gm + 1 < g.session.cfg.max_seq
            })
            .collect();
        spec.ctrl.pick(&candidates)
    }

    /// Try to serve `idx` through one speculative round.  Returns true
    /// when the round fully handled this step's advance (events pushed,
    /// clock moved); false to let the caller run the plain path —
    /// including after a round failure, which drops the request's
    /// speculation state so the step (and the rest of the generation)
    /// proceeds unspeculated.
    fn spec_step(&mut self, idx: usize, events: &mut Vec<CoreEvent>) -> bool {
        let engine = self.engine;
        let est_mode = engine.est_mode;
        let eos = self.config.eos_token;
        let gamma = self.spec_gamma_for(&self.active[idx]);
        let g = &mut self.active[idx];
        let Some(spec) = g.spec.as_mut() else { return false };
        // Committed tokens the draft has not ingested yet (it falls
        // behind when this generation advances through the batched or
        // plain path, and by one token after a fully-accepted round).
        // Far behind → speculation is not earning its keep here; drop it
        // rather than stall a scheduling step on replay.
        let behind = g.gen.pos - spec.draft_gen.pos;
        if behind > MAX_SPEC_CATCHUP {
            g.spec = None;
            return false;
        }
        let gamma_now = gamma.min(u8::MAX as usize) as u8;
        if gamma_now != g.gamma_last {
            g.gamma_last = gamma_now;
            global_tracer().record(EventKind::GammaChange {
                id: g.req.id,
                gamma: gamma_now,
            });
        }
        if gamma == 0 {
            return false;
        }
        let dstart = spec.draft_gen.pos - g.prompt_ids.len();
        let catchup: Vec<u32> =
            g.out_ids[dstart..g.out_ids.len() - 1].to_vec();
        let t0 = Instant::now();
        let round = spec_round(spec, g.session, &mut g.gen, g.next_token,
                               &catchup, gamma, est_mode);
        g.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
        match round {
            Ok(r) => {
                let mut toks = r.tokens;
                if truncate_at_eos(&mut toks, eos) {
                    g.done = true;
                }
                let n = toks.len() as u64;
                // Stream the whole accepted run in order — each token is
                // exactly what plain greedy decode would have emitted.
                for t in toks {
                    g.next_token = t;
                    g.out_ids.push(t);
                    events.push(CoreEvent::Token {
                        id: g.req.id,
                        index: g.out_ids.len() - 1,
                        token: t,
                        piece: engine.tokenizer.decode_one(t),
                        target: g.target,
                    });
                }
                self.token_clock += n;
                true
            }
            Err(e) => {
                // spec_round leaves the pair consistent (draft rewound);
                // drop speculation for this request and let the caller's
                // plain path advance it this very step.
                self.spec_errors += 1;
                if self.spec_errors == 1 {
                    crate::dpllm_log!(
                        Warn,
                        "core",
                        "speculative round failed; request {} falls back to \
                         plain decode (set DPLLM_NO_SPEC=1 or fix the \
                         verify_step_g* artifacts if this persists): {e:#}",
                        g.req.id
                    );
                }
                g.spec = None;
                false
            }
        }
    }

    /// One scheduling round.  Decode half: advance the policy-chosen
    /// generation — together with every batch-compatible runnable
    /// generation in the same device dispatch when the batched artifacts
    /// are available ([`pick_batch`] + [`DecodeSession::advance_batch`]),
    /// or by a multi-token *speculative round* when it runs alone and is
    /// spec-eligible (γ low-bit drafts verified in one target dispatch
    /// via `runtime::spec::spec_round`, each accepted token streamed in
    /// order).  Prefill half: at most ONE prompt-ingestion chunk of the
    /// [`pick_prefill`]-chosen `Prefilling` generation, so active
    /// decodes never wait on more than one bounded chunk dispatch
    /// between tokens; the round the last chunk lands, the first token
    /// streams (index 0) and TTFT stamps.  Terminal outcomes emit on
    /// completion; pending admission rejections
    /// ([`ServingCore::admit_from`]) drain first.  A failed batched
    /// dispatch falls back to per-request advances so one broken
    /// generation is evicted without poisoning its batch mates; a failed
    /// speculative round falls back to the plain path within the same
    /// step; a failed prefill chunk evicts only its own generation.
    pub fn step(&mut self) -> Result<Vec<CoreEvent>> {
        // Admission rejections recorded since the last round surface
        // first — terminal per-id events, ahead of any token traffic.
        let mut events: Vec<CoreEvent> = std::mem::take(&mut self.rejects);

        // ---- decode half: lead + ride-alongs over the decodable set ----
        let decodable: Vec<usize> = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, g)| matches!(g.phase, Phase::Decoding))
            .map(|(i, _)| i)
            .collect();
        let pairs: Vec<(u64, Option<Instant>)> = decodable
            .iter()
            .map(|&i| (self.active[i].seq, self.active[i].req.deadline_instant()))
            .collect();
        if let Some(lead_d) = pick_next(self.policy, self.rr_cursor, &pairs) {
            let lead = decodable[lead_d];
            let session: &'e DecodeSession = self.active[lead].session;
            let cap = self.config.max_batch.min(session.max_batch()).max(1);
            let picked: Vec<usize> = if cap > 1 {
                let items: Vec<BatchItem> = decodable
                    .iter()
                    .map(|&i| {
                        let g = &self.active[i];
                        BatchItem {
                            seq: g.seq,
                            deadline: g.req.deadline_instant(),
                            key: g.session as *const DecodeSession as usize,
                        }
                    })
                    .collect();
                pick_batch_with_lead(self.policy, lead_d, &items, cap)
                    .into_iter()
                    .map(|j| decodable[j])
                    .collect()
            } else {
                vec![lead]
            };
            self.rr_cursor = self.rr_cursor.wrapping_add(1);

            // Advance the non-finished picked generations.  Degradation
            // ladder (DESIGN.md §Speculation): a lone runnable generation
            // tries a speculative round first (γ low-bit drafts verified
            // in one target dispatch — converting idle batch capacity
            // into tokens); ≥ 2 compatible generations share one batched
            // dispatch; everything else is the per-request path.
            let to_advance: Vec<usize> = picked
                .iter()
                .copied()
                .filter(|&i| !self.active[i].finished())
                .collect();
            let est_mode = self.engine.est_mode;
            let mut failures: Vec<(u64, String)> = Vec::new();
            let mut spec_done = false;
            if self.config.spec && to_advance.len() == 1 {
                spec_done = self.spec_step(to_advance[0], &mut events);
            }
            if !spec_done {
                self.step_plain(&to_advance, &picked, est_mode, &mut events,
                                &mut failures);
            }
            // Evict broken generations; the rest of the set keeps serving.
            for (id, error) in failures {
                if let Some(pos) =
                    self.active.iter().position(|g| g.req.id == id)
                {
                    self.active.remove(pos);
                }
                events.push(CoreEvent::Failed { id, error });
            }
        }

        // ---- prefill half: at most one ingestion dispatch per round ----
        let stalled_decode = !decodable.is_empty();
        self.prefill_step(&mut events, stalled_decode);

        // Completions — including a prefill landing straight into
        // `finished` (max_new == 1) — resolved by id since indices shift.
        let done_ids: Vec<u64> = self
            .active
            .iter()
            .filter(|g| g.finished())
            .map(|g| g.req.id)
            .collect();
        for id in done_ids {
            if let Some(pos) = self.active.iter().position(|g| g.req.id == id) {
                let g = self.active.remove(pos);
                events.push(CoreEvent::Done(self.complete(g)));
            }
        }
        Ok(events)
    }

    /// The prefill half of one scheduling round: run at most ONE
    /// ingestion dispatch.  Priority goes to the next prompt chunk of
    /// the [`pick_prefill`]-chosen `Prefilling` generation (EDF:
    /// earliest deadline first; FIFO: admission order) — chunks gate
    /// someone's TTFT; with no prompt mid-ingestion, a deferred
    /// speculation pairing (`spec_pending`) takes the slot instead, so
    /// the draft prefill is a scheduled, metered dispatch too and the
    /// one-dispatch-per-round interleave bound holds with speculation
    /// enabled.  On a prompt's final chunk the first token streams
    /// immediately (index 0), TTFT stamps (arrival → first streamed
    /// token, the scheduled prefill spread inside it) and viable
    /// requests are marked `spec_pending`.  Artifacts without
    /// `prefill_chunk_*` entries degrade to running the whole bucketed
    /// prefill as this round's single ingestion unit.  `stalled_decode`
    /// marks that this round also served decode traffic; the dispatch's
    /// wall time then counts toward [`ServingCore::prefill_stall_ms`].
    /// A chunk failure evicts only this generation
    /// ([`CoreEvent::Failed`]) — the serving loop continues.
    fn prefill_step(&mut self, events: &mut Vec<CoreEvent>,
                    stalled_decode: bool) {
        let prefilling: Vec<usize> = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, g)| matches!(g.phase, Phase::Prefilling { .. }))
            .map(|(i, _)| i)
            .collect();
        let items: Vec<(u64, Option<Instant>)> = prefilling
            .iter()
            .map(|&i| (self.active[i].seq, self.active[i].req.deadline_instant()))
            .collect();
        let Some(p) = pick_prefill(self.policy, &items) else {
            self.spec_pairing_step(stalled_decode);
            return;
        };
        let idx = prefilling[p];
        let engine = self.engine;
        let config = self.config.clone();
        let mut failure: Option<String> = None;
        {
            let g = &mut self.active[idx];
            let session: &'e DecodeSession = g.session;
            let Phase::Prefilling { ingested } = g.phase else {
                unreachable!("filtered on phase above")
            };
            let t0 = Instant::now();
            let chunk = session.max_prefill_chunk();
            let total = g.prompt_ids.len();
            let outcome: Result<(usize, Option<Vec<f32>>)> = if chunk == 0 {
                // Chunk-less artifacts: the whole bucketed prefill is
                // this round's ingestion unit (prompt length was
                // validated against the bucket cap at admission).
                match session.begin(&g.prompt_ids) {
                    Ok((gen, logits)) => {
                        g.gen = gen;
                        Ok((total, Some(logits)))
                    }
                    Err(e) => Err(e),
                }
            } else {
                let end = (ingested + chunk).min(total);
                // Only the final chunk's logits are consulted (token 0);
                // intermediate chunks skip the vocab-sized download.
                session
                    .prefill_advance(&mut g.gen, &g.prompt_ids[ingested..end],
                                     end == total)
                    .map(|logits| (end, logits))
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            g.prefill_ms += ms;
            g.prefill_chunks += 1;
            self.prefill_chunks += 1;
            if stalled_decode {
                self.prefill_stall_ms += ms;
            }
            match outcome {
                Err(e) => failure = Some(format!("{e:#}")),
                Ok((now_ingested, final_logits)) => {
                    global_tracer().record(EventKind::PrefillChunk {
                        id: g.req.id,
                        chunk: g.prefill_chunks as u32,
                        pos: now_ingested as u32,
                    });
                    g.phase = Phase::Prefilling { ingested: now_ingested };
                    // Publish this prompt's quantized prefix into the
                    // shared cache once enough chunks have landed (the
                    // final chunk stays uncached so a hit still produces
                    // first-token logits).  First writer wins; later
                    // identical prompts clone the KV instead of
                    // prefilling.
                    if chunk > 0 {
                        if let Some(q) = kvpool::prefix_quantize(total, chunk)
                        {
                            if now_ingested >= q {
                                session.prefix_publish(
                                    &mut g.gen, &g.prompt_ids, q);
                            }
                        }
                    }
                    if let Some(logits) = final_logits {
                        match DecodeSession::argmax(&logits) {
                            Err(e) => failure = Some(format!("{e:#}")),
                            Ok(first) => {
                                g.phase = Phase::Decoding;
                                g.next_token = first;
                                g.out_ids.push(first);
                                g.ttft_ms =
                                    g.req.arrival.elapsed().as_secs_f64() * 1e3;
                                global_tracer().record(EventKind::FirstToken {
                                    id: g.req.id,
                                    ttft_us: (g.ttft_ms * 1e3).max(0.0) as u64,
                                });
                                events.push(CoreEvent::Token {
                                    id: g.req.id,
                                    index: 0,
                                    token: first,
                                    piece: engine.tokenizer.decode_one(first),
                                    target: g.target,
                                });
                                // The draft prefill is NOT run here — it
                                // would make this round's ingestion cost
                                // two dispatches.  A viable request is
                                // marked and paired by a later round's
                                // ingestion slot (spec_pairing_step).
                                g.spec_pending = spec_pairing_plan(
                                    engine, &config, session,
                                    g.prompt_ids.len(), g.req.deadline_ms)
                                    .is_some();
                            }
                        }
                    }
                }
            }
        }
        if let Some(error) = failure {
            let id = self.active[idx].req.id;
            self.active.remove(idx);
            events.push(CoreEvent::Failed { id, error });
        }
    }

    /// Run one deferred speculation pairing as this round's ingestion
    /// dispatch (only when no prompt is mid-ingestion — chunks outrank
    /// pairings, since they gate a TTFT).  The draft prefill's wall time
    /// is metered like any ingestion dispatch
    /// ([`ServingCore::prefill_chunks`] / `prefill_stall_ms`) — counted
    /// only when the dispatch actually ran, so the counters never show
    /// phantom work — and billed to the request's `decode_ms`: it is
    /// speed investment for the decode phase, and the request's first
    /// token already streamed, so billing it to `prefill_ms` would break
    /// the `ttft >= queue + prefill` record invariant.  Doomed pairings
    /// are dropped dispatch-free: a generation finishing this round, one
    /// already too far past its prompt for the catch-up bound (the first
    /// spec round would discard the pair), or one whose viability
    /// flipped since it was marked (mid-stream retarget).  A draft
    /// prefill failure just means plain decode — never a failed request.
    fn spec_pairing_step(&mut self, stalled_decode: bool) {
        let Some(idx) = self.active.iter().position(|g| g.spec_pending) else {
            return;
        };
        let engine = self.engine;
        let config = self.config.clone();
        let g = &mut self.active[idx];
        g.spec_pending = false;
        if g.finished()
            || g.out_ids.len().saturating_sub(1) > MAX_SPEC_CATCHUP
        {
            return;
        }
        let session: &'e DecodeSession = g.session;
        let Some((draft, ctrl)) = spec_pairing_plan(
            engine, &config, session, g.prompt_ids.len(), g.req.deadline_ms)
        else {
            return;
        };
        let t0 = Instant::now();
        g.spec = draft
            .begin(&g.prompt_ids)
            .ok()
            .map(|(draft_gen, _)| SpecState { draft, draft_gen, ctrl });
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        g.decode_ms += ms;
        self.prefill_chunks += 1;
        if stalled_decode {
            self.prefill_stall_ms += ms;
        }
    }

    /// The non-speculative advance of one scheduling step: one batched
    /// dispatch when ≥ 2 picked generations share the lead's session,
    /// else one per-request advance; streams the decoded tokens in pack
    /// order and records failures for the caller to evict.  EOS handling
    /// matches the speculative path: an emitted [`CoreConfig::eos_token`]
    /// finishes the generation (token kept), so every decode path
    /// produces the identical stream.
    fn step_plain(&mut self, to_advance: &[usize], picked: &[usize],
                  est_mode: EstMode, events: &mut Vec<CoreEvent>,
                  failures: &mut Vec<(u64, String)>) {
        let eos = self.config.eos_token;
        let mut advanced: Vec<u64> = Vec::new();
        let advance_one = |g: &mut Generation<'e>,
                               advanced: &mut Vec<u64>,
                               failures: &mut Vec<(u64, String)>| {
            let t0 = Instant::now();
            let stepped = g
                .session
                .advance(&mut g.gen, g.next_token, est_mode)
                .and_then(|out| DecodeSession::argmax(&out.logits));
            g.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
            match stepped {
                Ok(next) => {
                    g.next_token = next;
                    g.out_ids.push(next);
                    if eos == Some(next) {
                        g.done = true;
                    }
                    advanced.push(g.req.id);
                }
                Err(e) => failures.push((g.req.id, format!("{e:#}"))),
            }
        };
        if to_advance.len() >= 2 {
            // All picked generations share the lead's session by the
            // pick_batch key contract — any member names the batch exe.
            let session: &'e DecodeSession = self.active[to_advance[0]].session;
            let t0 = Instant::now();
            let mut gens: Vec<&mut Generation<'e>> = self
                .active
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| to_advance.contains(i))
                .map(|(_, g)| g)
                .collect();
            let batch_result = {
                let mut slots: Vec<(&mut GenState<'e>, u32)> = gens
                    .iter_mut()
                    .map(|g| {
                        let tok = g.next_token;
                        (&mut g.gen, tok)
                    })
                    .collect();
                session.advance_batch(&mut slots, est_mode)
            };
            match batch_result {
                Ok(outs) => {
                    // One dispatch served outs.len() tokens; attribute the
                    // wall time evenly across the slots.
                    let per_ms = t0.elapsed().as_secs_f64() * 1e3
                        / outs.len().max(1) as f64;
                    for (g, out) in gens.iter_mut().zip(outs) {
                        g.decode_ms += per_ms;
                        match DecodeSession::argmax(&out.logits) {
                            Ok(next) => {
                                g.next_token = next;
                                g.out_ids.push(next);
                                if eos == Some(next) {
                                    g.done = true;
                                }
                                advanced.push(g.req.id);
                            }
                            Err(e) => {
                                failures.push((g.req.id, format!("{e:#}")))
                            }
                        }
                    }
                }
                Err(e) => {
                    // advance_batch mutates nothing on failure, so every
                    // slot can be retried individually — the broken one
                    // is evicted alone.  Surface the error (first
                    // occurrence loudly): a persistently failing batched
                    // artifact would otherwise silently pay a doomed
                    // dispatch per token forever.
                    self.batch_errors += 1;
                    if self.batch_errors == 1 {
                        crate::dpllm_log!(
                            Warn,
                            "core",
                            "batched dispatch failed, falling back to \
                             per-request steps (set DPLLM_NO_BATCH=1 or fix \
                             the decode_step_b* artifacts if this persists): \
                             {e:#}"
                        );
                    }
                    for g in gens.iter_mut() {
                        advance_one(&mut **g, &mut advanced, &mut *failures);
                    }
                }
            }
        } else if let Some(&i) = to_advance.first() {
            advance_one(&mut self.active[i], &mut advanced, &mut *failures);
        }
        self.token_clock += advanced.len() as u64;

        // Stream the decoded tokens in pack order (EDF: deadline order;
        // FIFO: admission order).
        for &i in picked {
            let g = &self.active[i];
            if advanced.contains(&g.req.id) {
                events.push(CoreEvent::Token {
                    id: g.req.id,
                    index: g.out_ids.len() - 1,
                    token: g.next_token,
                    piece: self.engine.tokenizer.decode_one(g.next_token),
                    target: g.target,
                });
            }
        }
    }

    /// Run everything to completion: admit from `queue` as capacity frees
    /// up, tick `util` on the re-selection cadence, stream events.
    pub fn run(mut self, queue: &mut RequestQueue, util: &mut UtilizationSim,
               on_event: &mut dyn FnMut(&CoreEvent)) -> Result<Vec<ServeOutcome>> {
        let mut done = Vec::new();
        while self.has_active() || !queue.is_empty() {
            // Admission runs before EVERY dispatch — in particular
            // immediately after a step in which a request finished
            // mid-batch, so the freed slot is refilled in time to join
            // the very next batched dispatch (regression-tested by
            // admission_refills_freed_batch_slot_mid_flight; keep this
            // at the loop head, before reselect/step).  Rejections never
            // abort the loop: they surface as CoreEvent::Error from the
            // step() below (regression-tested by
            // poisoned_admission_does_not_kill_the_loop).
            self.admit_from(queue, util.current());
            if self.reselect_due() {
                let u = util.tick();
                self.reselect(u);
            }
            for ev in self.step()? {
                on_event(&ev);
                if let CoreEvent::Done(o) = ev {
                    done.push(o);
                }
            }
        }
        Ok(done)
    }

    /// Finish all currently-active generations (no further admission).
    /// Pending admission-rejection events are flushed too: a caller that
    /// ran [`ServingCore::admit_from`] over an all-invalid queue (no slot
    /// ever filled) still receives every terminal [`CoreEvent::Error`]
    /// here instead of them being silently dropped.
    pub fn drain(&mut self, on_event: &mut dyn FnMut(&CoreEvent))
                 -> Result<Vec<ServeOutcome>> {
        let mut done = Vec::new();
        while self.has_active() || !self.rejects.is_empty() {
            for ev in self.step()? {
                on_event(&ev);
                if let CoreEvent::Done(o) = ev {
                    done.push(o);
                }
            }
        }
        Ok(done)
    }

    fn complete(&self, g: Generation<'e>) -> ServeOutcome {
        let eff = g.gen.sel.effective_bits();
        global_tracer().record(EventKind::Done {
            id: g.req.id,
            tokens: g.out_ids.len() as u32,
            eff_mb: milli_bits(eff),
        });
        self.engine.metrics.record(RequestRecord {
            id: g.req.id,
            target_precision: g.target,
            effective_bits: eff,
            prompt_tokens: g.prompt_ids.len(),
            output_tokens: g.out_ids.len(),
            queue_ms: g.queue_ms,
            prefill_ms: g.prefill_ms,
            decode_ms: g.decode_ms,
            ttft_ms: g.ttft_ms,
            premium: super::router::is_premium(&g.req),
            arrival: g.req.arrival,
            completed: Instant::now(),
        });
        ServeOutcome {
            id: g.req.id,
            text: self.engine.tokenizer.decode(&g.out_ids),
            target_precision: g.target,
            effective_bits: eff,
            prefill_ms: g.prefill_ms,
            decode_ms: g.decode_ms,
            ttft_ms: g.ttft_ms,
            output_tokens: g.out_ids.len(),
            prefill_chunks: g.prefill_chunks,
            retargets: g.gen.retargets,
        }
    }
}

/// Measure mean decode-step latency over `n` steps (policy calibration).
pub fn measure_tpot(session: &DecodeSession, n: usize) -> Result<f64> {
    let mut gen = session.begin_empty()?;
    // Warm-up step (compile caches, allocator, rope/scalar buffers).
    session.advance(&mut gen, 1, EstMode::Approx)?;
    let t0 = Instant::now();
    for _ in 0..n {
        session.advance(&mut gen, 1, EstMode::Approx)?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3 / n as f64)
}

/// Build a FIFO/EDF queue from (prompt, qos) pairs — workload-gen helper.
pub fn make_queue(policy: SchedPolicy,
                  reqs: impl IntoIterator<Item = Request>) -> RequestQueue {
    let mut q = RequestQueue::new(policy);
    for r in reqs {
        q.push(r);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn now_plus(ms: u64) -> Option<Instant> {
        Some(Instant::now() + Duration::from_millis(ms))
    }

    /// FIFO interleaving fairness: with two active generations, each must
    /// advance within any 2-token window.
    #[test]
    fn fifo_round_robin_two_way_fairness() {
        let items = vec![(0u64, None), (1u64, None)];
        let mut picks = Vec::new();
        for cursor in 0..10 {
            picks.push(pick_next(SchedPolicy::Fifo, cursor, &items).unwrap());
        }
        for w in picks.windows(2) {
            assert_ne!(w[0], w[1], "a generation starved in a 2-token window");
        }
        assert!(picks.contains(&0) && picks.contains(&1));
    }

    /// FIFO cursor sweeps all active generations before repeating.
    #[test]
    fn fifo_round_robin_covers_all() {
        let items: Vec<(u64, Option<Instant>)> =
            (0..5u64).map(|s| (s, None)).collect();
        let picked: Vec<usize> = (0..5)
            .map(|c| pick_next(SchedPolicy::Fifo, c, &items).unwrap())
            .collect();
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    /// EDF at token granularity: the tightest deadline is stepped first,
    /// regardless of admission order; best-effort runs last; admission
    /// sequence breaks ties.
    #[test]
    fn edf_token_granularity_preemption() {
        let items = vec![
            (0u64, None),            // admitted first, best effort
            (1u64, now_plus(5000)),  // loose deadline
            (2u64, now_plus(50)),    // tight deadline, admitted last
        ];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &items), Some(2));

        // Tie on deadline -> FIFO by admission seq.
        let t = now_plus(300);
        let tied = vec![(7u64, t), (3u64, t)];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &tied), Some(1));

        // All best-effort -> earliest admission.
        let be = vec![(9u64, None), (4u64, None), (6u64, None)];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &be), Some(1));
    }

    #[test]
    fn pick_next_empty_is_none() {
        assert_eq!(pick_next(SchedPolicy::Fifo, 3, &[]), None);
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &[]), None);
    }

    fn bi(seq: u64, deadline: Option<Instant>, key: usize) -> BatchItem {
        BatchItem { seq, deadline, key }
    }

    /// Only generations sharing the lead's compatibility key (same target
    /// session / shape bucket) may join its dispatch.
    #[test]
    fn pick_batch_groups_by_key() {
        let items = vec![
            bi(0, None, 7),
            bi(1, None, 7),
            bi(2, None, 9), // different target stacks — must not join
            bi(3, None, 7),
        ];
        let sel = pick_batch(SchedPolicy::Fifo, 0, &items, 8);
        assert_eq!(sel, vec![0, 1, 3]);
        // Lead rotated onto the incompatible item: it runs alone-keyed,
        // batching with nothing but its own key.
        let sel = pick_batch(SchedPolicy::Fifo, 2, &items, 8);
        assert_eq!(sel, vec![2]);
    }

    /// EDF ordering is preserved within a batch: earliest deadline first,
    /// admission sequence as tie-break, best-effort last — and the lead
    /// is exactly pick_next's choice.
    #[test]
    fn pick_batch_edf_order_within_batch() {
        let t = |ms| now_plus(ms);
        let items = vec![
            bi(0, t(300), 1),
            bi(1, t(50), 1),
            bi(2, None, 1),
            bi(3, t(100), 1),
        ];
        let pairs: Vec<(u64, Option<Instant>)> =
            items.iter().map(|it| (it.seq, it.deadline)).collect();
        let lead = pick_next(SchedPolicy::Edf, 0, &pairs).unwrap();
        let sel = pick_batch(SchedPolicy::Edf, 0, &items, 8);
        assert_eq!(sel, vec![1, 3, 0, 2]);
        assert_eq!(sel[0], lead);
        // Capacity 2 keeps only the two tightest deadlines.
        assert_eq!(pick_batch(SchedPolicy::Edf, 0, &items, 2), vec![1, 3]);
    }

    /// max_batch == 1 degenerates to pick_next under both policies — the
    /// B = 1 fallback is byte-for-byte the pre-batching schedule.
    #[test]
    fn pick_batch_b1_matches_pick_next() {
        let items = vec![
            bi(0, None, 1),
            bi(1, now_plus(100), 1),
            bi(2, now_plus(40), 2),
        ];
        let pairs: Vec<(u64, Option<Instant>)> =
            items.iter().map(|it| (it.seq, it.deadline)).collect();
        for cursor in 0..7 {
            for policy in [SchedPolicy::Fifo, SchedPolicy::Edf] {
                assert_eq!(
                    pick_batch(policy, cursor, &items, 1),
                    vec![pick_next(policy, cursor, &pairs).unwrap()],
                    "policy {policy:?} cursor {cursor}"
                );
            }
        }
        assert!(pick_batch(SchedPolicy::Fifo, 0, &[], 4).is_empty());
    }

    /// FIFO with more runnable generations than batch slots: the cursor
    /// rotates the membership window so every generation is served, and
    /// the returned order is admission order (stable slot order).
    #[test]
    fn pick_batch_fifo_rotation_is_fair_and_stable() {
        let items: Vec<BatchItem> = (0..5).map(|s| bi(s, None, 3)).collect();
        let mut served = [0usize; 5];
        for cursor in 0..10 {
            let sel = pick_batch(SchedPolicy::Fifo, cursor, &items, 2);
            assert_eq!(sel.len(), 2);
            let mut sorted = sel.clone();
            sorted.sort_unstable();
            assert_eq!(sel, sorted, "batch order must be admission order");
            for i in sel {
                served[i] += 1;
            }
        }
        assert!(served.iter().all(|&n| n >= 2),
                "rotation starved a generation: {served:?}");
    }

    /// When everything fits in one batch the slot order is identical
    /// every step, so event streams stay strictly interleaved.
    #[test]
    fn pick_batch_fifo_full_fit_is_stable_across_cursors() {
        let items: Vec<BatchItem> = (0..3).map(|s| bi(s, None, 1)).collect();
        for cursor in 0..6 {
            assert_eq!(pick_batch(SchedPolicy::Fifo, cursor, &items, 4),
                       vec![0, 1, 2]);
        }
    }

    /// Prefill scheduling order: FIFO ingests prompts in admission
    /// order; EDF gives the earliest deadline its chunks first
    /// (best-effort last, admission seq tie-break) — so a deadlined long
    /// prompt reaches its first token ahead of best-effort ones.
    #[test]
    fn pick_prefill_ordering() {
        assert_eq!(pick_prefill(SchedPolicy::Fifo, &[]), None);
        assert_eq!(pick_prefill(SchedPolicy::Edf, &[]), None);
        let items = vec![
            (3u64, None),
            (1u64, now_plus(5000)),
            (2u64, now_plus(50)),
        ];
        // FIFO: lowest admission sequence, deadlines ignored.
        assert_eq!(pick_prefill(SchedPolicy::Fifo, &items), Some(1));
        // EDF: tightest deadline wins; best-effort runs last.
        assert_eq!(pick_prefill(SchedPolicy::Edf, &items), Some(2));
        let be = vec![(9u64, None), (4u64, None)];
        assert_eq!(pick_prefill(SchedPolicy::Edf, &be), Some(1));
        // Deadline tie → admission order.
        let t = now_plus(300);
        let tied = vec![(7u64, t), (3u64, t)];
        assert_eq!(pick_prefill(SchedPolicy::Edf, &tied), Some(1));
    }

    /// The default CoreConfig reproduces the historical hard-coded
    /// behavior exactly — the "defaulting to current behavior" contract
    /// of making the knobs runtime-configurable.
    #[test]
    fn core_config_default_matches_legacy_constants() {
        let c = CoreConfig::default();
        assert_eq!(c.reselect_every, RESELECT_EVERY);
        assert_eq!(c.max_active, DEFAULT_MAX_ACTIVE);
        assert_eq!(c.max_batch, usize::MAX);
        assert_eq!(c.gamma_cap, DEFAULT_GAMMA_CAP);
        assert!(c.spec);
        // None = the historical behavior (run to max_new); EOS
        // termination is opt-in and applies to every path uniformly.
        assert_eq!(c.eos_token, None);
        assert_eq!(c.loose_deadline_ms, DEFAULT_LOOSE_DEADLINE_MS);
    }
}
