//! The serving engine + token-interleaved serving core.
//!
//! [`ServingEngine`] binds one model's adaptation set (DP-LLM configurations
//! at several target precisions) to the PJRT runtime.  [`ServingCore`] is
//! the decode loop around it: it admits requests mid-flight from the
//! [`RequestQueue`], keeps every active generation's KV cache device-resident
//! ([`GenState`]), round-robins (FIFO) or deadline-orders (EDF) **per
//! token** across the active set, re-selects each request's target
//! precision mid-stream when utilization moves, and streams token events to
//! the caller.  A tight deadline admitted mid-generation preempts
//! best-effort traffic at the next token boundary instead of waiting a
//! whole generation.
//!
//! Each scheduling step serves one token of the policy-chosen request —
//! and, when the batched decode artifacts are available, one token of
//! every *batch-compatible* runnable request alongside it in the SAME
//! device dispatch: [`pick_batch`] groups the active set by target
//! session (same weight-stack device buffers, same KV shape bucket) and
//! [`DecodeSession::advance_batch`] packs the group into one
//! `decode_step_b{2,4,8}` call, preserving FIFO/EDF semantics (the lead
//! is always exactly [`pick_next`]'s choice) while cutting device
//! dispatches per generated token from 1.0 toward 1/B — DESIGN.md
//! §Batching.  When no batch forms (mixed targets, B = 1 artifacts,
//! `DPLLM_NO_BATCH`) every step degenerates to the per-request path.
//!
//! A spec-eligible generation running **alone** instead rides
//! self-speculative decoding (DESIGN.md §Speculation): the adaptation
//! set's lowest-precision session drafts γ tokens for free off the
//! any-precision overlay, one `verify_step_g{γ}` dispatch at the target
//! precision scores them all, and the accepted run streams in order —
//! up to γ+1 tokens per dispatch where batching has no partner to
//! amortize with.  Best-effort and loose-deadline requests are eligible;
//! tight-EDF requests keep token-granular preemption.  The degradation
//! ladder is spec → batched → single, every rung preserving greedy
//! numerics exactly.  All knobs live in [`CoreConfig`].

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::{counters_json, counters_report, MetricsRegistry, RequestRecord};
use super::qos::{AdaptationPolicy, UtilizationSim};
use super::sched::{Request, RequestQueue, SchedPolicy};
use crate::anyprec::materialize::MatSnapshot;
use crate::evalharness::{build_session_with_cache, engine_config_for, Method};
use crate::model::{art, Manifest, ModelAssets};
use crate::runtime::decode::{DecodeSession, EstMode, GenState, SwapReport, WeightCache};
use crate::runtime::spec::{spec_eligible, spec_round, truncate_at_eos,
                           GammaController, SpecState, MAX_SPEC_CATCHUP};
use crate::runtime::Runtime;
use crate::selector::EngineConfig;
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;

/// Default tokens between utilization ticks / mid-stream target
/// re-selection in the interleaved loop ([`CoreConfig::reselect_every`]).
pub const RESELECT_EVERY: u64 = 8;

/// Default cap on concurrently-interleaved generations (KV caches resident
/// on the device at once).
pub const DEFAULT_MAX_ACTIVE: usize = 4;

/// Default cap on the speculative draft length γ
/// ([`CoreConfig::gamma_cap`]); 0 disables speculation outright.
pub const DEFAULT_GAMMA_CAP: usize = 4;

/// Default boundary between "tight" and "loose" deadlines for the spec
/// path ([`CoreConfig::loose_deadline_ms`]): requests whose deadline is at
/// least this far out may commit multi-token speculative runs; tighter
/// deadlines keep token-granular EDF preemption.
pub const DEFAULT_LOOSE_DEADLINE_MS: f64 = 1_000.0;

/// Runtime-tunable knobs of the [`ServingCore`] scheduling loop.  The
/// `Default` instance reproduces the historical hard-coded behavior;
/// [`CoreConfig::from_env`] layers the environment escape hatches on top
/// and is what [`ServingCore::new`] uses, so deployments tune the loop
/// without recompiling (the `serve` CLI additionally plumbs flags).
#[derive(Debug, Clone)]
pub struct CoreConfig {
    /// Concurrently-interleaved generations (device KV residency cap).
    pub max_active: usize,
    /// Generations per shared device dispatch (1 = per-request dispatch;
    /// further capped by the lead session's largest `decode_step_b*`).
    pub max_batch: usize,
    /// Tokens between utilization ticks / mid-stream re-selection.
    pub reselect_every: u64,
    /// Largest speculative draft length γ the controller may pick
    /// (candidates are further limited to the compiled `verify_step_g*`
    /// graphs); 0 disables speculation.
    pub gamma_cap: usize,
    /// Master switch for the speculative path (`DPLLM_NO_SPEC` clears it).
    pub spec: bool,
    /// Deadlines at least this many ms out still ride the spec path.
    pub loose_deadline_ms: f64,
    /// Token that terminates a generation when it is emitted, on EVERY
    /// decode path — plain, batched, and speculative (where it truncates
    /// the accepted run, EOS kept) — so speculation and plain decode
    /// stay token-for-token identical.  `None` (the default) preserves
    /// the historical behavior: generations run to `max_new`.
    pub eos_token: Option<u32>,
}

impl Default for CoreConfig {
    fn default() -> CoreConfig {
        CoreConfig {
            max_active: DEFAULT_MAX_ACTIVE,
            max_batch: usize::MAX,
            reselect_every: RESELECT_EVERY,
            gamma_cap: DEFAULT_GAMMA_CAP,
            spec: true,
            loose_deadline_ms: DEFAULT_LOOSE_DEADLINE_MS,
            eos_token: None,
        }
    }
}

impl CoreConfig {
    /// Defaults + environment overrides: `DPLLM_NO_BATCH=1` forces
    /// per-request dispatch, `DPLLM_NO_SPEC=1` disables speculation,
    /// `DPLLM_RESELECT_EVERY=<n>` retunes the re-selection cadence and
    /// `DPLLM_GAMMA_CAP=<n>` caps the speculative draft length.
    pub fn from_env() -> CoreConfig {
        let mut c = CoreConfig::default();
        if std::env::var_os("DPLLM_NO_BATCH").is_some() {
            c.max_batch = 1;
        }
        if std::env::var_os("DPLLM_NO_SPEC").is_some() {
            c.spec = false;
        }
        if let Some(n) = std::env::var("DPLLM_RESELECT_EVERY")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
        {
            c.reselect_every = n.max(1);
        }
        if let Some(n) = std::env::var("DPLLM_GAMMA_CAP")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            c.gamma_cap = n;
        }
        c
    }
}

pub struct ServeOutcome {
    pub id: u64,
    pub text: String,
    pub target_precision: f64,
    pub effective_bits: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Request arrival → first streamed token (includes queue wait,
    /// prefill, and any interleaving delay before the first step).
    pub ttft_ms: f64,
    pub output_tokens: usize,
    /// Mid-stream target re-selections applied to this request.
    pub retargets: usize,
}

/// One event from a [`ServingCore::step`] call.
pub enum CoreEvent {
    /// A token was produced for request `id` (streaming callback payload).
    Token {
        id: u64,
        /// 0-based index within the request's output.
        index: usize,
        token: u32,
        /// Detokenized piece (may be empty for byte-partial tokens).
        piece: String,
        /// Target precision the token was decoded at.
        target: f64,
    },
    /// Request finished; terminal stats.
    Done(ServeOutcome),
    /// Request aborted on a decode error; the generation was evicted so
    /// the rest of the active set keeps serving.
    Failed { id: u64, error: String },
}

/// One model + its adaptation set, ready to serve.
pub struct ServingEngine {
    pub tokenizer: Tokenizer,
    /// target precision -> session (dynamic DP-LLM configs).
    sessions: BTreeMap<String, DecodeSession>,
    targets: Vec<(f64, String)>,
    pub policy: AdaptationPolicy,
    pub metrics: MetricsRegistry,
    pub est_mode: EstMode,
    /// Weight materialization cache shared by every session of the
    /// adaptation set: each (group, layer, bits) slab dequantizes and
    /// uploads once no matter how many targets use it, and
    /// [`ServingEngine::reconfigure`] rebinds are delta-materialized.
    weights: WeightCache,
    rt: Arc<Runtime>,
    /// Retained so [`ServingEngine::reconfigure`] rebinds without
    /// re-reading the packed store from disk (the store itself is an
    /// `Arc` already shared with every session).
    assets: ModelAssets,
    manifest: Manifest,
    budget: u32,
}

impl ServingEngine {
    /// Load DP-LLM configurations for every `tags` entry (e.g. "3.50").
    pub fn load(rt: &Arc<Runtime>, model: &str, budget: u32,
                tags: &[&str]) -> Result<ServingEngine> {
        let assets = ModelAssets::load(model)?;
        let manifest = Manifest::load()?;
        let tokenizer = Tokenizer::load(&art(&["data", "tokenizer.json"]))?;
        let weights = DecodeSession::fresh_weight_cache();
        let mut sessions = BTreeMap::new();
        let mut targets = Vec::new();
        for tag in tags {
            let m = Method::Dpllm { tag: tag.to_string() };
            let s = build_session_with_cache(rt, &assets, &manifest, budget, &m,
                                             weights.clone())?;
            targets.push((s.ec.target, tag.to_string()));
            sessions.insert(tag.to_string(), s);
        }
        if sessions.is_empty() {
            return Err(anyhow!("no configurations loaded"));
        }
        // Calibrate the adaptation policy with measured TPOTs.
        let mut options = Vec::new();
        for (target, tag) in &targets {
            let s = &sessions[tag];
            let tpot = measure_tpot(s, 3)?;
            options.push((*target, tpot));
        }
        Ok(ServingEngine {
            tokenizer,
            sessions,
            targets,
            policy: AdaptationPolicy::new(options),
            metrics: MetricsRegistry::new(),
            est_mode: EstMode::Approx,
            weights,
            rt: rt.clone(),
            assets,
            manifest,
            budget,
        })
    }

    /// Counters of the shared weight materialization cache (companion to
    /// `Runtime::transfers()` for the §Perf config-switch contract).
    pub fn weight_cache_stats(&self) -> MatSnapshot {
        self.weights.borrow().snapshot()
    }

    /// One serialized snapshot of every runtime counter family —
    /// transfers, weight cache, batching, speculation — via the shared
    /// serializer (`coordinator::metrics::counters_json`).  Backs the
    /// `counters` field of `GET /metrics` and the examples' reports.
    pub fn counters_json(&self) -> Json {
        counters_json(&self.rt.transfers().snapshot(),
                      &self.weights.borrow().snapshot())
    }

    /// Human-readable one-liner over [`ServingEngine::counters_json`]'s
    /// snapshot (examples / CLI end-of-run reports).
    pub fn counters_report(&self) -> String {
        counters_report(&self.rt.transfers().snapshot(),
                        &self.weights.borrow().snapshot())
    }

    /// Costmodel-priced TPOT of a target precision over THIS model's
    /// real packed-store byte counts, at the memory-bandwidth-bound
    /// asymptote (stream time only, no fixed per-token overhead).  The γ
    /// controller prices speculative rounds with this rather than the
    /// measured TPOTs: sandbox-scale measurements are overhead-dominated
    /// (DESIGN.md §2), which would hide exactly the low-bit draft
    /// advantage that reappears at paper scale — the affine slope is the
    /// quantity speculation arbitrages.
    pub fn modeled_tpot_ms(&self, target: f64) -> f64 {
        let bytes = crate::costmodel::weight_bytes_at(&self.assets.store, target);
        crate::costmodel::JETSON_ORIN.stream_ms(bytes)
    }

    /// The draft half of a self-speculative pair for `target`: the
    /// adaptation set's lowest-precision session — resident for free via
    /// the any-precision overlay.  `None` when speculation cannot engage:
    /// the target has no compiled `verify_step_g*` graphs (old
    /// artifacts), or it *is* the lowest-precision member (a draft as
    /// slow as its target can never win; the γ controller would sit at 0
    /// anyway, so the draft prefill is not worth paying).
    pub fn spec_draft_for(&self, target: &DecodeSession) -> Option<&DecodeSession> {
        if target.spec_gammas().is_empty() {
            return None;
        }
        let (_, tag) = self
            .targets
            .iter()
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())?;
        let draft = &self.sessions[tag];
        if std::ptr::eq(draft, target) {
            None
        } else {
            Some(draft)
        }
    }

    /// Swap the adaptation set at runtime (FlexQuant's scenario: the
    /// memory/latency envelope moved, so the coordinator re-selects which
    /// target precisions to keep resident).  Sessions for retained tags
    /// are untouched; a retired session is **rebound in place** to the
    /// first missing tag via [`DecodeSession::swap_bits`] (re-uploading
    /// only layers whose bits differ), and only when no retired session
    /// is available does a tag build fresh — through the shared cache, so
    /// even that re-uploads only slabs never materialized before.
    /// Requires exclusive access: call between [`ServingCore`] runs.
    ///
    /// Error semantics: config resolution failures (unknown tag, missing
    /// calib) happen before any state changes — the old set stays fully
    /// active.  A device-level failure mid-swap returns `Err` with the
    /// engine still **consistent and serviceable**, but the resident set
    /// may mix new and old tags; inspect [`ServingEngine::targets`] to
    /// see what is actually loaded before retrying.
    pub fn reconfigure(&mut self, tags: &[&str]) -> Result<SwapReport> {
        if tags.is_empty() {
            return Err(anyhow!("reconfigure to an empty adaptation set"));
        }
        let keep: Vec<String> = tags.iter().map(|t| t.to_string()).collect();
        // Resolve every missing tag's config BEFORE touching engine state,
        // so the common failure (unknown tag / missing calib) leaves the
        // current adaptation set fully intact.
        let mut pending: Vec<(String, EngineConfig)> = Vec::new();
        for tag in &keep {
            if self.sessions.contains_key(tag)
                || pending.iter().any(|(t, _)| t == tag)
            {
                continue;
            }
            let m = Method::Dpllm { tag: tag.clone() };
            pending.push((tag.clone(), engine_config_for(&self.assets, self.budget, &m)?));
        }
        let mut retired: Vec<(String, DecodeSession)> = Vec::new();
        let current: Vec<String> = self.sessions.keys().cloned().collect();
        for tag in current {
            if !keep.contains(&tag) {
                let s = self.sessions.remove(&tag).expect("listed key");
                retired.push((tag, s));
            }
        }
        let mut rep = SwapReport::default();
        let mut failure = None;
        for (tag, ec) in pending {
            let s = match retired.pop() {
                // swap_bits is atomic: on error the session is still fully
                // on its old configuration, so it goes back under its old
                // tag below.
                Some((old_tag, mut s)) => match s.swap_bits(ec) {
                    Ok(r) => {
                        rep.absorb(r);
                        s
                    }
                    Err(e) => {
                        failure = Some(e);
                        retired.push((old_tag, s));
                        break;
                    }
                },
                None => match DecodeSession::new_shared(
                    self.rt.clone(), &self.assets, &self.manifest, ec,
                    self.weights.clone())
                {
                    Ok(s) => s,
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                },
            };
            self.sessions.insert(tag, s);
        }
        if failure.is_some() {
            // Device-level failure mid-swap: restore the unconsumed retired
            // sessions so the engine never serves from an empty set.
            for (tag, s) in retired {
                self.sessions.insert(tag, s);
            }
        }
        // Targets always derive from the sessions actually resident.
        self.targets = self
            .sessions
            .iter()
            .map(|(tag, s)| (s.ec.target, tag.clone()))
            .collect();
        // Re-calibrate the adaptation policy for the new set.  A probe
        // failure falls back to the previous calibration's nearest
        // estimate so policy and targets never diverge — and never masks
        // an earlier swap failure.
        let mut options = Vec::new();
        for (target, tag) in &self.targets {
            let tpot = match measure_tpot(&self.sessions[tag], 3) {
                Ok(ms) => ms,
                Err(e) => {
                    let fallback = self
                        .policy
                        .options
                        .iter()
                        .min_by(|a, b| {
                            (a.0 - *target)
                                .abs()
                                .partial_cmp(&(b.0 - *target).abs())
                                .unwrap()
                        })
                        .map(|(_, ms)| *ms)
                        .unwrap_or(1.0);
                    if failure.is_none() {
                        failure = Some(e);
                    }
                    fallback
                }
            };
            options.push((*target, tpot));
        }
        self.policy = AdaptationPolicy::new(options);
        match failure {
            Some(e) => Err(e),
            None => Ok(rep),
        }
    }

    pub fn session_for_target(&self, target: f64) -> &DecodeSession {
        let tag = self
            .targets
            .iter()
            .min_by(|a, b| {
                (a.0 - target).abs().partial_cmp(&(b.0 - target).abs()).unwrap()
            })
            .map(|(_, tag)| tag.clone())
            .expect("nonempty");
        &self.sessions[&tag]
    }

    pub fn targets(&self) -> Vec<f64> {
        self.targets.iter().map(|(t, _)| *t).collect()
    }

    /// Serve one request at the target chosen by the QoS policy.
    pub fn handle(&self, req: &Request, utilization: f64) -> Result<ServeOutcome> {
        let mut core = ServingCore::new(self, SchedPolicy::Fifo);
        core.admit(req.clone(), utilization)?;
        drain_single(core)
    }

    /// Serve one request pinned to a specific target precision (no
    /// mid-stream re-selection).
    pub fn handle_at(&self, req: &Request, target: f64) -> Result<ServeOutcome> {
        let mut core = ServingCore::new(self, SchedPolicy::Fifo);
        core.admit_pinned(req.clone(), target)?;
        drain_single(core)
    }

    /// Drain a queue through the token-interleaved core: admission happens
    /// mid-flight as slots free up, decode steps round-robin / EDF across
    /// the active set, and the utilization simulator advances on the
    /// re-selection cadence.
    pub fn run_queue(&self, queue: &mut RequestQueue, util: &mut UtilizationSim)
                     -> Result<Vec<ServeOutcome>> {
        self.run_queue_streaming(queue, util, &mut |_| {})
    }

    /// [`ServingEngine::run_queue`] with a streaming event callback.
    pub fn run_queue_streaming(&self, queue: &mut RequestQueue,
                               util: &mut UtilizationSim,
                               on_event: &mut dyn FnMut(&CoreEvent))
                               -> Result<Vec<ServeOutcome>> {
        ServingCore::new(self, queue.policy()).run(queue, util, on_event)
    }
}

fn drain_single(mut core: ServingCore<'_>) -> Result<ServeOutcome> {
    let mut failure: Option<String> = None;
    let mut outcomes = core.drain(&mut |ev| {
        if let CoreEvent::Failed { error, .. } = ev {
            failure = Some(error.clone());
        }
    })?;
    match outcomes.pop() {
        Some(o) => Ok(o),
        None => Err(anyhow!(
            failure.unwrap_or_else(|| "request produced no outcome".into())
        )),
    }
}

/// Pure next-step selection over the active set, factored out so the
/// fairness / preemption properties are unit-testable without a device.
///
/// `items` carries, per active generation, its admission sequence number
/// and its absolute deadline (None = best effort).  FIFO round-robins via
/// `rr_cursor`; EDF picks the earliest deadline (best-effort last), with
/// the admission sequence as the FIFO tie-break.
pub fn pick_next(policy: SchedPolicy, rr_cursor: usize,
                 items: &[(u64, Option<Instant>)]) -> Option<usize> {
    if items.is_empty() {
        return None;
    }
    match policy {
        SchedPolicy::Fifo => Some(rr_cursor % items.len()),
        SchedPolicy::Edf => items
            .iter()
            .enumerate()
            .min_by_key(|(_, (seq, dl))| (dl.is_none(), *dl, *seq))
            .map(|(i, _)| i),
    }
}

/// One active generation as seen by [`pick_batch`]: admission sequence,
/// absolute deadline (None = best effort), and an opaque
/// batch-compatibility key.  Two generations may share a device dispatch
/// only when their keys are equal; the serving core keys on the target
/// [`DecodeSession`] pointer, which subsumes "same weight-stack `Arc`"
/// and "compatible KV shape bucket" (one session = one model config =
/// one `[L, 2, H, Smax, hd]` KV bucket).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchItem {
    pub seq: u64,
    pub deadline: Option<Instant>,
    pub key: usize,
}

/// Select up to `max_batch` active generations to advance in ONE device
/// dispatch.  Pure, so the grouping/fairness properties are unit-testable
/// without a device.  Contract:
///
/// * the scheduling *lead* is exactly [`pick_next`]'s choice — batching
///   never changes who is served next, only who rides along for free;
/// * only items sharing the lead's `key` join the batch;
/// * FIFO: membership is a circular window starting at the lead (so the
///   `rr_cursor` rotation stays fair when more than `max_batch`
///   compatible generations are runnable); the returned order is
///   admission order, i.e. stable slot/event order across steps;
/// * EDF: membership and order are earliest-deadline-first with the
///   admission sequence as tie-break and best-effort last — deadline
///   priority is preserved *within* the batch;
/// * `max_batch <= 1` degenerates to `vec![pick_next(..)]`.
pub fn pick_batch(policy: SchedPolicy, rr_cursor: usize, items: &[BatchItem],
                  max_batch: usize) -> Vec<usize> {
    let pairs: Vec<(u64, Option<Instant>)> =
        items.iter().map(|it| (it.seq, it.deadline)).collect();
    let Some(lead) = pick_next(policy, rr_cursor, &pairs) else {
        return Vec::new();
    };
    pick_batch_with_lead(policy, lead, items, max_batch)
}

/// [`pick_batch`] with the scheduling lead already computed: the serving
/// core calls [`pick_next`] once to derive the batch cap from the lead's
/// session, then reuses that pick here — one policy scan per step and a
/// single source of truth for the "lead == pick_next's choice" contract.
fn pick_batch_with_lead(policy: SchedPolicy, lead: usize, items: &[BatchItem],
                        max_batch: usize) -> Vec<usize> {
    let key = items[lead].key;
    let cap = max_batch.max(1);
    let mut sel = vec![lead];
    match policy {
        SchedPolicy::Fifo => {
            for off in 1..items.len() {
                if sel.len() >= cap {
                    break;
                }
                let i = (lead + off) % items.len();
                if items[i].key == key {
                    sel.push(i);
                }
            }
            sel.sort_by_key(|&i| items[i].seq);
        }
        SchedPolicy::Edf => {
            let mut rest: Vec<usize> = (0..items.len())
                .filter(|&i| i != lead && items[i].key == key)
                .collect();
            rest.sort_by_key(|&i| {
                (items[i].deadline.is_none(), items[i].deadline, items[i].seq)
            });
            rest.truncate(cap - 1);
            sel.extend(rest);
        }
    }
    sel
}

/// One in-flight generation inside the core.
struct Generation<'e> {
    req: Request,
    session: &'e DecodeSession,
    gen: GenState<'e>,
    target: f64,
    pinned: bool,
    seq: u64,
    /// Prompt length in tokens; `out_ids[j]` was fed (or will be fed) at
    /// absolute position `prompt_len + j`.
    prompt_len: usize,
    next_token: u32,
    out_ids: Vec<u32>,
    /// Speculation pair state: the low-bit draft generation + γ
    /// controller.  `None` when the request is ineligible (tight
    /// deadline), speculation is disabled, the artifacts lack verify
    /// graphs, or a speculative round failed (permanent per-request
    /// fallback to plain decode).
    spec: Option<SpecState<'e>>,
    /// Terminated by emitting [`CoreConfig::eos_token`] (on any decode
    /// path — plain, batched, or inside an accepted speculative run).
    done: bool,
    queue_ms: f64,
    prefill_ms: f64,
    decode_ms: f64,
    ttft_ms: f64,
}

impl Generation<'_> {
    fn finished(&self) -> bool {
        self.done
            || self.out_ids.len() >= self.req.max_new
            || self.gen.pos + 1 >= self.session.cfg.max_seq
    }
}

/// Token-interleaved decode loop over one [`ServingEngine`], with a
/// batched fast path: every scheduling step advances the policy-chosen
/// generation AND any batch-compatible runnable generations in a single
/// device dispatch (see [`pick_batch`] / DESIGN.md §Batching).
pub struct ServingCore<'e> {
    engine: &'e ServingEngine,
    policy: SchedPolicy,
    active: Vec<Generation<'e>>,
    rr_cursor: usize,
    next_seq: u64,
    /// Scheduling knobs ([`CoreConfig`]); seeded from the environment by
    /// [`ServingCore::new`].
    config: CoreConfig,
    /// Batched dispatches that failed and fell back to per-request
    /// advances (see [`ServingCore::batch_errors`]).
    batch_errors: u64,
    /// Speculative rounds that failed; each failure permanently drops
    /// that request's speculation state (see [`ServingCore::spec_errors`]).
    spec_errors: u64,
    token_clock: u64,
    /// Last `token_clock / reselect_every` epoch a re-selection ran for
    /// (see [`ServingCore::reselect_due`]).
    reselect_epoch: Option<u64>,
}

impl<'e> ServingCore<'e> {
    pub fn new(engine: &'e ServingEngine, policy: SchedPolicy) -> ServingCore<'e> {
        ServingCore {
            engine,
            policy,
            active: Vec::new(),
            rr_cursor: 0,
            next_seq: 0,
            config: CoreConfig::from_env(),
            batch_errors: 0,
            spec_errors: 0,
            token_clock: 0,
            reselect_epoch: None,
        }
    }

    /// Replace the scheduling knobs wholesale (tests, CLI plumbing).
    pub fn with_config(mut self, config: CoreConfig) -> ServingCore<'e> {
        self.config = config;
        self.config.max_active = self.config.max_active.max(1);
        self.config.max_batch = self.config.max_batch.max(1);
        self.config.reselect_every = self.config.reselect_every.max(1);
        self
    }

    pub fn with_max_active(mut self, n: usize) -> ServingCore<'e> {
        self.config.max_active = n.max(1);
        self
    }

    /// Cap the number of generations packed into one device dispatch
    /// (1 = per-request dispatch, the pre-batching behavior).
    pub fn with_max_batch(mut self, n: usize) -> ServingCore<'e> {
        self.config.max_batch = n.max(1);
        self
    }

    pub fn has_active(&self) -> bool {
        !self.active.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.config.max_active
    }

    /// Tokens decoded since construction (drives the re-selection
    /// cadence).  A batched step advances this by its occupancy, so it
    /// counts tokens, not device dispatches.
    pub fn token_clock(&self) -> u64 {
        self.token_clock
    }

    /// Batched dispatches that failed and fell back to per-request
    /// advances.  Non-zero with a growing trend means the
    /// `decode_step_b*` artifacts are broken and every step is paying a
    /// doomed dispatch — regenerate them or set `DPLLM_NO_BATCH=1`.
    pub fn batch_errors(&self) -> u64 {
        self.batch_errors
    }

    /// Speculative rounds that failed.  Each failure drops that
    /// request's speculation state permanently (plain decode from then
    /// on), so this stays small; a non-zero value usually means broken
    /// `verify_step_g*` artifacts — regenerate them or set
    /// `DPLLM_NO_SPEC=1`.
    pub fn spec_errors(&self) -> u64 {
        self.spec_errors
    }

    /// True when a utilization tick + mid-stream re-selection is due:
    /// once per [`CoreConfig::reselect_every`]-token epoch, and on the
    /// first call.  Epoch-based rather than `token_clock % n == 0`
    /// because a batched step or an accepted speculative run can move
    /// the clock across a multiple without ever landing on it.
    pub fn reselect_due(&mut self) -> bool {
        let epoch = self.token_clock / self.config.reselect_every.max(1);
        if self.reselect_epoch == Some(epoch) {
            false
        } else {
            self.reselect_epoch = Some(epoch);
            true
        }
    }

    /// Admit one request at the QoS-policy target for `utilization`.
    /// Runs prefill immediately (max precision), so the request's first
    /// token is ready before the next [`ServingCore::step`].
    pub fn admit(&mut self, req: Request, utilization: f64) -> Result<u64> {
        let target = self.engine.policy.select(req.qos, utilization);
        self.admit_inner(req, target, false)
    }

    /// Admit pinned to a target precision; never re-selected mid-stream.
    pub fn admit_pinned(&mut self, req: Request, target: f64) -> Result<u64> {
        self.admit_inner(req, target, true)
    }

    /// Pull requests from the queue while there is capacity.
    pub fn admit_from(&mut self, queue: &mut RequestQueue, utilization: f64)
                      -> Result<usize> {
        let mut admitted = 0;
        while self.has_capacity() {
            match queue.pop() {
                Some(r) => {
                    self.admit(r, utilization)?;
                    admitted += 1;
                }
                None => break,
            }
        }
        Ok(admitted)
    }

    fn admit_inner(&mut self, req: Request, target: f64, pinned: bool)
                   -> Result<u64> {
        if !self.has_capacity() {
            return Err(anyhow!("core at capacity ({})", self.config.max_active));
        }
        let session = self.engine.session_for_target(target);
        let queue_ms = req.arrival.elapsed().as_secs_f64() * 1e3;
        let prompt_ids = self.engine.tokenizer.encode(&req.prompt);
        if prompt_ids.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        let t0 = Instant::now();
        let (gen, logits) = session.begin(&prompt_ids)?;
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        let first = DecodeSession::argmax(&logits)?;
        let id = req.id;
        // Pair eligible requests with the low-bit draft session: a draft
        // prefill seeds the draft KV (prefill runs at max precision on
        // both sessions, so this is the same compute the target paid).
        // A failed draft prefill just means no speculation — never a
        // failed admission.
        let spec = if self.config.spec
            && self.config.gamma_cap > 0
            && spec_eligible(req.deadline_ms, self.config.loose_deadline_ms)
        {
            self.engine.spec_draft_for(session).and_then(|draft| {
                let ctrl = GammaController::new(
                    self.engine.modeled_tpot_ms(draft.ec.target),
                    self.engine.modeled_tpot_ms(session.ec.target),
                );
                // If even the optimistic-start controller can never pick
                // γ > 0 for this draft/target cost pair (e.g. adjacent
                // targets), skip the pairing entirely — no draft prefill
                // dispatch, no second device-resident KV cache.
                let candidates: Vec<usize> = session
                    .spec_gammas()
                    .into_iter()
                    .filter(|&g| g <= self.config.gamma_cap)
                    .collect();
                if ctrl.pick(&candidates) == 0 {
                    return None;
                }
                draft.begin(&prompt_ids).ok().map(|(draft_gen, _)| SpecState {
                    draft,
                    draft_gen,
                    ctrl,
                })
            })
        } else {
            None
        };
        self.active.push(Generation {
            req,
            session,
            gen,
            target: session.ec.target,
            pinned,
            seq: self.next_seq,
            prompt_len: prompt_ids.len(),
            next_token: first,
            out_ids: vec![first],
            spec,
            done: false,
            queue_ms,
            prefill_ms,
            decode_ms: 0.0,
            // Finalized when the first token actually streams; under load
            // that is later than admission+prefill (the generation may wait
            // behind deadlined traffic before its first step).
            ttft_ms: queue_ms + prefill_ms,
        });
        self.next_seq += 1;
        Ok(id)
    }

    /// Re-select the target precision of every non-pinned active
    /// generation for the current utilization.  A retargeted generation
    /// keeps its device-resident KV cache and effective-bit statistics;
    /// the new session adopts the state ([`DecodeSession::adopt`]).
    pub fn reselect(&mut self, utilization: f64) -> usize {
        let mut switched = 0;
        for g in &mut self.active {
            if g.pinned || g.finished() {
                continue;
            }
            let want = self.engine.policy.select(g.req.qos, utilization);
            let session = self.engine.session_for_target(want);
            if !std::ptr::eq(session, g.session) {
                g.session = session;
                session.adopt(&mut g.gen);
                g.target = session.ec.target;
                // The γ controller's cost comparison tracks the new
                // target (the draft half stays pinned to the lowest
                // member; if the target moved onto it, the controller's
                // strict-improvement rule parks γ at 0 by itself).
                if let Some(spec) = &mut g.spec {
                    spec.ctrl.tpot_target_ms =
                        self.engine.modeled_tpot_ms(g.target);
                }
                switched += 1;
            }
        }
        switched
    }

    /// Speculative draft length for one active generation this step, 0
    /// when the plain/batched path should run instead: no speculation
    /// state, γ controller says plain decode, or the remaining token /
    /// sequence budget cannot fit a γ+1 run.
    fn spec_gamma_for(&self, g: &Generation<'e>) -> usize {
        let Some(spec) = &g.spec else { return 0 };
        let remaining = g.req.max_new.saturating_sub(g.out_ids.len());
        let candidates: Vec<usize> = g
            .session
            .spec_gammas()
            .into_iter()
            .filter(|&gm| {
                gm <= self.config.gamma_cap
                    && gm + 1 <= remaining
                    && g.gen.pos + gm + 1 < g.session.cfg.max_seq
            })
            .collect();
        spec.ctrl.pick(&candidates)
    }

    /// Try to serve `idx` through one speculative round.  Returns true
    /// when the round fully handled this step's advance (events pushed,
    /// clock moved); false to let the caller run the plain path —
    /// including after a round failure, which drops the request's
    /// speculation state so the step (and the rest of the generation)
    /// proceeds unspeculated.
    fn spec_step(&mut self, idx: usize, events: &mut Vec<CoreEvent>) -> bool {
        let engine = self.engine;
        let est_mode = engine.est_mode;
        let eos = self.config.eos_token;
        let gamma = self.spec_gamma_for(&self.active[idx]);
        let g = &mut self.active[idx];
        let Some(spec) = g.spec.as_mut() else { return false };
        // Committed tokens the draft has not ingested yet (it falls
        // behind when this generation advances through the batched or
        // plain path, and by one token after a fully-accepted round).
        // Far behind → speculation is not earning its keep here; drop it
        // rather than stall a scheduling step on replay.
        let behind = g.gen.pos - spec.draft_gen.pos;
        if behind > MAX_SPEC_CATCHUP {
            g.spec = None;
            return false;
        }
        if gamma == 0 {
            return false;
        }
        let dstart = spec.draft_gen.pos - g.prompt_len;
        let catchup: Vec<u32> =
            g.out_ids[dstart..g.out_ids.len() - 1].to_vec();
        let t0 = Instant::now();
        let round = spec_round(spec, g.session, &mut g.gen, g.next_token,
                               &catchup, gamma, est_mode);
        g.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
        match round {
            Ok(r) => {
                let mut toks = r.tokens;
                if truncate_at_eos(&mut toks, eos) {
                    g.done = true;
                }
                let n = toks.len() as u64;
                // Stream the whole accepted run in order — each token is
                // exactly what plain greedy decode would have emitted.
                for t in toks {
                    g.next_token = t;
                    g.out_ids.push(t);
                    events.push(CoreEvent::Token {
                        id: g.req.id,
                        index: g.out_ids.len() - 1,
                        token: t,
                        piece: engine.tokenizer.decode_one(t),
                        target: g.target,
                    });
                }
                self.token_clock += n;
                true
            }
            Err(e) => {
                // spec_round leaves the pair consistent (draft rewound);
                // drop speculation for this request and let the caller's
                // plain path advance it this very step.
                self.spec_errors += 1;
                if self.spec_errors == 1 {
                    eprintln!(
                        "[core] speculative round failed; request {} falls \
                         back to plain decode (set DPLLM_NO_SPEC=1 or fix \
                         the verify_step_g* artifacts if this persists): \
                         {e:#}",
                        g.req.id
                    );
                }
                g.spec = None;
                false
            }
        }
    }

    /// Advance the policy-chosen generation — together with every
    /// batch-compatible runnable generation in the same device dispatch
    /// when the batched artifacts are available ([`pick_batch`] +
    /// [`DecodeSession::advance_batch`]), or by a multi-token
    /// *speculative round* when it runs alone and is spec-eligible
    /// (γ low-bit drafts verified in one target dispatch via
    /// `runtime::spec::spec_round`, each accepted token streamed in
    /// order).  Emits
    /// the streamed token events (a generation's first pick also emits
    /// its prefill-produced token 0) and, on completion, the terminal
    /// outcomes.  A failed batched dispatch falls back to per-request
    /// advances so one broken generation is evicted without poisoning
    /// its batch mates; a failed speculative round falls back to the
    /// plain path within the same step.
    pub fn step(&mut self) -> Result<Vec<CoreEvent>> {
        let pairs: Vec<(u64, Option<Instant>)> = self
            .active
            .iter()
            .map(|g| (g.seq, g.req.deadline_instant()))
            .collect();
        let Some(lead) = pick_next(self.policy, self.rr_cursor, &pairs) else {
            return Ok(Vec::new());
        };
        let session: &'e DecodeSession = self.active[lead].session;
        let cap = self.config.max_batch.min(session.max_batch()).max(1);
        let picked = if cap > 1 {
            let items: Vec<BatchItem> = self
                .active
                .iter()
                .map(|g| BatchItem {
                    seq: g.seq,
                    deadline: g.req.deadline_instant(),
                    key: g.session as *const DecodeSession as usize,
                })
                .collect();
            pick_batch_with_lead(self.policy, lead, &items, cap)
        } else {
            vec![lead]
        };
        self.rr_cursor = self.rr_cursor.wrapping_add(1);
        let picked_ids: Vec<u64> =
            picked.iter().map(|&i| self.active[i].req.id).collect();
        let mut events = Vec::new();

        // Token 0 (from prefill) streams on the generation's first pick;
        // TTFT is measured to *here*, not to admission.
        for &i in &picked {
            let g = &mut self.active[i];
            if g.gen.steps == 0 {
                g.ttft_ms = g.req.arrival.elapsed().as_secs_f64() * 1e3;
                events.push(CoreEvent::Token {
                    id: g.req.id,
                    index: 0,
                    token: g.next_token,
                    piece: self.engine.tokenizer.decode_one(g.next_token),
                    target: g.target,
                });
            }
        }

        // Advance the non-finished picked generations.  Degradation
        // ladder (DESIGN.md §Speculation): a lone runnable generation
        // tries a speculative round first (γ low-bit drafts verified in
        // one target dispatch — converting idle batch capacity into
        // tokens); ≥ 2 compatible generations share one batched
        // dispatch; everything else is the per-request path.
        let to_advance: Vec<usize> = picked
            .iter()
            .copied()
            .filter(|&i| !self.active[i].finished())
            .collect();
        let est_mode = self.engine.est_mode;
        let mut failures: Vec<(u64, String)> = Vec::new();
        let mut spec_done = false;
        if self.config.spec && to_advance.len() == 1 {
            spec_done = self.spec_step(to_advance[0], &mut events);
        }
        if !spec_done {
            self.step_plain(&to_advance, &picked, est_mode, &mut events,
                            &mut failures);
        }
        // Evict broken generations; the rest of the set keeps serving.
        for (id, error) in failures {
            if let Some(pos) = self.active.iter().position(|g| g.req.id == id) {
                self.active.remove(pos);
            }
            events.push(CoreEvent::Failed { id, error });
        }
        // Completions (indices may have shifted — resolve by id).
        for id in picked_ids {
            if let Some(pos) = self.active.iter().position(|g| g.req.id == id) {
                if self.active[pos].finished() {
                    let g = self.active.remove(pos);
                    events.push(CoreEvent::Done(self.complete(g)));
                }
            }
        }
        Ok(events)
    }

    /// The non-speculative advance of one scheduling step: one batched
    /// dispatch when ≥ 2 picked generations share the lead's session,
    /// else one per-request advance; streams the decoded tokens in pack
    /// order and records failures for the caller to evict.  EOS handling
    /// matches the speculative path: an emitted [`CoreConfig::eos_token`]
    /// finishes the generation (token kept), so every decode path
    /// produces the identical stream.
    fn step_plain(&mut self, to_advance: &[usize], picked: &[usize],
                  est_mode: EstMode, events: &mut Vec<CoreEvent>,
                  failures: &mut Vec<(u64, String)>) {
        let eos = self.config.eos_token;
        let mut advanced: Vec<u64> = Vec::new();
        let advance_one = |g: &mut Generation<'e>,
                               advanced: &mut Vec<u64>,
                               failures: &mut Vec<(u64, String)>| {
            let t0 = Instant::now();
            let stepped = g
                .session
                .advance(&mut g.gen, g.next_token, est_mode)
                .and_then(|out| DecodeSession::argmax(&out.logits));
            g.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
            match stepped {
                Ok(next) => {
                    g.next_token = next;
                    g.out_ids.push(next);
                    if eos == Some(next) {
                        g.done = true;
                    }
                    advanced.push(g.req.id);
                }
                Err(e) => failures.push((g.req.id, format!("{e:#}"))),
            }
        };
        if to_advance.len() >= 2 {
            // All picked generations share the lead's session by the
            // pick_batch key contract — any member names the batch exe.
            let session: &'e DecodeSession = self.active[to_advance[0]].session;
            let t0 = Instant::now();
            let mut gens: Vec<&mut Generation<'e>> = self
                .active
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| to_advance.contains(i))
                .map(|(_, g)| g)
                .collect();
            let batch_result = {
                let mut slots: Vec<(&mut GenState<'e>, u32)> = gens
                    .iter_mut()
                    .map(|g| {
                        let tok = g.next_token;
                        (&mut g.gen, tok)
                    })
                    .collect();
                session.advance_batch(&mut slots, est_mode)
            };
            match batch_result {
                Ok(outs) => {
                    // One dispatch served outs.len() tokens; attribute the
                    // wall time evenly across the slots.
                    let per_ms = t0.elapsed().as_secs_f64() * 1e3
                        / outs.len().max(1) as f64;
                    for (g, out) in gens.iter_mut().zip(outs) {
                        g.decode_ms += per_ms;
                        match DecodeSession::argmax(&out.logits) {
                            Ok(next) => {
                                g.next_token = next;
                                g.out_ids.push(next);
                                if eos == Some(next) {
                                    g.done = true;
                                }
                                advanced.push(g.req.id);
                            }
                            Err(e) => {
                                failures.push((g.req.id, format!("{e:#}")))
                            }
                        }
                    }
                }
                Err(e) => {
                    // advance_batch mutates nothing on failure, so every
                    // slot can be retried individually — the broken one
                    // is evicted alone.  Surface the error (first
                    // occurrence loudly): a persistently failing batched
                    // artifact would otherwise silently pay a doomed
                    // dispatch per token forever.
                    self.batch_errors += 1;
                    if self.batch_errors == 1 {
                        eprintln!(
                            "[core] batched dispatch failed, falling back to \
                             per-request steps (set DPLLM_NO_BATCH=1 or fix \
                             the decode_step_b* artifacts if this persists): \
                             {e:#}"
                        );
                    }
                    for g in gens.iter_mut() {
                        advance_one(&mut **g, &mut advanced, &mut *failures);
                    }
                }
            }
        } else if let Some(&i) = to_advance.first() {
            advance_one(&mut self.active[i], &mut advanced, &mut *failures);
        }
        self.token_clock += advanced.len() as u64;

        // Stream the decoded tokens in pack order (EDF: deadline order;
        // FIFO: admission order).
        for &i in picked {
            let g = &self.active[i];
            if advanced.contains(&g.req.id) {
                events.push(CoreEvent::Token {
                    id: g.req.id,
                    index: g.out_ids.len() - 1,
                    token: g.next_token,
                    piece: self.engine.tokenizer.decode_one(g.next_token),
                    target: g.target,
                });
            }
        }
    }

    /// Run everything to completion: admit from `queue` as capacity frees
    /// up, tick `util` on the re-selection cadence, stream events.
    pub fn run(mut self, queue: &mut RequestQueue, util: &mut UtilizationSim,
               on_event: &mut dyn FnMut(&CoreEvent)) -> Result<Vec<ServeOutcome>> {
        let mut done = Vec::new();
        while self.has_active() || !queue.is_empty() {
            // Admission runs before EVERY dispatch — in particular
            // immediately after a step in which a request finished
            // mid-batch, so the freed slot is refilled in time to join
            // the very next batched dispatch (regression-tested by
            // admission_refills_freed_batch_slot_mid_flight; keep this
            // at the loop head, before reselect/step).
            self.admit_from(queue, util.current())?;
            if self.reselect_due() {
                let u = util.tick();
                self.reselect(u);
            }
            for ev in self.step()? {
                on_event(&ev);
                if let CoreEvent::Done(o) = ev {
                    done.push(o);
                }
            }
        }
        Ok(done)
    }

    /// Finish all currently-active generations (no further admission).
    pub fn drain(&mut self, on_event: &mut dyn FnMut(&CoreEvent))
                 -> Result<Vec<ServeOutcome>> {
        let mut done = Vec::new();
        while self.has_active() {
            for ev in self.step()? {
                on_event(&ev);
                if let CoreEvent::Done(o) = ev {
                    done.push(o);
                }
            }
        }
        Ok(done)
    }

    fn complete(&self, g: Generation<'e>) -> ServeOutcome {
        let eff = g.gen.sel.effective_bits();
        self.engine.metrics.record(RequestRecord {
            id: g.req.id,
            target_precision: g.target,
            effective_bits: eff,
            prompt_tokens: g.prompt_len,
            output_tokens: g.out_ids.len(),
            queue_ms: g.queue_ms,
            prefill_ms: g.prefill_ms,
            decode_ms: g.decode_ms,
        });
        ServeOutcome {
            id: g.req.id,
            text: self.engine.tokenizer.decode(&g.out_ids),
            target_precision: g.target,
            effective_bits: eff,
            prefill_ms: g.prefill_ms,
            decode_ms: g.decode_ms,
            ttft_ms: g.ttft_ms,
            output_tokens: g.out_ids.len(),
            retargets: g.gen.retargets,
        }
    }
}

/// Measure mean decode-step latency over `n` steps (policy calibration).
pub fn measure_tpot(session: &DecodeSession, n: usize) -> Result<f64> {
    let mut gen = session.begin_empty()?;
    // Warm-up step (compile caches, allocator, rope/scalar buffers).
    session.advance(&mut gen, 1, EstMode::Approx)?;
    let t0 = Instant::now();
    for _ in 0..n {
        session.advance(&mut gen, 1, EstMode::Approx)?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3 / n as f64)
}

/// Build a FIFO/EDF queue from (prompt, qos) pairs — workload-gen helper.
pub fn make_queue(policy: SchedPolicy,
                  reqs: impl IntoIterator<Item = Request>) -> RequestQueue {
    let mut q = RequestQueue::new(policy);
    for r in reqs {
        q.push(r);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn now_plus(ms: u64) -> Option<Instant> {
        Some(Instant::now() + Duration::from_millis(ms))
    }

    /// FIFO interleaving fairness: with two active generations, each must
    /// advance within any 2-token window.
    #[test]
    fn fifo_round_robin_two_way_fairness() {
        let items = vec![(0u64, None), (1u64, None)];
        let mut picks = Vec::new();
        for cursor in 0..10 {
            picks.push(pick_next(SchedPolicy::Fifo, cursor, &items).unwrap());
        }
        for w in picks.windows(2) {
            assert_ne!(w[0], w[1], "a generation starved in a 2-token window");
        }
        assert!(picks.contains(&0) && picks.contains(&1));
    }

    /// FIFO cursor sweeps all active generations before repeating.
    #[test]
    fn fifo_round_robin_covers_all() {
        let items: Vec<(u64, Option<Instant>)> =
            (0..5u64).map(|s| (s, None)).collect();
        let picked: Vec<usize> = (0..5)
            .map(|c| pick_next(SchedPolicy::Fifo, c, &items).unwrap())
            .collect();
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    /// EDF at token granularity: the tightest deadline is stepped first,
    /// regardless of admission order; best-effort runs last; admission
    /// sequence breaks ties.
    #[test]
    fn edf_token_granularity_preemption() {
        let items = vec![
            (0u64, None),            // admitted first, best effort
            (1u64, now_plus(5000)),  // loose deadline
            (2u64, now_plus(50)),    // tight deadline, admitted last
        ];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &items), Some(2));

        // Tie on deadline -> FIFO by admission seq.
        let t = now_plus(300);
        let tied = vec![(7u64, t), (3u64, t)];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &tied), Some(1));

        // All best-effort -> earliest admission.
        let be = vec![(9u64, None), (4u64, None), (6u64, None)];
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &be), Some(1));
    }

    #[test]
    fn pick_next_empty_is_none() {
        assert_eq!(pick_next(SchedPolicy::Fifo, 3, &[]), None);
        assert_eq!(pick_next(SchedPolicy::Edf, 0, &[]), None);
    }

    fn bi(seq: u64, deadline: Option<Instant>, key: usize) -> BatchItem {
        BatchItem { seq, deadline, key }
    }

    /// Only generations sharing the lead's compatibility key (same target
    /// session / shape bucket) may join its dispatch.
    #[test]
    fn pick_batch_groups_by_key() {
        let items = vec![
            bi(0, None, 7),
            bi(1, None, 7),
            bi(2, None, 9), // different target stacks — must not join
            bi(3, None, 7),
        ];
        let sel = pick_batch(SchedPolicy::Fifo, 0, &items, 8);
        assert_eq!(sel, vec![0, 1, 3]);
        // Lead rotated onto the incompatible item: it runs alone-keyed,
        // batching with nothing but its own key.
        let sel = pick_batch(SchedPolicy::Fifo, 2, &items, 8);
        assert_eq!(sel, vec![2]);
    }

    /// EDF ordering is preserved within a batch: earliest deadline first,
    /// admission sequence as tie-break, best-effort last — and the lead
    /// is exactly pick_next's choice.
    #[test]
    fn pick_batch_edf_order_within_batch() {
        let t = |ms| now_plus(ms);
        let items = vec![
            bi(0, t(300), 1),
            bi(1, t(50), 1),
            bi(2, None, 1),
            bi(3, t(100), 1),
        ];
        let pairs: Vec<(u64, Option<Instant>)> =
            items.iter().map(|it| (it.seq, it.deadline)).collect();
        let lead = pick_next(SchedPolicy::Edf, 0, &pairs).unwrap();
        let sel = pick_batch(SchedPolicy::Edf, 0, &items, 8);
        assert_eq!(sel, vec![1, 3, 0, 2]);
        assert_eq!(sel[0], lead);
        // Capacity 2 keeps only the two tightest deadlines.
        assert_eq!(pick_batch(SchedPolicy::Edf, 0, &items, 2), vec![1, 3]);
    }

    /// max_batch == 1 degenerates to pick_next under both policies — the
    /// B = 1 fallback is byte-for-byte the pre-batching schedule.
    #[test]
    fn pick_batch_b1_matches_pick_next() {
        let items = vec![
            bi(0, None, 1),
            bi(1, now_plus(100), 1),
            bi(2, now_plus(40), 2),
        ];
        let pairs: Vec<(u64, Option<Instant>)> =
            items.iter().map(|it| (it.seq, it.deadline)).collect();
        for cursor in 0..7 {
            for policy in [SchedPolicy::Fifo, SchedPolicy::Edf] {
                assert_eq!(
                    pick_batch(policy, cursor, &items, 1),
                    vec![pick_next(policy, cursor, &pairs).unwrap()],
                    "policy {policy:?} cursor {cursor}"
                );
            }
        }
        assert!(pick_batch(SchedPolicy::Fifo, 0, &[], 4).is_empty());
    }

    /// FIFO with more runnable generations than batch slots: the cursor
    /// rotates the membership window so every generation is served, and
    /// the returned order is admission order (stable slot order).
    #[test]
    fn pick_batch_fifo_rotation_is_fair_and_stable() {
        let items: Vec<BatchItem> = (0..5).map(|s| bi(s, None, 3)).collect();
        let mut served = [0usize; 5];
        for cursor in 0..10 {
            let sel = pick_batch(SchedPolicy::Fifo, cursor, &items, 2);
            assert_eq!(sel.len(), 2);
            let mut sorted = sel.clone();
            sorted.sort_unstable();
            assert_eq!(sel, sorted, "batch order must be admission order");
            for i in sel {
                served[i] += 1;
            }
        }
        assert!(served.iter().all(|&n| n >= 2),
                "rotation starved a generation: {served:?}");
    }

    /// When everything fits in one batch the slot order is identical
    /// every step, so event streams stay strictly interleaved.
    #[test]
    fn pick_batch_fifo_full_fit_is_stable_across_cursors() {
        let items: Vec<BatchItem> = (0..3).map(|s| bi(s, None, 1)).collect();
        for cursor in 0..6 {
            assert_eq!(pick_batch(SchedPolicy::Fifo, cursor, &items, 4),
                       vec![0, 1, 2]);
        }
    }

    /// The default CoreConfig reproduces the historical hard-coded
    /// behavior exactly — the "defaulting to current behavior" contract
    /// of making the knobs runtime-configurable.
    #[test]
    fn core_config_default_matches_legacy_constants() {
        let c = CoreConfig::default();
        assert_eq!(c.reselect_every, RESELECT_EVERY);
        assert_eq!(c.max_active, DEFAULT_MAX_ACTIVE);
        assert_eq!(c.max_batch, usize::MAX);
        assert_eq!(c.gamma_cap, DEFAULT_GAMMA_CAP);
        assert!(c.spec);
        // None = the historical behavior (run to max_new); EOS
        // termination is opt-in and applies to every path uniformly.
        assert_eq!(c.eos_token, None);
        assert_eq!(c.loose_deadline_ms, DEFAULT_LOOSE_DEADLINE_MS);
    }
}
