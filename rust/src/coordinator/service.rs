//! The serving engine: an adaptation set of DP-LLM configurations bound to
//! one model, a QoS policy choosing among them per query, and the decode
//! loop that runs requests end to end (tokenize → admit → prefill at max
//! precision → dynamic-precision decode → detokenize).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::{MetricsRegistry, RequestRecord};
use super::qos::{AdaptationPolicy, UtilizationSim};
use super::sched::{Request, RequestQueue, SchedPolicy};
use crate::evalharness::{build_session, Method};
use crate::model::{art, Manifest, ModelAssets};
use crate::runtime::decode::{DecodeSession, EstMode};
use crate::runtime::Runtime;
use crate::tokenizer::Tokenizer;

pub struct ServeOutcome {
    pub id: u64,
    pub text: String,
    pub target_precision: f64,
    pub effective_bits: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub output_tokens: usize,
}

/// One model + its adaptation set, ready to serve.
pub struct ServingEngine {
    pub tokenizer: Tokenizer,
    /// target precision -> session (dynamic DP-LLM configs).
    sessions: BTreeMap<String, DecodeSession>,
    targets: Vec<(f64, String)>,
    pub policy: AdaptationPolicy,
    pub metrics: MetricsRegistry,
    pub est_mode: EstMode,
}

impl ServingEngine {
    /// Load DP-LLM configurations for every `tags` entry (e.g. "3.50").
    pub fn load(rt: &Arc<Runtime>, model: &str, budget: u32,
                tags: &[&str]) -> Result<ServingEngine> {
        let assets = ModelAssets::load(model)?;
        let manifest = Manifest::load()?;
        let tokenizer = Tokenizer::load(&art(&["data", "tokenizer.json"]))?;
        let mut sessions = BTreeMap::new();
        let mut targets = Vec::new();
        for tag in tags {
            let m = Method::Dpllm { tag: tag.to_string() };
            let s = build_session(rt, &assets, &manifest, budget, &m)?;
            targets.push((s.ec.target, tag.to_string()));
            sessions.insert(tag.to_string(), s);
        }
        if sessions.is_empty() {
            return Err(anyhow!("no configurations loaded"));
        }
        // Calibrate the adaptation policy with measured TPOTs.
        let mut options = Vec::new();
        for (target, tag) in &targets {
            let s = &sessions[tag];
            let tpot = measure_tpot(s, 3)?;
            options.push((*target, tpot));
        }
        Ok(ServingEngine {
            tokenizer,
            sessions,
            targets,
            policy: AdaptationPolicy::new(options),
            metrics: MetricsRegistry::new(),
            est_mode: EstMode::Approx,
        })
    }

    pub fn session_for_target(&self, target: f64) -> &DecodeSession {
        let tag = self
            .targets
            .iter()
            .min_by(|a, b| {
                (a.0 - target).abs().partial_cmp(&(b.0 - target).abs()).unwrap()
            })
            .map(|(_, tag)| tag.clone())
            .expect("nonempty");
        &self.sessions[&tag]
    }

    pub fn targets(&self) -> Vec<f64> {
        self.targets.iter().map(|(t, _)| *t).collect()
    }

    /// Serve one request at the target chosen by the QoS policy.
    pub fn handle(&self, req: &Request, utilization: f64) -> Result<ServeOutcome> {
        let target = self.policy.select(req.qos, utilization);
        self.handle_at(req, target)
    }

    /// Serve one request pinned to a specific target precision.
    pub fn handle_at(&self, req: &Request, target: f64) -> Result<ServeOutcome> {
        let session = self.session_for_target(target);
        let queue_ms = req.arrival.elapsed().as_secs_f64() * 1e3;
        let prompt_ids = self.tokenizer.encode(&req.prompt);
        if prompt_ids.is_empty() {
            return Err(anyhow!("empty prompt"));
        }

        let t0 = Instant::now();
        let pre = session.prefill(&prompt_ids)?;
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t1 = Instant::now();
        let mut kv = pre.kv;
        let mut sel = session.selector_state();
        let mut next = DecodeSession::argmax(&pre.logits);
        let mut out_ids = vec![next];
        let mut pos = prompt_ids.len();
        for _ in 1..req.max_new {
            if pos + 1 >= session.cfg.max_seq {
                break;
            }
            let step = session.step(next, pos, &kv, &sel.use_h_async, self.est_mode)?;
            sel.observe(&step.ests, &step.use_eff);
            kv = step.kv;
            next = DecodeSession::argmax(&step.logits);
            out_ids.push(next);
            pos += 1;
        }
        let decode_ms = t1.elapsed().as_secs_f64() * 1e3;
        let eff = sel.effective_bits();

        self.metrics.record(RequestRecord {
            id: req.id,
            target_precision: target,
            effective_bits: eff,
            prompt_tokens: prompt_ids.len(),
            output_tokens: out_ids.len(),
            queue_ms,
            prefill_ms,
            decode_ms,
        });
        Ok(ServeOutcome {
            id: req.id,
            text: self.tokenizer.decode(&out_ids),
            target_precision: target,
            effective_bits: eff,
            prefill_ms,
            decode_ms,
            output_tokens: out_ids.len(),
        })
    }

    /// Drain a queue sequentially (batch-1 on-device serving), with the
    /// utilization simulator advancing per request.
    pub fn run_queue(&self, queue: &mut RequestQueue, util: &mut UtilizationSim)
                     -> Result<Vec<ServeOutcome>> {
        let mut out = Vec::new();
        while let Some(req) = queue.pop() {
            let u = util.tick();
            out.push(self.handle(&req, u)?);
        }
        Ok(out)
    }
}

/// Measure mean decode-step latency over `n` steps (policy calibration).
pub fn measure_tpot(session: &DecodeSession, n: usize) -> Result<f64> {
    let mut kv = session.zero_kv();
    let sel = session.selector_state();
    // Warm-up step (compile caches, allocator).
    let w = session.step(1, 0, &kv, &sel.use_h_async, EstMode::Approx)?;
    kv = w.kv;
    let t0 = Instant::now();
    for i in 0..n {
        let s = session.step(1, i + 1, &kv, &sel.use_h_async, EstMode::Approx)?;
        kv = s.kv;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3 / n as f64)
}

/// Build a FIFO/EDF queue from (prompt, qos) pairs — workload-gen helper.
pub fn make_queue(policy: SchedPolicy,
                  reqs: impl IntoIterator<Item = Request>) -> RequestQueue {
    let mut q = RequestQueue::new(policy);
    for r in reqs {
        q.push(r);
    }
    q
}
