//! QoS budgets, the fluctuating-utilization simulator and the
//! slack → target-precision adaptation policy (paper Fig. 1).

use crate::util::rng::Rng;

/// Per-query quality-of-service budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosBudget {
    /// Latency target per output token, ms (∞ = best effort).
    pub ms_per_token: f64,
}

impl QosBudget {
    pub fn best_effort() -> QosBudget {
        QosBudget { ms_per_token: f64::INFINITY }
    }

    pub fn tight(ms: f64) -> QosBudget {
        QosBudget { ms_per_token: ms }
    }
}

/// Background system utilization: a bounded random walk in [0, max_util],
/// standing in for the "fluctuating system utilization" of Fig. 1 (other
/// apps competing for the device on an edge platform).
#[derive(Debug, Clone)]
pub struct UtilizationSim {
    rng: Rng,
    level: f64,
    max_util: f64,
    step: f64,
}

impl UtilizationSim {
    pub fn new(seed: u64, max_util: f64) -> UtilizationSim {
        UtilizationSim { rng: Rng::new(seed), level: max_util / 2.0,
                         max_util, step: 0.08 }
    }

    /// Constant utilization (for controlled experiments).
    pub fn constant(level: f64) -> UtilizationSim {
        UtilizationSim { rng: Rng::new(0), level, max_util: level, step: 0.0 }
    }

    /// Advance the walk and return the current utilization in [0, max].
    pub fn tick(&mut self) -> f64 {
        if self.step > 0.0 {
            self.level += (self.rng.f64() - 0.5) * 2.0 * self.step;
            self.level = self.level.clamp(0.0, self.max_util);
        }
        self.level
    }

    pub fn current(&self) -> f64 {
        self.level
    }
}

/// Maps (QoS budget, utilization) to a member of the adaptation set.
///
/// `tpot_at(target)` — predicted per-token latency of each configuration
/// (from the device cost model or live measurements); the policy picks the
/// highest-precision target whose predicted TPOT fits the slack
///     slack = budget · (1 − utilization)
/// falling back to the fastest configuration when nothing fits (the
/// best-effort semantics of the paper's §6.3 QoS study).
#[derive(Debug, Clone)]
pub struct AdaptationPolicy {
    /// (target_precision, predicted_tpot_ms), sorted by target ascending.
    pub options: Vec<(f64, f64)>,
}

impl AdaptationPolicy {
    pub fn new(mut options: Vec<(f64, f64)>) -> AdaptationPolicy {
        options.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        AdaptationPolicy { options }
    }

    pub fn select(&self, budget: QosBudget, utilization: f64) -> f64 {
        let slack = budget.ms_per_token * (1.0 - utilization.clamp(0.0, 0.99));
        let mut chosen = self.options.first().map(|o| o.0).unwrap_or(4.0);
        for &(target, tpot) in &self.options {
            if tpot <= slack {
                chosen = target; // options sorted ascending: keep the largest fit
            }
        }
        chosen
    }

    pub fn targets(&self) -> Vec<f64> {
        self.options.iter().map(|o| o.0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AdaptationPolicy {
        // TPOT grows with precision (affine, like Table 5).
        AdaptationPolicy::new(vec![
            (3.25, 10.0), (3.5, 11.0), (4.0, 13.0), (4.5, 15.0), (4.75, 16.0),
        ])
    }

    #[test]
    fn relaxed_budget_low_util_prefers_high_precision() {
        let p = policy();
        assert_eq!(p.select(QosBudget::tight(100.0), 0.0), 4.75);
        assert_eq!(p.select(QosBudget::best_effort(), 0.9), 4.75);
    }

    #[test]
    fn tight_budget_or_high_util_degrades_precision() {
        let p = policy();
        assert_eq!(p.select(QosBudget::tight(13.5), 0.0), 4.0);
        // same budget but 30% util -> slack 9.45ms -> nothing fits -> fastest
        assert_eq!(p.select(QosBudget::tight(13.5), 0.3), 3.25);
        assert_eq!(p.select(QosBudget::tight(11.5), 0.0), 3.5);
    }

    #[test]
    fn fallback_is_fastest() {
        let p = policy();
        assert_eq!(p.select(QosBudget::tight(1.0), 0.0), 3.25);
    }

    #[test]
    fn utilization_walk_bounded() {
        let mut u = UtilizationSim::new(3, 0.6);
        for _ in 0..1000 {
            let v = u.tick();
            assert!((0.0..=0.6).contains(&v));
        }
    }

    #[test]
    fn utilization_walk_moves() {
        let mut u = UtilizationSim::new(4, 0.8);
        let first = u.tick();
        let any_diff = (0..100).any(|_| (u.tick() - first).abs() > 0.05);
        assert!(any_diff);
    }
}
