//! Workload generation for the serving experiments: Poisson arrivals,
//! mixed QoS classes, prompt sampling from the instruct set — the
//! controllable analog of the paper's §6.3 query stream.

use anyhow::{bail, Result};

use super::qos::QosBudget;
use super::sched::Request;
use crate::util::rng::Rng;

/// A QoS class with its share of traffic.
#[derive(Debug, Clone, Copy)]
pub struct QosClass {
    pub share: f64,
    pub budget: QosBudget,
    /// Optional first-token deadline (ms from arrival) for EDF.
    pub deadline_ms: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean arrival rate (requests/second) for inter-arrival spacing.
    pub rate_per_s: f64,
    pub max_new: usize,
    pub classes: Vec<QosClass>,
}

impl WorkloadSpec {
    /// The default mixed-QoS workload used by the examples/benches:
    /// 1/3 best-effort, 1/3 relaxed, 1/3 tight with deadlines.
    pub fn mixed(rate_per_s: f64, max_new: usize) -> WorkloadSpec {
        WorkloadSpec {
            rate_per_s,
            max_new,
            classes: vec![
                QosClass { share: 1.0 / 3.0, budget: QosBudget::best_effort(),
                           deadline_ms: None },
                QosClass { share: 1.0 / 3.0, budget: QosBudget::tight(250.0),
                           deadline_ms: None },
                QosClass { share: 1.0 / 3.0, budget: QosBudget::tight(60.0),
                           deadline_ms: Some(2_000.0) },
            ],
        }
    }

    /// Validate the class table and normalize shares to sum to exactly
    /// 1.0.  Errors on an empty table and on any share that is NaN,
    /// infinite, or not strictly positive — a malformed spec must fail
    /// loudly instead of silently skewing class assignment (a NaN share
    /// poisons the cumulative draw in `pick_class`; a negative one makes
    /// its *neighbor* over-selected).
    pub fn validated(mut self) -> Result<WorkloadSpec> {
        if self.classes.is_empty() {
            bail!("WorkloadSpec: empty class table");
        }
        for (i, c) in self.classes.iter().enumerate() {
            if !c.share.is_finite() || c.share <= 0.0 {
                bail!(
                    "WorkloadSpec: class {i} share {} must be a finite \
                     positive number",
                    c.share
                );
            }
        }
        let sum: f64 = self.classes.iter().map(|c| c.share).sum();
        if !sum.is_finite() || sum <= 0.0 {
            bail!("WorkloadSpec: class shares sum to {sum}");
        }
        for c in &mut self.classes {
            c.share /= sum;
        }
        Ok(self)
    }

    fn pick_class(&self, rng: &mut Rng) -> &QosClass {
        let mut draw = rng.f64() * self.classes.iter().map(|c| c.share).sum::<f64>();
        for c in &self.classes {
            draw -= c.share;
            if draw <= 0.0 {
                return c;
            }
        }
        self.classes.last().expect("nonempty classes")
    }

    /// Generate `n` requests over `prompts` with Poisson inter-arrival
    /// offsets (returned alongside, in ms, for trace-driven replay).
    ///
    /// Panics on a malformed class table (see [`WorkloadSpec::validated`])
    /// — callers building specs from external input should validate
    /// first and surface the error.
    pub fn generate(&self, prompts: &[String], n: usize, seed: u64)
                    -> Vec<(f64, Request)> {
        let spec = self.clone().validated().expect("invalid WorkloadSpec");
        let mut rng = Rng::new(seed);
        let mut t_ms = 0.0;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            t_ms += rng.exp(spec.rate_per_s) * 1e3;
            let class = *spec.pick_class(&mut rng);
            let prompt = prompts[rng.range(0, prompts.len())].clone();
            let mut r = Request::new(i as u64, prompt, spec.max_new, class.budget);
            if let Some(d) = class.deadline_ms {
                r = r.with_deadline(d);
            }
            out.push((t_ms, r));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::for_each_seed;

    fn prompts() -> Vec<String> {
        vec!["a".into(), "b".into(), "c".into()]
    }

    #[test]
    fn generates_n_requests_with_increasing_arrivals() {
        let w = WorkloadSpec::mixed(10.0, 16);
        let reqs = w.generate(&prompts(), 50, 1);
        assert_eq!(reqs.len(), 50);
        for win in reqs.windows(2) {
            assert!(win[1].0 >= win[0].0);
        }
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let w = WorkloadSpec::mixed(20.0, 8);
        let reqs = w.generate(&prompts(), 400, 2);
        let span_s = reqs.last().unwrap().0 / 1e3;
        let rate = reqs.len() as f64 / span_s;
        assert!((rate - 20.0).abs() < 4.0, "rate {rate}");
    }

    #[test]
    fn class_mix_respected() {
        let w = WorkloadSpec::mixed(10.0, 8);
        let reqs = w.generate(&prompts(), 600, 3);
        let best_effort = reqs.iter()
            .filter(|(_, r)| r.qos.ms_per_token.is_infinite()).count();
        let frac = best_effort as f64 / reqs.len() as f64;
        assert!((frac - 1.0 / 3.0).abs() < 0.08, "share {frac}");
    }

    #[test]
    fn deterministic_per_seed() {
        let w = WorkloadSpec::mixed(5.0, 8);
        let a = w.generate(&prompts(), 20, 9);
        let b = w.generate(&prompts(), 20, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.1.prompt, y.1.prompt);
            assert_eq!(x.0, y.0);
        }
    }

    fn spec_with_shares(shares: &[f64]) -> WorkloadSpec {
        WorkloadSpec {
            rate_per_s: 10.0,
            max_new: 8,
            classes: shares
                .iter()
                .map(|&share| QosClass {
                    share,
                    budget: QosBudget::best_effort(),
                    deadline_ms: None,
                })
                .collect(),
        }
    }

    #[test]
    fn validated_normalizes_shares_to_one() {
        let w = spec_with_shares(&[2.0, 6.0]).validated().unwrap();
        assert!((w.classes[0].share - 0.25).abs() < 1e-12);
        assert!((w.classes[1].share - 0.75).abs() < 1e-12);
        let sum: f64 = w.classes.iter().map(|c| c.share).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validated_rejects_malformed_shares() {
        assert!(spec_with_shares(&[]).validated().is_err());
        assert!(spec_with_shares(&[0.0, 1.0]).validated().is_err());
        assert!(spec_with_shares(&[-0.5, 1.5]).validated().is_err());
        assert!(spec_with_shares(&[f64::NAN, 1.0]).validated().is_err());
        assert!(spec_with_shares(&[f64::INFINITY, 1.0]).validated().is_err());
    }

    #[test]
    #[should_panic(expected = "invalid WorkloadSpec")]
    fn generate_panics_on_negative_share() {
        spec_with_shares(&[-1.0, 2.0]).generate(&prompts(), 5, 1);
    }

    /// Un-normalized (but valid) shares still drive the advertised mix:
    /// generate normalizes internally, so 3:1 means 75% / 25%.
    #[test]
    fn generate_normalizes_unnormalized_shares() {
        let mut w = spec_with_shares(&[3.0, 1.0]);
        w.classes[1].budget = QosBudget::tight(100.0);
        let reqs = w.generate(&prompts(), 800, 11);
        let tight = reqs
            .iter()
            .filter(|(_, r)| r.qos.ms_per_token.is_finite())
            .count();
        let frac = tight as f64 / reqs.len() as f64;
        assert!((frac - 0.25).abs() < 0.06, "tight share {frac}");
    }

    /// Property: shares always sum to ~1 and every request gets a prompt
    /// from the pool.
    #[test]
    fn prompts_from_pool_property() {
        for_each_seed(10, |rng| {
            let w = WorkloadSpec::mixed(1.0 + rng.f64() * 30.0, 8);
            let ps = prompts();
            let reqs = w.generate(&ps, rng.range(1, 40), rng.next_u64());
            for (_, r) in reqs {
                assert!(ps.contains(&r.prompt));
            }
        });
    }
}
