//! Request queue + scheduling policies.
//!
//! The serving core interleaves active generations at token granularity
//! and batches compatible ones into shared device dispatches (see
//! `service::ServingCore` / `service::pick_batch`), so the queue's job is
//! *admission* order: FIFO for throughput studies, EDF (earliest deadline
//! first) when QoS deadlines differ across queries.  EDF is a binary heap
//! keyed on the absolute deadline instant with a FIFO tie-break sequence —
//! `pop` is O(log n), not the linear scan + `VecDeque::remove` it used to
//! be.  Admission re-runs before every dispatch, so a batch slot freed
//! by a request finishing mid-batch is refilled in time for the next
//! batched step — see `ServingCore::run` and the server executor.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::time::{Duration, Instant};

use super::qos::QosBudget;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub qos: QosBudget,
    /// Deadline for first token, ms from arrival (EDF key); None = best effort.
    pub deadline_ms: Option<f64>,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: impl Into<String>, max_new: usize,
               qos: QosBudget) -> Request {
        Request {
            id,
            prompt: prompt.into(),
            max_new,
            qos,
            deadline_ms: None,
            arrival: Instant::now(),
        }
    }

    pub fn with_deadline(mut self, ms_from_now: f64) -> Request {
        self.deadline_ms = Some(ms_from_now);
        self
    }

    /// Absolute deadline instant; None = best effort (sorts last).
    pub fn deadline_instant(&self) -> Option<Instant> {
        self.deadline_ms.map(|ms| {
            self.arrival + Duration::from_secs_f64(ms.max(0.0) / 1e3)
        })
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    Fifo,
    /// Earliest deadline first; best-effort requests run after all
    /// deadlined ones, FIFO among themselves.
    Edf,
}

/// EDF heap key: absolute deadline (None = +inf, i.e. best effort, runs
/// after every deadlined request), then the push sequence number so equal
/// deadlines — and all best-effort requests — pop FIFO.
#[derive(Debug)]
struct EdfEntry {
    deadline: Option<Instant>,
    seq: u64,
    req: Request,
}

impl EdfEntry {
    /// (is_best_effort, deadline, seq): best-effort sorts after any
    /// deadline; ties break on push order.
    fn key(&self) -> (bool, Option<Instant>, u64) {
        (self.deadline.is_none(), self.deadline, self.seq)
    }
}

impl PartialEq for EdfEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for EdfEntry {}
impl PartialOrd for EdfEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EdfEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

/// Admission queue.  Not thread-safe by itself — the serving engine wraps
/// it in a mutex; this keeps the policy logic testable in isolation.
#[derive(Debug)]
pub struct RequestQueue {
    policy: SchedPolicy,
    fifo: VecDeque<Request>,
    edf: BinaryHeap<Reverse<EdfEntry>>,
    seq: u64,
}

impl RequestQueue {
    pub fn new(policy: SchedPolicy) -> RequestQueue {
        RequestQueue {
            policy,
            fifo: VecDeque::new(),
            edf: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    pub fn push(&mut self, r: Request) {
        match self.policy {
            SchedPolicy::Fifo => self.fifo.push_back(r),
            SchedPolicy::Edf => {
                let entry = EdfEntry {
                    deadline: r.deadline_instant(),
                    seq: self.seq,
                    req: r,
                };
                self.seq += 1;
                self.edf.push(Reverse(entry));
            }
        }
    }

    pub fn len(&self) -> usize {
        match self.policy {
            SchedPolicy::Fifo => self.fifo.len(),
            SchedPolicy::Edf => self.edf.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Next request according to the policy.  O(1) for FIFO, O(log n) for
    /// EDF.
    pub fn pop(&mut self) -> Option<Request> {
        match self.policy {
            SchedPolicy::Fifo => self.fifo.pop_front(),
            SchedPolicy::Edf => self.edf.pop().map(|Reverse(e)| e.req),
        }
    }

    /// Earliest pending deadline, if any request has one.
    pub fn earliest_deadline(&self) -> Option<Instant> {
        match self.policy {
            SchedPolicy::Fifo => {
                self.fifo.iter().filter_map(|r| r.deadline_instant()).min()
            }
            SchedPolicy::Edf => {
                self.edf.peek().and_then(|Reverse(e)| e.deadline)
            }
        }
    }

    /// Queueing delay of the oldest waiting request, ms.
    pub fn oldest_wait_ms(&self) -> f64 {
        let waits = |r: &Request| r.arrival.elapsed().as_secs_f64() * 1e3;
        match self.policy {
            SchedPolicy::Fifo => self.fifo.iter().map(waits).fold(0.0, f64::max),
            SchedPolicy::Edf => {
                self.edf.iter().map(|Reverse(e)| waits(&e.req)).fold(0.0, f64::max)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::for_each_seed;

    fn req(id: u64, deadline: Option<f64>) -> Request {
        let r = Request::new(id, "x", 8, QosBudget::best_effort());
        match deadline {
            Some(d) => r.with_deadline(d),
            None => r,
        }
    }

    #[test]
    fn fifo_order() {
        let mut q = RequestQueue::new(SchedPolicy::Fifo);
        for i in 0..5 {
            q.push(req(i, None));
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn edf_prefers_tight_deadlines() {
        let mut q = RequestQueue::new(SchedPolicy::Edf);
        q.push(req(0, None));
        q.push(req(1, Some(500.0)));
        q.push(req(2, Some(100.0)));
        q.push(req(3, Some(300.0)));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![2, 3, 1, 0]);
    }

    #[test]
    fn edf_besteffort_fifo_among_themselves() {
        let mut q = RequestQueue::new(SchedPolicy::Edf);
        q.push(req(10, None));
        q.push(req(11, None));
        q.push(req(12, None));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![10, 11, 12]);
    }

    /// Equal deadlines must pop in push order (the FIFO tie-break the old
    /// linear scan guaranteed via index ordering; the heap guarantees it
    /// via the sequence number).
    #[test]
    fn edf_equal_deadlines_fifo_tiebreak() {
        let mut q = RequestQueue::new(SchedPolicy::Edf);
        // Share one Request template so the arrival instants (and thus the
        // absolute deadlines) are identical.
        let base = req(0, Some(250.0));
        for id in 0..6 {
            let mut r = base.clone();
            r.id = id;
            q.push(r);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }

    /// Property: every pushed request is popped exactly once (no loss, no
    /// duplication) under both policies.
    #[test]
    fn no_request_lost_property() {
        for_each_seed(30, |rng| {
            let policy = if rng.bool(0.5) { SchedPolicy::Fifo } else { SchedPolicy::Edf };
            let mut q = RequestQueue::new(policy);
            let n = rng.range(1, 40);
            let mut expect: Vec<u64> = (0..n as u64).collect();
            for i in 0..n as u64 {
                let dl = if rng.bool(0.5) { Some(rng.f64() * 1000.0) } else { None };
                q.push(req(i, dl));
            }
            let mut got: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect);
        });
    }

    /// Property: EDF pops in non-decreasing deadline order, best-effort
    /// strictly after all deadlined requests.
    #[test]
    fn edf_order_property() {
        for_each_seed(20, |rng| {
            let mut q = RequestQueue::new(SchedPolicy::Edf);
            let n = rng.range(2, 50);
            for i in 0..n as u64 {
                let dl = if rng.bool(0.3) { None } else { Some(rng.f64() * 5000.0) };
                q.push(req(i, dl));
            }
            let popped: Vec<Option<Instant>> =
                std::iter::from_fn(|| q.pop()).map(|r| r.deadline_instant()).collect();
            let mut seen_best_effort = false;
            let mut last: Option<Instant> = None;
            for d in popped {
                match d {
                    None => seen_best_effort = true,
                    Some(t) => {
                        assert!(!seen_best_effort, "deadlined after best-effort");
                        if let Some(prev) = last {
                            assert!(t >= prev, "deadline order violated");
                        }
                        last = Some(t);
                    }
                }
            }
        });
    }
}
