//! Request queue + scheduling policies.
//!
//! On-device serving decodes one request at a time (batch-1 GEMV is the
//! whole premise of weight-only quantization), so the scheduler's job is
//! admission order: FIFO for throughput studies, EDF (earliest deadline
//! first) when QoS deadlines differ across queries.

use std::collections::VecDeque;
use std::time::Instant;

use super::qos::QosBudget;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub qos: QosBudget,
    /// Absolute deadline for first token (EDF key); None = best effort.
    pub deadline_ms: Option<f64>,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: impl Into<String>, max_new: usize,
               qos: QosBudget) -> Request {
        Request {
            id,
            prompt: prompt.into(),
            max_new,
            qos,
            deadline_ms: None,
            arrival: Instant::now(),
        }
    }

    pub fn with_deadline(mut self, ms_from_now: f64) -> Request {
        self.deadline_ms = Some(ms_from_now);
        self
    }

    fn deadline_key(&self, now: Instant) -> f64 {
        match self.deadline_ms {
            Some(d) => d - now.duration_since(self.arrival).as_secs_f64() * 1e3,
            None => f64::INFINITY,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    Fifo,
    /// Earliest deadline first; best-effort requests run after all
    /// deadlined ones, FIFO among themselves.
    Edf,
}

/// Admission queue.  Not thread-safe by itself — the serving engine wraps
/// it in a mutex; this keeps the policy logic testable in isolation.
#[derive(Debug)]
pub struct RequestQueue {
    policy: SchedPolicy,
    items: VecDeque<Request>,
}

impl RequestQueue {
    pub fn new(policy: SchedPolicy) -> RequestQueue {
        RequestQueue { policy, items: VecDeque::new() }
    }

    pub fn push(&mut self, r: Request) {
        self.items.push_back(r);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Next request according to the policy.
    pub fn pop(&mut self) -> Option<Request> {
        match self.policy {
            SchedPolicy::Fifo => self.items.pop_front(),
            SchedPolicy::Edf => {
                let now = Instant::now();
                let best = self
                    .items
                    .iter()
                    .enumerate()
                    .min_by(|(ia, a), (ib, b)| {
                        a.deadline_key(now)
                            .partial_cmp(&b.deadline_key(now))
                            .unwrap()
                            .then(ia.cmp(ib)) // FIFO tie-break
                    })
                    .map(|(i, _)| i)?;
                self.items.remove(best)
            }
        }
    }

    /// Queueing delay of the oldest waiting request, ms.
    pub fn oldest_wait_ms(&self) -> f64 {
        self.items
            .iter()
            .map(|r| r.arrival.elapsed().as_secs_f64() * 1e3)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::for_each_seed;

    fn req(id: u64, deadline: Option<f64>) -> Request {
        let r = Request::new(id, "x", 8, QosBudget::best_effort());
        match deadline {
            Some(d) => r.with_deadline(d),
            None => r,
        }
    }

    #[test]
    fn fifo_order() {
        let mut q = RequestQueue::new(SchedPolicy::Fifo);
        for i in 0..5 {
            q.push(req(i, None));
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn edf_prefers_tight_deadlines() {
        let mut q = RequestQueue::new(SchedPolicy::Edf);
        q.push(req(0, None));
        q.push(req(1, Some(500.0)));
        q.push(req(2, Some(100.0)));
        q.push(req(3, Some(300.0)));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![2, 3, 1, 0]);
    }

    #[test]
    fn edf_besteffort_fifo_among_themselves() {
        let mut q = RequestQueue::new(SchedPolicy::Edf);
        q.push(req(10, None));
        q.push(req(11, None));
        q.push(req(12, None));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![10, 11, 12]);
    }

    /// Property: every pushed request is popped exactly once (no loss, no
    /// duplication) under both policies.
    #[test]
    fn no_request_lost_property() {
        for_each_seed(30, |rng| {
            let policy = if rng.bool(0.5) { SchedPolicy::Fifo } else { SchedPolicy::Edf };
            let mut q = RequestQueue::new(policy);
            let n = rng.range(1, 40);
            let mut expect: Vec<u64> = (0..n as u64).collect();
            for i in 0..n as u64 {
                let dl = if rng.bool(0.5) { Some(rng.f64() * 1000.0) } else { None };
                q.push(req(i, dl));
            }
            let mut got: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect);
        });
    }
}
