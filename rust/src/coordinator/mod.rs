//! L3 coordinator: the runtime-adaptation loop of Fig. 1.
//!
//! A query arrives with a QoS budget (a per-token latency target); system
//! utilization fluctuates; the *slack* that remains decides which member
//! of the adaptation set (target precisions 3.25..4.75 under the memory
//! budget) serves the query.  DP-LLM's contribution is that every member
//! is a *dynamic* configuration — per-layer precision keeps being chosen
//! token by token by the relative-error selector.

pub mod loadgen;
pub mod metrics;
pub mod sampler;
pub mod qos;
pub mod router;
pub mod sched;
pub mod workload;
pub mod service;

pub use loadgen::{ArrivalProcess, LengthDist, Trace, TraceReport, TraceSpec};
pub use qos::{AdaptationPolicy, QosBudget, UtilizationSim};
pub use router::{Router, RouterConfig, RouterCounters, RouterEvent};
pub use sched::{Request, RequestQueue, SchedPolicy};
pub use service::{BatchItem, CoreEvent, ServeOutcome, ServingCore, ServingEngine};
