//! HTTP-lite serving front-end on std::net (tokio is unavailable in the
//! offline sandbox; a hand-rolled HTTP/1.1 subset keeps the request path
//! entirely in Rust).
//!
//! Threading model: PJRT handles are `!Send` (FFI pointers), so the
//! [`ServingEngine`] lives on ONE executor thread; per-connection I/O
//! threads parse HTTP and exchange plain strings with the executor over
//! channels.  Model execution is serialized anyway — single device,
//! batch-1 decode — so this costs no throughput.
//!
//! Endpoints:
//!   POST /generate  {"prompt": str, "max_new"?: int, "qos_ms_per_token"?: f,
//!                    "target"?: f}  -> {"text", "target", "effective_bits",
//!                                       "tpot_ms", "output_tokens"}
//!   GET  /health    -> {"status": "ok", "targets": [...]}
//!   GET  /metrics   -> summary JSON

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::qos::{QosBudget, UtilizationSim};
use crate::coordinator::sched::Request;
use crate::coordinator::service::ServingEngine;
use crate::util::json::Json;

/// One parsed HTTP request handed to the executor thread.
struct Work {
    method: String,
    path: String,
    body: String,
    reply: Sender<String>,
}

pub struct Server {
    engine: ServingEngine,
    util: UtilizationSim,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(engine: ServingEngine, util: UtilizationSim) -> Server {
        Server { engine, util, stop: Arc::new(AtomicBool::new(false)) }
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve until the stop flag flips.
    pub fn serve(mut self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true)?;
        eprintln!("[server] listening on {addr}");
        let (tx, rx) = channel::<Work>();
        let stop = self.stop.clone();

        // Acceptor thread: sockets + HTTP parsing only (Send-safe).
        let acceptor = std::thread::spawn(move || {
            let mut next_id = 0u64;
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        next_id += 1;
                        let tx = tx.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, tx);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            drop(tx);
            let _ = next_id;
        });

        // Executor loop: owns the engine (and all !Send PJRT handles).
        let mut req_id = 0u64;
        loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(work) => {
                    req_id += 1;
                    let resp = self.dispatch(req_id, &work);
                    let _ = work.reply.send(resp);
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let _ = acceptor.join();
        Ok(())
    }

    fn dispatch(&mut self, id: u64, work: &Work) -> String {
        match (work.method.as_str(), work.path.as_str()) {
            ("GET", "/health") => {
                let mut j = Json::obj();
                j.set("status", "ok");
                j.set("targets", Json::Arr(
                    self.engine.targets().iter().map(|&t| Json::Num(t)).collect()));
                ok_json(&j)
            }
            ("GET", "/metrics") => {
                let s = self.engine.metrics.summary();
                let mut j = Json::obj();
                j.set("requests", s.n)
                    .set("mean_tpot_ms", s.mean_tpot_ms)
                    .set("p90_total_ms", s.p90_total_ms)
                    .set("p99_total_ms", s.p99_total_ms)
                    .set("mean_eff_bits", s.mean_eff_bits)
                    .set("p90_eff_bits", s.p90_eff_bits)
                    .set("p99_eff_bits", s.p99_eff_bits)
                    .set("throughput_tok_s", s.throughput_tok_s);
                ok_json(&j)
            }
            ("POST", "/generate") => match self.generate(id, &work.body) {
                Ok(j) => ok_json(&j),
                Err(e) => error_json(400, &format!("{e:#}")),
            },
            _ => error_json(404, "not found"),
        }
    }

    fn generate(&mut self, id: u64, body: &str) -> Result<Json> {
        let req_j = Json::parse(body).context("request body")?;
        let prompt = req_j.str_of("prompt")?;
        let max_new = req_j.get("max_new").and_then(|v| v.as_usize().ok()).unwrap_or(48);
        let qos = req_j
            .get("qos_ms_per_token")
            .and_then(|v| v.as_f64().ok())
            .map(QosBudget::tight)
            .unwrap_or_else(QosBudget::best_effort);
        let target = req_j.get("target").and_then(|v| v.as_f64().ok());
        let request = Request::new(id, prompt, max_new, qos);
        let u = self.util.tick();
        let outcome = match target {
            Some(t) => self.engine.handle_at(&request, t)?,
            None => self.engine.handle(&request, u)?,
        };
        let mut j = Json::obj();
        j.set("id", outcome.id as i64)
            .set("text", outcome.text.as_str())
            .set("target", outcome.target_precision)
            .set("effective_bits", outcome.effective_bits)
            .set("utilization", u)
            .set("prefill_ms", outcome.prefill_ms)
            .set("tpot_ms", outcome.decode_ms / outcome.output_tokens.max(1) as f64)
            .set("output_tokens", outcome.output_tokens);
        Ok(j)
    }
}

fn handle_conn(mut stream: TcpStream, tx: Sender<Work>) -> Result<()> {
    stream.set_nonblocking(false)?;
    let (method, path, body) = read_request(&mut stream)?;
    let (reply_tx, reply_rx) = channel();
    tx.send(Work { method, path, body, reply: reply_tx })
        .map_err(|_| anyhow::anyhow!("executor gone"))?;
    let resp = reply_rx
        .recv()
        .unwrap_or_else(|_| error_json(500, "executor dropped"));
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Minimal HTTP/1.1 plumbing.
// ---------------------------------------------------------------------------

fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line: {line:?}");
    }
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let t = h.trim();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn http_response(code: u32, reason: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

fn ok_json(j: &Json) -> String {
    http_response(200, "OK", &j.dump())
}

fn error_json(code: u32, msg: &str) -> String {
    let mut j = Json::obj();
    j.set("error", msg);
    http_response(code, "Error", &j.dump())
}

/// Tiny blocking HTTP client for examples / integration tests.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    read_response(stream)
}

pub fn http_get(addr: &str, path: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    read_response(stream)
}

fn read_response(stream: TcpStream) -> Result<Json> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some(v) = h.trim().to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    Json::parse(&String::from_utf8_lossy(&body)).context("response body")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_response_format() {
        let r = http_response(200, "OK", "{}");
        assert!(r.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(r.ends_with("\r\n\r\n{}"));
        assert!(r.contains("Content-Length: 2"));
    }

    #[test]
    fn error_body_is_json() {
        let r = error_json(404, "not found");
        let body = r.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.str_of("error").unwrap(), "not found");
    }

    #[test]
    fn request_parse_roundtrip() {
        // Exercise read_request via a local socketpair.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                b"POST /generate HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"prompt\":\"x\"}",
            )
            .unwrap();
            s
        });
        let (mut stream, _) = listener.accept().unwrap();
        let (m, p, b) = read_request(&mut stream).unwrap();
        assert_eq!(m, "POST");
        assert_eq!(p, "/generate");
        assert_eq!(b, "{\"prompt\":\"x\""); // 13 bytes of the 14-byte body
        let _ = t.join();
    }
}
