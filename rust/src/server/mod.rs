//! HTTP-lite serving front-end on std::net (tokio is unavailable in the
//! offline sandbox; a hand-rolled HTTP/1.1 subset keeps the request path
//! entirely in Rust).
//!
//! Threading model: PJRT handles are `!Send` (FFI pointers), so the
//! [`ServingEngine`] lives on ONE executor thread; per-connection I/O
//! threads parse HTTP and exchange plain strings with the executor over
//! channels.  The executor runs a [`ServingCore`]: concurrent `/generate`
//! requests are admitted mid-flight and interleaved **per token** (EDF
//! when a `deadline_ms` is given, FIFO tie-break otherwise), so a tight-
//! deadline request no longer waits behind a whole best-effort generation
//! — and requests decoding at the same target share batched device
//! dispatches (DESIGN.md §Batching), so concurrency costs ~1/B dispatch
//! overhead instead of scaling it linearly.  Prompt ingestion is
//! scheduled, not synchronous (DESIGN.md §Prefill): admission allocates
//! the slot and the core interleaves one prefill chunk per token round,
//! so a long prompt neither stalls active decodes nor caps at a prefill
//! bucket — and a rejected admission answers ITS connection only while
//! the loop keeps serving.  Rejections are classified (DESIGN.md
//! §Memory): a malformed request (empty tokenization, over-long prompt)
//! is a 400, while transient capacity pressure (core slots full, KV pool
//! exhausted) is a **503 with a `Retry-After` header** — the client did
//! nothing wrong and the same request succeeds once load drains.
//!
//! Endpoints:
//!   POST /generate  {"prompt": str, "max_new"?: int, "qos_ms_per_token"?: f,
//!                    "deadline_ms"?: f, "target"?: f}
//!                   -> {"text", "target", "effective_bits", "tpot_ms",
//!                       "ttft_ms", "retargets", "output_tokens"}
//!   GET  /health    -> {"status": "ok", "targets": [...]}
//!   GET  /metrics   -> summary JSON + a `counters` object (one
//!                      serialized snapshot of every runtime counter
//!                      family — transfers, weight cache, batching,
//!                      speculation, KV pool) + a `memory` object (the
//!                      combined weight-cache/KV byte report —
//!                      `coordinator::metrics::memory_json`) + a
//!                      `latency` object (per-SLO-class TTFT/ITL/queue
//!                      percentiles from the live log2 histograms)
//!   GET  /metrics?format=prometheus
//!                   -> the same counters as Prometheus text exposition
//!                      (gauges from every numeric leaf + native
//!                      histogram series per family × SLO class)
//!   GET  /trace     -> flight-recorder snapshot as Chrome trace-event
//!                      JSON (load into Perfetto / chrome://tracing);
//!                      non-destructive — the ring keeps recording
//!
//! Hardening: request bodies are capped at [`MAX_BODY_BYTES`]; a POST
//! without a parseable `Content-Length`, or with one over the cap, is
//! rejected with 413 *before* any allocation; wrong-method on a known
//! path returns 405 with an `Allow` header (404 is reserved for unknown
//! paths).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::qos::{QosBudget, UtilizationSim};
use crate::coordinator::router::{Router, RouterEvent};
use crate::coordinator::sched::{Request, RequestQueue, SchedPolicy};
use crate::coordinator::service::{
    is_capacity_reject, CoreConfig, CoreEvent, ServingCore, ServingEngine,
};
use crate::obs::{global_tracer, prom};
use crate::util::json::Json;

/// Hard cap on request-body size; larger Content-Lengths are rejected with
/// 413 before any buffer is allocated.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// One parsed HTTP request handed to the executor thread.
struct Work {
    method: String,
    path: String,
    body: String,
    reply: Sender<String>,
}

/// What a generate request is waiting on inside the executor.
struct Pending {
    reply: Sender<String>,
    utilization: f64,
    /// Target precision pinned by the client (bypasses the QoS policy and
    /// mid-stream re-selection).
    pinned: Option<f64>,
}

pub struct Server {
    engine: ServingEngine,
    util: UtilizationSim,
    /// Scheduling knobs for the executor's [`ServingCore`]; defaults to
    /// [`CoreConfig::from_env`], overridable via [`Server::with_core_config`]
    /// (the `serve` CLI plumbs `--reselect-every`/`--gamma-cap`/`--no-spec`).
    core_config: CoreConfig,
    stop: Arc<AtomicBool>,
    /// Write the flight-recorder trace (Chrome trace-event JSON) here on
    /// shutdown (`dpllm serve --trace-out`).
    trace_out: Option<std::path::PathBuf>,
}

impl Server {
    pub fn new(engine: ServingEngine, util: UtilizationSim) -> Server {
        Server {
            engine,
            util,
            core_config: CoreConfig::from_env(),
            stop: Arc::new(AtomicBool::new(false)),
            trace_out: None,
        }
    }

    pub fn with_core_config(mut self, config: CoreConfig) -> Server {
        self.core_config = config;
        self
    }

    /// Enable the global tracer and dump its ring to `path` on shutdown.
    pub fn with_trace_out(mut self, path: std::path::PathBuf) -> Server {
        global_tracer().set_enabled(true);
        self.trace_out = Some(path);
        self
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve until the stop flag flips.
    pub fn serve(self, addr: &str) -> Result<()> {
        let Server { engine, mut util, core_config, stop, trace_out } = self;
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true)?;
        crate::dpllm_log!(Info, "server", "listening on {addr}");
        let (tx, rx) = channel::<Work>();
        let acceptor = spawn_acceptor(listener, tx, stop.clone());

        // Executor loop: owns the engine (and all !Send PJRT handles) and a
        // token-interleaved ServingCore.  EDF so deadlined requests preempt
        // at token boundaries; best-effort requests FIFO among themselves.
        // Concurrent same-target requests share batched decode dispatches
        // (DESIGN.md §Batching).
        let mut core = ServingCore::new(&engine, SchedPolicy::Edf)
            .with_config(core_config);
        let mut queue = RequestQueue::new(SchedPolicy::Edf);
        let mut pending: HashMap<u64, Pending> = HashMap::new();
        let mut req_id = 0u64;
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            // Ingest: block briefly when idle, otherwise drain non-blocking
            // so decode steps keep flowing between arrivals.
            let idle = !core.has_active() && queue.is_empty();
            if idle {
                match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                    Ok(work) => {
                        req_id += 1;
                        ingest(&engine, &core, &mut queue, &mut pending,
                               &mut util, req_id, work);
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            drain_rx(&rx, &engine, &core, &mut queue, &mut pending, &mut util,
                     &mut req_id);

            // Admission: pull from the queue while slots are free.  Runs
            // before EVERY dispatch — in particular right after a step in
            // which a request finished mid-batch, so a freed slot is
            // refilled (from already-parsed arrivals drained above) in
            // time for the very next batched dispatch.
            admit_ready(&mut core, &mut queue, &mut pending, &mut util);
            // Mid-stream target re-selection on the token cadence
            // (epoch-based: a batched step advances the clock by its
            // occupancy, so exact multiples can be skipped over).
            if core.reselect_due() {
                let u = util.tick();
                core.reselect(u);
            }
            // One scheduling step: one token of every batch-compatible
            // runnable generation in a single dispatch.
            match core.step() {
                Ok(events) => {
                    for ev in events {
                        match ev {
                            CoreEvent::Done(o) => {
                                let u = pending
                                    .get(&o.id)
                                    .map(|p| p.utilization)
                                    .unwrap_or(0.0);
                                let body = ok_json(&outcome_json(&o, u));
                                respond(&mut pending, o.id, body);
                            }
                            CoreEvent::Failed { id, error } => {
                                respond(&mut pending, id, error_json(500, &error));
                            }
                            // Admission rejections surface as terminal
                            // per-id events when a queue drives the core
                            // (admit_from); this executor admits directly
                            // in admit_ready, so the arm is defensive.
                            CoreEvent::Error { id, error, capacity } => {
                                let body = reject_response(&error, capacity);
                                respond(&mut pending, id, body);
                            }
                            CoreEvent::Token { .. } => {}
                        }
                    }
                }
                Err(e) => {
                    crate::dpllm_log!(Warn, "server", "core step error: {e:#}")
                }
            }
        }
        let _ = acceptor.join();
        write_trace_out(trace_out.as_deref());
        Ok(())
    }
}

/// Dump the global flight recorder to `path` (Chrome trace-event JSON);
/// drains the ring, so the file holds everything still buffered.
fn write_trace_out(path: Option<&std::path::Path>) {
    let Some(path) = path else { return };
    let snap = global_tracer().drain();
    let n = snap.events.len();
    match std::fs::write(path, snap.chrome_json().dump()) {
        Ok(()) => crate::dpllm_log!(
            Info, "server",
            "wrote {n} trace events ({} dropped) to {}", snap.dropped,
            path.display()
        ),
        Err(e) => crate::dpllm_log!(
            Error, "server", "trace-out {} failed: {e}", path.display()
        ),
    }
}

/// Acceptor thread: sockets + HTTP parsing only (Send-safe); parsed
/// requests cross to the executor — single-engine [`Server`] or fleet
/// [`RouterServer`] — as [`Work`] over the channel.
fn spawn_acceptor(listener: TcpListener, tx: Sender<Work>,
                  stop: Arc<AtomicBool>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let tx = tx.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, tx);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
        drop(tx);
    })
}

/// Multi-replica front end (DESIGN.md §Scale-out): the same acceptor +
/// [`Work`] protocol as [`Server`], but the executor loop drives the
/// fleet [`Router`] — class routing, work stealing, capacity retries
/// and drain/respawn all happen here, while every decode loop runs on
/// its replica's own thread (PJRT handles never cross threads).
pub struct RouterServer {
    router: Router,
    stop: Arc<AtomicBool>,
    trace_out: Option<std::path::PathBuf>,
}

impl RouterServer {
    pub fn new(router: Router) -> RouterServer {
        RouterServer {
            router,
            stop: Arc::new(AtomicBool::new(false)),
            trace_out: None,
        }
    }

    /// Enable the global tracer and dump its ring to `path` on shutdown.
    pub fn with_trace_out(mut self, path: std::path::PathBuf) -> RouterServer {
        global_tracer().set_enabled(true);
        self.trace_out = Some(path);
        self
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve until the stop flag flips, then shut the fleet down.
    pub fn serve(self, addr: &str) -> Result<()> {
        let RouterServer { mut router, stop, trace_out } = self;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true)?;
        crate::dpllm_log!(Info, "router", "listening on {addr} ({} replicas)",
                          router.alive_count());
        let (tx, rx) = channel::<Work>();
        let acceptor = spawn_acceptor(listener, tx, stop.clone());
        let mut waiting: HashMap<u64, Sender<String>> = HashMap::new();
        let mut req_id = 0u64;
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            // Ingest: block briefly when nothing is pending so an idle
            // fleet costs no CPU; otherwise drain without blocking.
            if waiting.is_empty() {
                match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                    Ok(work) => {
                        req_id += 1;
                        ingest_routed(&mut router, &mut waiting, req_id, work);
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        break;
                    }
                }
            }
            while let Ok(work) = rx.try_recv() {
                req_id += 1;
                ingest_routed(&mut router, &mut waiting, req_id, work);
            }
            for ev in router.poll() {
                match ev {
                    RouterEvent::Done { replica, outcome } => {
                        let mut j = outcome_json(&outcome, 0.0);
                        j.set("replica", replica as i64);
                        if let Some(reply) = waiting.remove(&outcome.id) {
                            let _ = reply.send(ok_json(&j));
                        }
                    }
                    RouterEvent::Failed { id, error } => {
                        if let Some(reply) = waiting.remove(&id) {
                            let _ = reply.send(error_json(500, &error));
                        }
                    }
                    RouterEvent::Rejected { id, error, capacity } => {
                        if let Some(reply) = waiting.remove(&id) {
                            let _ = reply.send(reject_response(&error, capacity));
                        }
                    }
                    RouterEvent::Respawned { replica } => {
                        crate::dpllm_log!(
                            Warn, "router",
                            "replica {replica} drained and respawned"
                        );
                    }
                }
            }
            if !waiting.is_empty() || !router.idle() {
                // Replica work is asynchronous: poll at a token-ish
                // cadence instead of spinning.
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
        }
        router.shutdown();
        let _ = acceptor.join();
        write_trace_out(trace_out.as_deref());
        Ok(())
    }
}

/// [`ingest`]'s fleet twin: immediate endpoints answer from router
/// state; generate work routes to a replica and replies later from a
/// [`RouterEvent`].  Tokenization happens replica-side (the tokenizer
/// lives with each engine), so ingest screening here is byte-level
/// only — a prompt that tokenizes to nothing is still a per-request
/// 400 from replica admission, never more.
fn ingest_routed(router: &mut Router,
                 waiting: &mut HashMap<u64, Sender<String>>, id: u64,
                 work: Work) {
    let resp = match route(&work.method, &work.path) {
        Route::Health => {
            let mut j = Json::obj();
            j.set("status", "ok");
            j.set("targets", Json::Arr(
                router.targets().iter().map(|&t| Json::Num(t)).collect()));
            j.set("replicas_alive", router.alive_count() as i64);
            ok_json(&j)
        }
        Route::Metrics { prometheus } => {
            // Fleet-level metrics: `router_*` counters + the per-replica
            // `replicas` array (tier slice, queue depth, active slots,
            // tokens/s EWMA, steals, respawns) + per-class latency
            // percentiles.
            let j = router.metrics_json();
            if prometheus {
                let mut text = String::new();
                prom::flatten_object(&mut text, "", &j);
                if let Some(rows) =
                    j.get("replicas").and_then(|r| r.as_arr().ok())
                {
                    prom::replica_rows(&mut text, rows);
                }
                prom::histogram_set(&mut text, &router.histograms());
                ok_prometheus(&text)
            } else {
                ok_json(&j)
            }
        }
        Route::Trace => ok_json(&global_tracer().snapshot().chrome_json()),
        Route::Generate => match parse_generate(id, &work.body) {
            Ok((request, _)) if request.prompt.trim().is_empty() => {
                error_json(400, "empty prompt")
            }
            Ok((request, pinned)) => match router.submit(request, pinned) {
                None => {
                    waiting.insert(id, work.reply);
                    return; // replied later, from a RouterEvent
                }
                Some(RouterEvent::Rejected { error, capacity, .. }) => {
                    reject_response(&error, capacity)
                }
                Some(_) => error_json(500, "unexpected router event"),
            },
            Err(e) => error_json(400, &format!("{e:#}")),
        },
        Route::WrongMethod(allow) => {
            error_json_with(405, "Method Not Allowed",
                            &format!("method {} not allowed", work.method),
                            &[("Allow", allow)])
        }
        Route::NotFound => error_json(404, "not found"),
    };
    let _ = work.reply.send(resp);
}

/// Seconds a capacity-rejected client is told to wait before retrying.
/// Deliberately short: the pool drains at token cadence, so pressure
/// clears in tens to hundreds of milliseconds — 1s is the smallest
/// integral `Retry-After` value HTTP allows.
const RETRY_AFTER_SECS: &str = "1";

/// Status-mapped rejection body: malformed request → 400, transient
/// capacity pressure (core full / KV pool exhausted) → 503 with a
/// `Retry-After` header so well-behaved clients back off and retry.
fn reject_response(error: &str, capacity: bool) -> String {
    if capacity {
        error_json_with(503, "Service Unavailable", error,
                        &[("Retry-After", RETRY_AFTER_SECS)])
    } else {
        error_json(400, error)
    }
}

/// Pull queued requests into the core while it has free slots (pinned
/// targets bypass the QoS policy).  Admission is non-blocking (no
/// prefill runs inside it — the core's step() schedules the chunks), and
/// a rejection is terminal for THAT connection only — 400 to the waiting
/// client for a malformed request (over-long prompt past `max_seq`,
/// empty tokenization), 503 + `Retry-After` for capacity pressure —
/// while the executor loop and every in-flight generation keep serving.
fn admit_ready(core: &mut ServingCore<'_>, queue: &mut RequestQueue,
               pending: &mut HashMap<u64, Pending>, util: &mut UtilizationSim) {
    while core.has_capacity() && !queue.is_empty() {
        let Some(r) = queue.pop() else { break };
        let id = r.id;
        let u = util.tick();
        let mut pinned = None;
        if let Some(p) = pending.get_mut(&id) {
            p.utilization = u;
            pinned = p.pinned;
        }
        let admitted = match pinned {
            Some(t) => core.admit_pinned(r, t),
            None => core.admit(r, u),
        };
        if let Err(e) = admitted {
            let body = reject_response(&format!("{e:#}"), is_capacity_reject(&e));
            respond(pending, id, body);
        }
    }
}

fn drain_rx(rx: &Receiver<Work>, engine: &ServingEngine, core: &ServingCore<'_>,
            queue: &mut RequestQueue, pending: &mut HashMap<u64, Pending>,
            util: &mut UtilizationSim, req_id: &mut u64) {
    while let Ok(work) = rx.try_recv() {
        *req_id += 1;
        ingest(engine, core, queue, pending, util, *req_id, work);
    }
}

/// Classify one parsed request: answer immediate endpoints inline, enqueue
/// generate work, reject everything else with the right status code.
fn ingest(engine: &ServingEngine, core: &ServingCore<'_>,
          queue: &mut RequestQueue, pending: &mut HashMap<u64, Pending>,
          util: &mut UtilizationSim, id: u64, work: Work) {
    let resp = match route(&work.method, &work.path) {
        Route::Health => {
            let mut j = Json::obj();
            j.set("status", "ok");
            j.set("targets", Json::Arr(
                engine.targets().iter().map(|&t| Json::Num(t)).collect()));
            j.set("active", core.active_len() as i64)
                .set("queued", queue.len() as i64);
            ok_json(&j)
        }
        Route::Metrics { prometheus } => {
            let s = engine.metrics.summary();
            let mut j = Json::obj();
            j.set("requests", s.n)
                // How many retained records the p* fields cover — equal
                // to `requests` until the bounded ring wraps.
                .set("percentile_window", s.window as i64)
                .set("mean_tpot_ms", s.mean_tpot_ms)
                .set("p90_total_ms", s.p90_total_ms)
                .set("p99_total_ms", s.p99_total_ms)
                .set("mean_eff_bits", s.mean_eff_bits)
                .set("p90_eff_bits", s.p90_eff_bits)
                .set("p99_eff_bits", s.p99_eff_bits)
                .set("throughput_tok_s", s.throughput_tok_s)
                // Rate over the retained window's span — tracks recent
                // load where the lifetime figure dilutes across idle
                // gaps.
                .set("window_throughput_tok_s", s.window_throughput_tok_s)
                // One serialized snapshot of every runtime counter
                // family (transfers, weight cache, batching,
                // speculation, KV pool) — the shared serializer behind
                // the examples' reports too — plus the combined
                // device-memory report (weight cache + KV tiers +
                // cached prefixes vs their budgets).
                .set("counters", engine.counters_json())
                .set("memory", engine.memory_json());
            if prometheus {
                // Every numeric leaf above becomes a `dpllm_*` gauge;
                // the latency histograms export as native histogram
                // series rather than pre-baked percentile gauges.
                let mut text = String::new();
                prom::flatten_object(&mut text, "", &j);
                prom::histogram_set(&mut text, &engine.metrics.histograms());
                ok_prometheus(&text)
            } else {
                j.set("latency", engine.metrics.histograms().json());
                ok_json(&j)
            }
        }
        Route::Trace => ok_json(&global_tracer().snapshot().chrome_json()),
        Route::Generate => match parse_generate(id, &work.body) {
            // Cheap client-error screening at ingest; admission re-checks
            // and any later rejection is still per-connection (400), never
            // an executor abort.  Prompt LENGTH is not screened: chunked
            // prefill ingests any prompt up to the model's max_seq.
            Ok((request, _)) if engine.tokenizer.encode(&request.prompt)
                .is_empty() => error_json(400, "empty prompt"),
            Ok((request, pinned)) => {
                pending.insert(id, Pending {
                    reply: work.reply,
                    utilization: util.current(),
                    pinned,
                });
                queue.push(request);
                return; // replied later, from the core events
            }
            Err(e) => error_json(400, &format!("{e:#}")),
        },
        Route::WrongMethod(allow) => {
            error_json_with(405, "Method Not Allowed",
                            &format!("method {} not allowed", work.method),
                            &[("Allow", allow)])
        }
        Route::NotFound => error_json(404, "not found"),
    };
    let _ = work.reply.send(resp);
}

fn respond(pending: &mut HashMap<u64, Pending>, id: u64, body: String) {
    if let Some(p) = pending.remove(&id) {
        let _ = p.reply.send(body);
    }
}

fn parse_generate(id: u64, body: &str) -> Result<(Request, Option<f64>)> {
    let req_j = Json::parse(body).context("request body")?;
    let prompt = req_j.str_of("prompt")?;
    let max_new = req_j.get("max_new").and_then(|v| v.as_usize().ok()).unwrap_or(48);
    let qos = req_j
        .get("qos_ms_per_token")
        .and_then(|v| v.as_f64().ok())
        .map(QosBudget::tight)
        .unwrap_or_else(QosBudget::best_effort);
    let target = req_j.get("target").and_then(|v| v.as_f64().ok());
    let mut request = Request::new(id, prompt, max_new, qos);
    if let Some(d) = req_j.get("deadline_ms").and_then(|v| v.as_f64().ok()) {
        request = request.with_deadline(d);
    }
    Ok((request, target))
}

fn outcome_json(o: &crate::coordinator::service::ServeOutcome, u: f64) -> Json {
    let mut j = Json::obj();
    j.set("id", o.id as i64)
        .set("text", o.text.as_str())
        .set("target", o.target_precision)
        .set("effective_bits", o.effective_bits)
        .set("utilization", u)
        .set("prefill_ms", o.prefill_ms)
        .set("ttft_ms", o.ttft_ms)
        .set("tpot_ms", o.decode_ms / o.output_tokens.max(1) as f64)
        .set("retargets", o.retargets as i64)
        .set("output_tokens", o.output_tokens);
    j
}

// ---------------------------------------------------------------------------
// Routing.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Route {
    Health,
    Metrics {
        /// `?format=prometheus`: text exposition instead of JSON.
        prometheus: bool,
    },
    /// Flight-recorder snapshot as Chrome trace-event JSON.
    Trace,
    Generate,
    /// Known path, wrong method; payload = value for the `Allow` header.
    WrongMethod(&'static str),
    NotFound,
}

fn route(method: &str, path: &str) -> Route {
    // The query string selects representations (e.g. the Prometheus
    // exposition); it never changes which endpoint is addressed.
    let (path, query) = path.split_once('?').unwrap_or((path, ""));
    match (method, path) {
        ("GET", "/health") => Route::Health,
        ("GET", "/metrics") => Route::Metrics {
            prometheus: query.split('&').any(|kv| kv == "format=prometheus"),
        },
        ("GET", "/trace") => Route::Trace,
        ("POST", "/generate") => Route::Generate,
        (_, "/health") | (_, "/metrics") | (_, "/trace") => {
            Route::WrongMethod("GET")
        }
        (_, "/generate") => Route::WrongMethod("POST"),
        _ => Route::NotFound,
    }
}

fn handle_conn(mut stream: TcpStream, tx: Sender<Work>) -> Result<()> {
    stream.set_nonblocking(false)?;
    let (method, path, body) = match read_request(&mut stream)? {
        Parsed::Req { method, path, body } => (method, path, body),
        Parsed::Reject { code, reason, msg } => {
            let resp = error_json_with(code, reason, &msg, &[]);
            stream.write_all(resp.as_bytes())?;
            return Ok(());
        }
    };
    let (reply_tx, reply_rx) = channel();
    tx.send(Work { method, path, body, reply: reply_tx })
        .map_err(|_| anyhow::anyhow!("executor gone"))?;
    let resp = reply_rx
        .recv()
        .unwrap_or_else(|_| error_json(500, "executor dropped"));
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Minimal HTTP/1.1 plumbing.
// ---------------------------------------------------------------------------

/// Outcome of parsing one request off the wire.
enum Parsed {
    Req { method: String, path: String, body: String },
    /// Reject before touching the executor (and before allocating a body
    /// buffer): malformed line, missing/oversized Content-Length.
    Reject { code: u32, reason: &'static str, msg: String },
}

fn read_request(stream: &mut TcpStream) -> Result<Parsed> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Ok(Parsed::Reject {
            code: 400,
            reason: "Bad Request",
            msg: format!("malformed request line: {line:?}"),
        });
    }
    let mut content_len: Option<usize> = None;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let t = h.trim();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().ok();
        }
    }
    let content_len = match (method.as_str(), content_len) {
        // Bodyless methods may omit the header entirely.
        ("GET", None) | ("HEAD", None) | ("DELETE", None) => 0,
        // A body-bearing request MUST declare a parseable length — we
        // never allocate from an unbounded/undeclared body.
        (_, None) => {
            return Ok(Parsed::Reject {
                code: 413,
                reason: "Payload Too Large",
                msg: "missing or unparseable Content-Length".into(),
            })
        }
        (_, Some(n)) if n > MAX_BODY_BYTES => {
            return Ok(Parsed::Reject {
                code: 413,
                reason: "Payload Too Large",
                msg: format!("Content-Length {n} exceeds cap {MAX_BODY_BYTES}"),
            })
        }
        (_, Some(n)) => n,
    };
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Parsed::Req {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

fn http_response(code: u32, reason: &str, body: &str) -> String {
    http_response_with(code, reason, body, &[])
}

fn http_response_with(code: u32, reason: &str, body: &str,
                      extra_headers: &[(&str, &str)]) -> String {
    http_response_typed(code, reason, "application/json", body, extra_headers)
}

fn http_response_typed(code: u32, reason: &str, content_type: &str,
                       body: &str, extra_headers: &[(&str, &str)]) -> String {
    let mut headers = String::new();
    for (k, v) in extra_headers {
        headers.push_str(&format!("{k}: {v}\r\n"));
    }
    format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\n\
         {headers}Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

fn ok_json(j: &Json) -> String {
    http_response(200, "OK", &j.dump())
}

/// Prometheus text exposition (`GET /metrics?format=prometheus`).
fn ok_prometheus(text: &str) -> String {
    http_response_typed(200, "OK",
                        "text/plain; version=0.0.4; charset=utf-8", text, &[])
}

fn error_json(code: u32, msg: &str) -> String {
    error_json_with(code, "Error", msg, &[])
}

fn error_json_with(code: u32, reason: &str, msg: &str,
                   extra_headers: &[(&str, &str)]) -> String {
    let mut j = Json::obj();
    j.set("error", msg);
    http_response_with(code, reason, &j.dump(), extra_headers)
}

/// Tiny blocking HTTP client for examples / integration tests.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    Json::parse(&read_response(stream)?).context("response body")
}

pub fn http_get(addr: &str, path: &str) -> Result<Json> {
    Json::parse(&http_get_text(addr, path)?).context("response body")
}

/// `http_get` without the JSON parse — for non-JSON representations
/// (the Prometheus exposition).
pub fn http_get_text(addr: &str, path: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    read_response(stream)
}

fn read_response(stream: TcpStream) -> Result<String> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some(v) = h.trim().to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    Ok(String::from_utf8_lossy(&body).into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_response_format() {
        let r = http_response(200, "OK", "{}");
        assert!(r.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(r.ends_with("\r\n\r\n{}"));
        assert!(r.contains("Content-Length: 2"));
    }

    #[test]
    fn error_body_is_json() {
        let r = error_json(404, "not found");
        let body = r.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.str_of("error").unwrap(), "not found");
    }

    #[test]
    fn routing_known_paths_and_methods() {
        assert_eq!(route("GET", "/health"), Route::Health);
        assert_eq!(route("GET", "/metrics"),
                   Route::Metrics { prometheus: false });
        assert_eq!(route("GET", "/trace"), Route::Trace);
        assert_eq!(route("POST", "/generate"), Route::Generate);
        // The query string selects a representation, never a route.
        assert_eq!(route("GET", "/metrics?format=prometheus"),
                   Route::Metrics { prometheus: true });
        assert_eq!(route("GET", "/metrics?format=json"),
                   Route::Metrics { prometheus: false });
        assert_eq!(route("GET", "/metrics?x=1&format=prometheus"),
                   Route::Metrics { prometheus: true });
        assert_eq!(route("GET", "/health?anything"), Route::Health);
        // Wrong method on a known path -> 405 with the right Allow value.
        assert_eq!(route("POST", "/health"), Route::WrongMethod("GET"));
        assert_eq!(route("DELETE", "/metrics"), Route::WrongMethod("GET"));
        assert_eq!(route("POST", "/trace"), Route::WrongMethod("GET"));
        assert_eq!(route("GET", "/generate"), Route::WrongMethod("POST"));
        // Unknown path -> 404.
        assert_eq!(route("GET", "/nope"), Route::NotFound);
        assert_eq!(route("POST", "/admin"), Route::NotFound);
    }

    #[test]
    fn wrong_method_response_carries_allow_header() {
        let r = error_json_with(405, "Method Not Allowed", "nope",
                                &[("Allow", "POST")]);
        assert!(r.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"));
        assert!(r.contains("Allow: POST\r\n"));
    }

    #[test]
    fn capacity_reject_is_503_with_retry_after_invalid_is_400() {
        // Capacity pressure: the client did nothing wrong — retryable.
        let r = reject_response("core at capacity (4 slots)", true);
        assert!(r.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(r.contains("Retry-After: 1\r\n"));
        let body = r.split("\r\n\r\n").nth(1).unwrap();
        assert!(Json::parse(body).unwrap().str_of("error").unwrap()
            .contains("capacity"));
        // Malformed request: same request will never succeed — 400, no
        // Retry-After.
        let r = reject_response("empty prompt", false);
        assert!(r.starts_with("HTTP/1.1 400 Error\r\n"));
        assert!(!r.contains("Retry-After"));
    }

    fn roundtrip(raw: &[u8]) -> Parsed {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let t = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
            s
        });
        let (mut stream, _) = listener.accept().unwrap();
        let p = read_request(&mut stream).unwrap();
        let _ = t.join();
        p
    }

    #[test]
    fn request_parse_roundtrip() {
        match roundtrip(
            b"POST /generate HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"prompt\":\"x\"}",
        ) {
            Parsed::Req { method, path, body } => {
                assert_eq!(method, "POST");
                assert_eq!(path, "/generate");
                assert_eq!(body, "{\"prompt\":\"x\""); // 13 of the 14 bytes
            }
            Parsed::Reject { .. } => panic!("expected parse"),
        }
    }

    #[test]
    fn post_without_content_length_is_413() {
        match roundtrip(b"POST /generate HTTP/1.1\r\n\r\n") {
            Parsed::Reject { code, .. } => assert_eq!(code, 413),
            Parsed::Req { .. } => panic!("expected reject"),
        }
    }

    #[test]
    fn oversized_content_length_is_413_without_allocating() {
        // 8 GiB declared; must reject from the header alone.
        let raw = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            8usize << 30
        );
        match roundtrip(raw.as_bytes()) {
            Parsed::Reject { code, msg, .. } => {
                assert_eq!(code, 413);
                assert!(msg.contains("exceeds cap"));
            }
            Parsed::Req { .. } => panic!("expected reject"),
        }
    }

    #[test]
    fn get_without_content_length_still_parses() {
        match roundtrip(b"GET /health HTTP/1.1\r\n\r\n") {
            Parsed::Req { method, path, body } => {
                assert_eq!(method, "GET");
                assert_eq!(path, "/health");
                assert!(body.is_empty());
            }
            Parsed::Reject { .. } => panic!("expected parse"),
        }
    }

    #[test]
    fn malformed_request_line_is_400() {
        match roundtrip(b"\r\n\r\n") {
            Parsed::Reject { code, .. } => assert_eq!(code, 400),
            Parsed::Req { .. } => panic!("expected reject"),
        }
    }

    /// Hermetic end-to-end pass through the router executor: sim replicas
    /// (no model artifacts) behind a real TCP listener, driven by the same
    /// `http_post`/`http_get` clients the integration tests use.
    #[test]
    fn router_server_end_to_end_over_sim_replicas() {
        use crate::coordinator::router::RouterConfig;
        use crate::runtime::replica::sim::{sim_link, SimProfile};
        use crate::runtime::replica::ReplicaSpec;

        // The flight recorder is off by default; /trace assertions below
        // need it live.  Enabling is sticky and harmless to other tests
        // (they use local Tracer instances or ignore the global one).
        global_tracer().set_enabled(true);

        let specs = vec![
            ReplicaSpec::sim(0, &["3.25", "3.50"], false, 1.0),
            ReplicaSpec::sim(1, &["4.50", "4.75"], true, 2.0),
        ];
        let router = Router::new(
            specs,
            Box::new(|spec| {
                sim_link(
                    spec,
                    SimProfile {
                        token_us: 50,
                        ..SimProfile::default()
                    },
                )
            }),
            RouterConfig::default(),
        );
        let server = RouterServer::new(router);
        let stop = server.stop_handle();
        let addr = "127.0.0.1:18091";
        let handle = std::thread::spawn(move || server.serve(addr));
        std::thread::sleep(std::time::Duration::from_millis(50));

        let r = http_post(addr, "/generate", r#"{"prompt":"hello world","max_new":4}"#)
            .expect("generate roundtrip");
        assert_eq!(r.f64_of("output_tokens").unwrap(), 4.0);
        // Economy request (no deadline, no per-token budget) lands on the
        // low-bit tier; the executor stamps which replica served it.
        assert!(r.f64_of("replica").is_ok());
        assert!(r.f64_of("target").unwrap() <= 3.5);

        let h = http_get(addr, "/health").expect("health roundtrip");
        assert_eq!(h.f64_of("replicas_alive").unwrap(), 2.0);

        let m = http_get(addr, "/metrics").expect("metrics roundtrip");
        let rows = m.get("replicas").expect("replicas key").as_arr().expect("fleet rows");
        assert_eq!(rows.len(), 2);
        assert!(m.f64_of("router_routed_economy").unwrap() >= 1.0);
        // The completed request landed in the economy latency histogram.
        let lat = m.get("latency").expect("latency key");
        assert!(lat.get("economy").unwrap().f64_of("n").unwrap() >= 1.0);

        // Prometheus representation of the same state: parses line by
        // line and carries both the flattened counters and the native
        // histogram series.
        let text = http_get_text(addr, "/metrics?format=prometheus")
            .expect("prometheus scrape");
        crate::obs::prom::validate(&text).expect("valid exposition");
        assert!(text.contains("dpllm_router_routed_economy"));
        assert!(text.contains("dpllm_replica_done{"));
        assert!(text.contains("dpllm_ttft_ms_bucket{"));

        // Flight recorder: the scrape is valid Chrome trace JSON; the
        // routed request left route→forward lifecycle events.
        let t = http_get(addr, "/trace").expect("trace scrape");
        let events = t.get("traceEvents").expect("traceEvents").as_arr().unwrap();
        assert!(!events.is_empty());
        let names: Vec<String> = events
            .iter()
            .filter_map(|e| e.str_of("name").ok())
            .collect();
        assert!(names.iter().any(|n| n == "route"), "no route event traced");
        assert!(names.iter().any(|n| n == "forward"),
                "no forward event traced");

        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().unwrap().unwrap();
    }
}
