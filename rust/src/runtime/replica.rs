//! Replica workers for multi-replica serving (DESIGN.md §Scale-out).
//!
//! A replica is ONE thread running its own [`ServingCore`] over its own
//! [`Runtime`]: PJRT handles are `!Send`, so nothing device-resident
//! ever crosses a thread boundary — replicas exchange only plain-data
//! [`ReplicaCommand`]/[`ReplicaEvent`] messages with the front-of-house
//! router ([`crate::coordinator::router`]) over mpsc channels.  What
//! *is* shared is the parsed packed store: every replica engine holds
//! the same `Arc<ModelAssets>` (and through it the same
//! `Arc<AnyPrecStore>`), so N replicas parse the weights once and each
//! materializes only the slice of the precision ladder its tier serves.
//! Device-side caches (weight slabs, KV pool) stay per-replica — PJRT
//! buffers belong to one client.
//!
//! Fault isolation is the PR 5 story made fleet-wide: a panic anywhere
//! in the worker trips [`PanicGuard`] (its `Drop` runs during
//! unwinding) and surfaces as [`ReplicaEvent::Died`], a wedged worker
//! simply stops heartbeating, and either way the router drains and
//! respawns the replica without operator action.
//!
//! The [`sim`] submodule provides timing-faithful simulated workers
//! that speak the identical protocol, so the router's steal/drain/
//! respawn logic is exercised hermetically by unit tests and the
//! artifact-free `router_micro` bench.

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::qos::UtilizationSim;
use crate::coordinator::sched::{Request, RequestQueue, SchedPolicy};
use crate::coordinator::service::{is_capacity_reject, CoreConfig, CoreEvent,
                                  ServeOutcome, ServingCore, ServingEngine};
use crate::model::ModelAssets;
use crate::runtime::Runtime;

/// Everything needed to (re)spawn one replica worker.  Plain data: the
/// router keeps it and hands it back to the spawn function on respawn,
/// so a replica always comes back with its original tier slice.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub id: usize,
    /// Model name for [`ServingEngine::load_shared`].
    pub model: String,
    /// Per-layer bit budget (same meaning as the single-engine path).
    pub budget: u32,
    /// The slice of the precision ladder this replica materializes
    /// (adaptation-set tags, e.g. `["3.25", "3.50"]`).
    pub tags: Vec<String>,
    /// Parsed numeric targets of `tags` (router-side pin clamping).
    pub targets: Vec<f64>,
    /// Premium replicas take tight-SLO traffic; economy replicas take
    /// best-effort traffic (class→tier mapping, DESIGN.md §Scale-out).
    pub premium: bool,
    /// Modeled per-token latency of this replica's cheapest target
    /// (`costmodel` stream time) — the router's expected-delay unit.
    pub tpot_ms: f64,
    pub core: CoreConfig,
    /// Heartbeat cadence; the router declares a replica wedged after
    /// missing several of these.
    pub heartbeat_ms: u64,
}

impl ReplicaSpec {
    /// A spec for simulated workers ([`sim`]) — no artifacts involved.
    pub fn sim(id: usize, tags: &[&str], premium: bool, tpot_ms: f64)
               -> ReplicaSpec {
        ReplicaSpec {
            id,
            model: "sim".to_string(),
            budget: 0,
            tags: tags.iter().map(|t| t.to_string()).collect(),
            targets: tags.iter().filter_map(|t| t.parse().ok()).collect(),
            premium,
            tpot_ms,
            core: CoreConfig::default(),
            heartbeat_ms: 10,
        }
    }
}

/// Router → replica.
pub enum ReplicaCommand {
    /// Serve one request; `pinned` fixes the target precision (already
    /// clamped to this replica's tier slice by the router).
    Submit { req: Request, pinned: Option<f64> },
    /// Finish the active set, then exit cleanly with
    /// [`ReplicaEvent::Stopped`].
    Shutdown,
}

/// Replica → router.  Plain data only.
pub enum ReplicaEvent {
    /// Engine loaded; the replica is accepting work.  Carries the
    /// spawn→ready wall time (runtime init + session builds + TPOT
    /// calibration) so the router can surface per-replica cold-start
    /// cost in `/metrics` and the flight recorder.
    Ready { cold_start_ms: f64 },
    /// Periodic liveness + load signal.
    Heartbeat(ReplicaHealth),
    /// A request finished (terminal).
    Done(ServeOutcome),
    /// A request aborted mid-flight (terminal; replica keeps serving).
    Failed { id: u64, error: String },
    /// Admission rejected `id` — `capacity: true` is retryable
    /// (slot cap / KV pool), `false` is malformed (terminal 400).
    Error { id: u64, error: String, capacity: bool },
    /// Clean exit after [`ReplicaCommand::Shutdown`].
    Stopped,
    /// The worker is gone: load failure or panic (via [`PanicGuard`]).
    Died { error: String },
}

/// Load snapshot carried by [`ReplicaEvent::Heartbeat`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReplicaHealth {
    /// Requests accepted but not yet admitted to the core.
    pub queued: usize,
    /// Active generation slots.
    pub active: usize,
    /// Decode throughput EWMA.
    pub tokens_per_s: f64,
}

/// One replica's channel endpoints as the router sees them.
pub struct ReplicaLink {
    pub tx: Sender<ReplicaCommand>,
    pub rx: Receiver<ReplicaEvent>,
    /// `None` for workers the router abandoned (wedged threads cannot
    /// be joined — they are replaced, not reaped).
    pub join: Option<JoinHandle<()>>,
}

/// Sends [`ReplicaEvent::Died`] from `Drop` unless disarmed — `Drop`
/// runs during unwinding, so a panic anywhere in the worker body turns
/// into a protocol event instead of a silently dropped channel.
struct PanicGuard {
    tx: Sender<ReplicaEvent>,
    armed: bool,
}

impl PanicGuard {
    fn new(tx: Sender<ReplicaEvent>) -> PanicGuard {
        PanicGuard { tx, armed: true }
    }

    fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for PanicGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.tx.send(ReplicaEvent::Died {
                error: "replica thread terminated unexpectedly (panic)"
                    .to_string(),
            });
        }
    }
}

/// Tracks the heartbeat cadence and the decode-rate EWMA; shared by the
/// engine-backed and simulated workers so both report comparable
/// `tokens_per_s`.
struct HeartbeatClock {
    every: Duration,
    last: Instant,
    last_tokens: u64,
    ewma: Option<f64>,
}

impl HeartbeatClock {
    fn new(every_ms: u64) -> HeartbeatClock {
        HeartbeatClock {
            every: Duration::from_millis(every_ms.max(1)),
            last: Instant::now(),
            last_tokens: 0,
            ewma: None,
        }
    }

    /// When a beat is due, fold the window's token rate into the EWMA
    /// and return it; `None` between beats.
    fn tick(&mut self, tokens_total: u64) -> Option<f64> {
        let dt = self.last.elapsed();
        if dt < self.every {
            return None;
        }
        let inst = (tokens_total - self.last_tokens) as f64
            / dt.as_secs_f64().max(1e-9);
        let ewma = match self.ewma {
            Some(prev) => 0.3 * inst + 0.7 * prev,
            None => inst,
        };
        self.ewma = Some(ewma);
        self.last = Instant::now();
        self.last_tokens = tokens_total;
        Some(ewma)
    }
}

/// Build the channel pair and spawn an engine-backed replica worker.
pub fn engine_link(spec: &ReplicaSpec, assets: Arc<ModelAssets>)
                   -> ReplicaLink {
    let (cmd_tx, cmd_rx) = mpsc::channel();
    let (ev_tx, ev_rx) = mpsc::channel();
    let join = spawn_engine_replica(spec.clone(), assets, cmd_rx, ev_tx);
    ReplicaLink { tx: cmd_tx, rx: ev_rx, join: Some(join) }
}

/// Spawn one real replica: its own `Runtime` (PJRT client) and
/// `ServingCore`, an engine over the shared assets materializing only
/// `spec.tags`, and the command/event loop.
pub fn spawn_engine_replica(
    spec: ReplicaSpec,
    assets: Arc<ModelAssets>,
    rx: Receiver<ReplicaCommand>,
    tx: Sender<ReplicaEvent>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("replica-{}", spec.id))
        .spawn(move || run_engine_replica(spec, assets, rx, tx))
        .expect("spawn replica thread")
}

fn run_engine_replica(
    spec: ReplicaSpec,
    assets: Arc<ModelAssets>,
    rx: Receiver<ReplicaCommand>,
    tx: Sender<ReplicaEvent>,
) {
    let mut guard = PanicGuard::new(tx.clone());
    let t0 = Instant::now();
    let rt = match Runtime::new() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            guard.disarm();
            let _ = tx.send(ReplicaEvent::Died {
                error: format!("replica {}: runtime: {e:#}", spec.id),
            });
            return;
        }
    };
    let tags: Vec<&str> = spec.tags.iter().map(String::as_str).collect();
    let engine =
        match ServingEngine::load_shared(&rt, assets, spec.budget, &tags) {
            Ok(e) => e,
            Err(e) => {
                guard.disarm();
                let _ = tx.send(ReplicaEvent::Died {
                    error: format!("replica {}: load: {e:#}", spec.id),
                });
                return;
            }
        };
    let mut core =
        ServingCore::new(&engine, SchedPolicy::Edf).with_config(spec.core.clone());
    let mut queue = RequestQueue::new(SchedPolicy::Edf);
    let mut pinned: HashMap<u64, f64> = HashMap::new();
    let mut util = UtilizationSim::new(spec.id as u64 * 7919 + 13, 0.5);
    let mut hb = HeartbeatClock::new(spec.heartbeat_ms);
    let mut tokens_total = 0u64;
    let _ = tx.send(ReplicaEvent::Ready {
        cold_start_ms: t0.elapsed().as_secs_f64() * 1e3,
    });
    loop {
        // Ingest commands.  Block briefly only when fully idle, so an
        // idle replica still heartbeats instead of looking wedged.
        let mut shutdown = false;
        loop {
            let busy = core.has_active() || !queue.is_empty();
            let cmd = if busy {
                match rx.try_recv() {
                    Ok(c) => Some(c),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                let wait = Duration::from_millis(spec.heartbeat_ms.max(2) / 2);
                match rx.recv_timeout(wait) {
                    Ok(c) => Some(c),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            };
            match cmd {
                Some(ReplicaCommand::Submit { req, pinned: p }) => {
                    if let Some(t) = p {
                        pinned.insert(req.id, t);
                    }
                    queue.push(req);
                }
                Some(ReplicaCommand::Shutdown) => {
                    shutdown = true;
                    break;
                }
                None => break,
            }
        }
        if shutdown {
            // Finish the active set (no further admission), then exit
            // cleanly.  The router may already be gone (channel drop);
            // sends are best-effort.
            let outs = core.drain(&mut |ev| match ev {
                CoreEvent::Failed { id, error } => {
                    let _ = tx.send(ReplicaEvent::Failed {
                        id: *id,
                        error: error.clone(),
                    });
                }
                CoreEvent::Error { id, error, capacity } => {
                    let _ = tx.send(ReplicaEvent::Error {
                        id: *id,
                        error: error.clone(),
                        capacity: *capacity,
                    });
                }
                _ => {}
            });
            if let Ok(outs) = outs {
                for o in outs {
                    let _ = tx.send(ReplicaEvent::Done(o));
                }
            }
            guard.disarm();
            let _ = tx.send(ReplicaEvent::Stopped);
            return;
        }
        // Admit while there is capacity.  Pinned requests go straight to
        // their (tier-clamped) target; the rest ride the QoS policy.  A
        // rejected admission is terminal for that id only (PR 5).
        while core.has_capacity() && !queue.is_empty() {
            let Some(r) = queue.pop() else { break };
            let id = r.id;
            let res = match pinned.remove(&id) {
                Some(t) => core.admit_pinned(r, t),
                None => core.admit(r, util.tick()),
            };
            if let Err(e) = res {
                let capacity = is_capacity_reject(&e);
                let _ = tx.send(ReplicaEvent::Error {
                    id,
                    error: format!("{e:#}"),
                    capacity,
                });
            }
        }
        if core.has_active() {
            match core.step() {
                Ok(events) => {
                    for ev in events {
                        match ev {
                            CoreEvent::Token { .. } => tokens_total += 1,
                            CoreEvent::Done(o) => {
                                let _ = tx.send(ReplicaEvent::Done(o));
                            }
                            CoreEvent::Failed { id, error } => {
                                let _ = tx.send(ReplicaEvent::Failed {
                                    id, error,
                                });
                            }
                            CoreEvent::Error { id, error, capacity } => {
                                let _ = tx.send(ReplicaEvent::Error {
                                    id, error, capacity,
                                });
                            }
                        }
                    }
                }
                Err(e) => {
                    // Loop-level error: the PR 5 contract says keep
                    // serving — per-request failures already surfaced
                    // as events above.
                    crate::dpllm_log!(Warn, "replica",
                                      "[replica {}] step error: {e:#}", spec.id);
                }
            }
        }
        if let Some(rate) = hb.tick(tokens_total) {
            let _ = tx.send(ReplicaEvent::Heartbeat(ReplicaHealth {
                queued: queue.len(),
                active: core.active_len(),
                tokens_per_s: rate,
            }));
        }
    }
}

/// Simulated replica workers: the same channel protocol and the same
/// token-interleaved serving discipline as the engine-backed worker
/// (one round advances every active generation by one token), with a
/// configurable per-token cost and injectable faults — so the router,
/// its tests, and the `router_micro` bench exercise the REAL
/// routing/steal/drain/respawn logic without artifacts.
pub mod sim {
    use super::*;

    /// Timing + fault profile of one simulated replica.
    #[derive(Debug, Clone)]
    pub struct SimProfile {
        /// Simulated per-token service time (one interleaved round).
        pub token_us: u64,
        /// Active-generation slots (the sim's `max_active`).
        pub slots: usize,
        /// Panic once this many tokens have been produced (chaos:
        /// exercises [`PanicGuard`] → `Died` → drain/respawn).
        pub panic_after_tokens: Option<u64>,
        /// Go silent (no events, no heartbeats) once this many tokens
        /// have been produced — a wedged worker, detected only by
        /// heartbeat timeout.
        pub mute_after_tokens: Option<u64>,
        /// Answer the first `Submit` with a capacity reject
        /// (`PoolExhausted`-shaped) — exercises the router's
        /// retry-on-sibling path.
        pub reject_first: bool,
        /// Admission screening like the engine core's: an empty prompt,
        /// or one longer than this many chars, answers with a terminal
        /// invalid reject (`capacity: false`, the 400 shape the core's
        /// `admit_rejects_invalid` counter tracks).  `None` admits
        /// anything — the pre-chaos default.
        pub max_prompt_chars: Option<usize>,
    }

    impl Default for SimProfile {
        fn default() -> SimProfile {
            SimProfile {
                token_us: 200,
                slots: 4,
                panic_after_tokens: None,
                mute_after_tokens: None,
                reject_first: false,
                max_prompt_chars: None,
            }
        }
    }

    /// Build the channel pair and spawn a simulated worker.
    pub fn sim_link(spec: &ReplicaSpec, profile: SimProfile) -> ReplicaLink {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (ev_tx, ev_rx) = mpsc::channel();
        let join = spawn_sim_replica(spec.clone(), profile, cmd_rx, ev_tx);
        ReplicaLink { tx: cmd_tx, rx: ev_rx, join: Some(join) }
    }

    pub fn spawn_sim_replica(
        spec: ReplicaSpec,
        profile: SimProfile,
        rx: Receiver<ReplicaCommand>,
        tx: Sender<ReplicaEvent>,
    ) -> JoinHandle<()> {
        std::thread::Builder::new()
            .name(format!("sim-replica-{}", spec.id))
            .spawn(move || run_sim_replica(spec, profile, rx, tx))
            .expect("spawn sim replica thread")
    }

    /// An in-flight simulated generation.
    struct SimGen {
        req: Request,
        target: f64,
        produced: usize,
        /// Arrival → admission wait, ms (queue delay component of TTFT).
        wait_ms: f64,
    }

    fn outcome(g: &SimGen, token_us: u64) -> ServeOutcome {
        let per_tok_ms = token_us as f64 / 1e3;
        ServeOutcome {
            id: g.req.id,
            text: String::new(),
            target_precision: g.target,
            effective_bits: g.target,
            prefill_ms: per_tok_ms,
            decode_ms: per_tok_ms * g.produced as f64,
            ttft_ms: g.wait_ms + per_tok_ms,
            output_tokens: g.produced,
            prefill_chunks: 1,
            retargets: 0,
        }
    }

    fn run_sim_replica(
        spec: ReplicaSpec,
        profile: SimProfile,
        rx: Receiver<ReplicaCommand>,
        tx: Sender<ReplicaEvent>,
    ) {
        let mut guard = PanicGuard::new(tx.clone());
        let mut active: Vec<SimGen> = Vec::new();
        let mut queue: Vec<(Request, Option<f64>)> = Vec::new();
        let mut hb = HeartbeatClock::new(spec.heartbeat_ms);
        let mut tokens_total = 0u64;
        let mut rejected_once = false;
        // Sim workers are ready the instant they spawn; report the
        // simulated per-token cost as a stand-in cold-start so the
        // router/metrics plumbing is exercised with a nonzero value.
        let _ = tx.send(ReplicaEvent::Ready {
            cold_start_ms: profile.token_us as f64 / 1e3,
        });
        loop {
            let mut shutdown = false;
            loop {
                let busy = !active.is_empty() || !queue.is_empty();
                let cmd = if busy {
                    match rx.try_recv() {
                        Ok(c) => Some(c),
                        Err(TryRecvError::Empty) => None,
                        Err(TryRecvError::Disconnected) => {
                            shutdown = true;
                            None
                        }
                    }
                } else {
                    let wait =
                        Duration::from_millis(spec.heartbeat_ms.max(2) / 2);
                    match rx.recv_timeout(wait) {
                        Ok(c) => Some(c),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            shutdown = true;
                            None
                        }
                    }
                };
                match cmd {
                    Some(ReplicaCommand::Submit { req, pinned }) => {
                        let invalid = match profile.max_prompt_chars {
                            Some(m) => req.prompt.trim().is_empty()
                                || req.prompt.len() > m,
                            None => false,
                        };
                        if invalid {
                            let _ = tx.send(ReplicaEvent::Error {
                                id: req.id,
                                error: "sim: invalid prompt (empty or over \
                                        max length)"
                                    .to_string(),
                                capacity: false,
                            });
                        } else if profile.reject_first && !rejected_once {
                            rejected_once = true;
                            let _ = tx.send(ReplicaEvent::Error {
                                id: req.id,
                                error: "sim: KV pool exhausted".to_string(),
                                capacity: true,
                            });
                        } else {
                            queue.push((req, pinned));
                        }
                    }
                    Some(ReplicaCommand::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    None => break,
                }
            }
            if shutdown {
                // Finish the active set, drop the backlog (the router
                // re-routes anything it still tracks), exit cleanly.
                for g in &mut active {
                    g.produced = g.req.max_new;
                    let _ = tx.send(ReplicaEvent::Done(outcome(g, profile.token_us)));
                }
                guard.disarm();
                let _ = tx.send(ReplicaEvent::Stopped);
                return;
            }
            // Admit into free slots.
            while active.len() < profile.slots.max(1) && !queue.is_empty() {
                let (req, pinned) = queue.remove(0);
                let target = pinned.unwrap_or_else(|| {
                    spec.targets.first().copied().unwrap_or(4.0)
                });
                let wait_ms = req.arrival.elapsed().as_secs_f64() * 1e3;
                active.push(SimGen { req, target, produced: 0, wait_ms });
            }
            if !active.is_empty() {
                // One interleaved round: every active generation
                // advances one token for one `token_us` of service time
                // (the batched-decode idealization).
                std::thread::sleep(Duration::from_micros(profile.token_us));
                tokens_total += active.len() as u64;
                let mut i = 0;
                while i < active.len() {
                    active[i].produced += 1;
                    if active[i].produced >= active[i].req.max_new.max(1) {
                        let g = active.swap_remove(i);
                        let _ = tx.send(ReplicaEvent::Done(outcome(
                            &g,
                            profile.token_us,
                        )));
                    } else {
                        i += 1;
                    }
                }
            }
            if let Some(n) = profile.panic_after_tokens {
                if tokens_total >= n {
                    panic!("injected replica fault after {n} tokens");
                }
            }
            if let Some(n) = profile.mute_after_tokens {
                if tokens_total >= n {
                    // Wedge: stop emitting anything (including
                    // heartbeats) and idle until the router drops our
                    // channel, then vanish without a Died event.
                    loop {
                        match rx.recv_timeout(Duration::from_millis(20)) {
                            Err(RecvTimeoutError::Disconnected) => {
                                guard.disarm();
                                return;
                            }
                            _ => continue,
                        }
                    }
                }
            }
            if let Some(rate) = hb.tick(tokens_total) {
                let _ = tx.send(ReplicaEvent::Heartbeat(ReplicaHealth {
                    queued: queue.len(),
                    active: active.len(),
                    tokens_per_s: rate,
                }));
            }
        }
    }
}
