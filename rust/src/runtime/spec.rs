//! Self-speculative decoding: free low-bit drafts from the multi-scale
//! store, verified in one batched high-bit dispatch (DESIGN.md
//! §Speculation).
//!
//! The Any-Precision overlay means a low-bit variant of the model is
//! *already resident* whenever a higher-bit target is served — the
//! bitplane nested-prefix property (`code_{b+1} = code_b << 1 | bit_b`)
//! makes the draft model memory-free.  A [`spec_round`]:
//!
//!   1. **drafts** γ tokens greedily through the low-bit
//!      [`DecodeSession`] (γ cheap decode steps on the draft's own
//!      device-resident KV),
//!   2. **verifies** them in ONE target-precision dispatch
//!      ([`DecodeSession::advance_verify`], the `verify_step_g{2,4}`
//!      graph): γ+1 causal positions scored against the target KV —
//!      batch-1 decode is memory-bandwidth bound (DESIGN §2), so the
//!      whole verify costs roughly one token's weight traffic,
//!   3. **accepts** the longest draft prefix whose tokens match the
//!      target's own greedy choices ([`longest_accepted_prefix`]) plus
//!      one *bonus* token from the first disagreeing (or final)
//!      position — ≥ 1 token of progress per verify dispatch, always,
//!   4. **rolls back** by position-counter rewind ([`GenState::rewind`]):
//!      KV slots past the counter are stale but masked by the causal
//!      attention and overwritten in place when re-decoded — no device
//!      traffic.
//!
//! Because acceptance compares against the target's own argmax at every
//! position, speculative greedy decode emits **token-for-token the same
//! sequence** as plain greedy decode — speculation changes latency, never
//! output (asserted by the spec integration tests).
//!
//! The dynamic-γ controller ([`GammaController`]) picks γ ∈ {0, 2, 4}
//! per request in the DP-LLM spirit: an acceptance-rate EWMA feeds the
//! costmodel's affine-TPOT speculation model
//! ([`crate::costmodel::pick_gamma`]), and γ = 0 — plain decode — wins
//! whenever speculation would not be strictly cheaper.  Degradation
//! ladder: spec → batched → single (DESIGN.md §Speculation); the
//! `DPLLM_NO_SPEC` escape hatch and absent `verify_step_g*` manifest
//! entries both land on plain decode.

use anyhow::{bail, Result};

use crate::costmodel;
use crate::runtime::decode::{DecodeSession, EstMode, GenState};

/// Hard cap on how many committed-but-not-yet-drafted tokens a round will
/// replay into the draft model before speculating.  A generation that
/// mostly advances through batched dispatches (where speculation is
/// skipped) can fall arbitrarily far behind; past this bound the serving
/// core drops its speculation state instead of stalling a step on
/// catch-up work.
pub const MAX_SPEC_CATCHUP: usize = 32;

/// Acceptance-rate EWMA + the costmodel hook: picks the per-round draft
/// length γ from the compiled `verify_step_g*` candidates.
#[derive(Debug, Clone)]
pub struct GammaController {
    /// EWMA of the per-draft acceptance probability, seeded optimistic
    /// so speculation gets a chance to measure itself.
    pub accept_ewma: f64,
    pub alpha: f64,
    /// Predicted/measured per-token latency of the draft configuration
    /// (the adaptation policy's calibrated TPOT, or the costmodel's
    /// affine TPOT(b) at paper scale).
    pub tpot_draft_ms: f64,
    /// Same for the request's current target configuration (updated on
    /// mid-stream re-selection).
    pub tpot_target_ms: f64,
}

impl GammaController {
    pub fn new(tpot_draft_ms: f64, tpot_target_ms: f64) -> GammaController {
        GammaController {
            // Optimistic start: a draft model that shares every weight
            // bit with its target tends to agree with it, and an EWMA
            // seeded too low would park γ at 0 forever (γ = 0 rounds
            // produce no acceptance observations to recover from).
            // A few bad rounds pull it below the engagement threshold.
            accept_ewma: 0.9,
            alpha: 0.25,
            tpot_draft_ms,
            tpot_target_ms,
        }
    }

    /// Draft length for the next round: the candidate minimizing expected
    /// ms/token at the current acceptance estimate, 0 (plain decode)
    /// unless strictly cheaper ([`costmodel::pick_gamma`]).
    pub fn pick(&self, candidates: &[usize]) -> usize {
        costmodel::pick_gamma(self.tpot_draft_ms, self.tpot_target_ms,
                              self.accept_ewma, candidates)
    }

    /// Fold one round's outcome (`accepted` of `gamma` drafts kept) into
    /// the acceptance EWMA.
    pub fn observe_round(&mut self, accepted: usize, gamma: usize) {
        if gamma == 0 {
            return;
        }
        let obs = accepted as f64 / gamma as f64;
        self.accept_ewma = self.alpha * obs + (1.0 - self.alpha) * self.accept_ewma;
    }
}

/// Per-request speculation state: the draft half of the pair.  The
/// *target* half is the request's ordinary [`GenState`] on its target
/// session — mid-stream re-selection can move it freely; the draft stays
/// pinned to the adaptation set's lowest-precision session.
pub struct SpecState<'s> {
    /// The low-bit draft session (shares the runtime + weight overlay
    /// with the target; distinct weight stacks, distinct KV).
    pub draft: &'s DecodeSession,
    /// The draft model's own device-resident generation state.  Invariant
    /// between rounds: `draft_gen.pos <= target pos`, with the gap
    /// closed by catch-up replay at the start of the next round.
    pub draft_gen: GenState<'s>,
    pub ctrl: GammaController,
}

/// Outcome of one [`spec_round`].
pub struct SpecRound {
    /// Committed tokens, in stream order: the accepted draft prefix plus
    /// the bonus token.  Never empty (≥ 1 token of progress).
    pub tokens: Vec<u32>,
    /// How many of the γ drafts were accepted (0 ≤ accepted ≤ γ).
    pub accepted_drafts: usize,
    pub gamma: usize,
}

/// Greedy longest-prefix acceptance over a verify dispatch's logits:
/// draft `i` is kept iff the target's own argmax at position `i` equals
/// it and every earlier draft was kept; the bonus token is the target's
/// argmax at the first disagreeing (or final) position.  Returns
/// `(accepted, bonus)` — the round always commits `accepted + 1 ≥ 1`
/// tokens, the guaranteed-progress property of speculative decoding.
pub fn longest_accepted_prefix(logits: &[f32], vocab: usize,
                               drafts: &[u32]) -> Result<(usize, u32)> {
    if logits.len() < (drafts.len() + 1) * vocab {
        bail!("verify logits cover {} positions, need {}",
              logits.len() / vocab.max(1), drafts.len() + 1);
    }
    let mut accepted = 0usize;
    for (i, &d) in drafts.iter().enumerate() {
        let pred = DecodeSession::argmax(&logits[i * vocab..(i + 1) * vocab])?;
        if pred == d {
            accepted += 1;
        } else {
            break;
        }
    }
    let bonus = DecodeSession::argmax(
        &logits[accepted * vocab..(accepted + 1) * vocab])?;
    Ok((accepted, bonus))
}

/// Truncate a committed run at the first EOS token (kept, inclusive).
/// Returns true when an EOS was found — the generation is finished and
/// its slot frees at the end of the step; tokens speculated past the EOS
/// are discarded (their KV entries are stale-but-masked, like any
/// rejected tail).
pub fn truncate_at_eos(tokens: &mut Vec<u32>, eos: Option<u32>) -> bool {
    let Some(e) = eos else { return false };
    match tokens.iter().position(|&t| t == e) {
        Some(i) => {
            tokens.truncate(i + 1);
            true
        }
        None => false,
    }
}

/// QoS gate for the spec path: best-effort requests (no deadline) and
/// loose deadlines ride speculation; a tight deadline keeps token-granular
/// EDF preemption — a speculative round commits up to γ+1 tokens of ONE
/// request before the scheduler runs again, which is exactly the latency
/// slack a tight deadline does not have.
pub fn spec_eligible(deadline_ms: Option<f64>, loose_deadline_ms: f64) -> bool {
    match deadline_ms {
        None => true,
        Some(d) => d >= loose_deadline_ms,
    }
}

/// One speculative round over a (draft, target) pair.
///
/// `token` is the next committed token to feed (== the last emitted
/// token); `catchup` holds any committed tokens the draft has not yet
/// ingested, oldest first (computed by the caller from the committed
/// stream — replayed into the draft before drafting so its KV covers
/// every committed position).  `gamma` must name a compiled
/// `verify_step_g{γ}` graph of the target session.
///
/// On success the target [`GenState`] advanced by `accepted + 1`
/// positions with its selector having observed exactly the kept
/// positions — the identical evolution plain sequential decode would
/// have produced (jax-level parity test + greedy acceptance).  On error
/// the target is untouched except possibly its (unconditionally valid)
/// KV write, and the draft is rewound to the round's start; the caller
/// is expected to drop the [`SpecState`] and continue on plain decode.
pub fn spec_round(state: &mut SpecState<'_>, target: &DecodeSession,
                  target_gen: &mut GenState<'_>, token: u32, catchup: &[u32],
                  gamma: usize, mode: EstMode) -> Result<SpecRound> {
    if gamma == 0 {
        bail!("spec_round with γ = 0 — the caller owns the plain path");
    }
    let pos0 = target_gen.pos;
    // 1. Catch-up: replay committed tokens the draft missed (e.g. the
    //    final draft of a fully-accepted round, or tokens decoded through
    //    the batched path while speculation was skipped).
    for &t in catchup {
        state.draft.advance(&mut state.draft_gen, t, mode)?;
    }
    debug_assert_eq!(state.draft_gen.pos, pos0,
                     "draft out of sync after catch-up");
    // 2. Draft γ tokens greedily at the low bitwidth.
    let mut drafts = Vec::with_capacity(gamma);
    let mut t = token;
    for _ in 0..gamma {
        let out = match state.draft.advance(&mut state.draft_gen, t, mode) {
            Ok(o) => o,
            Err(e) => {
                state.draft_gen.rewind(pos0);
                return Err(e);
            }
        };
        t = match DecodeSession::argmax(&out.logits) {
            Ok(v) => v,
            Err(e) => {
                state.draft_gen.rewind(pos0);
                return Err(e);
            }
        };
        drafts.push(t);
    }
    // 3. Verify all γ+1 positions in one target-precision dispatch.
    let mut vtokens = Vec::with_capacity(gamma + 1);
    vtokens.push(token);
    vtokens.extend_from_slice(&drafts);
    let vout = match target.advance_verify(target_gen, &vtokens, mode) {
        Ok(v) => v,
        Err(e) => {
            state.draft_gen.rewind(pos0);
            return Err(e);
        }
    };
    // 4. Greedy longest-prefix acceptance + commit.  The selector
    //    observes exactly the kept positions (flags and effective-bit
    //    accounting evolve as plain sequential decode would).
    let (accepted, bonus) =
        longest_accepted_prefix(&vout.logits, vout.vocab, &drafts)?;
    for i in 0..=accepted {
        let so = vout.step_out(i);
        target_gen.sel.observe(&so.ests, &so.use_eff);
    }
    target_gen.pos = pos0 + accepted + 1;
    target_gen.steps += accepted + 1;
    // 5. Draft rollback: rejected positions rewind (stale KV is masked
    //    and overwritten in place); a fully-accepted round leaves the
    //    draft one token behind — drafts[γ-1] was never fed to it — and
    //    the next round's catch-up closes the gap.
    if accepted < gamma {
        state.draft_gen.rewind(pos0 + accepted + 1);
    }
    state.ctrl.observe_round(accepted, gamma);
    target
        .runtime()
        .transfers()
        .count_spec_round(gamma as u64, accepted as u64);
    let mut tokens = drafts;
    tokens.truncate(accepted);
    tokens.push(bonus);
    Ok(SpecRound { tokens, accepted_drafts: accepted, gamma })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_hot(vocab: usize, id: u32) -> Vec<f32> {
        let mut v = vec![0.0; vocab];
        v[id as usize] = 1.0;
        v
    }

    fn stack_logits(vocab: usize, ids: &[u32]) -> Vec<f32> {
        ids.iter().flat_map(|&i| one_hot(vocab, i)).collect()
    }

    #[test]
    fn acceptance_all_drafts_match() {
        // Target predictions: [5, 6, 7] for drafts [5, 6] → both accepted,
        // bonus from the final position.
        let logits = stack_logits(8, &[5, 6, 7]);
        let (k, bonus) = longest_accepted_prefix(&logits, 8, &[5, 6]).unwrap();
        assert_eq!((k, bonus), (2, 7));
    }

    #[test]
    fn acceptance_partial_prefix_takes_corrected_bonus() {
        // Draft [5, 2] but target predicts 6 at position 1 → one draft
        // kept, bonus is the target's correction (6), and the third
        // position's logits are never consulted.
        let logits = stack_logits(8, &[5, 6, 3]);
        let (k, bonus) = longest_accepted_prefix(&logits, 8, &[5, 2]).unwrap();
        assert_eq!((k, bonus), (1, 6));
    }

    #[test]
    fn acceptance_all_rejected_still_emits_one_token() {
        // Guaranteed progress: zero accepted drafts → exactly the bonus
        // token (the target's own next choice) commits.
        let logits = stack_logits(8, &[4, 1, 1]);
        let (k, bonus) = longest_accepted_prefix(&logits, 8, &[7, 7]).unwrap();
        assert_eq!(k, 0);
        assert_eq!(bonus, 4);
        // k + 1 tokens commit — never zero.
        assert_eq!(k + 1, 1);
    }

    #[test]
    fn acceptance_rejects_short_logits() {
        assert!(longest_accepted_prefix(&[0.0; 8], 8, &[1, 2]).is_err());
    }

    #[test]
    fn eos_truncates_inclusive_and_frees() {
        let mut toks = vec![3, 258, 9, 11];
        assert!(truncate_at_eos(&mut toks, Some(258)));
        assert_eq!(toks, vec![3, 258]);
        // No EOS / disabled → untouched.
        let mut toks = vec![3, 9];
        assert!(!truncate_at_eos(&mut toks, Some(258)));
        assert_eq!(toks, vec![3, 9]);
        assert!(!truncate_at_eos(&mut toks, None));
    }

    #[test]
    fn eligibility_gates_on_deadline_slack() {
        // Best-effort always rides the spec path.
        assert!(spec_eligible(None, 1000.0));
        // Loose deadline rides; tight keeps token-granular preemption.
        assert!(spec_eligible(Some(5000.0), 1000.0));
        assert!(!spec_eligible(Some(120.0), 1000.0));
        assert!(spec_eligible(Some(1000.0), 1000.0));
    }

    #[test]
    fn controller_ewma_converges_and_gates_gamma() {
        let mut c = GammaController::new(1.0, 10.0);
        // High measured acceptance → EWMA climbs → largest γ stays picked.
        for _ in 0..32 {
            c.observe_round(4, 4);
        }
        assert!(c.accept_ewma > 0.95);
        assert_eq!(c.pick(&[2, 4]), 4);
        // Collapse of acceptance → γ falls back to plain decode.
        for _ in 0..32 {
            c.observe_round(0, 4);
        }
        assert!(c.accept_ewma < 0.05);
        assert_eq!(c.pick(&[2, 4]), 0);
        // γ = 0 rounds never perturb the estimate.
        let before = c.accept_ewma;
        c.observe_round(0, 0);
        assert_eq!(c.accept_ewma, before);
    }

    #[test]
    fn controller_draft_as_slow_as_target_never_speculates() {
        let c = GammaController::new(10.0, 10.0);
        assert_eq!(c.pick(&[2, 4]), 0);
        // No verify graphs compiled → plain decode.
        let c = GammaController::new(1.0, 10.0);
        assert_eq!(c.pick(&[]), 0);
    }
}
