//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched.  Key properties:
//!
//! * HLO **text** is the interchange format (`HloModuleProto::from_text_file`)
//!   — serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1
//!   (64-bit instruction ids), text re-parses cleanly.
//! * Model weights are uploaded to the device **once** per configuration
//!   ([`DeviceArgs`]), and per-step inputs are a few KB of scalars/vectors —
//!   nothing Python ever runs on the request path.
//! * Executables are cached per (model, entry) in [`Runtime`].

pub mod decode;

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::model::HloEntry;
use crate::tensor::Tensor;

/// Process-wide PJRT CPU client + executable cache.
pub struct Runtime {
    pub client: PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Exe>>>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Load + compile an HLO-text entry (cached by path).
    pub fn load(&self, entry: &HloEntry) -> Result<std::sync::Arc<Exe>> {
        if let Some(e) = self.cache.lock().unwrap().get(&entry.path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&entry.path)
            .map_err(wrap)
            .with_context(|| format!("parsing {}", entry.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(wrap)
            .with_context(|| format!("compiling {}", entry.path))?;
        let arc = std::sync::Arc::new(Exe { exe, entry: entry.clone() });
        self.cache
            .lock()
            .unwrap()
            .insert(entry.path.clone(), arc.clone());
        Ok(arc)
    }

    // ---- host -> device upload helpers ------------------------------------
    pub fn upload_f32(&self, shape: &[usize], data: &[f32]) -> Result<PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, shape, None).map_err(wrap)
    }

    pub fn upload_tensor(&self, t: &Tensor) -> Result<PjRtBuffer> {
        self.upload_f32(&t.shape, &t.data)
    }

    pub fn upload_i32(&self, shape: &[usize], data: &[i32]) -> Result<PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, shape, None).map_err(wrap)
    }

    pub fn upload_u8(&self, shape: &[usize], data: &[u8]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_raw_bytes(ElementType::U8, data, shape, None)
            .map_err(wrap)
    }

    pub fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        self.upload_i32(&[], &[v])
    }

    pub fn scalar_f32(&self, v: f32) -> Result<PjRtBuffer> {
        self.upload_f32(&[], &[v])
    }
}

/// A compiled executable + its manifest signature.
pub struct Exe {
    exe: PjRtLoadedExecutable,
    pub entry: HloEntry,
}

impl Exe {
    /// Execute with device-resident args; returns the output buffers.
    ///
    /// The AOT graphs are lowered with `return_tuple=True`, so PJRT hands
    /// back a single tuple buffer; [`Outputs`] wraps the host-side literal
    /// decomposition.
    pub fn run(&self, args: &[&PjRtBuffer]) -> Result<Outputs> {
        let mut res = self.exe.execute_b(args).map_err(wrap)?;
        let replica = res
            .pop()
            .ok_or_else(|| anyhow!("no replica outputs"))?;
        outputs_from(replica, &self.entry)
    }

    /// Execute with host literals (tests / one-shot calls).
    pub fn run_literals<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Outputs> {
        let mut res = self.exe.execute(args).map_err(wrap)?;
        let replica = res.pop().ok_or_else(|| anyhow!("no replica outputs"))?;
        outputs_from(replica, &self.entry)
    }
}

fn outputs_from(replica: Vec<PjRtBuffer>, entry: &HloEntry) -> Result<Outputs> {
    if replica.is_empty() {
        bail!("executable returned no buffers");
    }
    let lit = if replica.len() == 1 {
        let l = replica[0].to_literal_sync().map_err(wrap)?;
        drop(replica);
        l
    } else {
        // Untupled multi-output: wrap as tuple for uniform handling.
        let lits: Vec<Literal> = replica
            .iter()
            .map(|b| b.to_literal_sync().map_err(wrap))
            .collect::<Result<_>>()?;
        Literal::tuple(lits)
    };
    let parts = lit.to_tuple().map_err(wrap)?;
    Ok(Outputs { parts, names: entry.outputs.clone() })
}

/// Decomposed outputs of one execution, addressable by manifest name.
pub struct Outputs {
    parts: Vec<Literal>,
    names: Vec<String>,
}

impl Outputs {
    pub fn len(&self) -> usize {
        self.parts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<&Literal> {
        let i = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow!("no output named '{name}' (have {:?})", self.names))?;
        self.parts
            .get(i)
            .ok_or_else(|| anyhow!("output arity {} < index {i}", self.parts.len()))
    }

    pub fn f32_vec(&self, name: &str) -> Result<Vec<f32>> {
        self.get(name)?.to_vec::<f32>().map_err(wrap)
    }

    pub fn by_index(&self, i: usize) -> Result<&Literal> {
        self.parts.get(i).ok_or_else(|| anyhow!("no output index {i}"))
    }
}

/// xla::Error -> anyhow::Error bridge.
pub fn wrap(e: impl std::fmt::Display) -> anyhow::Error {
    anyhow!("{e}")
}

/// Literal -> host f32 vec (convenience used across eval harnesses).
pub fn literal_f32(l: &Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(wrap)
}
