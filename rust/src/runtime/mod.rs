//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched.  Key properties:
//!
//! * HLO **text** is the interchange format (`HloModuleProto::from_text_file`)
//!   — serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1
//!   (64-bit instruction ids), text re-parses cleanly.
//! * Model weights are uploaded to the device **once** per configuration
//!   (the weight stacks cached by [`decode::DecodeSession`]), and per-step
//!   inputs are a few KB of scalars/vectors — nothing Python ever runs on
//!   the request path.
//! * Executables are cached per (model, entry) in [`Runtime`].
//! * Host↔device traffic is metered ([`Runtime::transfers`]): the decode
//!   hot path must stay O(1) in KV-cache size (DESIGN.md §Perf), and the
//!   GenState tests assert it through these counters.

pub mod decode;
pub mod kvpool;
pub mod replica;
pub mod spec;
pub mod stack;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::model::HloEntry;
use crate::tensor::Tensor;

/// Running totals of host→device uploads (count + bytes), device→host
/// literal reads, device-side stack assemblies, batched decode
/// dispatches, and speculative-decoding activity.  Cheap atomics;
/// benches and the GenState residency / batching / speculation tests
/// read deltas around a decode step.
#[derive(Default)]
pub struct TransferStats {
    uploads: AtomicU64,
    upload_bytes: AtomicU64,
    downloads: AtomicU64,
    assemblies: AtomicU64,
    batched_steps: AtomicU64,
    batch_occupancy: AtomicU64,
    spec_drafted: AtomicU64,
    spec_accepted: AtomicU64,
    spec_verify_dispatches: AtomicU64,
    prefill_chunks: AtomicU64,
    kv_bytes_resident: AtomicU64,
    kv_migrations: AtomicU64,
    prefix_hits: AtomicU64,
    prefix_prefills_saved: AtomicU64,
}

/// A point-in-time copy of [`TransferStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub uploads: u64,
    pub upload_bytes: u64,
    pub downloads: u64,
    /// Device-side weight-stack assemblies ([`stack::Stacker`]): stacks
    /// concatenated from cached per-layer buffers *on the device*, i.e.
    /// rebinds that did NOT pay an O(stack) host→device upload.
    pub assemblies: u64,
    /// Batched decode dispatches (`DecodeSession::advance_batch`): device
    /// calls that decoded one token for ≥ 2 requests at once.  Together
    /// with [`TransferSnapshot::batch_occupancy`] this is the counter
    /// pair the batching tests and `batch_micro` assert against —
    /// dispatch calls per generated token is
    /// `(batched_steps + single_steps) / tokens`, and single-call steps
    /// are derivable as `tokens - batch_occupancy` (DESIGN.md §Batching).
    pub batched_steps: u64,
    /// Total *real* (non-padding) slots served across all batched
    /// dispatches; `batch_occupancy / batched_steps` is the mean batch
    /// occupancy.  Padded no-op slots of a partially filled bucket are
    /// not counted.
    pub batch_occupancy: u64,
    /// Draft tokens proposed by speculative rounds
    /// (`runtime::spec::spec_round`).  Together with
    /// [`TransferSnapshot::spec_accepted`] this yields the realized
    /// draft acceptance rate `spec_accepted / spec_drafted` — the
    /// quantity the dynamic-γ controller's EWMA tracks per request
    /// (DESIGN.md §Speculation).
    pub spec_drafted: u64,
    /// Draft tokens accepted by greedy longest-prefix verification.
    pub spec_accepted: u64,
    /// `verify_step_g*` device dispatches
    /// ([`decode::DecodeSession::advance_verify`]).  Each commits
    /// between 1 (all drafts rejected) and γ+1 (all accepted + bonus)
    /// tokens, so `spec_verify_dispatches / tokens` is the spec-path
    /// analog of dispatch-calls-per-token.
    pub spec_verify_dispatches: u64,
    /// `prefill_chunk_<P>` device dispatches
    /// ([`decode::DecodeSession::prefill_advance`]): bounded prompt-
    /// ingestion units the serving core interleaves with decode steps
    /// (at most one per scheduling round — DESIGN.md §Prefill).
    pub prefill_chunks: u64,
    /// KV-cache bytes hard-committed to live generation tiers in the
    /// [`kvpool::KvPool`] (free-listed and prefix-cached bytes are
    /// evictable and reported separately via the pool's `memory_json`).
    /// A gauge, not a monotone counter — admission and tier acquisition
    /// add, release subtracts (DESIGN.md §Memory).
    pub kv_bytes_resident: u64,
    /// Tier migrations: a generation outgrew its KV tier and carried its
    /// cache into the next tier (device-side pad or host copy).  Each is
    /// one extra dispatch amortized over a whole tier worth of tokens.
    pub kv_migrations: u64,
    /// Shared-prefix cache hits: admissions that started from a cached
    /// prompt-prefix KV instead of prefilling from scratch.
    pub prefix_hits: u64,
    /// `prefill_chunk_<P>` dispatches avoided by prefix-cache hits — the
    /// direct savings meter: N requests sharing one prompt prefix pay
    /// ~1/N of the chunk dispatches (DESIGN.md §Memory).
    pub prefix_prefills_saved: u64,
}

impl TransferStats {
    fn count_upload(&self, bytes: usize) {
        self.uploads.fetch_add(1, Ordering::Relaxed);
        self.upload_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn count_download(&self) {
        self.downloads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_assembly(&self) {
        self.assemblies.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one batched decode dispatch serving `occupancy` real
    /// (non-padding) request slots.
    pub fn count_batched_step(&self, occupancy: u64) {
        self.batched_steps.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy.fetch_add(occupancy, Ordering::Relaxed);
    }

    /// Record one `verify_step_g*` dispatch
    /// ([`decode::DecodeSession::advance_verify`]).
    pub fn count_spec_verify(&self) {
        self.spec_verify_dispatches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one speculative round's drafting outcome: `drafted` tokens
    /// proposed, `accepted` of them kept by longest-prefix verification.
    pub fn count_spec_round(&self, drafted: u64, accepted: u64) {
        self.spec_drafted.fetch_add(drafted, Ordering::Relaxed);
        self.spec_accepted.fetch_add(accepted, Ordering::Relaxed);
    }

    /// Record one `prefill_chunk_<P>` dispatch
    /// ([`decode::DecodeSession::prefill_advance`]).
    pub fn count_prefill_chunk(&self) {
        self.prefill_chunks.fetch_add(1, Ordering::Relaxed);
    }

    /// Charge `bytes` of KV-cache residency against the pool gauge.
    pub fn count_kv_acquire(&self, bytes: u64) {
        self.kv_bytes_resident.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Credit `bytes` of KV-cache residency back (release / eviction).
    /// Saturates at zero so a double-release can never wrap the gauge.
    pub fn count_kv_release(&self, bytes: u64) {
        let _ = self.kv_bytes_resident.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(bytes)),
        );
    }

    /// Record one KV tier migration (generation outgrew its tier).
    pub fn count_kv_migration(&self) {
        self.kv_migrations.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one shared-prefix cache hit that avoided `chunks_saved`
    /// prefill-chunk dispatches.
    pub fn count_prefix_hit(&self, chunks_saved: u64) {
        self.prefix_hits.fetch_add(1, Ordering::Relaxed);
        self.prefix_prefills_saved
            .fetch_add(chunks_saved, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            uploads: self.uploads.load(Ordering::Relaxed),
            upload_bytes: self.upload_bytes.load(Ordering::Relaxed),
            downloads: self.downloads.load(Ordering::Relaxed),
            assemblies: self.assemblies.load(Ordering::Relaxed),
            batched_steps: self.batched_steps.load(Ordering::Relaxed),
            batch_occupancy: self.batch_occupancy.load(Ordering::Relaxed),
            spec_drafted: self.spec_drafted.load(Ordering::Relaxed),
            spec_accepted: self.spec_accepted.load(Ordering::Relaxed),
            spec_verify_dispatches: self
                .spec_verify_dispatches
                .load(Ordering::Relaxed),
            prefill_chunks: self.prefill_chunks.load(Ordering::Relaxed),
            kv_bytes_resident: self.kv_bytes_resident.load(Ordering::Relaxed),
            kv_migrations: self.kv_migrations.load(Ordering::Relaxed),
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_prefills_saved: self
                .prefix_prefills_saved
                .load(Ordering::Relaxed),
        }
    }
}

impl TransferSnapshot {
    /// Bytes uploaded since `earlier`.
    pub fn upload_bytes_since(&self, earlier: &TransferSnapshot) -> u64 {
        self.upload_bytes.saturating_sub(earlier.upload_bytes)
    }

    pub fn uploads_since(&self, earlier: &TransferSnapshot) -> u64 {
        self.uploads.saturating_sub(earlier.uploads)
    }
}

/// Process-wide PJRT CPU client + executable cache.
pub struct Runtime {
    pub client: PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Exe>>>,
    /// Compiled weight-stack concat graphs keyed by shape (`None` =
    /// compilation failed once; don't retry).  Lives here, not in the
    /// per-session [`stack::Stacker`], so sibling sessions share one
    /// compile per shape — see `stack.rs`.
    stack_exes: Mutex<HashMap<(usize, usize, usize), Option<std::sync::Arc<Exe>>>>,
    /// Compiled KV tier-migration / snapshot graphs keyed by
    /// `(layers, heads, head_dim, from_seq, to_seq)` (`from == to` is the
    /// plain copy used for prefix snapshots; `None` = compilation failed
    /// once, don't retry).  Shared across sessions like `stack_exes` —
    /// see `kvpool.rs`.
    kv_exes: Mutex<
        HashMap<(usize, usize, usize, usize, usize), Option<std::sync::Arc<Exe>>>,
    >,
    transfers: TransferStats,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            stack_exes: Mutex::new(HashMap::new()),
            kv_exes: Mutex::new(HashMap::new()),
            transfers: TransferStats::default(),
        })
    }

    /// Load + compile an HLO-text entry (cached by path).
    pub fn load(&self, entry: &HloEntry) -> Result<std::sync::Arc<Exe>> {
        if let Some(e) = self.cache.lock().unwrap().get(&entry.path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&entry.path)
            .map_err(wrap)
            .with_context(|| format!("parsing {}", entry.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(wrap)
            .with_context(|| format!("compiling {}", entry.path))?;
        let arc = std::sync::Arc::new(Exe { exe, entry: entry.clone() });
        self.cache
            .lock()
            .unwrap()
            .insert(entry.path.clone(), arc.clone());
        Ok(arc)
    }

    /// Host↔device transfer meters (uploads through the helpers below).
    pub fn transfers(&self) -> &TransferStats {
        &self.transfers
    }

    // ---- host -> device upload helpers ------------------------------------
    pub fn upload_f32(&self, shape: &[usize], data: &[f32]) -> Result<PjRtBuffer> {
        self.transfers.count_upload(data.len() * 4);
        self.client.buffer_from_host_buffer(data, shape, None).map_err(wrap)
    }

    pub fn upload_tensor(&self, t: &Tensor) -> Result<PjRtBuffer> {
        self.upload_f32(&t.shape, &t.data)
    }

    pub fn upload_i32(&self, shape: &[usize], data: &[i32]) -> Result<PjRtBuffer> {
        self.transfers.count_upload(data.len() * 4);
        self.client.buffer_from_host_buffer(data, shape, None).map_err(wrap)
    }

    pub fn upload_u8(&self, shape: &[usize], data: &[u8]) -> Result<PjRtBuffer> {
        self.transfers.count_upload(data.len());
        self.client
            .buffer_from_host_raw_bytes(ElementType::U8, data, shape, None)
            .map_err(wrap)
    }

    pub fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        self.upload_i32(&[], &[v])
    }

    pub fn scalar_f32(&self, v: f32) -> Result<PjRtBuffer> {
        self.upload_f32(&[], &[v])
    }
}

/// A compiled executable + its manifest signature.
pub struct Exe {
    exe: PjRtLoadedExecutable,
    pub entry: HloEntry,
}

impl Exe {
    /// Execute with device-resident args; returns host-side [`Outputs`].
    ///
    /// Convenience wrapper over [`Exe::run_buffers`] for callers that want
    /// every output on the host.  The decode hot path uses `run_buffers`
    /// directly so the KV cache never leaves the device.
    pub fn run(&self, args: &[&PjRtBuffer]) -> Result<Outputs> {
        let replica = self.run_buffers(args)?;
        outputs_from(replica, &self.entry)
    }

    /// Execute and return the raw per-replica output buffers, still on the
    /// device.  When the AOT graph was lowered untupled (one leaf buffer
    /// per manifest output) the caller can keep any of them device-resident
    /// and feed them back as inputs to the next execution — the mechanism
    /// behind [`decode::GenState`]'s O(1) per-token host traffic.
    pub fn run_buffers(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let mut res = self.exe.execute_b(args).map_err(wrap)?;
        let replica = res.pop().ok_or_else(|| anyhow!("no replica outputs"))?;
        if replica.is_empty() {
            bail!("executable returned no buffers");
        }
        Ok(replica)
    }

    /// Execute with host literals (tests / one-shot calls).
    pub fn run_literals<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Outputs> {
        let mut res = self.exe.execute(args).map_err(wrap)?;
        let replica = res.pop().ok_or_else(|| anyhow!("no replica outputs"))?;
        outputs_from(replica, &self.entry)
    }

    /// Decompose an already-executed replica into host-side [`Outputs`]
    /// (the fallback path when the graph was lowered as a single tuple and
    /// device residency is impossible).
    pub fn outputs(&self, replica: Vec<PjRtBuffer>) -> Result<Outputs> {
        outputs_from(replica, &self.entry)
    }

    /// Position of a named output among the graph's result leaves.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.entry
            .outputs
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| {
                anyhow!("no output named '{name}' (have {:?})", self.entry.outputs)
            })
    }

    /// True when this executable hands back one device buffer per manifest
    /// output (untupled lowering) — the precondition for keeping outputs
    /// device-resident across steps.
    pub fn untupled(&self, replica: &[PjRtBuffer]) -> bool {
        replica.len() == self.entry.outputs.len() && replica.len() > 1
    }
}

fn outputs_from(replica: Vec<PjRtBuffer>, entry: &HloEntry) -> Result<Outputs> {
    if replica.is_empty() {
        bail!("executable returned no buffers");
    }
    let lit = if replica.len() == 1 {
        let l = replica[0].to_literal_sync().map_err(wrap)?;
        drop(replica);
        l
    } else {
        // Untupled multi-output: wrap as tuple for uniform handling.
        let lits: Vec<Literal> = replica
            .iter()
            .map(|b| b.to_literal_sync().map_err(wrap))
            .collect::<Result<_>>()?;
        Literal::tuple(lits)
    };
    let parts = lit.to_tuple().map_err(wrap)?;
    Ok(Outputs { parts, names: entry.outputs.clone() })
}

/// Decomposed outputs of one execution, addressable by manifest name.
pub struct Outputs {
    parts: Vec<Literal>,
    names: Vec<String>,
}

impl Outputs {
    pub fn len(&self) -> usize {
        self.parts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<&Literal> {
        let i = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow!("no output named '{name}' (have {:?})", self.names))?;
        self.parts
            .get(i)
            .ok_or_else(|| anyhow!("output arity {} < index {i}", self.parts.len()))
    }

    pub fn f32_vec(&self, name: &str) -> Result<Vec<f32>> {
        self.get(name)?.to_vec::<f32>().map_err(wrap)
    }

    pub fn by_index(&self, i: usize) -> Result<&Literal> {
        self.parts.get(i).ok_or_else(|| anyhow!("no output index {i}"))
    }
}

/// xla::Error -> anyhow::Error bridge.
pub fn wrap(e: impl std::fmt::Display) -> anyhow::Error {
    anyhow!("{e}")
}

/// Literal -> host f32 vec (convenience used across eval harnesses).
pub fn literal_f32(l: &Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(wrap)
}

/// Device buffer -> host f32 vec (small per-step outputs: logits, estimates).
pub fn buffer_f32(b: &PjRtBuffer) -> Result<Vec<f32>> {
    b.to_literal_sync().map_err(wrap)?.to_vec::<f32>().map_err(wrap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_snapshot_deltas() {
        let t = TransferStats::default();
        let a = t.snapshot();
        t.count_upload(128);
        t.count_upload(64);
        t.count_download();
        t.count_assembly();
        t.count_batched_step(4);
        t.count_batched_step(2);
        t.count_spec_verify();
        t.count_spec_round(4, 3);
        t.count_spec_round(2, 0);
        t.count_prefill_chunk();
        t.count_prefill_chunk();
        t.count_kv_acquire(4096);
        t.count_kv_release(1024);
        t.count_kv_migration();
        t.count_prefix_hit(3);
        let b = t.snapshot();
        assert_eq!(b.uploads_since(&a), 2);
        assert_eq!(b.upload_bytes_since(&a), 192);
        assert_eq!(b.downloads - a.downloads, 1);
        assert_eq!(b.assemblies - a.assemblies, 1);
        assert_eq!(b.batched_steps - a.batched_steps, 2);
        assert_eq!(b.batch_occupancy - a.batch_occupancy, 6);
        assert_eq!(b.spec_verify_dispatches - a.spec_verify_dispatches, 1);
        assert_eq!(b.spec_drafted - a.spec_drafted, 6);
        assert_eq!(b.spec_accepted - a.spec_accepted, 3);
        assert_eq!(b.prefill_chunks - a.prefill_chunks, 2);
        assert_eq!(b.kv_bytes_resident, 3072);
        assert_eq!(b.kv_migrations - a.kv_migrations, 1);
        assert_eq!(b.prefix_hits - a.prefix_hits, 1);
        assert_eq!(b.prefix_prefills_saved - a.prefix_prefills_saved, 3);
        // The residency gauge saturates instead of wrapping on over-release.
        t.count_kv_release(1 << 40);
        assert_eq!(t.snapshot().kv_bytes_resident, 0);
    }
}
