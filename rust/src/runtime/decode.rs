//! Decode session + device-resident generation state.
//!
//! Request path per token (DESIGN.md §Perf):
//!   1. look up the token/pos scalars, rope tables and async-flag vectors in
//!      the device-buffer caches (upload only on miss / flag change),
//!   2. `execute_b` the decode graph with the **device-resident** KV cache
//!      from the previous step,
//!   3. read back only the small outputs (logits + per-linear estimates);
//!      the new KV buffer replaces the old one *on the device*,
//!   4. [`SelectorState::observe`] turns estimates into next-step flags.
//!
//! The KV cache — the only O(model · seq) tensor in the loop — never
//! crosses the host boundary after prefill, so per-token host↔device
//! traffic is O(1) in KV size.  When an AOT graph was lowered as a single
//! tuple (older artifacts), [`GenState`] degrades to a host round-trip and
//! reports it via [`GenState::kv_on_device`].

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::PjRtBuffer;

use crate::anyprec::GROUPS;
use crate::model::{Manifest, ModelAssets, ModelConfig};
use crate::runtime::{buffer_f32, wrap, Exe, Runtime};
use crate::selector::{EngineConfig, SelectorState, ASYNC_GROUPS};

/// Estimator source for a step (Table 3 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstMode {
    /// Hybrid approximate estimators + async selection (production path).
    Approx,
    /// Exact ‖W_h x − W_l x‖ for every selection, fully synchronous.
    Exact,
}

/// Host-visible results of one decode step.  The KV cache is *not* here —
/// it stays on the device inside [`GenState`].
pub struct StepOut {
    pub logits: Vec<f32>,
    pub ests: BTreeMap<String, Vec<f32>>,
    pub use_eff: BTreeMap<String, Vec<f32>>,
}

/// Where a generation's KV cache currently lives.
enum KvResidence {
    /// On the device; fed straight back into the next `execute_b`.
    Device(PjRtBuffer),
    /// Host fallback (tuple-lowered graph): re-uploaded each step.
    Host(Vec<f32>),
}

/// Per-request device-resident generation handle.
///
/// Created by [`DecodeSession::begin`] (prefill) or
/// [`DecodeSession::begin_empty`] (zero KV, teacher-forcing/benches) and
/// advanced one token at a time by [`DecodeSession::advance`].  Owns:
///
/// * the KV cache as a device buffer carried across steps,
/// * the [`SelectorState`] (async precision decisions + eff-bit stats),
/// * the uploaded async-flag buffers, re-uploaded only when the selector
///   actually changes a flag vector.
pub struct GenState<'s> {
    pub sel: SelectorState<'s>,
    kv: KvResidence,
    /// Next absolute position to decode (== tokens processed so far).
    pub pos: usize,
    /// Per-group (flags at upload time, device buffer).
    flag_bufs: HashMap<String, (Vec<f32>, PjRtBuffer)>,
    /// Decode steps taken through this state.
    pub steps: usize,
    /// Mid-stream target re-selections applied (ServingCore).
    pub retargets: usize,
}

impl<'s> GenState<'s> {
    /// True while the KV cache is device-resident (the O(1)-traffic path).
    pub fn kv_on_device(&self) -> bool {
        matches!(self.kv, KvResidence::Device(_))
    }

    /// Drop cached flag buffers so the next step re-uploads them (used
    /// after a rebind to a session with different thresholds/weights).
    fn invalidate_flags(&mut self) {
        self.flag_bufs.clear();
    }
}

/// A servable model: compiled graphs + device-resident weight stacks.
pub struct DecodeSession {
    rt: Arc<Runtime>,
    pub cfg: ModelConfig,
    pub ec: EngineConfig,
    decode: Arc<Exe>,
    decode_args: Vec<String>,
    /// (bucket_size, exe, arg names)
    prefills: Vec<(usize, Arc<Exe>, Vec<String>)>,
    static_bufs: HashMap<String, PjRtBuffer>,
    prefill_bufs: HashMap<String, PjRtBuffer>,
    kv_zero: Vec<f32>,
    // ---- per-step input caches (device buffers reused across steps and
    // across concurrent generations; the session lives on one executor
    // thread — PJRT handles are !Send — so RefCell suffices) -------------
    rope_bufs: RefCell<HashMap<usize, Rc<(PjRtBuffer, PjRtBuffer)>>>,
    scalar_bufs: RefCell<HashMap<i32, Rc<PjRtBuffer>>>,
    mode_bufs: RefCell<HashMap<bool, Rc<PjRtBuffer>>>,
    rope_hits: Cell<u64>,
    rope_misses: Cell<u64>,
}

impl DecodeSession {
    pub fn new(rt: Arc<Runtime>, assets: &ModelAssets, manifest: &Manifest,
               ec: EngineConfig) -> Result<DecodeSession> {
        let cfg = assets.cfg.clone();
        let decode_entry = manifest.entry(&cfg.name, "decode_step")?;
        let decode = rt.load(&decode_entry)?;

        let mut prefills = Vec::new();
        for p in [64usize, 128, 256] {
            if let Ok(e) = manifest.entry(&cfg.name, &format!("prefill_{p}")) {
                let exe = rt.load(&e)?;
                prefills.push((p, exe, e.args.clone()));
            }
        }
        if prefills.is_empty() {
            bail!("no prefill entries for {}", cfg.name);
        }

        // ---- static decode args -------------------------------------------
        let mut static_bufs = HashMap::new();
        let nl = &assets.nl;
        for (name, t) in [
            ("tok_emb", &nl.tok_emb), ("out_head", &nl.out_head),
            ("final_norm", &nl.final_norm), ("ln1", &nl.ln1), ("ln2", &nl.ln2),
        ] {
            static_bufs.insert(name.to_string(), rt.upload_tensor(t)?);
        }
        for g in GROUPS {
            let store = assets.store.group(g)?;
            let (lb, hb) = ec.group_bits(&cfg, g);
            let wl = store.dequant_stack(&lb)?;
            static_bufs.insert(format!("wl_{g}"), rt.upload_tensor(&wl)?);
            let wh = store.dequant_stack(&hb)?;
            static_bufs.insert(format!("wh_{g}"), rt.upload_tensor(&wh)?);
            let sel = &ec.groups[g];
            static_bufs.insert(
                format!("G_{g}"),
                rt.upload_f32(&sel.g_shape, &sel.g_proj)?,
            );
            let l = cfg.n_layers;
            static_bufs.insert(format!("lina_{g}"), rt.upload_f32(&[l], &sel.lin_a)?);
            static_bufs.insert(format!("linb_{g}"), rt.upload_f32(&[l], &sel.lin_b)?);
            static_bufs.insert(format!("uselin_{g}"), rt.upload_f32(&[l], &sel.use_lin)?);
            static_bufs.insert(format!("thr_{g}"), rt.upload_f32(&[l], &sel.thr)?);
        }

        // ---- prefill weights (paper: highest available precision) ---------
        let mut prefill_bufs = HashMap::new();
        for (name, t) in [
            ("tok_emb", &nl.tok_emb), ("out_head", &nl.out_head),
            ("final_norm", &nl.final_norm), ("ln1", &nl.ln1), ("ln2", &nl.ln2),
        ] {
            prefill_bufs.insert(name.to_string(), rt.upload_tensor(t)?);
        }
        let idx = cfg.linear_index();
        for g in GROUPS {
            let store = assets.store.group(g)?;
            let bits: Vec<u8> = idx
                .iter()
                .enumerate()
                .filter(|(_, (_, gg))| *gg == g)
                .map(|(li, _)| ec.prefill_bits[li])
                .collect();
            let w = store.dequant_stack(&bits)?;
            prefill_bufs.insert(format!("w_{g}"), rt.upload_tensor(&w)?);
        }

        let kv_len: usize = cfg.kv_shape().iter().product();
        Ok(DecodeSession {
            rt,
            decode_args: decode_entry.args.clone(),
            cfg,
            ec,
            decode,
            prefills,
            static_bufs,
            prefill_bufs,
            kv_zero: vec![0.0; kv_len],
            rope_bufs: RefCell::new(HashMap::new()),
            scalar_bufs: RefCell::new(HashMap::new()),
            mode_bufs: RefCell::new(HashMap::new()),
            rope_hits: Cell::new(0),
            rope_misses: Cell::new(0),
        })
    }

    pub fn selector_state(&self) -> SelectorState<'_> {
        SelectorState::new(&self.cfg, &self.ec)
    }

    pub fn zero_kv(&self) -> Vec<f32> {
        self.kv_zero.clone()
    }

    /// (hits, misses) of the per-position rope-table device cache.
    pub fn rope_cache_stats(&self) -> (u64, u64) {
        (self.rope_hits.get(), self.rope_misses.get())
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn prefill_bucket(&self, n: usize) -> Result<usize> {
        self.prefills
            .iter()
            .map(|(p, _, _)| *p)
            .filter(|&p| p >= n)
            .min()
            .ok_or_else(|| anyhow!("prompt of {n} tokens exceeds largest bucket"))
    }

    // ---- cached per-step input buffers -----------------------------------

    fn rope_buffers(&self, pos: usize) -> Result<Rc<(PjRtBuffer, PjRtBuffer)>> {
        if let Some(r) = self.rope_bufs.borrow().get(&pos) {
            self.rope_hits.set(self.rope_hits.get() + 1);
            return Ok(r.clone());
        }
        self.rope_misses.set(self.rope_misses.get() + 1);
        let (cos, sin) = self.cfg.rope_tables(pos);
        let cos_buf = self.rt.upload_f32(&[cos.len()], &cos)?;
        let sin_buf = self.rt.upload_f32(&[sin.len()], &sin)?;
        let rc = Rc::new((cos_buf, sin_buf));
        self.rope_bufs.borrow_mut().insert(pos, rc.clone());
        Ok(rc)
    }

    fn scalar_buffer(&self, v: i32) -> Result<Rc<PjRtBuffer>> {
        // Positions are bounded by max_seq, but token ids range over the
        // whole vocabulary — cap the cache so a long-lived session holds at
        // most max(max_seq, 1024) tiny device buffers, not one per vocab
        // entry ever sampled.  Past the cap, uncached values upload fresh
        // (a 4-byte transfer).
        if let Some(b) = self.scalar_bufs.borrow().get(&v) {
            return Ok(b.clone());
        }
        let rc = Rc::new(self.rt.scalar_i32(v)?);
        let cap = self.cfg.max_seq.max(1024);
        let mut cache = self.scalar_bufs.borrow_mut();
        if cache.len() < cap {
            cache.insert(v, rc.clone());
        }
        Ok(rc)
    }

    fn mode_buffer(&self, exact: bool) -> Result<Rc<PjRtBuffer>> {
        if let Some(b) = self.mode_bufs.borrow().get(&exact) {
            return Ok(b.clone());
        }
        let rc = Rc::new(self.rt.scalar_f32(if exact { 1.0 } else { 0.0 })?);
        self.mode_bufs.borrow_mut().insert(exact, rc.clone());
        Ok(rc)
    }

    /// Upload async flags for groups whose vectors changed since the last
    /// upload (the selector flips layers rarely, so most steps re-use all
    /// five buffers untouched).
    fn refresh_flags(&self, gen: &mut GenState<'_>) -> Result<()> {
        for g in ASYNC_GROUPS {
            let want = gen
                .sel
                .use_h_async
                .get(g)
                .ok_or_else(|| anyhow!("missing async flags for {g}"))?;
            let stale = match gen.flag_bufs.get(g) {
                Some((uploaded, _)) => uploaded != want,
                None => true,
            };
            if stale {
                let buf = self.rt.upload_f32(&[self.cfg.n_layers], want)?;
                gen.flag_bufs.insert(g.to_string(), (want.clone(), buf));
            }
        }
        Ok(())
    }

    // ---- generation lifecycle --------------------------------------------

    /// Start a generation from a prompt: prefill at the highest available
    /// precision, keep the produced KV cache on the device, and return the
    /// handle plus the last-position logits (caller samples token 1).
    pub fn begin(&self, prompt: &[u32]) -> Result<(GenState<'_>, Vec<f32>)> {
        let bucket = self.prefill_bucket(prompt.len())?;
        let (_, exe, args) = self
            .prefills
            .iter()
            .find(|(p, _, _)| *p == bucket)
            .expect("bucket exists");
        let mut padded: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        padded.resize(bucket, 0);
        let tok_buf = self.rt.upload_i32(&[bucket], &padded)?;
        let nv_buf = self.rt.scalar_i32(prompt.len() as i32)?;
        let half = self.cfg.head_dim() / 2;
        let mut cos = Vec::with_capacity(bucket * half);
        let mut sin = Vec::with_capacity(bucket * half);
        for p in 0..bucket {
            let (c, s) = self.cfg.rope_tables(p);
            cos.extend_from_slice(&c);
            sin.extend_from_slice(&s);
        }
        let cos_buf = self.rt.upload_f32(&[bucket, half], &cos)?;
        let sin_buf = self.rt.upload_f32(&[bucket, half], &sin)?;
        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(args.len());
        for name in args {
            arg_bufs.push(match name.as_str() {
                "tokens" => &tok_buf,
                "n_valid" => &nv_buf,
                "cos" => &cos_buf,
                "sin" => &sin_buf,
                other => self
                    .prefill_bufs
                    .get(other)
                    .ok_or_else(|| anyhow!("missing prefill arg {other}"))?,
            });
        }
        let replica = exe.run_buffers(&arg_bufs).context("prefill")?;
        let (kv, logits) = if exe.untupled(&replica) {
            let li = exe.output_index("logits_last")?;
            let ki = exe.output_index("kv")?;
            self.rt.transfers().count_download();
            let logits = buffer_f32(&replica[li])?;
            let mut kv = None;
            for (i, b) in replica.into_iter().enumerate() {
                if i == ki {
                    kv = Some(b);
                }
            }
            (KvResidence::Device(kv.expect("kv index in range")), logits)
        } else {
            let out = exe.outputs(replica)?;
            (KvResidence::Host(out.f32_vec("kv")?), out.f32_vec("logits_last")?)
        };
        Ok((
            GenState {
                sel: self.selector_state(),
                kv,
                pos: prompt.len(),
                flag_bufs: HashMap::new(),
                steps: 0,
                retargets: 0,
            },
            logits,
        ))
    }

    /// Start a generation from an empty (zeroed) KV cache at position 0 —
    /// teacher-forced perplexity and TPOT measurement.
    pub fn begin_empty(&self) -> Result<GenState<'_>> {
        let kv_buf = self.rt.upload_f32(&self.cfg.kv_shape(), &self.kv_zero)?;
        Ok(GenState {
            sel: self.selector_state(),
            kv: KvResidence::Device(kv_buf),
            pos: 0,
            flag_bufs: HashMap::new(),
            steps: 0,
            retargets: 0,
        })
    }

    /// Take over a generation started on a sibling session of the same
    /// model (mid-stream target re-selection).  The device KV cache and
    /// accumulated statistics carry over; the selector re-binds to this
    /// session's thresholds and the flag buffers are re-uploaded next step.
    pub fn adopt<'s>(&'s self, gen: &mut GenState<'s>) {
        gen.sel.rebind(&self.cfg, &self.ec);
        gen.invalidate_flags();
        gen.retargets += 1;
    }

    /// One decode step: feed `token` at `gen.pos`, advance the state.
    /// Updates the selector (async flags + effective-bit accounting)
    /// internally; the returned [`StepOut`] carries only host-readable
    /// per-step outputs.
    pub fn advance(&self, gen: &mut GenState<'_>, token: u32, mode: EstMode)
                   -> Result<StepOut> {
        if gen.pos + 1 >= self.cfg.max_seq {
            bail!("position {} at max_seq {}", gen.pos, self.cfg.max_seq);
        }
        let tok_buf = self.scalar_buffer(token as i32)?;
        let pos_buf = self.scalar_buffer(gen.pos as i32)?;
        let rope = self.rope_buffers(gen.pos)?;
        let mode_buf = self.mode_buffer(mode == EstMode::Exact)?;
        self.refresh_flags(gen)?;
        // Host-KV fallback: upload for this step only (tuple-lowered graph).
        let kv_upload = match &gen.kv {
            KvResidence::Device(_) => None,
            KvResidence::Host(v) => Some(self.rt.upload_f32(&self.cfg.kv_shape(), v)?),
        };

        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(self.decode_args.len());
        for name in &self.decode_args {
            arg_bufs.push(match name.as_str() {
                "token" => &*tok_buf,
                "pos" => &*pos_buf,
                "cos" => &rope.0,
                "sin" => &rope.1,
                "kv" => match (&gen.kv, &kv_upload) {
                    (KvResidence::Device(b), _) => b,
                    (_, Some(b)) => b,
                    _ => unreachable!("host kv uploaded above"),
                },
                "mode_exact" => &*mode_buf,
                other => gen
                    .flag_bufs
                    .get(other.strip_prefix("useh_").unwrap_or(other))
                    .map(|(_, b)| b)
                    .or_else(|| self.static_bufs.get(other))
                    .ok_or_else(|| anyhow!("missing decode arg {other}"))?,
            });
        }
        let replica = self.decode.run_buffers(&arg_bufs).context("decode step")?;

        let out = if self.decode.untupled(&replica) {
            // Device-resident path: read only the small outputs, keep KV on
            // the device for the next step.
            let mut ests = BTreeMap::new();
            let mut use_eff = BTreeMap::new();
            for g in GROUPS {
                let ei = self.decode.output_index(&format!("est_{g}"))?;
                let ui = self.decode.output_index(&format!("useh_{g}"))?;
                ests.insert(g.to_string(), buffer_f32(&replica[ei])?);
                use_eff.insert(g.to_string(), buffer_f32(&replica[ui])?);
            }
            let li = self.decode.output_index("logits")?;
            let logits = buffer_f32(&replica[li])?;
            self.rt.transfers().count_download();
            let ki = self.decode.output_index("kv")?;
            for (i, b) in replica.into_iter().enumerate() {
                if i == ki {
                    gen.kv = KvResidence::Device(b);
                }
            }
            StepOut { logits, ests, use_eff }
        } else {
            // Tuple fallback: full host decomposition (legacy artifacts).
            let parts = self.decode.outputs(replica)?;
            let mut ests = BTreeMap::new();
            let mut use_eff = BTreeMap::new();
            for g in GROUPS {
                ests.insert(g.to_string(), parts.f32_vec(&format!("est_{g}"))?);
                use_eff.insert(g.to_string(), parts.f32_vec(&format!("useh_{g}"))?);
            }
            gen.kv = KvResidence::Host(parts.f32_vec("kv")?);
            StepOut { logits: parts.f32_vec("logits")?, ests, use_eff }
        };

        gen.sel.observe(&out.ests, &out.use_eff);
        gen.pos += 1;
        gen.steps += 1;
        Ok(out)
    }

    /// Greedy argmax over logits.  NaN entries are skipped; empty or
    /// all-NaN logits are an error — silently emitting token 0 (the old
    /// behavior) corrupted generations downstream.
    pub fn argmax(logits: &[f32]) -> Result<u32> {
        if logits.is_empty() {
            bail!("argmax over empty logits");
        }
        let mut best: Option<usize> = None;
        for (i, &v) in logits.iter().enumerate() {
            if v.is_nan() {
                continue;
            }
            match best {
                Some(b) if v <= logits[b] => {}
                _ => best = Some(i),
            }
        }
        best.map(|b| b as u32)
            .ok_or_else(|| anyhow!("argmax over all-NaN logits"))
    }

    /// Host-visible device memory of the uploaded weight stacks (bytes) —
    /// used by the Table 9 memory-accounting bench.
    pub fn weight_bytes(&self) -> usize {
        let mut total = 0usize;
        for g in GROUPS {
            let (o, i) = self.cfg.group_shape(g);
            total += 2 * self.cfg.n_layers * o * i * 4; // wl + wh stacks
        }
        total
    }

    /// Bytes of one KV cache at this model's shape — the per-step traffic
    /// the device-resident path eliminates.
    pub fn kv_bytes(&self) -> usize {
        self.kv_zero.len() * 4
    }
}

pub fn wrap_err(e: impl std::fmt::Display) -> anyhow::Error {
    wrap(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(DecodeSession::argmax(&[0.1, 3.0, -1.0, 2.9]).unwrap(), 1);
        assert_eq!(DecodeSession::argmax(&[-5.0]).unwrap(), 0);
    }

    #[test]
    fn argmax_skips_nan() {
        assert_eq!(
            DecodeSession::argmax(&[f32::NAN, 1.0, 2.0, f32::NAN]).unwrap(),
            2
        );
        // NaN in first position must not poison the comparison chain.
        assert_eq!(DecodeSession::argmax(&[f32::NAN, -1.0]).unwrap(), 1);
    }

    #[test]
    fn argmax_rejects_empty_and_all_nan() {
        assert!(DecodeSession::argmax(&[]).is_err());
        assert!(DecodeSession::argmax(&[f32::NAN, f32::NAN]).is_err());
    }

    #[test]
    fn argmax_handles_neg_infinity() {
        assert_eq!(
            DecodeSession::argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY, -1.0])
                .unwrap(),
            2
        );
    }
}
