//! Decode session: one (model, engine-config) pair bound to the PJRT
//! executables, with weights resident on the device.
//!
//! Request path per token:
//!   1. upload ~(5·L + 3) small host values (token, pos, async flags),
//!   2. `execute_b` the decode graph,
//!   3. read back logits + per-linear estimates (+ carry the KV cache),
//!   4. [`SelectorState::observe`] turns estimates into next-step flags.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::PjRtBuffer;

use crate::anyprec::GROUPS;
use crate::model::{Manifest, ModelAssets, ModelConfig};
use crate::runtime::{wrap, Exe, Outputs, Runtime};
use crate::selector::{EngineConfig, SelectorState, ASYNC_GROUPS};

/// Estimator source for a step (Table 3 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstMode {
    /// Hybrid approximate estimators + async selection (production path).
    Approx,
    /// Exact ‖W_h x − W_l x‖ for every selection, fully synchronous.
    Exact,
}

pub struct StepOut {
    pub logits: Vec<f32>,
    /// KV cache to feed into the next step (host copy; see DESIGN §Perf).
    pub kv: Vec<f32>,
    pub ests: BTreeMap<String, Vec<f32>>,
    pub use_eff: BTreeMap<String, Vec<f32>>,
}

pub struct PrefillOut {
    pub logits: Vec<f32>,
    pub kv: Vec<f32>,
}

/// A servable model: compiled graphs + device-resident weight stacks.
pub struct DecodeSession {
    rt: Arc<Runtime>,
    pub cfg: ModelConfig,
    pub ec: EngineConfig,
    decode: Arc<Exe>,
    decode_args: Vec<String>,
    /// (bucket_size, exe, arg names)
    prefills: Vec<(usize, Arc<Exe>, Vec<String>)>,
    static_bufs: HashMap<String, PjRtBuffer>,
    prefill_bufs: HashMap<String, PjRtBuffer>,
    kv_zero: Vec<f32>,
}

impl DecodeSession {
    pub fn new(rt: Arc<Runtime>, assets: &ModelAssets, manifest: &Manifest,
               ec: EngineConfig) -> Result<DecodeSession> {
        let cfg = assets.cfg.clone();
        let decode_entry = manifest.entry(&cfg.name, "decode_step")?;
        let decode = rt.load(&decode_entry)?;

        let mut prefills = Vec::new();
        for p in [64usize, 128, 256] {
            if let Ok(e) = manifest.entry(&cfg.name, &format!("prefill_{p}")) {
                let exe = rt.load(&e)?;
                prefills.push((p, exe, e.args.clone()));
            }
        }
        if prefills.is_empty() {
            bail!("no prefill entries for {}", cfg.name);
        }

        // ---- static decode args -------------------------------------------
        let mut static_bufs = HashMap::new();
        let nl = &assets.nl;
        for (name, t) in [
            ("tok_emb", &nl.tok_emb), ("out_head", &nl.out_head),
            ("final_norm", &nl.final_norm), ("ln1", &nl.ln1), ("ln2", &nl.ln2),
        ] {
            static_bufs.insert(name.to_string(), rt.upload_tensor(t)?);
        }
        for g in GROUPS {
            let store = assets.store.group(g)?;
            let (lb, hb) = ec.group_bits(&cfg, g);
            let wl = store.dequant_stack(&lb)?;
            static_bufs.insert(format!("wl_{g}"), rt.upload_tensor(&wl)?);
            let wh = store.dequant_stack(&hb)?;
            static_bufs.insert(format!("wh_{g}"), rt.upload_tensor(&wh)?);
            let sel = &ec.groups[g];
            static_bufs.insert(
                format!("G_{g}"),
                rt.upload_f32(&sel.g_shape, &sel.g_proj)?,
            );
            let l = cfg.n_layers;
            static_bufs.insert(format!("lina_{g}"), rt.upload_f32(&[l], &sel.lin_a)?);
            static_bufs.insert(format!("linb_{g}"), rt.upload_f32(&[l], &sel.lin_b)?);
            static_bufs.insert(format!("uselin_{g}"), rt.upload_f32(&[l], &sel.use_lin)?);
            static_bufs.insert(format!("thr_{g}"), rt.upload_f32(&[l], &sel.thr)?);
        }

        // ---- prefill weights (paper: highest available precision) ---------
        let mut prefill_bufs = HashMap::new();
        for (name, t) in [
            ("tok_emb", &nl.tok_emb), ("out_head", &nl.out_head),
            ("final_norm", &nl.final_norm), ("ln1", &nl.ln1), ("ln2", &nl.ln2),
        ] {
            prefill_bufs.insert(name.to_string(), rt.upload_tensor(t)?);
        }
        let idx = cfg.linear_index();
        for g in GROUPS {
            let store = assets.store.group(g)?;
            let bits: Vec<u8> = idx
                .iter()
                .enumerate()
                .filter(|(_, (_, gg))| *gg == g)
                .map(|(li, _)| ec.prefill_bits[li])
                .collect();
            let w = store.dequant_stack(&bits)?;
            prefill_bufs.insert(format!("w_{g}"), rt.upload_tensor(&w)?);
        }

        let kv_len: usize = cfg.kv_shape().iter().product();
        Ok(DecodeSession {
            rt,
            decode_args: decode_entry.args.clone(),
            cfg,
            ec,
            decode,
            prefills,
            static_bufs,
            prefill_bufs,
            kv_zero: vec![0.0; kv_len],
        })
    }

    pub fn selector_state(&self) -> SelectorState<'_> {
        SelectorState::new(&self.cfg, &self.ec)
    }

    pub fn zero_kv(&self) -> Vec<f32> {
        self.kv_zero.clone()
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn prefill_bucket(&self, n: usize) -> Result<usize> {
        self.prefills
            .iter()
            .map(|(p, _, _)| *p)
            .filter(|&p| p >= n)
            .min()
            .ok_or_else(|| anyhow!("prompt of {n} tokens exceeds largest bucket"))
    }

    /// Run prefill at the highest available precision.
    pub fn prefill(&self, prompt: &[u32]) -> Result<PrefillOut> {
        let bucket = self.prefill_bucket(prompt.len())?;
        let (_, exe, args) = self
            .prefills
            .iter()
            .find(|(p, _, _)| *p == bucket)
            .expect("bucket exists");
        let mut padded: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        padded.resize(bucket, 0);
        let tok_buf = self.rt.upload_i32(&[bucket], &padded)?;
        let nv_buf = self.rt.scalar_i32(prompt.len() as i32)?;
        let half = self.cfg.head_dim() / 2;
        let mut cos = Vec::with_capacity(bucket * half);
        let mut sin = Vec::with_capacity(bucket * half);
        for p in 0..bucket {
            let (c, s) = self.cfg.rope_tables(p);
            cos.extend_from_slice(&c);
            sin.extend_from_slice(&s);
        }
        let cos_buf = self.rt.upload_f32(&[bucket, half], &cos)?;
        let sin_buf = self.rt.upload_f32(&[bucket, half], &sin)?;
        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(args.len());
        for name in args {
            arg_bufs.push(match name.as_str() {
                "tokens" => &tok_buf,
                "n_valid" => &nv_buf,
                "cos" => &cos_buf,
                "sin" => &sin_buf,
                other => self
                    .prefill_bufs
                    .get(other)
                    .ok_or_else(|| anyhow!("missing prefill arg {other}"))?,
            });
        }
        let out = exe.run(&arg_bufs)?;
        Ok(PrefillOut {
            logits: out.f32_vec("logits_last")?,
            kv: out.f32_vec("kv")?,
        })
    }

    /// One decode step.  `use_h_async` comes from [`SelectorState`].
    pub fn step(&self, token: u32, pos: usize, kv: &[f32],
                use_h_async: &BTreeMap<String, Vec<f32>>, mode: EstMode)
                -> Result<StepOut> {
        let tok_buf = self.rt.scalar_i32(token as i32)?;
        let pos_buf = self.rt.scalar_i32(pos as i32)?;
        let (cos, sin) = self.cfg.rope_tables(pos);
        let cos_buf = self.rt.upload_f32(&[cos.len()], &cos)?;
        let sin_buf = self.rt.upload_f32(&[sin.len()], &sin)?;
        let kv_buf = self.rt.upload_f32(&self.cfg.kv_shape(), kv)?;
        let mode_buf = self
            .rt
            .scalar_f32(if mode == EstMode::Exact { 1.0 } else { 0.0 })?;
        let mut flag_bufs: HashMap<String, PjRtBuffer> = HashMap::new();
        for g in ASYNC_GROUPS {
            let flags = use_h_async
                .get(g)
                .ok_or_else(|| anyhow!("missing async flags for {g}"))?;
            flag_bufs.insert(
                format!("useh_{g}"),
                self.rt.upload_f32(&[self.cfg.n_layers], flags)?,
            );
        }

        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(self.decode_args.len());
        for name in &self.decode_args {
            arg_bufs.push(match name.as_str() {
                "token" => &tok_buf,
                "pos" => &pos_buf,
                "cos" => &cos_buf,
                "sin" => &sin_buf,
                "kv" => &kv_buf,
                "mode_exact" => &mode_buf,
                other => flag_bufs
                    .get(other)
                    .or_else(|| self.static_bufs.get(other))
                    .ok_or_else(|| anyhow!("missing decode arg {other}"))?,
            });
        }
        let out = self.decode.run(&arg_bufs).context("decode step")?;
        self.unpack_step(out)
    }

    fn unpack_step(&self, out: Outputs) -> Result<StepOut> {
        let mut ests = BTreeMap::new();
        let mut use_eff = BTreeMap::new();
        for g in GROUPS {
            ests.insert(g.to_string(), out.f32_vec(&format!("est_{g}"))?);
            use_eff.insert(g.to_string(), out.f32_vec(&format!("useh_{g}"))?);
        }
        Ok(StepOut {
            logits: out.f32_vec("logits")?,
            kv: out.f32_vec("kv")?,
            ests,
            use_eff,
        })
    }

    /// Convenience: greedy argmax over logits.
    pub fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as u32
    }

    /// Host-visible device memory of the uploaded weight stacks (bytes) —
    /// used by the Table 9 memory-accounting bench.
    pub fn weight_bytes(&self) -> usize {
        let mut total = 0usize;
        for g in GROUPS {
            let (o, i) = self.cfg.group_shape(g);
            total += 2 * self.cfg.n_layers * o * i * 4; // wl + wh stacks
        }
        total
    }
}

pub fn wrap_err(e: impl std::fmt::Display) -> anyhow::Error {
    wrap(e)
}
