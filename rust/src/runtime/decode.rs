//! Decode session + device-resident generation state.
//!
//! Request path per token (DESIGN.md §Perf):
//!   1. look up the token/pos scalars, rope tables and async-flag vectors in
//!      the device-buffer caches (upload only on miss / flag change),
//!   2. `execute_b` the decode graph with the **device-resident** KV cache
//!      from the previous step,
//!   3. read back only the small outputs (logits + per-linear estimates);
//!      the new KV buffer replaces the old one *on the device*,
//!   4. [`SelectorState::observe`] turns estimates into next-step flags.
//!
//! The KV cache — the only O(model · seq) tensor in the loop — never
//! crosses the host boundary after prefill, so per-token host↔device
//! traffic is O(1) in KV size.  When an AOT graph was lowered as a single
//! tuple (older artifacts), [`GenState`] degrades to a host round-trip and
//! reports it via [`GenState::kv_on_device`].
//!
//! Concurrent requests additionally share device *dispatches*:
//! [`DecodeSession::advance_batch`] packs up to `max_batch` generations
//! into one `decode_step_b{2,4,8}` graph call (leading batch dim on the
//! per-request inputs, per-slot `kv<i>` parameters/outputs so each KV
//! cache stays an independent device buffer), cutting dispatch calls per
//! generated token from 1.0 to ~1/B — DESIGN.md §Batching.
//!
//! A *single* request can instead amortize dispatches through
//! self-speculative decoding: [`DecodeSession::advance_verify`] scores γ
//! draft tokens plus one bonus position against the KV cache in one
//! `verify_step_g{2,4}` dispatch, and the `runtime::spec` layer turns the
//! low-bit overlay variant into a free draft model — DESIGN.md
//! §Speculation.
//!
//! Prompt ingestion is a schedulable unit of work too: where the
//! monolithic `prefill_<P>` graphs build a KV cache from scratch (and cap
//! the prompt at the largest bucket), the `prefill_chunk_<P>` graphs take
//! the existing device-resident cache plus a position offset and append P
//! causal positions — [`DecodeSession::begin_chunked`] +
//! [`DecodeSession::prefill_advance`] ingest a prompt of any length up to
//! `max_seq` as a chain of bounded dispatches the serving core interleaves
//! with decode traffic, one chunk per scheduling round (DESIGN.md
//! §Prefill).

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::PjRtBuffer;

use crate::anyprec::materialize::{changed_layers, MatKey, MatSnapshot, MaterializeCache};
use crate::anyprec::{AnyPrecStore, GroupStore, GROUPS};
use crate::model::{Manifest, ModelAssets, ModelConfig};
use crate::runtime::kvpool::{self, KvCaster, SharedKvPool};
use crate::runtime::stack::Stacker;
use crate::runtime::{buffer_f32, wrap, Exe, Runtime};
use crate::selector::{EngineConfig, SelectorState, ASYNC_GROUPS};

/// Default byte budget for the host slabs held by a weight
/// materialization cache (the device mirrors are bounded by the same
/// figure; see `anyprec::materialize`).
pub const DEFAULT_WEIGHT_CACHE_BYTES: usize = 256 << 20;

/// The per-(group, layer, bits) weight materialization cache, shareable
/// across every [`DecodeSession`] of one model on one executor thread
/// (PJRT handles are `!Send`, hence `Rc<RefCell<..>>`).  The device
/// mirror is `None` when the entry was materialized while the
/// device-side stack-concat path was unavailable — those entries carry
/// only the host slab and stacks assemble through the host fallback.
pub type WeightCache = Rc<RefCell<MaterializeCache<Option<PjRtBuffer>>>>;

/// Estimator source for a step (Table 3 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstMode {
    /// Hybrid approximate estimators + async selection (production path).
    Approx,
    /// Exact ‖W_h x − W_l x‖ for every selection, fully synchronous.
    Exact,
}

/// Host-visible results of one decode step.  The KV cache is *not* here —
/// it stays on the device inside [`GenState`].
pub struct StepOut {
    pub logits: Vec<f32>,
    pub ests: BTreeMap<String, Vec<f32>>,
    pub use_eff: BTreeMap<String, Vec<f32>>,
}

/// Host-visible results of one speculative-verification dispatch
/// ([`DecodeSession::advance_verify`]): γ+1 positions' logits, estimates
/// and effective selection flags, each with a leading position dim.  The
/// updated KV cache (all γ+1 positions written) stays on the device in
/// the [`GenState`]; the caller commits acceptance separately —
/// `runtime::spec::spec_round` keeps the longest accepted draft prefix,
/// observes exactly the kept positions on the selector, and advances the
/// position counter past them (stale KV entries beyond the counter are
/// masked by the attention and overwritten on re-decode).
pub struct VerifyOut {
    /// Positions scored (γ + 1).
    pub n_pos: usize,
    pub vocab: usize,
    n_layers: usize,
    /// Flattened `[n_pos, vocab]`; `logits_at(i)` scores position
    /// `pos0 + i + 1`'s token.
    pub logits: Vec<f32>,
    /// Per group, flattened `[n_pos, L]`.
    pub ests: BTreeMap<String, Vec<f32>>,
    pub use_eff: BTreeMap<String, Vec<f32>>,
}

impl VerifyOut {
    pub fn logits_at(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    /// The per-position slice as a [`StepOut`] — exactly what a
    /// sequential [`DecodeSession::advance`] at that position would have
    /// returned (pinned by the jax-level parity test), so the selector
    /// can [`SelectorState::observe`] accepted positions one by one.
    pub fn step_out(&self, i: usize) -> StepOut {
        let l = self.n_layers;
        let slice = |m: &BTreeMap<String, Vec<f32>>| {
            m.iter()
                .map(|(g, v)| (g.clone(), v[i * l..(i + 1) * l].to_vec()))
                .collect()
        };
        StepOut {
            logits: self.logits_at(i).to_vec(),
            ests: slice(&self.ests),
            use_eff: slice(&self.use_eff),
        }
    }
}

/// Where a generation's KV cache currently lives.
enum KvResidence {
    /// On the device; fed straight back into the next `execute_b`.
    Device(PjRtBuffer),
    /// On the device but owned by the shared-prefix cache (copy-on-
    /// write): dispatches never mutate their inputs, so the shared
    /// buffer is read directly and the generation's first dispatch
    /// output becomes its private [`KvResidence::Device`] buffer.
    Shared(Rc<PjRtBuffer>),
    /// Host fallback (tuple-lowered graph): re-uploaded each step.
    Host(Vec<f32>),
}

/// Pool accounting attached to a [`GenState`]: the charged bytes are
/// credited back (and the residency gauge decremented) when the lease
/// drops, so completion, eviction, wholesale `GenState` replacement,
/// and mid-construction error paths all funnel through one destructor
/// and the pool can never leak a tier.
struct PoolLease {
    pool: SharedKvPool,
    rt: Arc<Runtime>,
    tier: usize,
}

impl Drop for PoolLease {
    fn drop(&mut self) {
        let bytes = self.pool.borrow().tier_bytes(self.tier);
        self.pool.borrow_mut().release(self.tier, None);
        self.rt.transfers().count_kv_release(bytes as u64);
    }
}

/// Per-request device-resident generation handle.
///
/// Created by [`DecodeSession::begin`] (prefill) or
/// [`DecodeSession::begin_empty`] (zero KV, teacher-forcing/benches) and
/// advanced one token at a time by [`DecodeSession::advance`].  Owns:
///
/// * the KV cache as a device buffer carried across steps,
/// * the [`SelectorState`] (async precision decisions + eff-bit stats),
/// * the uploaded async-flag buffers, re-uploaded only when the selector
///   actually changes a flag vector.
pub struct GenState<'s> {
    pub sel: SelectorState<'s>,
    kv: KvResidence,
    /// Next absolute position to decode (== tokens processed so far).
    pub pos: usize,
    /// Per-group (flags at upload time, device buffer).
    flag_bufs: HashMap<String, (Vec<f32>, PjRtBuffer)>,
    /// Decode steps taken through this state.
    pub steps: usize,
    /// Mid-stream target re-selections applied (ServingCore).
    pub retargets: usize,
    /// KV sequence capacity of the current buffer (== `cfg.max_seq`
    /// without an active tier ladder; see `runtime::kvpool`).
    tier: usize,
    /// Byte accounting against the shared KV pool (None off the pool).
    lease: Option<PoolLease>,
}

impl<'s> GenState<'s> {
    /// True while the KV cache is device-resident (the O(1)-traffic
    /// path) — privately owned or shared from the prefix cache.
    pub fn kv_on_device(&self) -> bool {
        matches!(self.kv, KvResidence::Device(_) | KvResidence::Shared(_))
    }

    /// KV sequence capacity of the current buffer (tier ladder).
    pub fn kv_tier(&self) -> usize {
        self.tier
    }

    /// True while the KV buffer is a copy-on-write reference into the
    /// shared-prefix cache (cleared by the first dispatch).
    pub fn kv_shared(&self) -> bool {
        matches!(self.kv, KvResidence::Shared(_))
    }

    /// Drop cached flag buffers so the next step re-uploads them (used
    /// after a rebind to a session with different thresholds/weights).
    fn invalidate_flags(&mut self) {
        self.flag_bufs.clear();
    }

    /// Rewind the position counter to `pos` (≤ current) — the KV
    /// "rollback" of speculative decoding.  Nothing touches the device:
    /// KV slots past `pos` keep their (now stale) contents, but the
    /// decode graphs mask attention to `arange(S) <= pos`, so stale
    /// entries are never attended and are overwritten in place when
    /// those positions are re-decoded.  `steps`/selector statistics are
    /// deliberately NOT rewound (they count real device work).
    pub fn rewind(&mut self, pos: usize) {
        debug_assert!(pos <= self.pos, "rewind forward ({} -> {pos})", self.pos);
        self.pos = pos.min(self.pos);
    }
}

impl Drop for GenState<'_> {
    fn drop(&mut self) {
        let Some(lease) = self.lease.take() else { return };
        let kv = std::mem::replace(&mut self.kv, KvResidence::Host(Vec::new()));
        let (pool, tier) = (lease.pool.clone(), lease.tier);
        // Credit the charged bytes first so the donation fits the budget.
        drop(lease);
        // Only a privately owned device buffer recycles (stale contents
        // are fine — see kvpool); shared/host residences have nothing to
        // donate.
        if let KvResidence::Device(b) = kv {
            pool.borrow_mut().donate(tier, b);
        }
    }
}

/// What a [`DecodeSession::swap_bits`] rebind actually did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SwapReport {
    /// Group stacks re-assembled (wl / wh / prefill count separately).
    pub stacks_rebuilt: usize,
    /// Layer-level bit-assignment changes across all rebuilt stacks.
    pub layers_changed: usize,
    /// Small selector-parameter buffers re-uploaded.
    pub selector_uploads: usize,
}

impl SwapReport {
    pub fn absorb(&mut self, other: SwapReport) {
        self.stacks_rebuilt += other.stacks_rebuilt;
        self.layers_changed += other.layers_changed;
        self.selector_uploads += other.selector_uploads;
    }
}

/// A servable model: compiled graphs + device-resident weight stacks.
pub struct DecodeSession {
    rt: Arc<Runtime>,
    pub cfg: ModelConfig,
    pub ec: EngineConfig,
    /// The packed store the stacks were materialized from; retained so
    /// [`DecodeSession::swap_bits`] can re-dequantize changed layers.
    store: Arc<AnyPrecStore>,
    /// Per-(group, layer, bits) host slabs + uploaded per-layer buffers.
    weights: WeightCache,
    /// Device-side `[1,out,in] × L → [L,out,in]` stack assembler.
    stacker: Stacker,
    decode: Arc<Exe>,
    decode_args: Vec<String>,
    /// Batched decode entries, ascending bucket size: (B, exe, arg names).
    /// Empty when the artifacts predate the batched AOT export — every
    /// caller then falls back to per-request [`DecodeSession::advance`].
    batched: Vec<(usize, Arc<Exe>, Vec<String>)>,
    /// Speculative-verification entries, ascending γ: (γ, exe, arg
    /// names).  Empty when the artifacts predate the `verify_step_g*`
    /// AOT export — the speculation path then degrades gracefully to
    /// plain per-token decode ([`DecodeSession::spec_gammas`]).
    verifies: Vec<(usize, Arc<Exe>, Vec<String>)>,
    /// Zero KV cache backing the masked padding slots of a partially
    /// filled batch (uploaded lazily, shared by all pad slots of all
    /// batched steps — inputs are not donated, so aliasing one buffer
    /// across several `kv<i>` parameters is safe).
    pad_kv: RefCell<Option<Rc<PjRtBuffer>>>,
    /// (bucket_size, exe, arg names)
    prefills: Vec<(usize, Arc<Exe>, Vec<String>)>,
    /// Chunked-prefill entries, ascending bucket size: (P, exe, arg
    /// names).  Unlike `prefills` these take the EXISTING KV cache plus a
    /// position offset and append P causal positions (the decode-step KV
    /// leaf protocol), so a prompt of any length up to `max_seq` ingests
    /// as a chain of bounded dispatches ([`DecodeSession::prefill_advance`]).
    /// Empty when the artifacts predate the `prefill_chunk_*` AOT export —
    /// ingestion then stays on the bucketed [`DecodeSession::begin`].
    prefill_chunks: Vec<(usize, Arc<Exe>, Vec<String>)>,
    /// Tier-shaped decode graphs (`decode_step_s{S}`) keyed by KV tier
    /// `S < max_seq` — optional AOT entries; absent tiers simply aren't
    /// offered and generations stay at `max_seq` shape (tier-1 behavior
    /// unchanged).  A tier is only listed when its chunked-prefill
    /// graphs cover every canonical chunk bucket, so ingestion never
    /// faces a bucket its tier can't dispatch.
    tier_decodes: BTreeMap<usize, (Arc<Exe>, Vec<String>)>,
    /// Tier-shaped chunked-prefill graphs (`prefill_chunk_{P}_s{S}`):
    /// tier -> ascending (P, exe, args).
    tier_chunks: BTreeMap<usize, Vec<(usize, Arc<Exe>, Vec<String>)>>,
    /// Shared byte-budgeted KV pool (None → every generation owns a
    /// `max_seq` buffer and no byte accounting happens — the historical
    /// behavior).  Installed by [`DecodeSession::set_kv_pool`].
    pool: Option<SharedKvPool>,
    /// Device-side tier-migration / snapshot graphs (pad / copy).
    caster: KvCaster,
    /// Target-stack identity for prefix-cache keying (precision targets
    /// must not share prefix KV — their prefill stacks differ).
    tag: String,
    static_bufs: HashMap<String, PjRtBuffer>,
    prefill_bufs: HashMap<String, PjRtBuffer>,
    kv_zero: Vec<f32>,
    // ---- per-step input caches (device buffers reused across steps and
    // across concurrent generations; the session lives on one executor
    // thread — PJRT handles are !Send — so RefCell suffices) -------------
    rope_bufs: RefCell<HashMap<usize, Rc<(PjRtBuffer, PjRtBuffer)>>>,
    scalar_bufs: RefCell<HashMap<i32, Rc<PjRtBuffer>>>,
    mode_bufs: RefCell<HashMap<bool, Rc<PjRtBuffer>>>,
    rope_hits: Cell<u64>,
    rope_misses: Cell<u64>,
}

/// Per-layer bits of one group under a per-linear assignment (canonical
/// `linear_index` order).
fn group_layer_bits(cfg: &ModelConfig, per_linear: &[u8], g: &str) -> Vec<u8> {
    cfg.linear_index()
        .iter()
        .enumerate()
        .filter(|(_, (_, gg))| *gg == g)
        .map(|(li, _)| per_linear[li])
        .collect()
}

/// Materialize one group's `[L, out, in]` stack through the weight cache:
/// per-layer slabs dequantize (+ upload, when the device-side concat is
/// available for this shape) only on cache miss, then the stack assembles
/// device-side — or from the host slabs in one upload when it isn't
/// ([`Stacker::stack`]).  Gating the per-layer uploads on
/// [`Stacker::device_side`] keeps the fallback path at exactly one
/// O(stack) upload instead of paying both.
fn materialize_stack(rt: &Arc<Runtime>, stacker: &Stacker,
                     cache: &WeightCache, store: &GroupStore, g: &str,
                     bits: &[u8]) -> Result<PjRtBuffer> {
    let (o, i) = (store.out_dim, store.in_dim);
    let dims = (bits.len(), o, i);
    let device = stacker.device_side(dims);
    let mut dev = Vec::with_capacity(bits.len());
    let mut host = Vec::with_capacity(bits.len());
    {
        let mut cache = cache.borrow_mut();
        for (layer, &b) in bits.iter().enumerate() {
            let key = MatKey { group: g.to_string(), layer, bits: b };
            let (h, d) = cache.get_or_materialize(&key, |k| {
                let mut slab = vec![0f32; o * i];
                store.dequant_into(k.layer, k.bits, &mut slab)?;
                let buf = if device {
                    Some(rt.upload_f32(&[1, o, i], &slab)?)
                } else {
                    None
                };
                Ok((slab, buf))
            })?;
            host.push(h);
            dev.push(d);
        }
    }
    // The device path needs every layer's mirror; entries cached while it
    // was unavailable lack one, and then the host path takes over.
    let dev_refs: Vec<&PjRtBuffer> =
        dev.iter().filter_map(|b| Option::as_ref(b)).collect();
    let dev_refs = if dev_refs.len() == bits.len() { dev_refs } else { Vec::new() };
    let host_refs: Vec<&[f32]> = host.iter().map(|h| h.as_slice()).collect();
    stacker.stack(dims, &dev_refs, &host_refs)
}

impl DecodeSession {
    /// Fresh weight cache at the default byte budget — share one across
    /// every session of a model so configurations at different targets
    /// materialize each (group, layer, bits) slab once, and so rebinds
    /// ([`DecodeSession::swap_bits`]) stay O(changed layers).
    pub fn fresh_weight_cache() -> WeightCache {
        Rc::new(RefCell::new(MaterializeCache::new(DEFAULT_WEIGHT_CACHE_BYTES)))
    }

    /// One-shot construction (benches, eval sweeps).  Materializes through
    /// a **zero-budget** cache: nothing is retained beyond the stack being
    /// assembled, so memory residency matches the pre-cache design (one
    /// stacked copy per group).  Long-lived serving paths that rebind
    /// should use [`DecodeSession::new_shared`] with a retaining cache
    /// ([`DecodeSession::fresh_weight_cache`]) — `ServingEngine` does.
    pub fn new(rt: Arc<Runtime>, assets: &ModelAssets, manifest: &Manifest,
               ec: EngineConfig) -> Result<DecodeSession> {
        Self::new_shared(rt, assets, manifest, ec,
                         Rc::new(RefCell::new(MaterializeCache::new(0))))
    }

    /// [`DecodeSession::new`] materializing through a caller-provided
    /// (typically shared) weight cache: layers whose (group, layer, bits)
    /// slab is already cached are neither re-dequantized nor re-uploaded.
    pub fn new_shared(rt: Arc<Runtime>, assets: &ModelAssets, manifest: &Manifest,
                      ec: EngineConfig, weights: WeightCache)
                      -> Result<DecodeSession> {
        let cfg = assets.cfg.clone();
        let decode_entry = manifest.entry(&cfg.name, "decode_step")?;
        let decode = rt.load(&decode_entry)?;

        // Batched buckets are optional (older manifests lack them); a
        // *present-but-broken* batched artifact fails loudly rather than
        // silently degrading the serving path to per-request dispatch.
        let mut batched = Vec::new();
        for b in [2usize, 4, 8] {
            if let Ok(e) = manifest.entry(&cfg.name, &format!("decode_step_b{b}")) {
                let exe = rt.load(&e)?;
                batched.push((b, exe, e.args.clone()));
            }
        }

        // Verify entries are optional the same way: absent → speculation
        // degrades to plain decode; present-but-broken → loud failure.
        let mut verifies = Vec::new();
        for g in [2usize, 4] {
            if let Ok(e) = manifest.entry(&cfg.name, &format!("verify_step_g{g}")) {
                let exe = rt.load(&e)?;
                verifies.push((g, exe, e.args.clone()));
            }
        }

        let mut prefills = Vec::new();
        for p in [64usize, 128, 256] {
            if let Ok(e) = manifest.entry(&cfg.name, &format!("prefill_{p}")) {
                let exe = rt.load(&e)?;
                prefills.push((p, exe, e.args.clone()));
            }
        }
        if prefills.is_empty() {
            bail!("no prefill entries for {}", cfg.name);
        }

        // Chunked-prefill entries are optional the same way as the batched
        // and verify graphs: absent → prompts stay bucket-capped;
        // present-but-broken → loud failure at load time.
        let mut prefill_chunks = Vec::new();
        for p in [64usize, 128] {
            if let Ok(e) = manifest.entry(&cfg.name, &format!("prefill_chunk_{p}")) {
                let exe = rt.load(&e)?;
                prefill_chunks.push((p, exe, e.args.clone()));
            }
        }

        // Tier-shaped graphs are optional the same way: absent → the KV
        // pool degrades to max_seq-only tiers; present-but-broken → loud
        // failure.  A tier is dropped unless its chunk graphs cover every
        // canonical chunk bucket (prefill_advance picks buckets from the
        // canonical set and must be able to dispatch them at any tier).
        let mut tier_decodes = BTreeMap::new();
        let mut tier_chunks: BTreeMap<usize, Vec<(usize, Arc<Exe>, Vec<String>)>> =
            BTreeMap::new();
        for s in kvpool::tier_ladder(cfg.max_seq, kvpool::BASE_TIER) {
            if s >= cfg.max_seq {
                continue;
            }
            if let Ok(e) = manifest.entry(&cfg.name, &format!("decode_step_s{s}")) {
                let exe = rt.load(&e)?;
                tier_decodes.insert(s, (exe, e.args.clone()));
            }
            for p in [64usize, 128] {
                if let Ok(e) =
                    manifest.entry(&cfg.name, &format!("prefill_chunk_{p}_s{s}"))
                {
                    let exe = rt.load(&e)?;
                    tier_chunks.entry(s).or_default().push((p, exe, e.args.clone()));
                }
            }
        }
        let canonical: Vec<usize> =
            prefill_chunks.iter().map(|(p, _, _)| *p).collect();
        tier_decodes.retain(|s, _| {
            canonical.is_empty()
                || tier_chunks.get(s).is_some_and(|set| {
                    canonical
                        .iter()
                        .all(|b| set.iter().any(|(p, _, _)| p == b))
                })
        });

        let stacker = Stacker::new(rt.clone());
        let caster = KvCaster::new(rt.clone());

        // ---- static decode args -------------------------------------------
        let mut static_bufs = HashMap::new();
        let nl = &assets.nl;
        for (name, t) in [
            ("tok_emb", &nl.tok_emb), ("out_head", &nl.out_head),
            ("final_norm", &nl.final_norm), ("ln1", &nl.ln1), ("ln2", &nl.ln2),
        ] {
            static_bufs.insert(name.to_string(), rt.upload_tensor(t)?);
        }
        for g in GROUPS {
            let store = assets.store.group(g)?;
            let (lb, hb) = ec.group_bits(&cfg, g);
            let wl = materialize_stack(&rt, &stacker, &weights, store, g, &lb)?;
            static_bufs.insert(format!("wl_{g}"), wl);
            let wh = materialize_stack(&rt, &stacker, &weights, store, g, &hb)?;
            static_bufs.insert(format!("wh_{g}"), wh);
            let sel = &ec.groups[g];
            static_bufs.insert(
                format!("G_{g}"),
                rt.upload_f32(&sel.g_shape, &sel.g_proj)?,
            );
            let l = cfg.n_layers;
            static_bufs.insert(format!("lina_{g}"), rt.upload_f32(&[l], &sel.lin_a)?);
            static_bufs.insert(format!("linb_{g}"), rt.upload_f32(&[l], &sel.lin_b)?);
            static_bufs.insert(format!("uselin_{g}"), rt.upload_f32(&[l], &sel.use_lin)?);
            static_bufs.insert(format!("thr_{g}"), rt.upload_f32(&[l], &sel.thr)?);
        }

        // ---- prefill weights (paper: highest available precision) ---------
        let mut prefill_bufs = HashMap::new();
        for (name, t) in [
            ("tok_emb", &nl.tok_emb), ("out_head", &nl.out_head),
            ("final_norm", &nl.final_norm), ("ln1", &nl.ln1), ("ln2", &nl.ln2),
        ] {
            prefill_bufs.insert(name.to_string(), rt.upload_tensor(t)?);
        }
        for g in GROUPS {
            let store = assets.store.group(g)?;
            let bits = group_layer_bits(&cfg, &ec.prefill_bits, g);
            let w = materialize_stack(&rt, &stacker, &weights, store, g, &bits)?;
            prefill_bufs.insert(format!("w_{g}"), w);
        }

        let kv_len: usize = cfg.kv_shape().iter().product();
        let tag = cfg.name.clone();
        Ok(DecodeSession {
            rt,
            decode_args: decode_entry.args.clone(),
            cfg,
            ec,
            store: assets.store.clone(),
            weights,
            stacker,
            decode,
            batched,
            verifies,
            pad_kv: RefCell::new(None),
            prefills,
            prefill_chunks,
            tier_decodes,
            tier_chunks,
            pool: None,
            caster,
            tag,
            static_bufs,
            prefill_bufs,
            kv_zero: vec![0.0; kv_len],
            rope_bufs: RefCell::new(HashMap::new()),
            scalar_bufs: RefCell::new(HashMap::new()),
            mode_bufs: RefCell::new(HashMap::new()),
            rope_hits: Cell::new(0),
            rope_misses: Cell::new(0),
        })
    }

    /// In-place engine-configuration rebind with **delta materialization**:
    /// only groups whose per-layer (low, high, prefill) bit assignments
    /// changed re-assemble their stacks, and within a rebuilt stack only
    /// the changed layers dequantize + upload — unchanged layers come out
    /// of the weight cache and the stack re-assembles device-side.  A
    /// rebind that changes k of L layers therefore uploads O(k), not O(L),
    /// weight bytes (asserted by the integration tests through
    /// [`Runtime::transfers`] and [`DecodeSession::materialize_stats`]),
    /// **provided** the session's weight cache retains the unchanged
    /// slabs — sessions built with [`DecodeSession::new`] use a
    /// zero-retention cache and re-materialize everything.
    ///
    /// The selector parameter vectors (thresholds, linear fits, JL stack)
    /// re-upload only when their values differ.  Requires exclusive access:
    /// no [`GenState`] may be borrowed from this session across the call
    /// (enforced by the borrow checker); live generations on *other*
    /// sessions are unaffected.
    pub fn swap_bits(&mut self, ec: EngineConfig) -> Result<SwapReport> {
        if ec.wl_bits.len() != self.ec.wl_bits.len()
            || ec.wh_bits.len() != self.ec.wh_bits.len()
            || ec.prefill_bits.len() != self.ec.prefill_bits.len()
        {
            bail!(
                "swap_bits across model shapes: {} vs {} linears",
                ec.wl_bits.len(), self.ec.wl_bits.len()
            );
        }
        let mut rep = SwapReport::default();
        // Stage every new buffer first, commit only after all of them
        // materialized: a mid-rebind failure (upload, device) leaves the
        // session fully on the OLD configuration instead of a mix whose
        // next diff against self.ec would be wrong.
        let mut staged_stacks: Vec<(String, bool, PjRtBuffer)> = Vec::new();
        let mut staged_small: Vec<(String, PjRtBuffer)> = Vec::new();
        for g in GROUPS {
            let store = self.store.group(g)?;
            let (old_l, old_h) = self.ec.group_bits(&self.cfg, g);
            let (new_l, new_h) = ec.group_bits(&self.cfg, g);
            let old_p = group_layer_bits(&self.cfg, &self.ec.prefill_bits, g);
            let new_p = group_layer_bits(&self.cfg, &ec.prefill_bits, g);
            for (name, is_prefill, old, new) in [
                (format!("wl_{g}"), false, &old_l, &new_l),
                (format!("wh_{g}"), false, &old_h, &new_h),
                (format!("w_{g}"), true, &old_p, &new_p),
            ] {
                let changed = changed_layers(old, new);
                if changed.is_empty() {
                    continue;
                }
                rep.layers_changed += changed.len();
                rep.stacks_rebuilt += 1;
                let buf = materialize_stack(
                    &self.rt, &self.stacker, &self.weights, store, g, new)?;
                staged_stacks.push((name, is_prefill, buf));
            }
            let old_sel = &self.ec.groups[g];
            let new_sel = &ec.groups[g];
            if old_sel.g_proj != new_sel.g_proj || old_sel.g_shape != new_sel.g_shape {
                staged_small.push((
                    format!("G_{g}"),
                    self.rt.upload_f32(&new_sel.g_shape, &new_sel.g_proj)?,
                ));
                rep.selector_uploads += 1;
            }
            let l = self.cfg.n_layers;
            for (name, old_v, new_v) in [
                ("lina", &old_sel.lin_a, &new_sel.lin_a),
                ("linb", &old_sel.lin_b, &new_sel.lin_b),
                ("uselin", &old_sel.use_lin, &new_sel.use_lin),
                ("thr", &old_sel.thr, &new_sel.thr),
            ] {
                if old_v != new_v {
                    staged_small.push((
                        format!("{name}_{g}"),
                        self.rt.upload_f32(&[l], new_v)?,
                    ));
                    rep.selector_uploads += 1;
                }
            }
        }
        // Commit phase: infallible.
        for (name, is_prefill, buf) in staged_stacks {
            if is_prefill {
                self.prefill_bufs.insert(name, buf);
            } else {
                self.static_bufs.insert(name, buf);
            }
        }
        for (name, buf) in staged_small {
            self.static_bufs.insert(name, buf);
        }
        self.ec = ec;
        Ok(rep)
    }

    /// Counters of the weight materialization cache this session
    /// dequantizes through (companion to [`Runtime::transfers`]).
    pub fn materialize_stats(&self) -> MatSnapshot {
        self.weights.borrow().snapshot()
    }

    /// The weight cache handle (to share with sibling sessions).
    pub fn weight_cache(&self) -> WeightCache {
        self.weights.clone()
    }

    pub fn selector_state(&self) -> SelectorState<'_> {
        SelectorState::new(&self.cfg, &self.ec)
    }

    pub fn zero_kv(&self) -> Vec<f32> {
        self.kv_zero.clone()
    }

    /// (hits, misses) of the per-position rope-table device cache.
    pub fn rope_cache_stats(&self) -> (u64, u64) {
        (self.rope_hits.get(), self.rope_misses.get())
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn prefill_bucket(&self, n: usize) -> Result<usize> {
        self.prefills
            .iter()
            .map(|(p, _, _)| *p)
            .filter(|&p| p >= n)
            .min()
            .ok_or_else(|| anyhow!("prompt of {n} tokens exceeds largest bucket"))
    }

    /// Chunked-prefill bucket sizes, ascending (empty when the artifacts
    /// predate the `prefill_chunk_*` AOT export — prompt ingestion is
    /// then capped at the largest `prefill_<P>` bucket).
    pub fn prefill_chunk_buckets(&self) -> Vec<usize> {
        self.prefill_chunks.iter().map(|(p, _, _)| *p).collect()
    }

    /// Largest chunked-prefill bucket (0 without chunk artifacts) — the
    /// per-round ingestion quantum of the serving core's interleaved
    /// prefill (DESIGN.md §Prefill).
    pub fn max_prefill_chunk(&self) -> usize {
        self.prefill_chunks.last().map(|(p, _, _)| *p).unwrap_or(0)
    }

    /// Largest prompt this session can ingest AND still decode at least
    /// one token: the largest `prefill_<P>` bucket without chunk
    /// artifacts, else [`max_chunked_prompt_len`] at the smallest chunk
    /// granularity (every chunk's *padded* bucket must fit under
    /// `max_seq` — the chunk graph writes a bucket-sized KV span).
    pub fn max_prompt_len(&self) -> usize {
        let bucketed = self.prefills.iter().map(|(p, _, _)| *p).max().unwrap_or(0);
        match self.prefill_chunks.first() {
            None => bucketed,
            Some((c, _, _)) => {
                max_chunked_prompt_len(self.cfg.max_seq, *c).max(bucketed)
            }
        }
    }

    // ---- KV pool / tier ladder / shared-prefix cache ---------------------

    /// Install the shared byte-budgeted KV pool and this session's
    /// prefix-cache identity tag (the serving engine passes the target
    /// string).  Without a pool the session behaves exactly as before:
    /// max_seq buffers, no accounting, no prefix sharing.
    pub fn set_kv_pool(&mut self, pool: SharedKvPool, tag: &str) {
        self.pool = Some(pool);
        self.tag = tag.to_string();
    }

    /// The shared KV pool handle, if one is installed.
    pub fn kv_pool(&self) -> Option<&SharedKvPool> {
        self.pool.as_ref()
    }

    /// Active KV tier ladder, ascending, always ending at `max_seq`.
    /// Sub-max tiers appear only when their AOT graphs are present.
    pub fn kv_tiers(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.tier_decodes.keys().copied().collect();
        v.push(self.cfg.max_seq);
        v
    }

    /// KV cache shape at sequence capacity `tier`.
    fn kv_shape_at(&self, tier: usize) -> Vec<usize> {
        let mut s = self.cfg.kv_shape();
        s[3] = tier;
        s
    }

    /// `(n_layers, n_heads, head_dim)` — the non-sequence KV dims.
    fn kv_dims(&self) -> (usize, usize, usize) {
        let s = self.cfg.kv_shape();
        (s[0], s[2], s[4])
    }

    /// The tier a fresh generation is born at: the smallest available
    /// tier when the pool + tier graphs are active, else `max_seq`.
    fn birth_tier(&self) -> usize {
        if self.pool.is_some() {
            self.kv_tiers().first().copied().unwrap_or(self.cfg.max_seq)
        } else {
            self.cfg.max_seq
        }
    }

    /// Charge `tier` bytes against the pool (no free-list pop — for
    /// buffers that arrive from dispatch outputs) and mint the lease
    /// that releases them on drop.  `None` without a pool.
    fn lease_for(&self, tier: usize) -> Result<Option<PoolLease>> {
        let Some(pool) = &self.pool else { return Ok(None) };
        pool.borrow_mut().charge(tier)?;
        let bytes = pool.borrow().tier_bytes(tier);
        self.rt.transfers().count_kv_acquire(bytes as u64);
        Ok(Some(PoolLease {
            pool: pool.clone(),
            rt: self.rt.clone(),
            tier,
        }))
    }

    /// A zeroed-or-recycled KV residence at `tier` plus its lease.
    /// Free-listed buffers are reused WITHOUT zeroing: every slot ≤ pos
    /// is overwritten by a dispatch before the `arange(S) <= pos` mask
    /// ever exposes it, so stale contents are unobservable.  Without a
    /// pool this is a plain zero upload at `tier` (= max_seq) and no
    /// lease.
    fn acquire_kv(&self, tier: usize)
                  -> Result<(KvResidence, Option<PoolLease>)> {
        let recycled = match &self.pool {
            Some(pool) => pool.borrow_mut().acquire(tier)?,
            None => None,
        };
        let lease = self.pool.as_ref().map(|pool| {
            let bytes = pool.borrow().tier_bytes(tier);
            self.rt.transfers().count_kv_acquire(bytes as u64);
            PoolLease { pool: pool.clone(), rt: self.rt.clone(), tier }
        });
        if let Some(buf) = recycled {
            return Ok((KvResidence::Device(buf), lease));
        }
        let shape = self.kv_shape_at(tier);
        let len: usize = shape.iter().product();
        // An upload failure drops `lease`, crediting the charge back.
        let buf = self.rt.upload_f32(&shape, &self.kv_zero[..len])?;
        Ok((KvResidence::Device(buf), lease))
    }

    /// Grow `gen`'s KV to the smallest tier covering `needed` positions
    /// — the tier-migration path.  Stale tail slots are don't-care under
    /// the `arange(S) <= pos` mask, so migration is a zero-pad on the
    /// sequence dim: device-side through [`KvCaster`], else a
    /// download/grow/upload host fallback.  The byte delta is charged
    /// before the copy (growth can hit the pool budget) and rolled back
    /// if the copy fails; the outgrown buffer is donated to the free
    /// list for the next birth-tier acquisition.
    fn ensure_tier(&self, gen: &mut GenState<'_>, needed: usize) -> Result<()> {
        // A tier is usable only where THIS session has matching graphs —
        // a retarget (`adopt`) can hand over a tier a sibling session
        // exported but this one didn't, which migrates up here too.
        let compatible = gen.tier == self.cfg.max_seq
            || self.tier_decodes.contains_key(&gen.tier);
        if needed <= gen.tier && compatible {
            return Ok(());
        }
        let want = needed.max(gen.tier.min(self.cfg.max_seq));
        let to = kvpool::tier_for(&self.kv_tiers(), want).ok_or_else(|| {
            anyhow!("kv tier for {needed} positions exceeds max_seq {}",
                    self.cfg.max_seq)
        })?;
        let from = gen.tier;
        if let Some(pool) = &self.pool {
            pool.borrow_mut().migrate_charge(from, to)?;
        }
        match self.grow_kv(&gen.kv, from, to) {
            Ok(kv) => {
                let old = std::mem::replace(&mut gen.kv, kv);
                if let Some(pool) = &self.pool {
                    if let KvResidence::Device(b) = old {
                        pool.borrow_mut().donate(from, b);
                    }
                    let delta = pool.borrow().tier_bytes(to)
                        - pool.borrow().tier_bytes(from);
                    self.rt.transfers().count_kv_acquire(delta as u64);
                }
                if let Some(lease) = &mut gen.lease {
                    lease.tier = to;
                }
                gen.tier = to;
                self.rt.transfers().count_kv_migration();
                // The session has no request identity down here; id 0
                // marks an unattributed migration on the precision track.
                crate::obs::global_tracer().record(
                    crate::obs::EventKind::KvMigrate {
                        id: 0,
                        from_tier: from as u32,
                        to_tier: to as u32,
                    },
                );
                Ok(())
            }
            Err(e) => {
                if let Some(pool) = &self.pool {
                    // Shrinking the charge back always fits.
                    let _ = pool.borrow_mut().migrate_charge(to, from);
                }
                Err(e)
            }
        }
    }

    /// The grown KV residence: device pad graph when available, else a
    /// host zero-pad (re-upload for device residences, in-place for the
    /// host fallback residence).
    fn grow_kv(&self, kv: &KvResidence, from: usize, to: usize)
               -> Result<KvResidence> {
        let (l, h, d) = self.kv_dims();
        let host = |data: &[f32]| -> Result<KvResidence> {
            let grown = kvpool::host_grow(data, l, h, d, from, to);
            Ok(KvResidence::Device(
                self.rt.upload_f32(&self.kv_shape_at(to), &grown)?,
            ))
        };
        match kv {
            KvResidence::Device(b) => match self.caster.cast((l, h, d), from, to, b) {
                Some(nb) => Ok(KvResidence::Device(nb)),
                None => host(&buffer_f32(b)?),
            },
            KvResidence::Shared(rc) => {
                match self.caster.cast((l, h, d), from, to, rc) {
                    Some(nb) => Ok(KvResidence::Device(nb)),
                    None => host(&buffer_f32(rc)?),
                }
            }
            KvResidence::Host(v) => {
                Ok(KvResidence::Host(kvpool::host_grow(v, l, h, d, from, to)))
            }
        }
    }

    /// The decode graph matching `tier` (the max_seq graph otherwise).
    fn decode_for(&self, tier: usize) -> (&Arc<Exe>, &[String]) {
        if tier < self.cfg.max_seq {
            if let Some((e, a)) = self.tier_decodes.get(&tier) {
                return (e, a);
            }
        }
        (&self.decode, &self.decode_args)
    }

    /// The chunked-prefill entry for `bucket` at `tier` (tier graphs
    /// cover every canonical bucket by the load-time retain rule).
    fn chunk_for(&self, tier: usize, bucket: usize)
                 -> Result<&(usize, Arc<Exe>, Vec<String>)> {
        let set = if tier < self.cfg.max_seq {
            self.tier_chunks.get(&tier).unwrap_or(&self.prefill_chunks)
        } else {
            &self.prefill_chunks
        };
        set.iter().find(|(p, _, _)| *p == bucket).ok_or_else(|| {
            anyhow!("no prefill_chunk_{bucket} graph at kv tier {tier}")
        })
    }

    /// Probe the shared-prefix cache for `prompt`.  A hit returns a
    /// generation already carrying the cached prefix KV (copy-on-write —
    /// see `runtime::kvpool`) at `pos = prefix_len`, plus the prefix
    /// length; the avoided chunk dispatches are counted on
    /// [`Runtime::transfers`].  `None` on a miss, without a pool, with
    /// the cache disabled, or when the pool can't fit the consumer tier.
    pub fn begin_from_prefix(&self, prompt: &[u32])
                             -> Option<(GenState<'_>, usize)> {
        let pool = self.pool.as_ref()?;
        let quantum = self.max_prefill_chunk();
        if quantum == 0 || kvpool::prefix_cache_disabled() {
            return None;
        }
        let hit = pool.borrow_mut().prefix_lookup(&self.tag, prompt, quantum)?;
        let lease = self.lease_for(hit.tier).ok()?;
        self.rt
            .transfers()
            .count_prefix_hit((hit.len / quantum) as u64);
        Some((
            GenState {
                sel: self.selector_state(),
                kv: KvResidence::Shared(hit.kv),
                pos: hit.len,
                flag_bufs: HashMap::new(),
                steps: 0,
                retargets: 0,
                tier: hit.tier,
                lease,
            },
            hit.len,
        ))
    }

    /// Publish `gen`'s KV as the immutable shared-prefix entry for
    /// `prompt[..len]`.  Zero-copy: dispatches never mutate their input
    /// buffers, so the published buffer stays valid forever while the
    /// generation continues — the generation's own handle becomes a
    /// shared reference to the same buffer (its next dispatch output is
    /// private again).  No-op without a pool, with the cache disabled,
    /// off a chunk boundary, before ingestion reached `len`, for
    /// host-resident KV, or when the entry already exists (first writer
    /// wins).
    pub fn prefix_publish(&self, gen: &mut GenState<'_>, prompt: &[u32],
                          len: usize) {
        let Some(pool) = &self.pool else { return };
        let quantum = self.max_prefill_chunk();
        if quantum == 0
            || kvpool::prefix_cache_disabled()
            || len == 0
            || len % quantum != 0
            || len > prompt.len()
            || gen.pos < len
        {
            return;
        }
        if pool.borrow().prefix_contains(&self.tag, prompt, len) {
            return;
        }
        let kv = std::mem::replace(&mut gen.kv, KvResidence::Host(Vec::new()));
        match kv {
            KvResidence::Device(b) => {
                let rc = Rc::new(b);
                pool.borrow_mut().prefix_insert(
                    &self.tag, prompt, len, gen.tier, rc.clone(),
                );
                gen.kv = KvResidence::Shared(rc);
            }
            other => gen.kv = other,
        }
    }

    // ---- cached per-step input buffers -----------------------------------

    fn rope_buffers(&self, pos: usize) -> Result<Rc<(PjRtBuffer, PjRtBuffer)>> {
        if let Some(r) = self.rope_bufs.borrow().get(&pos) {
            self.rope_hits.set(self.rope_hits.get() + 1);
            return Ok(r.clone());
        }
        self.rope_misses.set(self.rope_misses.get() + 1);
        let (cos, sin) = self.cfg.rope_tables(pos);
        let cos_buf = self.rt.upload_f32(&[cos.len()], &cos)?;
        let sin_buf = self.rt.upload_f32(&[sin.len()], &sin)?;
        let rc = Rc::new((cos_buf, sin_buf));
        self.rope_bufs.borrow_mut().insert(pos, rc.clone());
        Ok(rc)
    }

    fn scalar_buffer(&self, v: i32) -> Result<Rc<PjRtBuffer>> {
        // Positions are bounded by max_seq, but token ids range over the
        // whole vocabulary — cap the cache so a long-lived session holds at
        // most max(max_seq, 1024) tiny device buffers, not one per vocab
        // entry ever sampled.  Past the cap, uncached values upload fresh
        // (a 4-byte transfer).
        if let Some(b) = self.scalar_bufs.borrow().get(&v) {
            return Ok(b.clone());
        }
        let rc = Rc::new(self.rt.scalar_i32(v)?);
        let cap = self.cfg.max_seq.max(1024);
        let mut cache = self.scalar_bufs.borrow_mut();
        if cache.len() < cap {
            cache.insert(v, rc.clone());
        }
        Ok(rc)
    }

    fn mode_buffer(&self, exact: bool) -> Result<Rc<PjRtBuffer>> {
        if let Some(b) = self.mode_bufs.borrow().get(&exact) {
            return Ok(b.clone());
        }
        let rc = Rc::new(self.rt.scalar_f32(if exact { 1.0 } else { 0.0 })?);
        self.mode_bufs.borrow_mut().insert(exact, rc.clone());
        Ok(rc)
    }

    /// Upload async flags for groups whose vectors changed since the last
    /// upload (the selector flips layers rarely, so most steps re-use all
    /// five buffers untouched).
    fn refresh_flags(&self, gen: &mut GenState<'_>) -> Result<()> {
        for g in ASYNC_GROUPS {
            let want = gen
                .sel
                .use_h_async
                .get(g)
                .ok_or_else(|| anyhow!("missing async flags for {g}"))?;
            let stale = match gen.flag_bufs.get(g) {
                Some((uploaded, _)) => uploaded != want,
                None => true,
            };
            if stale {
                let buf = self.rt.upload_f32(&[self.cfg.n_layers], want)?;
                gen.flag_bufs.insert(g.to_string(), (want.clone(), buf));
            }
        }
        Ok(())
    }

    // ---- generation lifecycle --------------------------------------------

    /// Start a generation from a prompt: prefill at the highest available
    /// precision, keep the produced KV cache on the device, and return the
    /// handle plus the last-position logits (caller samples token 1).
    pub fn begin(&self, prompt: &[u32]) -> Result<(GenState<'_>, Vec<f32>)> {
        // Bucketed prefill emits a full max_seq KV buffer from the
        // dispatch, so the lease charges the top tier up front.
        let lease = self.lease_for(self.cfg.max_seq)?;
        let bucket = self.prefill_bucket(prompt.len())?;
        let (_, exe, args) = self
            .prefills
            .iter()
            .find(|(p, _, _)| *p == bucket)
            .expect("bucket exists");
        let mut padded: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        padded.resize(bucket, 0);
        let tok_buf = self.rt.upload_i32(&[bucket], &padded)?;
        let nv_buf = self.rt.scalar_i32(prompt.len() as i32)?;
        let half = self.cfg.head_dim() / 2;
        let mut cos = Vec::with_capacity(bucket * half);
        let mut sin = Vec::with_capacity(bucket * half);
        for p in 0..bucket {
            let (c, s) = self.cfg.rope_tables(p);
            cos.extend_from_slice(&c);
            sin.extend_from_slice(&s);
        }
        let cos_buf = self.rt.upload_f32(&[bucket, half], &cos)?;
        let sin_buf = self.rt.upload_f32(&[bucket, half], &sin)?;
        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(args.len());
        for name in args {
            arg_bufs.push(match name.as_str() {
                "tokens" => &tok_buf,
                "n_valid" => &nv_buf,
                "cos" => &cos_buf,
                "sin" => &sin_buf,
                other => self
                    .prefill_bufs
                    .get(other)
                    .ok_or_else(|| anyhow!("missing prefill arg {other}"))?,
            });
        }
        let replica = exe.run_buffers(&arg_bufs).context("prefill")?;
        let (kv, logits) = if exe.untupled(&replica) {
            let li = exe.output_index("logits_last")?;
            let ki = exe.output_index("kv")?;
            self.rt.transfers().count_download();
            let logits = buffer_f32(&replica[li])?;
            let mut kv = None;
            for (i, b) in replica.into_iter().enumerate() {
                if i == ki {
                    kv = Some(b);
                }
            }
            (KvResidence::Device(kv.expect("kv index in range")), logits)
        } else {
            let out = exe.outputs(replica)?;
            (KvResidence::Host(out.f32_vec("kv")?), out.f32_vec("logits_last")?)
        };
        Ok((
            GenState {
                sel: self.selector_state(),
                kv,
                pos: prompt.len(),
                flag_bufs: HashMap::new(),
                steps: 0,
                retargets: 0,
                tier: self.cfg.max_seq,
                lease,
            },
            logits,
        ))
    }

    /// Start a generation for CHUNKED prompt ingestion: a zeroed KV cache
    /// at position 0 that [`DecodeSession::prefill_advance`] extends one
    /// bounded chunk dispatch at a time — the schedulable alternative to
    /// the monolithic [`DecodeSession::begin`], with no bucket cap on the
    /// total prompt length (DESIGN.md §Prefill).  Errors when the
    /// artifacts predate the `prefill_chunk_*` export.
    pub fn begin_chunked(&self) -> Result<GenState<'_>> {
        if self.prefill_chunks.is_empty() {
            bail!(
                "artifacts lack prefill_chunk_* entries for {} — re-run the \
                 AOT export, or keep prompts within the {}-token prefill \
                 bucket cap",
                self.cfg.name,
                self.prefills.iter().map(|(p, _, _)| *p).max().unwrap_or(0)
            );
        }
        self.begin_empty()
    }

    /// Ingest one prompt chunk (≤ the largest chunk bucket) at the
    /// generation's current position: ONE `prefill_chunk_<P>` dispatch
    /// appends `tokens.len()` causal positions to the device-resident KV
    /// cache and advances `gen.pos` past them.  With `want_logits`
    /// (the FINAL chunk) the logits after the chunk's last token are
    /// downloaded and returned — exactly what [`DecodeSession::begin`]
    /// returns, for the caller to sample token 0 from; without it the
    /// vocab-sized download is skipped entirely, since intermediate
    /// chunks' logits are never consulted (on a 16-chunk prompt that is
    /// 15 avoided device→host logits transfers on the latency-bounded
    /// interleaved path).
    ///
    /// Padding protocol: the chunk pads to the smallest bucket ≥ n; pad
    /// positions may write stale KV entries past `gen.pos`, which the
    /// decode graphs mask (`arange(S) <= pos`) and the next chunk or
    /// decode step overwrites in place — the same stale-but-masked rule
    /// as speculative rollback, so chunked ingestion is numerically
    /// invisible downstream (pinned by the jax chain-parity test and the
    /// Rust `chunked_prefill_matches_bucketed_begin` integration test).
    /// `steps` is NOT advanced (it counts decode dispatches; the serving
    /// core keys first-token emission off prefill completion instead).
    pub fn prefill_advance(&self, gen: &mut GenState<'_>, tokens: &[u32],
                           want_logits: bool) -> Result<Option<Vec<f32>>> {
        let n = tokens.len();
        if n == 0 {
            bail!("empty prefill chunk");
        }
        let bucket = self
            .prefill_chunks
            .iter()
            .find(|(p, _, _)| *p >= n)
            .map(|(p, _, _)| *p)
            .ok_or_else(|| {
                anyhow!("prefill chunk of {n} tokens exceeds the largest \
                         chunk bucket {}", self.max_prefill_chunk())
            })?;
        // The chunk graph writes a BUCKET-sized KV span at gen.pos; XLA
        // clamps dynamic_update_slice starts, so an overhanging write
        // would silently shift backwards and corrupt earlier positions —
        // reject it here instead.
        if gen.pos + bucket > self.cfg.max_seq {
            bail!("prefill chunk bucket {bucket} at position {} overruns \
                   max_seq {}", gen.pos, self.cfg.max_seq);
        }
        // The same clamping rule applies within a KV tier: the whole
        // bucket span must fit the buffer, so migrate up front.
        self.ensure_tier(gen, gen.pos + bucket)?;
        let (_, exe, args) = self.chunk_for(gen.tier, bucket)?;
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(bucket, 0);
        let tok_buf = self.rt.upload_i32(&[bucket], &padded)?;
        let pos_buf = self.scalar_buffer(gen.pos as i32)?;
        let nv_buf = self.scalar_buffer(n as i32)?;
        let half = self.cfg.head_dim() / 2;
        let mut cos = Vec::with_capacity(bucket * half);
        let mut sin = Vec::with_capacity(bucket * half);
        for p in gen.pos..gen.pos + bucket {
            let (c, s) = self.cfg.rope_tables(p);
            cos.extend_from_slice(&c);
            sin.extend_from_slice(&s);
        }
        let cos_buf = self.rt.upload_f32(&[bucket, half], &cos)?;
        let sin_buf = self.rt.upload_f32(&[bucket, half], &sin)?;
        // Host-KV fallback for tuple-lowered graphs, as in `advance`.
        let kv_upload = match &gen.kv {
            KvResidence::Device(_) | KvResidence::Shared(_) => None,
            KvResidence::Host(v) => {
                Some(self.rt.upload_f32(&self.kv_shape_at(gen.tier), v)?)
            }
        };
        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(args.len());
        for name in args {
            arg_bufs.push(match name.as_str() {
                "tokens" => &tok_buf,
                "pos" => &*pos_buf,
                "n_valid" => &*nv_buf,
                "cos" => &cos_buf,
                "sin" => &sin_buf,
                "kv" => match (&gen.kv, &kv_upload) {
                    (KvResidence::Device(b), _) => b,
                    (KvResidence::Shared(rc), _) => rc.as_ref(),
                    (_, Some(b)) => b,
                    _ => unreachable!("host kv uploaded above"),
                },
                other => self
                    .prefill_bufs
                    .get(other)
                    .ok_or_else(|| anyhow!("missing prefill chunk arg {other}"))?,
            });
        }
        let replica = exe.run_buffers(&arg_bufs).context("prefill chunk")?;
        let logits = if exe.untupled(&replica) {
            let ki = exe.output_index("kv")?;
            let logits = if want_logits {
                let li = exe.output_index("logits_last")?;
                self.rt.transfers().count_download();
                Some(buffer_f32(&replica[li])?)
            } else {
                None
            };
            for (i, b) in replica.into_iter().enumerate() {
                if i == ki {
                    gen.kv = KvResidence::Device(b);
                }
            }
            logits
        } else {
            // Tuple fallback decomposes everything host-side anyway.
            let out = exe.outputs(replica)?;
            gen.kv = KvResidence::Host(out.f32_vec("kv")?);
            if want_logits {
                Some(out.f32_vec("logits_last")?)
            } else {
                None
            }
        };
        gen.pos += n;
        self.rt.transfers().count_prefill_chunk();
        Ok(logits)
    }

    /// Full-prompt ingestion through whichever path the artifacts
    /// support: the bucketed [`DecodeSession::begin`] when the prompt
    /// fits a `prefill_<P>` bucket (one dispatch), else a chain of
    /// [`DecodeSession::prefill_advance`] chunks.  One-stop entry for
    /// callers that don't schedule chunks themselves (eval harnesses,
    /// CLI `generate`); the serving core drives `prefill_advance`
    /// directly so chunks interleave with decode traffic.
    pub fn begin_prompt(&self, prompt: &[u32]) -> Result<(GenState<'_>, Vec<f32>)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if self.prefill_bucket(prompt.len()).is_ok() {
            return self.begin(prompt);
        }
        if prompt.len() > self.max_prompt_len() {
            bail!("prompt of {} tokens exceeds max ingestible length {} \
                   (max_seq {})", prompt.len(), self.max_prompt_len(),
                  self.cfg.max_seq);
        }
        let mut gen = self.begin_chunked()?;
        let chunk = self.max_prefill_chunk();
        let n_chunks = (prompt.len() + chunk - 1) / chunk;
        let mut logits = None;
        for (i, piece) in prompt.chunks(chunk).enumerate() {
            logits = self.prefill_advance(&mut gen, piece, i + 1 == n_chunks)?;
        }
        let logits = logits
            .ok_or_else(|| anyhow!("chunked prefill produced no final logits"))?;
        Ok((gen, logits))
    }

    /// Placeholder state for a generation whose real KV arrives later —
    /// the serving core's admission slot on CHUNK-LESS artifacts, where
    /// the first scheduled ingestion round replaces the whole `GenState`
    /// via [`DecodeSession::begin`].  No device upload, no host slab
    /// (unlike [`DecodeSession::begin_empty`], which uploads a full
    /// zeroed KV cache the bucketed prefill would immediately discard).
    /// Must not be advanced before replacement; a misuse surfaces as a
    /// shape-mismatch upload error, never silent corruption.
    pub fn begin_deferred(&self) -> GenState<'_> {
        GenState {
            sel: self.selector_state(),
            kv: KvResidence::Host(Vec::new()),
            pos: 0,
            flag_bufs: HashMap::new(),
            steps: 0,
            retargets: 0,
            tier: self.cfg.max_seq,
            lease: None,
        }
    }

    /// Start a generation from an empty (zeroed) KV cache at position 0 —
    /// teacher-forced perplexity, TPOT measurement, and the seed state
    /// for chunked prefill.  With an active KV pool the generation is
    /// born at the smallest available tier (recycling a free-listed
    /// buffer when one fits) and migrates up as `pos` grows.
    pub fn begin_empty(&self) -> Result<GenState<'_>> {
        let tier = self.birth_tier();
        let (kv, lease) = self.acquire_kv(tier)?;
        Ok(GenState {
            sel: self.selector_state(),
            kv,
            pos: 0,
            flag_bufs: HashMap::new(),
            steps: 0,
            retargets: 0,
            tier,
            lease,
        })
    }

    /// Take over a generation started on a sibling session of the same
    /// model (mid-stream target re-selection).  The device KV cache and
    /// accumulated statistics carry over; the selector re-binds to this
    /// session's thresholds and the flag buffers are re-uploaded next step.
    pub fn adopt<'s>(&'s self, gen: &mut GenState<'s>) {
        gen.sel.rebind(&self.cfg, &self.ec);
        gen.invalidate_flags();
        gen.retargets += 1;
    }

    /// One decode step: feed `token` at `gen.pos`, advance the state.
    /// Updates the selector (async flags + effective-bit accounting)
    /// internally; the returned [`StepOut`] carries only host-readable
    /// per-step outputs.
    pub fn advance(&self, gen: &mut GenState<'_>, token: u32, mode: EstMode)
                   -> Result<StepOut> {
        if gen.pos + 1 >= self.cfg.max_seq {
            bail!("position {} at max_seq {}", gen.pos, self.cfg.max_seq);
        }
        // The step writes slot `pos`; migrate up a KV tier if the
        // current buffer can't hold it (no-op off the tier ladder).
        self.ensure_tier(gen, gen.pos + 1)?;
        let (decode, decode_args) = self.decode_for(gen.tier);
        let tok_buf = self.scalar_buffer(token as i32)?;
        let pos_buf = self.scalar_buffer(gen.pos as i32)?;
        let rope = self.rope_buffers(gen.pos)?;
        let mode_buf = self.mode_buffer(mode == EstMode::Exact)?;
        self.refresh_flags(gen)?;
        // Host-KV fallback: upload for this step only (tuple-lowered graph).
        let kv_upload = match &gen.kv {
            KvResidence::Device(_) | KvResidence::Shared(_) => None,
            KvResidence::Host(v) => {
                Some(self.rt.upload_f32(&self.kv_shape_at(gen.tier), v)?)
            }
        };

        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(decode_args.len());
        for name in decode_args {
            arg_bufs.push(match name.as_str() {
                "token" => &*tok_buf,
                "pos" => &*pos_buf,
                "cos" => &rope.0,
                "sin" => &rope.1,
                "kv" => match (&gen.kv, &kv_upload) {
                    (KvResidence::Device(b), _) => b,
                    (KvResidence::Shared(rc), _) => rc.as_ref(),
                    (_, Some(b)) => b,
                    _ => unreachable!("host kv uploaded above"),
                },
                "mode_exact" => &*mode_buf,
                other => gen
                    .flag_bufs
                    .get(other.strip_prefix("useh_").unwrap_or(other))
                    .map(|(_, b)| b)
                    .or_else(|| self.static_bufs.get(other))
                    .ok_or_else(|| anyhow!("missing decode arg {other}"))?,
            });
        }
        let replica = decode.run_buffers(&arg_bufs).context("decode step")?;

        let out = if decode.untupled(&replica) {
            // Device-resident path: read only the small outputs, keep KV on
            // the device for the next step.
            let mut ests = BTreeMap::new();
            let mut use_eff = BTreeMap::new();
            for g in GROUPS {
                let ei = decode.output_index(&format!("est_{g}"))?;
                let ui = decode.output_index(&format!("useh_{g}"))?;
                ests.insert(g.to_string(), buffer_f32(&replica[ei])?);
                use_eff.insert(g.to_string(), buffer_f32(&replica[ui])?);
            }
            let li = decode.output_index("logits")?;
            let logits = buffer_f32(&replica[li])?;
            self.rt.transfers().count_download();
            let ki = decode.output_index("kv")?;
            for (i, b) in replica.into_iter().enumerate() {
                if i == ki {
                    gen.kv = KvResidence::Device(b);
                }
            }
            StepOut { logits, ests, use_eff }
        } else {
            // Tuple fallback: full host decomposition (legacy artifacts).
            let parts = decode.outputs(replica)?;
            let mut ests = BTreeMap::new();
            let mut use_eff = BTreeMap::new();
            for g in GROUPS {
                ests.insert(g.to_string(), parts.f32_vec(&format!("est_{g}"))?);
                use_eff.insert(g.to_string(), parts.f32_vec(&format!("useh_{g}"))?);
            }
            gen.kv = KvResidence::Host(parts.f32_vec("kv")?);
            StepOut { logits: parts.f32_vec("logits")?, ests, use_eff }
        };

        gen.sel.observe(&out.ests, &out.use_eff);
        gen.pos += 1;
        gen.steps += 1;
        Ok(out)
    }

    /// The runtime this session executes on (counter access for the
    /// speculation layer).
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Draft lengths γ for which this session's artifacts carry a
    /// `verify_step_g{γ}` graph, ascending.  Empty → no speculation
    /// (older manifests); the serving core then stays on plain decode.
    pub fn spec_gammas(&self) -> Vec<usize> {
        self.verifies.iter().map(|(g, _, _)| *g).collect()
    }

    /// Score `tokens` (the next committed token followed by γ draft
    /// tokens) at consecutive positions starting at `gen.pos` in ONE
    /// device dispatch — the target half of self-speculative decoding
    /// (DESIGN.md §Speculation).
    ///
    /// Requires an exact `verify_step_g{tokens.len()-1}` artifact and a
    /// device-resident KV cache.  On success the generation's KV buffer
    /// is replaced by the output leaf (all γ+1 positions written) but
    /// `pos`/`steps`/selector state are **not** advanced: acceptance is
    /// the caller's decision (`runtime::spec::spec_round` commits the
    /// longest accepted prefix and rewinds past the rejected tail via
    /// [`GenState::rewind`]).  Counts one `spec_verify_dispatches` on
    /// [`Runtime::transfers`].
    pub fn advance_verify(&self, gen: &mut GenState<'_>, tokens: &[u32],
                          mode: EstMode) -> Result<VerifyOut> {
        let n_pos = tokens.len();
        if n_pos < 2 {
            bail!("verify needs at least one draft token (got {n_pos} total)");
        }
        let (_, exe, args) = self
            .verifies
            .iter()
            .find(|(g, _, _)| g + 1 == n_pos)
            .ok_or_else(|| {
                anyhow!("no verify_step_g{} artifact (have γ ∈ {:?})",
                        n_pos - 1, self.spec_gammas())
            })?;
        if gen.pos + n_pos >= self.cfg.max_seq {
            bail!("verify of {n_pos} positions at {} exceeds max_seq {}",
                  gen.pos, self.cfg.max_seq);
        }
        if !gen.kv_on_device() {
            bail!("speculative verify requires device-resident KV \
                   (tuple-lowered artifacts fall back to plain decode)");
        }
        // Verify graphs are exported at max shape only — migrate a
        // tiered generation up before dispatch (DESIGN.md §Memory).
        self.ensure_tier(gen, self.cfg.max_seq)?;
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.rt.upload_i32(&[n_pos], &toks)?;
        let pos_buf = self.scalar_buffer(gen.pos as i32)?;
        let half = self.cfg.head_dim() / 2;
        let mut cos = Vec::with_capacity(n_pos * half);
        let mut sin = Vec::with_capacity(n_pos * half);
        for p in gen.pos..gen.pos + n_pos {
            let (c, s) = self.cfg.rope_tables(p);
            cos.extend_from_slice(&c);
            sin.extend_from_slice(&s);
        }
        let cos_buf = self.rt.upload_f32(&[n_pos, half], &cos)?;
        let sin_buf = self.rt.upload_f32(&[n_pos, half], &sin)?;
        let mode_buf = self.mode_buffer(mode == EstMode::Exact)?;
        self.refresh_flags(gen)?;

        let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(args.len());
        for name in args {
            arg_bufs.push(match name.as_str() {
                "tokens" => &tok_buf,
                "pos" => &*pos_buf,
                "cos" => &cos_buf,
                "sin" => &sin_buf,
                "kv" => match &gen.kv {
                    KvResidence::Device(b) => b,
                    KvResidence::Shared(rc) => rc.as_ref(),
                    KvResidence::Host(_) => {
                        unreachable!("validated device-resident above")
                    }
                },
                "mode_exact" => &*mode_buf,
                other => gen
                    .flag_bufs
                    .get(other.strip_prefix("useh_").unwrap_or(other))
                    .map(|(_, b)| b)
                    .or_else(|| self.static_bufs.get(other))
                    .ok_or_else(|| anyhow!("missing verify arg {other}"))?,
            });
        }
        let replica = exe.run_buffers(&arg_bufs).context("verify step")?;
        if !exe.untupled(&replica) {
            bail!("verify graph lowered as a tuple — KV residency \
                   impossible; falling back to plain decode");
        }
        let (v, l) = (self.cfg.vocab, self.cfg.n_layers);
        let li = exe.output_index("logits")?;
        let logits = buffer_f32(&replica[li])?;
        self.rt.transfers().count_download();
        if logits.len() != n_pos * v {
            bail!("verify logits: {} values for {n_pos} positions, V={v}",
                  logits.len());
        }
        let mut ests = BTreeMap::new();
        let mut use_eff = BTreeMap::new();
        for g in GROUPS {
            let ei = exe.output_index(&format!("est_{g}"))?;
            let ui = exe.output_index(&format!("useh_{g}"))?;
            let e = buffer_f32(&replica[ei])?;
            let u = buffer_f32(&replica[ui])?;
            if e.len() != n_pos * l || u.len() != n_pos * l {
                bail!("verify {g} outputs: {}/{} values for {n_pos} \
                       positions, L={l}", e.len(), u.len());
            }
            ests.insert(g.to_string(), e);
            use_eff.insert(g.to_string(), u);
        }
        let ki = exe.output_index("kv")?;
        for (i, b) in replica.into_iter().enumerate() {
            if i == ki {
                gen.kv = KvResidence::Device(b);
            }
        }
        self.rt.transfers().count_spec_verify();
        Ok(VerifyOut { n_pos, vocab: v, n_layers: l, logits, ests, use_eff })
    }

    /// Largest batched-decode bucket this session's artifacts provide
    /// (1 when the manifest has no `decode_step_b*` entries — callers
    /// then keep dispatching per request).
    pub fn max_batch(&self) -> usize {
        self.batched.last().map(|(b, _, _)| *b).unwrap_or(1)
    }

    /// The available batched bucket sizes, ascending (empty without
    /// batched artifacts).
    pub fn batch_buckets(&self) -> Vec<usize> {
        self.batched.iter().map(|(b, _, _)| *b).collect()
    }

    /// Zero-KV device buffer backing masked padding slots (lazy, shared).
    fn pad_kv_buffer(&self) -> Result<Rc<PjRtBuffer>> {
        if let Some(b) = self.pad_kv.borrow().as_ref() {
            return Ok(b.clone());
        }
        let rc = Rc::new(self.rt.upload_f32(&self.cfg.kv_shape(), &self.kv_zero)?);
        *self.pad_kv.borrow_mut() = Some(rc.clone());
        Ok(rc)
    }

    /// One decode step for up to `max_batch` generations in a SINGLE
    /// device dispatch: the batched fast path behind the serving core's
    /// `pick_batch` (DESIGN.md §Batching).
    ///
    /// Each `slots` entry is a generation of **this** session plus the
    /// token to feed it.  Per-slot inputs (token, position, rope tables,
    /// async selector flags) pack into leading-batch-dim arrays; the
    /// weight stacks are the session's shared device buffers; each slot's
    /// KV cache is passed as its own `kv<i>` graph parameter and comes
    /// back as its own output leaf, so KV residency is exactly the
    /// per-request [`DecodeSession::advance`] contract.  When fewer slots
    /// than the chosen bucket are supplied, the tail slots are masked
    /// no-op requests (token 0 at position 0 over a shared zero KV
    /// buffer) whose outputs are discarded.
    ///
    /// Failure atomicity: every validation and the device call happen
    /// before ANY generation is mutated — on `Err` all slots are exactly
    /// as they were, so the caller can retry them through per-request
    /// [`DecodeSession::advance`] (which is also the n == 1 fast path
    /// here).  Counters: each successful call adds one to
    /// `batched_steps` and `slots.len()` to `batch_occupancy` on
    /// [`Runtime::transfers`].
    pub fn advance_batch(&self, slots: &mut [(&mut GenState<'_>, u32)],
                         mode: EstMode) -> Result<Vec<StepOut>> {
        let n = slots.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if n == 1 {
            let (gen, token) = slots.first_mut().expect("n == 1");
            let tok = *token;
            return Ok(vec![self.advance(&mut **gen, tok, mode)?]);
        }
        let (bucket, exe, args) = self
            .batched
            .iter()
            .find(|(b, _, _)| *b >= n)
            .ok_or_else(|| {
                anyhow!("no batched decode bucket fits {n} slots (max {})",
                        self.max_batch())
            })?;
        let b = *bucket;
        // ---- validate everything up front (atomicity on failure) ---------
        for (gen, _) in slots.iter() {
            if gen.pos + 1 >= self.cfg.max_seq {
                bail!("position {} at max_seq {}", gen.pos, self.cfg.max_seq);
            }
            if !gen.kv_on_device() {
                bail!("batched decode requires device-resident KV \
                       (tuple-lowered artifacts fall back to per-request steps)");
            }
        }
        // Batched graphs are exported at max shape only — migrate tiered
        // slots up before any inputs pack (DESIGN.md §Memory).  A failed
        // migration leaves every gen still valid at its old tier.
        for (gen, _) in slots.iter_mut() {
            self.ensure_tier(gen, self.cfg.max_seq)?;
        }
        // ---- pack per-slot inputs with a leading batch dim ---------------
        let l = self.cfg.n_layers;
        let half = self.cfg.head_dim() / 2;
        let mut tokens = vec![0i32; b];
        let mut poss = vec![0i32; b];
        let mut cos = vec![0f32; b * half];
        let mut sin = vec![0f32; b * half];
        let mut flags: HashMap<&str, Vec<f32>> = ASYNC_GROUPS
            .iter()
            .map(|g| (*g, vec![0f32; b * l]))
            .collect();
        for (i, (gen, token)) in slots.iter().enumerate() {
            tokens[i] = *token as i32;
            poss[i] = gen.pos as i32;
            let (c, s) = self.cfg.rope_tables(gen.pos);
            cos[i * half..(i + 1) * half].copy_from_slice(&c);
            sin[i * half..(i + 1) * half].copy_from_slice(&s);
            for g in ASYNC_GROUPS {
                let want = gen
                    .sel
                    .use_h_async
                    .get(g)
                    .ok_or_else(|| anyhow!("missing async flags for {g}"))?;
                flags.get_mut(g).expect("known group")[i * l..(i + 1) * l]
                    .copy_from_slice(want);
            }
        }
        // Pad slots keep token/pos 0 and zero flags: position 0 masks the
        // attention to a single (zeroed) KV entry, so the no-op slot can
        // never produce NaNs that XLA might propagate across the batch.
        let tok_buf = self.rt.upload_i32(&[b], &tokens)?;
        let pos_buf = self.rt.upload_i32(&[b], &poss)?;
        let cos_buf = self.rt.upload_f32(&[b, half], &cos)?;
        let sin_buf = self.rt.upload_f32(&[b, half], &sin)?;
        let mode_buf = self.mode_buffer(mode == EstMode::Exact)?;
        let mut flag_bufs: HashMap<&str, PjRtBuffer> = HashMap::new();
        for g in ASYNC_GROUPS {
            flag_bufs.insert(g, self.rt.upload_f32(&[b, l], &flags[g])?);
        }
        let pad = if n < b { Some(self.pad_kv_buffer()?) } else { None };

        let replica = {
            let mut arg_bufs: Vec<&PjRtBuffer> = Vec::with_capacity(args.len());
            for name in args {
                let buf: &PjRtBuffer = if let Some(i) = name
                    .strip_prefix("kv")
                    .and_then(|s| s.parse::<usize>().ok())
                {
                    if i < n {
                        match &slots[i].0.kv {
                            KvResidence::Device(kb) => kb,
                            KvResidence::Shared(rc) => rc.as_ref(),
                            KvResidence::Host(_) => {
                                unreachable!("validated device-resident above")
                            }
                        }
                    } else {
                        pad.as_ref().expect("pad buffer uploaded").as_ref()
                    }
                } else {
                    match name.as_str() {
                        "tokens" => &tok_buf,
                        "poss" => &pos_buf,
                        "cos" => &cos_buf,
                        "sin" => &sin_buf,
                        "mode_exact" => &*mode_buf,
                        other => flag_bufs
                            .get(other.strip_prefix("useh_").unwrap_or(other))
                            .or_else(|| self.static_bufs.get(other))
                            .ok_or_else(|| {
                                anyhow!("missing batched decode arg {other}")
                            })?,
                    }
                };
                arg_bufs.push(buf);
            }
            exe.run_buffers(&arg_bufs).context("batched decode step")?
        };
        if !exe.untupled(&replica) {
            bail!("batched graph lowered as a tuple — per-slot KV residency \
                   impossible; falling back to per-request steps");
        }
        // ---- read the small outputs, locate the per-slot KV leaves -------
        let v = self.cfg.vocab;
        let li = exe.output_index("logits")?;
        let logits_all = buffer_f32(&replica[li])?;
        self.rt.transfers().count_download();
        if logits_all.len() != b * v {
            bail!("batched logits: {} values for B={b} V={v}", logits_all.len());
        }
        let mut ests_all = BTreeMap::new();
        let mut use_all = BTreeMap::new();
        for g in GROUPS {
            let ei = exe.output_index(&format!("est_{g}"))?;
            let ui = exe.output_index(&format!("useh_{g}"))?;
            let e = buffer_f32(&replica[ei])?;
            let u = buffer_f32(&replica[ui])?;
            if e.len() != b * l || u.len() != b * l {
                bail!("batched {g} outputs: {}/{} values for B={b} L={l}",
                      e.len(), u.len());
            }
            ests_all.insert(g, e);
            use_all.insert(g, u);
        }
        let mut kv_slot_of = HashMap::new();
        for i in 0..n {
            kv_slot_of.insert(exe.output_index(&format!("kv{i}"))?, i);
        }
        let mut new_kvs: Vec<Option<PjRtBuffer>> = (0..n).map(|_| None).collect();
        for (oi, buf) in replica.into_iter().enumerate() {
            if let Some(&slot) = kv_slot_of.get(&oi) {
                new_kvs[slot] = Some(buf);
            }
        }
        if new_kvs.iter().any(|k| k.is_none()) {
            bail!("batched decode returned fewer KV leaves than slots");
        }
        // ---- commit: scatter outputs back to their generations -----------
        let mut outs = Vec::with_capacity(n);
        for (i, (gen, _)) in slots.iter_mut().enumerate() {
            let mut ests = BTreeMap::new();
            let mut use_eff = BTreeMap::new();
            for g in GROUPS {
                ests.insert(g.to_string(),
                            ests_all[g][i * l..(i + 1) * l].to_vec());
                use_eff.insert(g.to_string(),
                               use_all[g][i * l..(i + 1) * l].to_vec());
            }
            let out = StepOut {
                logits: logits_all[i * v..(i + 1) * v].to_vec(),
                ests,
                use_eff,
            };
            gen.kv = KvResidence::Device(new_kvs[i].take().expect("checked above"));
            gen.sel.observe(&out.ests, &out.use_eff);
            gen.pos += 1;
            gen.steps += 1;
            outs.push(out);
        }
        self.rt.transfers().count_batched_step(n as u64);
        Ok(outs)
    }

    /// Greedy argmax over logits.  NaN entries are skipped; empty or
    /// all-NaN logits are an error — silently emitting token 0 (the old
    /// behavior) corrupted generations downstream.
    pub fn argmax(logits: &[f32]) -> Result<u32> {
        if logits.is_empty() {
            bail!("argmax over empty logits");
        }
        let mut best: Option<usize> = None;
        for (i, &v) in logits.iter().enumerate() {
            if v.is_nan() {
                continue;
            }
            match best {
                Some(b) if v <= logits[b] => {}
                _ => best = Some(i),
            }
        }
        best.map(|b| b as u32)
            .ok_or_else(|| anyhow!("argmax over all-NaN logits"))
    }

    /// Host-visible device memory of the uploaded weight stacks (bytes) —
    /// used by the Table 9 memory-accounting bench.
    pub fn weight_bytes(&self) -> usize {
        let mut total = 0usize;
        for g in GROUPS {
            let (o, i) = self.cfg.group_shape(g);
            total += 2 * self.cfg.n_layers * o * i * 4; // wl + wh stacks
        }
        total
    }

    /// Bytes of one KV cache at this model's shape — the per-step traffic
    /// the device-resident path eliminates.
    pub fn kv_bytes(&self) -> usize {
        self.kv_zero.len() * 4
    }
}

pub fn wrap_err(e: impl std::fmt::Display) -> anyhow::Error {
    wrap(e)
}

/// Largest prompt length ingestible through chunked prefill with chunk
/// granularity `c` (the smallest chunk bucket) under `max_seq`.  Two
/// constraints: every chunk's *padded* bucket must fit under `max_seq`
/// (the chunk graph writes a bucket-sized KV span — rounding the prompt
/// up to `c` must not overrun), and one decode position must remain so
/// the first generated token can be fed back (`advance` requires
/// `pos + 1 < max_seq`).
pub fn max_chunked_prompt_len(max_seq: usize, c: usize) -> usize {
    if c == 0 {
        return 0;
    }
    let mut l = max_seq.saturating_sub(2);
    while l > 0 && (l + c - 1) / c * c > max_seq {
        l -= 1;
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(DecodeSession::argmax(&[0.1, 3.0, -1.0, 2.9]).unwrap(), 1);
        assert_eq!(DecodeSession::argmax(&[-5.0]).unwrap(), 0);
    }

    #[test]
    fn argmax_skips_nan() {
        assert_eq!(
            DecodeSession::argmax(&[f32::NAN, 1.0, 2.0, f32::NAN]).unwrap(),
            2
        );
        // NaN in first position must not poison the comparison chain.
        assert_eq!(DecodeSession::argmax(&[f32::NAN, -1.0]).unwrap(), 1);
    }

    #[test]
    fn argmax_rejects_empty_and_all_nan() {
        assert!(DecodeSession::argmax(&[]).is_err());
        assert!(DecodeSession::argmax(&[f32::NAN, f32::NAN]).is_err());
    }

    #[test]
    fn argmax_handles_neg_infinity() {
        assert_eq!(
            DecodeSession::argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY, -1.0])
                .unwrap(),
            2
        );
    }

    /// The chunked-prompt capacity bound: padded buckets must fit under
    /// max_seq and one decode slot must remain.
    #[test]
    fn max_chunked_prompt_len_bounds() {
        // max_seq a multiple of the granularity: only the decode slot
        // constrains (512 - 2).
        assert_eq!(max_chunked_prompt_len(512, 64), 510);
        // Non-multiple: the last chunk's padding must still fit — 540
        // rounds 480 < L <= 512 up to 512+ buckets... largest L with
        // roundup64(L) <= 540 is 512, and 512 <= 538.
        assert_eq!(max_chunked_prompt_len(540, 64), 512);
        // Decode-slot bound tighter than the padding bound.
        assert_eq!(max_chunked_prompt_len(128, 64), 126);
        // Degenerate inputs.
        assert_eq!(max_chunked_prompt_len(512, 0), 0);
        assert_eq!(max_chunked_prompt_len(0, 64), 0);
        assert_eq!(max_chunked_prompt_len(1, 64), 0);
        // Every admissible L really is ingestible: padded length fits.
        for max_seq in [130usize, 512, 700] {
            let l = max_chunked_prompt_len(max_seq, 64);
            assert!((l + 63) / 64 * 64 <= max_seq);
            assert!(l + 1 < max_seq);
        }
    }
}
